"""Train a ~100M-parameter model for a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_small.py --arch internlm2-1.8b \
        --steps 300 --layers 4 --d-model 512

Uses the production substrate end to end: the assigned-architecture model
family (scaled down by CLI flags), the synthetic Markov LM data pipeline,
AdamW + cosine schedule, and npz checkpointing with resume.
"""

import argparse
import dataclasses

import repro.configs  # noqa: F401  (registers archs)
from repro.data.synthetic import DataConfig
from repro.models.registry import arch_ids, build_model, get_config
from repro.optim.adamw import AdamW
from repro.training.loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=arch_ids())
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    heads = max(4, args.d_model // 64)
    kv = max(1, heads // max(1, cfg.num_heads // cfg.num_kv_heads))
    over = dict(
        num_layers=args.layers,
        d_model=args.d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=64,
        d_ff=4 * args.d_model if cfg.d_ff else 0,
        vocab_size=args.vocab,
    )
    if cfg.num_experts:
        over.update(num_experts=8, moe_top_k=2)
    if cfg.prefix_tokens:
        over.update(prefix_tokens=16, prefix_dim=128)
    elif cfg.prefix_dim:
        over.update(prefix_dim=128)
    cfg = dataclasses.replace(cfg, **over)

    model = build_model(cfg)
    n_params = model_param_count(model)
    print(f"{args.arch} (scaled): {n_params / 1e6:.1f}M params, "
          f"{args.layers}L d={args.d_model}")

    result = train(
        model,
        steps=args.steps,
        data_cfg=DataConfig(
            vocab_size=cfg.vocab_size,
            seq_len=args.seq_len,
            global_batch=args.batch,
            seed=0,
        ),
        optimizer=AdamW(learning_rate=args.lr),
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=100,
        log_every=20,
    )
    print(
        f"done: loss {result.losses[0]:.3f} -> {result.losses[-1]:.3f} "
        f"in {result.wall_s:.0f}s ({result.wall_s / args.steps * 1e3:.0f} ms/step)"
    )


def model_param_count(model) -> int:
    import jax

    import numpy as np

    abstract = model.abstract_params()
    return int(sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(abstract)))


if __name__ == "__main__":
    main()
