"""End-to-end driver: REAL serving with batched requests over trained models.

    PYTHONPATH=src python examples/serve_adaptive.py [--fast] [--workers c]
                                                     [--max-batch B] [--linger s]

This is the full Compass loop with nothing simulated:

1. trains three JAX transformer generators (small/medium/large) on the
   needle-QA task — bigger models genuinely reach higher accuracy;
2. COMPASS-V searches the live RAG pipeline (retriever -> reranker ->
   generator), where every accuracy sample is a real workflow execution;
3. the Planner profiles real wall-clock latency per configuration;
4. the threaded ServingEngine executes a Poisson-with-burst workload while
   Elastico switches the active configuration from real queue depth.
"""

import argparse
import statistics
import sys
import time

from repro.core.compass_v import CompassV
from repro.core.elastico import ElasticoController
from repro.core.planner import Planner
from repro.serving.engine import EngineReport, ServingEngine, replay_workload
from repro.serving.executor import WorkerPool, WorkflowExecutor
from repro.serving.scheduler import Scheduler
from repro.serving.workload import Request, bursty_pattern, generate_arrivals
from repro.workflows.rag import RagWorkflow


def _check_demo_api() -> None:
    """Fail loudly (not silently drift) if the engine/scheduler API this
    example demonstrates changes: every attribute the demo relies on is
    resolved up front, so a rename aborts with a clear message instead of
    a misleading mid-run failure."""
    required = [
        (ServingEngine, ["submit", "drain_and_stop", "start", "num_workers"]),
        (Scheduler, ["offer", "poll", "observe", "buffered"]),
        (WorkerPool, ["submit", "start", "stop", "mean_batch_size"]),
        (EngineReport, ["slo_compliance", "goodput", "mean_accuracy"]),
    ]
    for obj, attrs in required:
        for attr in attrs:
            if not hasattr(obj, attr):
                sys.exit(f"serve_adaptive demo is stale: {obj.__name__}.{attr} "
                         "no longer exists — update the example")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduce training/eval sizes")
    ap.add_argument("--duration", type=float, default=30.0)
    ap.add_argument("--workers", type=int, default=1,
                    help="worker-pool size c (1 = paper-faithful M/G/1)")
    ap.add_argument("--max-batch", type=int, default=1,
                    help="per-worker batch cap B (1 = unbatched; >1 drains "
                         "up to B requests per dequeue and derives "
                         "batch-aware thresholds)")
    ap.add_argument("--linger", type=float, default=0.0,
                    help="batch linger window in seconds (batch_timeout_s): "
                         "how long a worker holds a short batch open")
    args = ap.parse_args()
    _check_demo_api()

    print("=== 1. preparing the live RAG workflow (training generators) ===")
    t0 = time.time()
    wf = RagWorkflow(seed=0, log_fn=lambda s: print("   ", s))
    wf.prepare()
    print(f"    trained {len(wf._models)} generators in {time.time() - t0:.0f}s")

    print("=== 2. COMPASS-V over the live pipeline ===")
    budget = (6, 12, 24) if args.fast else (8, 16, 32)
    res = CompassV(
        space=wf.space,
        evaluator=wf.evaluate_samples,
        tau=0.5,
        budget_schedule=budget,
        seed=0,
    ).run()
    print(
        f"    {len(res.feasible)} feasible of {res.num_evaluations} evaluated "
        f"(space {wf.space.cardinality})"
    )
    if not res.feasible:
        sys.exit("no feasible configurations at tau=0.5")

    print("=== 3. Planner: wall-clock profiling on this host ===")
    # note: without a batch_profiler the Planner assumes the no-amortization
    # law (the python workflow here runs requests sequentially inside a
    # batch), so --max-batch keeps thresholds honest rather than optimistic;
    # a vectorized batch_workflow_fn + measured batch profiles is where the
    # real jax-level win comes from (see docs/batching.md).
    plan = Planner(
        profiler=wf.profile_latency,
        profile_samples=6 if args.fast else 10,
        num_servers=args.workers,
        max_batch_size=args.max_batch,
    ).plan(res.feasible, slo_p95_s=0.5)
    print(plan.describe())

    print(f"=== 4. threaded serving with Elastico (c = {args.workers}) ===")
    ladder = plan.table.policies
    configs = [p.point.config for p in ladder]
    accuracy = [p.point.accuracy for p in ladder]

    def wf_fn(config, payload):
        return wf.executor_fn(config, payload)

    # Scale load to REAL pool capacity.  The Planner profiles the pipeline
    # in isolation; under the threaded engine each request also pays queue /
    # GIL / control-loop overhead, and c workers do NOT scale c-fold for
    # GIL-bound stages — so calibrate against a measured *concurrent* warm-up
    # burst through the same WorkerPool machinery the engine uses and target
    # ~50% of the throughput it actually achieved.
    warm = WorkflowExecutor(configs=configs, workflow_fn=wf_fn)
    warm_pool = WorkerPool(warm, c=args.workers)
    n_warm = max(30, args.workers)
    t0 = time.time()
    warm_pool.start()
    for i in range(n_warm):
        warm_pool.submit(Request(request_id=i, arrival_s=0.0))
    deadline = time.time() + 60.0
    while len(warm.records) < n_warm and time.time() < deadline:
        time.sleep(0.002)
    warm_pool.stop()
    if len(warm.records) < n_warm:
        sys.exit(
            f"warm-up stalled: {len(warm.records)}/{n_warm} completed "
            "(a workflow error in a worker thread?)"
        )
    pool_qps = n_warm / (time.time() - t0)
    base_qps = 0.5 * min(pool_qps, args.workers / ladder[0].point.profile.mean)
    print(f"    calibrated pool throughput ~{pool_qps:.1f} QPS "
          f"(c={args.workers}) -> base load {base_qps:.1f} QPS")
    arrivals = generate_arrivals(
        bursty_pattern(base_qps, duration_s=args.duration, seed=0),
        args.duration,
        seed=0,
    )
    results = {}
    for name, ctrl, static in [
        ("elastico", ElasticoController(plan.table), 0),
        ("static-accurate", None, len(ladder) - 1),
    ]:
        executor = WorkflowExecutor(configs=configs, workflow_fn=wf_fn)
        if static:
            executor.set_active(static)
        engine = ServingEngine(executor, controller=ctrl, control_tick_s=0.02,
                               num_workers=args.workers,
                               max_batch_size=args.max_batch,
                               batch_timeout_s=args.linger)
        engine.start()
        replay_workload(engine, arrivals)
        report = engine.drain_and_stop()
        comp = report.slo_compliance(0.5)
        acc = report.mean_accuracy(accuracy)
        results[name] = (comp, acc, len(report.records))
        sw = len(ctrl.events) if ctrl else 0
        batch_note = (f" mean_batch={report.mean_batch_size:.2f}"
                      if args.max_batch > 1 else "")
        print(
            f"    {name:16s} served={len(report.records):4d} "
            f"compliance={comp * 100:5.1f}% accuracy={acc:.3f} switches={sw}"
            f"{batch_note}"
        )

    comp_e, acc_e, _ = results["elastico"]
    comp_a, acc_a, _ = results["static-accurate"]
    print(
        f"\nElastico vs static-accurate: compliance {comp_e - comp_a:+.1%}, "
        f"accuracy {acc_e - acc_a:+.3f}"
    )


if __name__ == "__main__":
    main()
