"""End-to-end driver: REAL serving with batched requests over trained models.

    PYTHONPATH=src python examples/serve_adaptive.py [--fast]

This is the full Compass loop with nothing simulated:

1. trains three JAX transformer generators (small/medium/large) on the
   needle-QA task — bigger models genuinely reach higher accuracy;
2. COMPASS-V searches the live RAG pipeline (retriever -> reranker ->
   generator), where every accuracy sample is a real workflow execution;
3. the Planner profiles real wall-clock latency per configuration;
4. the threaded ServingEngine executes a Poisson-with-burst workload while
   Elastico switches the active configuration from real queue depth.
"""

import argparse
import statistics
import sys
import time

from repro.core.compass_v import CompassV
from repro.core.elastico import ElasticoController
from repro.core.planner import Planner
from repro.serving.engine import ServingEngine, replay_workload
from repro.serving.executor import WorkflowExecutor
from repro.serving.workload import bursty_pattern, generate_arrivals
from repro.workflows.rag import RagWorkflow


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="reduce training/eval sizes")
    ap.add_argument("--duration", type=float, default=30.0)
    args = ap.parse_args()

    print("=== 1. preparing the live RAG workflow (training generators) ===")
    t0 = time.time()
    wf = RagWorkflow(seed=0, log_fn=lambda s: print("   ", s))
    wf.prepare()
    print(f"    trained {len(wf._models)} generators in {time.time() - t0:.0f}s")

    print("=== 2. COMPASS-V over the live pipeline ===")
    budget = (6, 12, 24) if args.fast else (8, 16, 32)
    res = CompassV(
        space=wf.space,
        evaluator=wf.evaluate_samples,
        tau=0.5,
        budget_schedule=budget,
        seed=0,
    ).run()
    print(
        f"    {len(res.feasible)} feasible of {res.num_evaluations} evaluated "
        f"(space {wf.space.cardinality})"
    )
    if not res.feasible:
        sys.exit("no feasible configurations at tau=0.5")

    print("=== 3. Planner: wall-clock profiling on this host ===")
    plan = Planner(
        profiler=wf.profile_latency, profile_samples=6 if args.fast else 10
    ).plan(res.feasible, slo_p95_s=0.5)
    print(plan.describe())

    print("=== 4. threaded serving with Elastico ===")
    ladder = plan.table.policies
    configs = [p.point.config for p in ladder]
    accuracy = [p.point.accuracy for p in ladder]

    def wf_fn(config, payload):
        return wf.executor_fn(config, payload)

    # Scale load to REAL engine capacity.  The Planner profiles the pipeline
    # in isolation; under the threaded engine each request also pays queue /
    # GIL / control-loop overhead, so calibrate against a measured engine
    # round: run a short warm-up burst and use its observed service rate.
    warm = WorkflowExecutor(configs=configs, workflow_fn=wf_fn)
    t0 = time.time()
    for i in range(30):
        warm.execute(i, 0.0, i)
    engine_service_s = (time.time() - t0) / 30
    base_qps = 0.5 / max(engine_service_s, ladder[0].point.profile.mean)
    print(f"    calibrated engine service ~{engine_service_s * 1e3:.1f}ms "
          f"-> base load {base_qps:.1f} QPS")
    arrivals = generate_arrivals(
        bursty_pattern(base_qps, duration_s=args.duration, seed=0),
        args.duration,
        seed=0,
    )
    results = {}
    for name, ctrl, static in [
        ("elastico", ElasticoController(plan.table), 0),
        ("static-accurate", None, len(ladder) - 1),
    ]:
        executor = WorkflowExecutor(configs=configs, workflow_fn=wf_fn)
        if static:
            executor.set_active(static)
        engine = ServingEngine(executor, controller=ctrl, control_tick_s=0.02)
        engine.start()
        replay_workload(engine, arrivals)
        report = engine.drain_and_stop()
        comp = report.slo_compliance(0.5)
        acc = report.mean_accuracy(accuracy)
        results[name] = (comp, acc, len(report.records))
        sw = len(ctrl.events) if ctrl else 0
        print(
            f"    {name:16s} served={len(report.records):4d} "
            f"compliance={comp * 100:5.1f}% accuracy={acc:.3f} switches={sw}"
        )

    comp_e, acc_e, _ = results["elastico"]
    comp_a, acc_a, _ = results["static-accurate"]
    print(
        f"\nElastico vs static-accurate: compliance {comp_e - comp_a:+.1%}, "
        f"accuracy {acc_e - acc_a:+.3f}"
    )


if __name__ == "__main__":
    main()
