"""Production-plane integration: Compass ladders over MODEL-SERVING configs.

    PYTHONPATH=src python examples/serving_ladders.py --arch granite-moe-3b-a800m

The paper's "compound AI configuration" generalizes, on the production plane,
to a *model serving configuration*: quantization dtype, attention window,
MoE top-k, batch cap.  This example builds each assigned architecture's
serving-config space, estimates per-config service time and relative accuracy
with the analytic roofline model (v5e constants), runs COMPASS-V + Planner on
it, and prints the AQM switching ladder that Elastico would use on the pod.

Everything is analytic (no TPU needed) but flows through the identical
pipeline as the live example — demonstrating the paper's technique as a
first-class feature of the serving framework.
"""

import argparse
import math

import repro.configs  # noqa: F401
from repro.core.compass_v import CompassV
from repro.core.planner import Planner
from repro.core.space import ConfigSpace, Parameter
from repro.launch.analytic import serving_config_costs
from repro.models.registry import arch_ids, get_config


def serving_space(cfg) -> ConfigSpace:
    params = [
        Parameter("quant", ("bf16", "int8"), kind="ordinal"),
        Parameter("batch_cap", (8, 16, 32), kind="ordinal"),
    ]
    if cfg.family not in ("ssm",):
        params.append(Parameter("window", (1024, 4096, 0), kind="ordinal"))  # 0=full
    if cfg.num_experts:
        ks = sorted({max(1, cfg.moe_top_k // 4), max(2, cfg.moe_top_k // 2), cfg.moe_top_k})
        params.append(Parameter("moe_top_k", tuple(ks), kind="ordinal"))
    return ConfigSpace(params)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-3b-a800m", choices=arch_ids())
    ap.add_argument("--slo-ms", type=float, default=30.0)
    ap.add_argument("--tau", type=float, default=0.9, help="relative accuracy floor")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    space = serving_space(cfg)
    print(f"{args.arch}: serving-config space of {space.cardinality} configs "
          f"({[p.name for p in space.parameters]})")

    def evaluate(config, idx):
        """Per-sample relative-accuracy draws from the analytic quality model."""
        d = space.as_dict(config)
        acc, _ = serving_config_costs(cfg, d)
        # deterministic Bernoulli-ish mixture so Wilson machinery is exercised
        out = []
        for i in idx:
            import zlib
            u = (zlib.crc32(repr((args.arch, sorted(d.items()), i)).encode()) & 0xFFFF) / 0xFFFF
            out.append(1.0 if u < acc else acc * 0.5)
        return out

    res = CompassV(
        space=space, evaluator=evaluate, tau=args.tau,
        budget_schedule=(16, 48, 128), seed=0,
    ).run()
    print(f"feasible: {len(res.feasible)}/{space.cardinality} at tau={args.tau}")
    if not res.feasible:
        return

    def profiler(config, n):
        d = space.as_dict(config)
        _, service_s = serving_config_costs(cfg, d)
        # deterministic-ish TPU service times: tight spread (see DESIGN §3)
        return [service_s * (1.0 + 0.03 * math.sin(i)) for i in range(n)]

    plan = Planner(profiler=profiler, slack_buffer_s=0.002).plan(
        res.feasible, slo_p95_s=args.slo_ms / 1e3
    )
    print(plan.describe())
    print("\nladder rungs (fast -> accurate):")
    for pol in plan.table.policies:
        d = space.as_dict(pol.point.config)
        print(f"  {d}  rel_acc={pol.point.accuracy:.3f} "
              f"service={pol.point.profile.mean * 1e3:.2f}ms N_up={pol.upscale_threshold}")


if __name__ == "__main__":
    main()
