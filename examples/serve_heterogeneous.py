"""Heterogeneous worker pools: mixes, switching, and in-worker batching.

    PYTHONPATH=src python examples/serve_heterogeneous.py [--servers 4]
                                                          [--max-batch 8]

A fast, fully deterministic demo (discrete-event simulator, no model
training) of the per-worker config-pinning runtime:

1. builds a synthetic three-rung Pareto ladder (fast/medium/accurate);
2. derives homogeneous Eq. 10/13 thresholds (``derive_policies``) and the
   heterogeneous mix ladder with Allen-Cunneen M/G/c thresholds
   (``derive_mix_policies``);
3. replays a flash-crowd trace against pools of the same size: static
   all-fast, homogeneous-switching Elastico, mix-shifting Elastico (one
   worker repinned per decision), and — with ``--max-batch > 1`` — a
   batching pool under batch-aware thresholds (an alpha-dominated
   ``alpha + beta*b`` service law; see docs/batching.md);
4. prints per-policy SLO compliance / accuracy, the mix trajectory, and
   the batching pool's realized mean batch size.
"""

import argparse

from repro.core.aqm import (
    HysteresisSpec,
    derive_mix_policies,
    derive_policies,
    mix_mean_wait,
)
from repro.core.elastico import ElasticoController, ElasticoMixController
from repro.core.pareto import BatchProfile, LatencyProfile, ParetoPoint
from repro.serving.simulator import ServingSimulator, lognormal_sampler_from_profile
from repro.serving.workload import flash_crowd_pattern, generate_arrivals

MEANS = [0.10, 0.25, 0.45]
P95S = [0.14, 0.35, 0.63]
ACCS = [0.76, 0.82, 0.85]
SLO_S = 1.0
DURATION_S = 120.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--servers", type=int, default=4, help="worker-pool size c")
    ap.add_argument("--base-qps", type=float, default=3.0)
    ap.add_argument("--max-batch", type=int, default=8,
                    help="per-worker batch cap B for the batching pool "
                         "(1 disables the batching comparison)")
    args = ap.parse_args()
    c = args.servers

    front = [
        ParetoPoint(config=("rung", i), accuracy=a,
                    profile=LatencyProfile(mean=m, p95=p))
        for i, (m, p, a) in enumerate(zip(MEANS, P95S, ACCS))
    ]
    hyst = HysteresisSpec(downscale_cooldown_s=5.0)
    table = derive_policies(front, slo_p95_s=SLO_S, hysteresis=hyst,
                            num_servers=c)
    mix_table = derive_mix_policies(front, slo_p95_s=SLO_S, hysteresis=hyst,
                                    num_servers=c)

    print(f"=== mix ladder (c = {c}, Allen-Cunneen M/G/c thresholds) ===")
    for mp in mix_table.policies:
        w = mix_mean_wait(mp, args.base_qps * 2)
        print(f"  [{mp.index}] {list(mp.assignment)}  mu={mp.drain_rate_qps:5.1f}/s "
              f"scv={mp.scv:.2f}  acc~{mp.expected_accuracy:.3f}  "
              f"N_up={mp.upscale_threshold:3d}  N_dn={mp.downscale_threshold}  "
              f"EW@{args.base_qps * 2:.0f}qps={w * 1e3:6.1f}ms")

    arrivals = generate_arrivals(
        flash_crowd_pattern(args.base_qps, peak_factor=10.0,
                            crowd_start_s=40.0, ramp_s=5.0, hold_s=20.0),
        DURATION_S, seed=1,
    )
    sampler = lognormal_sampler_from_profile(MEANS, P95S)

    runs = {
        "static-all-fast": ServingSimulator(
            sampler, assignment=[0] * c, seed=0, num_servers=c),
        "homogeneous-switching": ServingSimulator(
            sampler, controller=ElasticoController(table), seed=0,
            num_servers=c),
        "mix-shifting": ServingSimulator(
            sampler, controller=ElasticoMixController(mix_table), seed=0,
            num_servers=c),
    }
    print(f"\n=== flash crowd, {len(arrivals)} arrivals over {DURATION_S:.0f}s ===")
    outs = {}
    for name, sim in runs.items():
        out = sim.run(arrivals, DURATION_S)
        outs[name] = out
        print(f"  {name:22s} compliance={out.slo_compliance(SLO_S) * 100:5.1f}% "
              f"accuracy={out.mean_accuracy(ACCS):.3f} "
              f"p95={out.p95_latency() * 1e3:6.0f}ms "
              f"switches={len(out.switch_events)}")

    if args.max_batch > 1:
        # Batching is an *overload* tool: it trades per-request latency
        # (every batch member pays the whole batch's service time) for
        # drain rate, so it is demonstrated on a trace that swamps the
        # unbatched pool — 7x one server's fastest-rung capacity, beyond
        # what c unbatched workers can drain.
        batch_profiles = [BatchProfile(alpha=0.6 * m, beta=0.4 * m)
                          for m in MEANS]  # alpha-dominated: S(8) ~ 3.8 s-bar
        batched_table = derive_policies(
            front, slo_p95_s=SLO_S, hysteresis=hyst, num_servers=c,
            max_batch_size=args.max_batch, batch_profiles=batch_profiles)
        print(f"\n=== batch-aware thresholds (B = {args.max_batch}) ===")
        for pol, unb in zip(batched_table.policies, table.policies):
            print(f"  [{pol.index}] N_up {unb.upscale_threshold:3d} -> "
                  f"{pol.upscale_threshold:3d}  (deeper queue drains faster)")
        from repro.serving.workload import sustained_overload_pattern
        overload = generate_arrivals(
            sustained_overload_pattern(1.0 / MEANS[0], overload_factor=7.0,
                                       warmup_s=20.0), DURATION_S, seed=1)
        print(f"\n=== sustained overload (7x one-server capacity), "
              f"{len(overload)} arrivals ===")
        for name, sim in [
            ("unbatched", ServingSimulator(
                sampler, controller=ElasticoController(table), seed=0,
                num_servers=c)),
            (f"batched-B{args.max_batch}", ServingSimulator(
                sampler, controller=ElasticoController(batched_table), seed=0,
                num_servers=c, max_batch_size=args.max_batch,
                batch_timeout_s=0.005, batch_profiles=batch_profiles)),
        ]:
            out = sim.run(overload, DURATION_S)
            ok = sum(1 for r in out.completed if r.latency_s <= SLO_S)
            batch_note = (f" mean_batch={out.mean_batch_size():.2f}"
                          if sim.max_batch_size > 1 else "")
            print(f"  {name:22s} goodput={ok / len(overload) * 100:5.1f}% "
                  f"accuracy={out.mean_accuracy(ACCS):.3f} "
                  f"p95={out.p95_latency() * 1e3:6.0f}ms{batch_note}")

    mix = outs["mix-shifting"]
    print("\n=== mix trajectory (one worker repinned per event) ===")
    for t, vec in mix.assignment_timeline[:12]:
        print(f"  t={t:7.2f}s  {list(vec)}")
    if len(mix.assignment_timeline) > 12:
        print(f"  ... {len(mix.assignment_timeline) - 12} more repin events")


if __name__ == "__main__":
    main()
