"""Heterogeneous worker pools: mixes, switching, batching, and stealing.

    PYTHONPATH=src python examples/serve_heterogeneous.py [--servers 4]
                                                          [--max-batch 8]

A fast, fully deterministic demo (discrete-event simulator, no model
training) of the per-worker config-pinning runtime — every policy below
is one implementation, :class:`repro.serving.scheduler.Scheduler`, driven
here under virtual time and by the threaded engine under wall-clock time:

1. builds a synthetic three-rung Pareto ladder (fast/medium/accurate);
2. derives homogeneous Eq. 10/13 thresholds (``derive_policies``) and the
   heterogeneous mix ladder with Allen-Cunneen M/G/c thresholds
   (``derive_mix_policies``), which also emits the steal / re-route
   thresholds (see docs/scheduler.md);
3. replays a flash-crowd trace against pools of the same size: static
   all-fast, homogeneous-switching Elastico, mix-shifting Elastico (one
   worker repinned per decision), and — with ``--max-batch > 1`` — a
   batching pool under batch-aware thresholds (an alpha-dominated
   ``alpha + beta*b`` service law; see docs/batching.md);
4. demonstrates **work stealing** on per-worker backlogs (a skewed static
   pinning drowns its slow partition; stealing recovers the shared-queue
   ideal) and **mix-aware admission** (a tight bound re-routes to the
   all-fast mix before dropping);
5. prints per-policy SLO compliance / accuracy, the mix trajectory, and
   the batching pool's realized mean batch size.
"""

import argparse
import sys

from repro.core.aqm import (
    HysteresisSpec,
    derive_mix_policies,
    derive_policies,
    mix_mean_wait,
    steal_threshold,
)
from repro.core.elastico import ElasticoController, ElasticoMixController
from repro.core.pareto import BatchProfile, LatencyProfile, ParetoPoint
from repro.serving.scheduler import Scheduler
from repro.serving.simulator import ServingSimulator, lognormal_sampler_from_profile
from repro.serving.workload import (
    flash_crowd_pattern,
    generate_arrivals,
    sustained_overload_pattern,
)


def _check_demo_api() -> None:
    """Fail loudly (not silently drift) if the simulator/scheduler API this
    example demos changes: resolve every relied-upon attribute up front."""
    required = [
        (ServingSimulator, ["run"]),
        (Scheduler, ["offer", "poll", "observe", "on_linger_expired"]),
    ]
    for obj, attrs in required:
        for attr in attrs:
            if not hasattr(obj, attr):
                sys.exit(f"serve_heterogeneous demo is stale: "
                         f"{obj.__name__}.{attr} no longer exists — update "
                         "the example")
    for fld in ("dropped", "rerouted", "stolen_batches"):
        from repro.serving.simulator import SimulationResult
        if fld not in SimulationResult.__dataclass_fields__:
            sys.exit(f"serve_heterogeneous demo is stale: "
                     f"SimulationResult.{fld} no longer exists")

MEANS = [0.10, 0.25, 0.45]
P95S = [0.14, 0.35, 0.63]
ACCS = [0.76, 0.82, 0.85]
SLO_S = 1.0
DURATION_S = 120.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--servers", type=int, default=4, help="worker-pool size c")
    ap.add_argument("--base-qps", type=float, default=3.0)
    ap.add_argument("--max-batch", type=int, default=8,
                    help="per-worker batch cap B for the batching pool "
                         "(1 disables the batching comparison)")
    args = ap.parse_args()
    _check_demo_api()
    c = args.servers

    front = [
        ParetoPoint(config=("rung", i), accuracy=a,
                    profile=LatencyProfile(mean=m, p95=p))
        for i, (m, p, a) in enumerate(zip(MEANS, P95S, ACCS))
    ]
    hyst = HysteresisSpec(downscale_cooldown_s=5.0)
    table = derive_policies(front, slo_p95_s=SLO_S, hysteresis=hyst,
                            num_servers=c)
    mix_table = derive_mix_policies(front, slo_p95_s=SLO_S, hysteresis=hyst,
                                    num_servers=c)

    print(f"=== mix ladder (c = {c}, Allen-Cunneen M/G/c thresholds) ===")
    for mp in mix_table.policies:
        w = mix_mean_wait(mp, args.base_qps * 2)
        print(f"  [{mp.index}] {list(mp.assignment)}  mu={mp.drain_rate_qps:5.1f}/s "
              f"scv={mp.scv:.2f}  acc~{mp.expected_accuracy:.3f}  "
              f"N_up={mp.upscale_threshold:3d}  N_dn={mp.downscale_threshold}  "
              f"EW@{args.base_qps * 2:.0f}qps={w * 1e3:6.1f}ms")

    arrivals = generate_arrivals(
        flash_crowd_pattern(args.base_qps, peak_factor=10.0,
                            crowd_start_s=40.0, ramp_s=5.0, hold_s=20.0),
        DURATION_S, seed=1,
    )
    sampler = lognormal_sampler_from_profile(MEANS, P95S)

    runs = {
        "static-all-fast": ServingSimulator(
            sampler, assignment=[0] * c, seed=0, num_servers=c),
        "homogeneous-switching": ServingSimulator(
            sampler, controller=ElasticoController(table), seed=0,
            num_servers=c),
        "mix-shifting": ServingSimulator(
            sampler, controller=ElasticoMixController(mix_table), seed=0,
            num_servers=c),
    }
    print(f"\n=== flash crowd, {len(arrivals)} arrivals over {DURATION_S:.0f}s ===")
    outs = {}
    for name, sim in runs.items():
        out = sim.run(arrivals, DURATION_S)
        outs[name] = out
        print(f"  {name:22s} compliance={out.slo_compliance(SLO_S) * 100:5.1f}% "
              f"accuracy={out.mean_accuracy(ACCS):.3f} "
              f"p95={out.p95_latency() * 1e3:6.0f}ms "
              f"switches={len(out.switch_events)}")

    if args.max_batch > 1:
        # Batching is an *overload* tool: it trades per-request latency
        # (every batch member pays the whole batch's service time) for
        # drain rate, so it is demonstrated on a trace that swamps the
        # unbatched pool — 7x one server's fastest-rung capacity, beyond
        # what c unbatched workers can drain.
        batch_profiles = [BatchProfile(alpha=0.6 * m, beta=0.4 * m)
                          for m in MEANS]  # alpha-dominated: S(8) ~ 3.8 s-bar
        batched_table = derive_policies(
            front, slo_p95_s=SLO_S, hysteresis=hyst, num_servers=c,
            max_batch_size=args.max_batch, batch_profiles=batch_profiles)
        print(f"\n=== batch-aware thresholds (B = {args.max_batch}) ===")
        for pol, unb in zip(batched_table.policies, table.policies):
            print(f"  [{pol.index}] N_up {unb.upscale_threshold:3d} -> "
                  f"{pol.upscale_threshold:3d}  (deeper queue drains faster)")
        overload = generate_arrivals(
            sustained_overload_pattern(1.0 / MEANS[0], overload_factor=7.0,
                                       warmup_s=20.0), DURATION_S, seed=1)
        print(f"\n=== sustained overload (7x one-server capacity), "
              f"{len(overload)} arrivals ===")
        for name, sim in [
            ("unbatched", ServingSimulator(
                sampler, controller=ElasticoController(table), seed=0,
                num_servers=c)),
            (f"batched-B{args.max_batch}", ServingSimulator(
                sampler, controller=ElasticoController(batched_table), seed=0,
                num_servers=c, max_batch_size=args.max_batch,
                batch_timeout_s=0.005, batch_profiles=batch_profiles)),
        ]:
            out = sim.run(overload, DURATION_S)
            ok = sum(1 for r in out.completed if r.latency_s <= SLO_S)
            batch_note = (f" mean_batch={out.mean_batch_size():.2f}"
                          if sim.max_batch_size > 1 else "")
            print(f"  {name:22s} goodput={ok / len(overload) * 100:5.1f}% "
                  f"accuracy={out.mean_accuracy(ACCS):.3f} "
                  f"p95={out.p95_latency() * 1e3:6.0f}ms{batch_note}")

    # -- work stealing on per-worker backlogs ------------------------------
    # A skewed pinning under partitioned (round-robin) routing: the slow
    # workers' share alone overloads them while the fast workers idle.
    # Stealing (idle worker pulls from the globally deepest backlog, at the
    # aqm-derived threshold, serving stolen work under its OWN pin)
    # recovers the shared-queue ideal without giving up per-worker queues.
    skew = [0] * (c - c // 2) + [2] * (c // 2)
    n_steal = steal_threshold(front, skew, slo_p95_s=SLO_S)
    steal_arr = generate_arrivals(
        sustained_overload_pattern(1.0 / MEANS[0], overload_factor=1.8,
                                   warmup_s=20.0), DURATION_S, seed=1)
    print(f"\n=== work stealing: pinning {skew}, N_steal={n_steal}, "
          f"{len(steal_arr)} arrivals ===")
    for name, kw in [
        ("pinned-no-steal", dict(queue_discipline="per_worker")),
        ("pinned-steal", dict(queue_discipline="per_worker", steal=True,
                              steal_threshold=n_steal)),
        ("shared-queue", {}),
    ]:
        out = ServingSimulator(sampler, assignment=skew, seed=0,
                               num_servers=c, **kw).run(steal_arr, DURATION_S)
        print(f"  {name:22s} goodput={out.goodput(SLO_S) * 100:5.1f}% "
              f"accuracy={out.mean_accuracy(ACCS):.3f} "
              f"stolen={out.stolen_batches}")

    # -- mix-aware admission -----------------------------------------------
    # A tight admission bound clamps the observed depth below the mix
    # thresholds, so a plain bounded pool gets stuck mid-ladder dropping
    # through the whole crowd; re-routing to the all-fast state before
    # rejecting converts most drops into served requests.
    crowd = generate_arrivals(
        flash_crowd_pattern(args.base_qps, peak_factor=15.0,
                            crowd_start_s=40.0, ramp_s=1.0, hold_s=25.0),
        DURATION_S, seed=1)
    print(f"\n=== mix-aware admission: bound 8, {len(crowd)} arrivals "
          f"(reroute cap N_up[0]={mix_table.reroute_threshold}) ===")
    for name, reroute in [("bounded-drop", False), ("bounded-reroute", True)]:
        out = ServingSimulator(
            sampler, controller=ElasticoMixController(mix_table), seed=0,
            num_servers=c, max_queue_depth=8, admission_reroute=reroute,
        ).run(crowd, DURATION_S)
        print(f"  {name:22s} goodput={out.goodput(SLO_S) * 100:5.1f}% "
              f"dropped={out.dropped:4d} rerouted={out.rerouted}")

    mix = outs["mix-shifting"]
    print("\n=== mix trajectory (one worker repinned per event) ===")
    for t, vec in mix.assignment_timeline[:12]:
        print(f"  t={t:7.2f}s  {list(vec)}")
    if len(mix.assignment_timeline) > 12:
        print(f"  ... {len(mix.assignment_timeline) - 12} more repin events")


if __name__ == "__main__":
    main()
