"""Quickstart: the complete Compass pipeline in ~60 seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. COMPASS-V searches the paper's RAG configuration space (360 configs) for
   everything meeting the accuracy threshold tau.
2. The Planner profiles the feasible set, builds the Pareto ladder and
   derives AQM switching thresholds for a P95 latency SLO.
3. Elastico serves a 3-minute spike workload in the discrete-event server,
   switching configurations to hold the SLO, and is compared against the
   static baselines.
"""

import random
import statistics

from repro.core.compass_v import CompassV
from repro.core.elastico import ElasticoController
from repro.core.planner import Planner
from repro.serving.simulator import ServingSimulator
from repro.serving.workload import generate_arrivals, spike_pattern
from repro.workflows.surrogate import RagSurrogate

TAU = 0.75          # minimum acceptable F1
SLO_S = 1.0         # P95 latency SLO (seconds)


def main() -> None:
    surrogate = RagSurrogate(seed=0)

    # ---- offline phase 1: task optimization (COMPASS-V, paper §IV) --------
    result = CompassV(
        space=surrogate.space,
        evaluator=surrogate,
        tau=TAU,
        budget_schedule=(10, 25, 50, 100),
        seed=0,
    ).run()
    print(
        f"COMPASS-V: {len(result.feasible)} feasible configs "
        f"({result.num_evaluations}/{surrogate.space.cardinality} evaluated, "
        f"{result.savings_vs_exhaustive(surrogate.space, 100) * 100:.1f}% sample savings)"
    )

    # ---- offline phase 2: deployment planning (Planner + AQM, paper §V) ---
    def profiler(config, n):
        import zlib
        rng = random.Random(zlib.crc32(repr(config).encode()) & 0xFFFF)
        m = surrogate.mean_latency_s(config)
        return [max(1e-4, rng.gauss(m, 0.25 * m)) for _ in range(n)]

    plan = Planner(profiler=profiler).plan(result.feasible, slo_p95_s=SLO_S)
    print("\nDeployment plan:")
    print(plan.describe())

    # ---- online phase: Elastico under a 4x load spike (paper §VI-C) -------
    arrivals = generate_arrivals(spike_pattern(1.5, factor=4.0), 180.0, seed=1)
    ladder = plan.table.policies

    def sampler(idx, rng):
        m = surrogate.mean_latency_s(ladder[idx].point.config)
        return max(1e-4, rng.gauss(m, 0.25 * m))

    print(f"\nServing {len(arrivals)} requests (spike pattern, {SLO_S * 1e3:.0f}ms SLO):")
    print(f"{'variant':18s} {'compliance':>10s} {'accuracy':>9s} {'p95 ms':>8s} {'switches':>8s}")
    for name, ctrl, static in [
        ("elastico", ElasticoController(plan.table), 0),
        ("static-fast", None, 0),
        ("static-accurate", None, len(ladder) - 1),
    ]:
        sim = ServingSimulator(sampler, controller=ctrl, static_index=static, seed=2)
        out = sim.run(arrivals, 180.0)
        acc = statistics.mean(ladder[r.config_index].point.accuracy for r in out.completed)
        print(
            f"{name:18s} {out.slo_compliance(SLO_S) * 100:9.1f}% {acc:9.3f} "
            f"{out.p95_latency() * 1e3:8.0f} {len(out.switch_events):8d}"
        )


if __name__ == "__main__":
    main()
