"""Discrete-event M/G/1 serving simulator + workload generators (paper §VI-C)."""

import math
import statistics

import pytest
from proptest import given, settings, st

from repro.core.aqm import HysteresisSpec, derive_policies
from repro.core.elastico import ElasticoController
from repro.serving.simulator import ServingSimulator
from repro.serving.workload import (
    bursty_pattern,
    constant_rate,
    diurnal_pattern,
    generate_arrivals,
    spike_pattern,
)

from conftest import synthetic_point


def ladder_table(**hyst):
    front = [
        synthetic_point(0.10, 0.14, 0.76, "fast"),
        synthetic_point(0.25, 0.35, 0.82, "medium"),
        synthetic_point(0.45, 0.63, 0.85, "accurate"),
    ]
    return derive_policies(
        front, slo_p95_s=1.0, hysteresis=HysteresisSpec(**hyst)
    )


MEANS = [0.10, 0.25, 0.45]


def deterministic_sampler(idx, rng):
    return MEANS[idx]


# -- workload generators -------------------------------------------------------


def test_constant_rate_mean_count():
    arr = generate_arrivals(constant_rate(10.0), 100.0, seed=0)
    # Poisson(1000): mean 1000, sd ~32
    assert 870 <= len(arr) <= 1130
    assert all(0 <= t <= 100.0 for t in arr)
    assert arr == sorted(arr)


def test_arrivals_reproducible_by_seed():
    f = spike_pattern(2.0)
    assert generate_arrivals(f, 60, seed=4) == generate_arrivals(f, 60, seed=4)
    assert generate_arrivals(f, 60, seed=4) != generate_arrivals(f, 60, seed=5)


def test_spike_pattern_shape():
    f = spike_pattern(1.5, factor=4.0, duration_s=180.0)
    assert math.isclose(f(10.0), 1.5)          # before spike
    assert math.isclose(f(90.0), 6.0)          # middle third
    assert math.isclose(f(170.0), 1.5)         # after


def test_bursty_pattern_bounded():
    f = bursty_pattern(1.5, seed=0, burst_factor_range=(2.0, 5.0))
    rates = [f(t / 10) for t in range(1800)]
    assert min(rates) >= 1.5 - 1e-9
    assert max(rates) <= 1.5 * 5.0 + 1e-9
    assert max(rates) > 1.5  # bursts actually occur


def test_diurnal_pattern_positive():
    f = diurnal_pattern(1.5)
    assert all(f(t) > 0 for t in range(0, 200, 5))


# -- simulator invariants -------------------------------------------------------


def test_all_requests_complete_and_fifo():
    arr = generate_arrivals(constant_rate(3.0), 60.0, seed=1)
    sim = ServingSimulator(deterministic_sampler, static_index=0, seed=0)
    out = sim.run(arr, 60.0)
    assert len(out.completed) == len(arr)
    starts = [r.start_s for r in sorted(out.completed, key=lambda r: r.arrival_s)]
    assert starts == sorted(starts)  # FIFO, no preemption
    for r in out.completed:
        assert r.completion_s >= r.start_s >= r.arrival_s


def test_low_load_deterministic_service_no_wait():
    """lambda * s = 0.1: waits should be ~0 and latency == service time."""
    arr = [float(i) for i in range(30)]  # 1 QPS deterministic spacing
    sim = ServingSimulator(deterministic_sampler, static_index=0, seed=0)
    out = sim.run(arr, 40.0)
    for r in out.completed:
        assert r.wait_s == pytest.approx(0.0, abs=1e-9)
        assert r.latency_s == pytest.approx(0.10, abs=1e-9)


def test_overload_builds_queue():
    """Static accurate config at 5 QPS (rho = 2.25): latency must blow up."""
    arr = generate_arrivals(constant_rate(5.0), 60.0, seed=2)
    sim = ServingSimulator(deterministic_sampler, static_index=2, seed=0)
    out = sim.run(arr, 60.0)
    assert out.slo_compliance(1.0) < 0.5
    assert out.p95_latency() > 5.0


def test_static_vs_elastico_under_spike():
    """The paper's core claim (Fig. 5): Elastico beats static-accurate on
    compliance and static-fast on accuracy."""
    arr = generate_arrivals(spike_pattern(2.0, factor=4.0), 180.0, seed=1)
    accs = [0.76, 0.82, 0.85]

    def run(ctrl, static=0):
        sim = ServingSimulator(
            deterministic_sampler, controller=ctrl, static_index=static, seed=0
        )
        out = sim.run(arr, 180.0)
        acc = statistics.mean(accs[r.config_index] for r in out.completed)
        return out.slo_compliance(1.0), acc

    comp_e, acc_e = run(ElasticoController(ladder_table()))
    comp_f, acc_f = run(None, static=0)
    comp_a, acc_a = run(None, static=2)

    assert comp_e > comp_a + 0.3       # >> static-accurate compliance
    assert acc_e > acc_f + 0.005       # > static-fast accuracy
    assert comp_e > 0.85               # paper: 90-98% band


def test_switch_latency_counts():
    arr = generate_arrivals(spike_pattern(3.0, factor=4.0), 120.0, seed=3)
    ctrl = ElasticoController(ladder_table())
    sim = ServingSimulator(deterministic_sampler, controller=ctrl, seed=0)
    out = sim.run(arr, 120.0)
    assert len(out.switch_events) >= 1
    # timeline covers the full horizon and uses valid indices
    for t, idx in out.config_timeline:
        assert 0 <= idx < 3


def test_queue_depth_samples_nonnegative():
    arr = generate_arrivals(constant_rate(8.0), 30.0, seed=0)
    sim = ServingSimulator(deterministic_sampler, static_index=1, seed=0)
    out = sim.run(arr, 30.0)
    assert all(d >= 0 for _, d in out.queue_depth_samples)


def test_result_metrics_consistency():
    arr = generate_arrivals(constant_rate(2.0), 30.0, seed=0)
    sim = ServingSimulator(deterministic_sampler, static_index=0, seed=0)
    out = sim.run(arr, 30.0)
    lats = out.latencies()
    assert len(lats) == len(out.completed)
    assert 0.0 <= out.slo_compliance(1.0) <= 1.0
    assert out.slo_compliance(1e9) == 1.0
    assert out.slo_compliance(1e-9) == 0.0


@given(st.integers(0, 2**16), st.floats(1.0, 6.0))
@settings(max_examples=20, deadline=None)
def test_conservation_property(seed, qps):
    """Every arrival is eventually completed exactly once, any load/seed."""
    arr = generate_arrivals(constant_rate(qps), 20.0, seed=seed)
    ctrl = ElasticoController(ladder_table())
    sim = ServingSimulator(deterministic_sampler, controller=ctrl, seed=seed)
    out = sim.run(arr, 20.0)
    assert len(out.completed) == len(arr)
    ids = [r.request_id for r in out.completed]
    assert len(set(ids)) == len(ids)
