"""Sharded lowering smoke (deliverable e, reduced scale).

The production dry-run needs 512 forced host devices, which must be set
before jax initializes — so these tests run ``repro.launch.dryrun`` machinery
in a SUBPROCESS with a smaller forced device count and reduced configs.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, timeout=900) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )


@pytest.mark.slow
def test_reduced_dryrun_all_kinds_on_8_devices():
    """Every step kind (train/prefill/decode) lowers + compiles on a 2x4 mesh
    with reduced configs, through the exact production code path."""
    proc = run_py(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json, dataclasses, jax
        from repro.configs import INPUT_SHAPES
        from repro.configs.reduced import reduced_config
        from repro.launch.dryrun import lower_case

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        out = {}
        cases = [
            ("internlm2-1.8b", "train_4k"),
            ("granite-moe-3b-a800m", "train_4k"),
            ("xlstm-1.3b", "prefill_32k"),
            ("hymba-1.5b", "decode_32k"),
        ]
        for arch, shape_name in cases:
            cfg = reduced_config(arch)
            shape = INPUT_SHAPES[shape_name]
            small = dataclasses.replace(
                shape, seq_len=128, global_batch=8
            )
            import repro.launch.dryrun as DR
            orig = DR.INPUT_SHAPES[shape_name]
            DR.INPUT_SHAPES[shape_name] = small
            try:
                lowered, meta = lower_case(arch, shape_name, mesh=mesh, cfg=cfg)
                compiled = lowered.compile()
                ca = compiled.cost_analysis()
                out[f"{arch}/{shape_name}"] = float(ca.get("flops", -1.0))
            finally:
                DR.INPUT_SHAPES[shape_name] = orig
        print("RESULT::" + json.dumps(out))
        """
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT::")][0]
    res = json.loads(line[len("RESULT::"):])
    assert len(res) == 4
    for k, flops in res.items():
        assert flops > 0, k


@pytest.mark.slow
def test_production_mesh_shapes():
    proc = run_py(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import jax
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        print("RESULT::", m1.devices.shape, m1.axis_names, m2.devices.shape, m2.axis_names)
        """
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = [l for l in proc.stdout.splitlines() if l.startswith("RESULT::")][0]
    assert "(16, 16)" in out and "('data', 'model')" in out
    assert "(2, 16, 16)" in out and "('pod', 'data', 'model')" in out


def test_dryrun_results_file_covers_all_pairs():
    """The committed dry-run artifact must cover 10 archs x 4 shapes x 2
    meshes with no errors (deliverable e evidence)."""
    path = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun_results.jsonl")
    assert os.path.exists(path), "run: PYTHONPATH=src python -m repro.launch.dryrun"
    rows = [json.loads(l) for l in open(path)]
    pairs = {(r["arch"], r["shape"], r["mesh"]) for r in rows}
    assert len(pairs) >= 80
    archs = {r["arch"] for r in rows}
    assert len(archs) == 10
    for r in rows:
        assert "error" not in r, r.get("arch")
        assert r["compute_s"] > 0
        assert r["bottleneck"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_train_launcher_subprocess():
    """The distributed training launcher runs sharded steps end to end."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "internlm2-1.8b",
         "--reduced", "--steps", "4", "--devices", "8", "--mesh", "2x4",
         "--log-every", "2"],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "loss" in proc.stdout


@pytest.mark.slow
def test_serve_launcher_subprocess():
    """The serving launcher compiles two configs and switches between them."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "internlm2-1.8b",
         "--devices", "8", "--mesh", "2x4", "--tokens", "9"],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "switch accurate -> fast" in proc.stdout
    assert "decoded 9 tokens" in proc.stdout
