"""Heterogeneous worker pools: Allen-Cunneen M/G/c, mix-policy derivation,
per-worker config pinning in the simulator/engine, and the mix controller."""

import math
import time

import pytest

from proptest import given, settings, st

from repro.core.aqm import (
    HysteresisSpec,
    allen_cunneen_mean_wait,
    derive_mix_policies,
    derive_policies,
    erlang_c_mean_wait,
    mix_aggregates,
    mix_ladder,
    mix_ladder_is_monotone,
    mix_mean_wait,
)
from repro.core.elastico import ElasticoController, ElasticoMixController
from repro.core.planner import Planner
from repro.serving.engine import ServingEngine
from repro.serving.executor import WorkerPool, WorkflowExecutor
from repro.serving.simulator import (
    ServingSimulator,
    lognormal_sampler_from_profile,
)
from repro.serving.workload import (
    Request,
    constant_rate,
    generate_arrivals,
    sustained_overload_pattern,
)

from conftest import synthetic_point

MEANS = [0.10, 0.25, 0.45]
P95S = [0.14, 0.35, 0.63]
ACCS = [0.76, 0.82, 0.85]


def ladder_front():
    return [
        synthetic_point(m, p, a, f"c{i}")
        for i, (m, p, a) in enumerate(zip(MEANS, P95S, ACCS))
    ]


def mix_table_for(c, scv=None, **hyst):
    return derive_mix_policies(
        ladder_front(), slo_p95_s=1.0, hysteresis=HysteresisSpec(**hyst),
        num_servers=c, scv=scv,
    )


# -- Allen-Cunneen -------------------------------------------------------------


@given(st.integers(1, 8), st.floats(0.05, 0.95))
@settings(max_examples=40, deadline=None)
def test_allen_cunneen_collapses_to_erlang_c_at_scv_one(c, rho):
    """SCV = 1 (exponential service) must reproduce the M/M/c Erlang-C wait
    bit-for-bit: Allen-Cunneen's variability factor is exactly 1 there."""
    s = 0.2
    lam = rho * c / s
    assert allen_cunneen_mean_wait(c, lam, s, scv_service=1.0) == \
        erlang_c_mean_wait(c, lam, s)


def test_allen_cunneen_m_g_1_is_pollaczek_khinchine():
    """c=1, Poisson arrivals: E[W] = rho*s/(1-rho) * (1+C_s^2)/2 — the exact
    P-K mean wait, for any SCV."""
    s, rho = 0.2, 0.6
    lam = rho / s
    for scv in (0.0, 0.5, 1.0, 2.5, 4.0):
        want = rho * s / (1.0 - rho) * 0.5 * (1.0 + scv)
        got = allen_cunneen_mean_wait(1, lam, s, scv_service=scv)
        assert got == pytest.approx(want, rel=1e-12)


def test_allen_cunneen_variability_scaling_and_saturation():
    base = erlang_c_mean_wait(3, 10.0, 0.2)
    assert allen_cunneen_mean_wait(3, 10.0, 0.2, scv_service=4.0) == \
        pytest.approx(2.5 * base, rel=1e-12)
    assert allen_cunneen_mean_wait(3, 10.0, 0.2, scv_service=0.0) == \
        pytest.approx(0.5 * base, rel=1e-12)      # deterministic service
    assert allen_cunneen_mean_wait(2, 100.0, 0.2, scv_service=3.0) == \
        float("inf")
    with pytest.raises(ValueError):
        allen_cunneen_mean_wait(2, 1.0, 0.2, scv_service=-1.0)


# -- mix ladder & aggregates ---------------------------------------------------


@given(st.integers(1, 4), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_mix_ladder_shape(n, c):
    states = mix_ladder(n, c)
    assert len(states) == (n - 1) * c + 1
    assert states[0] == tuple([0] * c)
    assert states[-1] == tuple([n - 1] * c)
    for u, v in zip(states, states[1:]):
        assert sum(1 for a, b in zip(u, v) if a != b) == 1  # one-worker shift
        assert sum(v) == sum(u) + 1                          # one rung slower
        assert tuple(sorted(u)) == u                         # ascending


def test_mix_aggregates_homogeneous_and_blend():
    front = ladder_front()
    mu, s_eff, scv, p95, acc = mix_aggregates(front, (0, 0, 0, 0))
    assert mu == pytest.approx(4.0 / MEANS[0])
    assert s_eff == pytest.approx(MEANS[0])
    assert scv == pytest.approx(1.0)      # synthetic profiles: exponential
    assert p95 == P95S[0]
    assert acc == pytest.approx(ACCS[0])

    mu, s_eff, scv, p95, acc = mix_aggregates(front, (0, 0, 1, 1))
    assert mu == pytest.approx(2.0 / MEANS[0] + 2.0 / MEANS[1])
    assert s_eff == pytest.approx(4.0 / mu)
    assert p95 == P95S[1]                 # worst pinned tail
    share_fast = 2.0 * (1.0 / MEANS[0]) / mu   # two fast workers' drain share
    assert acc == pytest.approx(share_fast * ACCS[0] + (1 - share_fast) * ACCS[1])
    assert scv > 1.0                      # mixture of unequal means: extra spread


# -- mix thresholds ------------------------------------------------------------


@given(st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_all_same_mix_thresholds_match_homogeneous(c):
    """Collapse property: every all-same-config mix state has the exact
    homogeneous Eq. 10 upscale threshold (SCV=1 -> phi=1, mu_agg=c/s)."""
    hom = derive_policies(ladder_front(), slo_p95_s=1.0, num_servers=c)
    mix = mix_table_for(c)
    for k, pol in enumerate(hom.policies):
        state = next(p for p in mix.policies
                     if set(p.assignment) == {k})
        assert state.upscale_threshold == pol.upscale_threshold


def test_mix_ladder_monotone_thresholds_and_waits():
    """Adding one fast worker never lowers the tolerable depth and never
    raises the predicted stationary wait."""
    table = mix_table_for(4)
    assert mix_ladder_is_monotone(table)
    lam = 6.0  # stable even for the all-accurate mix (mu = 8.9/s)
    waits = [mix_mean_wait(p, lam) for p in table.policies]
    assert all(a <= b + 1e-12 for a, b in zip(waits, waits[1:]))
    accs = [p.expected_accuracy for p in table.policies]
    assert all(a < b for a, b in zip(accs, accs[1:]))  # slower = more accurate


def test_mix_ladder_monotone_with_heterogeneous_scv():
    """Monotonicity survives per-config SCVs measured off-profile (heavier
    fast-config tails)."""
    table = mix_table_for(4, scv=[2.0, 1.5, 1.2])
    assert mix_ladder_is_monotone(table)


def test_mix_table_c1_equals_homogeneous_ladder():
    """One worker: the mix ladder degenerates to the plain Pareto ladder."""
    hom = derive_policies(ladder_front(), slo_p95_s=1.0)
    mix = mix_table_for(1)
    assert mix.ladder_size == hom.ladder_size
    for mp, hp in zip(mix.policies, hom.policies):
        assert mp.assignment == (hp.index,)
        assert mp.upscale_threshold == hp.upscale_threshold


def test_derive_mix_policies_validation():
    with pytest.raises(ValueError):
        derive_mix_policies(ladder_front(), slo_p95_s=0.0, num_servers=2)
    with pytest.raises(ValueError):
        derive_mix_policies(ladder_front(), slo_p95_s=1.0, num_servers=0)
    with pytest.raises(ValueError):
        derive_mix_policies(ladder_front(), slo_p95_s=1.0, num_servers=2,
                            scv=[1.0])  # wrong length
    # SLO below every p95: empty ladder, everything excluded
    empty = derive_mix_policies(ladder_front(), slo_p95_s=0.05, num_servers=2)
    assert empty.ladder_size == 0
    assert len(empty.excluded) == 3


# -- simulator: assignment vectors ---------------------------------------------


def test_all_same_assignment_reproduces_homogeneous_golden():
    """Golden equivalence: a static all-same assignment vector must take the
    same code path as the homogeneous simulator — identical completions,
    busy time, and depth samples for every rung (PR 1 behavior preserved)."""
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    arr = generate_arrivals(
        sustained_overload_pattern(1.0 / MEANS[0], overload_factor=2.5,
                                   warmup_s=20.0), 120.0, seed=1)
    for k in range(3):
        hom = ServingSimulator(sampler, static_index=k, seed=0,
                               num_servers=4).run(arr, 120.0)
        het = ServingSimulator(sampler, assignment=[k] * 4, seed=0,
                               num_servers=4).run(arr, 120.0)
        assert het.completed == hom.completed
        assert het.per_server_busy_s == hom.per_server_busy_s
        assert het.queue_depth_samples == hom.queue_depth_samples
        assert het.assignment_timeline == [(0.0, (k,) * 4)]
        assert hom.assignment_timeline == []


def test_static_heterogeneous_mix_blends_configs():
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    arr = generate_arrivals(constant_rate(12.0), 60.0, seed=3)
    out = ServingSimulator(sampler, assignment=[0, 0, 1, 2], seed=0,
                           num_servers=4).run(arr, 60.0)
    assert len(out.completed) == len(arr)
    served_cfgs = {r.config_index for r in out.completed}
    assert served_cfgs == {0, 1, 2}
    # per-server pinning respected: server i always serves assignment[i]
    pin = [0, 0, 1, 2]
    for r in out.completed:
        assert r.config_index == pin[r.server_id]
    acc = out.mean_accuracy(ACCS)
    assert ACCS[0] < acc < ACCS[2]


def test_simulator_rejects_bad_assignment_length():
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    sim = ServingSimulator(sampler, assignment=[0, 1], num_servers=4)
    with pytest.raises(ValueError):
        sim.run([0.1, 0.2], 1.0)


def test_simulator_rejects_negative_assignment_index():
    """Negative indices would silently alias Python's tail indexing inside
    the sampler and corrupt config_index accounting — must raise up front."""
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    sim = ServingSimulator(sampler, assignment=[-1, 0, 0, 0], num_servers=4)
    with pytest.raises(IndexError):
        sim.run([0.1, 0.2], 1.0)


def test_simulator_rejects_assignment_with_controller():
    """A static pinning under any controller would be silently dead (the
    controller's switches could never reach pinned servers) — must raise."""
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    hom = ElasticoController(derive_policies(ladder_front(), slo_p95_s=1.0,
                                             num_servers=4))
    sim = ServingSimulator(sampler, controller=hom, assignment=[0, 0, 1, 2],
                           num_servers=4)
    with pytest.raises(ValueError, match="static runs"):
        sim.run([0.1], 1.0)
    mix = ElasticoMixController(mix_table_for(4))
    sim = ServingSimulator(sampler, controller=mix, assignment=[0, 0, 1, 2],
                           num_servers=4)
    with pytest.raises(ValueError, match="static runs"):
        sim.run([0.1], 1.0)


def test_engine_rejects_assignment_with_controller():
    executor = WorkflowExecutor(configs=[("cfg", i) for i in range(3)],
                                workflow_fn=sleep_workflow)
    hom = ElasticoController(derive_policies(ladder_front(), slo_p95_s=1.0,
                                             num_servers=2))
    with pytest.raises(ValueError, match="static runs"):
        ServingEngine(executor, controller=hom, num_workers=2,
                      assignment=[0, 1])


def test_mix_controller_shifts_one_worker_at_a_time():
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    arr = generate_arrivals(
        sustained_overload_pattern(1.0 / MEANS[0], overload_factor=2.5,
                                   warmup_s=20.0), 120.0, seed=1)
    table = mix_table_for(4, downscale_cooldown_s=5.0)
    out = ServingSimulator(sampler, controller=ElasticoMixController(table),
                           seed=0, num_servers=4).run(arr, 120.0)
    assert len(out.completed) == len(arr)
    tl = out.assignment_timeline
    assert tl[0] == (0.0, (2, 2, 2, 2))   # starts all-accurate
    assert len(tl) > 1                    # overload forces repinning
    for (_, u), (_, v) in zip(tl, tl[1:]):
        assert sum(1 for a, b in zip(u, v) if a != b) == 1
    # under sustained overload the mix must stay SLO-compliant while beating
    # the all-fast accuracy floor
    assert out.slo_compliance(1.0) > 0.95
    assert out.mean_accuracy(ACCS) > ACCS[0]


def test_mix_controller_requires_mix_table():
    hom = derive_policies(ladder_front(), slo_p95_s=1.0, num_servers=4)
    with pytest.raises(TypeError):
        ElasticoMixController(hom)


# -- planner integration -------------------------------------------------------


def test_planner_derives_mix_table_for_pools(rag_plan):
    from conftest import make_profiler
    from repro.workflows.surrogate import RagSurrogate

    res, _ = rag_plan
    plan = Planner(profiler=make_profiler(RagSurrogate(seed=0)),
                   num_servers=4).plan(res.feasible, slo_p95_s=1.0)
    assert plan.mix_table is not None
    assert plan.mix_table.num_servers == 4
    expect = (plan.table.ladder_size - 1) * 4 + 1
    assert plan.mix_table.ladder_size == expect
    # SCVs come from the measured profiles, not the exponential fallback
    assert any(abs(p.scv - 1.0) > 1e-6 for p in plan.mix_table.policies)
    assert "mix ladder" in plan.describe()
    # default: no mix table for single-server plans
    single = Planner(profiler=make_profiler(RagSurrogate(seed=0))).plan(
        res.feasible, slo_p95_s=1.0)
    assert single.mix_table is None


# -- real-time worker pool pinning ---------------------------------------------


def sleep_workflow(config, payload):
    time.sleep(0.002)
    return payload


def test_worker_pool_assignment_pins_configs():
    executor = WorkflowExecutor(configs=[("cfg", 0), ("cfg", 1), ("cfg", 2)],
                                workflow_fn=sleep_workflow)
    pool = WorkerPool(executor, c=3, assignment=[0, 1, 2])
    assert pool.assignment() == (0, 1, 2)
    pool.start()
    for i in range(60):
        pool.submit(Request(request_id=i, arrival_s=0.0))
    deadline = time.monotonic() + 10.0
    while len(executor.records) < 60 and time.monotonic() < deadline:
        time.sleep(0.005)
    pool.stop()
    assert len(executor.records) == 60
    for r in executor.records:
        assert r.config_index == [0, 1, 2][r.worker_id]


def test_worker_pool_assignment_validation():
    executor = WorkflowExecutor(configs=[("cfg", 0)],
                                workflow_fn=sleep_workflow)
    pool = WorkerPool(executor, c=2)
    assert pool.assignment() is None
    assert pool.config_for_worker(0) is None
    with pytest.raises(ValueError):
        pool.set_assignment([0])          # wrong length
    with pytest.raises(IndexError):
        pool.set_assignment([0, 5])       # config out of range
    pool.set_assignment([0, 0])
    assert pool.config_for_worker(1) == 0
    pool.set_assignment(None)
    assert pool.assignment() is None


def test_engine_mix_controller_repins_pool():
    table = mix_table_for(2, downscale_cooldown_s=60.0)
    executor = WorkflowExecutor(
        configs=[("cfg", i) for i in range(3)], workflow_fn=sleep_workflow)
    engine = ServingEngine(executor, controller=ElasticoMixController(table),
                           num_workers=2, control_tick_s=0.01)
    engine.start()
    assert engine.pool.assignment() == (2, 2)   # starts all-accurate
    for i in range(150):                         # flood -> forced repinning
        engine.submit(Request(request_id=i, arrival_s=0.0))
    report = engine.drain_and_stop()
    assert len(report.records) == 150
    assert len(report.assignment_timeline) > 1
    assert report.assignment_timeline[0] == (0.0, (2, 2))
    for (_, u), (_, v) in zip(report.assignment_timeline,
                              report.assignment_timeline[1:]):
        assert sum(1 for a, b in zip(u, v) if a != b) == 1
    # monitor snapshots carry the live assignment for post-hoc analysis
    assert any(s.assignment is not None for s in engine.monitor.history())


def test_engine_static_assignment():
    executor = WorkflowExecutor(
        configs=[("cfg", i) for i in range(3)], workflow_fn=sleep_workflow)
    engine = ServingEngine(executor, num_workers=2, assignment=[0, 2],
                           control_tick_s=0.01)
    engine.start()
    for i in range(40):
        engine.submit(Request(request_id=i, arrival_s=0.0))
    report = engine.drain_and_stop()
    assert len(report.records) == 40
    pin = [0, 2]
    for r in report.records:
        assert r.config_index == pin[r.worker_id]
    assert report.assignment_timeline == [(0.0, (0, 2))]
