"""COMPASS-V end-to-end: recall, savings, termination (paper §IV, §VI-B)."""

import pytest

from repro.core.compass_v import CompassV
from repro.workflows.surrogate import (
    DetectionSurrogate,
    RagSurrogate,
    paper_detection_thresholds,
    paper_rag_thresholds,
)

from conftest import exhaustive_feasible


def run_search(surrogate, tau, budget=(10, 25, 50, 100), seed=0):
    cv = CompassV(
        space=surrogate.space,
        evaluator=surrogate,
        tau=tau,
        budget_schedule=budget,
        seed=seed,
    )
    return cv.run()


@pytest.mark.parametrize("tau", [0.5, 0.75, 0.85])
def test_rag_full_recall(rag_surrogate, tau):
    """Paper headline: 100% recall vs exhaustive grid-search ground truth."""
    res = run_search(rag_surrogate, tau)
    gt = exhaustive_feasible(rag_surrogate, tau)
    found = set(res.feasible)
    missed = gt - found
    assert not missed, f"missed {len(missed)}/{len(gt)} feasible configs"
    assert res.recall(gt) == 1.0


@pytest.mark.parametrize("tau", [0.6, 0.7])
def test_detection_full_recall(detection_surrogate, tau):
    res = run_search(detection_surrogate, tau, budget=(20, 50, 100, 200))
    gt = exhaustive_feasible(detection_surrogate, tau, budget=200)
    assert not (gt - set(res.feasible))


def test_savings_positive_at_tight_threshold(rag_surrogate):
    """At tight thresholds most configs early-stop as infeasible; savings must
    be large (paper: up to 95.3%)."""
    res = run_search(rag_surrogate, 0.85)
    exhaustive = rag_surrogate.space.cardinality * 100
    savings = res.savings_vs_exhaustive(rag_surrogate.space, 100)
    assert savings > 0.3
    assert res.samples_consumed < exhaustive


def test_each_config_evaluated_at_most_once(rag_surrogate):
    res = run_search(rag_surrogate, 0.75)
    assert len(res.evaluated) == res.num_evaluations
    assert res.num_evaluations <= rag_surrogate.space.cardinality


def test_feasible_subset_of_evaluated(rag_surrogate):
    res = run_search(rag_surrogate, 0.75)
    assert set(res.feasible) <= set(res.evaluated)
    for c, a in res.feasible.items():
        assert 0.0 <= a <= 1.0


def test_trace_is_anytime_monotone(rag_surrogate):
    """The convergence trace (Fig. 3) must be monotone: cumulative samples and
    discovered-feasible counts only grow."""
    res = run_search(rag_surrogate, 0.75)
    samples = [t.samples for t in res.trace]
    found = [t.feasible_found for t in res.trace]
    assert samples == sorted(samples)
    assert found == sorted(found)
    assert found[-1] == len(res.feasible)


def test_deterministic_given_seed(rag_surrogate):
    r1 = run_search(rag_surrogate, 0.75, seed=3)
    r2 = run_search(rag_surrogate, 0.75, seed=3)
    assert set(r1.feasible) == set(r2.feasible)
    assert r1.samples_consumed == r2.samples_consumed


def test_empty_feasible_set_terminates(rag_surrogate):
    res = run_search(rag_surrogate, 0.999)
    assert dict(res.feasible) == {}
    # early stopping should have pruned aggressively
    assert res.savings_vs_exhaustive(rag_surrogate.space, 100) > 0.5


def test_paper_threshold_grids_cover_both_workflows():
    assert len(paper_rag_thresholds()) == 8
    assert len(paper_detection_thresholds()) == 8


@pytest.mark.slow
@pytest.mark.parametrize("tau", paper_rag_thresholds())
def test_rag_recall_all_paper_thresholds(rag_surrogate, tau):
    res = run_search(rag_surrogate, tau)
    gt = exhaustive_feasible(rag_surrogate, tau)
    assert not (gt - set(res.feasible)), f"tau={tau}"
