"""AQM threshold derivation (paper §V, Eqs. 7-13)."""

import math

import pytest
from proptest import given, settings, st

from repro.core.aqm import (
    HysteresisSpec,
    derive_policies,
    expected_wait,
    ladder_is_monotone,
    max_sustainable_rate,
)

from conftest import synthetic_point


def simple_front():
    # fast / medium / accurate, roughly the paper's Table I shape (seconds)
    return [
        synthetic_point(0.14, 0.20, 0.761, "fast"),
        synthetic_point(0.32, 0.45, 0.825, "medium"),
        synthetic_point(0.50, 0.70, 0.853, "accurate"),
    ]


def test_thresholds_match_hand_computation():
    front = simple_front()
    L, hs = 1.0, 0.05
    table = derive_policies(front, slo_p95_s=L, slack_buffer_s=hs)
    p0, p1, p2 = table.policies

    # Eq. 7: Delta_k = L - s95_k
    assert math.isclose(p0.queuing_slack, 1.0 - 0.20)
    assert math.isclose(p2.queuing_slack, 1.0 - 0.70)
    # Eq. 10: N_up = floor(Delta_k / mean_k)
    assert p0.upscale_threshold == math.floor(0.80 / 0.14)  # 5
    assert p1.upscale_threshold == math.floor(0.55 / 0.32)  # 1
    assert p2.upscale_threshold == math.floor(0.30 / 0.50)  # 0
    # Eq. 13: N_dn = floor((Delta_{k+1} - h_s) / mean_{k+1})
    assert p0.downscale_threshold == math.floor((0.55 - hs) / 0.32)  # 1
    assert p1.downscale_threshold == math.floor((0.30 - hs) / 0.50)  # 0
    assert p2.downscale_threshold is None  # top rung


def test_eq11_ladder_monotone():
    table = derive_policies(simple_front(), slo_p95_s=1.0)
    assert ladder_is_monotone(table)


def test_infeasible_configs_excluded():
    front = simple_front() + [synthetic_point(1.2, 1.8, 0.90, "too-slow")]
    table = derive_policies(front, slo_p95_s=1.0)
    assert len(table.excluded) == 1
    assert table.excluded[0].config[0] == "too-slow"
    assert table.ladder_size == 3


def test_all_infeasible_gives_empty_ladder():
    front = [synthetic_point(2.0, 3.0, 0.9, "slow")]
    table = derive_policies(front, slo_p95_s=1.0)
    assert table.ladder_size == 0 and len(table.excluded) == 1


def test_requires_ordered_front():
    front = simple_front()[::-1]
    with pytest.raises(ValueError):
        derive_policies(front, slo_p95_s=1.0)
    with pytest.raises(ValueError):
        derive_policies(simple_front(), slo_p95_s=0.0)


def test_hysteresis_validation():
    with pytest.raises(ValueError):
        HysteresisSpec(upscale_cooldown_s=-1.0)


def test_expected_wait_and_rate():
    assert expected_wait(5, 0.2) == 1.0
    table = derive_policies(simple_front(), slo_p95_s=1.0)
    assert math.isclose(max_sustainable_rate(table.policy(0)), 1 / 0.14)


# -- property: thresholds well-formed for arbitrary valid fronts --------------


@st.composite
def random_fronts(draw):
    n = draw(st.integers(1, 8))
    means = sorted(
        draw(
            st.lists(
                st.floats(0.01, 1.5, allow_nan=False),
                min_size=n,
                max_size=n,
                unique=True,
            )
        )
    )
    pts = []
    acc = 0.5
    for i, m in enumerate(means):
        acc += draw(st.floats(0.001, 0.05))
        p95 = m * draw(st.floats(1.0, 2.0))
        pts.append(synthetic_point(m, p95, acc, f"c{i}"))
    return pts


@given(random_fronts(), st.floats(0.2, 3.0))
@settings(max_examples=150, deadline=None)
def test_policy_table_invariants(front, slo):
    table = derive_policies(front, slo_p95_s=slo)
    assert table.ladder_size + len(table.excluded) == len(front)
    for k, pol in enumerate(table.policies):
        assert pol.index == k
        assert pol.queuing_slack > 0           # admitted => positive slack
        assert pol.upscale_threshold >= 0
        if k + 1 < table.ladder_size:
            assert pol.downscale_threshold is not None
            assert pol.downscale_threshold >= 0
        if k == table.ladder_size - 1:
            assert pol.downscale_threshold is None
    for p in table.excluded:
        assert slo - p.profile.p95 <= 0
