"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated as a REDUCED same-family variant
(2 layers, d_model <= 512, <= 4 experts) and runs one forward + one train step
on CPU, asserting output shapes and finiteness.  Decode (prefill -> serve_step)
consistency is additionally checked for one arch per family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs  # noqa: F401  (registers archs)
from repro.configs.reduced import reduced_config
from repro.models.registry import arch_ids, build_model, get_config
from repro.optim.adamw import AdamW
from repro.training.steps import make_train_step

ARCHS = arch_ids()
B, S = 2, 32


def make_batch(cfg, key, with_labels=True):
    batch = {}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.prefix_tokens, cfg.prefix_dim), jnp.bfloat16
        )
    elif cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, S, cfg.prefix_dim), jnp.bfloat16)
    batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if with_labels:
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


def test_all_ten_archs_assigned():
    assert len(ARCHS) == 10
    families = {get_config(a).family for a in ARCHS}
    assert {"dense", "moe", "ssm", "hybrid", "vlm", "audio"} <= families


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expect = {
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
    }[arch]
    got = (
        cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
        cfg.d_ff, cfg.vocab_size,
    )
    assert got == expect
    if arch == "granite-moe-3b-a800m":
        assert (cfg.num_experts, cfg.moe_top_k) == (40, 8) or (cfg.num_experts, cfg.moe_top_k) == (32, 8)
    if arch == "deepseek-moe-16b":
        assert cfg.num_experts == 64 and cfg.moe_top_k == 6 and cfg.num_shared_experts == 2
    if arch == "hymba-1.5b":
        assert cfg.ssm_state == 16
    if arch == "xlstm-1.3b":
        assert cfg.ssm_state > 0 or cfg.slstm_every > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_constraints(arch):
    cfg = reduced_config(arch)
    assert cfg.num_layers == 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.family == get_config(arch).family


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, key, with_labels=False)
    logits, aux = model.forward(params, batch)
    n_tok = batch["tokens"].shape[1]
    if cfg.family == "vlm":
        assert logits.shape == (B, cfg.prefix_tokens + n_tok, cfg.vocab_size) or \
            logits.shape == (B, n_tok, cfg.vocab_size)
    else:
        assert logits.shape == (B, n_tok, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_finite(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    opt = AdamW(learning_rate=1e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    batch = make_batch(cfg, key)
    loss, params2, state2 = step(params, state, batch)
    assert jnp.isfinite(loss)
    # parameters actually moved
    leaves1 = jax.tree_util.tree_leaves(params)
    leaves2 = jax.tree_util.tree_leaves(params2)
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(leaves1, leaves2)
    )
    assert moved


def test_loss_decreases_dense():
    """A few steps on a fixed batch must reduce loss (learning sanity)."""
    cfg = reduced_config("internlm2-1.8b")
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    opt = AdamW(learning_rate=3e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    batch = make_batch(cfg, key)
    losses = []
    for _ in range(8):
        loss, params, state = step(params, state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


@pytest.mark.parametrize(
    "arch",
    ["internlm2-1.8b", "granite-moe-3b-a800m", "xlstm-1.3b", "hymba-1.5b",
     "paligemma-3b", "seamless-m4t-medium"],
)
def test_prefill_then_decode_matches_forward(arch):
    """serve_step semantics: greedy decode after prefill must match the
    argmax of the teacher-forced forward logits at the same position."""
    cfg = reduced_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(3)
    params = model.init(key)
    batch = make_batch(cfg, key, with_labels=False)
    logits, _ = model.forward(params, batch)

    cache_len = S + 8
    prefill_batch = dict(batch)
    last_logits, state = model.prefill(params, prefill_batch, cache_len=cache_len)
    # last prefill logits == forward logits at the final position
    np.testing.assert_allclose(
        np.asarray(last_logits, np.float32),
        np.asarray(logits[:, -1, :], np.float32),
        rtol=2e-2, atol=2e-2,
    )
    # one decode step runs and stays finite
    nxt = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    step_logits, state2 = model.decode_step(params, state, nxt)
    assert step_logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(step_logits.astype(jnp.float32))))


def test_moe_router_balanced_aux():
    """MoE aux loss exists and is finite; top-k selects exactly k experts."""
    cfg = reduced_config("granite-moe-3b-a800m")
    model = build_model(cfg)
    key = jax.random.PRNGKey(4)
    params = model.init(key)
    batch = make_batch(cfg, key)
    loss = model.loss(params, batch, aux_weight=0.05)
    assert jnp.isfinite(loss)


def test_sliding_window_variant_lowers_memory_profile():
    """Dense arch with a window must produce different (still finite) logits
    than full attention — the long_500k sub-quadratic variant."""
    import dataclasses

    cfg = reduced_config("stablelm-3b")
    cfg_win = dataclasses.replace(cfg, sliding_window=8)
    key = jax.random.PRNGKey(5)
    m_full, m_win = build_model(cfg), build_model(cfg_win)
    params = m_full.init(key)
    batch = make_batch(cfg, key, with_labels=False)
    lf, _ = m_full.forward(params, batch)
    lw, _ = m_win.forward(params, batch)
    assert lf.shape == lw.shape
    assert not np.allclose(np.asarray(lf, np.float32), np.asarray(lw, np.float32))
