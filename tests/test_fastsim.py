"""Fast-path simulation engine (repro/serving/fastsim.py).

The contract under test, in order of strictness:

1. **c = 1 golden, bit-for-bit**: the dispatcher's fast path reproduces the
   event-heap ``ServingSimulator`` *exactly* — same RNG draw order, same
   float operations, identical per-request waits/starts/completions.
2. **c > 1 exactness and statistics**: the Kiefer-Wolfowitz recursion with
   the lowest-free-server tie-break matches the event heap per-request at
   c in {2, 4}, and ``simulate_batch`` agrees statistically with both the
   oracle and the Erlang-C / Allen-Cunneen predictions.
3. **Dispatcher eligibility**: every dynamic-policy feature (controller,
   batching, stealing, per-worker queues, admission bounds) must fall back
   to the event-heap oracle.
4. **Batch-cell purity**: a sweep cell is a pure function of its inputs —
   permuting traces along an axis permutes the result grid identically,
   and sub-batches reproduce the same cells (no vectorization cross-talk).

Property tests run through the ``tests/proptest.py`` hypothesis shim.
"""

import math

import numpy as np
import pytest

from proptest import given, settings, st

from repro.core.aqm import (
    allen_cunneen_mean_wait,
    derive_policies,
    erlang_c_mean_wait,
)
from repro.core.elastico import ElasticoController
from repro.core.pareto import LatencyProfile, ParetoPoint
from repro.serving import fastsim
from repro.serving.fastsim import (
    FastSimulationResult,
    fast_path_eligible,
    simulate,
    simulate_batch,
)
from repro.serving.simulator import (
    ServingSimulator,
    SimulationResult,
    lognormal_sampler_from_profile,
)
from repro.serving.workload import (
    constant_rate,
    generate_arrivals,
    spike_pattern,
)

MEANS = [0.10, 0.25, 0.45]
P95S = [0.14, 0.35, 0.63]
ACCS = [0.76, 0.82, 0.85]
SLO_S = 1.0
DURATION_S = 120.0


def _front():
    return [
        ParetoPoint(config=("rung", i), accuracy=a,
                    profile=LatencyProfile(mean=m, p95=p))
        for i, (m, p, a) in enumerate(zip(MEANS, P95S, ACCS))
    ]


def _arrivals(seed=1, qps=3.0):
    return generate_arrivals(spike_pattern(qps, duration_s=DURATION_S),
                             DURATION_S, seed=seed)


def _oracle(arrivals, **kw):
    return ServingSimulator(
        lognormal_sampler_from_profile(MEANS, P95S), **kw
    ).run(arrivals, DURATION_S)


def _fast(arrivals, **kw):
    return simulate(
        lognormal_sampler_from_profile(MEANS, P95S), arrivals, DURATION_S,
        **kw)


def _schedule(result):
    """(arrival, start, completion, config, server) rows in request order."""
    rows = sorted(
        (r.request_id, r.arrival_s, r.start_s, r.completion_s,
         r.config_index, r.server_id)
        for r in result.completed
    )
    return rows


# --------------------------------------------------------------------------
# 1. golden agreement with the event-heap oracle
# --------------------------------------------------------------------------


def test_c1_golden_bit_for_bit():
    """The acceptance criterion: identical schedule at c = 1 — same seeds,
    same RNG draw order, exact float equality on every field."""
    arrivals = _arrivals()
    ev = _oracle(arrivals, static_index=1, seed=0, num_servers=1)
    fa = _fast(arrivals, static_index=1, seed=0, num_servers=1)
    assert isinstance(fa, FastSimulationResult)
    assert _schedule(ev) == _schedule(fa)            # bit-for-bit
    assert ev.per_server_busy_s == fa.per_server_busy_s
    assert ev.queue_depth_samples == fa.queue_depth_samples
    assert ev.config_timeline == fa.config_timeline
    assert ev.p95_latency() == fa.p95_latency()
    # per-request fields are exactly equal (asserted above); the aggregate
    # mean differs only by numpy's pairwise vs sequential summation order
    assert ev.mean_wait() == pytest.approx(fa.mean_wait(), rel=1e-12)


@pytest.mark.parametrize("c", [2, 4])
def test_multi_server_schedule_matches_oracle(c):
    """c > 1 shares the oracle's RNG draw order and tie-breaks, so the
    recursion reproduces the event heap exactly there too (the formal
    requirement is only statistical agreement; exactness is stronger)."""
    arrivals = _arrivals(qps=3.0 * c)
    ev = _oracle(arrivals, static_index=0, seed=3, num_servers=c)
    fa = _fast(arrivals, static_index=0, seed=3, num_servers=c)
    assert _schedule(ev) == _schedule(fa)
    assert ev.per_server_busy_s == fa.per_server_busy_s


def test_heterogeneous_assignment_matches_oracle():
    arrivals = _arrivals(qps=6.0)
    assign = [0, 0, 2, 2]
    ev = _oracle(arrivals, seed=0, num_servers=4, assignment=assign)
    fa = _fast(arrivals, seed=0, num_servers=4, assignment=assign)
    assert isinstance(fa, FastSimulationResult)
    assert _schedule(ev) == _schedule(fa)
    assert ev.assignment_timeline == fa.assignment_timeline


def test_fast_result_metric_surface_consistent():
    """Array-backed metrics must equal the list-based computation over the
    lazily materialized completed records."""
    arrivals = _arrivals(qps=8.0)
    fa = _fast(arrivals, static_index=2, seed=1, num_servers=2)
    recs = fa.completed
    assert fa.num_completed == len(recs) == len(arrivals)
    assert fa.mean_wait() == pytest.approx(
        sum(r.wait_s for r in recs) / len(recs))
    assert fa.slo_compliance(SLO_S) == pytest.approx(
        sum(1 for r in recs if r.latency_s <= SLO_S) / len(recs))
    assert fa.mean_accuracy(ACCS) == pytest.approx(
        sum(ACCS[r.config_index] for r in recs) / len(recs))
    counts = fa.config_counts()
    assert sum(counts.values()) == len(recs)
    assert fa.latencies() == [r.latency_s for r in recs]


# --------------------------------------------------------------------------
# 2. statistical agreement: simulate_batch vs oracle and queueing theory
# --------------------------------------------------------------------------


@pytest.mark.parametrize("c", [2, 4])
def test_batch_agrees_with_oracle_statistically(c):
    """Mean wait / p95 / compliance of the batched sweep agree with the
    event-heap oracle within sampling tolerance at c in {2, 4}."""
    rate = 3.0 * c
    slo = 0.6
    res = simulate_batch(
        [MEANS[1]], [P95S[1]],
        arrival_rates_qps=[rate], duration_s=400.0, num_servers=c,
        replications=12, slo_s=slo, seed=5)
    # oracle: a few independent replications of the same scenario
    waits, p95s, comps = [], [], []
    for rep in range(4):
        arrivals = generate_arrivals(constant_rate(rate), 400.0, seed=50 + rep)
        out = ServingSimulator(
            lognormal_sampler_from_profile([MEANS[1]], [P95S[1]]),
            static_index=0, seed=rep, num_servers=c).run(arrivals, 400.0)
        waits.append(out.mean_wait())
        p95s.append(out.p95_latency())
        comps.append(out.slo_compliance(slo))
    sim_wait = float(res.mean_wait_s.mean())
    orc_wait = sum(waits) / len(waits)
    assert sim_wait == pytest.approx(orc_wait, rel=0.25, abs=0.01)
    assert float(res.p95_latency_s.mean()) == pytest.approx(
        sum(p95s) / len(p95s), rel=0.25, abs=0.05)
    assert float(res.slo_compliance.mean()) == pytest.approx(
        sum(comps) / len(comps), abs=0.05)


@pytest.mark.parametrize("c", [1, 2, 4])
def test_batch_converges_to_erlang_c(c):
    """Exponential service (no p95s) is M/M/c: the sweep's mean wait must
    land on the Erlang-C prediction."""
    rate, mean = 3.0 * c, 0.2
    res = simulate_batch(
        [mean], arrival_rates_qps=[rate], duration_s=2000.0,
        num_servers=c, replications=20, slo_s=SLO_S, seed=7)
    pred = erlang_c_mean_wait(c, rate, mean)
    assert float(res.mean_wait_s.mean()) == pytest.approx(pred, rel=0.12)


def test_batch_matches_allen_cunneen_for_lognormal():
    """Lognormal service at c = 1 is M/G/1 where Allen-Cunneen is exact
    (Pollaczek-Khinchine)."""
    mean, p95, rate = 0.25, 0.35, 3.0
    _, sigma = fastsim.lognormal_params(mean, p95)
    scv = math.exp(sigma * sigma) - 1.0
    res = simulate_batch(
        [mean], [p95], arrival_rates_qps=[rate], duration_s=4000.0,
        num_servers=1, replications=24, slo_s=SLO_S, seed=11)
    pred = allen_cunneen_mean_wait(1, rate, mean, scv_service=scv)
    assert float(res.mean_wait_s.mean()) == pytest.approx(pred, rel=0.12)


# --------------------------------------------------------------------------
# 3. dispatcher eligibility
# --------------------------------------------------------------------------


def test_eligible_static_cases():
    assert fast_path_eligible()
    assert fast_path_eligible(num_servers=4)
    assert fast_path_eligible(assignment=[0, 1], num_servers=2)
    # a linger window never forms at B = 1
    assert fast_path_eligible(batch_timeout_s=0.005)


def test_ineligible_dynamic_cases():
    table = derive_policies(_front(), slo_p95_s=SLO_S)
    assert not fast_path_eligible(controller=ElasticoController(table))
    assert not fast_path_eligible(max_batch_size=8)
    assert not fast_path_eligible(queue_discipline="per_worker")
    assert not fast_path_eligible(queue_discipline="per_worker", steal=True)
    assert not fast_path_eligible(max_queue_depth=64)


def test_dispatcher_routes_static_to_fast_path():
    out = _fast(_arrivals(), static_index=0, seed=0, num_servers=2)
    assert isinstance(out, FastSimulationResult)


def test_dispatcher_falls_back_for_controller():
    table = derive_policies(_front(), slo_p95_s=SLO_S)
    out = _fast(_arrivals(), controller=ElasticoController(table), seed=0)
    assert isinstance(out, SimulationResult)
    # and the fallback is the *same* event-heap run, bit-for-bit
    ev = _oracle(_arrivals(), controller=ElasticoController(table), seed=0)
    assert _schedule(ev) == _schedule(out)


def test_dispatcher_falls_back_for_batching():
    out = _fast(_arrivals(), static_index=0, seed=0, num_servers=2,
                max_batch_size=4, batch_timeout_s=0.005)
    assert isinstance(out, SimulationResult)
    assert out.mean_batch_size() >= 1.0


def test_dispatcher_falls_back_for_stealing_and_admission():
    arr = _arrivals()
    out = _fast(arr, seed=0, num_servers=2, assignment=[0, 2],
                queue_discipline="per_worker", steal=True)
    assert isinstance(out, SimulationResult)
    out = _fast(arr, static_index=0, seed=0, max_queue_depth=4)
    assert isinstance(out, SimulationResult)
    assert out.offered == len(arr)


# --------------------------------------------------------------------------
# 4. sweep-cell purity (permutation / slicing invariance)
# --------------------------------------------------------------------------


def _traces(seeds, n=300):
    return [np.sort(np.random.default_rng(s).uniform(0.0, 100.0, size=n))
            for s in seeds]


@given(st.lists(st.integers(0, 2 ** 16), min_size=2, max_size=5, unique=True),
       st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_batch_permutation_invariant_across_replication_axis(seeds, seed):
    """Permuting the arrival traces along the replication axis permutes the
    result grid identically — each replication's cell is computed from its
    own trace and config only, never from batch position."""
    base = _traces(seeds)
    perm = list(reversed(range(len(base))))
    # traces replayed identically across replications: use each trace as
    # its own load column, then permute the columns
    a = simulate_batch(MEANS, P95S, arrival_traces=base,
                       duration_s=100.0, num_servers=2, replications=1,
                       slo_s=SLO_S, seed=seed)
    b = simulate_batch(MEANS, P95S, arrival_traces=[base[p] for p in perm],
                       duration_s=100.0, num_servers=2, replications=1,
                       slo_s=SLO_S, seed=seed)
    for field in ("mean_wait_s", "p95_latency_s", "slo_compliance",
                  "throughput_qps"):
        got = getattr(b, field)[:, :, :]
        want = getattr(a, field)[:, :, perm]
        assert np.array_equal(got, want), field


def test_batch_cells_independent_of_batch_composition():
    """A sub-batch reproduces the big batch's cells exactly: dropping a
    config or a load from the sweep must not change the others."""
    rates = [2.0, 5.0]
    big = simulate_batch(MEANS, P95S, arrival_rates_qps=rates,
                         duration_s=200.0, num_servers=2, replications=3,
                         slo_s=SLO_S, seed=9)
    one_cfg = simulate_batch(MEANS[1:2], P95S[1:2], arrival_rates_qps=rates,
                             duration_s=200.0, num_servers=2, replications=3,
                             slo_s=SLO_S, seed=9)
    assert np.array_equal(big.mean_wait_s[:, 1:2, :], one_cfg.mean_wait_s)
    one_rate = simulate_batch(MEANS, P95S, arrival_rates_qps=rates[1:],
                              duration_s=200.0, num_servers=2, replications=3,
                              slo_s=SLO_S, seed=9)
    assert np.array_equal(big.mean_wait_s[:, :, 1:], one_rate.mean_wait_s)
    # growing the replication axis never disturbs earlier replications
    more_reps = simulate_batch(MEANS, P95S, arrival_rates_qps=rates,
                               duration_s=200.0, num_servers=2,
                               replications=5, slo_s=SLO_S, seed=9)
    assert np.array_equal(big.mean_wait_s, more_reps.mean_wait_s[:3])
    # permuting the config axis permutes the grid identically
    perm = [2, 0, 1]
    permuted = simulate_batch([MEANS[p] for p in perm],
                              [P95S[p] for p in perm],
                              arrival_rates_qps=rates, duration_s=200.0,
                              num_servers=2, replications=3,
                              slo_s=SLO_S, seed=9)
    assert np.array_equal(big.mean_wait_s[:, perm, :], permuted.mean_wait_s)


def test_batch_deterministic():
    kw = dict(arrival_rates_qps=[3.0], duration_s=150.0, num_servers=1,
              replications=4, slo_s=SLO_S, seed=13)
    a = simulate_batch(MEANS, P95S, **kw)
    b = simulate_batch(MEANS, P95S, **kw)
    for field in ("mean_wait_s", "p95_latency_s", "slo_compliance"):
        assert np.array_equal(getattr(a, field), getattr(b, field))


def test_batch_validates_inputs():
    with pytest.raises(ValueError):
        simulate_batch([], arrival_rates_qps=[1.0], duration_s=10.0)
    with pytest.raises(ValueError):
        simulate_batch([0.1], duration_s=10.0)   # no loads at all
    with pytest.raises(ValueError):
        simulate_batch([0.1], arrival_rates_qps=[1.0],
                       arrival_traces=[[0.5]], duration_s=10.0)
    with pytest.raises(ValueError):
        simulate_batch([-0.1], arrival_rates_qps=[1.0], duration_s=10.0)
    with pytest.raises(ValueError):
        simulate_batch([0.1], [0.2, 0.3], arrival_rates_qps=[1.0],
                       duration_s=10.0)


def test_non_dyadic_tick_grid_matches_oracle():
    """control_tick_s values not representable in binary (0.1) accumulate
    differently than an i*tick grid; the fast path must reproduce the
    event heap's accumulated tick times and sample count exactly."""
    arrivals = _arrivals()
    ev = _oracle(arrivals, static_index=0, seed=0, control_tick_s=0.1)
    fa = _fast(arrivals, static_index=0, seed=0, control_tick_s=0.1)
    assert isinstance(fa, FastSimulationResult)
    assert ev.queue_depth_samples == fa.queue_depth_samples


def test_unsorted_arrivals_fall_back_to_oracle():
    """The FIFO recursion requires time-ordered arrivals; unsorted input
    (which the event heap handles by sorting its heap) must not silently
    take the fast path."""
    out = simulate(lognormal_sampler_from_profile(MEANS, P95S),
                   [2.0, 1.0, 3.0], 10.0, static_index=0, seed=0)
    assert isinstance(out, SimulationResult)
    ev = ServingSimulator(
        lognormal_sampler_from_profile(MEANS, P95S),
        static_index=0, seed=0).run([2.0, 1.0, 3.0], 10.0)
    assert _schedule(ev) == _schedule(out)


def test_empty_arrivals_fast_path():
    out = _fast([], static_index=0, seed=0, num_servers=2)
    assert isinstance(out, FastSimulationResult)
    assert out.num_completed == 0
    assert out.mean_wait() == 0.0
    assert out.slo_compliance(SLO_S) == 1.0
    assert out.p95_latency() == 0.0
    # matches the oracle's conventions for the degenerate run
    ev = _oracle([], static_index=0, seed=0, num_servers=2)
    assert ev.mean_wait() == out.mean_wait()
    assert ev.queue_depth_samples == out.queue_depth_samples


# --------------------------------------------------------------------------
# Planner.validate rides on simulate_batch
# --------------------------------------------------------------------------


def test_planner_validate_grids():
    from repro.core.planner import Planner

    def profiler(config, n):
        i = config[0]
        return [MEANS[i] * (1.0 + 0.04 * math.sin(j)) for j in range(n)]

    feasible = {(i,): ACCS[i] for i in range(3)}
    planner = Planner(profiler=profiler, num_servers=2)
    plan = planner.plan(feasible, slo_p95_s=SLO_S)
    val = planner.validate(plan, duration_s=60.0, replications=4, seed=1)
    K = plan.table.ladder_size
    assert len(val.mean_wait_s) == K
    assert len(val.arrival_rates_qps) == 3
    for row in val.slo_compliance:
        assert all(0.0 <= x <= 1.0 for x in row)
    # the load grid is fractions of the fastest rung's capacity: the
    # fastest rung must be stable (finite predicted wait) on all of them
    assert all(math.isfinite(w) for w in val.predicted_wait_s[0])
    # low load: every rung the SLO admits complies comfortably
    lo_rate = val.arrival_rates_qps[0]
    assert 0 in val.compliant_rungs(lo_rate, target=0.9)
    assert val.num_requests > 0
    assert "rung 0" in val.describe()
