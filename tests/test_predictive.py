"""PredictiveElastico: anticipatory switching (paper §VIII future work)."""

import pytest

from repro.core.aqm import HysteresisSpec, derive_policies
from repro.core.elastico import ElasticoController
from repro.core.predictive import PredictiveElastico

from conftest import synthetic_point


def make_table():
    front = [
        synthetic_point(0.10, 0.14, 0.76, "fast"),
        synthetic_point(0.25, 0.35, 0.82, "medium"),
        synthetic_point(0.45, 0.63, 0.85, "accurate"),
    ]
    return derive_policies(front, slo_p95_s=1.0,
                           hysteresis=HysteresisSpec(downscale_cooldown_s=5.0))


def test_zero_horizon_matches_reactive():
    """horizon=0 must reproduce the reactive controller decision-for-decision."""
    table = make_table()
    reactive = ElasticoController(table)
    predictive = PredictiveElastico(table, horizon_s=0.0)
    depths = [0, 0, 1, 3, 5, 9, 4, 2, 0, 0, 0, 0, 7, 1, 0]
    for i, d in enumerate(depths):
        e1 = reactive.observe(d, i * 0.25)
        e2 = predictive.observe(d, i * 0.25)
        assert (e1 is None) == (e2 is None)
        assert reactive.current_index == predictive.current_index


def test_predictive_switches_before_threshold_crossed():
    """A rising queue that has NOT yet crossed N_up must already trigger the
    anticipatory upscale."""
    table = make_table()
    # start at the accurate rung: N_up = 0 there, so use medium (index 1)
    ctrl = PredictiveElastico(table, horizon_s=3.0, rate_halflife_s=0.5,
                              initial_index=1)
    n_up = table.policy(1).upscale_threshold
    # queue grows by 1 every 250 ms but stays AT the threshold, not above
    t, ev = 0.0, None
    for d in range(n_up + 1):  # 0..N_up inclusive — never exceeds N_up
        ev = ctrl.observe(d, t)
        if ev is not None:
            break
        t += 0.25
    assert ev is not None and ev.direction == "faster"
    # a reactive controller never switches on the same trace
    reactive = ElasticoController(table, initial_index=1)
    t = 0.0
    for d in range(n_up + 1):
        assert reactive.observe(d, t) is None
        t += 0.25


def test_predictive_steady_queue_no_false_positive():
    """A constant (non-growing) queue below N_up must not trigger."""
    table = make_table()
    ctrl = PredictiveElastico(table, horizon_s=3.0, initial_index=1)
    n_up = table.policy(1).upscale_threshold
    for i in range(50):
        assert ctrl.observe(max(0, n_up - 1), i * 0.25) is None
    assert ctrl.current_index == 1


def test_predictive_downscale_still_hysteretic():
    table = make_table()
    ctrl = PredictiveElastico(table, horizon_s=3.0, initial_index=0)
    assert ctrl.observe(0, 0.0) is None
    assert ctrl.observe(0, 2.0) is None         # not sustained yet
    ev = ctrl.observe(0, 5.0)
    assert ev is not None and ev.direction == "more_accurate"


def test_reset_clears_rate_state():
    table = make_table()
    ctrl = PredictiveElastico(table, horizon_s=3.0, initial_index=1)
    ctrl.observe(0, 0.0)
    ctrl.observe(5, 0.25)
    ctrl.reset()
    assert ctrl._rate == 0.0 and ctrl._last_depth is None
    assert ctrl.current_index == 1
