"""Schema, trajectory-store, and regression-detector tests for
``repro.tools.benchhist`` — the hardened harness around every speed claim.

Three layers, mirroring the module:

1. **Schema**: construction/parsing is strict (malformed and
   missing-field records raise :class:`BenchHistError` with actionable
   messages), serialization is byte-stable (serialize → parse →
   serialize is byte-identical), and a run's free-form ``context`` block
   is scrubbed with the same volatile-key filter as the stable artifacts.
2. **Trajectory store**: append/load round-trips, benchmark-name
   mismatches and invalid JSON are rejected naming the file and record.
3. **Detector**: unit tests for window/mode semantics plus property
   tests (via ``tests.proptest`` — hypothesis when installed, seeded
   sampling otherwise) for the statistical guarantees: bounded noise on
   a flat trajectory never fires, step regressions beyond tolerance
   always fire, direction awareness for ``higher_is_better=False``, and
   invariance to permutations of history outside the window.
"""

import json

import pytest

from repro.tools.benchhist import (
    DEFAULT_TOLERANCE,
    BenchHistError,
    BenchmarkSpec,
    BenchRun,
    Measurement,
    MeasurementSpec,
    append_run,
    detect_regressions,
    dumps_run,
    dumps_trajectory,
    gate_all,
    load_trajectory,
    loads_run,
    render_trends,
    resolve_path,
    scrub_volatile,
    trajectory_path,
)

from proptest import given, settings, st

ENV = {
    "git_sha": "deadbeef" * 5,
    "timestamp_utc": "2026-08-07T12:00:00+00:00",
    "platform": "Linux-test",
    "python": "3.11.0",
    "numpy": "1.26.0",
    "jax": None,
    "backend": "numpy",
}


def make_run(values, *, mode="smoke", higher_is_better=True, tolerance=None,
             benchmark="demo", timestamp="2026-08-07T12:00:00+00:00",
             context=None):
    """A BenchRun with one measurement per (name, value) pair."""
    if isinstance(values, (int, float)):
        values = {"metric": values}
    ms = tuple(
        Measurement(name, v, "rps", higher_is_better, tolerance=tolerance)
        for name, v in values.items())
    return BenchRun(benchmark=benchmark, mode=mode, git_sha=ENV["git_sha"],
                    timestamp_utc=timestamp, platform=ENV["platform"],
                    python=ENV["python"], numpy=ENV["numpy"],
                    jax=ENV["jax"], backend=ENV["backend"],
                    measurements=ms, context=context)


# ---------------------------------------------------------------------------
# schema: strict validation


@pytest.mark.parametrize("kwargs, fragment", [
    (dict(name="Bad-Name", value=1.0, unit="rps", higher_is_better=True),
     "name must match"),
    (dict(name="m", value=float("nan"), unit="rps", higher_is_better=True),
     "finite"),
    (dict(name="m", value="fast", unit="rps", higher_is_better=True),
     "expected a number"),
    (dict(name="m", value=1.0, unit="", higher_is_better=True),
     "non-empty"),
    (dict(name="m", value=1.0, unit="rps", higher_is_better=1),
     "must be a bool"),
    (dict(name="m", value=1.0, unit="rps", higher_is_better=True,
          tolerance=0.0), "tolerance must be in"),
    (dict(name="m", value=1.0, unit="rps", higher_is_better=True,
          tolerance=1.5), "tolerance must be in"),
])
def test_measurement_validation_rejects(kwargs, fragment):
    with pytest.raises(BenchHistError, match=fragment):
        Measurement(**kwargs)


def test_measurement_coerces_bool_value_to_float():
    m = Measurement("passed", True, "bool", True)
    assert m.value == 1.0 and isinstance(m.value, float)


def test_measurement_from_dict_rejects_missing_and_unknown_fields():
    with pytest.raises(BenchHistError, match=r"missing required field"):
        Measurement.from_dict({"name": "m", "value": 1.0})
    with pytest.raises(BenchHistError, match=r"unknown field.*wall_s"):
        Measurement.from_dict({"name": "m", "value": 1.0, "unit": "rps",
                               "higher_is_better": True, "wall_s": 0.5})
    with pytest.raises(BenchHistError, match="expected an object"):
        Measurement.from_dict([1, 2, 3])


def test_benchrun_validation_rejects():
    with pytest.raises(BenchHistError, match="mode must be one of"):
        make_run(1.0, mode="dev")
    with pytest.raises(BenchHistError, match="git_sha must be a non-empty"):
        BenchRun(
            benchmark="demo", mode="smoke", git_sha="",
            timestamp_utc=ENV["timestamp_utc"], platform="p", python="3",
            numpy="1", backend="numpy",
            measurements=(Measurement("m", 1.0, "rps", True),))
    with pytest.raises(BenchHistError, match="ISO-8601"):
        make_run(1.0, timestamp="yesterday")
    with pytest.raises(BenchHistError, match="must be non-empty"):
        BenchRun(benchmark="demo", mode="smoke", git_sha=ENV["git_sha"],
                 timestamp_utc=ENV["timestamp_utc"], platform="p",
                 python="3", numpy="1", backend="numpy", measurements=())
    with pytest.raises(BenchHistError, match=r"duplicate measurement"):
        BenchRun(benchmark="demo", mode="smoke", git_sha=ENV["git_sha"],
                 timestamp_utc=ENV["timestamp_utc"], platform="p",
                 python="3", numpy="1", backend="numpy",
                 measurements=(Measurement("m", 1.0, "rps", True),
                               Measurement("m", 2.0, "rps", True)))


def test_benchrun_from_dict_errors_are_actionable():
    good = make_run(1.0).to_dict()
    bad = dict(good)
    del bad["git_sha"]
    with pytest.raises(BenchHistError, match=r"missing required field.*git_sha"):
        BenchRun.from_dict(bad)
    bad = dict(good, extra_field=1)
    with pytest.raises(BenchHistError, match=r"unknown field.*extra_field"):
        BenchRun.from_dict(bad)
    bad = dict(good, measurements={"m": 1})
    with pytest.raises(BenchHistError, match="must be a list"):
        BenchRun.from_dict(bad)
    # the nested measurement error names its index
    bad = dict(good, measurements=[{"name": "m"}])
    with pytest.raises(BenchHistError, match=r"measurements\[0\]"):
        BenchRun.from_dict(bad)


def test_benchrun_context_is_scrubbed_of_volatile_keys():
    run = make_run(1.0, context={"artifact": "demo.json", "wall_s": 1.2,
                                 "nested": {"rps": 3.0, "kept": 7}})
    assert run.context == {"artifact": "demo.json", "nested": {"kept": 7}}
    # and the scrub is the same function the stable artifacts use
    assert scrub_volatile({"wall_s": 1, "kept": 2}) == {"kept": 2}


# ---------------------------------------------------------------------------
# schema: byte-stable serialization


def test_run_roundtrip_is_byte_identical():
    run = make_run({"a_rps": 123.456, "b_err": 0.001},
                   context={"artifact": "demo.json"})
    text = dumps_run(run)
    again = loads_run(text)
    assert again == run
    assert dumps_run(again) == text


def test_loads_run_rejects_invalid_json():
    with pytest.raises(BenchHistError, match="not valid JSON"):
        loads_run("{nope")


def test_golden_serialization():
    """The on-disk schema is an interface: fixed key order, fixed indent.
    If this golden changes, schema_version must be bumped."""
    run = make_run({"metric": 2.0})
    golden = json.dumps({
        "backend": "numpy",
        "benchmark": "demo",
        "git_sha": ENV["git_sha"],
        "jax": None,
        "measurements": [{
            "higher_is_better": True,
            "name": "metric",
            "unit": "rps",
            "value": 2.0,
        }],
        "mode": "smoke",
        "numpy": "1.26.0",
        "platform": "Linux-test",
        "python": "3.11.0",
        "timestamp_utc": "2026-08-07T12:00:00+00:00",
    }, sort_keys=True, indent=1)
    assert dumps_run(run) == golden


# ---------------------------------------------------------------------------
# trajectory store


def test_append_and_load_trajectory(tmp_path):
    r1 = make_run(10.0)
    r2 = make_run(11.0, timestamp="2026-08-07T13:00:00+00:00")
    path = append_run(tmp_path, r1)
    assert path == trajectory_path(tmp_path, "demo")
    append_run(tmp_path, r2)
    runs = load_trajectory(path)
    assert runs == [r1, r2]
    # the file itself is byte-stable: load → dump reproduces it
    assert dumps_trajectory("demo", runs) == path.read_text()


def test_load_trajectory_missing_file_names_the_remedy(tmp_path):
    with pytest.raises(BenchHistError, match="--record"):
        load_trajectory(tmp_path / "BENCH_demo.json")


def test_load_trajectory_rejects_malformed(tmp_path):
    p = tmp_path / "BENCH_demo.json"
    p.write_text("{invalid")
    with pytest.raises(BenchHistError, match="not valid JSON"):
        load_trajectory(p)
    p.write_text(json.dumps({"benchmark": "demo", "runs": []}))
    with pytest.raises(BenchHistError, match="schema_version"):
        load_trajectory(p)
    p.write_text(json.dumps({"schema_version": 99, "benchmark": "demo",
                             "runs": []}))
    with pytest.raises(BenchHistError, match="schema_version 99"):
        load_trajectory(p)
    # a record for the wrong benchmark names the index
    p.write_text(dumps_trajectory("demo", [make_run(1.0, benchmark="other")]))
    with pytest.raises(BenchHistError, match=r"runs\[0\].*'other'"):
        load_trajectory(p)


def test_load_trajectory_names_file_and_record_index(tmp_path):
    p = tmp_path / "BENCH_demo.json"
    good = make_run(1.0).to_dict()
    bad = dict(good)
    del bad["platform"]
    p.write_text(json.dumps({"schema_version": 1, "benchmark": "demo",
                             "runs": [good, bad]}))
    with pytest.raises(BenchHistError, match=r"runs\[1\].*platform"):
        load_trajectory(p)


# ---------------------------------------------------------------------------
# declaration layer


def test_resolve_path_and_errors():
    payload = {"a": {"b": [10, {"c": 42}]}}
    assert resolve_path(payload, "a.b.1.c") == 42
    assert resolve_path(payload, "a.b.0") == 10
    with pytest.raises(BenchHistError, match="not in"):
        resolve_path(payload, "a.missing")
    with pytest.raises(BenchHistError, match="does not index"):
        resolve_path(payload, "a.b.9")
    with pytest.raises(BenchHistError, match="reached a leaf"):
        resolve_path(payload, "a.b.0.c")


def test_measurement_spec_requires_exactly_one_source():
    with pytest.raises(BenchHistError, match="exactly one"):
        MeasurementSpec("m", "rps", True)
    with pytest.raises(BenchHistError, match="exactly one"):
        MeasurementSpec("m", "rps", True, path="a", extract=lambda p: 1.0)


def test_measurement_spec_missing_source_is_actionable():
    spec = MeasurementSpec("m", "rps", True, path="gone")
    with pytest.raises(BenchHistError, match="BENCH_SPEC"):
        spec.measure({"present": 1})
    assert MeasurementSpec("m", "rps", True, path="gone",
                           optional=True).measure({}) is None
    # extract callables that poke a vanished row are wrapped the same way
    bad = MeasurementSpec("m", "rps", True,
                          extract=lambda rows: next(
                              r for r in rows if r["variant"] == "gone"))
    with pytest.raises(BenchHistError, match="BENCH_SPEC"):
        bad.measure([{"variant": "here"}])


def test_benchmark_spec_mode_filtering():
    spec = BenchmarkSpec(
        artifact="full.json", smoke_artifact="smoke.json",
        measurements=(
            MeasurementSpec("always", "rps", True, path="a"),
            MeasurementSpec("full_only", "rps", True, path="b", smoke=False),
            MeasurementSpec("wallclock", "rps", True, path="a",
                            volatile=True),
        ))
    assert spec.artifact_for("full") == "full.json"
    assert spec.artifact_for("smoke") == "smoke.json"
    names = lambda mode, iv: [s.name for s in
                              spec.specs_for(mode, include_volatile=iv)]
    assert names("full", True) == ["always", "full_only", "wallclock"]
    assert names("smoke", True) == ["always", "wallclock"]
    assert names("smoke", False) == ["always"]
    got = spec.collect({"a": 1.0, "b": 2.0}, "smoke")
    assert [m.name for m in got] == ["always", "wallclock"]


# ---------------------------------------------------------------------------
# detector: unit tests


def ts(i):
    return f"2026-08-07T{i:02d}:00:00+00:00"


def flat_then(values, last, **kw):
    """A trajectory of constant `values` with `last` appended."""
    runs = [make_run(v, timestamp=ts(i)) for i, v in enumerate(values)]
    runs.append(make_run(last, timestamp=ts(len(values)), **kw))
    return runs


def test_detector_passes_with_no_history():
    assert detect_regressions([make_run(1.0)]) == []
    assert detect_regressions([]) == []


def test_detector_fires_on_step_regression_and_names_it():
    runs = flat_then([100.0] * 4, 50.0)
    v = detect_regressions(runs)
    assert len(v) == 1
    assert v[0].measurement == "metric"
    assert "fell below" in v[0].describe()
    assert "metric" in v[0].describe()


def test_detector_tolerates_within_tolerance_dip():
    assert detect_regressions(flat_then([100.0] * 4, 71.0)) == []
    assert detect_regressions(flat_then([100.0] * 4, 69.0))


def test_detector_direction_aware_for_lower_is_better():
    runs = [make_run(100.0, higher_is_better=False, timestamp=ts(i))
            for i in range(4)]
    runs.append(make_run(150.0, higher_is_better=False, timestamp=ts(4)))
    v = detect_regressions(runs)
    assert len(v) == 1 and "rose above" in v[0].describe()
    # a *drop* in a lower-is-better metric is an improvement, not a violation
    runs[-1] = make_run(10.0, higher_is_better=False, timestamp=ts(4))
    assert detect_regressions(runs) == []


def test_detector_per_measurement_tolerance_overrides_default():
    # 10% dip: default 30% tolerance passes, 5% per-measurement fires
    assert detect_regressions(flat_then([100.0] * 4, 90.0)) == []
    assert detect_regressions(flat_then([100.0] * 4, 90.0, tolerance=0.05))


def test_detector_only_gates_same_mode_history():
    runs = [make_run(1000.0, mode="full", timestamp=ts(i)) for i in range(4)]
    runs.append(make_run(100.0, mode="smoke", timestamp=ts(4)))
    # smoke current, full-only history: nothing to compare against
    assert detect_regressions(runs) == []


def test_detector_new_measurement_passes():
    runs = [make_run({"old": 100.0}, timestamp=ts(0)),
            make_run({"old": 100.0, "new": 5.0}, timestamp=ts(1))]
    assert detect_regressions(runs) == []


def test_detector_window_excludes_ancient_history():
    # 5 recent good runs push the ancient 1000.0 out of the window
    runs = flat_then([1000.0] + [100.0] * 5, 95.0)
    assert detect_regressions(runs, window=5) == []
    # with a window wide enough to see 1000.0 the median is still 100.0
    # (median is robust to the single outlier) — widen the regression
    runs = flat_then([1000.0] * 3 + [100.0] * 3, 95.0)
    assert detect_regressions(runs, window=6)


def test_detector_validates_its_knobs():
    with pytest.raises(BenchHistError, match="window"):
        detect_regressions([], window=0)
    with pytest.raises(BenchHistError, match="default_tolerance"):
        detect_regressions([], default_tolerance=0.0)


# ---------------------------------------------------------------------------
# detector: property tests (hypothesis when available, seeded otherwise)


@settings(max_examples=40)
@given(st.lists(st.floats(min_value=-0.2, max_value=0.2), min_size=2,
                max_size=12),
       st.floats(min_value=10.0, max_value=1e6))
def test_prop_bounded_noise_on_flat_trajectory_never_fires(noise, base):
    """Relative noise within ±20% of a flat baseline stays inside the 30%
    default tolerance of the window median, whatever the window contents."""
    runs = [make_run(base * (1.0 + n), timestamp=ts(i % 24))
            for i, n in enumerate(noise)]
    # median of history in [0.8b, 1.2b]; current >= 0.8b >= 0.7 * median
    assert detect_regressions(runs) == []


@settings(max_examples=40)
@given(st.lists(st.floats(min_value=-0.05, max_value=0.05), min_size=1,
                max_size=8),
       st.floats(min_value=10.0, max_value=1e6),
       st.floats(min_value=0.35, max_value=0.95))
def test_prop_step_regression_beyond_tolerance_always_fires(noise, base, drop):
    """A drop strictly beyond tolerance + noise band must always fire."""
    runs = [make_run(base * (1.0 + n), timestamp=ts(i % 24))
            for i, n in enumerate(noise)]
    runs.append(make_run(base * (1.0 - drop), timestamp=ts(23)))
    # median >= 0.95*base; current <= 0.65*base < 0.7 * median
    assert detect_regressions(runs), (noise, base, drop)


@settings(max_examples=40)
@given(st.lists(st.floats(min_value=-0.05, max_value=0.05), min_size=1,
                max_size=8),
       st.floats(min_value=10.0, max_value=1e6),
       st.floats(min_value=0.35, max_value=0.95))
def test_prop_direction_aware_lower_is_better(noise, base, rise):
    """For higher_is_better=False the SAME relative move flips verdicts:
    a rise beyond tolerance fires, the mirrored drop never does."""
    hist = [make_run(base * (1.0 + n), higher_is_better=False,
                     timestamp=ts(i % 24)) for i, n in enumerate(noise)]
    worse = hist + [make_run(base * (1.0 + rise), higher_is_better=False,
                             timestamp=ts(23))]
    better = hist + [make_run(base * (1.0 - rise) if rise < 1 else 0.0,
                              higher_is_better=False, timestamp=ts(23))]
    assert detect_regressions(worse)
    assert detect_regressions(better) == []


@settings(max_examples=25)
@given(st.lists(st.floats(min_value=10.0, max_value=1e6), min_size=8,
                max_size=14),
       st.integers(min_value=0, max_value=10**6))
def test_prop_history_outside_window_is_irrelevant(values, seed):
    """Permuting (or rewriting) entries older than the window cannot change
    the verdict — the gate sees only the last `window` same-mode runs."""
    import random

    window = 5
    runs = [make_run(v, timestamp=ts(i % 24)) for i, v in enumerate(values)]
    before = bool(detect_regressions(runs, window=window))
    head = values[:-(window + 1)]
    tail = values[-(window + 1):]
    rng = random.Random(seed)
    shuffled = head[:]
    rng.shuffle(shuffled)
    # also rewrite the pre-window values entirely: replace with constants
    for head2 in (shuffled, [1.0] * len(head)):
        runs2 = [make_run(v, timestamp=ts(i % 24))
                 for i, v in enumerate(head2 + tail)]
        assert bool(detect_regressions(runs2, window=window)) == before


@settings(max_examples=25)
@given(st.lists(st.floats(min_value=10.0, max_value=1e6), min_size=2,
                max_size=10),
       st.floats(min_value=1.0, max_value=10.0))
def test_prop_improvements_never_fire(values, gain):
    """A current value at or above the history median can never violate a
    higher-is-better gate."""
    runs = [make_run(v, timestamp=ts(i % 24)) for i, v in enumerate(values)]
    import statistics

    med = statistics.median(v for v in values[:-1][-5:])
    runs[-1] = make_run(med * gain, timestamp=ts(23))
    assert detect_regressions(runs) == []


# ---------------------------------------------------------------------------
# gate_all + trend rendering


def test_gate_all_ok_and_regression(tmp_path, capsys):
    for i, v in enumerate([100.0, 101.0, 99.0]):
        append_run(tmp_path, make_run(v, timestamp=ts(i)))
    lines = []
    assert gate_all(tmp_path, log=lines.append) == 0
    assert any("demo: OK" in l for l in lines)
    assert any("gate-all: OK" in l for l in lines)

    append_run(tmp_path, make_run(10.0, timestamp=ts(5)))
    lines = []
    assert gate_all(tmp_path, log=lines.append) == 1
    joined = "\n".join(lines)
    assert "REGRESSION" in joined and "demo.metric" in joined
    assert "FAILED" in joined


def test_gate_all_empty_dir_fails(tmp_path):
    lines = []
    assert gate_all(tmp_path, log=lines.append) == 1
    assert "no BENCH_" in lines[0]


def test_gate_all_malformed_trajectory_fails(tmp_path):
    (tmp_path / "BENCH_demo.json").write_text("{broken")
    lines = []
    assert gate_all(tmp_path, log=lines.append) == 1
    assert any("MALFORMED" in l for l in lines)


def test_gate_all_lists_every_violation(tmp_path):
    for i in range(3):
        append_run(tmp_path, make_run({"a": 100.0, "b": 200.0},
                                      timestamp=ts(i)))
    append_run(tmp_path, make_run({"a": 1.0, "b": 2.0}, timestamp=ts(4)))
    lines = []
    assert gate_all(tmp_path, log=lines.append) == 1
    joined = "\n".join(lines)
    assert "demo.a" in joined and "demo.b" in joined


def test_render_trends(tmp_path):
    for i, v in enumerate([100.0, 110.0]):
        append_run(tmp_path, make_run(v, timestamp=ts(i)))
    lines = render_trends(tmp_path)
    joined = "\n".join(lines)
    assert "BENCH_demo.json" in joined
    assert "| metric |" in joined
    assert "100 → 110" in joined
    assert render_trends(tmp_path / "empty") == []
