"""End-to-end system tests: the full Compass pipeline (paper §III).

offline:  COMPASS-V search  ->  Planner (profile + Pareto + AQM)
online:   Elastico switching in the discrete-event server
and the same pipeline over REAL locally-trained JAX models (marked slow).
"""

import statistics

import pytest

from repro.core.compass_v import CompassV
from repro.core.elastico import ElasticoController
from repro.core.planner import Planner
from repro.serving.simulator import ServingSimulator
from repro.serving.workload import bursty_pattern, generate_arrivals, spike_pattern

from conftest import make_profiler


def build_pipeline(surrogate, tau, slo):
    res = CompassV(
        space=surrogate.space,
        evaluator=surrogate,
        tau=tau,
        budget_schedule=(10, 25, 50, 100),
        seed=0,
    ).run()
    plan = Planner(profiler=make_profiler(surrogate)).plan(res.feasible, slo_p95_s=slo)
    return res, plan


def make_sampler(surrogate, ladder):
    def sampler(idx, rng):
        cfg = ladder[idx].point.config
        m = surrogate.mean_latency_s(cfg)
        cv = surrogate.latency_cv(cfg)
        return max(1e-4, rng.gauss(m, m * cv))

    return sampler


@pytest.mark.parametrize("pattern_name", ["spike", "bursty"])
def test_full_pipeline_meets_paper_bands(rag_surrogate, pattern_name):
    """Offline search + planning + online adaptation reproduces the paper's
    evaluation bands: Elastico lands in (or near) 90-98% compliance, beats
    static-accurate on compliance and static-fast on accuracy."""
    res, plan = build_pipeline(rag_surrogate, tau=0.75, slo=1.0)
    ladder = plan.table.policies
    assert len(ladder) >= 3

    rate = (
        spike_pattern(1.5, factor=4.0)
        if pattern_name == "spike"
        else bursty_pattern(1.5, seed=0)
    )
    arrivals = generate_arrivals(rate, 180.0, seed=1)
    sampler = make_sampler(rag_surrogate, ladder)

    def run(ctrl, static=0):
        sim = ServingSimulator(sampler, controller=ctrl, static_index=static, seed=2)
        out = sim.run(arrivals, 180.0)
        acc = statistics.mean(
            ladder[r.config_index].point.accuracy for r in out.completed
        )
        return out.slo_compliance(1.0), acc

    comp_e, acc_e = run(ElasticoController(plan.table))
    comp_fast, acc_fast = run(None, 0)
    comp_acc, acc_acc = run(None, len(ladder) - 1)

    assert comp_e >= 0.85, f"Elastico compliance {comp_e:.3f}"
    assert comp_e - comp_acc > 0.3, "must beat static-accurate on compliance"
    assert acc_e - acc_fast > 0.005, "must beat static-fast on accuracy"
    assert acc_acc > acc_e  # static-accurate still wins accuracy (by design)


def test_detection_pipeline_end_to_end(detection_surrogate):
    res, plan = build_pipeline(detection_surrogate, tau=0.6, slo=0.5)
    assert plan.table.ladder_size >= 2
    arrivals = generate_arrivals(spike_pattern(6.0, factor=3.0), 120.0, seed=3)
    sampler = make_sampler(detection_surrogate, plan.table.policies)
    sim = ServingSimulator(
        sampler, controller=ElasticoController(plan.table), seed=0
    )
    out = sim.run(arrivals, 120.0)
    assert len(out.completed) == len(arrivals)
    assert out.slo_compliance(0.5) > 0.7


@pytest.mark.slow
def test_real_rag_workflow_pipeline():
    """The paper pipeline over REAL tiny JAX models trained in-process:
    accuracy ladder emerges from model size, latency is true wall-clock."""
    from repro.workflows.rag import RagWorkflow

    wf = RagWorkflow(seed=0)
    wf.prepare()  # trains gen-s/gen-m/gen-l

    res = CompassV(
        space=wf.space,
        evaluator=wf.evaluate_samples,
        tau=0.5,
        budget_schedule=(8, 16, 32),
        seed=0,
    ).run()
    assert res.feasible, "no feasible configs found on the real workflow"

    plan = Planner(profiler=wf.profile_latency, profile_samples=8).plan(
        res.feasible, slo_p95_s=2.0
    )
    assert plan.table.ladder_size >= 1
    # larger generators must be slower on the front
    means = [p.profile.mean for p in plan.front]
    assert means == sorted(means)


def test_serving_ladder_every_arch():
    """Production-plane integration (deliverable a+f): the paper's pipeline
    runs over every assigned architecture's serving-config space and yields a
    usable AQM ladder."""
    import importlib

    bench = importlib.import_module("benchmarks.serving_ladders_bench")
    import repro.configs  # noqa: F401
    from repro.models.registry import arch_ids

    for arch in arch_ids():
        space, res, plan, validation = bench.build_ladder(
            arch, validate_duration_s=2.0, validate_replications=2)
        assert res.feasible, arch
        assert plan is not None and plan.table.ladder_size >= 1, arch
        # ladder ordering invariant (Eq. 4)
        means = [p.point.profile.mean for p in plan.table.policies]
        assert means == sorted(means)
        # the fast-path validation sweep covered every rung at every rate
        assert validation is not None
        assert len(validation.mean_wait_s) == plan.table.ladder_size
        assert validation.num_requests > 0


@pytest.mark.slow
def test_real_cascade_workflow_pipeline():
    """The paper's second workflow (detection cascade) over REAL locally
    trained models: bigger detectors and verifier escalation genuinely help,
    and the full search->plan pipeline produces a usable ladder."""
    import statistics

    from repro.workflows.cascade import CascadeWorkflow

    wf = CascadeWorkflow(seed=0)
    wf.prepare()

    def acc(d, n=80):
        return statistics.mean(wf.evaluate_samples(wf.space.from_dict(d), range(n)))

    base = {"verifier": "none", "confidence": 0.6, "smoothing": 0.0}
    a_n = acc({**base, "detector": "det-n"})
    a_m = acc({**base, "detector": "det-m"})
    a_casc = acc({"detector": "det-n", "verifier": "ver-x",
                  "confidence": 0.75, "smoothing": 0.0})
    assert a_m > a_n, "bigger detector must be more accurate"
    assert a_casc > a_n, "verifier escalation must help the small detector"

    res = CompassV(space=wf.space, evaluator=wf, tau=0.55,
                   budget_schedule=(10, 20, 40), seed=0).run()
    assert res.feasible
    plan = Planner(profiler=wf.profile_latency, profile_samples=8).plan(
        res.feasible, slo_p95_s=1.0
    )
    assert plan.table.ladder_size >= 1


def test_cost_annotation(rag_plan):
    """Cost/energy objectives (§VIII future work): rung cost is monotone in
    service time and the aggregate run cost is consistent."""
    from repro.core.cost import annotate_costs, timeline_cost

    res, plan = rag_plan
    rungs = annotate_costs(plan, chips=256)
    costs = [r.usd_per_1k_requests for r in rungs]
    assert costs == sorted(costs)          # slower rung => more $/request
    assert all(r.wh_per_1k_requests > 0 for r in rungs)
    agg = timeline_cost([], {r.index: 100 for r in rungs}, rungs)
    assert agg["requests"] == 100 * len(rungs)
    assert agg["usd"] == pytest.approx(
        sum(c / 1e3 * 100 for c in costs), rel=1e-9
    )
