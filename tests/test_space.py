"""ConfigSpace: structure, geometry, adjacency, LHS (paper §II-A, §IV-C)."""

import math

import pytest
from proptest import given, settings, st

from repro.core.space import (
    ConfigSpace,
    Parameter,
    detection_paper_space,
    rag_paper_space,
)


def small_space():
    return ConfigSpace(
        [
            Parameter("a", (1, 2, 3), kind="ordinal"),
            Parameter("b", ("x", "y"), kind="categorical"),
            Parameter("c", (0.1, 0.2, 0.3, 0.4), kind="ordinal"),
        ]
    )


# -- strategies ---------------------------------------------------------------

spaces = st.sampled_from([small_space(), rag_paper_space(), detection_paper_space()])


@st.composite
def space_and_config(draw):
    space = draw(spaces)
    idx = tuple(draw(st.integers(0, p.cardinality - 1)) for p in space.parameters)
    return space, space.from_indices(idx)


# -- basics -------------------------------------------------------------------


def test_cardinality_paper_spaces():
    assert rag_paper_space().cardinality == 6 * 5 * 4 * 3
    assert detection_paper_space().cardinality == 3 * 4 * 7 * 5


def test_enumerate_is_exhaustive_and_unique():
    space = small_space()
    all_cfgs = list(space.enumerate())
    assert len(all_cfgs) == space.cardinality == 24
    assert len(set(all_cfgs)) == len(all_cfgs)


def test_dict_roundtrip():
    space = small_space()
    cfg = (2, "y", 0.3)
    assert space.from_dict(space.as_dict(cfg)) == cfg


def test_validate_rejects_bad_configs():
    space = small_space()
    with pytest.raises(ValueError):
        space.validate((1, "x"))  # wrong arity
    with pytest.raises(KeyError):
        space.validate((1, "z", 0.1))  # bad value


def test_duplicate_parameter_names_rejected():
    with pytest.raises(ValueError):
        ConfigSpace([Parameter("a", (1,)), Parameter("a", (2,))])


def test_parameter_validation():
    with pytest.raises(ValueError):
        Parameter("empty", ())
    with pytest.raises(ValueError):
        Parameter("dup", (1, 1))
    with pytest.raises(ValueError):
        Parameter("kind", (1, 2), kind="weird")


# -- geometry -----------------------------------------------------------------


@given(space_and_config())
@settings(max_examples=60, deadline=None)
def test_normalize_in_unit_cube(sc):
    space, cfg = sc
    x = space.normalize(cfg)
    assert len(x) == space.num_parameters
    assert all(0.0 <= v <= 1.0 for v in x)


@given(space_and_config(), space_and_config())
@settings(max_examples=60, deadline=None)
def test_distance_symmetric_nonnegative(sc1, sc2):
    space1, a = sc1
    space2, b = sc2
    if space1 is not space2:
        return
    d = space1.distance(a, b)
    assert d >= 0.0
    assert math.isclose(d, space1.distance(b, a))
    assert (d == 0.0) == (space1.normalize(a) == space1.normalize(b))


@given(space_and_config())
@settings(max_examples=60, deadline=None)
def test_neighbors_differ_in_exactly_one_axis(sc):
    """Paper §IV-C adjacency: neighbors differ in exactly one parameter."""
    space, cfg = sc
    idx = space.indices(cfg)
    for nb in space.neighbors(cfg):
        nidx = space.indices(nb)
        diffs = [i for i, (x, y) in enumerate(zip(idx, nidx)) if x != y]
        assert len(diffs) == 1
        ax = diffs[0]
        if space.parameters[ax].kind == "ordinal":
            assert abs(idx[ax] - nidx[ax]) == 1


@given(space_and_config())
@settings(max_examples=40, deadline=None)
def test_adjacency_is_symmetric(sc):
    space, cfg = sc
    for nb in space.neighbors(cfg):
        assert cfg in space.neighbors(nb)


def test_step_on_axis_bounds():
    space = small_space()
    lo = space.from_indices((0, 0, 0))
    assert space.step_on_axis(lo, 0, -1) is None
    up = space.step_on_axis(lo, 0, +1)
    assert space.indices(up)[0] == 1


# -- LHS ----------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 5, 20, 100])
def test_lhs_distinct_valid_samples(n):
    space = rag_paper_space()
    samples = space.lhs_sample(n, seed=3)
    assert len(samples) == min(n, space.cardinality)
    assert len(set(samples)) == len(samples)
    for s in samples:
        space.validate(s)


def test_lhs_deterministic_per_seed():
    space = detection_paper_space()
    assert space.lhs_sample(16, seed=7) == space.lhs_sample(16, seed=7)
    assert space.lhs_sample(16, seed=7) != space.lhs_sample(16, seed=8)


def test_lhs_stratification_covers_axis():
    """With n >= cardinality of an axis, every value of that axis appears."""
    space = small_space()
    samples = space.lhs_sample(24, seed=0)
    for ax, p in enumerate(space.parameters):
        seen = {space.indices(s)[ax] for s in samples}
        assert seen == set(range(p.cardinality))
