"""IDW finite-difference gradients (paper Eq. 3) and lateral-axis selection."""

import math

import pytest

from repro.core.gradient import idw_gradient, low_gradient_axes
from repro.core.space import ConfigSpace, Parameter


def grid_space(n=5, m=5):
    return ConfigSpace(
        [
            Parameter("x", tuple(range(n)), kind="ordinal"),
            Parameter("y", tuple(range(m)), kind="ordinal"),
        ]
    )


def test_gradient_points_uphill_on_linear_surface():
    """Acc = x_norm (increases along axis 0, flat along axis 1)."""
    space = grid_space()
    evaluated = {c: space.normalize(c)[0] for c in space.enumerate()}
    g = idw_gradient(space, (2, 2), evaluated, k=8)
    assert g.vector[0] > 0.1
    assert abs(g.vector[1]) < 1e-6
    assert g.support == 8


def test_gradient_sign_flips_on_descending_surface():
    space = grid_space()
    evaluated = {c: 1.0 - space.normalize(c)[0] for c in space.enumerate()}
    g = idw_gradient(space, (2, 2), evaluated, k=8)
    assert g.vector[0] < -0.1


def test_gradient_requires_center_evaluated():
    space = grid_space()
    with pytest.raises(KeyError):
        idw_gradient(space, (0, 0), {(1, 1): 0.5})


def test_gradient_no_neighbors_is_zero():
    space = grid_space()
    g = idw_gradient(space, (0, 0), {(0, 0): 0.5})
    assert g.vector == (0.0, 0.0)
    assert g.support == 0 and g.magnitude == 0.0


def test_closer_neighbors_dominate():
    """IDW weighting: a near neighbor with +delta outweighs a far one with
    -delta."""
    space = grid_space(9, 9)
    c = (4, 4)
    evaluated = {
        c: 0.5,
        (5, 4): 0.6,   # distance 1/8 on axis 0, uphill
        (0, 4): 0.1,   # distance 4/8, steeply downhill but far
    }
    g = idw_gradient(space, c, evaluated, k=8, power=2.0)
    assert g.vector[0] > 0


def test_low_gradient_axes_orders_by_magnitude():
    from repro.core.gradient import GradientEstimate

    g = GradientEstimate(vector=(0.9, 0.01, -0.5, 0.02), support=4)
    axes = low_gradient_axes(g, fraction=0.5)
    assert set(axes) == {1, 3}


def test_magnitude():
    from repro.core.gradient import GradientEstimate

    g = GradientEstimate(vector=(3.0, 4.0), support=2)
    assert math.isclose(g.magnitude, 5.0)
