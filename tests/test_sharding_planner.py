"""ShardingPlanner: divisibility fallback, rule sets, hint no-op semantics."""

import jax
import pytest
from proptest import given, settings, st
from jax.sharding import PartitionSpec

from repro.sharding.planner import ShardingPlanner, shard_hint


@pytest.fixture(scope="module")
def mesh():
    # 1x1 mesh: axis names exist, every size divides, single real device
    return jax.make_mesh((1, 1), ("data", "model"))


def planner(mesh, **kw):
    return ShardingPlanner(mesh, **kw)


def test_basic_rules_train(mesh):
    p = planner(mesh, context="train")
    assert p.spec_for((1024, 4096), ("embed", "mlp")) == PartitionSpec("data", "model")
    assert p.spec_for((64, 1024, 128), ("experts", "embed", "mlp")) == \
        PartitionSpec("model", "data", None)  # model consumed by experts first


def test_vocab_params_not_fsdp_sharded(mesh):
    p = planner(mesh, context="train")
    # embed dim of a vocab-bearing tensor stays unsharded (§Perf pair B)
    assert p.spec_for((1024, 50304), ("embed", "vocab")) == PartitionSpec(None, "model")
    assert p.spec_for((50304, 1024), ("vocab", "embed")) == PartitionSpec("model", None)
    # opt-in restores the old behavior
    p2 = planner(mesh, context="train", fsdp_vocab=True)
    assert p2.spec_for((1024, 50304), ("embed", "vocab")) == PartitionSpec("data", "model")


def test_serve_context_no_fsdp(mesh):
    p = planner(mesh, context="serve")
    assert p.spec_for((1024, 4096), ("embed", "mlp")) == PartitionSpec(None, "model")


def test_serve_weight_2d(mesh):
    p = planner(mesh, context="serve", serve_weight_2d=True)
    assert p.spec_for((1024, 4096), ("embed", "mlp")) == PartitionSpec("data", "model")


def test_divisibility_fallback():
    """Dims the axis size does not divide are replicated (e.g. hymba's 25
    heads, granite's 49155 vocab on a 16-way model axis)."""
    mesh16 = jax.make_mesh((1, 1), ("data", "model"))
    p = ShardingPlanner(mesh16)
    # fake a 16-wide model axis through the divisibility check
    p.axis_sizes = {"data": 16, "model": 16}
    assert p.spec_for((25, 64), ("heads", "head")) == PartitionSpec(None, None)
    assert p.spec_for((49155, 1536), ("vocab", "embed")) == PartitionSpec(None, None)
    assert p.spec_for((32, 64), ("heads", "head")) == PartitionSpec("model", None)


@given(
    st.integers(1, 8).map(lambda k: 2 ** k),
    st.sampled_from(["embed", "mlp", "heads", "vocab", "batch", None]),
)
@settings(max_examples=60, deadline=None)
def test_spec_rank_and_axis_use(size, logical):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    p = ShardingPlanner(mesh)
    p.axis_sizes = {"data": 4, "model": 8}
    spec = p.spec_for((size, size), (logical, logical))
    assert len(spec) == 2
    # a mesh axis is consumed at most once per tensor
    used = [a for dim in spec if dim for a in (dim if isinstance(dim, tuple) else (dim,))]
    assert len(used) == len(set(used))


def test_shard_hint_noop_outside_mesh():
    import jax.numpy as jnp

    x = jnp.ones((8, 16))
    y = shard_hint(x, ["batch", None])
    assert (y == x).all()


def test_shard_hint_skips_nondivisible_dims(mesh):
    import jax.numpy as jnp

    with mesh:
        # 7 not divisible by model size 1? size-1 axes divide everything;
        # exercise via the divisibility branch using a fake... just assert
        # it runs and preserves values under a live mesh context.
        x = jnp.ones((8, 7))
        y = shard_hint(x, ["batch", "model"])
        assert (y == x).all()
