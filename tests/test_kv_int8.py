"""int8-quantized KV cache (§Perf pair C optimization).

Validates that the quantized cache (a) halves storage, (b) keeps decode
logits within ~1-2% of the bf16/f32 cache, (c) preserves greedy decisions.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs  # noqa: F401
from repro.configs.reduced import reduced_config
from repro.models.attention import _dequantize_kv, _quantize_kv
from repro.models.registry import build_model


def test_quantize_roundtrip_error_bounded():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 16, 8, 64)) * 3.0
    q, s = _quantize_kv(x)
    assert q.dtype == jnp.int8
    assert s.shape == x.shape[:-1]
    back = _dequantize_kv(q, s, jnp.float32)
    # absmax int8: error bounded by scale/2 = absmax/254 per row
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    err = jnp.abs(back - x)
    assert float(jnp.max(err / jnp.maximum(absmax, 1e-9))) <= 1.0 / 127 + 1e-6


def test_quantize_handles_zeros():
    q, s = _quantize_kv(jnp.zeros((2, 3, 4)))
    assert np.all(np.asarray(q) == 0)
    back = _dequantize_kv(q, s, jnp.float32)
    assert np.all(np.asarray(back) == 0)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "stablelm-3b", "hymba-1.5b"])
def test_decode_matches_fp_cache(arch):
    cfg = reduced_config(arch)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    m, m8 = build_model(cfg), build_model(cfg8)
    key = jax.random.PRNGKey(1)
    params = m.init(key)
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}

    last, st = m.prefill(params, batch, cache_len=S + 8)
    last8, st8 = m8.prefill(params, batch, cache_len=S + 8)
    # int8 leaves actually present
    leaves8 = jax.tree_util.tree_leaves(st8)
    assert any(l.dtype == jnp.int8 for l in leaves8)

    # single-step comparison: one decode step against the just-prefilled
    # cache.  (Closed-loop multi-step drift on RANDOM-INIT weights is not a
    # meaningful quantization metric — the logit gaps are themselves noise.)
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    l1, st = m.decode_step(params, st, tok)
    l2, st8 = m8.decode_step(params, st8, tok)
    a = np.asarray(l1, np.float32)
    b = np.asarray(l2, np.float32)
    rel = np.abs(a - b).max() / max(np.abs(a).max(), 1e-9)
    assert rel < 0.05, rel
    # high agreement of the full logit vector, not just its max
    corr = np.corrcoef(a.reshape(-1), b.reshape(-1))[0, 1]
    assert corr > 0.999, corr


def test_int8_cache_storage_is_half():
    cfg = reduced_config("internlm2-1.8b")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    m, m8 = build_model(cfg), build_model(cfg8)
    st = jax.eval_shape(lambda: m.init_decode_state(2, 1024))
    st8 = jax.eval_shape(lambda: m8.init_decode_state(2, 1024))

    def nbytes(t):
        return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(t))

    # int8 cache + fp32 scales must be well below the bf16 cache
    assert nbytes(st8) < 0.6 * nbytes(st)
