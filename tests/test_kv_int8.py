"""int8-quantized KV cache (§Perf pair C optimization).

Validates that the quantized cache (a) halves storage, (b) keeps decode
logits within ~1-2% of the bf16/f32 cache, (c) preserves greedy decisions.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs  # noqa: F401
from repro.configs.reduced import reduced_config
from repro.models.attention import _dequantize_kv, _quantize_kv
from repro.models.registry import build_model


def test_quantize_roundtrip_error_bounded():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 16, 8, 64)) * 3.0
    q, s = _quantize_kv(x)
    assert q.dtype == jnp.int8
    assert s.shape == x.shape[:-1]
    back = _dequantize_kv(q, s, jnp.float32)
    # absmax int8: error bounded by scale/2 = absmax/254 per row
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    err = jnp.abs(back - x)
    assert float(jnp.max(err / jnp.maximum(absmax, 1e-9))) <= 1.0 / 127 + 1e-6


def test_quantize_handles_zeros():
    q, s = _quantize_kv(jnp.zeros((2, 3, 4)))
    assert np.all(np.asarray(q) == 0)
    back = _dequantize_kv(q, s, jnp.float32)
    assert np.all(np.asarray(back) == 0)


@pytest.mark.parametrize(
    "arch",
    [
        "internlm2-1.8b",
        pytest.param(
            "stablelm-3b",
            marks=pytest.mark.xfail(
                strict=False,
                reason="random-init tied top-2 attention scores: decode "
                "logits are discontinuous in K for this config; see "
                "test_stablelm_decode_ill_conditioned_reproducer",
            ),
        ),
        "hymba-1.5b",
    ],
)
def test_decode_matches_fp_cache(arch):
    """Diagnosis of the stablelm-3b xfail (rel ~ 0.53 vs the 0.05 bound):

    Under the random-init reduced configs the pre-softmax attention scores
    are enormous (|score| ~ 4e3 at fp32, against a softmax scale of 1), so
    every decode head is numerically one-hot: the output is the value row
    of the single winning key.  For stablelm-3b — the only full-MHA config
    in this sweep — one decode head's top-2 key scores are EXACTLY tied at
    bf16 resolution (internlm2's smallest gap is 64).  Any perturbation of
    the cached K breaks the tie arbitrarily, so that head attends a
    completely different value row and the final logits move by O(1).

    The int8 path itself is structurally exact: replacing the
    quantize/dequantize pair with an identity passthrough reproduces the
    fp logits bit-for-bit, and Gaussian K noise at 0.2% of row absmax —
    a quarter of int8's own worst-case rounding error (1/254 ~ 0.4%) —
    already produces the same O(0.5) relative logit error with no
    quantization involved (see the reproducer test below).  The failure is
    a property of this arch/seed's degenerate random-init attention, not
    of the quantized cache."""
    cfg = reduced_config(arch)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    m, m8 = build_model(cfg), build_model(cfg8)
    key = jax.random.PRNGKey(1)
    params = m.init(key)
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}

    last, st = m.prefill(params, batch, cache_len=S + 8)
    last8, st8 = m8.prefill(params, batch, cache_len=S + 8)
    # int8 leaves actually present
    leaves8 = jax.tree_util.tree_leaves(st8)
    assert any(l.dtype == jnp.int8 for l in leaves8)

    # single-step comparison: one decode step against the just-prefilled
    # cache.  (Closed-loop multi-step drift on RANDOM-INIT weights is not a
    # meaningful quantization metric — the logit gaps are themselves noise.)
    tok = jnp.argmax(last, -1).astype(jnp.int32)
    l1, st = m.decode_step(params, st, tok)
    l2, st8 = m8.decode_step(params, st8, tok)
    a = np.asarray(l1, np.float32)
    b = np.asarray(l2, np.float32)
    rel = np.abs(a - b).max() / max(np.abs(a).max(), 1e-9)
    assert rel < 0.05, rel
    # high agreement of the full logit vector, not just its max
    corr = np.corrcoef(a.reshape(-1), b.reshape(-1))[0, 1]
    assert corr > 0.999, corr


def _prefill_decode_logits(cfg, monkey_quantize=None, monkey_dequantize=None):
    """One prefill + one greedy decode step; optionally with the module's
    quantize/dequantize pair replaced (restored afterwards)."""
    import repro.models.attention as att

    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    m, m8 = build_model(cfg), build_model(cfg8)
    key = jax.random.PRNGKey(1)
    params = m.init(key)
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    orig = (att._quantize_kv, att._dequantize_kv)
    if monkey_quantize is not None:
        att._quantize_kv = monkey_quantize
    if monkey_dequantize is not None:
        att._dequantize_kv = monkey_dequantize
    try:
        last, st = m.prefill(params, batch, cache_len=S + 8)
        last8, st8 = m8.prefill(params, batch, cache_len=S + 8)
        tok = jnp.argmax(last, -1).astype(jnp.int32)
        l1, _ = m.decode_step(params, st, tok)
        l2, _ = m8.decode_step(params, st8, tok)
    finally:
        att._quantize_kv, att._dequantize_kv = orig
    a = np.asarray(l1, np.float32)
    b = np.asarray(l2, np.float32)
    return a, b, np.abs(a - b).max() / max(np.abs(a).max(), 1e-9)


def test_stablelm_decode_ill_conditioned_reproducer():
    """Minimal reproducer for the stablelm-3b xfail above.

    Two claims, each isolating one side of the failure:

    1. The int8 cache path is structurally exact: with the
       quantize/dequantize pair replaced by a lossless passthrough
       (identity values, unit scales) the "quantized" model reproduces the
       fp decode logits bit-for-bit.  Every cache index, update slice and
       attention mask in the int8 path is therefore correct — the rel=0.53
       failure cannot be a plumbing bug.

    2. The config itself is ill-conditioned: additive Gaussian noise on the
       cached K at 0.2% of each row's absmax — a quarter of int8's
       worst-case rounding error of 1/254 per row — already moves the
       decode logits past the 5% tolerance the accuracy test uses, with no
       quantization anywhere.  One decode head's top-2 key scores are
       exactly tied at bf16 resolution while softmax runs fully saturated
       (|score| ~ 4e3), so the logits are a discontinuous function of K
       and ANY sub-percent cache perturbation can flip them by O(1).
    """
    cfg = reduced_config("stablelm-3b")

    # -- claim 1: passthrough quantizer => bit-exact decode ---------------
    def pass_q(x):
        return x.astype(jnp.float32), jnp.ones(x.shape[:-1], jnp.float32)

    def pass_d(q, s, dtype):
        del s
        return q.astype(dtype)

    a, b, rel = _prefill_decode_logits(cfg, pass_q, pass_d)
    assert np.array_equal(a, b), f"int8 plumbing not exact: rel={rel}"

    # -- claim 2: K noise far below the int8 bound flips the logits -------
    EPS = 0.002  # 0.2% of row absmax; int8's own bound is 1/254 ~ 0.4%
    worst = 0.0
    for noise_seed in (7, 8, 9):
        calls = {"n": 0}

        def noisy_q(x):
            i = calls["n"]
            calls["n"] += 1
            if i % 2 == 0:  # K is quantized before V at every call site
                sub = jax.random.PRNGKey(1000 * noise_seed + i)
                absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
                x = x + EPS * absmax * jax.random.normal(sub, x.shape)
            return x.astype(jnp.float32), jnp.ones(x.shape[:-1], jnp.float32)

        _, _, rel = _prefill_decode_logits(cfg, noisy_q, pass_d)
        worst = max(worst, rel)
        if worst > 0.05:
            break
    assert worst > 0.05, (
        f"expected tiny K noise to exceed the 5% decode tolerance on the "
        f"degenerate stablelm-3b config, got rel={worst}"
    )


def test_int8_cache_storage_is_half():
    cfg = reduced_config("internlm2-1.8b")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    m, m8 = build_model(cfg), build_model(cfg8)
    st = jax.eval_shape(lambda: m.init_decode_state(2, 1024))
    st8 = jax.eval_shape(lambda: m8.init_decode_state(2, 1024))

    def nbytes(t):
        return sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(t))

    # int8 cache + fp32 scales must be well below the bf16 cache
    assert nbytes(st8) < 0.6 * nbytes(st)
