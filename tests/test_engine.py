"""Real-time threaded serving engine (paper §III-B architecture)."""

import time

import pytest

from repro.core.aqm import HysteresisSpec, derive_policies
from repro.core.elastico import ElasticoController
from repro.serving.engine import ServingEngine, replay_workload
from repro.serving.executor import WorkflowExecutor
from repro.serving.monitor import LoadMonitor
from repro.serving.scheduler import Scheduler
from repro.serving.workload import Request

from conftest import synthetic_point


SERVICE = {0: 0.002, 1: 0.008}


def workflow_fn(config, payload):
    time.sleep(SERVICE[config[1]])
    return 1.0


def make_engine(controller=None):
    executor = WorkflowExecutor(
        configs=[("cfg", 0), ("cfg", 1)], workflow_fn=workflow_fn
    )
    return ServingEngine(executor, controller=controller, control_tick_s=0.01)


def test_engine_serves_all_requests():
    engine = make_engine()
    engine.start()
    for i in range(20):
        engine.submit(Request(request_id=i, arrival_s=0.0))
    report = engine.drain_and_stop()
    assert len(report.records) == 20
    ids = sorted(r.request_id for r in report.records)
    assert ids == list(range(20))
    assert all(r.latency_s > 0 for r in report.records)
    # latencies are on the engine-relative axis: 20 x 2ms of service through
    # a single worker must land well under a second (catches epoch-offset
    # timestamp bugs)
    assert max(r.latency_s for r in report.records) < 1.0
    assert report.slo_compliance(1.0) == 1.0


def test_engine_with_elastico_switches_under_burst():
    front = [
        synthetic_point(0.002, 0.003, 0.7, "fast"),
        synthetic_point(0.008, 0.012, 0.9, "accurate"),
    ]
    table = derive_policies(
        front,
        slo_p95_s=0.05,
        hysteresis=HysteresisSpec(upscale_cooldown_s=0.0, downscale_cooldown_s=0.2),
    )
    ctrl = ElasticoController(table)  # starts accurate
    engine = make_engine(ctrl)
    engine.start()
    # burst of 150 requests back-to-back: queue depth blows past N_up
    for i in range(150):
        engine.submit(Request(request_id=i, arrival_s=0.0))
    report = engine.drain_and_stop()
    assert len(report.records) == 150
    assert any(e.direction == "faster" for e in ctrl.events)


def test_replay_workload_timing():
    engine = make_engine()
    engine.start()
    t0 = time.monotonic()
    replay_workload(engine, [0.0, 0.02, 0.04], time_scale=1.0)
    report = engine.drain_and_stop()
    assert len(report.records) == 3
    assert time.monotonic() - t0 >= 0.04


def test_scheduler_fifo_and_close():
    """The shared core preserves FIFO order across dispatches and rejects
    ingress after close (the semantics the old RequestQueue provided for
    the engine alone)."""
    s = Scheduler(num_workers=1)
    for i in range(5):
        s.offer(Request(request_id=i, arrival_s=0.0), 0.0)
    assert s.buffered() == 5
    served = []
    for t in range(5):
        dispatches, _ = s.poll(float(t))
        for d in dispatches:
            served.extend(r.request_id for r in d.items)
            s.release(d.worker_id, float(t))
    assert served == list(range(5))
    s.close()
    with pytest.raises(RuntimeError):
        s.offer(Request(request_id=9, arrival_s=0.0), 9.0)


def test_load_monitor_rates():
    mon = LoadMonitor(halflife_s=1.0)
    for i in range(40):
        mon.record_arrival(now_s=i * 0.1)
    assert mon.total_arrivals == 40
    # steady 10 QPS stream: EWMA should land in the right decade
    assert 3.0 < mon.arrival_rate(now_s=4.0) < 30.0
    snap = mon.snapshot(queue_depth=3, in_flight=1, now_s=4.1)
    assert snap.queue_depth == 3 and snap.in_flight == 1
