"""Wilson intervals + progressive evaluation (paper §IV-B)."""

import math

import pytest
from proptest import given, settings, st

from repro.core.evaluate import ProgressiveEvaluator, make_budget_schedule
from repro.core.wilson import classify, wilson_interval, z_value


# -- z values -----------------------------------------------------------------


def test_z_values_match_tables():
    assert math.isclose(z_value(0.95), 1.959963984540054)
    # Acklam approximation path for non-tabled levels
    assert abs(z_value(0.954499736104) - 2.0) < 1e-4


# -- interval properties ------------------------------------------------------


@given(
    st.integers(1, 500),
    st.floats(0.0, 1.0, allow_nan=False),
    st.sampled_from([0.8, 0.9, 0.95, 0.99]),
)
@settings(max_examples=200, deadline=None)
def test_wilson_interval_invariants(n, frac, conf):
    s = frac * n
    ci = wilson_interval(s, n, conf)
    assert 0.0 <= ci.lower <= ci.center <= ci.upper <= 1.0
    # interval contains the point estimate's shrunk center, and p_hat is
    # inside [lower, upper] (Wilson is centered on a shrunk estimate but
    # always covers p_hat)
    p_hat = s / n
    assert ci.lower - 1e-12 <= p_hat <= ci.upper + 1e-12 or n < 3


@given(st.floats(0.05, 0.95), st.sampled_from([0.9, 0.95]))
@settings(max_examples=50, deadline=None)
def test_wilson_width_shrinks_with_n(p, conf):
    widths = [wilson_interval(p * n, n, conf).width for n in (10, 40, 160, 640)]
    assert all(a > b for a, b in zip(widths, widths[1:]))


def test_wilson_zero_trials():
    ci = wilson_interval(0, 0)
    assert (ci.lower, ci.upper) == (0.0, 1.0)


def test_wilson_rejects_out_of_range():
    with pytest.raises(ValueError):
        wilson_interval(11, 10)


def test_classify_three_way():
    assert classify(98, 100, 0.75) == "feasible"
    assert classify(10, 100, 0.75) == "infeasible"
    assert classify(76, 100, 0.75) == "uncertain"


# -- progressive evaluation ---------------------------------------------------


class CountingEvaluator:
    """Deterministic scorer: every sample returns ``value``."""

    def __init__(self, value):
        self.value = value
        self.calls = 0

    def __call__(self, config, idx):
        self.calls += len(list(idx))
        return [self.value] * len(list(idx))


def test_early_stop_clearly_feasible():
    ev = CountingEvaluator(1.0)
    pe = ProgressiveEvaluator(evaluator=ev, budget_schedule=(10, 25, 50, 100))
    res = pe.evaluate(("c",), tau=0.5)
    assert res.classification == "feasible"
    assert res.samples_used == 10  # stopped at the first budget level
    assert ev.calls == 10


def test_early_stop_clearly_infeasible():
    ev = CountingEvaluator(0.0)
    pe = ProgressiveEvaluator(evaluator=ev, budget_schedule=(10, 25, 50, 100))
    res = pe.evaluate(("c",), tau=0.5)
    assert res.classification == "infeasible"
    assert res.samples_used == 10


def test_borderline_consumes_full_budget():
    ev = CountingEvaluator(0.75)
    pe = ProgressiveEvaluator(evaluator=ev, budget_schedule=(10, 25, 50, 100))
    res = pe.evaluate(("c",), tau=0.75)
    assert res.samples_used == 100  # never confident at tau == true value
    # budget exhaustion resolves by point estimate
    assert res.classification == "feasible"


def test_asymmetric_infeasible_confidence_uses_more_samples():
    ev1 = CountingEvaluator(0.62)
    pe1 = ProgressiveEvaluator(evaluator=ev1, budget_schedule=(10, 25, 50, 100))
    r1 = pe1.evaluate(("c",), tau=0.75)
    ev2 = CountingEvaluator(0.62)
    pe2 = ProgressiveEvaluator(
        evaluator=ev2, budget_schedule=(10, 25, 50, 100), infeasible_confidence=0.999
    )
    r2 = pe2.evaluate(("c",), tau=0.75)
    assert r1.classification == r2.classification == "infeasible"
    assert r2.samples_used >= r1.samples_used


def test_rejects_bad_schedules_and_scores():
    with pytest.raises(ValueError):
        ProgressiveEvaluator(evaluator=CountingEvaluator(1.0), budget_schedule=())
    with pytest.raises(ValueError):
        ProgressiveEvaluator(evaluator=CountingEvaluator(1.0), budget_schedule=(10, 10))
    pe = ProgressiveEvaluator(evaluator=CountingEvaluator(1.5), budget_schedule=(5,))
    with pytest.raises(ValueError):
        pe.evaluate(("c",), tau=0.5)


def test_sample_order_respected():
    seen = []

    def ev(config, idx):
        seen.extend(idx)
        return [1.0] * len(list(idx))

    order = list(range(99, -1, -1))
    pe = ProgressiveEvaluator(evaluator=ev, budget_schedule=(10,), sample_order=order)
    pe.evaluate(("c",), tau=0.5)
    assert seen == order[:10]


@given(st.integers(11, 5000))
@settings(max_examples=50, deadline=None)
def test_make_budget_schedule_invariants(max_budget):
    sched = make_budget_schedule(max_budget)
    assert sched[-1] == max_budget
    assert all(a < b for a, b in zip(sched, sched[1:]))
    assert sched[0] >= 1
