"""Shared fixtures.

NOTE: this process must keep the real single-device view — the 512-device
forcing happens only inside ``repro.launch.dryrun`` subprocesses.
"""

import random

import pytest

from repro.core.compass_v import CompassV
from repro.core.pareto import LatencyProfile, ParetoPoint
from repro.core.planner import Planner
from repro.workflows.surrogate import DetectionSurrogate, RagSurrogate


@pytest.fixture(scope="session")
def rag_surrogate():
    return RagSurrogate(seed=0)


@pytest.fixture(scope="session")
def detection_surrogate():
    return DetectionSurrogate(seed=0)


def full_budget_accuracy(surrogate, config, budget=100):
    xs = surrogate.evaluate_samples(config, range(budget))
    return sum(xs) / len(xs)


def exhaustive_feasible(surrogate, tau, budget=100):
    """Ground truth exactly as the paper's grid-search baseline computes it:
    every configuration evaluated at the full budget."""
    return {
        c
        for c in surrogate.space.enumerate()
        if full_budget_accuracy(surrogate, c, budget) >= tau
    }


def make_profiler(surrogate):
    def profiler(config, n):
        import zlib

        rng = random.Random(zlib.crc32(repr(config).encode()) & 0xFFFF)
        m = surrogate.mean_latency_s(config)
        cv = surrogate.latency_cv(config)
        return [max(1e-4, rng.gauss(m, m * cv)) for _ in range(n)]

    return profiler


@pytest.fixture(scope="session")
def rag_plan(rag_surrogate):
    """Search -> plan pipeline output for the RAG surrogate at tau=0.75."""
    res = CompassV(
        space=rag_surrogate.space,
        evaluator=rag_surrogate,
        tau=0.75,
        budget_schedule=(10, 25, 50, 100),
        seed=0,
    ).run()
    plan = Planner(profiler=make_profiler(rag_surrogate)).plan(
        res.feasible, slo_p95_s=1.0
    )
    return res, plan


def synthetic_point(mean, p95, acc, name="c"):
    return ParetoPoint(
        config=(name, mean),
        accuracy=acc,
        profile=LatencyProfile(mean=mean, p95=p95),
    )
