"""Fault-injection plane and degradation-aware adaptation.

The contract stack, strictest first:

1. **The empty schedule is inert**: ``faults=None``, ``FaultSchedule()``
   and an omitted argument all take the identical code path — completions,
   timelines and busy time are bit-for-bit equal across the flat
   simulator (every queue discipline and batch shape) and the DAG oracle.
2. **Crash semantics are exact**: a crashed worker's in-flight batch is
   cancelled and requeued at the queue head, retried under a per-request
   budget, and counted as ``failed`` — never silently lost — when the
   budget runs out.  Deterministic samplers make the retried completion
   times exact.
3. **Degradation-aware control**: :func:`repro.core.aqm.\
derive_degraded_tables` pre-derives one threshold table per surviving
   capacity, and :meth:`repro.core.elastico.ElasticoController.\
on_capacity_change` swaps them at the instant the scheduler loses or
   regains a worker.
4. **Wall-clock hardening**: a raising ``workflow_fn`` neither deadlocks
   the pool nor loses accounting; ``drain_and_stop`` reports a truthful
   ``drain_timed_out`` / ``backlog`` instead of hanging when every worker
   is dead.
"""

import time

import pytest

from proptest import given, settings, st

from repro.core.aqm import (
    HysteresisSpec,
    derive_degraded_tables,
    derive_mix_policies,
    derive_policies,
)
from repro.core.elastico import ElasticoController, ElasticoMixController
from repro.serving import fastsim
from repro.serving.dag import DagSimulator, StageSpec, WorkflowDAG
from repro.serving.engine import ServingEngine
from repro.serving.executor import WorkerPool, WorkflowExecutor
from repro.serving.fastsim import FastSimulationResult, fast_path_eligible
from repro.serving.faults import Brownout, FaultSchedule, Straggler, WorkerCrash
from repro.serving.simulator import (
    ServingSimulator,
    SimulationResult,
    deterministic_sampler,
    lognormal_sampler_from_profile,
)
from repro.serving.workload import (
    Request,
    constant_rate,
    generate_arrivals,
)

from conftest import synthetic_point

MEANS = [0.10, 0.25, 0.45]
P95S = [0.14, 0.35, 0.63]
ACCS = [0.76, 0.82, 0.85]
SLO_S = 1.0


def ladder_front():
    return [
        synthetic_point(m, p, a, f"c{i}")
        for i, (m, p, a) in enumerate(zip(MEANS, P95S, ACCS))
    ]


def flat_stage(name="svc", **kw):
    return StageSpec(name=name, mean_s=tuple(MEANS), p95_s=tuple(P95S),
                     accuracy=tuple(ACCS), **kw)


# --------------------------------------------------------------------------
# 1. schedule construction and validation
# --------------------------------------------------------------------------


def test_fault_dataclass_validation():
    with pytest.raises(ValueError, match="recover_s"):
        WorkerCrash(time_s=5.0, worker_id=0, recover_s=5.0)
    with pytest.raises(ValueError, match=">= 0"):
        WorkerCrash(time_s=-1.0, worker_id=0)
    with pytest.raises(ValueError, match="factor"):
        Straggler(worker_id=0, start_s=0.0, end_s=1.0, factor=1.0)
    with pytest.raises(ValueError, match="start_s"):
        Straggler(worker_id=0, start_s=2.0, end_s=1.0, factor=2.0)
    with pytest.raises(ValueError, match="factor"):
        Brownout(stage=0, start_s=0.0, end_s=1.0, factor=0.5)


def test_schedule_rejects_overlapping_down_windows():
    # crash at t=3 while still down since t=1 is a schedule bug
    with pytest.raises(ValueError, match="overlapping"):
        FaultSchedule(crashes=(
            WorkerCrash(time_s=1.0, worker_id=0, recover_s=5.0),
            WorkerCrash(time_s=3.0, worker_id=0, recover_s=9.0),
        ))
    # a permanent crash (recover_s=None) blocks any later crash too
    with pytest.raises(ValueError, match="overlapping"):
        FaultSchedule(crashes=(
            WorkerCrash(time_s=1.0, worker_id=0),
            WorkerCrash(time_s=3.0, worker_id=0),
        ))
    # sequential windows on one worker, and overlap on *different* workers
    # (or the same id at a different stage), are fine
    FaultSchedule(crashes=(
        WorkerCrash(time_s=1.0, worker_id=0, recover_s=2.0),
        WorkerCrash(time_s=2.0, worker_id=0, recover_s=3.0),
        WorkerCrash(time_s=1.5, worker_id=1, recover_s=9.0),
        WorkerCrash(time_s=1.5, worker_id=0, recover_s=9.0, stage=2),
    ))


def test_capacity_events_sorted_crash_before_recover():
    sched = FaultSchedule(crashes=(
        WorkerCrash(time_s=2.0, worker_id=1, recover_s=4.0),
        WorkerCrash(time_s=4.0, worker_id=0, recover_s=6.0),
    ))
    ev = sched.capacity_events(None)
    assert ev == [(2.0, "crash", 1), (4.0, "crash", 0),
                  (4.0, "recover", 1), (6.0, "recover", 0)]
    # stage scoping: nothing addressed to stage 3
    assert sched.capacity_events(3) == []


def test_inflation_composes_stragglers_and_brownouts():
    sched = FaultSchedule(
        stragglers=(
            Straggler(worker_id=0, start_s=1.0, end_s=2.0, factor=2.0),
            Straggler(worker_id=0, start_s=1.5, end_s=3.0, factor=1.5,
                      stage=1),
        ),
        brownouts=(Brownout(stage=1, start_s=0.0, end_s=4.0, factor=3.0),),
    )
    # flat pool: only the stage=None straggler applies, [start, end) closed-open
    assert sched.inflation(0, 1.0) == 2.0
    assert sched.inflation(0, 2.0) == 1.0
    assert sched.inflation(1, 1.0) == 1.0
    # stage 1: brownout x stage-scoped straggler compose multiplicatively
    assert sched.inflation(0, 1.5, stage=1) == pytest.approx(4.5)
    assert sched.inflation(0, 3.5, stage=1) == 3.0
    assert sched.max_worker(None) == 0
    assert sched.max_worker(1) == 0
    assert FaultSchedule().max_worker() == -1


def test_driver_validation_rejects_out_of_range_faults():
    bad = FaultSchedule(crashes=(WorkerCrash(time_s=1.0, worker_id=5),))
    with pytest.raises(ValueError, match="worker 5"):
        ServingSimulator(deterministic_sampler(MEANS), num_servers=2,
                         faults=bad).run([0.0], 5.0)
    with pytest.raises(ValueError, match="retry_budget"):
        ServingSimulator(deterministic_sampler(MEANS),
                         retry_budget=-1).run([0.0], 5.0)
    # DAG faults must carry an in-range stage index...
    dag = WorkflowDAG.single(flat_stage(num_servers=2))
    flat_fault = FaultSchedule(crashes=(WorkerCrash(time_s=1.0, worker_id=0),))
    with pytest.raises(ValueError, match="stage"):
        DagSimulator(dag, static_stage_indices=(0,),
                     faults=flat_fault).run([0.0], 5.0)
    # ...and a worker id inside that stage's pool
    deep = FaultSchedule(
        crashes=(WorkerCrash(time_s=1.0, worker_id=3, stage=0),))
    with pytest.raises(ValueError, match="worker"):
        DagSimulator(dag, static_stage_indices=(0,),
                     faults=deep).run([0.0], 5.0)
    # threaded pool validates eagerly at construction
    with pytest.raises(ValueError, match="worker"):
        WorkerPool(WorkflowExecutor(configs=[("c", 0)],
                                    workflow_fn=lambda c, p: 1.0),
                   c=1, faults=bad)
    with pytest.raises(ValueError, match="on_worker_error"):
        WorkerPool(WorkflowExecutor(configs=[("c", 0)],
                                    workflow_fn=lambda c, p: 1.0),
                   c=1, on_worker_error="ignore")


# --------------------------------------------------------------------------
# 2. the empty schedule is inert (bit-for-bit golden invariant)
# --------------------------------------------------------------------------


def _flat_surface(out):
    return (out.completed, out.config_timeline, out.queue_depth_samples,
            out.per_server_busy_s, out.offered, out.dropped, out.failed,
            out.retried, out.in_flight)


@pytest.mark.parametrize("kw", [
    dict(num_servers=1),
    dict(num_servers=3),
    dict(num_servers=2, max_batch_size=4, batch_timeout_s=0.02),
    dict(num_servers=3, queue_discipline="per_worker", steal=True),
    dict(num_servers=2, max_queue_depth=3),
])
def test_empty_schedule_is_bit_for_bit_inert_flat(kw):
    """faults=FaultSchedule() reproduces faults=None exactly, across every
    queue discipline and batch shape — no extra events, no extra RNG."""
    arr = generate_arrivals(constant_rate(8.0), 30.0, seed=5)
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    base = ServingSimulator(sampler, static_index=1, seed=9, **kw
                            ).run(arr, 30.0)
    inert = ServingSimulator(sampler, static_index=1, seed=9,
                             faults=FaultSchedule(), **kw).run(arr, 30.0)
    assert _flat_surface(inert) == _flat_surface(base)


def test_empty_schedule_is_bit_for_bit_inert_controller_and_dag():
    table = derive_policies(ladder_front(), slo_p95_s=SLO_S)
    arr = generate_arrivals(constant_rate(6.0), 40.0, seed=2)
    sampler = lognormal_sampler_from_profile(MEANS, P95S)

    base = ServingSimulator(sampler, controller=ElasticoController(table),
                            seed=4).run(arr, 40.0)
    inert = ServingSimulator(sampler, controller=ElasticoController(table),
                             seed=4, faults=FaultSchedule()).run(arr, 40.0)
    assert _flat_surface(inert) == _flat_surface(base)
    assert ([e.time_s for e in inert.switch_events]
            == [e.time_s for e in base.switch_events])

    dag = WorkflowDAG.tandem([flat_stage(name="a", num_servers=2),
                              flat_stage(name="b")])
    db = DagSimulator(dag, static_stage_indices=(0, 1), seed=3
                      ).run(arr, 40.0)
    di = DagSimulator(dag, static_stage_indices=(0, 1), seed=3,
                      faults=FaultSchedule()).run(arr, 40.0)
    assert di.completed == db.completed
    assert di.stage_stats == db.stage_stats


# --------------------------------------------------------------------------
# 3. crash / straggler / deadline semantics (deterministic, exact)
# --------------------------------------------------------------------------


def test_crash_requeues_at_head_and_retries_exactly():
    """One worker, one request: crashed mid-service at t=0.05, recovered
    at t=0.2 — the request must restart at exactly 0.2 and complete at
    0.3 (deterministic 0.1 s service), counted once, retried once."""
    faults = FaultSchedule(crashes=(
        WorkerCrash(time_s=0.05, worker_id=0, recover_s=0.2),))
    out = ServingSimulator(deterministic_sampler(MEANS), static_index=0,
                           faults=faults).run([0.0], 5.0)
    assert len(out.completed) == 1
    r = out.completed[0]
    assert r.start_s == pytest.approx(0.2)
    assert r.completion_s == pytest.approx(0.3)
    assert out.retried == 1 and out.failed == 0 and out.in_flight == 0
    # the cancelled attempt's busy time was refunded: only the 0.05 s
    # spent before the crash plus the 0.1 s successful run are booked
    assert sum(out.per_server_busy_s) == pytest.approx(0.15)


def test_crash_exhausts_retry_budget_into_failed():
    faults = FaultSchedule(crashes=(
        WorkerCrash(time_s=0.05, worker_id=0, recover_s=0.2),))
    out = ServingSimulator(deterministic_sampler(MEANS), static_index=0,
                           faults=faults, retry_budget=0).run([0.0], 5.0)
    assert len(out.completed) == 0
    assert out.failed == 1 and out.retried == 0
    assert out.offered == len(out.completed) + out.dropped + out.failed \
        + out.in_flight


def test_permanent_total_crash_strands_work_as_in_flight():
    """Every worker dead with no recovery: buffered work is reported as
    in_flight (conservation, not silent loss) and the run terminates."""
    faults = FaultSchedule(crashes=(WorkerCrash(time_s=0.05, worker_id=0),))
    arr = [0.0, 0.01, 0.02, 0.03, 0.04]
    out = ServingSimulator(deterministic_sampler(MEANS), static_index=0,
                           faults=faults).run(arr, 10.0)
    assert len(out.completed) == 0 and out.failed == 0
    assert out.in_flight == len(arr)
    assert out.retried == 1  # the cancelled in-service request requeued
    assert out.offered == len(arr)


def test_surviving_worker_absorbs_permanent_crash():
    faults = FaultSchedule(crashes=(WorkerCrash(time_s=1.0, worker_id=0),))
    arr = generate_arrivals(constant_rate(6.0), 20.0, seed=7)
    out = ServingSimulator(deterministic_sampler(MEANS), static_index=0,
                           num_servers=2, faults=faults).run(arr, 20.0)
    assert len(out.completed) == len(arr)
    assert out.failed == 0 and out.in_flight == 0
    # no dispatch ever starts on the dead worker after the crash
    assert all(r.start_s <= 1.0 for r in out.completed if r.server_id == 0)


def test_straggler_inflates_service_exactly_within_window():
    faults = FaultSchedule(stragglers=(
        Straggler(worker_id=0, start_s=0.0, end_s=1.0, factor=2.0),))
    out = ServingSimulator(deterministic_sampler(MEANS), static_index=0,
                           faults=faults).run([0.0, 2.0], 10.0)
    a, b = out.completed
    assert a.completion_s - a.start_s == pytest.approx(0.2)   # inside window
    assert b.completion_s - b.start_s == pytest.approx(0.1)   # outside
    assert out.retried == out.failed == 0


def test_request_deadline_expires_waiting_requests_with_backoff():
    """Queue-wait deadline: the blocked request is pulled at timeout,
    re-offered at the tail after an exponential backoff, and fails once
    the shared retry budget is spent.  The in-service request is never
    cancelled by its deadline."""
    out = ServingSimulator(
        deterministic_sampler(MEANS), static_index=2,  # 0.45 s service
        request_timeout_s=0.1, retry_budget=1, retry_backoff_s=0.05,
    ).run([0.0, 0.01], 10.0)
    assert [r.request_id for r in out.completed] == [0]
    assert out.failed == 1 and out.retried == 1
    assert out.offered == len(out.completed) + out.failed


def test_dag_crash_with_brownout_conserves_every_stage():
    dag = WorkflowDAG.tandem([flat_stage(name="a", num_servers=2),
                              flat_stage(name="b")])
    faults = FaultSchedule(
        brownouts=(Brownout(stage=0, start_s=0.0, end_s=100.0, factor=2.0),),
        crashes=(WorkerCrash(time_s=2.0, worker_id=0, recover_s=6.0,
                             stage=1),))
    arr = generate_arrivals(constant_rate(4.0), 20.0, seed=11)
    # stage b runs its slowest rung (0.45 s mean) against 4 qps: its one
    # worker is saturated, so the t=2 crash is guaranteed to interrupt an
    # in-service batch and force a head-of-queue retry
    out = DagSimulator(dag, static_stage_indices=(0, 2), seed=1,
                       faults=faults).run(arr, 20.0)
    for s in out.stage_stats:
        assert s.admitted == s.completed + s.in_flight + s.failed, s
    assert out.stage_stats[1].retried >= 1
    assert out.stage_stats[1].failed == 0  # default budget covers one crash


def test_dag_brownout_inflation_is_exact():
    """Single stage, single worker, single arrival: the browned-out
    sojourn is exactly factor x the fault-free one (same seed, same
    lognormal draw — only the multiplier differs)."""
    dag = WorkflowDAG.single(flat_stage())
    base = DagSimulator(dag, static_stage_indices=(0,), seed=13
                        ).run([0.0], 10.0)
    slow = DagSimulator(
        dag, static_stage_indices=(0,), seed=13,
        faults=FaultSchedule(brownouts=(
            Brownout(stage=0, start_s=0.0, end_s=10.0, factor=2.5),)),
    ).run([0.0], 10.0)
    (rb,), (rs,) = base.completed, slow.completed
    assert rs.start_s == rb.start_s == 0.0
    assert rs.completion_s == pytest.approx(2.5 * rb.completion_s)


# --------------------------------------------------------------------------
# 4. degradation-aware control (tables per surviving capacity)
# --------------------------------------------------------------------------


def test_derive_degraded_tables_family():
    hyst = HysteresisSpec()
    fam = derive_degraded_tables(ladder_front(), slo_p95_s=SLO_S,
                                 hysteresis=hyst, num_servers=4)
    assert sorted(fam) == [1, 2, 3, 4]
    full = derive_policies(ladder_front(), slo_p95_s=SLO_S, hysteresis=hyst,
                           num_servers=4)
    # the full-capacity member is the identical derivation the Planner runs
    assert fam[4].policies == full.policies
    for c, tab in fam.items():
        assert tab.num_servers == c
    # thresholds scale with the drain rate: fewer survivors -> tighter N_up
    for i in range(len(MEANS)):
        ups = [fam[c].policies[i].upscale_threshold for c in (1, 2, 3, 4)]
        assert ups == sorted(ups), ups
        assert ups[0] < ups[-1]


def test_on_capacity_change_swaps_and_restores_tables():
    fam = derive_degraded_tables(ladder_front(), slo_p95_s=SLO_S,
                                 num_servers=3)
    ctrl = ElasticoController(fam[3], degraded_tables=fam)
    assert ctrl.table is ctrl._full_table
    ev = ctrl.on_capacity_change(2, 0, 1.0)
    assert ev is None  # same ladder length: swap without a rung change
    assert ctrl.table.policies == fam[2].policies
    assert ctrl.capacity_timeline == [(1.0, 2)]
    # idempotent at unchanged capacity
    assert ctrl.on_capacity_change(2, 0, 1.5) is None
    assert ctrl.capacity_timeline == [(1.0, 2)]
    # recovery restores the full table; >= full capacity maps to full
    ctrl.on_capacity_change(3, 0, 2.0)
    assert ctrl.table is ctrl._full_table
    assert ctrl.capacity_timeline == [(1.0, 2), (2.0, 3)]
    # without degraded tables the hook is a no-op
    plain = ElasticoController(fam[3])
    assert plain.on_capacity_change(1, 0, 1.0) is None
    assert plain.capacity_timeline == []


def test_on_capacity_change_clamps_to_shorter_ladder():
    full = derive_policies(ladder_front(), slo_p95_s=SLO_S, num_servers=2)
    short = derive_policies(ladder_front()[:1], slo_p95_s=SLO_S,
                            num_servers=1)
    ctrl = ElasticoController(full, degraded_tables={1: short},
                              initial_index=2)
    ev = ctrl.on_capacity_change(1, 7, 3.0)
    assert ev is not None and ev.to_index == 0 and ev.from_index == 2
    assert "capacity change" in ev.reason
    assert ctrl.current_index == 0
    with pytest.raises(ValueError):
        ctrl.on_capacity_change(0, 0, 4.0)


def test_mix_controller_rejects_runtime_capacity_swap():
    mix = derive_mix_policies(ladder_front(), slo_p95_s=SLO_S, num_servers=2)
    ctrl = ElasticoMixController(mix)
    with pytest.raises(NotImplementedError, match="homogeneous-only"):
        ctrl.on_capacity_change(1, 0, 1.0)


def test_simulator_drives_capacity_swaps_through_scheduler():
    """End to end: a crash/recover pair reaches the controller via the
    scheduler's capacity-change hook, swapping tables both ways."""
    fam = derive_degraded_tables(ladder_front(), slo_p95_s=SLO_S,
                                 num_servers=2)
    ctrl = ElasticoController(fam[2], degraded_tables=fam)
    faults = FaultSchedule(crashes=(
        WorkerCrash(time_s=5.0, worker_id=0, recover_s=15.0),))
    arr = generate_arrivals(constant_rate(5.0), 30.0, seed=3)
    out = ServingSimulator(lognormal_sampler_from_profile(MEANS, P95S),
                           controller=ctrl, num_servers=2, faults=faults,
                           ).run(arr, 30.0)
    assert [(t, c) for t, c in ctrl.capacity_timeline] == [(5.0, 1),
                                                           (15.0, 2)]
    assert out.offered == len(out.completed) + out.dropped + out.failed \
        + out.in_flight


def test_planner_packages_degraded_tables():
    from repro.core.planner import Planner

    planner = Planner(profiler=lambda c, n: [0.05 * (1 + c[1])] * n,
                      num_servers=3)
    feasible = {("rung", i): a for i, a in enumerate(ACCS)}
    plan = planner.plan(feasible, slo_p95_s=SLO_S)
    assert plan.degraded_tables is not None
    assert sorted(plan.degraded_tables) == [1, 2, 3]
    ctrl = plan.controller()
    assert ctrl.degraded_tables is plan.degraded_tables
    assert "degraded" in plan.describe()
    # single-server plans have nothing to degrade to
    single = Planner(profiler=lambda c, n: [0.05] * n)
    assert single.plan(feasible, slo_p95_s=SLO_S).degraded_tables is None


# --------------------------------------------------------------------------
# 5. fastsim dispatcher gating
# --------------------------------------------------------------------------


def test_fastsim_routes_faults_to_oracle():
    assert fast_path_eligible(faults=None)
    assert fast_path_eligible(faults=FaultSchedule())
    crash = FaultSchedule(crashes=(WorkerCrash(time_s=1.0, worker_id=0),))
    assert not fast_path_eligible(faults=crash)
    assert not fast_path_eligible(request_timeout_s=1.0)

    arr = generate_arrivals(constant_rate(5.0), 10.0, seed=1)
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    fast = fastsim.simulate(sampler, arr, 10.0, faults=FaultSchedule())
    assert isinstance(fast, FastSimulationResult)
    oracle = fastsim.simulate(sampler, arr, 10.0, num_servers=2,
                              faults=crash, retry_budget=2)
    assert isinstance(oracle, SimulationResult)
    assert oracle.offered == len(arr)
    assert oracle.offered == oracle.num_completed + oracle.dropped \
        + oracle.failed + oracle.in_flight


# --------------------------------------------------------------------------
# 6. wall-clock hardening (threaded engine)
# --------------------------------------------------------------------------


def _flaky_workflow(fail_ids):
    def fn(config, payload):
        if payload in fail_ids:
            raise RuntimeError(f"boom on {payload}")
        time.sleep(0.001)
        return 1.0
    return fn


def _engine(fn, **kw):
    executor = WorkflowExecutor(configs=[("cfg", 0)], workflow_fn=fn)
    return ServingEngine(executor, control_tick_s=0.01, **kw)


def test_raising_workflow_does_not_deadlock_or_lose_accounting():
    """Satellite regression: a workflow_fn exception surfaces in
    EngineReport.worker_errors, the request fails after its retry budget,
    and every other request still completes — no hang, no lost slot."""
    engine = _engine(_flaky_workflow({7}), retry_budget=1)
    engine.start()
    for i in range(20):
        engine.submit(Request(request_id=i, arrival_s=0.0, payload=i))
    report = engine.drain_and_stop(timeout_s=10.0)
    assert not report.drain_timed_out and report.backlog == 0
    assert sorted(r.request_id for r in report.records) == [
        i for i in range(20) if i != 7]
    assert report.failed == 1
    # budget 1 -> the raising request was attempted twice
    assert len(report.worker_errors) == 2
    for err in report.worker_errors:
        assert "boom on 7" in err.error and not err.halted
        assert err.request_ids == (7,)
    assert report.total_requests == len(report.records) + report.dropped \
        + report.failed + report.backlog


def test_halt_policy_kills_worker_and_drain_reports_backlog():
    """on_worker_error='halt' with a single worker: the pool goes dead,
    drain_and_stop early-stops instead of spinning out its timeout, and
    the unserved requests are reported as backlog."""
    engine = _engine(_flaky_workflow({0}), on_worker_error="halt",
                     retry_budget=0)
    engine.start()
    for i in range(4):
        engine.submit(Request(request_id=i, arrival_s=0.0, payload=i))
    t0 = time.monotonic()
    report = engine.drain_and_stop(timeout_s=30.0)
    assert time.monotonic() - t0 < 5.0  # early stop, not the 30 s timeout
    assert report.drain_timed_out
    assert engine.pool.all_workers_dead()
    assert engine.pool.dead_workers() == [0]
    assert report.failed == 1
    assert len(report.records) == 0
    assert report.backlog == 3
    (err,) = report.worker_errors
    assert err.halted
    assert report.total_requests == len(report.records) + report.dropped \
        + report.failed + report.backlog


def test_engine_fault_schedule_crashes_worker_at_tick_granularity():
    """A scheduled wall-clock crash removes the worker from dispatch at
    the next control tick; the survivor serves everything."""
    faults = FaultSchedule(crashes=(WorkerCrash(time_s=0.05, worker_id=0),))
    engine = _engine(_flaky_workflow(set()), num_workers=2, faults=faults)
    engine.start()
    time.sleep(0.2)  # let the crash tick land
    for i in range(30):
        engine.submit(Request(request_id=i, arrival_s=0.0, payload=i))
    report = engine.drain_and_stop(timeout_s=10.0)
    assert len(report.records) == 30
    assert report.failed == 0 and not report.worker_errors
    assert engine.scheduler.is_down(0)
    served = {r.worker_id for r in report.records}
    assert served == {1}
