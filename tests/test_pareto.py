"""Pareto front construction + ladder invariants (paper §V-A, Eq. 4)."""

import pytest
from proptest import given, settings, st

from repro.core.pareto import (
    LatencyProfile,
    ParetoPoint,
    pareto_front,
    thin_front,
    validate_front,
)

from conftest import synthetic_point


def test_front_drops_dominated():
    pts = [
        synthetic_point(0.1, 0.15, 0.70, "fast"),
        synthetic_point(0.2, 0.30, 0.80, "mid"),
        synthetic_point(0.3, 0.45, 0.75, "dominated"),  # slower AND worse than mid
        synthetic_point(0.4, 0.60, 0.90, "best"),
    ]
    front = pareto_front(pts)
    names = [p.config[0] for p in front]
    assert names == ["fast", "mid", "best"]
    validate_front(front)


def test_front_ordering_implies_accuracy_ordering():
    pts = [synthetic_point(m, m * 1.4, a, f"c{i}") for i, (m, a) in enumerate(
        [(0.1, 0.7), (0.15, 0.75), (0.2, 0.74), (0.25, 0.8)]
    )]
    front = pareto_front(pts)
    accs = [p.accuracy for p in front]
    means = [p.profile.mean for p in front]
    assert accs == sorted(accs) and means == sorted(means)


@st.composite
def point_lists(draw):
    n = draw(st.integers(2, 25))
    pts = []
    for i in range(n):
        mean = draw(st.floats(0.01, 2.0, allow_nan=False))
        acc = draw(st.floats(0.0, 1.0, allow_nan=False))
        pts.append(synthetic_point(mean, mean * 1.5, acc, f"c{i}"))
    return pts


@given(point_lists())
@settings(max_examples=100, deadline=None)
def test_front_points_not_dominated(pts):
    front = pareto_front(pts)
    assert front, "front never empty for non-empty input"
    for f in front:
        for p in pts:
            strictly_better = (
                p.accuracy >= f.accuracy
                and p.profile.mean <= f.profile.mean
                and (p.accuracy > f.accuracy or p.profile.mean < f.profile.mean)
            )
            assert not strictly_better, (f, p)
    # ladder invariant
    validate_front(front)


@given(point_lists())
@settings(max_examples=50, deadline=None)
def test_front_contains_best_accuracy_and_best_latency(pts):
    front = pareto_front(pts)
    best_acc = max(p.accuracy for p in pts)
    best_lat = min(p.profile.mean for p in pts)
    assert any(p.accuracy == best_acc for p in front)
    assert any(p.profile.mean == best_lat for p in front)


def test_thin_front_keeps_ends_and_gaps():
    pts = [
        synthetic_point(0.10, 0.15, 0.700, "c0"),
        synthetic_point(0.11, 0.16, 0.702, "c1"),  # within gap -> thinned
        synthetic_point(0.20, 0.30, 0.800, "c2"),
        synthetic_point(0.30, 0.45, 0.900, "c3"),
    ]
    front = pareto_front(pts)
    thinned = thin_front(front, min_accuracy_gap=0.01)
    names = [p.config[0] for p in thinned]
    assert names == ["c0", "c2", "c3"]
    assert thinned[0] is front[0] and thinned[-1].accuracy == 0.900


def test_thin_front_empty_and_singleton():
    assert thin_front([]) == []
    p = synthetic_point(0.1, 0.15, 0.7)
    assert thin_front([p]) == [p]


def test_latency_profile_validation():
    with pytest.raises(ValueError):
        LatencyProfile(mean=0.0, p95=0.1)
    with pytest.raises(ValueError):
        LatencyProfile(mean=1.0, p95=0.1)  # p95 far below mean
