"""Property-test compat layer: re-export hypothesis when available, else a
small deterministic fallback.

The test suite's property tests are written against the hypothesis API
(``given`` / ``settings`` / ``strategies as st``).  Minimal environments
(e.g. the CI verify gate) don't ship hypothesis, and a module-level
``from hypothesis import ...`` used to abort collection of seven test
modules.  Importing from this module instead keeps the property tests
*running* everywhere: with hypothesis installed you get real shrinking
and example databases; without it you get seeded random sampling over the
same strategy space (no shrinking, deterministic per test name).

Only the strategy subset this suite uses is implemented in the fallback:
``integers``, ``floats``, ``sampled_from``, ``lists`` (min/max size,
``unique``), ``composite``, and ``Strategy.map``.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import random
    import zlib

    HAVE_HYPOTHESIS = False

    _DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        """A draw function wrapped with .map(), mirroring hypothesis."""

        def __init__(self, draw_fn):
            self._draw = draw_fn

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            # allow_nan etc. are no-ops: bounded uniform never yields NaN/inf
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def lists(elements, *, min_size=0, max_size=10, unique=False):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                out = []
                attempts = 0
                while len(out) < n:
                    x = elements._draw(rng)
                    if unique and x in out:
                        attempts += 1
                        if attempts > 1000:
                            raise RuntimeError("could not draw a unique list")
                        continue
                    out.append(x)
                return out

            return _Strategy(draw)

        @staticmethod
        def composite(fn):
            def make(*args, **kwargs):
                def draw_value(rng):
                    def draw(strategy):
                        return strategy._draw(rng)

                    return fn(draw, *args, **kwargs)

                return _Strategy(draw_value)

            return make

    st = _Strategies()

    def settings(*, max_examples=_DEFAULT_MAX_EXAMPLES, **_kw):
        def deco(fn):
            fn._proptest_max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            # NOTE: no functools.wraps — copying __wrapped__ would make
            # pytest see the original signature and demand fixtures for
            # the drawn arguments.  The wrapper takes no arguments.
            def wrapper():
                n = getattr(
                    wrapper,
                    "_proptest_max_examples",
                    getattr(fn, "_proptest_max_examples", _DEFAULT_MAX_EXAMPLES),
                )
                # deterministic per test: same examples on every run
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = [s._draw(rng) for s in strategies]
                    fn(*drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
