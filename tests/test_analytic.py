"""Analytic FLOP/byte model invariants (the roofline's compute/memory source)."""

import dataclasses

import pytest
from proptest import given, settings, st

import repro.configs  # noqa: F401
from repro.launch.analytic import param_bytes_cached, serving_config_costs, step_costs
from repro.launch.roofline import model_flops_for
from repro.models.registry import arch_ids, get_config

ARCHS = arch_ids()


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("kind,seq,batch", [
    ("train", 4096, 256), ("prefill", 32768, 32), ("decode", 32768, 128),
])
def test_costs_positive_and_consistent(arch, kind, seq, batch):
    cfg = get_config(arch)
    c = step_costs(cfg, kind, seq, batch)
    assert c.flops > 0 and c.param_bytes > 0 and c.hbm_bytes > 0
    assert c.hbm_bytes >= c.param_bytes * (0.99 if kind != "train" else 0)
    # enc-dec: the 6ND token count is the decoder length (as run_case does)
    dec_len = (seq // cfg.decoder_len_ratio) if cfg.family == "audio" else None
    mf = model_flops_for(cfg, kind, seq, batch, decoder_len=dec_len)
    assert mf > 0
    # the 6ND floor never exceeds the analytic count by more than the model's
    # known slack (elementwise/recurrence terms are not in 6ND)
    assert mf / c.flops < 1.25, (arch, kind, mf / c.flops)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_flops_exceed_prefill(arch):
    """Backward pass: train >= ~3x the prefill forward at the same shape."""
    cfg = get_config(arch)
    tr = step_costs(cfg, "train", 4096, 8).flops
    pf = step_costs(cfg, "prefill", 4096, 8).flops
    assert tr >= 2.5 * pf


def test_flops_scale_linearly_in_batch_and_layers():
    cfg = get_config("internlm2-1.8b")
    f1 = step_costs(cfg, "train", 2048, 8).flops
    f2 = step_costs(cfg, "train", 2048, 16).flops
    assert f2 == pytest.approx(2 * f1, rel=1e-6)
    cfg2 = dataclasses.replace(cfg, num_layers=cfg.num_layers * 2)
    f3 = step_costs(cfg2, "train", 2048, 8).flops
    assert f3 / f1 == pytest.approx(2.0, rel=0.15)  # unembed not doubled


def test_sliding_window_cuts_decode_state_bytes():
    cfg = get_config("stablelm-3b")
    full = step_costs(cfg, "decode", 32768, 128)
    win = step_costs(dataclasses.replace(cfg, sliding_window=8192),
                     "decode", 32768, 128)
    assert win.state_bytes < 0.3 * full.state_bytes


def test_int8_kv_halves_state_bytes():
    cfg = get_config("llama3-405b")
    bf16 = step_costs(cfg, "decode", 32768, 128)
    int8 = step_costs(dataclasses.replace(cfg, kv_cache_dtype="int8"),
                      "decode", 32768, 128)
    assert 0.4 < int8.state_bytes / bf16.state_bytes < 0.6


def test_gshard_cheaper_than_dense_dispatch():
    cfg = get_config("deepseek-moe-16b")
    dense = step_costs(cfg, "train", 4096, 256).flops
    gsh = step_costs(dataclasses.replace(cfg, moe_impl="gshard"),
                     "train", 4096, 256).flops
    assert gsh < 0.35 * dense


@given(st.sampled_from(ARCHS), st.sampled_from([512, 2048, 8192]),
       st.sampled_from([1, 8, 64]))
@settings(max_examples=40, deadline=None)
def test_decode_flops_independent_of_nothing_weird(arch, seq, batch):
    """Decode FLOPs grow with batch, and with context only via attention."""
    cfg = get_config(arch)
    f_small = step_costs(cfg, "decode", seq, batch).flops
    f_big_batch = step_costs(cfg, "decode", seq, batch * 2).flops
    assert f_big_batch == pytest.approx(2 * f_small, rel=1e-6)


def test_serving_config_costs_tradeoffs():
    cfg = get_config("granite-moe-3b-a800m")
    base_acc, base_s = serving_config_costs(
        cfg, {"quant": "bf16", "batch_cap": 16, "window": 0, "moe_top_k": 8})
    fast_acc, fast_s = serving_config_costs(
        cfg, {"quant": "int8", "batch_cap": 16, "window": 1024, "moe_top_k": 2})
    assert base_acc == 1.0
    assert fast_acc < base_acc
    assert fast_s < base_s  # the ladder premise: cheaper configs are faster


def test_param_bytes_cached_stable():
    cfg = get_config("minitron-4b")
    assert param_bytes_cached(cfg) == param_bytes_cached(cfg) > 1e9
