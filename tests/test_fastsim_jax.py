"""jax backend of the batched fast-path sweep (repro/serving/fastsim.py).

The contract, in order of strictness:

1. **numpy is authoritative**: every jax result is judged against the
   committed numpy engine, never the other way around.
2. **c = 1 sequential scan is bit-exact**: same op order as the numpy
   reference loop, so the per-request latency grids — and therefore the
   p95 and compliance grids, which are order statistics — are *exactly*
   equal; only the mean reductions may differ at float-summation-order
   level (~1e-13).
3. **Associative / Pallas scans are reorderings**: the max-plus operator
   algebra reassociates the same float ops, so parity is tight allclose,
   not bit-exactness.
4. **c > 1 Kiefer-Wolfowitz**: the sorted-workload comparator network
   maintains the same multiset as numpy's set-column-and-sort, so parity
   is again tight allclose.
5. **Grid purity**: the jax sweep is a pure function of its cell inputs —
   permuting the config axis permutes the grids, slicing the load axis
   reproduces the same cells — and backend selection
   (:func:`~repro.serving.fastsim.resolve_backend`) is explicit,
   validated, and falls back to numpy without error when jax is missing.

Max-plus associativity (the property the associative scan and the Pallas
kernel both rely on) is tested directly on the operator.
"""

import numpy as np
import pytest

from repro.serving import fastsim
from repro.serving.fastsim import (
    jax_available,
    jax_unavailable_reason,
    resolve_backend,
    simulate_batch,
)

pytestmark = pytest.mark.jax

needs_jax = pytest.mark.skipif(
    not jax_available(),
    reason=f"jax not importable: {jax_unavailable_reason()}")

MEANS = [0.10, 0.25, 0.45]
P95S = [0.14, 0.35, 0.63]
GRIDS = ["mean_wait_s", "mean_latency_s", "p95_latency_s",
         "slo_compliance", "throughput_qps", "num_requests"]


def _sweep(*, backend, scan_impl="auto", seed=0, num_servers=1,
           rates=(2.0, 6.0), duration_s=60.0, replications=2,
           lognormal=True):
    return simulate_batch(
        MEANS, P95S if lognormal else None,
        arrival_rates_qps=list(rates), duration_s=duration_s,
        num_servers=num_servers, replications=replications,
        slo_s=1.0, seed=seed, backend=backend, scan_impl=scan_impl)


def _assert_parity(ref, got, *, exact_order_stats=False, rtol=1e-9):
    for name in GRIDS:
        a, b = getattr(ref, name), getattr(got, name)
        if exact_order_stats and name in ("p95_latency_s", "slo_compliance",
                                          "num_requests"):
            np.testing.assert_array_equal(a, b, err_msg=name)
        else:
            np.testing.assert_allclose(a, b, rtol=rtol, atol=1e-12,
                                       err_msg=name)


# --------------------------------------------------------------------------
# max-plus operator algebra
# --------------------------------------------------------------------------


@needs_jax
def test_maxplus_combine_is_associative():
    """(f3 . f2) . f1 == f3 . (f2 . f1) for random affine max-plus maps
    x -> max(x + a, b): the property that makes the Lindley recursion an
    associative scan at all.  Mathematically exact; in floats the shift
    components compose as a1 + a2 + a3 in either grouping, so parity is
    last-ulp allclose, not bit equality."""
    import jax.experimental

    from repro.kernels.lindley_scan import maxplus_combine

    rng = np.random.default_rng(0)
    with jax.experimental.enable_x64():
        for _ in range(50):
            a1, a2, a3 = rng.normal(scale=3.0, size=(3, 8))
            b1, b2, b3 = rng.normal(scale=3.0, size=(3, 8))
            left = maxplus_combine(
                maxplus_combine((a1, b1), (a2, b2)), (a3, b3))
            right = maxplus_combine(
                (a1, b1), maxplus_combine((a2, b2), (a3, b3)))
            np.testing.assert_allclose(np.asarray(left[0]),
                                       np.asarray(right[0]), rtol=1e-14)
            np.testing.assert_allclose(np.asarray(left[1]),
                                       np.asarray(right[1]), rtol=1e-14)


@needs_jax
def test_maxplus_identity_element():
    """(0, -inf) is the identity: padding slots carry it, which is why the
    sweep can right-pad ragged traces without changing any cell.  Adding
    zero and maxing with -inf are exact, so this one IS bit equality."""
    import jax.experimental

    from repro.kernels.lindley_scan import maxplus_combine

    rng = np.random.default_rng(1)
    a, b = rng.normal(size=4), rng.normal(size=4)
    ident = (np.zeros(4), np.full(4, -np.inf))
    with jax.experimental.enable_x64():
        for out in (maxplus_combine(ident, (a, b)),
                    maxplus_combine((a, b), ident)):
            np.testing.assert_array_equal(np.asarray(out[0]), a)
            np.testing.assert_array_equal(np.asarray(out[1]), b)


# --------------------------------------------------------------------------
# parity with the numpy engine
# --------------------------------------------------------------------------


@needs_jax
@pytest.mark.parametrize("seed", [0, 3, 11])
@pytest.mark.parametrize("lognormal", [True, False])
def test_jax_c1_sequential_bit_exact_order_stats(seed, lognormal):
    """The sequential lax.scan replays the numpy loop's op order, so the
    per-request latency grid is bit-for-bit identical: p95, compliance and
    the request counts must be EXACTLY equal; the means may differ only by
    float summation order."""
    ref = _sweep(backend="numpy", seed=seed, lognormal=lognormal)
    got = _sweep(backend="jax", scan_impl="sequential", seed=seed,
                 lognormal=lognormal)
    _assert_parity(ref, got, exact_order_stats=True, rtol=1e-12)


@needs_jax
@pytest.mark.parametrize("scan_impl", ["associative", "pallas"])
def test_jax_c1_reassociated_scans_tight_parity(scan_impl):
    """Max-plus reassociation (associative_scan / blocked Pallas kernel)
    computes the same recursion in a different grouping: tight allclose,
    including on the order statistics."""
    ref = _sweep(backend="numpy", seed=5)
    got = _sweep(backend="jax", scan_impl=scan_impl, seed=5)
    _assert_parity(ref, got, rtol=1e-9)


@needs_jax
@pytest.mark.parametrize("c", [2, 3])
@pytest.mark.parametrize("seed", [1, 7])
def test_jax_kw_multi_server_parity(c, seed):
    """c > 1: the comparator-network re-insertion maintains the same sorted
    workload vector as numpy's set-column-0-and-sort."""
    ref = _sweep(backend="numpy", seed=seed, num_servers=c,
                 rates=(6.0, 14.0))
    got = _sweep(backend="jax", seed=seed, num_servers=c,
                 rates=(6.0, 14.0))
    _assert_parity(ref, got, rtol=1e-9)


@needs_jax
def test_jax_explicit_traces_parity():
    """Common-random-number arrival traces (the Planner.validate shape)
    through both backends."""
    rng = np.random.default_rng(2)
    traces = [np.sort(rng.uniform(0.0, 60.0, size=n)) for n in (150, 90)]
    kw = dict(arrival_traces=[t.tolist() for t in traces],
              duration_s=60.0, replications=2, slo_s=1.0, seed=4)
    ref = simulate_batch(MEANS, P95S, backend="numpy", **kw)
    got = simulate_batch(MEANS, P95S, backend="jax",
                         scan_impl="sequential", **kw)
    _assert_parity(ref, got, exact_order_stats=True, rtol=1e-12)


# --------------------------------------------------------------------------
# sweep-grid purity
# --------------------------------------------------------------------------


@needs_jax
def test_jax_sweep_config_permutation_invariance():
    """Permuting the config axis permutes every grid identically: no
    cross-talk between cells inside the jitted sweep."""
    perm = [2, 0, 1]
    base = simulate_batch(MEANS, P95S, arrival_rates_qps=[3.0, 8.0],
                          duration_s=60.0, replications=2, slo_s=1.0,
                          seed=9, backend="jax")
    permuted = simulate_batch([MEANS[i] for i in perm],
                              [P95S[i] for i in perm],
                              arrival_rates_qps=[3.0, 8.0],
                              duration_s=60.0, replications=2, slo_s=1.0,
                              seed=9, backend="jax")
    for name in GRIDS:
        np.testing.assert_array_equal(getattr(base, name)[:, perm, :],
                                      getattr(permuted, name),
                                      err_msg=name)


@needs_jax
def test_jax_sweep_load_slicing_invariance():
    """A sub-batch over a subset of loads reproduces exactly the same
    cells as the full sweep: each (r, k, l) cell is a pure function of its
    own trace and service stream."""
    rates = [2.0, 5.0, 9.0]
    full = simulate_batch(MEANS, P95S, arrival_rates_qps=rates,
                          duration_s=60.0, replications=2, slo_s=1.0,
                          seed=6, backend="jax")
    sub = simulate_batch(MEANS, P95S, arrival_rates_qps=rates[1:],
                         duration_s=60.0, replications=2, slo_s=1.0,
                         seed=6, backend="jax")
    for name in GRIDS:
        np.testing.assert_array_equal(getattr(full, name)[:, :, 1:],
                                      getattr(sub, name), err_msg=name)


# --------------------------------------------------------------------------
# backend selection
# --------------------------------------------------------------------------


def test_resolve_backend_literals_and_validation():
    assert resolve_backend("numpy") == "numpy"
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("cuda")
    if jax_available():
        assert resolve_backend("jax", num_servers=1) == "jax"
        with pytest.raises(ValueError, match="num_servers"):
            resolve_backend("jax",
                            num_servers=fastsim._JAX_MAX_SERVERS + 1)


def test_resolve_backend_auto_thresholds():
    """auto: numpy for small grids (device dispatch would dominate) and
    for pools past the comparator-network bound; jax only for large,
    eligible sweeps — and only when jax imports at all."""
    small = fastsim._JAX_AUTO_MIN_SLOTS - 1
    big = fastsim._JAX_AUTO_MIN_SLOTS
    assert resolve_backend("auto", total_slots=small) == "numpy"
    assert (resolve_backend("auto", total_slots=big)
            == ("jax" if jax_available() else "numpy"))
    assert resolve_backend(
        "auto", num_servers=fastsim._JAX_MAX_SERVERS + 1,
        total_slots=big) == "numpy"
    # no size hint: resolution must still be deterministic, not an error
    assert resolve_backend("auto") in ("numpy", "jax")


def test_missing_jax_fallback_and_error(monkeypatch):
    """With jax absent, auto silently resolves numpy while explicit
    backend='jax' raises with the recorded import reason."""
    monkeypatch.setattr(fastsim, "_jax", None)
    monkeypatch.setattr(fastsim, "_JAX_IMPORT_ERROR", "No module named 'jax'")
    assert not fastsim.jax_available()
    assert "jax" in fastsim.jax_unavailable_reason()
    assert resolve_backend("auto", total_slots=10**9) == "numpy"
    with pytest.raises(RuntimeError, match="not importable"):
        resolve_backend("jax")
    # and the sweep entry point inherits the silent fallback
    res = _sweep(backend="auto", duration_s=20.0, replications=1,
                 rates=(2.0,))
    assert res.total_requests > 0


def test_bad_scan_impl_rejected():
    with pytest.raises(ValueError, match="scan_impl"):
        _sweep(backend="numpy", scan_impl="warp")


def test_resolve_backend_auto_on_dag_sized_grids():
    """The grid sizes the pipeline benchmarks actually produce: a
    smoke-scale DAG validation (few rungs x short grid) must stay on
    numpy under ``auto``, while the full trace-replay-scale validation
    crosses the amortization threshold and picks jax when importable.
    Pins the threshold semantics to the real workloads, not just to
    ``_JAX_AUTO_MIN_SLOTS +- 1``."""

    def grid_slots(*, rungs, rates, replications, duration_s):
        # padded slots = R x K x L x N_max, N_max ~ peak-rate trace + 10%
        return (replications * rungs * len(rates)
                * int(max(rates) * duration_s * 1.1))

    smoke = grid_slots(rungs=3, rates=(2.0, 3.0, 3.75), replications=2,
                       duration_s=90.0)
    assert smoke < fastsim._JAX_AUTO_MIN_SLOTS
    assert resolve_backend("auto", num_servers=1,
                           total_slots=smoke) == "numpy"

    full = grid_slots(rungs=5, rates=(5.5, 7.3, 9.1), replications=8,
                      duration_s=900.0)
    assert full >= fastsim._JAX_AUTO_MIN_SLOTS
    assert resolve_backend("auto", num_servers=1, total_slots=full) == (
        "jax" if jax_available() else "numpy")
    # a fork-join-wide pool disqualifies the grid regardless of size
    assert resolve_backend(
        "auto", num_servers=fastsim._JAX_MAX_SERVERS + 1,
        total_slots=full) == "numpy"


def test_resolve_backend_auto_counts_stage_recursions():
    """Pipeline-aware auto sizing: the amortization bar counts recursion
    steps (``total_slots x num_stages``), so a per-stage grid too small
    for the flat path qualifies once enough chained stages multiply the
    device work — and exactly at the boundary on both sides.
    """
    bar = fastsim._JAX_AUTO_MIN_SLOTS
    want = "jax" if jax_available() else "numpy"

    # flat default (num_stages=1): the bar applies to total_slots alone
    assert resolve_backend("auto", total_slots=bar - 1) == "numpy"
    assert resolve_backend("auto", total_slots=bar) == want
    assert resolve_backend("auto", total_slots=bar - 1,
                           num_stages=1) == "numpy"

    # a 3-stage pipeline clears the bar at a third of the flat slot count
    per_stage = -(-bar // 3)                     # ceil(bar / 3)
    assert per_stage * 3 >= bar
    assert per_stage < bar
    assert resolve_backend("auto", total_slots=per_stage,
                           num_stages=3) == want
    # ... but one slot under the boundary still resolves numpy
    under = (bar - 1) // 3
    assert under * 3 < bar
    assert resolve_backend("auto", total_slots=under,
                           num_stages=3) == "numpy"

    # degenerate stage counts clamp to the flat semantics, never divide
    assert resolve_backend("auto", total_slots=bar, num_stages=0) == want
    assert resolve_backend("auto", total_slots=bar - 1,
                           num_stages=0) == "numpy"


# --------------------------------------------------------------------------
# Planner.validate backend forwarding
# --------------------------------------------------------------------------


def _tiny_plan():
    from repro.core.planner import Planner

    def profiler(config, n):
        _, mean = config
        return [mean * (0.8 + 0.4 * i / (n - 1)) for i in range(n)]

    planner = Planner(profiler=profiler)
    plan = planner.plan({("fast", 0.10): 0.80, ("slow", 0.30): 0.90},
                        slo_p95_s=1.0)
    return planner, plan


@pytest.mark.parametrize("backend", ["numpy", "jax", "auto"])
def test_planner_validate_forwards_backend_verbatim(monkeypatch, backend):
    """``Planner.validate`` must hand its ``backend`` argument to
    :func:`simulate_batch` untouched — resolution (including the jax ->
    numpy fallback) belongs to the sweep engine, so the Planner forwards
    even ``"jax"`` on a host without jax rather than resolving early."""
    seen = {}

    class _StubSweep:
        total_requests = 1234

        def over_replications(self):
            k, l = len(seen["means"]), len(seen["rates"])
            grid = [[0.0] * l for _ in range(k)]
            return {"mean_wait_s": grid, "p95_latency_s": grid,
                    "slo_compliance": [[1.0] * l for _ in range(k)]}

    def stub(means, p95s, *, arrival_rates_qps, backend, **kw):
        seen.update(means=list(means), rates=list(arrival_rates_qps),
                    backend=backend, kw=kw)
        return _StubSweep()

    monkeypatch.setattr(fastsim, "simulate_batch", stub)
    planner, plan = _tiny_plan()
    val = planner.validate(plan, duration_s=30.0, replications=2,
                           backend=backend)
    assert seen["backend"] == backend
    assert len(seen["means"]) == plan.table.ladder_size
    # the stub's grids landed in the validation result unresolved
    assert val.num_requests == 1234
    assert val.slo_compliance == tuple(
        (1.0,) * len(seen["rates"]) for _ in seen["means"])


def test_planner_validate_default_backend_is_auto(monkeypatch):
    seen = {}

    class _StubSweep:
        total_requests = 1

        def over_replications(self):
            return {"mean_wait_s": [[0.0]], "p95_latency_s": [[0.0]],
                    "slo_compliance": [[1.0]]}

    def stub(means, p95s, *, backend, **kw):
        seen["backend"] = backend
        return _StubSweep()

    monkeypatch.setattr(fastsim, "simulate_batch", stub)
    planner, plan = _tiny_plan()
    planner.validate(plan, arrival_rates_qps=[2.0], duration_s=30.0,
                     replications=1)
    assert seen["backend"] == "auto"
