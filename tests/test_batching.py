"""In-worker batching: the alpha + beta * b service law, batch-aware
thresholds, linger semantics, and the max_batch_size=1 golden equivalences
against the PR-2 (unbatched) engine and simulator."""

import time

import pytest

from proptest import given, settings, st

from repro.core.aqm import (
    HysteresisSpec,
    allen_cunneen_mean_wait,
    batch_expected_wait,
    batch_mean_wait,
    derive_mix_policies,
    derive_policies,
    expected_wait,
    max_sustainable_rate,
)
from repro.core.elastico import ElasticoController, ElasticoMixController
from repro.core.pareto import (
    BatchProfile,
    LatencyProfile,
    fit_batch_profile,
)
from repro.core.planner import Planner
from repro.serving.engine import ServingEngine
from repro.serving.executor import WorkerPool, WorkflowExecutor
from repro.serving.scheduler import Scheduler
from repro.serving.simulator import (
    ServingSimulator,
    lognormal_sampler_from_profile,
)
from repro.serving.workload import (
    Request,
    constant_rate,
    generate_arrivals,
    sustained_overload_pattern,
)

from conftest import synthetic_point

MEANS = [0.10, 0.25, 0.45]
P95S = [0.14, 0.35, 0.63]
ACCS = [0.76, 0.82, 0.85]
# alpha-dominated amortization: S(1) = s-bar, S(8) = 3.8 s-bar for 8 requests
BATCH_PROFILES = [BatchProfile(alpha=0.6 * m, beta=0.4 * m) for m in MEANS]


def ladder_front():
    return [
        synthetic_point(m, p, a, f"c{i}")
        for i, (m, p, a) in enumerate(zip(MEANS, P95S, ACCS))
    ]


# -- BatchProfile / fit --------------------------------------------------------


def test_batch_profile_service_law():
    bp = BatchProfile(alpha=0.06, beta=0.04)
    assert bp.service_time(1) == pytest.approx(0.10)
    assert bp.service_time(8) == pytest.approx(0.06 + 0.32)
    assert bp.per_request_time(8) < bp.per_request_time(1)
    assert bp.speedup(8) == pytest.approx(8 * 0.10 / 0.38)
    with pytest.raises(ValueError):
        bp.service_time(0)
    with pytest.raises(ValueError):
        BatchProfile(alpha=-0.1, beta=0.2)
    with pytest.raises(ValueError):
        BatchProfile(alpha=0.0, beta=0.0)


def test_fit_batch_profile_recovers_law():
    bp = BatchProfile(alpha=0.06, beta=0.04)
    sizes = [1, 2, 4, 8]
    times = [bp.service_time(b) for b in sizes]
    fit = fit_batch_profile(sizes, times)
    assert fit.alpha == pytest.approx(0.06, abs=1e-9)
    assert fit.beta == pytest.approx(0.04, abs=1e-9)


def test_fit_batch_profile_degenerate_and_validation():
    # one batch size observed: everything goes to the marginal term
    fit = fit_batch_profile([4, 4], [0.4, 0.4])
    assert fit.alpha == 0.0
    assert fit.beta == pytest.approx(0.1)
    with pytest.raises(ValueError):
        fit_batch_profile([], [])
    with pytest.raises(ValueError):
        fit_batch_profile([1, 2], [0.1])
    with pytest.raises(ValueError):
        fit_batch_profile([0, 1], [0.1, 0.1])
    with pytest.raises(ValueError):
        fit_batch_profile([1, 2], [0.1, -0.1])


def test_effective_batch_profile_fallback():
    prof = LatencyProfile(mean=0.2, p95=0.3)
    fb = prof.effective_batch_profile()
    assert fb.alpha == 0.0 and fb.beta == 0.2
    assert fb.service_time(1) == 0.2          # exact, not approx
    measured = BatchProfile(alpha=0.1, beta=0.1)
    prof2 = LatencyProfile(mean=0.2, p95=0.3, batch_profile=measured)
    assert prof2.effective_batch_profile() is measured


# -- batch_expected_wait -------------------------------------------------------


@given(st.integers(0, 200), st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_batch_expected_wait_collapses_at_b1(n, c):
    """max_batch_size=1 must equal Eq. 8's expected_wait exactly for a
    profile-derived law (S(1) = s-bar, no float drift)."""
    bp = LatencyProfile(mean=0.2, p95=0.3).effective_batch_profile()
    assert batch_expected_wait(n, bp, c, 1) == expected_wait(n, 0.2, c)


def test_batch_expected_wait_depth_speeds_drain():
    """With an amortizing law the *per-request* drain time falls as depth
    unlocks larger batches: wait grows sublinearly until the cap."""
    bp = BatchProfile(alpha=0.06, beta=0.04)
    c, B = 4, 8
    w1 = batch_expected_wait(c * 1, bp, c, B)      # singleton batches
    w8 = batch_expected_wait(c * 8, bp, c, B)      # full batches
    # 8x the depth but much less than 8x the wait
    assert w8 < 8 * w1 * 0.6
    # per-request wait is lower at full batch
    assert w8 / (c * 8) < w1 / (c * 1)
    assert batch_expected_wait(0, bp, c, B) == 0.0
    with pytest.raises(ValueError):
        batch_expected_wait(-1, bp, c, B)
    with pytest.raises(ValueError):
        batch_expected_wait(1, bp, 0, B)
    with pytest.raises(ValueError):
        batch_expected_wait(1, bp, c, 0)


# -- batch_mean_wait -----------------------------------------------------------


@given(st.integers(1, 8), st.floats(0.05, 0.95), st.floats(0.0, 4.0))
@settings(max_examples=40, deadline=None)
def test_batch_mean_wait_collapses_to_allen_cunneen(c, rho, scv):
    """The satellite criterion: B = 1 must reproduce allen_cunneen_mean_wait
    bit-for-bit, for any SCV (and hence Erlang-C at SCV = 1)."""
    bp = BatchProfile(alpha=0.0, beta=0.2)
    lam = rho * c / 0.2
    assert batch_mean_wait(c, lam, bp, max_batch_size=1, scv_service=scv) == \
        allen_cunneen_mean_wait(c, lam, 0.2, scv_service=scv)


def test_batch_mean_wait_stabilizes_overload():
    """An arrival rate that saturates the unbatched pool is finite under
    batching — the throughput headline in analytic form."""
    bp = BATCH_PROFILES[0]                      # S(1)=0.1, S(8)=0.38
    c = 4
    lam = 60.0                                  # > c/S(1) = 40 qps
    assert allen_cunneen_mean_wait(c, lam, bp.service_time(1)) == float("inf")
    w = batch_mean_wait(c, lam, bp, max_batch_size=8)
    assert w < float("inf")
    # beyond full-batch capacity c*B/S(B) = 84.2 qps: unstable again
    assert batch_mean_wait(c, 90.0, bp, max_batch_size=8) == float("inf")


def test_batch_mean_wait_forming_delay_bounded_by_linger():
    bp = BATCH_PROFILES[0]
    c, lam = 4, 2.0                             # light load: b_eq = 1
    base = batch_mean_wait(c, lam, bp, max_batch_size=8)
    lingered = batch_mean_wait(c, lam, bp, max_batch_size=8,
                               batch_timeout_s=0.05)
    # forming term = min(0.05, (8-1)/(2*2)) = 0.05 at this light rate
    assert lingered == pytest.approx(base + 0.05)
    # at high (still stable) rates the fill time, not the timeout, binds
    lam = 80.0                                  # < c*B/S(B) = 84.2 qps
    hi = batch_mean_wait(c, lam, bp, max_batch_size=8, batch_timeout_s=10.0)
    assert hi - batch_mean_wait(c, lam, bp, max_batch_size=8) == \
        pytest.approx((8 - 1) / (2 * lam))
    assert batch_mean_wait(c, 0.0, bp, max_batch_size=8) == 0.0
    with pytest.raises(ValueError):
        batch_mean_wait(c, 1.0, bp, max_batch_size=0)
    with pytest.raises(ValueError):
        batch_mean_wait(c, 1.0, bp, max_batch_size=2, batch_timeout_s=-1.0)


def test_max_sustainable_rate_scales_with_batch():
    pol = derive_policies(ladder_front(), slo_p95_s=1.0).policies[0]
    base = max_sustainable_rate(pol, num_servers=4)
    assert base == pytest.approx(4 / MEANS[0])
    # unmeasured batch law: batching buys nothing
    assert max_sustainable_rate(pol, num_servers=4, max_batch_size=8) == \
        pytest.approx(base)


# -- batch-aware thresholds ----------------------------------------------------


@given(st.integers(1, 8), st.floats(0.7, 3.0))
@settings(max_examples=30, deadline=None)
def test_derive_policies_b1_is_bit_for_bit(c, slo):
    """max_batch_size=1 must produce the identical table (same floats, same
    ints) as the unbatched derivation."""
    a = derive_policies(ladder_front(), slo_p95_s=slo, num_servers=c)
    b = derive_policies(ladder_front(), slo_p95_s=slo, num_servers=c,
                        max_batch_size=1, batch_profiles=BATCH_PROFILES)
    assert a.policies == b.policies
    assert b.max_batch_size == 1


def test_batched_thresholds_shift_outward():
    unb = derive_policies(ladder_front(), slo_p95_s=1.0, num_servers=4)
    bat = derive_policies(ladder_front(), slo_p95_s=1.0, num_servers=4,
                          max_batch_size=8, batch_profiles=BATCH_PROFILES)
    assert bat.max_batch_size == 8
    for u, b in zip(unb.policies, bat.policies):
        assert b.upscale_threshold >= u.upscale_threshold
        if b.downscale_threshold is not None:
            assert b.downscale_threshold >= u.downscale_threshold
    # the fast rung (large unbatched threshold -> full-batch regime) shifts
    # strictly and substantially
    assert bat.policies[0].upscale_threshold > \
        1.5 * unb.policies[0].upscale_threshold


def test_batched_thresholds_neutral_without_amortization():
    """No measured batch profile -> no-amortization fallback -> identical
    integer thresholds (the model never invents amortization)."""
    unb = derive_policies(ladder_front(), slo_p95_s=1.0, num_servers=4)
    bat = derive_policies(ladder_front(), slo_p95_s=1.0, num_servers=4,
                          max_batch_size=8)
    for u, b in zip(unb.policies, bat.policies):
        assert b.upscale_threshold == u.upscale_threshold
        assert b.downscale_threshold == u.downscale_threshold


def test_derive_policies_batch_validation():
    with pytest.raises(ValueError):
        derive_policies(ladder_front(), slo_p95_s=1.0, max_batch_size=0)
    with pytest.raises(ValueError):
        derive_policies(ladder_front(), slo_p95_s=1.0, max_batch_size=2,
                        batch_profiles=BATCH_PROFILES[:1])


def test_batched_threshold_region_is_downward_closed():
    """An upscale threshold must guarantee every depth at or below it: with
    an extreme alpha-dominated law the batch wait is non-monotone (depth 2
    at c=2 drains slower than depth 3), and the threshold must stop at the
    last depth below the first unsafe one rather than skipping past it."""
    from repro.core.aqm import _batch_drain_threshold
    bp = BatchProfile(alpha=1.0, beta=0.01)
    c, B, budget = 2, 2, 0.8
    t = _batch_drain_threshold(budget, bp, c, B)
    for n in range(t + 1):
        assert batch_expected_wait(n, bp, c, B) <= budget
    # ...and the threshold is exactly the last depth of the safe prefix
    assert batch_expected_wait(t + 1, bp, c, B) > budget


def test_max_sustainable_rate_honors_override():
    pol = derive_policies(ladder_front(), slo_p95_s=1.0).policies[0]
    bp = BATCH_PROFILES[0]
    got = max_sustainable_rate(pol, num_servers=4, max_batch_size=8,
                               batch_profile=bp)
    assert got == pytest.approx(4 * 8 / bp.service_time(8))
    assert got > max_sustainable_rate(pol, num_servers=4, max_batch_size=8)


@given(st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_derive_mix_policies_b1_is_bit_for_bit(c):
    a = derive_mix_policies(ladder_front(), slo_p95_s=1.0, num_servers=c)
    b = derive_mix_policies(ladder_front(), slo_p95_s=1.0, num_servers=c,
                            max_batch_size=1, batch_profiles=BATCH_PROFILES)
    assert a.policies == b.policies


def test_mix_batched_thresholds_shift_outward():
    unb = derive_mix_policies(ladder_front(), slo_p95_s=1.0, num_servers=4)
    bat = derive_mix_policies(ladder_front(), slo_p95_s=1.0, num_servers=4,
                              max_batch_size=8, batch_profiles=BATCH_PROFILES)
    assert bat.max_batch_size == 8
    for u, b in zip(unb.policies, bat.policies):
        assert b.assignment == u.assignment
        assert b.upscale_threshold >= u.upscale_threshold
    assert bat.policies[0].upscale_threshold > unb.policies[0].upscale_threshold


# -- planner integration -------------------------------------------------------


def test_planner_measures_batch_profile_and_batch_thresholds():
    base = BatchProfile(alpha=0.12, beta=0.08)   # S(1) = 0.2

    def profiler(config, n):
        return [0.2] * n

    def batch_profiler(config, b, n):
        return [base.service_time(b)] * n

    plan_unb = Planner(profiler=profiler, num_servers=4).plan(
        {("cfg",): 0.9}, slo_p95_s=1.0)
    plan_bat = Planner(profiler=profiler, num_servers=4, max_batch_size=8,
                       batch_profiler=batch_profiler).plan(
        {("cfg",): 0.9}, slo_p95_s=1.0)
    prof = plan_bat.front[0].profile
    assert prof.batch_profile is not None
    assert prof.batch_profile.alpha == pytest.approx(0.12, abs=1e-9)
    assert prof.batch_profile.beta == pytest.approx(0.08, abs=1e-9)
    assert plan_bat.table.max_batch_size == 8
    assert plan_bat.table.policies[0].upscale_threshold > \
        plan_unb.table.policies[0].upscale_threshold
    assert "batching B = 8" in plan_bat.describe()
    assert "batching" not in plan_unb.describe()


# -- scheduler batch draining / linger -----------------------------------------
#
# These used to exercise RequestQueue.get_batch's threaded linger; the
# semantics now live in the shared Scheduler and are tested in pure
# virtual time (no sleeps, no threads) — the same code path both the
# engine and the simulator drive.


def _req(i):
    return Request(request_id=i, arrival_s=0.0)


def _ids(dispatches):
    return [r.request_id for d in dispatches for r in d.items]


def test_scheduler_b1_never_lingers():
    """max_batch_size=1: a batch is full at the first request, so the
    linger window never opens even with a huge timeout."""
    s = Scheduler(num_workers=1, max_batch_size=1, batch_timeout_s=10.0)
    s.offer(_req(0), 0.0)
    dispatches, lingers = s.poll(0.0)
    assert _ids(dispatches) == [0]
    assert lingers == []
    assert s.next_linger_deadline() is None


def test_scheduler_batches_drain_fifo_runs_greedily():
    s = Scheduler(num_workers=2, max_batch_size=4)
    for i in range(10):
        s.offer(_req(i), 0.0)
    dispatches, _ = s.poll(0.0)
    assert [_ids([d]) for d in dispatches] == [[0, 1, 2, 3], [4, 5, 6, 7]]
    assert [d.worker_id for d in dispatches] == [0, 1]
    s.release(0, 1.0)
    dispatches, _ = s.poll(1.0)
    assert _ids(dispatches) == [8, 9]
    with pytest.raises(ValueError):
        Scheduler(num_workers=1, max_batch_size=0)


def test_scheduler_linger_fills_from_late_arrivals():
    """A short batch held open by the linger window must absorb arrivals
    that land inside it and dispatch the moment it fills — before the
    window expires."""
    s = Scheduler(num_workers=1, max_batch_size=3, batch_timeout_s=5.0)
    s.offer(_req(0), 0.0)
    dispatches, lingers = s.poll(0.0)
    assert dispatches == [] and len(lingers) == 1
    assert lingers[0].deadline_s == pytest.approx(5.0)
    s.offer(_req(1), 0.05)
    assert s.poll(0.05) == ([], [])       # still short: keeps lingering
    s.offer(_req(2), 0.06)
    dispatches, _ = s.poll(0.06)          # full: dispatches at the fill time
    assert _ids(dispatches) == [0, 1, 2]
    assert dispatches[0].start_s == pytest.approx(0.06)
    # the scheduled expiry is now stale
    assert s.on_linger_expired(lingers[0].token, 5.0) is None


def test_scheduler_linger_timeout_flushes_partial():
    s = Scheduler(num_workers=1, max_batch_size=4, batch_timeout_s=0.05)
    s.offer(_req(0), 0.0)
    _, lingers = s.poll(0.0)
    res = s.on_linger_expired(lingers[0].token, 0.05)
    assert res is not None
    dispatches, _ = res
    assert _ids(dispatches) == [0]
    assert dispatches[0].batch_size == 1
    assert dispatches[0].start_s == pytest.approx(0.05)


def test_scheduler_forming_batch_visible_as_buffered():
    """Requests held by a forming (lingering) batch must stay visible in
    buffered() — that is the depth the controller observes and the
    engine's drain loop keys off, and it matches the simulator exactly
    because both drive this one implementation."""
    s = Scheduler(num_workers=1, max_batch_size=8, batch_timeout_s=0.3)
    s.offer(_req(0), 0.0)
    s.offer(_req(1), 0.0)
    _, lingers = s.poll(0.0)
    assert len(lingers) == 1              # forming batch held open
    assert s.buffered() == 2              # still counted while forming
    res = s.on_linger_expired(lingers[0].token, 0.3)
    dispatches, _ = res
    assert len(dispatches[0].items) == 2
    assert s.buffered() == 0


def test_bounded_scheduler_counts_forming_batch_toward_admission():
    """Admission control bounds buffered depth *including* a forming
    batch: holding requests in a linger window must not let the bounded
    scheduler admit past max_queue_depth."""
    s = Scheduler(num_workers=1, max_batch_size=8, batch_timeout_s=0.3,
                  max_queue_depth=2)
    assert s.offer(_req(0), 0.0).admitted
    assert s.offer(_req(1), 0.0).admitted
    _, lingers = s.poll(0.0)
    assert s.buffered() == 2              # both held by the forming batch
    assert not s.offer(_req(2), 0.1).admitted   # still full
    assert s.dropped == 1
    res = s.on_linger_expired(lingers[0].token, 0.3)
    assert len(res[0][0].items) == 2      # batch dispatched: capacity freed
    assert s.offer(_req(3), 0.4).admitted


def test_scheduler_stale_linger_token_is_noop():
    """An expiry for a batch that already dispatched (filled early) must
    not flush anything — the token invalidation the old threaded queue
    implemented with its claimed-count machinery."""
    s = Scheduler(num_workers=1, max_batch_size=2, batch_timeout_s=1.0)
    s.offer(_req(0), 0.0)
    _, lingers = s.poll(0.0)
    s.offer(_req(1), 0.2)
    dispatches, _ = s.poll(0.2)           # fills -> dispatches early
    assert _ids(dispatches) == [0, 1]
    assert s.on_linger_expired(lingers[0].token, 1.0) is None
    assert s.buffered() == 0


# -- executor.execute_batch ----------------------------------------------------


def test_execute_batch_shares_timestamps_and_records_batch_size():
    calls = []

    def wf(config, payload):
        calls.append(payload)
        return payload * 2

    ex = WorkflowExecutor(configs=[("cfg", 0)], workflow_fn=wf)
    reqs = [Request(request_id=i, arrival_s=0.1 * i, payload=i)
            for i in range(4)]
    recs = ex.execute_batch(reqs, worker_id=1)
    assert len(recs) == 4
    assert calls == [0, 1, 2, 3]            # sequential fallback, in order
    assert len({r.start_s for r in recs}) == 1
    assert len({r.completion_s for r in recs}) == 1
    for i, r in enumerate(recs):
        assert r.batch_size == 4
        assert r.result == 2 * i
        assert r.worker_id == 1
    assert ex.records == recs
    with pytest.raises(ValueError):
        ex.execute_batch([])


def test_execute_batch_uses_vectorized_fn():
    def wf(config, payload):                 # must NOT be called
        raise AssertionError("scalar path used")

    def batch_wf(config, payloads):
        return [p + 100 for p in payloads]

    ex = WorkflowExecutor(configs=[("cfg", 0)], workflow_fn=wf,
                          batch_workflow_fn=batch_wf)
    reqs = [Request(request_id=i, arrival_s=0.0, payload=i) for i in range(3)]
    recs = ex.execute_batch(reqs)
    assert [r.result for r in recs] == [100, 101, 102]

    def bad_batch_wf(config, payloads):
        return payloads[:-1]                 # wrong length

    ex2 = WorkflowExecutor(configs=[("cfg", 0)], workflow_fn=wf,
                           batch_workflow_fn=bad_batch_wf)
    with pytest.raises(ValueError, match="results"):
        ex2.execute_batch(reqs)
    assert ex2.in_flight() == 0              # accounting restored on error


def test_execute_batch_of_one_delegates_to_execute():
    ex = WorkflowExecutor(configs=[("cfg", 0)],
                          workflow_fn=lambda c, p: p)
    recs = ex.execute_batch([Request(request_id=7, arrival_s=0.0, payload=9)])
    assert len(recs) == 1
    assert recs[0].batch_size == 1
    assert recs[0].request_id == 7


# -- worker pool / engine ------------------------------------------------------


def sleep_workflow(config, payload):
    time.sleep(0.003)
    return payload


def test_engine_b1_matches_pr2_engine_behavior():
    """Golden equivalence for the threaded path: max_batch_size=1 must
    behave exactly like the PR-2 engine — same FIFO completion order at
    c=1, every record a singleton batch, no linger stalls."""
    def run(**kw):
        ex = WorkflowExecutor(configs=[("cfg", 0)], workflow_fn=sleep_workflow)
        eng = ServingEngine(ex, num_workers=1, control_tick_s=0.01, **kw)
        eng.start()
        for i in range(30):
            eng.submit(Request(request_id=i, arrival_s=0.0))
        return eng.drain_and_stop()

    plain = run()
    b1 = run(max_batch_size=1, batch_timeout_s=0.5)
    for rep in (plain, b1):
        assert [r.request_id for r in rep.records] == list(range(30))
        assert all(r.batch_size == 1 for r in rep.records)
        assert rep.mean_batch_size == 1.0
    assert b1.max_batch_size == 1
    assert [r.request_id for r in b1.records] == \
        [r.request_id for r in plain.records]


def test_engine_batching_forms_batches_and_drains_all():
    # a (never-switching) controller so the observe loop records snapshots
    front = [synthetic_point(0.003, 0.005, 0.7, "fast"),
             synthetic_point(0.008, 0.012, 0.9, "accurate")]
    table = derive_policies(front, slo_p95_s=30.0,
                            hysteresis=HysteresisSpec(downscale_cooldown_s=60.0))
    ex = WorkflowExecutor(configs=[("cfg", 0), ("cfg", 1)],
                          workflow_fn=sleep_workflow)
    eng = ServingEngine(ex, controller=ElasticoController(table),
                        num_workers=2, control_tick_s=0.01,
                        max_batch_size=4, batch_timeout_s=0.02)
    eng.start()
    for i in range(100):
        eng.submit(Request(request_id=i, arrival_s=0.0))
    rep = eng.drain_and_stop()
    assert len(rep.records) == 100
    assert rep.total_requests == 100 and rep.dropped == 0
    assert any(r.batch_size > 1 for r in rep.records)
    assert rep.mean_batch_size > 1.0
    assert rep.max_batch_size == 4
    assert sum(rep.served_per_worker) == 100
    # batch members share their dispatch timestamps
    by_batch = {}
    for r in rep.records:
        by_batch.setdefault((r.worker_id, r.start_s), []).append(r)
    for members in by_batch.values():
        assert len({m.completion_s for m in members}) == 1
        assert len({m.batch_size for m in members}) == 1
        assert members[0].batch_size == len(members)
    # monitor snapshots carry the realized batch size
    assert any(s.batch_size is not None and s.batch_size >= 1.0
               for s in eng.monitor.history())


def test_engine_linger_does_not_lose_partial_batches():
    """Drain must wait for a lingering worker's claimed-but-unexecuted
    batch (pool.pending), or the last requests of a trace vanish."""
    ex = WorkflowExecutor(configs=[("cfg", 0)], workflow_fn=sleep_workflow)
    eng = ServingEngine(ex, num_workers=1, control_tick_s=0.01,
                        max_batch_size=8, batch_timeout_s=0.2)
    eng.start()
    eng.submit(Request(request_id=0, arrival_s=0.0))
    time.sleep(0.05)   # worker is now lingering with a claimed singleton
    rep = eng.drain_and_stop()
    assert len(rep.records) == 1
    assert rep.records[0].request_id == 0


def test_worker_pool_batch_validation():
    ex = WorkflowExecutor(configs=[("cfg", 0)], workflow_fn=sleep_workflow)
    with pytest.raises(ValueError):
        WorkerPool(ex, c=1, max_batch_size=0)
    with pytest.raises(ValueError):
        WorkerPool(ex, c=1, batch_timeout_s=-0.1)
    pool = WorkerPool(ex, c=2, max_batch_size=4)
    assert pool.mean_batch_size() == 1.0       # before any dispatch
    assert pool.pending() == 0


# -- simulator: goldens and batching behavior ----------------------------------


def test_simulator_b1_reproduces_pr2_schedule_bit_for_bit():
    """The tentpole golden: max_batch_size=1 (with every batching knob set)
    must reproduce the PR-2 simulator's schedule exactly — homogeneous,
    static-mix, and controller-driven runs alike."""
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    arr = generate_arrivals(
        sustained_overload_pattern(1.0 / MEANS[0], overload_factor=2.5,
                                   warmup_s=20.0), 120.0, seed=1)
    table = derive_policies(ladder_front(), slo_p95_s=1.0,
                            hysteresis=HysteresisSpec(downscale_cooldown_s=5.0),
                            num_servers=4)
    cases = [
        dict(static_index=0),
        dict(assignment=[0, 0, 1, 2]),
        dict(controller=ElasticoController(table)),
    ]
    for kw in cases:
        plain = ServingSimulator(sampler, seed=0, num_servers=4, **kw)
        batched = ServingSimulator(sampler, seed=0, num_servers=4,
                                   max_batch_size=1, batch_timeout_s=0.5,
                                   batch_profiles=BATCH_PROFILES, **kw)
        a = plain.run(arr, 120.0)
        b = batched.run(arr, 120.0)
        assert a.completed == b.completed
        assert a.per_server_busy_s == b.per_server_busy_s
        assert a.queue_depth_samples == b.queue_depth_samples
        assert a.config_timeline == b.config_timeline
        assert b.num_batches == len(b.completed)
        assert b.mean_batch_size() == 1.0


def test_simulator_batching_conserves_and_amortizes():
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    arr = generate_arrivals(
        sustained_overload_pattern(1.0 / MEANS[0], overload_factor=7.0,
                                   warmup_s=10.0), 60.0, seed=1)
    out = ServingSimulator(sampler, static_index=0, seed=0, num_servers=4,
                           max_batch_size=8,
                           batch_profiles=BATCH_PROFILES).run(arr, 60.0)
    assert len(out.completed) == len(arr)
    ids = [r.request_id for r in out.completed]
    assert len(set(ids)) == len(ids)
    assert all(1 <= r.batch_size <= 8 for r in out.completed)
    assert out.mean_batch_size() > 2.0         # overload fills batches
    # batching must beat the unbatched pool on this trace
    unb = ServingSimulator(sampler, static_index=0, seed=0,
                           num_servers=4).run(arr, 60.0)
    ok = sum(1 for r in out.completed if r.latency_s <= 1.0) / len(arr)
    ok_unb = sum(1 for r in unb.completed if r.latency_s <= 1.0) / len(arr)
    assert ok >= 1.5 * ok_unb


def test_simulator_linger_boundary_light_load():
    """Light load + linger: singletons dispatch exactly at the linger
    window (the boundary case), never earlier, never much later."""
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    arr = generate_arrivals(constant_rate(0.5), 30.0, seed=2)
    tau = 0.05
    out = ServingSimulator(sampler, static_index=0, seed=0, num_servers=2,
                           max_batch_size=4, batch_timeout_s=tau,
                           batch_profiles=BATCH_PROFILES).run(arr, 30.0)
    assert len(out.completed) == len(arr)
    for r in out.completed:
        if r.batch_size == 1:
            assert r.start_s - r.arrival_s == pytest.approx(tau, abs=1e-9)


def test_simulator_linger_zero_dispatches_greedily():
    """tau = 0: no linger events, batches form only from backlog; under
    light load every batch is a singleton dispatched immediately."""
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    arr = generate_arrivals(constant_rate(0.5), 30.0, seed=2)
    out = ServingSimulator(sampler, static_index=0, seed=0, num_servers=2,
                           max_batch_size=4,
                           batch_profiles=BATCH_PROFILES).run(arr, 30.0)
    for r in out.completed:
        if r.batch_size == 1:
            assert r.start_s == pytest.approx(r.arrival_s, abs=1e-9)


def test_simulator_linger_fill_dispatches_before_timeout():
    """Arrivals that complete a forming batch dispatch it at the fill
    moment, not at the timeout."""
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    # two arrivals 10 ms apart, linger window 10 s, B = 2: the batch must
    # dispatch at t = 0.01 (fill), far before the window.
    out = ServingSimulator(sampler, static_index=0, seed=0, num_servers=1,
                           max_batch_size=2, batch_timeout_s=10.0,
                           batch_profiles=BATCH_PROFILES).run([0.0, 0.01], 1.0)
    assert len(out.completed) == 2
    assert all(r.batch_size == 2 for r in out.completed)
    assert all(r.start_s == pytest.approx(0.01, abs=1e-9)
               for r in out.completed)


def test_simulator_batch_validation():
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    with pytest.raises(ValueError):
        ServingSimulator(sampler, max_batch_size=0).run([0.1], 1.0)
    with pytest.raises(ValueError):
        ServingSimulator(sampler, batch_timeout_s=-1.0).run([0.1], 1.0)


def test_batched_elastico_holds_accuracy_longer_under_load():
    """The threshold-shift payoff: with batch-aware thresholds and a
    batched pool, Elastico serves overload at visibly higher goodput than
    the unbatched pool with its own honest thresholds."""
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    arr = generate_arrivals(
        sustained_overload_pattern(1.0 / MEANS[0], overload_factor=7.0,
                                   warmup_s=20.0), 120.0, seed=1)
    hyst = HysteresisSpec(downscale_cooldown_s=5.0)
    unb_table = derive_policies(ladder_front(), slo_p95_s=1.0,
                                hysteresis=hyst, num_servers=4)
    bat_table = derive_policies(ladder_front(), slo_p95_s=1.0,
                                hysteresis=hyst, num_servers=4,
                                max_batch_size=8,
                                batch_profiles=BATCH_PROFILES)
    unb = ServingSimulator(sampler, controller=ElasticoController(unb_table),
                           seed=0, num_servers=4).run(arr, 120.0)
    bat = ServingSimulator(sampler, controller=ElasticoController(bat_table),
                           seed=0, num_servers=4, max_batch_size=8,
                           batch_timeout_s=0.005,
                           batch_profiles=BATCH_PROFILES).run(arr, 120.0)
    good_unb = sum(1 for r in unb.completed if r.latency_s <= 1.0) / len(arr)
    good_bat = sum(1 for r in bat.completed if r.latency_s <= 1.0) / len(arr)
    assert good_bat >= 1.5 * good_unb
    assert bat.mean_batch_size() > 1.5
