"""Calibrated surrogate workflows (paper-scale COMPASS-V substrate)."""

import statistics

import pytest

from repro.core.space import detection_paper_space, rag_paper_space


def test_spaces_match_paper_grids(rag_surrogate, detection_surrogate):
    assert rag_surrogate.space.cardinality == rag_paper_space().cardinality
    assert detection_surrogate.space.cardinality == detection_paper_space().cardinality


def test_scores_in_unit_interval(rag_surrogate):
    for c in list(rag_surrogate.space.enumerate())[::37]:
        for s in rag_surrogate.evaluate_samples(c, range(20)):
            assert 0.0 <= s <= 1.0


def test_samples_deterministic(rag_surrogate):
    c = next(rag_surrogate.space.enumerate())
    a = rag_surrogate.evaluate_samples(c, range(50))
    b = rag_surrogate.evaluate_samples(c, range(50))
    assert a == b


def test_sample_mean_converges_to_accuracy(rag_surrogate):
    """Per-sample Bernoulli-ish outcomes must be unbiased for Acc(c)."""
    for c in list(rag_surrogate.space.enumerate())[::61]:
        true = rag_surrogate.accuracy(c)
        est = statistics.mean(rag_surrogate.evaluate_samples(c, range(400)))
        assert abs(est - true) < 0.08, (c, true, est)


def test_bigger_generator_more_accurate_and_slower(rag_surrogate):
    """The paper's premise: larger models -> higher accuracy + latency."""
    space = rag_surrogate.space
    gen_axis = space.axis("generator")
    base = space.from_dict(
        {"generator": "llama3-1b", "retriever_k": 10, "rerank_k": 3, "reranker": "bge-v2"}
    )
    big = space.from_dict(
        {"generator": "llama3-8b", "retriever_k": 10, "rerank_k": 3, "reranker": "bge-v2"}
    )
    assert rag_surrogate.accuracy(big) > rag_surrogate.accuracy(base)
    assert rag_surrogate.mean_latency_s(big) > rag_surrogate.mean_latency_s(base)


def test_detection_verifier_helps_accuracy(detection_surrogate):
    space = detection_surrogate.space
    none = space.from_dict(
        {"detector": "yolov8s", "verifier": "none", "confidence": 0.3, "nms": 0.5}
    )
    big = space.from_dict(
        {"detector": "yolov8s", "verifier": "yolov8x", "confidence": 0.3, "nms": 0.5}
    )
    assert detection_surrogate.accuracy(big) > detection_surrogate.accuracy(none)
    assert detection_surrogate.mean_latency_s(big) > detection_surrogate.mean_latency_s(none)


def test_latencies_positive(rag_surrogate, detection_surrogate):
    for sur in (rag_surrogate, detection_surrogate):
        for c in list(sur.space.enumerate())[::53]:
            assert sur.mean_latency_s(c) > 0
            assert sur.latency_cv(c) > 0
