"""Workflow-DAG queueing networks (repro/serving/dag.py).

The contract stack, strictest first:

1. **Degenerate collapse is bit-exact**: a single-stage
   :class:`~repro.serving.dag.WorkflowDAG` through
   :class:`~repro.serving.dag.DagSimulator` replays
   :class:`~repro.serving.simulator.ServingSimulator` op-for-op — pinned
   against the seed-commit golden digest, so the DAG layer provably costs
   nothing when the workflow is not compound.
2. **The fast path is the oracle**: for static, unbounded, B = 1 runs,
   :func:`~repro.serving.dag.simulate_dag` produces the event-heap
   simulator's sink records bit-for-bit — property-tested over random
   tandem and fork-join topologies, mixed pool sizes, lognormal tails.
3. **Conservation**: admitted == completed + in-flight (+ dropped ==
   offered) at every stage, for random topologies, bounded queues, and
   mid-flight stops (``drain=False``).
4. **Analytic anchors**: Burke's theorem through
   :func:`~repro.core.aqm.departure_scv` (M/M/c departures are Poisson),
   the Jackson product form through :func:`~repro.core.aqm.tandem_waits`,
   and the m * H_k harmonic fork-join penalty through
   :func:`~repro.core.aqm.fork_join_sojourn`.
5. **Ladder collapse**: single-stage pipeline thresholds equal
   :func:`~repro.core.aqm.derive_policies` exactly, and the weighted
   per-stage depth collapse in
   :meth:`~repro.core.elastico.ElasticoController.observe_stages` makes
   bit-identical decisions to scalar :meth:`observe`.
"""

import hashlib
import math
import random

import numpy as np
import pytest

from proptest import given, settings, st

from repro.core.aqm import (
    HysteresisSpec,
    departure_scv,
    derive_policies,
    fork_join_sojourn,
    tandem_waits,
)
from repro.core.elastico import ElasticoController
from repro.core.planner import Planner
from repro.serving.dag import (
    DagSimulator,
    PipelinePlan,
    StageSpec,
    WorkflowDAG,
    derive_pipeline_policies,
    pipeline_service_profile,
    pipeline_sojourn,
    simulate_dag,
    sweep_pipeline,
)
from repro.serving.fastsim import chained_lindley
from repro.serving.faults import Brownout, FaultSchedule, Straggler, WorkerCrash
from repro.serving.scheduler import Scheduler
from repro.serving.simulator import (
    ServingSimulator,
    lognormal_sampler_from_profile,
)
from repro.serving.traces import diurnal_trace, replay_dag
from repro.serving.workload import (
    constant_rate,
    generate_arrivals,
    spike_pattern,
)

from conftest import synthetic_point

MEANS = [0.10, 0.25, 0.45]
P95S = [0.14, 0.35, 0.63]
ACCS = [0.76, 0.82, 0.85]


def ladder_front():
    return [
        synthetic_point(m, p, a, f"c{i}")
        for i, (m, p, a) in enumerate(zip(MEANS, P95S, ACCS))
    ]


def flat_stage(**kw):
    """The golden scenario's ladder as a single StageSpec."""
    return StageSpec(name="svc", mean_s=tuple(MEANS), p95_s=tuple(P95S),
                     accuracy=tuple(ACCS), **kw)


def _digest(completed):
    h = hashlib.sha256()
    for r in completed:
        h.update(
            f"{r.request_id},{r.arrival_s:.12e},{r.start_s:.12e},"
            f"{r.completion_s:.12e},{r.config_index};".encode()
        )
    return h.hexdigest()


# --------------------------------------------------------------------------
# 1. degenerate collapse: single-stage DAG == flat simulator, bit-for-bit
# --------------------------------------------------------------------------


def test_single_stage_dag_reproduces_seed_golden():
    """The golden scenario of ``test_multi_server.py`` through the DAG
    layer: same digest as the flat ServingSimulator's seed-commit run.
    If this moves, the degenerate DAG no longer replays the paper-faithful
    M/G/1 runtime bit-for-bit."""
    table = derive_policies(ladder_front(), slo_p95_s=1.0)
    arr = generate_arrivals(spike_pattern(2.0, factor=4.0), 180.0, seed=1)
    sim = DagSimulator(
        WorkflowDAG.single(flat_stage()),
        controller=ElasticoController(table),
        seed=7,
    )
    out = sim.run(arr, 180.0)
    assert len(out.completed) == 732
    assert len(out.switch_events) == 14
    assert _digest(out.completed) == (
        "dfec2ace7a6aa74c5246f4769e3ed8ec433b3f2ea07e4a6c0d38ba79038ed1f6"
    )


def test_single_stage_dag_full_equality_with_flat_simulator():
    """Beyond the completion digest: config timeline, depth samples, busy
    time and switch events all agree with the flat simulator — the whole
    observable surface, controller runs included."""
    table = derive_policies(ladder_front(), slo_p95_s=1.0)
    arr = generate_arrivals(constant_rate(5.0), 60.0, seed=3)

    flat = ServingSimulator(
        lognormal_sampler_from_profile(MEANS, P95S),
        controller=ElasticoController(table), seed=11,
    ).run(arr, 60.0)
    dag = DagSimulator(
        WorkflowDAG.single(flat_stage()),
        controller=ElasticoController(table), seed=11,
    ).run(arr, 60.0)

    assert dag.completed == flat.completed
    assert dag.config_timeline == flat.config_timeline
    assert dag.queue_depth_samples == flat.queue_depth_samples
    assert dag.per_server_busy_s == flat.per_server_busy_s
    assert [(e.time_s, e.from_index, e.to_index) for e in dag.switch_events] \
        == [(e.time_s, e.from_index, e.to_index) for e in flat.switch_events]
    assert dag.num_servers == flat.num_servers == 1
    # and the per-request accuracy is the stage factor actually served
    for r in dag.completed:
        assert dag.request_accuracy[r.request_id] == ACCS[r.config_index]


# --------------------------------------------------------------------------
# 2. fast path == oracle (bit-for-bit), random topologies
# --------------------------------------------------------------------------


def _random_stage(rng, name, *, max_c=3):
    m = rng.uniform(0.02, 0.15)
    return StageSpec(name=name, mean_s=(m,), p95_s=(m * rng.uniform(1.2, 2.0),),
                     num_servers=rng.randint(1, max_c))


def _random_dag(kind, width, topo_seed):
    rng = random.Random(topo_seed)
    if kind == 0:
        return WorkflowDAG.single(_random_stage(rng, "s0"))
    if kind == 1:
        return WorkflowDAG.tandem(
            [_random_stage(rng, f"s{j}") for j in range(width + 1)])
    branches = [_random_stage(rng, f"b{j}") for j in range(max(2, width))]
    join = _random_stage(rng, "join")
    tail = [_random_stage(rng, "tail")] if rng.random() < 0.5 else []
    return WorkflowDAG.fork_join(branches, join, tail=tail)


@given(st.integers(0, 2), st.integers(1, 3), st.integers(0, 10**6),
       st.floats(3.0, 9.0))
@settings(max_examples=12, deadline=None)
def test_fast_path_matches_oracle_bit_for_bit(kind, width, topo_seed, rate):
    """simulate_dag's sink records equal DagSimulator's exactly — same
    request ids, same start/completion floats, same dispatch order —
    across tandem and fork-join topologies with mixed pool sizes."""
    dag = _random_dag(kind, width, topo_seed)
    cfg = (0,) * dag.num_stages
    arr = generate_arrivals(constant_rate(rate), 30.0,
                            seed=topo_seed % 1000)
    oracle = DagSimulator(dag, static_stage_indices=cfg,
                          seed=topo_seed % 97).run(arr, 30.0)
    fast = simulate_dag(dag, arr, stage_indices=cfg, seed=topo_seed % 97)
    assert _digest(fast.completed) == _digest(oracle.completed)
    assert len(fast.completed) == len(arr)
    np.testing.assert_array_equal(
        np.sort(fast.stage_completions[-1]),
        np.sort([r.completion_s for r in oracle.completed]))


def test_fast_path_fork_join_waits_for_all_branches():
    """A join request's stage arrival is the max over its branch
    completions: every sink latency must be >= the slowest branch's
    service contribution, and the per-stage grid must satisfy the
    max-composition row-wise."""
    rng = random.Random(5)
    dag = WorkflowDAG.fork_join(
        [_random_stage(rng, "a", max_c=1), _random_stage(rng, "b", max_c=1)],
        _random_stage(rng, "join", max_c=1))
    arr = generate_arrivals(constant_rate(4.0), 25.0, seed=2)
    res = simulate_dag(dag, arr, stage_indices=(0, 0, 0), seed=9)
    comp = res.stage_completions
    # join completion strictly after both branch completions
    assert np.all(comp[2] > np.maximum(comp[0], comp[1]))


def test_fast_path_rejects_bounded_queues():
    st_ = StageSpec(name="s", mean_s=(0.1,), max_queue_depth=4)
    dag = WorkflowDAG.single(st_)
    with pytest.raises(ValueError, match="unbounded"):
        simulate_dag(dag, [0.0, 0.1], stage_indices=(0,))


# --------------------------------------------------------------------------
# 3. conservation at every stage
# --------------------------------------------------------------------------


@given(st.integers(0, 2), st.integers(1, 3), st.integers(0, 10**6),
       st.floats(4.0, 14.0), st.sampled_from([None, 2, 5]),
       st.sampled_from([True, False]))
@settings(max_examples=15, deadline=None)
def test_stage_conservation(kind, width, topo_seed, rate, bound, drain):
    """offered == dropped + completed + in_flight at every stage, whether
    the run drains, stops mid-flight, or sheds load at a bounded queue.
    Drained runs additionally finish with zero in-flight everywhere."""
    dag = _random_dag(kind, width, topo_seed)
    if bound is not None:
        # bound the *sink* queue: downstream drops exercise the invariant
        # without starving the join bookkeeping upstream
        stages = list(dag.stages)
        j = dag.sink()
        stages[j] = StageSpec(
            name=stages[j].name, mean_s=stages[j].mean_s,
            p95_s=stages[j].p95_s, num_servers=stages[j].num_servers,
            max_queue_depth=bound)
        dag = WorkflowDAG(stages=tuple(stages), edges=dag.edges)
    arr = generate_arrivals(constant_rate(rate), 20.0, seed=topo_seed % 500)
    out = DagSimulator(dag, static_stage_indices=(0,) * dag.num_stages,
                       seed=topo_seed % 89).run(arr, 20.0, drain=drain)
    for s in out.stage_stats:
        assert s.offered == s.dropped + s.completed + s.in_flight, s
        if drain:
            assert s.in_flight == 0
    # end-to-end: completion records are appended at sink *dispatch*, so a
    # mid-flight stop may have records whose completion event is still
    # pending — bounded by the sink's in-service population
    sink_stats = out.stage_stats[dag.sink()]
    assert sink_stats.completed <= len(out.completed) \
        <= sink_stats.completed + sink_stats.in_flight
    if drain:
        assert sink_stats.completed == len(out.completed)
        assert out.offered == len(arr)


def _random_stage_faults(dag, fault_seed, horizon):
    """A per-stage fault schedule for an arbitrary topology: at most one
    crash window per stage (on a worker that stage actually has), plus
    stage-scoped stragglers and brownouts, all derived from the seed."""
    rng = random.Random(fault_seed)
    crashes, stragglers, brownouts = [], [], []
    for j, stg in enumerate(dag.stages):
        if rng.random() < 0.55:
            t = rng.uniform(0.05, 0.6) * horizon
            recover = (t + rng.uniform(0.05, 0.3) * horizon
                       if rng.random() < 0.75 else None)
            crashes.append(WorkerCrash(
                time_s=t, worker_id=rng.randrange(stg.num_servers),
                recover_s=recover, stage=j))
        if rng.random() < 0.35:
            a = rng.uniform(0.0, 0.7) * horizon
            stragglers.append(Straggler(
                worker_id=rng.randrange(stg.num_servers), start_s=a,
                end_s=a + rng.uniform(0.05, 0.25) * horizon,
                factor=rng.uniform(1.2, 2.5), stage=j))
        if rng.random() < 0.3:
            a = rng.uniform(0.0, 0.7) * horizon
            brownouts.append(Brownout(
                stage=j, start_s=a,
                end_s=a + rng.uniform(0.05, 0.25) * horizon,
                factor=rng.uniform(1.2, 2.0)))
    return FaultSchedule(crashes=tuple(crashes),
                         stragglers=tuple(stragglers),
                         brownouts=tuple(brownouts))


@given(st.integers(0, 2), st.integers(1, 3), st.integers(0, 10**6),
       st.floats(4.0, 12.0), st.integers(0, 3),
       st.sampled_from([True, False]))
@settings(max_examples=15, deadline=None)
def test_stage_conservation_under_random_faults(kind, width, topo_seed,
                                                rate, budget, drain):
    """admitted == completed + in_flight + failed at every stage, for
    random topologies under random crash/straggler/brownout schedules and
    retry budgets — a failed request never propagates downstream, a
    crashed batch never vanishes."""
    dag = _random_dag(kind, width, topo_seed)
    faults = _random_stage_faults(dag, topo_seed + 17, 20.0)
    arr = generate_arrivals(constant_rate(rate), 20.0, seed=topo_seed % 500)
    out = DagSimulator(dag, static_stage_indices=(0,) * dag.num_stages,
                       seed=topo_seed % 89, faults=faults,
                       retry_budget=budget).run(arr, 20.0, drain=drain)
    total_failed = 0
    for s in out.stage_stats:
        assert s.admitted == s.completed + s.in_flight + s.failed, s
        assert s.retried >= 0
        total_failed += s.failed
    assert out.failed == total_failed
    assert out.offered == len(arr)
    # sink records are never duplicated, whatever was retried upstream
    ids = [r.request_id for r in out.completed]
    assert len(set(ids)) == len(ids)
    # a drained run with every crash recovered ends with nothing in flight
    if drain and all(c.recover_s is not None for c in faults.crashes):
        assert out.in_flight == 0
        assert sum(s.in_flight for s in out.stage_stats) == 0


# --------------------------------------------------------------------------
# 4. analytic anchors for the queueing-network model
# --------------------------------------------------------------------------


def test_departure_scv_burke_anchor():
    """M/M/c: Poisson in, exponential service -> Poisson out (C_d^2 = 1)
    at every utilization and pool size."""
    for c in (1, 2, 8):
        for rho in (0.1, 0.5, 0.95):
            assert departure_scv(c, rho) == pytest.approx(1.0, abs=1e-12)
    # limits: rho -> 0 reproduces the arrivals, rho -> 1 (c=1) the services
    assert departure_scv(1, 0.0, scv_arrival=2.5, scv_service=0.3) \
        == pytest.approx(2.5)
    assert departure_scv(1, 1.0, scv_arrival=2.5, scv_service=0.3) \
        == pytest.approx(0.3)
    # overload clamps to the service process
    assert departure_scv(1, 1.7, scv_service=0.3) == pytest.approx(0.3)


def test_tandem_waits_jackson_product_form():
    """Exponential service everywhere: each stage is its own M/M/1 with
    wait rho * s / (1 - rho), and every departure SCV stays exactly 1 —
    the Jackson-network anchor of the decomposition."""
    rate, s = 4.0, 0.1
    rho = rate * s
    waits = tandem_waits(rate, [s, s, s])
    for w in waits:
        assert w.mean_wait_s == pytest.approx(rho * s / (1 - rho), rel=1e-12)
        assert w.utilization == pytest.approx(rho)
        assert w.scv_arrival == pytest.approx(1.0)
        assert w.scv_departure == pytest.approx(1.0)


def test_tandem_waits_saturation_propagates():
    waits = tandem_waits(12.0, [0.05, 0.2])    # stage 2 at rho = 2.4
    assert math.isfinite(waits[0].mean_wait_s)
    assert waits[1].mean_wait_s == float("inf")
    assert waits[1].utilization == pytest.approx(2.4)


def test_fork_join_harmonic_penalty():
    m = 0.2
    assert fork_join_sojourn([m]) == pytest.approx(m)
    assert fork_join_sojourn([m, m]) == pytest.approx(1.5 * m, rel=1e-12)
    h4 = 1.0 + 0.5 + 1.0 / 3.0 + 0.25
    assert fork_join_sojourn([m] * 4) == pytest.approx(m * h4, rel=1e-12)
    # two distinct branches: E[max] = m1 + m2 - 1/(l1 + l2)
    want = 0.1 + 0.3 - 1.0 / (10.0 + 10.0 / 3.0)
    assert fork_join_sojourn([0.1, 0.3]) == pytest.approx(want, rel=1e-12)
    with pytest.raises(ValueError, match="16"):
        fork_join_sojourn([m] * 17)


def test_pipeline_sojourn_tandem_matches_tandem_waits():
    """pipeline_sojourn over a tandem DAG is exactly the tandem_waits
    decomposition plus the service means (same SCV chaining)."""
    stages = [StageSpec(name=f"s{j}", mean_s=(m,), p95_s=(p,))
              for j, (m, p) in enumerate([(0.05, 0.08), (0.08, 0.13)])]
    dag = WorkflowDAG.tandem(stages)
    rate = 6.0
    from repro.serving.dag import stage_service_scv

    scvs = [stage_service_scv(s.mean_s[0], s.p95_s[0]) for s in stages]
    waits = tandem_waits(rate, [s.mean_s[0] for s in stages],
                         scv_service=scvs)
    want = sum(w.mean_wait_s for w in waits) \
        + sum(s.mean_s[0] for s in stages)
    assert pipeline_sojourn(dag, (0, 0), rate) == pytest.approx(want,
                                                                rel=1e-12)


# --------------------------------------------------------------------------
# 5. the pipeline ladder
# --------------------------------------------------------------------------


def test_single_stage_ladder_collapses_to_aqm_thresholds():
    """Every threshold, slack and exclusion of the single-stage pipeline
    ladder equals derive_policies' — Eq. 10/13 recovered exactly."""
    base = derive_policies(ladder_front(), slo_p95_s=1.0)
    pipe = derive_pipeline_policies(WorkflowDAG.single(flat_stage()),
                                    slo_p95_s=1.0)
    assert pipe.ladder_size == base.ladder_size
    for a, b in zip(pipe.policies, base.policies):
        assert a.upscale_threshold == b.upscale_threshold
        assert a.downscale_threshold == b.downscale_threshold
        assert a.queuing_slack_s == b.queuing_slack
        assert a.stage_indices == (b.index,)
        assert a.stage_weights == (1.0,)
    assert pipe.slo_p95_s == base.slo_p95_s


def test_greedy_rung_walk_shape_and_monotonicity():
    """Default ladder: sum_j (K_j - 1) + 1 rungs, strictly non-decreasing
    end-to-end mean, all-fastest first, all-most-accurate last."""
    dag = WorkflowDAG.tandem([
        StageSpec(name="a", mean_s=(0.02, 0.05), accuracy=(0.9, 0.95)),
        StageSpec(name="b", mean_s=(0.05, 0.09, 0.20),
                  accuracy=(0.7, 0.8, 0.9)),
    ])
    table = derive_pipeline_policies(dag, slo_p95_s=5.0)
    assert table.ladder_size == (2 - 1) + (3 - 1) + 1
    assert table.policies[0].stage_indices == (0, 0)
    assert table.policies[-1].stage_indices == (1, 2)
    means = [p.mean_latency_s for p in table.policies]
    assert means == sorted(means)
    accs = [p.accuracy for p in table.policies]
    assert accs == sorted(accs)
    # accuracy is the product of the stage factors
    assert table.policies[-1].accuracy == pytest.approx(0.95 * 0.9)


def test_pipeline_ladder_excludes_infeasible_and_orders_rungs():
    dag = WorkflowDAG.tandem([
        StageSpec(name="a", mean_s=(0.1, 0.4), p95_s=(0.15, 0.6)),
        StageSpec(name="b", mean_s=(0.1, 0.4), p95_s=(0.15, 0.6)),
    ])
    table = derive_pipeline_policies(dag, slo_p95_s=0.6,
                                     rungs=[(0, 0), (1, 1)])
    assert table.ladder_size == 1          # (1,1) cannot meet 0.6 s p95
    assert table.excluded == ((1, 1),)
    with pytest.raises(ValueError, match="strictly increasing"):
        derive_pipeline_policies(dag, slo_p95_s=2.0,
                                 rungs=[(1, 1), (0, 0)])


def test_bottleneck_thresholds_and_weights():
    """N_up = floor(c_b * Delta / s_b) at the slowest-drain stage; the
    stage weights are drain times relative to the bottleneck's."""
    dag = WorkflowDAG.tandem([
        StageSpec(name="a", mean_s=(0.06,), p95_s=(0.09,), num_servers=2),
        StageSpec(name="b", mean_s=(0.10,), p95_s=(0.15,)),
    ])
    table = derive_pipeline_policies(dag, slo_p95_s=1.0, rungs=[(0, 0)])
    pol = table.policies[0]
    assert pol.bottleneck_stage == 1       # 0.10/1 > 0.06/2
    delta = 1.0 - pol.p95_latency_s
    assert pol.upscale_threshold == int(math.floor(delta / 0.10))
    assert pol.stage_weights == pytest.approx((0.03 / 0.10, 1.0))
    assert pol.downscale_threshold is None  # last rung


def test_observe_stages_weighted_collapse_matches_scalar_observe():
    """observe_stages(depths) must decide exactly like observe(N_eff) with
    N_eff = floor(sum N_j w_j); an AQM table (no weights) falls back to
    the plain sum."""
    dag = WorkflowDAG.tandem([
        StageSpec(name="a", mean_s=(0.05, 0.1), p95_s=(0.08, 0.15)),
        StageSpec(name="b", mean_s=(0.10, 0.2), p95_s=(0.15, 0.3)),
    ])
    table = derive_pipeline_policies(
        dag, slo_p95_s=1.0, rungs=[(0, 0), (1, 1)],
        hysteresis=HysteresisSpec(downscale_cooldown_s=0.0))
    a, b = ElasticoController(table), ElasticoController(table)
    rng = random.Random(0)
    for i in range(200):
        depths = [rng.randint(0, 12), rng.randint(0, 12)]
        w = b.table.policy(b.current_index).stage_weights
        eff = int(math.floor(sum(n * wj for n, wj in zip(depths, w)) + 1e-9))
        ev_a = a.observe_stages(depths, 0.1 * i)
        ev_b = b.observe(eff, 0.1 * i)
        assert (ev_a is None) == (ev_b is None)
        assert a.current_index == b.current_index

    # AQM fallback: no stage_weights -> plain sum (degenerate DAG parity)
    aqm = derive_policies(ladder_front(), slo_p95_s=1.0)
    c, d = ElasticoController(aqm), ElasticoController(aqm)
    for i in range(50):
        n = rng.randint(0, 15)
        ev_c = c.observe_stages([n], 0.1 * i)
        ev_d = d.observe(n, 0.1 * i)
        assert (ev_c is None) == (ev_d is None)
        assert c.current_index == d.current_index
    with pytest.raises(ValueError, match="stage depth"):
        c.observe_stages([], 0.0)
    with pytest.raises(ValueError, match="stage weights"):
        a.observe_stages([1, 2, 3], 999.0)


def test_set_active_index_validation_and_switch_latency():
    s = Scheduler(static_index=0, num_configs=3, switch_latency_s=0.01,
                  record_initial_config=True)
    s.set_active_index(0, 1.0)            # no-op: unchanged index
    assert s.config_timeline == [(0.0, 0)]
    s.set_active_index(2, 1.0)
    assert s.config_timeline == [(0.0, 0), (1.0, 2)]
    with pytest.raises(IndexError, match="out of range"):
        s.set_active_index(3, 2.0)
    ctl = Scheduler(controller=ElasticoController(
        derive_policies(ladder_front(), slo_p95_s=1.0)))
    with pytest.raises(ValueError, match="controller"):
        ctl.set_active_index(0, 0.0)
    pinned = Scheduler(num_workers=2, assignment=[0, 1], num_configs=2)
    with pytest.raises(ValueError, match="assignment"):
        pinned.set_active_index(1, 0.0)


# --------------------------------------------------------------------------
# 6. pipeline switching beats the statics (miniature of dag_bench)
# --------------------------------------------------------------------------


def test_pipeline_switching_beats_static_baselines():
    """2-stage tandem under a 4x spike: the pipeline controller must beat
    static-accurate on SLO compliance and static-fast on accuracy — the
    dag_bench acceptance criterion, in-process and tier-1 sized."""
    dag = WorkflowDAG.tandem([
        StageSpec(name="a", mean_s=(0.05, 0.12), p95_s=(0.07, 0.17)),
        StageSpec(name="b", mean_s=(0.05, 0.12), p95_s=(0.07, 0.17),
                  accuracy=(0.70, 0.90)),
    ])
    table = derive_pipeline_policies(dag, slo_p95_s=1.0,
                                     rungs=[(0, 0), (1, 1)])
    arr = generate_arrivals(spike_pattern(3.0, factor=4.0), 120.0, seed=1)

    def serve(controller, rung=0):
        sim = DagSimulator(dag, controller=controller, static_rung=rung,
                           rungs=[p.stage_indices for p in table.policies],
                           seed=4)
        return sim.run(arr, 120.0)

    dyn = serve(ElasticoController(table))
    fast = serve(None, rung=0)
    slow = serve(None, rung=1)
    assert dyn.slo_compliance(1.0) > slow.slo_compliance(1.0)
    assert dyn.mean_pipeline_accuracy() > fast.mean_pipeline_accuracy()
    assert dyn.switch_events
    # statics serve every request at the pinned rung's accuracy product
    assert fast.mean_pipeline_accuracy() == pytest.approx(0.70)
    assert slow.mean_pipeline_accuracy() == pytest.approx(0.90)


def test_dag_simulator_configuration_errors():
    dag = WorkflowDAG.tandem([StageSpec(name="a", mean_s=(0.1,)),
                              StageSpec(name="b", mean_s=(0.1,))])
    with pytest.raises(ValueError, match="controller-.*free|controller"):
        DagSimulator(dag, controller=ElasticoController(
            derive_policies(ladder_front(), slo_p95_s=1.0)),
            static_stage_indices=(0, 0)).run([0.0], 1.0)
    with pytest.raises(ValueError, match="static_rung"):
        DagSimulator(dag, static_rung=5).run([0.0], 1.0)
    with pytest.raises(ValueError, match="pipeline rungs"):
        DagSimulator(dag, controller=ElasticoController(
            derive_policies(ladder_front(), slo_p95_s=1.0))).run([0.0], 1.0)
    with pytest.raises(ValueError, match="one config index per stage"):
        DagSimulator(dag, static_stage_indices=(0,)).run([0.0], 1.0)


# --------------------------------------------------------------------------
# 7. chained recursions: chained_lindley, sweep_pipeline, replay_dag
# --------------------------------------------------------------------------


def test_chained_lindley_hand_computed_tandem():
    A = np.array([0.0, 1.0, 1.5])
    S1 = np.array([1.0, 1.0, 1.0])
    S2 = np.array([0.5, 0.5, 0.5])
    comp = chained_lindley(A, [S1, S2])
    np.testing.assert_allclose(comp[0], [1.0, 2.0, 3.0])
    np.testing.assert_allclose(comp[1], [1.5, 2.5, 3.5])
    assert comp.shape == (2, 3)


def test_chained_lindley_unsorted_arrivals_fifo():
    """Arrivals given out of order are served FIFO-by-arrival-time, with
    results scattered back to the original positions."""
    A = np.array([2.0, 0.0, 1.0])
    S = np.array([1.5, 1.5, 1.5])           # consumed in dispatch order
    comp = chained_lindley(A, [S])
    np.testing.assert_allclose(comp[0], [4.5, 1.5, 3.0])


def test_chained_lindley_multi_server_matches_brute_kw():
    rng = np.random.default_rng(3)
    A = np.sort(rng.uniform(0.0, 20.0, size=60))
    S = rng.lognormal(-1.5, 0.5, size=60)
    got = chained_lindley(A, [S], num_servers=[2])[0]
    free = [0.0, 0.0]
    want = np.empty(60)
    for i in range(60):
        start = max(A[i], free[0])
        want[i] = start + S[i]
        free[0] = want[i]
        free.sort()
    np.testing.assert_array_equal(got, want)


def test_sweep_pipeline_model_agreement_at_moderate_load():
    """The chained-recursion grid agrees with the queueing-network
    prediction to ~10% at low-to-moderate utilization (the regime the
    decomposition approximation is built for)."""
    dag = WorkflowDAG.tandem([
        StageSpec(name="a", mean_s=(0.04,), p95_s=(0.06,)),
        StageSpec(name="b", mean_s=(0.06,), p95_s=(0.09,)),
    ])
    sweep = sweep_pipeline(dag, [(0, 0)], arrival_rates_qps=[4.0, 8.0],
                           duration_s=400.0, replications=4, seed=0)
    assert sweep.num_requests > 0
    assert sweep.sojourn_model_error() < 0.10
    # grids are (K, L)
    assert len(sweep.mean_latency_s) == 1
    assert len(sweep.mean_latency_s[0]) == 2
    # sojourn grows with load
    assert sweep.mean_latency_s[0][1] > sweep.mean_latency_s[0][0]


def test_planner_plan_and_validate_pipeline():
    """Planner.plan_pipeline wraps derive_pipeline_policies with the
    Planner's slack/hysteresis; validate_pipeline defaults its load grid
    to fractions of the fastest rung's bottleneck drain rate."""
    dag = WorkflowDAG.tandem([
        StageSpec(name="a", mean_s=(0.03, 0.06), p95_s=(0.05, 0.09)),
        StageSpec(name="b", mean_s=(0.05, 0.10), p95_s=(0.08, 0.15),
                  accuracy=(0.8, 0.9)),
    ])
    planner = Planner(profiler=lambda c, n: [0.1] * n)
    plan = planner.plan_pipeline(dag, slo_p95_s=1.0)
    assert isinstance(plan, PipelinePlan)
    assert plan.table.ladder_size >= 2
    assert "a -> b" in plan.describe()

    # fractions of the FAST rung's capacity (20 qps at stage b); keep the
    # slowest rung (10 qps capacity) below saturation so every predicted
    # sojourn stays finite
    val = planner.validate_pipeline(plan, load_fractions=(0.2, 0.4),
                                    duration_s=60.0, replications=2, seed=1)
    cap = 1.0 / 0.05                        # fastest rung bottleneck: stage b
    assert val.arrival_rates_qps == pytest.approx((0.2 * cap, 0.4 * cap))
    assert val.replications == 2
    assert len(val.slo_compliance) == plan.table.ladder_size
    assert all(math.isfinite(p) for row in val.predicted_sojourn_s
               for p in row)
    assert val.sojourn_model_error() < 0.5

    with pytest.raises(ValueError, match="excluded"):
        planner.plan_pipeline(dag, slo_p95_s=0.01)


def test_replay_dag_streaming_tandem_consistency():
    """Streamed tandem replay: per-stage sojourns sum exactly to the
    end-to-end mean (the chaining identity), waits likewise, and the
    whole run stays on the chained closed-form engine."""
    trace = diurnal_trace(60.0, amplitude=0.5, duration_s=600.0, seed=7)
    stats = replay_dag(trace, [0.004, 0.006], [0.006, 0.009],
                       slo_s=0.5, seed=3)
    assert len(stats.stages) == 2
    e2e = stats.end_to_end
    assert e2e.engine == "chained_closed_form"
    assert e2e.num_requests == stats.stages[0].num_requests > 0
    assert e2e.mean_latency_s == pytest.approx(
        sum(s.mean_latency_s for s in stats.stages), rel=1e-12)
    assert e2e.mean_wait_s == pytest.approx(
        sum(s.mean_wait_s for s in stats.stages), rel=1e-12)
    assert 0.0 <= e2e.slo_compliance <= 1.0
    assert e2e.slo_s == 0.5
    with pytest.raises(ValueError, match="positive"):
        replay_dag(trace, [0.004, -1.0])


# --------------------------------------------------------------------------
# 8. DAG construction and validation
# --------------------------------------------------------------------------


def test_workflow_dag_validation_errors():
    a = StageSpec(name="a", mean_s=(0.1,))
    b = StageSpec(name="b", mean_s=(0.1,))
    c = StageSpec(name="c", mean_s=(0.1,))
    with pytest.raises(ValueError, match="at least one stage"):
        WorkflowDAG(stages=())
    with pytest.raises(ValueError, match="duplicate stage names"):
        WorkflowDAG(stages=(a, StageSpec(name="a", mean_s=(0.2,))),
                    edges=((0, 1),))
    with pytest.raises(ValueError, match="out of range"):
        WorkflowDAG(stages=(a, b), edges=((0, 2),))
    with pytest.raises(ValueError, match="self-loop"):
        WorkflowDAG(stages=(a, b), edges=((0, 0), (0, 1)))
    with pytest.raises(ValueError, match="duplicate edge"):
        WorkflowDAG(stages=(a, b), edges=((0, 1), (0, 1)))
    with pytest.raises(ValueError, match="cycle"):
        WorkflowDAG(stages=(a, b), edges=((0, 1), (1, 0)))
    with pytest.raises(ValueError, match="exactly one sink"):
        WorkflowDAG(stages=(a, b, c), edges=((0, 1), (0, 2)))
    with pytest.raises(ValueError, match="two branches"):
        WorkflowDAG.fork_join([a], b)


def test_stage_spec_validation_errors():
    with pytest.raises(ValueError, match="name"):
        StageSpec(name="", mean_s=(0.1,))
    with pytest.raises(ValueError, match="empty config ladder"):
        StageSpec(name="s", mean_s=())
    with pytest.raises(ValueError, match="positive"):
        StageSpec(name="s", mean_s=(0.0,))
    with pytest.raises(ValueError, match="p95 ladder"):
        StageSpec(name="s", mean_s=(0.1, 0.2), p95_s=(0.15,))
    with pytest.raises(ValueError, match="accuracy ladder"):
        StageSpec(name="s", mean_s=(0.1,), accuracy=(0.9, 0.8))
    with pytest.raises(ValueError, match="num_servers"):
        StageSpec(name="s", mean_s=(0.1,), num_servers=0)
    with pytest.raises(ValueError, match="max_queue_depth"):
        StageSpec(name="s", mean_s=(0.1,), max_queue_depth=0)


def test_topology_helpers():
    dag = WorkflowDAG.fork_join(
        [StageSpec(name="a", mean_s=(0.1,)), StageSpec(name="b", mean_s=(0.1,))],
        StageSpec(name="j", mean_s=(0.1,)),
        tail=[StageSpec(name="t", mean_s=(0.1,))])
    assert dag.sources() == (0, 1)
    assert dag.sink() == 3
    assert dag.predecessors(2) == (0, 1)
    assert dag.successors(2) == (3,)
    assert not dag.is_tandem()
    assert dag.topological_order() == (0, 1, 2, 3)
    assert dag.stage_index("t") == 3
    with pytest.raises(KeyError):
        dag.stage_index("nope")
    chain = WorkflowDAG.tandem([StageSpec(name="x", mean_s=(0.1,)),
                                StageSpec(name="y", mean_s=(0.1,))])
    assert chain.is_tandem()
    with pytest.raises(IndexError, match="out of range"):
        chain.validate_stage_indices((0, 5))


def test_pipeline_service_profile_single_stage_passthrough():
    """One stage: the profile is the stage's own (mean, p95) unchanged —
    the special case that makes the degenerate ladder collapse exact."""
    dag = WorkflowDAG.single(flat_stage())
    for k in range(3):
        assert pipeline_service_profile(dag, (k,)) == (MEANS[k], P95S[k])
    # multi-stage tandem means add
    two = WorkflowDAG.tandem([flat_stage(), StageSpec(name="t",
                                                      mean_s=tuple(MEANS),
                                                      p95_s=tuple(P95S))])
    mean, p95 = pipeline_service_profile(two, (1, 1))
    assert mean == pytest.approx(2 * MEANS[1])
    assert p95 > mean
