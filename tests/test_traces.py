"""Chunked trace generation + streaming replay (repro/serving/traces.py).

The contract:

1. **Traces are values**: a trace is its parameter tuple — same seed and
   shape give byte-identical chunk streams and the same content
   fingerprint; chunks come out sorted, in-range, and with the Poisson
   count the rate integral predicts.
2. **Chunk engines carry exact state**: splitting a workload across chunk
   boundaries (the whole point of streaming) reproduces the unsplit
   recursion — bit-for-bit for the sequential engines, allclose for the
   reassociated closed form — and the closed form matches a naive
   per-request Lindley loop.
3. **Replay is pure per lane**: a ladder lane's service stream is keyed by
   its config fingerprint, not its position, so replaying a config alone
   equals replaying it inside any mix.
4. **The quantile sketch is bounded**: ``quantile(q)`` brackets the exact
   ``ceil(q n)``-rank order statistic from above by at most one bin width,
   through any number of range doublings.
5. **Memory stays O(chunk)**: a 1e7-request replay allocates a small
   constant multiple of the chunk size, never the full trace (the
   regression test pins the peak).
"""

import tracemalloc

import numpy as np
import pytest

from repro.serving import traces as tr
from repro.serving.fastsim import jax_available, jax_unavailable_reason
from repro.serving.traces import (
    StreamingQuantile,
    bursty_mmpp_trace,
    diurnal_trace,
    flash_crowd_trace,
    replay_mix,
    replay_trace,
)

needs_jax = pytest.mark.skipif(
    not jax_available(),
    reason=f"jax not importable: {jax_unavailable_reason()}")


def _all_arrivals(trace):
    chunks = list(trace.chunks())
    return np.concatenate(chunks) if chunks else np.empty(0)


# --------------------------------------------------------------------------
# 1. trace generation
# --------------------------------------------------------------------------


@pytest.mark.parametrize("make", [
    lambda seed: diurnal_trace(40.0, duration_s=3600.0, seed=seed,
                               window_s=300.0),
    lambda seed: flash_crowd_trace(20.0, peak_factor=5.0,
                                   crowd_start_s=600.0, ramp_s=30.0,
                                   hold_s=120.0, duration_s=1800.0,
                                   seed=seed, window_s=300.0),
    lambda seed: bursty_mmpp_trace(25.0, burst_factor=3.0,
                                   duration_s=1800.0, seed=seed,
                                   window_s=300.0),
])
def test_trace_deterministic_sorted_in_range(make):
    t1, t2, t_other = make(5), make(5), make(6)
    a1, a2, a3 = _all_arrivals(t1), _all_arrivals(t2), _all_arrivals(t_other)
    np.testing.assert_array_equal(a1, a2)
    assert t1.fingerprint == t2.fingerprint
    assert t1.fingerprint != t_other.fingerprint
    assert a1.size != a3.size or not np.array_equal(a1, a3)
    assert np.all(np.diff(a1) >= 0.0)
    assert a1.size == 0 or (a1[0] >= 0.0 and a1[-1] < t1.duration_s)


def test_diurnal_count_matches_rate_integral():
    """The sinusoid integrates to base_qps x duration over whole periods;
    the thinned-Poisson count must sit within 5 sigma of it."""
    base, dur = 50.0, 4 * 86400.0
    trace = diurnal_trace(base, amplitude=0.8, duration_s=dur, seed=3)
    n = sum(c.size for c in trace.chunks())
    expected = base * dur
    assert abs(n - expected) < 5.0 * np.sqrt(expected)


def test_mmpp_mean_rate_between_base_and_burst():
    base, factor, dur = 30.0, 4.0, 6 * 3600.0
    trace = bursty_mmpp_trace(base, burst_factor=factor, duration_s=dur,
                              seed=9)
    n = sum(c.size for c in trace.chunks())
    assert base * dur * 0.8 < n < base * factor * dur


def test_window_schedule_is_part_of_trace_identity():
    a = diurnal_trace(40.0, duration_s=3600.0, seed=1, window_s=300.0)
    b = diurnal_trace(40.0, duration_s=3600.0, seed=1, window_s=600.0)
    assert a.fingerprint != b.fingerprint


# --------------------------------------------------------------------------
# 2. chunk engines: carried state and oracles
# --------------------------------------------------------------------------


def _rand_workload(seed, n=400, K=3):
    rng = np.random.default_rng(seed)
    A = np.sort(rng.uniform(0.0, n / 8.0, size=n))
    S = rng.lognormal(mean=-2.0, sigma=0.6, size=(n, K))
    return A, S


def test_closed_form_matches_sequential_lindley():
    A, S = _rand_workload(0)
    comp0 = np.array([0.0, 0.7, 2.5])
    waits, lats, carry = tr._chunk_closed_form(A, S, comp0.copy())
    comp = comp0.copy()
    for i in range(A.size):
        start = np.maximum(A[i], comp)
        comp = start + S[i]
        np.testing.assert_allclose(start - A[i], waits[i], rtol=1e-12,
                                   atol=1e-12)
        np.testing.assert_allclose(comp - A[i], lats[i], rtol=1e-12)
    np.testing.assert_allclose(comp, carry, rtol=1e-12)


def test_closed_form_chunk_split_invariance():
    A, S = _rand_workload(1)
    comp0 = np.zeros(S.shape[1])
    w_full, l_full, c_full = tr._chunk_closed_form(A, S, comp0.copy())
    cut = 157
    w1, l1, mid = tr._chunk_closed_form(A[:cut], S[:cut], comp0.copy())
    w2, l2, c_split = tr._chunk_closed_form(A[cut:], S[cut:], mid)
    np.testing.assert_allclose(np.vstack([w1, w2]), w_full, atol=1e-12)
    np.testing.assert_allclose(np.vstack([l1, l2]), l_full, rtol=1e-12)
    np.testing.assert_allclose(c_split, c_full, rtol=1e-12)


def test_loop_kw_chunk_split_bit_exact():
    """The c > 1 loop carries the sorted workload matrix in place: chunk
    boundaries don't even change the op order, so splits are bit-exact."""
    A, S = _rand_workload(2, n=300)
    c = 3
    F_full = np.zeros((S.shape[1], c))
    w_full, l_full = tr._chunk_loop_kw(A, S, F_full)
    F_split = np.zeros((S.shape[1], c))
    cut = 101
    w1, l1 = tr._chunk_loop_kw(A[:cut], S[:cut], F_split)
    w2, l2 = tr._chunk_loop_kw(A[cut:], S[cut:], F_split)
    np.testing.assert_array_equal(np.vstack([w1, w2]), w_full)
    np.testing.assert_array_equal(np.vstack([l1, l2]), l_full)
    np.testing.assert_array_equal(F_split, F_full)


def test_loop_kw_c1_reduces_to_lindley():
    A, S = _rand_workload(3, n=200, K=2)
    F = np.zeros((2, 1))
    waits, lats = tr._chunk_loop_kw(A, S, F)
    w_ref, l_ref, _ = tr._chunk_closed_form(A, S, np.zeros(2))
    np.testing.assert_allclose(waits, w_ref, atol=1e-12)
    np.testing.assert_allclose(lats, l_ref, rtol=1e-12)


# --------------------------------------------------------------------------
# 3. replay purity and engine parity
# --------------------------------------------------------------------------


def _small_trace(seed=7):
    return diurnal_trace(30.0, duration_s=1800.0, seed=seed, window_s=300.0)


MEANS = [0.02, 0.05, 0.11]
P95S = [0.028, 0.07, 0.15]


def test_replay_deterministic():
    a = replay_mix(_small_trace(), MEANS, P95S, slo_s=0.5, seed=3)
    b = replay_mix(_small_trace(), MEANS, P95S, slo_s=0.5, seed=3)
    assert a == b


def test_replay_lane_independence():
    """A lane's service stream is keyed (seed, config, trace), not by its
    position in the ladder: replaying config k alone reproduces its mix
    statistics: compliance, max and count exactly; the means to numpy's
    pairwise-summation blocking noise (a (n, K) column and a (n, 1) array
    sum in different groupings); the p95s to their sketch resolutions (the
    sketch range depends on the ladder's max mean)."""
    trace = _small_trace()
    mix = replay_mix(trace, MEANS, P95S, slo_s=0.5, seed=3)
    for k, (m, p) in enumerate(zip(MEANS, P95S)):
        solo = replay_trace(trace, m, p, slo_s=0.5, seed=3)
        np.testing.assert_allclose(solo.mean_wait_s, mix[k].mean_wait_s,
                                   rtol=1e-12)
        np.testing.assert_allclose(solo.mean_latency_s,
                                   mix[k].mean_latency_s, rtol=1e-12)
        assert solo.slo_compliance == mix[k].slo_compliance
        assert solo.max_latency_s == mix[k].max_latency_s
        assert abs(solo.p95_latency_s - mix[k].p95_latency_s) <= (
            solo.p95_resolution_s + mix[k].p95_resolution_s)


def test_resolve_replay_engine_mapping(monkeypatch):
    resolve = tr._resolve_replay_engine
    assert resolve("auto", 1) == "closed_form"
    assert resolve("numpy", 1) == "closed_form"
    assert resolve("numpy", 4) == "loop"
    with pytest.raises(ValueError, match="unknown backend"):
        resolve("cuda", 1)
    if jax_available():
        assert resolve("jax", 1) == "jax"
        assert resolve("auto", 4) == "jax"
        with pytest.raises(ValueError, match="num_servers"):
            resolve("jax", 64)
    monkeypatch.setattr(tr, "jax_available", lambda: False)
    monkeypatch.setattr(tr, "jax_unavailable_reason",
                        lambda: "No module named 'jax'")
    assert resolve("auto", 4) == "loop"
    with pytest.raises(RuntimeError, match="not importable"):
        resolve("jax", 1)


@needs_jax
def test_replay_jax_engine_matches_numpy_c1():
    """Explicit jax replay vs the closed form: same host-drawn services,
    sequential scan vs reassociated prefix — tight allclose."""
    trace = _small_trace()
    np_stats = replay_trace(trace, 0.02, 0.028, slo_s=0.5, seed=1)
    jx_stats = replay_trace(trace, 0.02, 0.028, slo_s=0.5, seed=1,
                            backend="jax")
    assert np_stats.engine == "closed_form" and jx_stats.engine == "jax"
    assert np_stats.num_requests == jx_stats.num_requests
    np.testing.assert_allclose(np_stats.mean_wait_s, jx_stats.mean_wait_s,
                               rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(np_stats.mean_latency_s,
                               jx_stats.mean_latency_s, rtol=1e-9)
    np.testing.assert_allclose(np_stats.max_latency_s,
                               jx_stats.max_latency_s, rtol=1e-9)
    assert abs(np_stats.p95_latency_s - jx_stats.p95_latency_s) <= (
        np_stats.p95_resolution_s + jx_stats.p95_resolution_s)


@needs_jax
def test_replay_jax_engine_matches_loop_multiserver():
    """c = 3: the jitted comparator scan against the numpy KW loop.  Same
    op order on the same draws — the multiserver stats agree to float
    noise."""
    trace = _small_trace(seed=8)
    np_stats = replay_trace(trace, 0.08, 0.11, num_servers=3, slo_s=0.5,
                            seed=2, backend="numpy")
    jx_stats = replay_trace(trace, 0.08, 0.11, num_servers=3, slo_s=0.5,
                            seed=2, backend="jax")
    assert np_stats.engine == "loop" and jx_stats.engine == "jax"
    assert np_stats.num_requests == jx_stats.num_requests
    np.testing.assert_allclose(np_stats.mean_wait_s, jx_stats.mean_wait_s,
                               rtol=1e-12, atol=1e-15)
    np.testing.assert_allclose(np_stats.mean_latency_s,
                               jx_stats.mean_latency_s, rtol=1e-12)
    assert np_stats.slo_compliance == jx_stats.slo_compliance
    np.testing.assert_allclose(np_stats.max_latency_s,
                               jx_stats.max_latency_s, rtol=1e-12)


def test_replay_validates_inputs():
    trace = _small_trace()
    with pytest.raises(ValueError, match="non-empty"):
        replay_mix(trace, [])
    with pytest.raises(ValueError, match="positive"):
        replay_mix(trace, [0.0])
    with pytest.raises(ValueError, match="match"):
        replay_mix(trace, [0.1, 0.2], [0.15])
    with pytest.raises(ValueError, match="num_servers"):
        replay_mix(trace, [0.1], num_servers=0)


# --------------------------------------------------------------------------
# 4. streaming quantile sketch
# --------------------------------------------------------------------------


@pytest.mark.parametrize("q", [0.5, 0.95, 0.99])
def test_streaming_quantile_brackets_order_statistic(q):
    """quantile(q) returns the upper edge of the bin holding the
    ceil(q n)-rank order statistic: stat <= sketch <= stat + resolution."""
    rng = np.random.default_rng(11)
    values = rng.lognormal(mean=-1.0, sigma=0.9, size=20_000)
    sk = StreamingQuantile(num_bins=4096, initial_max=1.0)
    for lo in range(0, values.size, 3000):
        sk.update(values[lo:lo + 3000])
    exact = np.sort(values)[int(np.ceil(q * values.size)) - 1]
    got = sk.quantile(q)
    assert exact <= got <= exact + sk.resolution + 1e-12


def test_streaming_quantile_survives_range_doublings():
    """Values far past initial_max force repeated pair-merge rebinnings;
    the bracket bound must hold through all of them."""
    rng = np.random.default_rng(12)
    values = np.concatenate([
        rng.uniform(0.0, 1.0, size=5000),
        rng.uniform(50.0, 400.0, size=5000),   # >> initial_max=1.0
    ])
    rng.shuffle(values)
    sk = StreamingQuantile(num_bins=2048, initial_max=1.0)
    sk.update(values)
    assert sk.count == values.size
    for q in (0.25, 0.9, 0.99):
        exact = np.sort(values)[int(np.ceil(q * values.size)) - 1]
        got = sk.quantile(q)
        assert exact <= got <= exact + sk.resolution + 1e-9


# --------------------------------------------------------------------------
# 5. memory: O(chunk), never O(trace)
# --------------------------------------------------------------------------


def test_replay_1e7_requests_peak_allocation_bounded():
    """Regression pin for the streaming claim: a 1e7-request diurnal cell
    replays with peak traced allocation well under the ~80 MB a single
    materialized float64 arrival array would need (measured ~35 MB:
    a few chunk-sized arrays).  If someone accidentally materializes the
    trace, this trips at 10x."""
    base = 2500.0
    n_target = 1.0e7
    trace = diurnal_trace(base, amplitude=0.6,
                          duration_s=n_target / base, seed=21)
    tracemalloc.start()
    try:
        stats = replay_trace(trace, 0.9 / base, 1.25 / base, slo_s=0.02,
                             seed=4)
        _, peak = tracemalloc.get_traced_memory()
    finally:
        tracemalloc.stop()
    assert stats.num_requests >= 1e7
    assert stats.engine == "closed_form"
    assert peak < 150 * 1024 * 1024, f"peak={peak / 1e6:.1f} MB"
