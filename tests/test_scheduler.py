"""The shared scheduling core: invariants, work stealing, mix-aware
admission, and the threaded/simulated drivers exercising one policy.

Property tests run through the ``tests/proptest.py`` hypothesis shim and
pin the scheduler's conservation guarantees: no admitted request is ever
lost or double-dispatched, and a steal never violates assignment pinning
(stolen work runs under the thief's own rung)."""

import random
import time

import pytest

from proptest import given, settings, st

from repro.core.aqm import (
    HysteresisSpec,
    derive_mix_policies,
    derive_policies,
    steal_threshold,
)
from repro.core.elastico import ElasticoController, ElasticoMixController
from repro.serving.engine import ServingEngine, replay_workload
from repro.serving.executor import WorkflowExecutor
from repro.serving.faults import FaultSchedule, Straggler, WorkerCrash
from repro.serving.scheduler import Scheduler
from repro.serving.simulator import (
    ServingSimulator,
    deterministic_sampler,
    lognormal_sampler_from_profile,
)
from repro.serving.workload import (
    Request,
    constant_rate,
    flash_crowd_pattern,
    generate_arrivals,
    sustained_overload_pattern,
)

from conftest import synthetic_point

MEANS = [0.10, 0.25, 0.45]
P95S = [0.14, 0.35, 0.63]
ACCS = [0.76, 0.82, 0.85]
SLO_S = 1.0


def ladder_front():
    return [
        synthetic_point(m, p, a, f"c{i}")
        for i, (m, p, a) in enumerate(zip(MEANS, P95S, ACCS))
    ]


# -- construction-time validation ----------------------------------------------


def test_scheduler_validation():
    with pytest.raises(ValueError):
        Scheduler(num_workers=0)
    with pytest.raises(ValueError):
        Scheduler(num_workers=1, queue_discipline="priority")
    with pytest.raises(ValueError):
        Scheduler(num_workers=2, steal=True)   # needs per-worker queues
    with pytest.raises(ValueError):
        Scheduler(num_workers=2, queue_discipline="per_worker",
                  batch_timeout_s=0.1)         # linger is shared-queue only
    with pytest.raises(ValueError):
        Scheduler(num_workers=2, queue_discipline="per_worker", steal=True,
                  steal_threshold=0)
    with pytest.raises(ValueError):
        Scheduler(num_workers=1, admission_reroute=True)  # needs controller+bound
    with pytest.raises(ValueError):
        Scheduler(num_workers=2, assignment=[0])          # wrong length
    with pytest.raises(IndexError):
        Scheduler(num_workers=2, assignment=[0, -1])
    with pytest.raises(IndexError):
        Scheduler(num_workers=2, assignment=[0, 5], num_configs=2)


# -- per-worker queues and stealing --------------------------------------------


def test_per_worker_round_robin_routing():
    s = Scheduler(num_workers=3, queue_discipline="per_worker")
    for i in range(7):
        s.offer(i, 0.0)
    assert s.backlog_depths() == [3, 2, 2]
    assert s.buffered() == 7


def test_steal_takes_deepest_backlog_under_thief_pin():
    """An idle worker with an empty backlog pulls from the globally deepest
    backlog — and serves the stolen request under its OWN pinned config."""
    s = Scheduler(num_workers=2, queue_discipline="per_worker", steal=True,
                  steal_threshold=1, assignment=[0, 1], num_configs=2)
    for i in range(6):            # round-robin: w0 <- 0,2,4 ; w1 <- 1,3,5
        s.offer(i, 0.0)
    first, _ = s.poll(0.0)
    assert [(d.worker_id, d.items[0], d.config_index) for d in first] == \
        [(0, 0, 0), (1, 1, 1)]
    for t in range(3):
        s.release(0, float(t))    # only the fast worker keeps freeing
        ds, _ = s.poll(float(t))
        assert len(ds) == 1 and ds[0].worker_id == 0
    # w0 drained its own 2, 4 first, then stole w1's head (3) — under pin 0
    stolen = ds[0]
    assert stolen.items == (3,)
    assert stolen.stolen
    assert stolen.config_index == 0          # thief's pin, not the victim's
    assert s.backlog_depths() == [0, 1]      # 5 still with its owner
    assert s.stolen_batches == 1


def test_steal_respects_threshold():
    s = Scheduler(num_workers=2, queue_discipline="per_worker", steal=True,
                  steal_threshold=3)
    s.offer(0, 0.0)               # w0's backlog
    s.offer(1, 0.0)               # w1's backlog
    ds, _ = s.poll(0.0)           # both serve their own
    s.release(0, 1.0)
    s.offer(2, 1.0)               # w0's backlog -> w0 takes it
    ds, _ = s.poll(1.0)
    assert [(d.worker_id, d.items[0]) for d in ds] == [(0, 2)]
    s.release(0, 2.0)
    s.offer(3, 2.0)               # w1's backlog: depth 1 < threshold 3
    ds, _ = s.poll(2.0)
    assert ds == []               # w0 idles rather than steal a shallow queue
    s.offer(5, 3.0)               # w0's own backlog: takes it normally
    ds, _ = s.poll(3.0)
    assert [(d.worker_id, d.items[0], d.stolen) for d in ds] == [(0, 5, False)]


# -- conservation properties (proptest shim) -----------------------------------


@given(st.integers(1, 5), st.integers(0, 2**16), st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_shared_scheduler_conserves_requests(c, seed, batch):
    """Simulator-driven conservation: every arrival completes exactly once,
    for any pool size / seed / batch cap."""
    arr = generate_arrivals(constant_rate(6.0), 15.0, seed=seed)
    out = ServingSimulator(
        deterministic_sampler(MEANS), static_index=0, seed=seed,
        num_servers=c, max_batch_size=batch,
    ).run(arr, 15.0)
    ids = [r.request_id for r in out.completed]
    assert len(ids) == len(arr)
    assert len(set(ids)) == len(ids)


@given(st.integers(2, 5), st.integers(0, 2**16), st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_stealing_scheduler_conserves_and_respects_pinning(c, seed, thr):
    """Per-worker queues with stealing: no request lost or double-served,
    and every request — stolen or not — runs under the config its *server*
    is pinned to (a steal moves work, never breaks pinning)."""
    assignment = [i % 3 for i in range(c)]
    arr = generate_arrivals(constant_rate(5.0), 15.0, seed=seed)
    out = ServingSimulator(
        lognormal_sampler_from_profile(MEANS, P95S),
        assignment=assignment, seed=seed, num_servers=c,
        queue_discipline="per_worker", steal=True, steal_threshold=thr,
    ).run(arr, 15.0)
    ids = [r.request_id for r in out.completed]
    assert len(ids) == len(arr)
    assert len(set(ids)) == len(ids)
    for r in out.completed:
        assert r.config_index == assignment[r.server_id]


@given(st.integers(1, 4), st.integers(0, 2**16), st.integers(1, 6))
@settings(max_examples=15, deadline=None)
def test_bounded_scheduler_accounts_every_offer(c, seed, depth):
    """With admission control: offered == completed + dropped, exactly."""
    arr = generate_arrivals(constant_rate(12.0), 10.0, seed=seed)
    out = ServingSimulator(
        deterministic_sampler(MEANS), static_index=2, seed=seed,
        num_servers=c, max_queue_depth=depth,
    ).run(arr, 10.0)
    assert out.offered == len(arr)
    assert len(out.completed) + out.dropped == out.offered
    ids = [r.request_id for r in out.completed]
    assert len(set(ids)) == len(ids)


def _random_fault_schedule(seed, c, horizon):
    """At most one crash window and one straggler per worker, derived
    deterministically from the seed (overlap-free by construction)."""
    rng = random.Random(seed)
    crashes, stragglers = [], []
    for w in range(c):
        if rng.random() < 0.6:
            t = rng.uniform(0.05, 0.6) * horizon
            recover = (t + rng.uniform(0.05, 0.35) * horizon
                       if rng.random() < 0.75 else None)
            crashes.append(WorkerCrash(time_s=t, worker_id=w,
                                       recover_s=recover))
        if rng.random() < 0.4:
            a = rng.uniform(0.0, 0.7) * horizon
            stragglers.append(Straggler(
                worker_id=w, start_s=a,
                end_s=a + rng.uniform(0.05, 0.25) * horizon,
                factor=rng.uniform(1.2, 3.0)))
    return FaultSchedule(crashes=tuple(crashes),
                         stragglers=tuple(stragglers))


@given(st.integers(1, 5), st.integers(0, 2**16), st.integers(0, 3),
       st.sampled_from([None, 0.5, 2.0]))
@settings(max_examples=15, deadline=None)
def test_faulty_scheduler_conserves_requests(c, seed, budget, timeout):
    """Fault-plane conservation: under random crash/recover windows,
    stragglers, retry budgets and request deadlines, every offered
    request is accounted exactly once — completed, dropped, failed, or
    stranded in_flight behind a dead pool; never lost, never duplicated."""
    arr = generate_arrivals(constant_rate(6.0), 15.0, seed=seed)
    out = ServingSimulator(
        deterministic_sampler(MEANS), static_index=1, seed=seed,
        num_servers=c, faults=_random_fault_schedule(seed, c, 15.0),
        retry_budget=budget, request_timeout_s=timeout,
    ).run(arr, 15.0)
    assert out.offered == len(arr)
    assert out.offered == len(out.completed) + out.dropped + out.failed \
        + out.in_flight
    ids = [r.request_id for r in out.completed]
    assert len(set(ids)) == len(ids)
    # no completion was served by a worker inside one of its down windows
    faults = _random_fault_schedule(seed, c, 15.0)
    for r in out.completed:
        for f in faults.crashes:
            if f.worker_id == r.server_id:
                t1 = f.recover_s if f.recover_s is not None else float("inf")
                assert not (f.time_s <= r.start_s < t1), (r, f)


# -- steal / re-route threshold derivation (core/aqm) --------------------------


def test_steal_threshold_slo_aware_values():
    front = ladder_front()
    # homogeneous all-fast: the worker itself drains floor(0.86/0.10) = 8
    # inside its slack — don't break locality before that.
    assert steal_threshold(front, (0, 0, 0, 0), slo_p95_s=SLO_S) == 8
    # a skewed mix drowns at its slowest rung: floor(0.37/0.45) = 0 -> 1.
    assert steal_threshold(front, (0, 0, 2, 2), slo_p95_s=SLO_S) == 1
    assert steal_threshold(front, (1,), slo_p95_s=SLO_S) == \
        int((SLO_S - P95S[1]) / MEANS[1])
    with pytest.raises(ValueError):
        steal_threshold(front, (), slo_p95_s=SLO_S)
    with pytest.raises(ValueError):
        steal_threshold(front, (0,), slo_p95_s=0.0)
    with pytest.raises(IndexError):
        steal_threshold(front, (7,), slo_p95_s=SLO_S)


def test_mix_table_emits_steal_and_reroute_thresholds():
    table = derive_mix_policies(ladder_front(), slo_p95_s=SLO_S,
                                num_servers=4)
    assert table.reroute_threshold == table.policies[0].upscale_threshold
    for mp in table.policies:
        assert mp.steal_threshold >= 1
        assert mp.steal_threshold == steal_threshold(
            ladder_front(), mp.assignment, slo_p95_s=SLO_S)
    # all-fast states tolerate the deepest local backlog before stealing
    assert table.policies[0].steal_threshold == \
        max(p.steal_threshold for p in table.policies)


def test_scheduler_uses_mix_state_steal_threshold():
    table = derive_mix_policies(ladder_front(), slo_p95_s=SLO_S,
                                num_servers=2)
    ctrl = ElasticoMixController(table)
    s = Scheduler(num_workers=2, queue_discipline="per_worker", steal=True,
                  controller=ctrl)
    # starts at the top (all-accurate) state; explicit param would override
    assert s.current_steal_threshold() == table.policies[-1].steal_threshold
    s2 = Scheduler(num_workers=2, queue_discipline="per_worker", steal=True,
                   controller=ElasticoMixController(table), steal_threshold=7)
    assert s2.current_steal_threshold() == 7


# -- mix-aware admission -------------------------------------------------------


def test_force_fastest_jumps_and_records():
    table = derive_policies(ladder_front(), slo_p95_s=SLO_S)
    ctrl = ElasticoController(table)     # starts most accurate
    ev = ctrl.force_fastest(9, 1.0)
    assert ev is not None
    assert ev.to_index == 0 and ev.direction == "faster"
    assert "admission reroute" in ev.reason
    assert ctrl.current_index == 0
    assert ctrl.events[-1] is ev
    assert ctrl.force_fastest(9, 2.0) is None   # already all-fast: drop
    with pytest.raises(ValueError):
        ctrl.force_fastest(-1, 3.0)


def test_admission_reroute_saves_goodput_under_flash_crowd():
    """Mix-aware admission: a tight bound clamps the observed depth below
    the mix thresholds, so a plain bounded pool gets stuck mid-ladder and
    drops for the whole crowd; re-routing to the all-fast state first
    converts most of those drops into served requests."""
    front = ladder_front()
    table = derive_mix_policies(front, slo_p95_s=SLO_S,
                                hysteresis=HysteresisSpec(downscale_cooldown_s=5.0),
                                num_servers=4)
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    arr = generate_arrivals(
        flash_crowd_pattern(3.0, peak_factor=15.0, crowd_start_s=40.0,
                            ramp_s=1.0, hold_s=25.0), 120.0, seed=1)
    outs = {}
    for name, reroute in [("bounded", False), ("reroute", True)]:
        outs[name] = ServingSimulator(
            sampler, controller=ElasticoMixController(table), seed=0,
            num_servers=4, max_queue_depth=8, admission_reroute=reroute,
        ).run(arr, 120.0)
    plain, rerouted = outs["bounded"], outs["reroute"]
    assert rerouted.rerouted > 0
    assert rerouted.dropped < plain.dropped * 0.5
    assert rerouted.goodput(SLO_S) > plain.goodput(SLO_S) + 0.1
    assert any("admission reroute" in e.reason for e in rerouted.switch_events)
    # conservation still holds with drops in play
    assert len(rerouted.completed) + rerouted.dropped == rerouted.offered


def test_admission_reroute_respects_table_cap():
    """Past the table's reroute_threshold even the all-fast mix cannot
    drain inside the SLO — the scheduler must drop, not re-route."""
    table = derive_mix_policies(ladder_front(), slo_p95_s=SLO_S,
                                num_servers=1)
    cap = table.reroute_threshold
    ctrl = ElasticoMixController(table)
    s = Scheduler(num_workers=1, max_queue_depth=cap + 1, controller=ctrl,
                  admission_reroute=True)
    for i in range(cap + 1):
        assert s.offer(i, 0.0).admitted
    # depth is now cap + 1 > cap: no re-route, hard drop
    adm = s.offer(999, 0.0)
    assert not adm.admitted and adm.event is None
    assert ctrl.current_index == table.ladder_size - 1   # never forced


# -- threaded drivers over the same core ---------------------------------------


def _sleepy(d):
    def fn(config, payload):
        time.sleep(d[config[1]])
        return payload
    return fn


def test_engine_steals_across_pinned_workers():
    """Threaded path: per-worker queues + stealing through the same core —
    the fast worker absorbs the slow worker's backlog, nothing is lost,
    and stolen requests run under the thief's pin."""
    executor = WorkflowExecutor(
        configs=[("cfg", 0), ("cfg", 1)],
        workflow_fn=_sleepy({0: 0.001, 1: 0.02}))
    engine = ServingEngine(executor, num_workers=2, assignment=[0, 1],
                           control_tick_s=0.01,
                           queue_discipline="per_worker", steal=True,
                           steal_threshold=1)
    engine.start()
    for i in range(60):
        engine.submit(Request(request_id=i, arrival_s=0.0))
    report = engine.drain_and_stop()
    assert sorted(r.request_id for r in report.records) == list(range(60))
    assert report.stolen_batches > 0
    for r in report.records:
        assert r.config_index == [0, 1][r.worker_id]
    # the fast worker served strictly more than its round-robin half
    assert report.served_per_worker[0] > 30


def test_worker_pool_rejects_conflicting_scheduler_args():
    """Policy knobs live on the scheduler: passing both an explicit
    scheduler and pool-level assignment/batching knobs must raise instead
    of silently ignoring the caller's configuration."""
    executor = WorkflowExecutor(configs=[("cfg", 0)],
                                workflow_fn=_sleepy({0: 0.001}))
    from repro.serving.executor import WorkerPool
    sched = Scheduler(num_workers=2)
    with pytest.raises(ValueError, match="owned by"):
        WorkerPool(executor, c=2, scheduler=sched, max_batch_size=8)
    with pytest.raises(ValueError, match="owned by"):
        WorkerPool(executor, c=2, scheduler=sched, assignment=[0, 0])
    with pytest.raises(ValueError):
        WorkerPool(executor, c=1, scheduler=sched)   # size mismatch


def test_replay_workload_c2_with_drops():
    """replay_workload against a bounded multi-worker engine: the
    admission-control invariant total == served + dropped must hold, with
    no request served twice (engine.py's replay path under c > 1)."""
    executor = WorkflowExecutor(configs=[("cfg", 0)],
                                workflow_fn=_sleepy({0: 0.01}))
    engine = ServingEngine(executor, num_workers=2, max_queue_depth=3,
                           control_tick_s=0.01)
    engine.start()
    # 500 qps offered vs 2 workers x 100 qps capacity + depth-3 buffer:
    # must drop regardless of sleep jitter (the old 200 qps trace sat
    # exactly at capacity, so drops depended on timer overshoot)
    arrivals = [i * 0.002 for i in range(150)]
    replay_workload(engine, arrivals, time_scale=1.0)
    report = engine.drain_and_stop()
    assert report.total_requests == 150
    assert report.total_requests == len(report.records) + report.dropped
    assert report.dropped > 0
    ids = [r.request_id for r in report.records]
    assert len(set(ids)) == len(ids)
    assert report.num_workers == 2
    assert report.goodput(10.0) <= report.slo_compliance(10.0)
