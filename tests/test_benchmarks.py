"""Tier-1 benchmark hygiene: every benchmarks/*.py module must import.

Benchmarks bit-rot silently — they only run when someone reproduces a
figure, so a refactor that renames a symbol they import can sit broken for
PRs at a time.  Importing every module (and checking the driver's registry
is complete) catches that class of rot at tier-1 cost.  Running every
benchmark stays out of tier-1; ``python -m benchmarks.run --smoke`` runs
each one at its smallest setting as the cheap execution gate — of which
the multi-server smoke (the serving substrate's acceptance sweep,
including the work-stealing setting) and the ``--check-docs`` gate are
cheap enough to execute here outright.
"""

import importlib
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
BENCH_DIR = REPO / "benchmarks"
MODULES = sorted(p.stem for p in BENCH_DIR.glob("*.py")
                 if p.stem != "__init__")


@pytest.fixture(autouse=True, scope="module")
def _benchmarks_on_path():
    """benchmarks/ is a top-level package next to src/; tier-1 runs with
    PYTHONPATH=src, so the repo root must be importable too."""
    added = False
    if str(REPO) not in sys.path:
        sys.path.insert(0, str(REPO))
        added = True
    yield
    if added:
        sys.path.remove(str(REPO))


def test_every_benchmark_module_is_listed():
    assert MODULES, "no benchmark modules found"
    assert "run" in MODULES and "multi_server_bench" in MODULES


@pytest.mark.parametrize("name", MODULES)
def test_benchmark_module_imports(name):
    mod = importlib.import_module(f"benchmarks.{name}")
    assert mod is not None


def test_driver_registry_covers_every_bench_module():
    """Every non-driver benchmark module must be wired into benchmarks.run's
    registry (a new bench that is never runnable from the driver is rot of
    another kind), and every registry entry must expose a callable run()."""
    run = importlib.import_module("benchmarks.run")
    registered = {m.__name__.rsplit(".", 1)[-1] for m in run.MODULES.values()}
    helpers = {"run", "common", "render_report"}
    assert registered == set(MODULES) - helpers
    for name, mod in run.MODULES.items():
        assert callable(run.BENCHES[name])
        smoke = getattr(mod, "run_smoke", None)
        if smoke is not None:
            assert callable(smoke)


def test_smoke_flag_is_wired():
    run = importlib.import_module("benchmarks.run")
    assert "--smoke" in run.__doc__
    # the smallest-setting entry points the smoke gate relies on
    msb = importlib.import_module("benchmarks.multi_server_bench")
    assert callable(msb.run_smoke)


def _run_gate(*args: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *args],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=300)


def test_multi_server_smoke_gate_exits_zero():
    """The CI smoke path must actually run: the multi-server smoke sweep
    (all four parts, including the work-stealing setting) exits 0 and its
    acceptance checks hold."""
    proc = _run_gate("--smoke", "multi_server")
    assert proc.returncode == 0, proc.stderr
    assert "multi_server," in proc.stdout
    assert "steal" in proc.stdout           # part 4 ran
    assert "FAILED" not in proc.stdout      # no acceptance check tripped


def test_trace_replay_smoke_gate_exits_zero():
    """The million-user replay pipeline at its ~1e5-request smoke setting:
    trace generation, streaming mix replay and the Planner validation all
    run end to end, on the streaming engines (no event-heap fallback) and
    with no acceptance marker tripped."""
    proc = _run_gate("--smoke", "trace_replay")
    assert proc.returncode == 0, proc.stderr
    assert "trace_replay," in proc.stdout
    assert "engine=closed_form" in proc.stdout
    assert "FAILED" not in proc.stdout


def test_dag_bench_smoke_gate_exits_zero():
    """The workflow-DAG pipeline at its smoke setting: the 3-stage RAG
    tandem's network-model validation, the pipeline-switching-vs-statics
    diurnal comparison, and the fork-join section all run end to end with
    the acceptance criterion (dynamic beats static-accurate on compliance
    and static-fast on accuracy) holding."""
    proc = _run_gate("--smoke", "dag_bench")
    assert proc.returncode == 0, proc.stderr
    assert "dag_bench," in proc.stdout
    assert "dyn_comp=" in proc.stdout
    assert "fj_penalty=" in proc.stdout     # fork-join section ran
    assert "FAILED" not in proc.stdout


def test_scrub_volatile_drops_wall_clock_keys():
    from benchmarks.common import VOLATILE_KEYS, scrub_volatile

    payload = {
        "metadata": {"timestamp_utc": "2026-01-01T00:00:00+00:00"},
        "section": {"requests": 10, "wall_s": 1.23, "rps": 8.1,
                    "rungs": [{"mean_s": 0.1, "wall_s": 0.5}]},
        "kept": 42,
    }
    out = scrub_volatile(payload)
    assert out == {"section": {"requests": 10, "rungs": [{"mean_s": 0.1}]},
                   "kept": 42}
    assert "timestamp_utc" in VOLATILE_KEYS and "metadata" in VOLATILE_KEYS


def test_stable_smoke_artifacts_are_idempotent(tmp_path, monkeypatch):
    """Rerunning a stable-saved smoke benchmark must reproduce the
    artifact byte-for-byte — the smoke gates rewrite these files on every
    test run, so any volatile key turns each `pytest` into a dirty
    working tree (the churn ISSUE 7 fixes)."""
    import benchmarks.common as common
    from benchmarks.trace_replay_bench import _run

    monkeypatch.setattr(common, "EXPERIMENTS_DIR", str(tmp_path))
    _run(target_requests=2e3, artifact="idem.json", stable=True)
    first = (tmp_path / "idem.json").read_bytes()
    _run(target_requests=2e3, artifact="idem.json", stable=True)
    assert (tmp_path / "idem.json").read_bytes() == first
    assert b"wall_s" not in first and b"timestamp_utc" not in first


def test_check_docs_gate_exits_zero():
    proc = _run_gate("--check-docs")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "docscheck: OK" in proc.stdout


def test_perf_gate_exits_zero():
    """The fast-path throughput guard: a fresh gate-sized fastsim_bench
    measurement must stay within 30% of the committed
    ``experiments/fastsim_bench.json`` baseline.  Keeps the vectorized
    engine from quietly rotting back toward event-heap speed."""
    proc = _run_gate("--perf-gate")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "perf-gate: OK" in proc.stdout
    assert "REGRESSION" not in proc.stdout


# ---------------------------------------------------------------------------
# benchmark-history telemetry (repro.tools.benchhist)


def _registry():
    run = importlib.import_module("benchmarks.run")
    return run.MODULES


def test_every_registered_benchmark_declares_gate_worthy_measurements():
    """Every module in the driver's registry must export a BENCH_SPEC with
    at least one smoke-eligible measurement — a benchmark whose speed
    claims never reach a trajectory cannot be regression-gated."""
    from repro.tools.benchhist import BenchmarkSpec

    for name, mod in _registry().items():
        spec = getattr(mod, "BENCH_SPEC", None)
        assert isinstance(spec, BenchmarkSpec), f"{name}: missing BENCH_SPEC"
        assert spec.specs_for("smoke"), (
            f"{name}: no smoke-eligible measurement — --smoke --record "
            f"would append an empty run")


@pytest.mark.parametrize("name", sorted(
    p.stem.removeprefix("BENCH_").removesuffix(".json")
    for p in REPO.glob("BENCH_*.json")))
def test_committed_trajectory_is_schema_valid_and_seeded(name):
    """Each committed BENCH_<name>.json must parse strictly (no silently
    skipped records), belong to a registered benchmark, hold >= 1 recorded
    run, and serialize back byte-identically (appends diff minimally)."""
    from repro.tools import benchhist

    path = benchhist.trajectory_path(REPO, name)
    runs = benchhist.load_trajectory(path)
    assert runs, f"{path.name}: trajectory committed but empty"
    assert name in _registry(), f"{path.name}: not a registered benchmark"
    assert benchhist.dumps_trajectory(name, runs) == path.read_text()


def test_every_registered_benchmark_has_a_committed_trajectory():
    from repro.tools import benchhist

    missing = [name for name in _registry()
               if not benchhist.trajectory_path(REPO, name).exists()]
    assert not missing, (
        f"no committed BENCH_<name>.json for {missing} — seed one with "
        f"`PYTHONPATH=src python -m benchmarks.run --smoke --record`")


@pytest.mark.parametrize("name", sorted({
    "fastsim_bench", "trace_replay", "dag_bench", "multi_server",
    "serving_ladders"}))
def test_smoke_artifact_validates_against_bench_spec(name):
    """The committed smoke artifacts must still carry every non-volatile
    measurement their module's BENCH_SPEC declares (volatile ones are
    scrubbed from disk by design and ride only the trajectory)."""
    import json

    from repro.tools.benchhist import Measurement

    mod = _registry()[name]
    spec = mod.BENCH_SPEC
    art = REPO / "experiments" / spec.artifact_for("smoke")
    assert art.exists(), f"{art} missing — run the smoke gate"
    payload = json.loads(art.read_text())
    got = spec.collect(payload, "smoke", include_volatile=False)
    for m in got:
        assert isinstance(m, Measurement)
    declared = [s.name for s in spec.specs_for("smoke",
                                               include_volatile=False)
                if not s.optional]
    assert {m.name for m in got} >= set(declared)


def test_run_unknown_flag_exits_2_with_usage():
    """Deterministic CLI contract: an unknown flag must exit 2 (not 0, not
    a stack trace) and print usage on stderr, so CI wrappers can't silently
    no-op on a typo like --gate-al."""
    for argv in (["--gate-al"], ["--recored", "--smoke"], ["--bench-dir"]):
        proc = _run_gate(*argv)
        assert proc.returncode == 2, (argv, proc.stdout, proc.stderr)
        assert "usage:" in proc.stderr
    proc = _run_gate("no_such_benchmark")
    assert proc.returncode == 2
    assert "unknown benchmark" in proc.stderr


def test_record_then_gate_all_roundtrip(tmp_path):
    """End-to-end: `--smoke fastsim_bench --record` into a throwaway
    bench-dir appends a schema-valid run, `--gate-all` over it exits 0,
    and appending a synthetically regressed run flips the gate to exit 1
    naming the offending measurement.  Never touches the committed
    BENCH_*.json trajectories."""
    from repro.tools import benchhist

    proc = _run_gate("--smoke", "fastsim_bench", "--record",
                     f"--bench-dir={tmp_path}")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "recorded" in proc.stderr
    path = benchhist.trajectory_path(tmp_path, "fastsim_bench")
    runs = benchhist.load_trajectory(path)
    assert len(runs) == 1 and runs[0].mode == "smoke"
    assert runs[0].measurement("batch_speedup_c1") is not None

    proc = _run_gate("--gate-all", f"--bench-dir={tmp_path}")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "gate-all: OK" in proc.stdout

    base = runs[-1]
    regressed = tuple(
        benchhist.Measurement(m.name, m.value * 0.1, m.unit,
                              m.higher_is_better, target=m.target,
                              tolerance=m.tolerance)
        if m.name == "batch_speedup_c1" else m
        for m in base.measurements)
    benchhist.append_run(tmp_path, benchhist.BenchRun(
        base.benchmark, base.mode, base.git_sha, base.timestamp_utc,
        base.platform, base.python, base.numpy, base.backend, regressed,
        jax=base.jax, context=base.context))
    proc = _run_gate("--gate-all", f"--bench-dir={tmp_path}")
    assert proc.returncode == 1
    assert "REGRESSION" in proc.stdout
    assert "fastsim_bench.batch_speedup_c1" in proc.stdout


def test_composed_run_record_gate_single_invocation(tmp_path):
    """`--smoke --record --gate-all` must compose run -> record -> gate in
    ONE invocation (the ``ci/bench_record.sh`` recipe): the selected
    benchmark runs at smoke settings, its measurements land in the
    throwaway bench-dir, and the suite gate judges that freshly appended
    trajectory before the process exits 0."""
    from repro.tools import benchhist

    proc = _run_gate("--smoke", "--record", "--gate-all",
                     f"--bench-dir={tmp_path}", "dag_bench")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "dag_bench," in proc.stdout          # the benchmark ran
    assert "recorded" in proc.stderr            # ... and recorded
    assert "gate-all: OK" in proc.stdout        # ... and was gated
    runs = benchhist.load_trajectory(
        benchhist.trajectory_path(tmp_path, "dag_bench"))
    assert len(runs) == 1 and runs[0].mode == "smoke"


def test_gate_all_on_committed_trajectories_exits_zero():
    """The committed per-PR trajectories must pass their own gate — this
    is the suite-wide generalization of --perf-gate, and it runs on
    recorded data only (no re-measurement), so it is cheap and exact."""
    proc = _run_gate("--gate-all")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "gate-all: OK" in proc.stdout
    assert "REGRESSION" not in proc.stdout


def test_fastsim_smoke_artifact_is_stable(tmp_path, monkeypatch):
    """fastsim's smoke artifact is stable-saved: its wall-clock throughput
    sections are scrubbed on disk (they ride the BENCH trajectory instead)
    and a rerun reproduces the bytes exactly."""
    import benchmarks.common as common
    from benchmarks.fastsim_bench import GATE, _run

    cfg = dict(GATE, duration_s=60.0, replications=4)
    monkeypatch.setattr(common, "EXPERIMENTS_DIR", str(tmp_path))
    _run(cfg, "idem.json", large=False, stable=True)
    first = (tmp_path / "idem.json").read_bytes()
    assert b"wall_s" not in first and b'"gate"' not in first
    assert b'"rps"' not in first
    # the pre-scrub payload keeps the volatile numbers for --record
    payload = common.LAST_PAYLOADS["idem.json"]
    assert payload["gate"]["fast_batch_rps_c1"] > 0
    _run(cfg, "idem.json", large=False, stable=True)
    assert (tmp_path / "idem.json").read_bytes() == first
