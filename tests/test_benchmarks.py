"""Tier-1 benchmark hygiene: every benchmarks/*.py module must import.

Benchmarks bit-rot silently — they only run when someone reproduces a
figure, so a refactor that renames a symbol they import can sit broken for
PRs at a time.  Importing every module (and checking the driver's registry
is complete) catches that class of rot at tier-1 cost.  Actually *running*
the benchmarks stays out of tier-1; ``python -m benchmarks.run --smoke``
runs each one at its smallest setting as the cheap execution gate.
"""

import importlib
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
BENCH_DIR = REPO / "benchmarks"
MODULES = sorted(p.stem for p in BENCH_DIR.glob("*.py")
                 if p.stem != "__init__")


@pytest.fixture(autouse=True, scope="module")
def _benchmarks_on_path():
    """benchmarks/ is a top-level package next to src/; tier-1 runs with
    PYTHONPATH=src, so the repo root must be importable too."""
    added = False
    if str(REPO) not in sys.path:
        sys.path.insert(0, str(REPO))
        added = True
    yield
    if added:
        sys.path.remove(str(REPO))


def test_every_benchmark_module_is_listed():
    assert MODULES, "no benchmark modules found"
    assert "run" in MODULES and "multi_server_bench" in MODULES


@pytest.mark.parametrize("name", MODULES)
def test_benchmark_module_imports(name):
    mod = importlib.import_module(f"benchmarks.{name}")
    assert mod is not None


def test_driver_registry_covers_every_bench_module():
    """Every non-driver benchmark module must be wired into benchmarks.run's
    registry (a new bench that is never runnable from the driver is rot of
    another kind), and every registry entry must expose a callable run()."""
    run = importlib.import_module("benchmarks.run")
    registered = {m.__name__.rsplit(".", 1)[-1] for m in run.MODULES.values()}
    helpers = {"run", "common", "render_report"}
    assert registered == set(MODULES) - helpers
    for name, mod in run.MODULES.items():
        assert callable(run.BENCHES[name])
        smoke = getattr(mod, "run_smoke", None)
        if smoke is not None:
            assert callable(smoke)


def test_smoke_flag_is_wired():
    run = importlib.import_module("benchmarks.run")
    assert "--smoke" in run.__doc__
    # the smallest-setting entry points the smoke gate relies on
    msb = importlib.import_module("benchmarks.multi_server_bench")
    assert callable(msb.run_smoke)
