"""Tier-1 benchmark hygiene: every benchmarks/*.py module must import.

Benchmarks bit-rot silently — they only run when someone reproduces a
figure, so a refactor that renames a symbol they import can sit broken for
PRs at a time.  Importing every module (and checking the driver's registry
is complete) catches that class of rot at tier-1 cost.  Running every
benchmark stays out of tier-1; ``python -m benchmarks.run --smoke`` runs
each one at its smallest setting as the cheap execution gate — of which
the multi-server smoke (the serving substrate's acceptance sweep,
including the work-stealing setting) and the ``--check-docs`` gate are
cheap enough to execute here outright.
"""

import importlib
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
BENCH_DIR = REPO / "benchmarks"
MODULES = sorted(p.stem for p in BENCH_DIR.glob("*.py")
                 if p.stem != "__init__")


@pytest.fixture(autouse=True, scope="module")
def _benchmarks_on_path():
    """benchmarks/ is a top-level package next to src/; tier-1 runs with
    PYTHONPATH=src, so the repo root must be importable too."""
    added = False
    if str(REPO) not in sys.path:
        sys.path.insert(0, str(REPO))
        added = True
    yield
    if added:
        sys.path.remove(str(REPO))


def test_every_benchmark_module_is_listed():
    assert MODULES, "no benchmark modules found"
    assert "run" in MODULES and "multi_server_bench" in MODULES


@pytest.mark.parametrize("name", MODULES)
def test_benchmark_module_imports(name):
    mod = importlib.import_module(f"benchmarks.{name}")
    assert mod is not None


def test_driver_registry_covers_every_bench_module():
    """Every non-driver benchmark module must be wired into benchmarks.run's
    registry (a new bench that is never runnable from the driver is rot of
    another kind), and every registry entry must expose a callable run()."""
    run = importlib.import_module("benchmarks.run")
    registered = {m.__name__.rsplit(".", 1)[-1] for m in run.MODULES.values()}
    helpers = {"run", "common", "render_report"}
    assert registered == set(MODULES) - helpers
    for name, mod in run.MODULES.items():
        assert callable(run.BENCHES[name])
        smoke = getattr(mod, "run_smoke", None)
        if smoke is not None:
            assert callable(smoke)


def test_smoke_flag_is_wired():
    run = importlib.import_module("benchmarks.run")
    assert "--smoke" in run.__doc__
    # the smallest-setting entry points the smoke gate relies on
    msb = importlib.import_module("benchmarks.multi_server_bench")
    assert callable(msb.run_smoke)


def _run_gate(*args: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *args],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=300)


def test_multi_server_smoke_gate_exits_zero():
    """The CI smoke path must actually run: the multi-server smoke sweep
    (all four parts, including the work-stealing setting) exits 0 and its
    acceptance checks hold."""
    proc = _run_gate("--smoke", "multi_server")
    assert proc.returncode == 0, proc.stderr
    assert "multi_server," in proc.stdout
    assert "steal" in proc.stdout           # part 4 ran
    assert "FAILED" not in proc.stdout      # no acceptance check tripped


def test_trace_replay_smoke_gate_exits_zero():
    """The million-user replay pipeline at its ~1e5-request smoke setting:
    trace generation, streaming mix replay and the Planner validation all
    run end to end, on the streaming engines (no event-heap fallback) and
    with no acceptance marker tripped."""
    proc = _run_gate("--smoke", "trace_replay")
    assert proc.returncode == 0, proc.stderr
    assert "trace_replay," in proc.stdout
    assert "engine=closed_form" in proc.stdout
    assert "FAILED" not in proc.stdout


def test_dag_bench_smoke_gate_exits_zero():
    """The workflow-DAG pipeline at its smoke setting: the 3-stage RAG
    tandem's network-model validation, the pipeline-switching-vs-statics
    diurnal comparison, and the fork-join section all run end to end with
    the acceptance criterion (dynamic beats static-accurate on compliance
    and static-fast on accuracy) holding."""
    proc = _run_gate("--smoke", "dag_bench")
    assert proc.returncode == 0, proc.stderr
    assert "dag_bench," in proc.stdout
    assert "dyn_comp=" in proc.stdout
    assert "fj_penalty=" in proc.stdout     # fork-join section ran
    assert "FAILED" not in proc.stdout


def test_scrub_volatile_drops_wall_clock_keys():
    from benchmarks.common import VOLATILE_KEYS, scrub_volatile

    payload = {
        "metadata": {"timestamp_utc": "2026-01-01T00:00:00+00:00"},
        "section": {"requests": 10, "wall_s": 1.23, "rps": 8.1,
                    "rungs": [{"mean_s": 0.1, "wall_s": 0.5}]},
        "kept": 42,
    }
    out = scrub_volatile(payload)
    assert out == {"section": {"requests": 10, "rungs": [{"mean_s": 0.1}]},
                   "kept": 42}
    assert "timestamp_utc" in VOLATILE_KEYS and "metadata" in VOLATILE_KEYS


def test_stable_smoke_artifacts_are_idempotent(tmp_path, monkeypatch):
    """Rerunning a stable-saved smoke benchmark must reproduce the
    artifact byte-for-byte — the smoke gates rewrite these files on every
    test run, so any volatile key turns each `pytest` into a dirty
    working tree (the churn ISSUE 7 fixes)."""
    import benchmarks.common as common
    from benchmarks.trace_replay_bench import _run

    monkeypatch.setattr(common, "EXPERIMENTS_DIR", str(tmp_path))
    _run(target_requests=2e3, artifact="idem.json", stable=True)
    first = (tmp_path / "idem.json").read_bytes()
    _run(target_requests=2e3, artifact="idem.json", stable=True)
    assert (tmp_path / "idem.json").read_bytes() == first
    assert b"wall_s" not in first and b"timestamp_utc" not in first


def test_check_docs_gate_exits_zero():
    proc = _run_gate("--check-docs")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "docscheck: OK" in proc.stdout


def test_perf_gate_exits_zero():
    """The fast-path throughput guard: a fresh gate-sized fastsim_bench
    measurement must stay within 30% of the committed
    ``experiments/fastsim_bench.json`` baseline.  Keeps the vectorized
    engine from quietly rotting back toward event-heap speed."""
    proc = _run_gate("--perf-gate")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "perf-gate: OK" in proc.stdout
    assert "REGRESSION" not in proc.stdout
