"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Each kernel sweeps shapes and dtypes and asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import ops as da_ops, ref as da_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.rmsnorm import ops as rn_ops, ref as rn_ref
from repro.kernels.ssm_scan import ops as ss_ops, ref as ss_ref


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# -- flash attention -----------------------------------------------------------


@pytest.mark.parametrize("seq,block", [(128, 128), (256, 128), (512, 256)])
@pytest.mark.parametrize("kv", [2, 1])  # GQA coverage
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(seq, block, kv, dtype, causal):
    key = jax.random.PRNGKey(seq + kv)
    ks = jax.random.split(key, 3)
    B, H, D = 2, 2, 64
    q = jax.random.normal(ks[0], (B, H, seq, D), dtype)
    k = jax.random.normal(ks[1], (B, kv, seq, D), dtype)
    v = jax.random.normal(ks[2], (B, kv, seq, D), dtype)
    out = fa_ops.flash_attention(
        q, k, v, causal=causal, block_q=block, block_k=block, interpret=True
    )
    ref = fa_ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    B, H, S, D = 1, 2, 256, 64
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    out = fa_ops.flash_attention(
        q, k, v, causal=True, window=window, block_q=128, block_k=128, interpret=True
    )
    ref = fa_ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=2e-5, atol=2e-5
    )


# -- decode attention ------------------------------------------------------------


@pytest.mark.parametrize("cache,block_k", [(1024, 256), (2048, 512), (384, 128)])
@pytest.mark.parametrize("kv", [4, 1])  # MHA-group and MQA
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(cache, block_k, kv, dtype):
    key = jax.random.PRNGKey(cache + kv)
    ks = jax.random.split(key, 4)
    B, H, D = 2, 4, 64
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, cache, kv, D), dtype)
    v = jax.random.normal(ks[2], (B, cache, kv, D), dtype)
    length = jnp.asarray(cache * 3 // 4, jnp.int32)
    out = da_ops.decode_attention(q, k, v, length, block_k=block_k, interpret=True)
    ref = da_ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


def test_decode_attention_respects_length_mask():
    """Positions beyond `length` must not affect the output."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    B, H, S, D = 1, 2, 512, 64
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    length = jnp.asarray(100, jnp.int32)
    out1 = da_ops.decode_attention(q, k, v, length, interpret=True)
    k2 = k.at[:, 100:].set(999.0)
    v2 = v.at[:, 100:].set(-999.0)
    out2 = da_ops.decode_attention(q, k2, v2, length, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


# -- rmsnorm ---------------------------------------------------------------------


@pytest.mark.parametrize("rows,dim", [(4, 256), (16, 512), (3, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(rows, dim, dtype):
    key = jax.random.PRNGKey(rows * dim)
    x = jax.random.normal(key, (rows, dim), dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), (dim,), dtype)
    out = rn_ops.rmsnorm(x, g, interpret=True)
    ref = rn_ref.rmsnorm_ref(x, g)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


# -- ssm scan --------------------------------------------------------------------


@pytest.mark.parametrize("seq,d", [(128, 128), (256, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssm_scan_matches_ref(seq, d, dtype):
    key = jax.random.PRNGKey(seq + d)
    ks = jax.random.split(key, 3)
    N = 8
    # decay in (0,1) for stability; shapes follow ops signature
    decay = jax.nn.sigmoid(jax.random.normal(ks[0], (1, seq, d, N), dtype))
    drive = 0.1 * jax.random.normal(ks[1], (1, seq, d, N), dtype)
    c = jax.random.normal(ks[2], (1, seq, N), dtype)
    out = ss_ops.ssm_scan(decay, drive, c, block_d=64, time_chunk=64, interpret=True)
    ref = ss_ref.ssm_scan_ref(decay, drive, c)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=2e-4, atol=2e-4
    )


def test_ssm_scan_is_sequential_not_parallaxed():
    """State must propagate: zeroing early drive changes late outputs."""
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    S, D, N = 128, 64, 8
    decay = jnp.full((1, S, D, N), 0.95)
    drive = 0.1 * jax.random.normal(ks[1], (1, S, D, N))
    c = jax.random.normal(ks[2], (1, S, N))
    out1 = ss_ops.ssm_scan(decay, drive, c, block_d=64, time_chunk=64, interpret=True)
    drive2 = drive.at[:, :4].set(0.0)
    out2 = ss_ops.ssm_scan(decay, drive2, c, block_d=64, time_chunk=64, interpret=True)
    assert not np.allclose(np.asarray(out1[:, -1]), np.asarray(out2[:, -1]))


# -- chunked cross-entropy -------------------------------------------------------

from repro.kernels.cross_entropy import ops as ce_ops, ref as ce_ref


@pytest.mark.parametrize("t,v,bt,bv", [
    (256, 2048, 128, 512),
    (512, 4096, 256, 1024),
    (128, 1024, 128, 1024),   # single vocab block
    (128, 2048, 128, 256),    # many small blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cross_entropy_matches_ref(t, v, bt, bv, dtype):
    key = jax.random.PRNGKey(t + v)
    ks = jax.random.split(key, 2)
    logits = (jax.random.normal(ks[0], (t, v)) * 4).astype(dtype)
    labels = jax.random.randint(ks[1], (t,), 0, v)
    out = ce_ops.cross_entropy(logits, labels, block_t=bt, block_v=bv, interpret=True)
    ref = ce_ref.cross_entropy_ref(logits, labels)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **tol(dtype))


def test_cross_entropy_gold_on_block_boundaries():
    """Labels exactly at vocab-block edges must pick the right gold logit."""
    t, v, bv = 128, 1024, 256
    key = jax.random.PRNGKey(9)
    logits = jax.random.normal(key, (t, v))
    edges = jnp.array([0, bv - 1, bv, 2 * bv - 1, 2 * bv, v - 1], jnp.int32)
    labels = jnp.tile(edges, t // len(edges) + 1)[:t]
    out = ce_ops.cross_entropy(logits, labels, block_t=128, block_v=bv, interpret=True)
    ref = ce_ref.cross_entropy_ref(logits, labels)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_cross_entropy_rejects_nondivisible():
    logits = jnp.zeros((100, 1000))
    labels = jnp.zeros((100,), jnp.int32)
    with pytest.raises(ValueError):
        ce_ops.cross_entropy(logits, labels, block_t=64, block_v=512, interpret=True)
