"""Elastico controller: ladder walking + asymmetric hysteresis (paper §V-F)."""

import pytest

from repro.core.aqm import HysteresisSpec, derive_policies
from repro.core.elastico import ElasticoController

from conftest import synthetic_point


def make_table(upscale_cd=0.0, downscale_cd=5.0):
    front = [
        synthetic_point(0.14, 0.20, 0.761, "fast"),
        synthetic_point(0.32, 0.45, 0.825, "medium"),
        synthetic_point(0.50, 0.70, 0.853, "accurate"),
    ]
    return derive_policies(
        front,
        slo_p95_s=1.0,
        hysteresis=HysteresisSpec(
            upscale_cooldown_s=upscale_cd, downscale_cooldown_s=downscale_cd
        ),
    )


def test_starts_at_most_accurate():
    ctrl = ElasticoController(make_table())
    assert ctrl.current_index == 2
    assert ctrl.current_policy.point.config[0] == "accurate"


def test_upscale_is_immediate():
    ctrl = ElasticoController(make_table())
    # accurate rung tolerates N_up=0; depth 1 must trip an immediate switch
    ev = ctrl.observe(queue_depth=1, now_s=0.0)
    assert ev is not None and ev.direction == "faster"
    assert ctrl.current_index == 1


def test_upscale_steps_one_rung_by_default():
    ctrl = ElasticoController(make_table())
    ctrl.observe(queue_depth=50, now_s=0.0)
    assert ctrl.current_index == 1  # paper-faithful: rung by rung
    ctrl.observe(queue_depth=50, now_s=0.1)
    assert ctrl.current_index == 0


def test_aggressive_descent_jumps():
    ctrl = ElasticoController(make_table(), aggressive_descent=True)
    ctrl.observe(queue_depth=50, now_s=0.0)
    assert ctrl.current_index == 0  # beyond-paper: straight to fastest


def test_downscale_requires_sustained_low_load():
    ctrl = ElasticoController(make_table(downscale_cd=5.0), initial_index=0)
    # low depth but not sustained: no switch before the cooldown elapses
    assert ctrl.observe(0, now_s=0.0) is None
    assert ctrl.observe(0, now_s=2.0) is None
    assert ctrl.current_index == 0
    ev = ctrl.observe(0, now_s=5.0)  # sustained 5s
    assert ev is not None and ev.direction == "more_accurate"
    assert ctrl.current_index == 1


def test_high_depth_resets_sustain_window():
    ctrl = ElasticoController(make_table(downscale_cd=5.0), initial_index=0)
    ctrl.observe(0, now_s=0.0)
    ctrl.observe(100, now_s=2.0)       # burst: resets low-load window
    ctrl.observe(0, now_s=3.0)
    assert ctrl.observe(0, now_s=7.9) is None   # only 4.9s sustained
    assert ctrl.observe(0, now_s=8.1) is not None


def test_no_oscillation_under_fluctuating_load():
    """Alternating depths around the fast rung's thresholds must not produce
    rapid back-and-forth switching (the hysteresis claim)."""
    ctrl = ElasticoController(make_table(downscale_cd=5.0), initial_index=0)
    t = 0.0
    for i in range(100):
        depth = 0 if i % 2 == 0 else 2   # flaps every 100 ms
        ctrl.observe(depth, now_s=t)
        t += 0.1
    # N_dn[0]=1, so depth 2 resets the window; depth never exceeds N_up[0]=5
    assert ctrl.current_index == 0
    assert len(ctrl.events) == 0


def test_converges_to_most_accurate_under_zero_load():
    ctrl = ElasticoController(make_table(downscale_cd=1.0), initial_index=0)
    t = 0.0
    for _ in range(100):
        ctrl.observe(0, now_s=t)
        t += 0.25
    assert ctrl.current_index == 2  # top of the ladder
    dirs = {e.direction for e in ctrl.events}
    assert dirs == {"more_accurate"}


def test_upscale_cooldown_blocks_consecutive_switches():
    ctrl = ElasticoController(make_table(upscale_cd=1.0))
    assert ctrl.observe(50, now_s=0.0) is not None
    assert ctrl.observe(50, now_s=0.5) is None   # within cooldown
    assert ctrl.observe(50, now_s=1.5) is not None


def test_bounds_and_validation():
    table = make_table()
    with pytest.raises(ValueError):
        ElasticoController(table, initial_index=99)
    ctrl = ElasticoController(table, initial_index=0)
    with pytest.raises(ValueError):
        ctrl.observe(-1, now_s=0.0)
    # at fastest rung, huge depth cannot move further down
    assert ctrl.observe(10_000, now_s=0.0) is None
    assert ctrl.current_index == 0


def test_empty_table_rejected():
    front = [synthetic_point(2.0, 3.0, 0.9, "slow")]
    table = derive_policies(front, slo_p95_s=1.0)
    with pytest.raises(ValueError):
        ElasticoController(table)


def test_reset():
    ctrl = ElasticoController(make_table())
    ctrl.observe(50, now_s=0.0)
    assert ctrl.current_index != 2 or ctrl.events
    ctrl.reset()
    assert ctrl.current_index == 2 and ctrl.events == []
