"""Planner: profiling -> Pareto front -> AQM policies (paper §III-A)."""

import pytest

from repro.core.aqm import ladder_is_monotone
from repro.core.pareto import validate_front
from repro.core.planner import Planner, summarize_latencies


def test_summarize_latencies():
    prof = summarize_latencies([0.1] * 99 + [1.0])
    assert prof.mean == pytest.approx(0.109)
    assert prof.p95 == pytest.approx(0.1, abs=0.05)
    assert prof.samples == 100
    with pytest.raises(ValueError):
        summarize_latencies([])
    with pytest.raises(ValueError):
        summarize_latencies([0.1, -0.1])


def test_plan_end_to_end(rag_plan):
    res, plan = rag_plan
    # every feasible config got profiled
    assert set(plan.profiled) == set(res.feasible)
    # front is a valid increasing ladder
    validate_front(plan.front)
    # ladder + dominated + excluded partitions the profiled set
    assert len(plan.front) + len(plan.dominated) == len(res.feasible)
    assert plan.table.ladder_size >= 2
    # Eq. 11 ordering on the derived thresholds.  The strict form holds under
    # the paper's idealized profiles; with noisy measured profiles adjacent
    # rungs can tie, so assert the operational (non-increasing) form.
    ups = [p.upscale_threshold for p in plan.table.policies]
    assert all(a >= b for a, b in zip(ups, ups[1:])), ups
    assert ups[0] > ups[-1]
    # describe() renders without crashing and mentions every rung
    text = plan.describe()
    assert text.count("N_up") == plan.table.ladder_size


def test_plan_rejects_empty():
    planner = Planner(profiler=lambda c, n: [0.1] * n)
    with pytest.raises(ValueError):
        planner.plan({}, slo_p95_s=1.0)


def test_front_accuracy_spans_feasible_range(rag_plan):
    res, plan = rag_plan
    best = max(res.feasible.values())
    assert plan.front[-1].accuracy == pytest.approx(best)
    # the fastest rung has the lowest accuracy on the front
    accs = [p.accuracy for p in plan.front]
    assert accs == sorted(accs)
