"""jax backend of the workflow-DAG fast path: parity property tests.

Three independent implementations of the same tandem/fork-join queueing
recursion are held against each other over *random* topologies:

1. **numpy** (:func:`repro.serving.fastsim.chained_lindley`,
   :func:`repro.serving.dag.sweep_pipeline` ``backend="numpy"``) — the
   authoritative committed reference.
2. **jax** — the batched device engine under test.  With the sequential
   scan (the CPU ``"auto"`` resolution) it replays numpy's exact op order
   per (request, stage), so grids are bit-equal; the associative and
   Pallas reorderings are held to float64 allclose.
3. **an event-heap oracle** written here, from scratch, against the
   queueing definition only (per-stage FIFO, ``c`` servers, explicit
   service times) — so a shared bug in the two production engines cannot
   self-certify.

Draws are continuous (lognormal / uniform), so exact arrival ties — where
the jax engine's dispatch pairing may legitimately differ from numpy's
stable-by-request-index convention — occur with probability zero.

The jax-less contract rides along: with ``fastsim._jax`` monkeypatched
away, ``backend="auto"`` silently falls back to the numpy engine
everywhere while explicit ``backend="jax"`` raises ``RuntimeError`` with
the recorded import reason — in :func:`chained_lindley`,
:func:`sweep_pipeline`, and :func:`repro.serving.traces.replay_dag`.
"""

import heapq
import random

import numpy as np
import pytest

from proptest import given, settings, st

from repro.serving import fastsim
from repro.serving.dag import (
    DagSimulator,
    StageSpec,
    WorkflowDAG,
    sweep_pipeline,
)
from repro.serving.fastsim import (
    chained_lindley,
    jax_available,
    jax_unavailable_reason,
)
from repro.serving.traces import diurnal_trace, replay_dag
from repro.serving.workload import constant_rate, generate_arrivals

pytestmark = pytest.mark.jax

needs_jax = pytest.mark.skipif(
    not jax_available(),
    reason=f"jax not importable: {jax_unavailable_reason()}")


# --------------------------------------------------------------------------
# the from-scratch event-heap oracle
# --------------------------------------------------------------------------


def _heap_stage(arrivals, services, c):
    """One FIFO stage with ``c`` servers: dispatch in arrival order
    (stable by request index on ties), each request takes the
    earliest-free server.  ``services`` is consumed in dispatch order.
    Returns completions aligned to the *original* request order."""
    order = np.argsort(arrivals, kind="stable")
    free = [0.0] * c
    heapq.heapify(free)
    comp = np.empty(len(arrivals))
    for s, i in zip(services, order):
        t = heapq.heappop(free)
        done = max(arrivals[i], t) + s
        comp[i] = done
        heapq.heappush(free, done)
    return comp


def _heap_tandem(A, stage_services, servers):
    """Chain of heap stages: stage j's completions arrive at stage j+1."""
    cur = np.asarray(A, dtype=float)
    out = []
    for S, c in zip(stage_services, servers):
        cur = _heap_stage(cur, S, c)
        out.append(cur)
    return np.stack(out)


def _draw_chain(seed, *, n_stages, max_c, rate=40.0, n=None):
    """Continuous random arrivals + per-stage dispatch-order services."""
    gen = np.random.Generator(np.random.PCG64(seed))
    n = int(gen.poisson(rate)) + 5 if n is None else n
    A = np.sort(gen.uniform(0.0, 10.0, size=n))
    servers = [int(gen.integers(1, max_c + 1)) for _ in range(n_stages)]
    services = [gen.lognormal(mean=np.log(0.05), sigma=0.6, size=n)
                for _ in range(n_stages)]
    return A, services, servers


# --------------------------------------------------------------------------
# chained_lindley: numpy == jax == oracle over random chains
# --------------------------------------------------------------------------


@needs_jax
@given(st.integers(1, 5), st.integers(1, 3), st.integers(0, 10**6))
@settings(max_examples=15, deadline=None)
def test_chained_jax_sequential_bit_exact_vs_numpy(n_stages, max_c, seed):
    """Random chains mixing c = 1 and pooled stages: the jax sequential
    engine reproduces the numpy reference bit-for-bit, and both agree
    with the independent event-heap oracle to float64 allclose (the c = 1
    closed form reassociates the oracle's additions)."""
    A, services, servers = _draw_chain(seed, n_stages=n_stages, max_c=max_c)
    ref = chained_lindley(A, services, num_servers=servers,
                          backend="numpy")
    got = chained_lindley(A, services, num_servers=servers,
                          backend="jax", scan_impl="sequential")
    np.testing.assert_array_equal(ref, got)
    oracle = _heap_tandem(A, services, servers)
    np.testing.assert_allclose(ref, oracle, rtol=1e-9, atol=1e-12)


@needs_jax
@given(st.sampled_from(["associative", "pallas"]),
       st.integers(1, 4), st.integers(0, 10**6))
@settings(max_examples=12, deadline=None)
def test_chained_jax_reassociated_impls_allclose(scan_impl, n_stages, seed):
    """The associative max-plus scan and the blocked Pallas kernel are
    float reorderings of the same recursion: allclose against numpy and
    the oracle on random all-c = 1 chains, never judged bit-exact."""
    A, services, _ = _draw_chain(seed, n_stages=n_stages, max_c=1)
    servers = [1] * n_stages
    ref = chained_lindley(A, services, num_servers=servers,
                          backend="numpy")
    got = chained_lindley(A, services, num_servers=servers,
                          backend="jax", scan_impl=scan_impl)
    np.testing.assert_allclose(ref, got, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(got, _heap_tandem(A, services, servers),
                               rtol=1e-9, atol=1e-12)


# --------------------------------------------------------------------------
# sweep_pipeline: jax grid == numpy grid over random DAGs, and the
# chained fast path tracks the DagSimulator oracle's sink completions
# --------------------------------------------------------------------------


def _random_stage(rng, name, *, max_c=3):
    m = rng.uniform(0.02, 0.12)
    return StageSpec(name=name, mean_s=(m,),
                     p95_s=(m * rng.uniform(1.3, 2.0),),
                     num_servers=rng.randint(1, max_c))


def _random_dag(kind, width, topo_seed):
    rng = random.Random(topo_seed)
    if kind == 0:
        return WorkflowDAG.tandem(
            [_random_stage(rng, f"s{j}") for j in range(width + 1)])
    branches = [_random_stage(rng, f"b{j}") for j in range(max(2, width))]
    join = _random_stage(rng, "join")
    tail = [_random_stage(rng, "tail")] if rng.random() < 0.5 else []
    return WorkflowDAG.fork_join(branches, join, tail=tail)


@needs_jax
@given(st.integers(0, 1), st.integers(1, 3), st.integers(0, 10**6),
       st.floats(3.0, 8.0))
@settings(max_examples=10, deadline=None)
def test_sweep_pipeline_jax_grid_bit_equal(kind, width, topo_seed, rate):
    """Random tandem / fork-join topologies through the full sweep: the
    jax (R, K, L) grid engine — host permutations, fused c = 1 runs,
    comparator-chain pooled stages, element-wise max joins — reproduces
    the numpy per-cell loop's latency / p95 / compliance grids exactly
    (sequential scan on CPU), with the identical content-keyed draws."""
    dag = _random_dag(kind, width, topo_seed)
    kw = dict(arrival_rates_qps=(rate, rate * 1.6), duration_s=15.0,
              replications=2, slo_s=0.8, seed=topo_seed % 1000)
    ref = sweep_pipeline(dag, [(0,) * dag.num_stages], backend="numpy",
                         **kw)
    got = sweep_pipeline(dag, [(0,) * dag.num_stages], backend="jax",
                         scan_impl="sequential", **kw)
    assert ref.num_requests == got.num_requests
    for field in ("mean_latency_s", "p95_latency_s", "slo_compliance"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, field)),
            np.asarray(getattr(got, field)), err_msg=field)


@needs_jax
@given(st.integers(1, 3), st.integers(0, 10**6), st.floats(3.0, 7.0))
@settings(max_examples=8, deadline=None)
def test_chained_jax_matches_event_heap_simulator(width, topo_seed, rate):
    """The jax chained recursion against :class:`DagSimulator` itself on
    random tandems: replaying the oracle's own per-stage dispatch-order
    service draws through ``chained_lindley(backend="jax")`` reproduces
    the oracle's sink completion multiset (allclose — the closed form
    reassociates the heap's additions)."""
    from repro.serving.dag import _stage_seed

    dag = _random_dag(0, width, topo_seed)
    arr = generate_arrivals(constant_rate(rate), 20.0,
                            seed=topo_seed % 997)
    cfg = (0,) * dag.num_stages
    sim_seed = topo_seed % 89
    oracle = DagSimulator(dag, static_stage_indices=cfg,
                          seed=sim_seed).run(arr, 20.0)
    assert len(oracle.completed) == len(arr)

    # the oracle consumes stage j's services from
    # random.Random(_stage_seed(seed, j)) in dispatch order — pre-drawing
    # the same streams yields its exact dispatch-order service arrays
    topo = dag.topological_order()
    services = []
    for j in topo:
        rng_j = random.Random(_stage_seed(sim_seed, j))
        sampler = dag.stages[j].sampler()
        services.append(np.array([sampler(0, rng_j)
                                  for _ in range(len(arr))]))
    servers = [dag.stages[j].num_servers for j in topo]
    comp = chained_lindley(arr, services, num_servers=servers,
                           backend="jax", scan_impl="sequential")
    np.testing.assert_allclose(
        np.sort(comp[-1]),
        np.sort([r.completion_s for r in oracle.completed]),
        rtol=1e-9, atol=1e-12)


# --------------------------------------------------------------------------
# jax-less contract: silent auto fallback, loud explicit failure
# --------------------------------------------------------------------------


def test_dag_paths_without_jax(monkeypatch):
    """Every DAG-path entry point honors the backend contract when jax is
    gone: auto falls back to the numpy engine with identical results,
    explicit 'jax' raises RuntimeError naming the import failure."""
    A, services, servers = _draw_chain(3, n_stages=2, max_c=2)
    dag = _random_dag(0, 1, 5)
    trace = diurnal_trace(20.0, duration_s=30.0, seed=1)
    kw = dict(arrival_rates_qps=(4.0,), duration_s=10.0, replications=1,
              seed=0)
    want_chain = chained_lindley(A, services, num_servers=servers,
                                 backend="numpy")
    want_sweep = sweep_pipeline(dag, [(0, 0)], backend="numpy", **kw)

    monkeypatch.setattr(fastsim, "_jax", None)
    monkeypatch.setattr(fastsim, "_JAX_IMPORT_ERROR",
                        "No module named 'jax'")
    assert not fastsim.jax_available()

    got_chain = chained_lindley(A, services, num_servers=servers,
                                backend="auto")
    np.testing.assert_array_equal(want_chain, got_chain)
    got_sweep = sweep_pipeline(dag, [(0, 0)], backend="auto", **kw)
    np.testing.assert_array_equal(np.asarray(want_sweep.mean_latency_s),
                                  np.asarray(got_sweep.mean_latency_s))
    stats = replay_dag(trace, [0.01, 0.02], [0.015, 0.03], slo_s=1.0,
                       seed=0, backend="auto")
    assert stats.end_to_end.engine == "chained_closed_form"

    for call in (
        lambda: chained_lindley(A, services, num_servers=servers,
                                backend="jax"),
        lambda: sweep_pipeline(dag, [(0, 0)], backend="jax", **kw),
        lambda: replay_dag(trace, [0.01, 0.02], [0.015, 0.03],
                           slo_s=1.0, seed=0, backend="jax"),
    ):
        with pytest.raises(RuntimeError, match="not importable"):
            call()


def test_unknown_backend_rejected_everywhere():
    A, services, servers = _draw_chain(4, n_stages=2, max_c=1)
    with pytest.raises(ValueError, match="unknown backend"):
        chained_lindley(A, services, num_servers=servers, backend="tpu")
    trace = diurnal_trace(10.0, duration_s=20.0, seed=2)
    with pytest.raises(ValueError, match="unknown backend"):
        replay_dag(trace, [0.01], [0.02], slo_s=1.0, seed=0,
                   backend="tpu")
