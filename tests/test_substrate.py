"""Substrate: optimizer, schedule, data pipeline, checkpointing, train loop."""

import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import latest_step, restore_checkpoint, save_checkpoint
from repro.configs.reduced import reduced_config
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.models.registry import build_model
from repro.optim.adamw import AdamW
from repro.optim.schedule import cosine_with_warmup
from repro.training.loop import train


# -- optimizer -----------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    opt = AdamW(learning_rate=0.1)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(100):
        g = jax.grad(loss_fn)(params)
        params, state = opt.update(g, state, params)
    assert float(loss_fn(params)) < 1e-2


def test_adamw_weight_decay_shrinks_params():
    opt_wd = AdamW(learning_rate=0.01, weight_decay=0.5)
    opt_no = AdamW(learning_rate=0.01, weight_decay=0.0)
    params = {"w": jnp.ones((4,))}
    zero_g = {"w": jnp.zeros((4,))}
    s1, s2 = opt_wd.init(params), opt_no.init(params)
    p_wd, _ = opt_wd.update(zero_g, s1, params)
    p_no, _ = opt_no.update(zero_g, s2, params)
    assert float(jnp.max(p_wd["w"])) < float(jnp.max(p_no["w"])) == 1.0


def test_cosine_schedule_shape():
    sched = lambda s: float(
        cosine_with_warmup(s, warmup_steps=10, total_steps=100, min_ratio=0.1)
    )
    assert sched(0) < sched(5) < sched(10)
    assert sched(10) == pytest.approx(1.0)
    assert sched(99) == pytest.approx(0.1, abs=0.05)


# -- synthetic data --------------------------------------------------------------


def _first_batch(lm, start=0):
    return next(lm.batches(start_step=start))


def test_synthetic_data_shapes_and_determinism():
    cfg = DataConfig(vocab_size=128, seq_len=64, global_batch=8, seed=0)
    lm = SyntheticLM(cfg)
    b1 = _first_batch(lm)
    b2 = _first_batch(lm)
    assert b1["tokens"].shape == b1["labels"].shape == (8, 64)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = _first_batch(lm, start=1)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 128
    # next-token alignment: labels are tokens shifted by one
    it = lm.batches(start_step=0)
    b = next(it)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_synthetic_data_is_learnable():
    """Markov structure means a model can beat uniform cross-entropy."""
    cfg = DataConfig(vocab_size=64, seq_len=32, global_batch=4, seed=1)
    lm = SyntheticLM(cfg)
    tokens = _first_batch(lm)["tokens"].reshape(-1)
    assert len(np.unique(tokens)) > 4


# -- checkpointing ----------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "layer": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.zeros(3)},
        "step_count": jnp.asarray(7),
    }
    d = str(tmp_path)
    save_checkpoint(d, 100, tree, metadata={"note": "test"})
    restored, step, meta = restore_checkpoint(d, tree)
    assert step == 100 and meta["note"] == "test"
    np.testing.assert_array_equal(restored["layer"]["w"], tree["layer"]["w"])


def test_checkpoint_keep_policy(tmp_path):
    tree = {"w": jnp.zeros(2)}
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, tree, keep=2)
    assert latest_step(d) == 5
    restored, step, _ = restore_checkpoint(d, tree)
    assert step == 5
    # only `keep` checkpoints remain on disk
    entries = [e for e in os.listdir(d) if "step" in e or e.isdigit() or "ckpt" in e]
    assert len(entries) <= 3


# -- train loop -------------------------------------------------------------------


def test_train_loop_end_to_end(tmp_path):
    model = build_model(reduced_config("internlm2-1.8b"))
    steps = 30
    result = train(
        model,
        steps=steps,
        data_cfg=DataConfig(
            vocab_size=model.cfg.vocab_size, seq_len=32, global_batch=8, seed=0
        ),
        optimizer=AdamW(learning_rate=3e-3),
        checkpoint_dir=str(tmp_path),
        checkpoint_every=15,
        log_every=100,
        log_fn=lambda s: None,
    )
    assert len(result.losses) == steps
    assert all(math.isfinite(l) for l in result.losses)
    # later-window mean loss below the early-window mean (it is learning)
    assert np.mean(result.losses[-5:]) < np.mean(result.losses[:5])
    assert latest_step(str(tmp_path)) == steps
