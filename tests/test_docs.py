"""Tier-1 docs gate: README/docs must exist and reference only live code.

The checker (repro.tools.docscheck, also exposed as
`python -m benchmarks.run --check-docs`) resolves every inline-code
reference in README.md and docs/*.md — dotted repro.* names via
import+getattr, repo paths via existence, CLI flags via grep — so a rename
or removal that orphans the documentation fails tier-1."""

from pathlib import Path

import pytest

from repro.tools.docscheck import (
    check_docs,
    check_links,
    check_text,
    doc_files,
    extract_links,
    extract_references,
    repo_root,
    resolve_dotted,
)

ROOT = repo_root()


def test_repo_root_is_the_repo():
    assert (ROOT / "src" / "repro").is_dir()
    assert (ROOT / "pytest.ini").exists()


def test_required_documents_exist():
    names = {str(p.relative_to(ROOT)) for p in doc_files()}
    assert "README.md" in names
    assert "docs/architecture.md" in names
    assert "docs/queueing.md" in names
    assert "docs/batching.md" in names
    assert "docs/scheduler.md" in names


def test_extract_skips_fenced_blocks():
    text = (
        "Use `repro.core.aqm` here.\n"
        "```bash\npython -m `not.a.ref`\n```\n"
        "And `docs/queueing.md` inline.\n"
    )
    refs = extract_references(text)
    assert "repro.core.aqm" in refs
    assert "docs/queueing.md" in refs
    assert "not.a.ref" not in refs


def test_resolve_dotted_live_and_stale():
    assert resolve_dotted("repro.core.aqm.derive_mix_policies") is None
    assert resolve_dotted("repro.serving.engine.ServingEngine") is None
    assert resolve_dotted("repro.core.aqm.no_such_function") is not None
    assert resolve_dotted("repro.no_such_module.thing") is not None


def test_check_text_flags_stale_references():
    bad = (
        "See `repro.core.aqm.totally_gone` and `src/repro/nope.py` "
        "plus `--no-such-flag-anywhere`."
    )
    problems = check_text(bad, source="synthetic")
    assert len(problems) == 3


def test_check_text_ignores_plain_prose_backticks():
    ok = "Set `c = 1` and watch `N_k(up)`; run `pytest -x` as usual."
    assert check_text(ok, source="synthetic") == []


def test_extract_links_skips_fences_and_dedups():
    text = (
        "See [queueing](queueing.md) and [again](queueing.md).\n"
        "```md\n[not a link](fenced.md)\n```\n"
        "Plus [anchored](batching.md#section) and [ext](https://x.test/a).\n"
    )
    links = extract_links(text)
    assert links == ["queueing.md", "batching.md#section", "https://x.test/a"]
    assert "fenced.md" not in links


def test_check_links_resolves_relative_to_doc_dir():
    docs = ROOT / "docs"
    ok = "[queueing model](queueing.md) and [batching](batching.md#top)"
    assert check_links(ok, source="synthetic", base_dir=docs) == []
    # the same targets are broken when resolved from the repo root — the
    # exact class of bug that used to pass silently
    assert len(check_links(ok, source="synthetic", base_dir=ROOT)) == 2


def test_check_links_flags_broken_and_skips_external():
    text = (
        "[gone](no/such/file.md) [ext](https://example.test/x) "
        "[mail](mailto:a@b.c) [anchor](#local-section) [root](/README.md)"
    )
    problems = check_links(text, source="synthetic", base_dir=ROOT / "docs")
    assert len(problems) == 1
    assert "no/such/file.md" in problems[0]


def test_check_text_includes_link_validation():
    bad = "A [broken link](missing-target.md) in prose."
    problems = check_text(bad, source="synthetic", base_dir=ROOT / "docs")
    assert len(problems) == 1
    assert "broken markdown link" in problems[0]


def test_repo_docs_have_no_stale_references():
    problems = check_docs()
    assert not problems, "\n".join(problems)
