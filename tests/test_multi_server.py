"""M/G/c worker-pool substrate: Erlang-C thresholds, multi-server simulator,
threaded WorkerPool engine, admission control, and the new load patterns."""

import hashlib
import math
import time

import pytest

from proptest import given, settings, st

from repro.core.aqm import (
    HysteresisSpec,
    derive_policies,
    erlang_c,
    erlang_c_mean_wait,
    expected_wait,
    max_sustainable_rate,
)
from repro.core.elastico import ElasticoController
from repro.serving.engine import ServingEngine
from repro.serving.executor import WorkerPool, WorkflowExecutor
from repro.serving.scheduler import Scheduler
from repro.serving.simulator import (
    ServingSimulator,
    exponential_sampler,
    lognormal_sampler_from_profile,
)
from repro.serving.workload import (
    Request,
    constant_rate,
    flash_crowd_pattern,
    generate_arrivals,
    sustained_overload_pattern,
)

from conftest import synthetic_point

MEANS = [0.10, 0.25, 0.45]
P95S = [0.14, 0.35, 0.63]
ACCS = [0.76, 0.82, 0.85]


def ladder_front():
    return [
        synthetic_point(m, p, a, f"c{i}")
        for i, (m, p, a) in enumerate(zip(MEANS, P95S, ACCS))
    ]


def table_for(c, **hyst):
    return derive_policies(
        ladder_front(), slo_p95_s=1.0, hysteresis=HysteresisSpec(**hyst),
        num_servers=c,
    )


def det_sampler(idx, rng):
    return MEANS[idx]


# -- Erlang-C / threshold derivation ------------------------------------------


def test_c1_thresholds_collapse_to_mg1():
    """num_servers=1 must reproduce the paper's M/G/1 table exactly —
    including against the closed-form Eq. 10/13 values."""
    base = derive_policies(ladder_front(), slo_p95_s=1.0)
    c1 = table_for(1)
    assert base.num_servers == 1
    for a, b in zip(base.policies, c1.policies):
        assert a.upscale_threshold == b.upscale_threshold
        assert a.downscale_threshold == b.downscale_threshold
        assert a.queuing_slack == b.queuing_slack
    # closed-form M/G/1 check
    for k, pol in enumerate(c1.policies):
        delta = 1.0 - P95S[k]
        assert pol.upscale_threshold == int(math.floor(delta / MEANS[k]))


@given(st.integers(1, 16), st.floats(0.7, 3.0))
@settings(max_examples=40, deadline=None)
def test_thresholds_scale_linearly_with_c(c, slo):
    table = derive_policies(ladder_front(), slo_p95_s=slo, num_servers=c)
    for k, pol in enumerate(table.policies):
        delta = slo - pol.point.profile.p95
        want = max(0, int(math.floor(c * delta / pol.point.profile.mean)))
        assert pol.upscale_threshold == want
        if pol.downscale_threshold is not None:
            nxt = table.policies[k + 1].point
            delta_n = slo - nxt.profile.p95
            want_dn = int(math.floor(
                c * max(0.0, delta_n - table.slack_buffer_s) / nxt.profile.mean
            ))
            assert pol.downscale_threshold == want_dn


def test_derive_policies_rejects_bad_num_servers():
    with pytest.raises(ValueError):
        derive_policies(ladder_front(), slo_p95_s=1.0, num_servers=0)


def test_erlang_c_reduces_to_mm1():
    """c = 1: P(wait) = rho and E[W] = rho * s / (1 - rho)."""
    for rho in (0.1, 0.5, 0.9):
        assert erlang_c(1, rho) == pytest.approx(rho, rel=1e-12)
        s = 0.2
        lam = rho / s
        want = rho * s / (1.0 - rho)
        assert erlang_c_mean_wait(1, lam, s) == pytest.approx(want, rel=1e-12)


def test_erlang_c_known_value():
    """Textbook check: c=2, a=1 erlang -> C = 1/3."""
    assert erlang_c(2, 1.0) == pytest.approx(1.0 / 3.0, rel=1e-12)


def test_erlang_c_saturation_and_monotonicity():
    assert erlang_c(2, 2.0) == 1.0
    assert erlang_c_mean_wait(2, 10.0, 0.2) == float("inf")
    assert erlang_c(4, 0.0) == 0.0
    # adding servers at fixed offered load strictly reduces waiting
    waits = [erlang_c_mean_wait(c, 8.0, 0.2) for c in (2, 3, 4, 8)]
    assert all(a > b for a, b in zip(waits, waits[1:]))


def test_expected_wait_and_sustainable_rate_scale_with_c():
    assert expected_wait(6, 0.5) == pytest.approx(3.0)
    assert expected_wait(6, 0.5, num_servers=3) == pytest.approx(1.0)
    pol = table_for(1).policies[0]
    assert max_sustainable_rate(pol) == pytest.approx(1.0 / MEANS[0])
    assert max_sustainable_rate(pol, num_servers=4) == pytest.approx(4.0 / MEANS[0])


# -- simulator: c = 1 reproduces the seed exactly ------------------------------


def _digest(completed):
    h = hashlib.sha256()
    for r in completed:
        h.update(
            f"{r.request_id},{r.arrival_s:.12e},{r.start_s:.12e},"
            f"{r.completion_s:.12e},{r.config_index};".encode()
        )
    return h.hexdigest()


def test_c1_simulator_reproduces_seed_golden():
    """Golden regression: the exact completion schedule produced by the
    pre-refactor single-server simulator (seed commit) for this scenario.
    If this digest moves, c=1 no longer reproduces the paper-faithful
    M/G/1 runtime bit-for-bit."""
    from repro.serving.workload import spike_pattern

    table = derive_policies(ladder_front(), slo_p95_s=1.0)
    arr = generate_arrivals(spike_pattern(2.0, factor=4.0), 180.0, seed=1)
    sim = ServingSimulator(
        lognormal_sampler_from_profile(MEANS, P95S),
        controller=ElasticoController(table),
        seed=7,
        num_servers=1,
    )
    out = sim.run(arr, 180.0)
    assert len(out.completed) == 732
    assert len(out.switch_events) == 14
    assert _digest(out.completed) == (
        "dfec2ace7a6aa74c5246f4769e3ed8ec433b3f2ea07e4a6c0d38ba79038ed1f6"
    )


def test_default_num_servers_is_one_and_deterministic():
    arr = generate_arrivals(constant_rate(4.0), 40.0, seed=3)
    a = ServingSimulator(det_sampler, static_index=1, seed=5).run(arr, 40.0)
    b = ServingSimulator(det_sampler, static_index=1, seed=5, num_servers=1).run(arr, 40.0)
    assert a.num_servers == b.num_servers == 1
    assert a.completed == b.completed
    assert a.queue_depth_samples == b.queue_depth_samples
    assert a.per_server_busy_s == b.per_server_busy_s


# -- simulator: multi-server behavior ------------------------------------------


@given(st.integers(2, 6), st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_conservation_any_pool_size(c, seed):
    arr = generate_arrivals(constant_rate(6.0), 20.0, seed=seed)
    ctrl = ElasticoController(table_for(c))
    sim = ServingSimulator(det_sampler, controller=ctrl, seed=seed, num_servers=c)
    out = sim.run(arr, 20.0)
    assert len(out.completed) == len(arr)
    ids = [r.request_id for r in out.completed]
    assert len(set(ids)) == len(ids)
    assert all(0 <= r.server_id < c for r in out.completed)
    assert len(out.per_server_busy_s) == c
    assert all(b >= 0.0 for b in out.per_server_busy_s)


def test_pool_reduces_wait_under_load():
    """At rho ~ 0.9 for one server, a second server must cut the mean wait."""
    arr = generate_arrivals(constant_rate(9.0), 120.0, seed=4)
    waits = {}
    for c in (1, 2, 4):
        out = ServingSimulator(
            det_sampler, static_index=0, seed=0, num_servers=c
        ).run(arr, 120.0)
        waits[c] = out.mean_wait()
    assert waits[2] < waits[1]
    assert waits[4] <= waits[2]


def test_mmc_wait_converges_to_erlang_c():
    """M/M/c validation: simulated mean wait under Poisson load matches the
    Erlang-C stationary prediction within tolerance (c = 1, 2, 3)."""
    mean_s = 0.2
    for c, lam in ((1, 3.5), (2, 7.0), (3, 10.5)):  # rho = 0.7 each
        arr = generate_arrivals(constant_rate(lam), 2000.0, seed=11 + c)
        sim = ServingSimulator(
            exponential_sampler([mean_s]), static_index=0, seed=29 + c,
            num_servers=c,
        )
        out = sim.run(arr, 2000.0)
        predicted = erlang_c_mean_wait(c, lam, mean_s)
        assert out.mean_wait() == pytest.approx(predicted, rel=0.15), (
            f"c={c}: simulated {out.mean_wait():.4f} vs Erlang-C {predicted:.4f}"
        )


def test_per_server_utilization_balanced_under_saturation():
    # rho = 38 * 0.1 / 4 = 0.95: every server near fully busy
    arr = generate_arrivals(constant_rate(38.0), 60.0, seed=2)
    out = ServingSimulator(
        det_sampler, static_index=0, seed=0, num_servers=4
    ).run(arr, 60.0)
    utils = out.per_server_utilization()
    assert len(utils) == 4
    assert all(u > 0.8 for u in utils)
    assert max(utils) - min(utils) < 0.2  # lowest-free-server dispatch balances


def test_c4_beats_c1_under_sustained_overload():
    """Acceptance criterion: under the sustained-overload trace a c=4 pool
    shows strictly higher SLO compliance than c=1 (same arrivals, each with
    the Elastico table derived for its own c)."""
    capacity = 1.0 / MEANS[0]
    arr = generate_arrivals(
        sustained_overload_pattern(capacity, overload_factor=2.5, warmup_s=20.0),
        120.0, seed=1,
    )
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    comp = {}
    for c in (1, 4):
        sim = ServingSimulator(
            sampler, controller=ElasticoController(table_for(c)),
            seed=0, num_servers=c,
        )
        comp[c] = sim.run(arr, 120.0).slo_compliance(1.0)
    assert comp[4] > comp[1]
    assert comp[4] > 0.9


# -- new load patterns ---------------------------------------------------------


def test_flash_crowd_shape():
    f = flash_crowd_pattern(2.0, peak_factor=10.0, crowd_start_s=60.0,
                            ramp_s=5.0, hold_s=20.0)
    assert f(0.0) == pytest.approx(2.0)
    assert f(59.9) == pytest.approx(2.0)
    assert f(62.5) == pytest.approx(11.0)       # mid-ramp
    assert f(70.0) == pytest.approx(20.0)       # hold
    assert f(84.9) == pytest.approx(20.0, abs=0.5)
    assert f(95.0) == pytest.approx(2.0)        # back to base
    with pytest.raises(ValueError):
        flash_crowd_pattern(1.0, peak_factor=0.5)


def test_sustained_overload_shape():
    f = sustained_overload_pattern(10.0, overload_factor=2.5, warmup_s=30.0)
    assert f(10.0) == pytest.approx(5.0)        # warmup at half capacity
    assert f(30.0) == pytest.approx(25.0)
    assert f(500.0) == pytest.approx(25.0)
    with pytest.raises(ValueError):
        sustained_overload_pattern(0.0)


# -- real-time worker pool -----------------------------------------------------


SERVICE_S = 0.004


def sleep_workflow(config, payload):
    time.sleep(SERVICE_S)
    return payload


def make_engine(num_workers=1, **kw):
    executor = WorkflowExecutor(
        configs=[("cfg", 0), ("cfg", 1)], workflow_fn=sleep_workflow
    )
    return ServingEngine(executor, num_workers=num_workers,
                         control_tick_s=0.01, **kw)


def test_worker_pool_c1_serves_all_fifo():
    engine = make_engine(num_workers=1)
    engine.start()
    for i in range(30):
        engine.submit(Request(request_id=i, arrival_s=0.0))
    report = engine.drain_and_stop()
    assert report.num_workers == 1
    assert report.dropped == 0
    assert sorted(r.request_id for r in report.records) == list(range(30))
    # single worker: completion order == submission order (FIFO, no overlap)
    assert [r.request_id for r in report.records] == list(range(30))
    assert report.served_per_worker == [30]


def test_worker_pool_parallelism_speedup():
    """c=4 drains a backlog of sleep-requests ~4x faster than c=1 (generous
    2x bound to stay robust on loaded CI hosts)."""
    n = 80

    def drain_time(c):
        engine = make_engine(num_workers=c)
        engine.start()
        t0 = time.monotonic()
        for i in range(n):
            engine.submit(Request(request_id=i, arrival_s=0.0))
        report = engine.drain_and_stop()
        elapsed = time.monotonic() - t0
        assert len(report.records) == n
        assert report.num_workers == c
        return elapsed

    t1 = drain_time(1)
    t4 = drain_time(4)
    assert t4 < t1 / 2.0, f"c=4 took {t4:.3f}s vs c=1 {t1:.3f}s"


def test_worker_pool_spreads_load():
    engine = make_engine(num_workers=4)
    engine.start()
    for i in range(100):
        engine.submit(Request(request_id=i, arrival_s=0.0))
    report = engine.drain_and_stop()
    assert len(report.records) == 100
    assert sum(report.served_per_worker) == 100
    assert sum(1 for s in report.served_per_worker if s > 0) >= 2
    workers = {r.worker_id for r in report.records}
    assert len(workers) >= 2


def test_admission_control_counts_drops():
    engine = make_engine(num_workers=1, max_queue_depth=5)
    engine.start()
    accepted = 0
    for i in range(200):  # flood much faster than one worker drains
        if engine.submit(Request(request_id=i, arrival_s=0.0)):
            accepted += 1
    report = engine.drain_and_stop()
    assert report.total_requests == 200
    assert report.dropped > 0
    assert report.dropped == 200 - accepted
    assert len(report.records) == accepted
    assert engine.monitor.total_drops == report.dropped
    # goodput charges drops, compliance does not
    assert report.goodput(10.0) <= report.slo_compliance(10.0)


def test_bounded_scheduler_admission_semantics():
    """The scheduler's admission bound: offers over max_queue_depth are
    rejected and counted; dispatching frees capacity (the exact semantics
    the old bounded RequestQueue implemented for the engine alone — now
    shared with the simulator)."""
    s = Scheduler(num_workers=1, max_queue_depth=2)
    assert s.offer(Request(request_id=0, arrival_s=0.0), 0.0).admitted
    assert s.offer(Request(request_id=1, arrival_s=0.0), 0.0).admitted
    assert not s.offer(Request(request_id=2, arrival_s=0.0), 0.0).admitted
    assert s.offered == 3
    assert s.dropped == 1
    dispatches, _ = s.poll(0.0)
    assert [r.request_id for d in dispatches for r in d.items] == [0]
    assert s.offer(Request(request_id=3, arrival_s=0.0), 0.1).admitted
    with pytest.raises(ValueError):
        Scheduler(num_workers=1, max_queue_depth=0)


def test_engine_monitor_shares_time_axis():
    """record_arrival (ingress) and snapshot/arrival_rate (observe) must
    stamp on the engine's epoch-relative axis, or the EWMA decay term sees
    dt = 0 forever and the arrival rate never decays."""
    t = {"now": 1000.0}  # absolute host clock, far from zero

    def clock():
        return t["now"]

    executor = WorkflowExecutor(configs=[("cfg", 0)],
                                workflow_fn=lambda cfg, p: None, clock=clock)
    engine = ServingEngine(executor, num_workers=1, clock=clock)
    engine.start()
    for i in range(20):
        t["now"] += 0.1
        engine.submit(Request(request_id=i, arrival_s=0.0))
    rate_at_burst = engine.monitor.arrival_rate()
    assert rate_at_burst > 1.0  # ~10 QPS stream just ended
    t["now"] += 60.0            # long quiet period: rate must decay to ~0
    assert engine.monitor.arrival_rate() < rate_at_burst * 0.01
    engine.drain_and_stop()


def test_worker_pool_standalone():
    """WorkerPool used directly (without the engine): c workers drain the
    shared scheduler and every record lands in the executor."""
    executor = WorkflowExecutor(configs=[("cfg", 0)],
                                workflow_fn=lambda cfg, p: p)
    pool = WorkerPool(executor, c=3)
    pool.start()
    for i in range(50):
        pool.submit(Request(request_id=i, arrival_s=0.0))
    deadline = time.monotonic() + 10.0
    while len(executor.records) < 50 and time.monotonic() < deadline:
        time.sleep(0.005)
    pool.stop()
    assert sorted(r.request_id for r in executor.records) == list(range(50))
    assert pool.num_workers == 3
    with pytest.raises(ValueError):
        WorkerPool(executor, c=0)
