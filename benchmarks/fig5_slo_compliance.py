"""Fig. 5: Elastico vs static baselines across SLOs and load patterns.

Paper: Elastico reaches 90-98% SLO compliance, +71.6% over Static-Accurate
under the 1000ms-SLO spike, and +3-5 accuracy points over Static-Fast.
SLO targets are scaled to the ladder: ~slowest-config P95, 1.5x, 2x.
"""

from __future__ import annotations

from repro.core.elastico import ElasticoController

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import Timer, paper_arrivals, save_json, simulate


def _variant(rows, name):
    return [r for r in rows if r["variant"] == name]


# Trajectory measurements (BENCH_fig5_slo_compliance.json): Elastico's
# compliance band across the pattern x SLO grid (paper: 90-98%) and its
# accuracy margin over the always-fast static baseline.
BENCH_SPEC = BenchmarkSpec(
    artifact="fig5_slo_compliance.json",
    measurements=(
        MeasurementSpec(
            "elastico_min_compliance", "frac", True,
            extract=lambda rows: min(r["compliance"]
                                     for r in _variant(rows, "elastico")),
            tolerance=0.10),
        MeasurementSpec(
            "elastico_mean_accuracy", "frac", True,
            extract=lambda rows: (
                sum(r["mean_accuracy"] for r in _variant(rows, "elastico"))
                / len(_variant(rows, "elastico"))),
            tolerance=0.05),
        MeasurementSpec(
            "accuracy_gain_vs_static_fast", "pts", True,
            extract=lambda rows: (
                sum(r["mean_accuracy"] for r in _variant(rows, "elastico"))
                / len(_variant(rows, "elastico"))
                - sum(r["mean_accuracy"]
                      for r in _variant(rows, "static-fast"))
                / len(_variant(rows, "static-fast"))),
            tolerance=0.25),
    ),
)
from .table1_baselines import build_plan


def run() -> dict:
    sur, res, plan0 = build_plan()
    slowest_p95 = plan0.front[-1].profile.p95
    slo_targets = [round(s, 3) for s in (slowest_p95, 1.5 * slowest_p95, 2.0 * slowest_p95)]

    rows = []
    with Timer() as t:
        for pattern in ("spike", "bursty", "diurnal"):
            arrivals = paper_arrivals(pattern)
            for slo in slo_targets:
                from .common import plan_for

                plan = plan_for(sur, res.feasible, slo)
                ladder = plan.table.policies
                variants = {
                    "elastico": (ElasticoController(plan.table), 0),
                    "static-fast": (None, 0),
                    "static-medium": (None, len(ladder) // 2),
                    "static-accurate": (None, len(ladder) - 1),
                }
                for name, (ctrl, static) in variants.items():
                    out, acc = simulate(
                        sur, plan, arrivals, 180.0, controller=ctrl, static=static
                    )
                    rows.append(
                        {
                            "pattern": pattern,
                            "slo_ms": slo * 1e3,
                            "variant": name,
                            "compliance": out.slo_compliance(slo),
                            "mean_accuracy": acc,
                            "p95_ms": out.p95_latency() * 1e3,
                            "switches": len(out.switch_events),
                        }
                    )
    save_json("fig5_slo_compliance.json", rows)

    # headline: spike @ middle SLO
    mid = slo_targets[1]
    sel = {r["variant"]: r for r in rows if r["pattern"] == "spike" and r["slo_ms"] == mid * 1e3}
    d_comp = sel["elastico"]["compliance"] - sel["static-accurate"]["compliance"]
    d_acc = sel["elastico"]["mean_accuracy"] - sel["static-fast"]["mean_accuracy"]
    return {
        "name": "fig5_slo_compliance",
        "us_per_call": t.elapsed / len(rows) * 1e6,
        "derived": (
            f"elastico_compliance={sel['elastico']['compliance']:.3f} "
            f"vs_static_accurate=+{d_comp * 100:.1f}pts "
            f"acc_vs_fast=+{d_acc * 100:.1f}pts"
        ),
    }


if __name__ == "__main__":
    print(run())
