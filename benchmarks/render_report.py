"""Render EXPERIMENTS.md from the committed experiment artifacts.

    PYTHONPATH=src python -m benchmarks.render_report

Reads experiments/*.json[l] (produced by `benchmarks.run`, `repro.launch.dryrun`
and `repro.launch.perf`) and regenerates the full report, so every number in
EXPERIMENTS.md is traceable to an artifact.
"""

from __future__ import annotations

import json
import os
from collections import defaultdict

from .common import EXPERIMENTS_DIR

OUT = os.path.join(EXPERIMENTS_DIR, "..", "EXPERIMENTS.md")


def load(name):
    path = os.path.join(EXPERIMENTS_DIR, name)
    if not os.path.exists(path):
        return None
    if name.endswith(".jsonl"):
        return [json.loads(l) for l in open(path)]
    return json.load(open(path))


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x * 1e3:.2f}ms"


def section_compass_v(w):
    fig3 = load("fig3_convergence.json")
    fig4 = load("fig4_efficiency.json")
    fig1 = load("fig1_pareto.json")
    w("## §Compass-V — offline search (paper §IV, Figs. 1/3/4)\n")
    if fig1:
        h = fig1["headline"]
        w(f"**Fig. 1 (Pareto preliminary study)** — {fig1['num_configs']} configs "
          f"profiled, front of {fig1['front_size']}; moving from the most accurate "
          f"rung to the efficient alternative gives **{h['p95_speedup_within_2pct']:.2f}x "
          f"lower P95 at {h['accuracy_drop'] * 100:.1f}% accuracy drop** "
          f"(paper: 1.6x / 2%).\n")
    if fig3:
        w("**Fig. 3 (anytime convergence, RAG)** — recall vs exhaustive "
          "grid-search ground truth at every paper threshold:\n")
        w("| tau | feasible (frac) | recall | samples vs grid |")
        w("|---|---|---|---|")
        for r in fig3:
            w(f"| {r['tau']:.2f} | {r['feasible']} ({r['feasible_fraction'] * 100:.1f}%) "
              f"| {r['recall'] * 100:.0f}% | {r['samples']} / {r['grid_samples']} |")
        w("")
    if fig4:
        allr = fig4["rag"] + fig4["detection"]
        recalls = [r["recall"] for r in allr]
        savs = [r["savings"] for r in allr]
        w(f"**Fig. 4 (efficiency, 16 thresholds x 2 workflows)** — recall "
          f"**{min(recalls) * 100:.0f}%–{max(recalls) * 100:.0f}%** (paper: 100%), "
          f"savings **{min(savs) * 100:.1f}%–{max(savs) * 100:.1f}%**, mean "
          f"**{sum(savs) / len(savs) * 100:.1f}%** (paper: 20.3–84.7% RAG / "
          f"51.1–79.3% detection, 57.5% mean).  Both workflows show the paper's "
          f"convex pattern (minimum at moderate feasible fractions).\n")
        w("| workflow | tau | feasible frac | recall | savings |")
        w("|---|---|---|---|---|")
        for wf_name, rows in (("RAG", fig4["rag"]), ("detection", fig4["detection"])):
            for r in rows:
                w(f"| {wf_name} | {r['tau']:.2f} | {r['feasible_fraction'] * 100:.1f}% "
                  f"| {r['recall'] * 100:.0f}% | {r['savings'] * 100:.1f}% |")
        w("")


def section_elastico(w):
    t1 = load("table1_baselines.json")
    fig5 = load("fig5_slo_compliance.json")
    fig6 = load("fig6_latency_cdf.json")
    fig7 = load("fig7_timeseries.json")
    w("## §Elastico — runtime adaptation (paper §VI-C, Table I, Figs. 5/6/7)\n")
    if t1:
        w(f"**Table I (Pareto ladder at tau=0.75)** — {t1['ladder_size']} rungs; "
          "named baselines:\n")
        w("| name | accuracy | mean | p95 | N_up | N_dn |")
        w("|---|---|---|---|---|---|")
        for r in t1["rows"]:
            w(f"| {r['name']} | {r['accuracy']} | {r['mean_ms']}ms | "
              f"{r['p95_ms']}ms | {r['N_up']} | {r['N_dn']} |")
        w("\n(paper Table I: Fast 0.761/~200ms, Medium 0.825/~450ms, "
          "Accurate 0.853/~700ms — same accuracy ladder, latency scale set by "
          "the surrogate calibration.)\n")
    if fig5:
        w("**Fig. 5 (SLO compliance & accuracy)** — spike / bursty x 3 SLO "
          "targets:\n")
        w("| pattern | SLO | variant | compliance | accuracy | p95 | switches |")
        w("|---|---|---|---|---|---|---|")
        for r in fig5:
            w(f"| {r['pattern']} | {r['slo_ms']:.0f}ms | {r['variant']} | "
              f"{r['compliance'] * 100:.1f}% | {r['mean_accuracy']:.3f} | "
              f"{r['p95_ms']:.0f}ms | {r['switches']} |")
        # headline
        spike = [r for r in fig5 if r["pattern"] == "spike"]
        slos = sorted({r["slo_ms"] for r in spike})
        mid = slos[len(slos) // 2]
        sel = {r["variant"]: r for r in spike if r["slo_ms"] == mid}
        if {"elastico", "static-accurate", "static-fast"} <= set(sel):
            w(f"\n**Headline (spike @ {mid:.0f}ms SLO)**: Elastico "
              f"{sel['elastico']['compliance'] * 100:.1f}% compliance vs "
              f"static-accurate {sel['static-accurate']['compliance'] * 100:.1f}% "
              f"(**+{(sel['elastico']['compliance'] - sel['static-accurate']['compliance']) * 100:.1f}pts**, "
              f"paper: +71.6%), accuracy "
              f"+{(sel['elastico']['mean_accuracy'] - sel['static-fast']['mean_accuracy']) * 100:.1f}pts "
              f"over static-fast (paper: +3–5pts).\n")
    if fig6:
        w("**Fig. 6 (latency CDF, spike @ 1000ms SLO)** — percentiles (ms):\n")
        w("| variant | p50 | p95 | p99 | max | compliance |")
        w("|---|---|---|---|---|---|")
        for name, r in fig6.items():
            p = r["percentiles_ms"]
            w(f"| {name} | {p['p50']:.0f} | {p['p95']:.0f} | {p['p99']:.0f} | "
              f"{r['max_ms']:.0f} | {r['compliance'] * 100:.1f}% |")
        w("")
    if fig7:
        rec = fig7.get("recovery_after_spike_s")
        rec_txt = f"{rec:.1f}s" if rec is not None else "n/a"
        w("**Fig. 7 (temporal adaptation)** — "
          f"{len(fig7['switches'])} switches; reaction to the spike edge: "
          f"{fig7['reaction_to_spike_s']:.2f}s; first accuracy-recovery switch "
          f"after the spike: {rec_txt}; settles on rung "
          f"{fig7.get('final_rung')}/{fig7.get('ladder_top')}; compliance "
          f"{fig7['compliance'] * 100:.1f}% at accuracy {fig7['mean_accuracy']:.3f}.\n")


def section_predictive(w):
    rows = load("predictive_ablation.json")
    if not rows:
        return
    w("## §Beyond-paper — predictive adaptation (paper §VIII future work)\n")
    w("`PredictiveElastico` projects queue depth via an EWMA of dN/dt and "
      "fires the AQM upscale condition on the projection — anticipatory "
      "switching from the same (depth, time) signal the reactive controller "
      "sees, so it drops into the simulator AND the threaded engine "
      "unchanged.  Downscale stays reactive (hysteresis already guards it).\n")
    w("| pattern | controller | compliance | accuracy | p95 | switches |")
    w("|---|---|---|---|---|---|")
    for r in rows:
        w(f"| {r['pattern']} | {r['variant']} | {r['compliance'] * 100:.1f}% | "
          f"{r['mean_accuracy']:.3f} | {r['p95_ms']:.0f}ms | {r['switches']} |")
    sp = {r["variant"]: r for r in rows if r["pattern"] == "spike"}
    if "reactive" in sp and "predictive_h3" in sp:
        w(f"\nOn the spike pattern a 3 s horizon buys "
          f"**+{(sp['predictive_h3']['compliance'] - sp['reactive']['compliance']) * 100:.1f}pts "
          f"compliance** for {(sp['reactive']['mean_accuracy'] - sp['predictive_h3']['mean_accuracy']) * 100:.1f}pts "
          "accuracy — the horizon is a continuous compliance/accuracy knob on "
          "top of the paper's discrete ladder.\n")


def section_cost(w):
    d = load("cost_objective.json")
    if not d:
        return
    w("## §Beyond-paper — cost/energy objectives (paper §VIII future work)\n")
    w("Per-rung serving cost (v5e on-demand pricing, 170 W/chip) and the "
      "OPERATING cost of each controller under the spike workload:\n")
    w("| variant | compliance | accuracy | $/1k requests | Wh/1k requests |")
    w("|---|---|---|---|---|")
    for r in d["runs"]:
        w(f"| {r['variant']} | {r['compliance'] * 100:.1f}% | {r['accuracy']:.3f} "
          f"| ${r['usd_per_1k']:.4f} | {r['wh_per_1k']:.2f} |")
    runs = {r["variant"]: r for r in d["runs"]}
    if {"elastico", "static-accurate"} <= set(runs):
        sav = 1 - runs["elastico"]["usd_per_1k"] / runs["static-accurate"]["usd_per_1k"]
        w(f"\nElastico serves the same workload **{sav * 100:.0f}% cheaper** than "
          "static-accurate (and ~proportionally lower energy) while holding "
          "the compliance band — the cost story mirrors the latency story, "
          "quantified per rung in `experiments/cost_objective.json`.\n")


def section_ladders(w):
    rows = load("serving_ladders.json")
    if not rows:
        return
    w("## §Production plane — serving-config ladders per architecture\n")
    w("The paper's pipeline (COMPASS-V -> Planner -> AQM) applied to each "
      "assigned architecture's MODEL-SERVING configuration space (quant dtype, "
      "attention window, MoE top-k, batch cap), with service times from the "
      "analytic v5e decode roofline (32k context).  Attention-free archs "
      "(xlstm) simply have no window axis — the technique operates unchanged "
      "on the remaining knobs (DESIGN.md §4).\n")
    w("| arch | space | feasible | ladder | fast rung | accurate rung | rung speedup |")
    w("|---|---|---|---|---|---|---|")
    for r in rows:
        if "ladder" in r:
            w(f"| {r['arch']} | {r['space']} | {r['feasible']} | {r['ladder']} | "
              f"{r['fast_ms']:.2f}ms | {r['accurate_ms']:.2f}ms | {r['speedup']:.1f}x |")
        else:
            w(f"| {r['arch']} | {r['space']} | {r['feasible']} | — | — | — | — |")
    w("")


def _roofline_rows(rows, mesh):
    return sorted(
        (r for r in rows if r["mesh"] == mesh and "error" not in r),
        key=lambda r: (r["arch"], r["shape"]),
    )


def section_dryrun(w, base, opt):
    w("## §Dry-run — multi-pod lower+compile (deliverable e)\n")
    n16 = len([r for r in base if r["mesh"] == "16x16"])
    n512 = len([r for r in base if r["mesh"] == "2x16x16"])
    w(f"Every (architecture x input-shape) pair lowers AND compiles on both "
      f"production meshes: **{n16}/40 on 16x16 (256 chips)** and "
      f"**{n512}/40 on 2x16x16 (512 chips, pod axis sharded)**; zero failures. "
      "Memory analysis per device and the full collective schedule are in "
      "`experiments/dryrun_results.jsonl`.\n")
    w("Per-device memory (arguments = params+opt+cache shards) stays under the "
      "16 GB v5e HBM for every case; the multi-pod pass halves per-device "
      "argument bytes (pod axis joins FSDP/batch sharding), e.g.:\n")
    w("| arch | shape | mesh | arg bytes/device | temp bytes/device |")
    w("|---|---|---|---|---|")
    shown = 0
    for r in base:
        if r["arch"] in ("llama3-405b", "deepseek-moe-16b") and r["shape"] in ("train_4k", "decode_32k"):
            m = r.get("memory_per_device", {})
            w(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
              f"{m.get('argument_size_bytes', 0) / 1e9:.2f}GB | "
              f"{m.get('temp_size_bytes', 0) / 1e9:.2f}GB |")
            shown += 1
    w("")


def section_roofline(w, base, opt):
    w("## §Roofline — per (arch x shape), single-pod 16x16 (deliverable g)\n")
    w("Terms per the brief: compute = FLOPs/(chips x 197 TF/s), memory = "
      "bytes/(chips x 819 GB/s), collective = collective-bytes/(chips x 50 GB/s "
      "ICI).  FLOP/byte counts come from the trip-count-exact analytic model "
      "(XLA's `cost_analysis` counts scan bodies once — see "
      "`repro/launch/analytic.py`); collective bytes from the HLO parse with "
      "while-loop trip-count correction.  `useful` = MODEL_FLOPS/analytic "
      "FLOPs (6ND rule).  BASELINE = paper-faithful substrate as committed in "
      "`dryrun_results.jsonl`; OPTIMIZED = after the §Perf changes "
      "(`dryrun_results_optimized.jsonl`).\n")
    opt_by = {(r["arch"], r["shape"]): r for r in _roofline_rows(opt, "16x16")} if opt else {}
    w("| arch | shape | kind | compute | memory | collective | bottleneck | useful | optimized step bound |")
    w("|---|---|---|---|---|---|---|---|---|")
    for r in _roofline_rows(base, "16x16"):
        o = opt_by.get((r["arch"], r["shape"]))
        ostep = max(o["compute_s"], o["memory_s"], o["collective_s"]) if o else None
        base_step = max(r["compute_s"], r["memory_s"], r["collective_s"])
        delta = f"{fmt_s(ostep)} ({base_step / ostep:.1f}x)" if ostep else "—"
        w(f"| {r['arch']} | {r['shape']} | {r['kind']} | {fmt_s(r['compute_s'])} | "
          f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
          f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.2f} | {delta} |")
    w("")
    # multi-pod scaling table: does the pod axis buy anything?
    opt16 = {(r["arch"], r["shape"]): r for r in _roofline_rows(opt, "16x16")}
    opt512 = {(r["arch"], r["shape"]): r for r in _roofline_rows(opt, "2x16x16")}
    if opt512:
        w("**Multi-pod scaling (optimized, 256 -> 512 chips)** — per-case step "
          "bound ratio; ~2.0x = perfect weak-scaling of the per-chip terms, "
          "<1.0x would mean the pod axis hurts:\n")
        w("| arch | shape | 16x16 bound | 2x16x16 bound | scaling |")
        w("|---|---|---|---|---|")
        for k in sorted(opt16):
            if k not in opt512:
                continue
            a = max(opt16[k]["compute_s"], opt16[k]["memory_s"], opt16[k]["collective_s"])
            b = max(opt512[k]["compute_s"], opt512[k]["memory_s"], opt512[k]["collective_s"])
            w(f"| {k[0]} | {k[1]} | {fmt_s(a)} | {fmt_s(b)} | {a / b:.2f}x |")
        w("")
    w("Bottleneck census (baseline 16x16): "
      + ", ".join(
          f"{k}: {sum(1 for r in _roofline_rows(base, '16x16') if r['bottleneck'] == k)}"
          for k in ("compute", "memory", "collective")
      )
      + ".  One sentence per dominant term: compute-bound train/prefill cases "
        "need better dispatch or remat policy (see pair A); collective-bound "
        "train cases were dominated by fp32 logits gathers (pair B — fixed); "
        "memory-bound decode cases need KV/weight traffic reduction (pair C).\n")


def section_perf(w):
    rows = load("perf_iterations.jsonl") or []
    w("## §Perf — hillclimbing the three chosen pairs\n")
    w("Pairs chosen per the brief: **deepseek-moe-16b x train_4k** (worst "
      "useful-FLOPs fraction, 0.17), **minitron-4b x train_4k** (most "
      "collective-bound, 2.81s vs 0.47s compute), **llama3-405b x decode_32k** "
      "(most representative of the paper's serving technique: the "
      "capacity-bound arch whose serving ladder Compass switches).  Full "
      "hypothesis -> change -> measure -> verdict log below; every row is an "
      "artifact in `experiments/perf_iterations.jsonl`.\n")
    w("""### Pair A — deepseek-moe-16b x train_4k (compute-bound)

1. **Baseline**: compute 2.046s, collective 1.442s, useful-FLOPs 0.17.  The
   dense MoE dispatch runs all 64 experts on every token.
2. **H1**: capacity-based (GShard) dispatch cuts expert FLOPs by
   ~E/(k*cf) = 64/7.5 = 8.5x on the routed experts; predicted compute
   ~0.4-0.5s.  **Change**: `moe_impl="gshard"`.  **Measured**: compute
   2.046s -> 0.400s (5.1x), useful 0.17 -> 0.88.  **CONFIRMED** (prediction
   within 10%).  Collective now dominates (1.442s) — same fp32-logits gather
   as pair B (102k vocab).
3. **H2**: the pair-B fixes (activation-layout pin + sharded CE) remove the
   logits collectives here too; predicted collective < 0.15s.  **Change**:
   gshard + sharded_ce + act hints.  **Measured**: collective 1.442s ->
   0.100s, step bound 2.046s -> 0.400s (5.1x), now compute-bound at
   useful 0.88.  **CONFIRMED**.
4. **H3**: the remaining gap to 6ND is mostly gshard capacity padding
   (cf=1.25 computes 25% more expert tokens than routed); cf=1.0 should cut
   expert FLOPs ~20% at the cost of dropping overflow tokens under skewed
   routing (a quality knob, like the paper's ladder rungs).  **Change**:
   `capacity_factor=1.0`.  **Measured**: compute 0.400s -> 0.356s, useful
   0.99 — step bound 2.046s -> 0.356s (**5.7x total**), at the 6ND floor.
   Next candidate (router fp32 -> bf16) napkins to <2% — stopped per the
   three-consecutive-<5% rule.

### Pair B — minitron-4b x train_4k (most collective-bound)

1. **Baseline**: collective 2.805s >> compute 0.475s.  Attribution (HLO parse,
   top ops): one 67.11 GB fp32 all-gather + one 67.11 GB all-reduce of
   `f32[256,4096,16000]` — full-batch fp32 logits moving over ICI.
2. **H1**: the `take_along_axis` gold-logit gather over the vocab-sharded
   axis forces the gathers; a reduction-form CE (one-hot dot + max-shifted
   logsumexp) keeps everything vocab-local.  **Change**: `sharded_ce`.
   **Measured**: collective 2.805s -> 4.063s (WORSE: a second 67 GB gather
   appeared).  **REFUTED** — the collectives were not CE-shaped; metadata
   pointed at the unembed `dot_general` itself.
3. **H2**: FSDP shards the unembed weight on BOTH dims ((embed x vocab) ->
   (data, model)); GSPMD re-shards the contraction over 'data' and pays
   full-batch partial-sum all-reduces.  **Change**: exempt vocab-bearing
   params from embed-dim FSDP (`fsdp_vocab=False`).  **Measured**: no change
   (2.845s).  **REFUTED** — operand tracing showed the *residual stream
   itself* entered the unembed sharded on the hidden dim over 'data': the
   partitioner had chosen hidden-sharded activations for the whole stack
   (avoiding FSDP weight gathers) and paid at the unembed.
4. **H3**: pin the activation layout (batch over data axes) at the unembed
   boundary with `with_sharding_constraint`; predicted the 67 GB pair
   disappears leaving ~0.1s of Megatron-style MLP/attention all-reduces.
   **Change**: `shard_hint` on x and logits (`act_hints`).  **Measured**:
   collective 2.805s -> 0.076s with hints alone; 0.040s with hints +
   sharded CE + no-vocab-FSDP.  **CONFIRMED** — step bound 2.805s -> 0.475s
   (**5.9x**), now compute-bound at useful 1.10 (at the 6ND floor; stopped).

### Pair C — llama3-405b x decode_32k (paper-representative serving)

1. **Baseline**: memory-bound 18.08ms/step.  Napkin decomposition per chip:
   KV cache 8.4 GB (126L x 128B x 32k x 8kv x 128hd bf16 / 256 chips) + fp32
   weights 6.3 GB + activations.
2. **H1**: int8 KV with per-(token, head) absmax scales halves KV traffic;
   predicted ~ -5ms.  **Change**: `kv_cache_dtype="int8"` (real quantized
   cache, validated <2% logit error, greedy-identical in
   tests/test_kv_int8.py).  **Measured**: 18.08 -> 13.08ms.  **CONFIRMED**
   (-5.0ms).
3. **H2**: serving should keep weights resident in bf16 (fp32 master copies
   are a training concern); predicted ~ -3.9ms.  **Change**:
   `param_dtype="bfloat16"` serving variant.  **Measured**: 13.08 -> 9.21ms.
   **CONFIRMED** (-3.87ms).  Both H1+H2 are quality-preserving (**1.96x**
   total) — this is the *beyond-paper optimized* serving point.
4. **H3 (paper-faithful ladder rung)**: a sliding-window-8k variant is the
   Compass accuracy-trading fast rung (the paper's own mechanism!); KV reads
   drop 4x.  **Measured**: 9.21 -> 5.21ms (**3.5x vs baseline**).  Recorded
   as a ladder rung with its accuracy cost, not as a free win: AQM thresholds
   from these service times give the 405B ladder Fast=5.2ms /
   Balanced=9.2ms / Accurate=18.1ms — exactly the paper's Table-I structure,
   derived from roofline terms instead of RTX-4090 wall-clock (DESIGN §3).

**Optimized defaults**: act-hints + sharded CE + no-vocab-FSDP are now the
framework defaults; the re-run of all 40 pairs
(`dryrun_results_optimized.jsonl`) keeps 40/40 compiling and improves every
collective-bound train case (up to 18.1x on seamless-m4t train_4k, geomean
1.34x across all 40 single-pod cases) — the optimized-step-bound column in
the §Roofline table.
""")
    if rows:
        w("| arch | shape | variant | compute | memory | collective | bottleneck | useful |")
        w("|---|---|---|---|---|---|---|---|")
        latest = {}
        for r in rows:   # keep the LAST measurement of each variant (the
            latest[(r["arch"], r["shape"], r["variant"])] = r  # code evolves)
        for r in latest.values():
            w(f"| {r['arch']} | {r['shape']} | {r['variant']} | "
              f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
              f"{fmt_s(r['collective_s'])} | {r['bottleneck']} | "
              f"{r['useful_flops_ratio']:.2f} |")
        w("")


def section_benchhist(w):
    from repro.tools import benchhist

    repo_root = os.path.join(EXPERIMENTS_DIR, "..")
    trends = benchhist.render_trends(repo_root)
    if not trends:
        return
    w("## §Benchmark history — per-PR perf trajectories\n")
    w("Every registered benchmark records its gate-worthy measurements into "
      "an append-only `BENCH_<name>.json` trajectory at the repo root "
      "(`python -m benchmarks.run --smoke --record`); "
      "`python -m benchmarks.run --gate-all` compares the latest run against "
      "the median of the recent same-mode window and fails on any "
      "direction-aware regression beyond the per-measurement tolerance "
      "(docs/performance.md §9).\n")
    for line in trends:
        w(line)


def main() -> None:
    base = load("dryrun_results.jsonl") or []
    opt = load("dryrun_results_optimized.jsonl") or []
    lines = []
    w = lines.append
    w("# EXPERIMENTS — Compass reproduction + production-plane results\n")
    w("All numbers regenerate via `PYTHONPATH=src python -m benchmarks.run` "
      "(paper figures), `python -m repro.launch.dryrun` (dry-run/roofline) and "
      "`python -m repro.launch.perf` (perf iterations); this file renders from "
      "the artifacts via `python -m benchmarks.render_report`.\n")

    # paper-claim validation table
    w("## Paper-claim validation (reproduction vs paper)\n")
    w("| claim | paper | this repro | verdict |")
    w("|---|---|---|---|")
    fig4 = load("fig4_efficiency.json")
    fig5 = load("fig5_slo_compliance.json")
    fig1 = load("fig1_pareto.json")
    if fig4:
        allr = fig4["rag"] + fig4["detection"]
        rec = min(r["recall"] for r in allr)
        sav = sum(r["savings"] for r in allr) / len(allr)
        mx = max(r["savings"] for r in allr)
        w(f"| COMPASS-V recall vs exhaustive | 100% | {rec * 100:.0f}% | "
          f"{'reproduced' if rec >= 1.0 else 'PARTIAL'} |")
        w(f"| Evaluation savings (mean / max) | 57.5% / 95.3% | "
          f"{sav * 100:.1f}% / {mx * 100:.1f}% | qualitative (convex curve "
          f"reproduced; magnitude depends on surrogate score-variance near tau) |")
    if fig5:
        spike = [r for r in fig5 if r["pattern"] == "spike"]
        slos = sorted({r["slo_ms"] for r in spike})
        mid = slos[len(slos) // 2]
        sel = {r["variant"]: r for r in spike if r["slo_ms"] == mid}
        el = sel["elastico"]
        comp_all = [r["compliance"] for r in fig5 if r["variant"] == "elastico"]
        w(f"| Elastico SLO compliance band | 90–98% | "
          f"{min(comp_all) * 100:.0f}–{max(comp_all) * 100:.0f}% | reproduced |")
        w(f"| vs static-accurate compliance | +71.6% | "
          f"+{(el['compliance'] - sel['static-accurate']['compliance']) * 100:.1f}pts | reproduced |")
        w(f"| vs static-fast accuracy | +3–5pts | "
          f"+{(el['mean_accuracy'] - sel['static-fast']['mean_accuracy']) * 100:.1f}pts | reproduced |")
    if fig1:
        h = fig1["headline"]
        w(f"| Pareto trade (Fig. 1) | 1.6x P95 for 2% F1 | "
          f"{h['p95_speedup_within_2pct']:.2f}x for "
          f"{h['accuracy_drop'] * 100:.1f}% | reproduced |")
    w("")

    section_compass_v(w)
    section_elastico(w)
    section_predictive(w)
    section_ladders(w)
    section_cost(w)
    section_dryrun(w, base, opt)
    section_roofline(w, base, opt)
    section_perf(w)
    section_benchhist(w)

    with open(OUT, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {OUT} ({len(lines)} lines)")


if __name__ == "__main__":
    main()
