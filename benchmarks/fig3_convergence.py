"""Fig. 3: COMPASS-V anytime convergence across 8 accuracy SLOs (RAG).

For each threshold: feasible-configs-discovered vs samples consumed, against
the grid-search best/worst envelope, plus terminal recall.
"""

from __future__ import annotations

from repro.workflows.surrogate import RagSurrogate, paper_rag_thresholds

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import RAG_BUDGET, Timer, ground_truth, save_json, search

# Trajectory measurements (BENCH_fig3_convergence.json): anytime
# convergence vs the exhaustive grid — worst-case recall across the
# paper's tau thresholds (claim: 100%) and the mean fraction of grid
# samples COMPASS-V spends to get there.
BENCH_SPEC = BenchmarkSpec(
    artifact="fig3_convergence.json",
    measurements=(
        MeasurementSpec(
            "min_recall", "frac", True,
            extract=lambda rows: min(r["recall"] for r in rows),
            target=1.0, tolerance=0.01),
        MeasurementSpec(
            "mean_sample_fraction", "frac", False,
            extract=lambda rows: (sum(r["samples"] for r in rows)
                                  / sum(r["grid_samples"] for r in rows)),
            tolerance=0.15),
    ),
)


def run() -> dict:
    sur = RagSurrogate(seed=0)
    max_budget = RAG_BUDGET[-1]
    out = []
    with Timer() as t:
        for tau in paper_rag_thresholds():
            gt = ground_truth(sur, tau, max_budget)
            res = search(sur, tau, RAG_BUDGET)
            n_feas = len(gt.feasible)
            # grid-search envelope (paper Fig. 3 shading): best case finds all
            # feasible configs in the first n_feas * B evaluations, worst case
            # in the last.
            out.append(
                {
                    "tau": tau,
                    "feasible": n_feas,
                    "feasible_fraction": n_feas / sur.space.cardinality,
                    "recall": res.recall(list(gt.feasible)),
                    "samples": res.samples_consumed,
                    "grid_samples": gt.samples_consumed,
                    "grid_best_case": n_feas * max_budget,
                    "grid_worst_case": gt.samples_consumed,
                    "trace": [
                        [p.samples, p.feasible_found] for p in res.trace[:: max(1, len(res.trace) // 60)]
                    ],
                }
            )
    save_json("fig3_convergence.json", out)
    recalls = [row["recall"] for row in out]
    return {
        "name": "fig3_convergence",
        "us_per_call": t.elapsed / len(out) * 1e6,
        "derived": f"recall_min={min(recalls):.3f} thresholds={len(out)}",
    }


if __name__ == "__main__":
    print(run())
