"""Production-plane integration: Compass ladders for every assigned arch.

For each of the 10 architectures, run COMPASS-V + Planner + AQM over the
arch's model-serving configuration space (quant / window / MoE top-k / batch
cap, service times from the analytic v5e roofline model at decode_32k) and
report the resulting switching ladder — the paper's technique operating on
the production plane.

Each derived plan is additionally *validated* offline
(:meth:`repro.core.planner.Planner.validate`): every ladder rung is
replayed against a grid of arrival rates through the vectorized batched
sweep (:func:`repro.serving.fastsim.simulate_batch`), confirming the
fastest rung holds the 30 ms decode-step SLO at the loads the ladder
claims to cover — hundreds of thousands of simulated requests per run,
affordable only on the fast path.
"""

from __future__ import annotations

import math

import repro.configs  # noqa: F401
from repro.core.compass_v import CompassV
from repro.core.planner import Planner
from repro.launch.analytic import serving_config_costs
from repro.models.registry import arch_ids, get_config

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import Timer, save_json

# Trajectory measurements (BENCH_serving_ladders.json): the ladder
# surface across all assigned architectures — how many spaces produced a
# ladder, the widest rung speedup, and the validated fast-rung compliance
# floor.  All derived from the analytic roofline + seeded sweeps, so
# drift means the planning pipeline itself changed.
BENCH_SPEC = BenchmarkSpec(
    artifact="serving_ladders.json",
    smoke_artifact="serving_ladders_smoke.json",
    measurements=(
        MeasurementSpec(
            "ladder_count", "ladders", True,
            extract=lambda rows: sum(1 for r in rows if "ladder" in r),
            tolerance=0.01),
        MeasurementSpec(
            "max_rung_speedup", "x", True,
            extract=lambda rows: max(r["speedup"] for r in rows
                                     if "ladder" in r),
            tolerance=0.05),
        MeasurementSpec(
            "fast_rung_min_compliance", "frac", True,
            extract=lambda rows: min(
                r["fast_rung_min_compliance"] for r in rows
                if "fast_rung_min_compliance" in r),
            tolerance=0.10),
    ),
)

# import the space builder from the example (single source of truth)
import importlib.util
import os

_spec = importlib.util.spec_from_file_location(
    "serving_ladders_example",
    os.path.join(os.path.dirname(__file__), "..", "examples", "serving_ladders.py"),
)
_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(_mod)
serving_space = _mod.serving_space

TAU = 0.9          # relative-accuracy floor
SLO_S = 0.030      # 30 ms P95 per decode step


def build_ladder(arch: str, *, validate_duration_s: float = 10.0,
                 validate_replications: int = 3):
    cfg = get_config(arch)
    space = serving_space(cfg)

    def evaluate(config, idx):
        d = space.as_dict(config)
        acc, _ = serving_config_costs(cfg, d)
        out = []
        for i in idx:
            import zlib
            u = (zlib.crc32(repr((arch, sorted(d.items()), i)).encode()) & 0xFFFF) / 0xFFFF
            out.append(1.0 if u < acc else acc * 0.5)
        return out

    res = CompassV(space=space, evaluator=evaluate, tau=TAU,
                   budget_schedule=(16, 48, 128), seed=0).run()
    if not res.feasible:
        return space, res, None, None

    def profiler(config, n):
        d = space.as_dict(config)
        _, service_s = serving_config_costs(cfg, d)
        return [service_s * (1.0 + 0.03 * math.sin(i)) for i in range(n)]

    planner = Planner(profiler=profiler, slack_buffer_s=0.002)
    plan = planner.plan(res.feasible, slo_p95_s=SLO_S)
    validation = planner.validate(plan, duration_s=validate_duration_s,
                                  replications=validate_replications, seed=0)
    return space, res, plan, validation


def run(*, validate_duration_s: float = 10.0, validate_replications: int = 3,
        artifact: str = "serving_ladders.json", stable: bool = False) -> dict:
    rows = []
    validated_requests = 0
    with Timer() as t:
        for arch in arch_ids():
            space, res, plan, validation = build_ladder(
                arch, validate_duration_s=validate_duration_s,
                validate_replications=validate_replications)
            row = {
                "arch": arch,
                "space": space.cardinality,
                "feasible": len(res.feasible),
                "evals": res.num_evaluations,
            }
            if plan is not None and plan.table.ladder_size > 0:
                pols = plan.table.policies
                row.update(
                    ladder=plan.table.ladder_size,
                    fast_ms=pols[0].point.profile.mean * 1e3,
                    accurate_ms=pols[-1].point.profile.mean * 1e3,
                    fast_rel_acc=pols[0].point.accuracy,
                    speedup=pols[-1].point.profile.mean / pols[0].point.profile.mean,
                )
            if validation is not None:
                validated_requests += validation.num_requests
                # compliance of the fastest rung across the load grid
                # (fractions of its own capacity): at 0.9 load even the
                # fastest rung can miss a tight decode SLO — exactly the
                # regime the switching thresholds exist to avoid, which is
                # what makes the surface worth validating offline
                row.update(
                    validated_requests=validation.num_requests,
                    fast_rung_min_compliance=min(validation.slo_compliance[0]),
                    wait_model_max_rel_err=validation.wait_model_error(),
                )
            rows.append(row)
    save_json(artifact, rows, stable=stable)
    withladders = [r for r in rows if "ladder" in r]
    max_speedup = max(r["speedup"] for r in withladders)
    validated = [r for r in rows if "fast_rung_min_compliance" in r]
    min_fast_comp = min(r["fast_rung_min_compliance"] for r in validated)
    return {
        "name": "serving_ladders",
        "us_per_call": t.elapsed / len(rows) * 1e6,
        "derived": (
            f"archs={len(rows)} ladders={len(withladders)} "
            f"max_rung_speedup={max_speedup:.1f}x "
            f"validated={validated_requests} reqs "
            f"fast_rung_min_comp={min_fast_comp:.3f}"
        ),
    }


def run_smoke() -> dict:
    """Same ladders, smallest validation sweep; writes its own
    stable-scrubbed artifact so the smoke gate never overwrites the
    committed full-run evidence and reruns are byte-identical."""
    return run(validate_duration_s=2.0, validate_replications=2,
               artifact="serving_ladders_smoke.json", stable=True)


if __name__ == "__main__":
    print(run())
