"""Roofline table from the committed multi-pod dry-run artifact.

Reads experiments/dryrun_results.jsonl (written by
``PYTHONPATH=src python -m repro.launch.dryrun``) and reports the
compute/memory/collective terms per (arch x shape x mesh) with the dominant
bottleneck — deliverable (g).
"""

from __future__ import annotations

import json
import os
from collections import Counter

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import EXPERIMENTS_DIR, save_json

DRYRUN = os.path.join(EXPERIMENTS_DIR, "dryrun_results.jsonl")


def _single_pod(rows):
    return [r for r in rows if r["mesh"] == "16x16"]


def _geomean_step_bound(rows):
    import math

    bounds = [r["step_lower_bound_s"] for r in _single_pod(rows)]
    return math.exp(sum(math.log(b) for b in bounds) / len(bounds))


# Trajectory measurements (BENCH_roofline_table.json): the roofline
# surface — every (arch x shape) pair still compiles (40 on the single
# pod), and the geometric-mean step lower bound across them, the one
# number that moves when an optimization (or a regression) lands in the
# analytic serving model.
BENCH_SPEC = BenchmarkSpec(
    artifact="roofline_table.json",
    measurements=(
        MeasurementSpec(
            "single_pod_pairs", "pairs", True,
            extract=lambda rows: len(_single_pod(rows)),
            target=40.0, tolerance=0.01),
        MeasurementSpec(
            "geomean_step_bound_s", "s", False,
            extract=_geomean_step_bound, tolerance=0.10),
    ),
)


def load_rows():
    if not os.path.exists(DRYRUN):
        raise FileNotFoundError(
            "run `PYTHONPATH=src python -m repro.launch.dryrun` first"
        )
    return [json.loads(l) for l in open(DRYRUN)]


def run_smoke() -> dict:
    """Smallest setting: report the table when the dry-run artifact exists,
    otherwise skip cleanly — a fresh checkout has no
    experiments/dryrun_results.jsonl, and the smoke gate's job here is only
    to prove the module still imports and its pipeline still parses."""
    if not os.path.exists(DRYRUN):
        return {
            "name": "roofline_table",
            "us_per_call": 0.0,
            "derived": "SKIPPED (no dryrun artifact; run repro.launch.dryrun)",
        }
    return run()


def run() -> dict:
    rows = load_rows()
    table = []
    for r in rows:
        step = max(r["compute_s"], r["memory_s"], r["collective_s"])
        table.append(
            {
                "arch": r["arch"],
                "shape": r["shape"],
                "mesh": r["mesh"],
                "kind": r["kind"],
                "compute_s": r["compute_s"],
                "memory_s": r["memory_s"],
                "collective_s": r["collective_s"],
                "bottleneck": r["bottleneck"],
                "useful_flops_ratio": r["useful_flops_ratio"],
                "step_lower_bound_s": step,
            }
        )
    bn = Counter((t["mesh"], t["bottleneck"]) for t in table)
    save_json("roofline_table.json", table)
    single = [t for t in table if t["mesh"] == "16x16"]
    return {
        "name": "roofline_table",
        "us_per_call": 0.0,
        "derived": (
            f"pairs={len(single)} bottlenecks="
            + ",".join(f"{k[1]}@{k[0]}:{v}" for k, v in sorted(bn.items()))
        ),
    }


if __name__ == "__main__":
    print(run())
