"""Shared benchmark machinery: pipeline builders mirroring the paper's setup."""

from __future__ import annotations

import json
import os
import random
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.compass_v import CompassV, exhaustive_search
from repro.core.elastico import ElasticoController
from repro.core.planner import Planner
from repro.serving import fastsim

# the canonical volatile-key filter lives with the benchmark-history
# schema (the trajectory serializer scrubs run context with the same
# notion of "wall-clock dependent" the stable artifacts use); re-exported
# here so benchmark modules and tests keep importing it from common
from repro.tools.benchhist import VOLATILE_KEYS, scrub_volatile  # noqa: F401
from repro.serving.workload import (
    bursty_pattern,
    diurnal_pattern,
    generate_arrivals,
    spike_pattern,
)
from repro.workflows.surrogate import DetectionSurrogate, RagSurrogate

EXPERIMENTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")

RAG_BUDGET = (10, 25, 50, 100)
DET_BUDGET = (20, 50, 100, 200)

# Pre-scrub payload of the most recent save_json() per artifact name.
# `benchmarks.run --record` extracts trajectory measurements from here so
# wall-clock values (throughput, speedups) are recordable even when the
# on-disk smoke artifact is stable-scrubbed for byte-idempotence.
LAST_PAYLOADS: Dict[str, object] = {}


def save_json(name: str, payload, *, stable: bool = False) -> str:
    """Write an experiment artifact.  ``stable=True`` scrubs volatile keys
    (:func:`scrub_volatile`) first — use it for smoke artifacts that test
    gates regenerate, so reruns are diff-clean.  The pre-scrub payload is
    kept in :data:`LAST_PAYLOADS` for ``--record``."""
    LAST_PAYLOADS[name] = payload
    if stable:
        payload = scrub_volatile(payload)
    os.makedirs(EXPERIMENTS_DIR, exist_ok=True)
    path = os.path.join(EXPERIMENTS_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path


def _stable_seed(config) -> int:
    import zlib

    # hash() is per-process salted (PYTHONHASHSEED); profiles must be
    # deterministic across runs for reproducible figures
    return zlib.crc32(repr(config).encode()) & 0xFFFF


def make_profiler(surrogate):
    def profiler(config, n):
        rng = random.Random(_stable_seed(config))
        m = surrogate.mean_latency_s(config)
        cv = surrogate.latency_cv(config)
        return [max(1e-4, rng.gauss(m, m * cv)) for _ in range(n)]

    return profiler


def search(surrogate, tau, budget, seed=0):
    return CompassV(
        space=surrogate.space,
        evaluator=surrogate,
        tau=tau,
        budget_schedule=budget,
        seed=seed,
    ).run()


def ground_truth(surrogate, tau, max_budget):
    return exhaustive_search(surrogate.space, surrogate, tau, max_budget)


def plan_for(surrogate, feasible, slo_s):
    return Planner(profiler=make_profiler(surrogate)).plan(feasible, slo_p95_s=slo_s)


def make_sampler(surrogate, ladder):
    def sampler(idx, rng):
        cfg = ladder[idx].point.config
        m = surrogate.mean_latency_s(cfg)
        cv = surrogate.latency_cv(cfg)
        return max(1e-4, rng.gauss(m, m * cv))

    return sampler


def simulate(surrogate, plan, arrivals, duration_s, *, controller=None, static=0,
             seed=0, num_servers=1):
    """One serving run via the :func:`repro.serving.fastsim.simulate`
    dispatcher: static baselines take the vectorized Lindley fast path
    (bit-for-bit identical to the event heap), controller runs fall back
    to the event-heap oracle."""
    ladder = plan.table.policies
    out = fastsim.simulate(
        make_sampler(surrogate, ladder),
        arrivals,
        duration_s,
        controller=controller,
        static_index=static,
        seed=seed,
        num_servers=num_servers,
    )
    rung_accs = [pol.point.accuracy for pol in ladder]
    mean_acc = out.mean_accuracy(rung_accs)   # 0.0 when nothing completed
    return out, mean_acc


# paper §VI-C setup: 180 s runs, base 1.5 QPS scaled to capacity
PAPER_DURATION_S = 180.0
PAPER_BASE_QPS = 1.5


def paper_arrivals(pattern: str, seed: int = 1, base_qps: float = PAPER_BASE_QPS):
    if pattern == "spike":
        rate = spike_pattern(base_qps, factor=4.0, duration_s=PAPER_DURATION_S)
    elif pattern == "bursty":
        rate = bursty_pattern(base_qps, duration_s=PAPER_DURATION_S, seed=seed)
    elif pattern == "diurnal":
        rate = diurnal_pattern(base_qps * 2.0, period_s=PAPER_DURATION_S)
    else:
        raise ValueError(pattern)
    return generate_arrivals(rate, PAPER_DURATION_S, seed=seed)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0
