"""Million-user trace replay: day-scale chunked traces through the full
mix ladder, plus the Planner validation the replay feeds.

The paper's serving experiments replay 180 s traces; a real deployment
decides its ladder against *weeks* of diurnal load.  This benchmark runs
that scale offline:

- **Diurnal replay** (the headline): a ~29-simulated-day diurnal trace —
  >= 1e7 requests — generated chunk by chunk
  (:func:`repro.serving.traces.diurnal_trace`) and replayed through every
  rung of the RAG plan's switching ladder simultaneously
  (:func:`repro.serving.traces.replay_mix`).  Memory stays O(chunk); the
  fast rungs hold the SLO across the daily peak while the accurate rungs
  saturate — the regime split the switching thresholds exist for.  No
  event-heap fallback anywhere: the replay runs on the streaming
  Lindley engines, start to finish.
- **Flash-crowd and bursty-MMPP replays**: shorter stress traces through
  the same ladder, exercising the other two chunked generators.
- **Diurnal pipeline replay**: the same >= 1e7-request diurnal trace
  streamed through the fastest rung decomposed into its
  retrieve -> rerank -> generate tandem
  (:func:`repro.serving.traces.replay_dag`), carrying per-stage backlogs
  across chunk boundaries — on the numpy chained closed form and, when
  importable, on the fused jitted jax chunk engine
  (``backend="jax"``, the >= ~1.3M req/s acceptance measurement).
- **Planner validation**: the same plan is validated with
  :meth:`repro.core.planner.Planner.validate` at the diurnal trace's
  base / mean / peak rates (``backend="auto"``, which at this grid size
  resolves to the jax sweep backend when jax is importable) — the
  replay supplies the load levels, the Planner confirms its ladder
  against them.

Writes ``experiments/trace_replay.json`` (metadata, per-rung replay
statistics, validation summary).  Acceptance: the default run's diurnal
section replays >= 1e7 requests across the full ladder.
"""

from __future__ import annotations

from repro.core.planner import Planner
from repro.serving import fastsim
from repro.serving.traces import (
    bursty_mmpp_trace,
    diurnal_trace,
    flash_crowd_trace,
    replay_dag,
    replay_mix,
)
from repro.workflows.surrogate import RagSurrogate

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import RAG_BUDGET, Timer, make_profiler, save_json, search

# Trajectory measurements (BENCH_trace_replay.json): the streaming-replay
# throughput headline (wall-clock, volatile, recorded from the pre-scrub
# payload) plus the seed-deterministic replay quality surface — the fast
# rung's diurnal compliance and the Planner-validation wait-model fit.
BENCH_SPEC = BenchmarkSpec(
    artifact="trace_replay.json",
    smoke_artifact="trace_replay_smoke.json",
    measurements=(
        MeasurementSpec("diurnal_replay_rps", "req/s", True,
                        path="diurnal.rps", volatile=True),
        MeasurementSpec("flash_crowd_replay_rps", "req/s", True,
                        path="flash_crowd.rps", volatile=True),
        MeasurementSpec("diurnal_requests", "requests", True,
                        path="diurnal.requests", tolerance=0.01),
        MeasurementSpec("diurnal_fast_rung_compliance", "frac", True,
                        path="diurnal.rungs.0.slo_compliance",
                        tolerance=0.05),
        MeasurementSpec("wait_model_max_rel_err", "frac", False,
                        path="validation.wait_model_max_rel_err",
                        tolerance=0.25),
        MeasurementSpec("pipeline_replay_rps", "req/s", True,
                        path="pipeline_replay.rps", volatile=True),
        MeasurementSpec("pipeline_replay_jax_rps", "req/s", True,
                        path="pipeline_replay.jax_rps", target=1.3e6,
                        volatile=True, smoke=False, optional=True),
        MeasurementSpec("pipeline_e2e_compliance", "frac", True,
                        path="pipeline_replay.e2e_slo_compliance",
                        tolerance=0.05),
    ),
)
from .fastsim_bench import run_metadata

TAU = 0.75          # relative-accuracy floor (table1/fig7 setting)
SLO_S = 1.0         # 1000 ms p95, the paper's serving SLO
BASE_UTIL = 0.55    # diurnal base load as a fraction of the fastest rung
AMPLITUDE = 0.65    # daily swing: peak ~ 0.9x the fastest rung's capacity


def build_plan():
    """The RAG switching ladder, planned exactly like the paper-pipeline
    benchmarks, with the Planner kept for validation."""
    sur = RagSurrogate()
    res = search(sur, TAU, RAG_BUDGET)
    planner = Planner(profiler=make_profiler(sur))
    plan = planner.plan(res.feasible, slo_p95_s=SLO_S)
    return sur, planner, plan


def _ladder_stats(plan):
    means = [pol.point.profile.mean for pol in plan.table.policies]
    p95s = [pol.point.profile.p95 for pol in plan.table.policies]
    return means, p95s


def _replay_section(trace, means, p95s, *, seed: int) -> dict:
    with Timer() as t:
        stats = replay_mix(trace, means, p95s, slo_s=SLO_S, seed=seed)
    n = stats[0].num_requests
    return {
        "requests": n,
        "wall_s": t.elapsed,
        "rps": n / t.elapsed,
        "engine": stats[0].engine,
        "trace_duration_s": trace.duration_s,
        "rungs": [
            {
                "mean_s": means[k],
                "mean_wait_s": s.mean_wait_s,
                "p95_latency_s": s.p95_latency_s,
                "p95_resolution_s": s.p95_resolution_s,
                "slo_compliance": s.slo_compliance,
                "max_latency_s": s.max_latency_s,
            }
            for k, s in enumerate(stats)
        ],
    }


def _pipeline_replay_section(trace, sur, plan, *, seed: int) -> dict:
    """Stream the diurnal trace through the fastest rung's stage tandem
    (:func:`repro.serving.traces.replay_dag`): numpy chained closed form
    timed as the reference, the fused jax chunk engine timed next to it
    when importable (jax-less installs record the skip reason)."""
    from .dag_bench import STAGE_ORDER, _p95_from_cv

    fastest = plan.table.policies[0]
    parts = sur.stage_latencies_s(fastest.point.config)
    cv = sur.latency_cv(fastest.point.config)
    stage_means = [parts[name] for name in STAGE_ORDER]
    stage_p95s = [_p95_from_cv(m, cv) for m in stage_means]

    with Timer() as t:
        stats = replay_dag(trace, stage_means, stage_p95s, slo_s=SLO_S,
                           seed=seed)
    n = stats.end_to_end.num_requests
    out = {
        "requests": n,
        "stages": list(STAGE_ORDER),
        "stage_means_s": stage_means,
        "wall_s": t.elapsed,
        "rps": n / t.elapsed,
        "engine": stats.end_to_end.engine,
        "e2e_mean_latency_s": stats.end_to_end.mean_latency_s,
        "e2e_p95_latency_s": stats.end_to_end.p95_latency_s,
        "e2e_slo_compliance": stats.end_to_end.slo_compliance,
        "stage_mean_wait_s": [s.mean_wait_s for s in stats.stages],
    }
    if fastsim.jax_available():
        # jit compile cost rides in the wall clock: a streaming engine
        # pays it once per chunk shape, amortized over >= 1e7 requests
        with Timer() as tj:
            jstats = replay_dag(trace, stage_means, stage_p95s,
                                slo_s=SLO_S, seed=seed, backend="jax")
        out["jax_wall_s"] = tj.elapsed
        out["jax_rps"] = n / tj.elapsed
        out["jax_engine"] = jstats.end_to_end.engine
    else:
        out["jax_skipped"] = (f"jax not importable "
                              f"({fastsim.jax_unavailable_reason()})")
    return out


def _run(*, target_requests: float, artifact: str,
         stable: bool = False) -> dict:
    sur, planner, plan = build_plan()
    means, p95s = _ladder_stats(plan)
    cap = 1.0 / means[0]                     # fastest rung's drain rate
    base = BASE_UTIL * cap
    duration = target_requests / base        # mean diurnal rate == base

    with Timer() as t:
        diurnal = diurnal_trace(base, amplitude=AMPLITUDE,
                                duration_s=duration, seed=11)
        sections = {
            "diurnal": _replay_section(diurnal, means, p95s, seed=11),
            "flash_crowd": _replay_section(
                flash_crowd_trace(base, peak_factor=1.8 / BASE_UTIL,
                                  crowd_start_s=600.0, ramp_s=30.0,
                                  hold_s=300.0,
                                  duration_s=min(duration / 8.0, 7200.0),
                                  seed=12),
                means, p95s, seed=12),
            "bursty_mmpp": _replay_section(
                bursty_mmpp_trace(base * 0.7, burst_factor=1.6 / BASE_UTIL,
                                  duration_s=min(duration / 8.0, 7200.0),
                                  seed=13),
                means, p95s, seed=13),
            "pipeline_replay": _pipeline_replay_section(
                diurnal, sur, plan, seed=11),
        }

        # validate the plan at the load levels the diurnal replay covers:
        # base, daily mean, daily peak of the fastest rung's capacity
        rates = [base, base * (1.0 + AMPLITUDE / 2.0),
                 base * (1.0 + AMPLITUDE)]
        validation = planner.validate(
            plan, arrival_rates_qps=rates, duration_s=900.0,
            replications=8, seed=0, backend="auto")
        sweep_slots = (8 * len(means) * len(rates)
                       * int(rates[-1] * 900.0 * 1.1))
        validation_backend = fastsim.resolve_backend(
            "auto", num_servers=1, total_slots=sweep_slots)

    payload = {
        "metadata": run_metadata(),
        "ladder": {"rungs": len(means), "fastest_mean_s": means[0],
                   "slowest_mean_s": means[-1], "slo_s": SLO_S},
        **sections,
        "validation": {
            "backend": validation_backend,
            "arrival_rates_qps": list(validation.arrival_rates_qps),
            "num_requests": validation.num_requests,
            "fast_rung_min_compliance": min(validation.slo_compliance[0]),
            "wait_model_max_rel_err": validation.wait_model_error(),
        },
    }
    save_json(artifact, payload, stable=stable)
    d = sections["diurnal"]
    pr = sections["pipeline_replay"]
    pipe = (f" pipeline@{pr['jax_rps'] / 1e6:.2f}M req/s (jax)"
            if "jax_rps" in pr
            else f" pipeline@{pr['rps'] / 1e6:.2f}M req/s (numpy)")
    ok = d["requests"] >= 1e7
    return {
        "name": "trace_replay",
        "us_per_call": t.elapsed * 1e6,
        "derived": (
            f"diurnal={d['requests']} reqs over {duration / 86400.0:.1f} "
            f"days @ {d['rps'] / 1e6:.2f}M req/s engine={d['engine']} "
            f"fast_rung_comp={d['rungs'][0]['slo_compliance']:.4f} "
            f"validated={payload['validation']['num_requests']} reqs "
            f"on {validation_backend}" + pipe
            + ("" if ok or "smoke" in artifact
               else " [<1e7 requests: acceptance FAILED]")
        ),
    }


def run() -> dict:
    return _run(target_requests=1.05e7, artifact="trace_replay.json")


def run_smoke() -> dict:
    """Same pipeline at ~1e5 requests (a few simulated hours); separate
    artifact so the smoke gate never overwrites the full-run evidence.
    ``stable=True``: the smoke artifact is scrubbed of wall-clock and
    host-dependent keys, so the tier-1 gate's rerun is diff-clean."""
    return _run(target_requests=1e5, artifact="trace_replay_smoke.json",
                stable=True)


if __name__ == "__main__":
    print(run())
