"""Benchmark driver: one function per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [name ...]
        PYTHONPATH=src python -m benchmarks.run --check-docs

Prints ``name,us_per_call,derived`` CSV and writes per-benchmark JSON
artifacts into experiments/.  ``--check-docs`` runs the documentation
cross-reference checker (:mod:`repro.tools.docscheck`) instead of any
benchmark and exits non-zero on stale references.
"""

from __future__ import annotations

import sys
import traceback

from . import (
    cost_objective,
    fig1_pareto,
    predictive_ablation,
    fig3_convergence,
    fig4_efficiency,
    fig5_slo_compliance,
    fig6_latency_cdf,
    fig7_timeseries,
    kernels_bench,
    multi_server_bench,
    roofline_table,
    serving_ladders_bench,
    table1_baselines,
)

BENCHES = {
    "fig1_pareto": fig1_pareto.run,
    "fig3_convergence": fig3_convergence.run,
    "fig4_efficiency": fig4_efficiency.run,
    "table1_baselines": table1_baselines.run,
    "fig5_slo_compliance": fig5_slo_compliance.run,
    "fig6_latency_cdf": fig6_latency_cdf.run,
    "fig7_timeseries": fig7_timeseries.run,
    "kernels_bench": kernels_bench.run,
    "predictive_ablation": predictive_ablation.run,
    "serving_ladders": serving_ladders_bench.run,
    "multi_server": multi_server_bench.run,
    "cost_objective": cost_objective.run,
    "roofline_table": roofline_table.run,
}


def main() -> None:
    if "--check-docs" in sys.argv[1:]:
        from repro.tools.docscheck import main as docscheck_main

        sys.exit(docscheck_main())
    names = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            row = BENCHES[name]()
            print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
