"""Benchmark driver: one function per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [--smoke] [--record] [name ...]
        PYTHONPATH=src python -m benchmarks.run --check-docs
        PYTHONPATH=src python -m benchmarks.run --perf-gate
        PYTHONPATH=src python -m benchmarks.run --gate-all [--bench-dir=PATH]

Prints ``name,us_per_call,derived`` CSV and writes per-benchmark JSON
artifacts into experiments/.  ``--check-docs`` runs the documentation
cross-reference checker (:mod:`repro.tools.docscheck`) instead of any
benchmark and exits non-zero on stale references.

``--smoke`` runs each selected benchmark at its smallest setting: a module
that defines ``run_smoke()`` (reduced durations / sweep sizes, same code
paths) runs that; modules without one run their normal ``run()`` — the
fallback keeps the smoke sweep total, so a bit-rotted benchmark fails fast
either way.  CI uses this as a cheap all-benchmarks gate.

``--record`` appends one :class:`repro.tools.benchhist.BenchRun` per
successfully-run benchmark to its ``BENCH_<name>.json`` trajectory
(repo root by default; ``--bench-dir=PATH`` redirects, which is how tests
record into a tmpdir without touching the committed history).  Each
module declares its gate-worthy measurements as a module-level
``BENCH_SPEC`` (:class:`repro.tools.benchhist.BenchmarkSpec`); recording
a benchmark without one is a loud failure, not a silent skip.

``--gate-all`` is the suite-wide regression gate
(:func:`repro.tools.benchhist.gate_all`): every trajectory's newest run
is compared per-measurement against the median of its recent same-mode
history, direction-aware, and the process exits non-zero listing every
violated measurement.  Bare ``--gate-all`` gates the recorded data as-is
(no re-measurement — cheap enough for tier-1).  Combined with a run
(``--record``, ``--smoke``, or explicit benchmark names) it *composes*:
the selected benchmarks run (and record) first, then the gate judges the
trajectories that run just appended — ``--smoke --record --gate-all`` is
the one-command CI recipe (see ``ci/bench_record.sh``).

``--perf-gate`` re-measures the fast-path simulation throughput at the
small fixed gate configuration (:mod:`benchmarks.fastsim_bench`) and
compares it against the committed ``experiments/fastsim_bench.json``
baseline, exiting non-zero on a >30% regression — the guard that keeps
the vectorized engine from quietly rotting back toward event-heap speed.
Run as a tier-1 subprocess gate by ``tests/test_benchmarks.py``.

Any unknown flag exits 2 with usage on stderr — a typo'd gate flag must
fail loudly, not fall through to a full-settings run of every benchmark
with exit code 0.
"""

from __future__ import annotations

import os
import sys
import traceback

from . import (
    common,
    cost_objective,
    dag_bench,
    fastsim_bench,
    fault_bench,
    fig1_pareto,
    predictive_ablation,
    fig3_convergence,
    fig4_efficiency,
    fig5_slo_compliance,
    fig6_latency_cdf,
    fig7_timeseries,
    kernels_bench,
    multi_server_bench,
    roofline_table,
    serving_ladders_bench,
    table1_baselines,
    trace_replay_bench,
)

MODULES = {
    "fig1_pareto": fig1_pareto,
    "fig3_convergence": fig3_convergence,
    "fig4_efficiency": fig4_efficiency,
    "table1_baselines": table1_baselines,
    "fig5_slo_compliance": fig5_slo_compliance,
    "fig6_latency_cdf": fig6_latency_cdf,
    "fig7_timeseries": fig7_timeseries,
    "kernels_bench": kernels_bench,
    "predictive_ablation": predictive_ablation,
    "serving_ladders": serving_ladders_bench,
    "multi_server": multi_server_bench,
    "cost_objective": cost_objective,
    "roofline_table": roofline_table,
    "fastsim_bench": fastsim_bench,
    "trace_replay": trace_replay_bench,
    "dag_bench": dag_bench,
    "fault_bench": fault_bench,
}

BENCHES = {name: mod.run for name, mod in MODULES.items()}

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))

USAGE = ("usage: python -m benchmarks.run [--smoke] [--record] "
         "[--gate-all] [--bench-dir=PATH] [name ...] | --check-docs | "
         "--perf-gate | --gate-all [--bench-dir=PATH]")


def _usage_error(msg: str) -> None:
    print(msg, file=sys.stderr)
    print(USAGE, file=sys.stderr)
    sys.exit(2)


def _record(name: str, smoke: bool, bench_dir: str, env: dict) -> None:
    """Append one BenchRun for a benchmark that just ran successfully."""
    from repro.tools import benchhist

    mod = MODULES[name]
    spec = getattr(mod, "BENCH_SPEC", None)
    if spec is None:
        raise benchhist.BenchHistError(
            f"benchmark {name!r} declares no BENCH_SPEC — every registered "
            f"benchmark must name its gate-worthy measurements "
            f"(see repro.tools.benchhist.BenchmarkSpec)")
    # the *effective* mode: --smoke on a module without run_smoke runs the
    # full benchmark, and its measurements must gate against full history
    mode = "smoke" if smoke and getattr(mod, "run_smoke", None) else "full"
    artifact = spec.artifact_for(mode)
    payload = common.LAST_PAYLOADS.get(artifact)
    if payload is None:
        # a benchmark may legitimately skip without writing its artifact
        # (e.g. roofline_table on a checkout without the dry-run input);
        # skipping the record is correct — there is nothing to gate
        print(f"record: {name}: no {artifact!r} payload this run, skipping",
              file=sys.stderr)
        return
    measurements = spec.collect(payload, mode)
    run = benchhist.build_run(name, mode, measurements, env=env,
                              context={"artifact": artifact})
    path = benchhist.append_run(bench_dir, run)
    rel = os.path.relpath(path)
    shown = rel if not rel.startswith(os.pardir) else path
    print(f"recorded {shown} "
          f"(+{len(measurements)} measurements, mode={mode})",
          file=sys.stderr)


def main() -> None:
    args = sys.argv[1:]
    known_flags = {"--smoke", "--check-docs", "--perf-gate", "--record",
                   "--gate-all"}
    bench_dir = REPO_ROOT
    flags, names = [], []
    for a in args:
        if a.startswith("--bench-dir="):
            bench_dir = a.split("=", 1)[1]
            if not bench_dir:
                _usage_error("--bench-dir= requires a path")
        elif a.startswith("--"):
            if a not in known_flags:
                _usage_error(f"unknown flag(s): {a}")
            flags.append(a)
        else:
            names.append(a)
    if "--check-docs" in flags:
        from repro.tools.docscheck import main as docscheck_main

        sys.exit(docscheck_main())
    if "--perf-gate" in flags:
        baseline = os.path.join(REPO_ROOT, "experiments",
                                "fastsim_bench.json")
        sys.exit(fastsim_bench.perf_gate(baseline))
    smoke = "--smoke" in flags
    record = "--record" in flags
    gate = "--gate-all" in flags
    if gate and not (smoke or record or names):
        # bare --gate-all: judge the recorded trajectories as they stand
        from repro.tools.benchhist import gate_all

        sys.exit(gate_all(bench_dir))
    unknown_names = [n for n in names if n not in BENCHES]
    if unknown_names:
        _usage_error(f"unknown benchmark(s): {' '.join(unknown_names)} "
                     f"(known: {' '.join(sorted(BENCHES))})")
    names = names or list(BENCHES)
    env = None
    if record:
        from repro.tools import benchhist

        env = benchhist.collect_environment()
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            fn = BENCHES[name]
            if smoke:
                fn = getattr(MODULES[name], "run_smoke", fn)
            row = fn()
            print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
            if record:
                _record(name, smoke, bench_dir, env)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)
    if gate:
        # compose run -> record -> gate: judge the trajectories this very
        # invocation appended (requires --record to have anything new)
        from repro.tools.benchhist import gate_all

        sys.exit(gate_all(bench_dir))


if __name__ == "__main__":
    main()
