"""Benchmark driver: one function per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [--smoke] [name ...]
        PYTHONPATH=src python -m benchmarks.run --check-docs
        PYTHONPATH=src python -m benchmarks.run --perf-gate

Prints ``name,us_per_call,derived`` CSV and writes per-benchmark JSON
artifacts into experiments/.  ``--check-docs`` runs the documentation
cross-reference checker (:mod:`repro.tools.docscheck`) instead of any
benchmark and exits non-zero on stale references.

``--smoke`` runs each selected benchmark at its smallest setting: a module
that defines ``run_smoke()`` (reduced durations / sweep sizes, same code
paths) runs that; modules without one run their normal ``run()`` — the
fallback keeps the smoke sweep total, so a bit-rotted benchmark fails fast
either way.  CI uses this as a cheap all-benchmarks gate.

``--perf-gate`` re-measures the fast-path simulation throughput at the
small fixed gate configuration (:mod:`benchmarks.fastsim_bench`) and
compares it against the committed ``experiments/fastsim_bench.json``
baseline, exiting non-zero on a >30% regression — the guard that keeps
the vectorized engine from quietly rotting back toward event-heap speed.
Run as a tier-1 subprocess gate by ``tests/test_benchmarks.py``.
"""

from __future__ import annotations

import sys
import traceback

from . import (
    cost_objective,
    dag_bench,
    fastsim_bench,
    fig1_pareto,
    predictive_ablation,
    fig3_convergence,
    fig4_efficiency,
    fig5_slo_compliance,
    fig6_latency_cdf,
    fig7_timeseries,
    kernels_bench,
    multi_server_bench,
    roofline_table,
    serving_ladders_bench,
    table1_baselines,
    trace_replay_bench,
)

MODULES = {
    "fig1_pareto": fig1_pareto,
    "fig3_convergence": fig3_convergence,
    "fig4_efficiency": fig4_efficiency,
    "table1_baselines": table1_baselines,
    "fig5_slo_compliance": fig5_slo_compliance,
    "fig6_latency_cdf": fig6_latency_cdf,
    "fig7_timeseries": fig7_timeseries,
    "kernels_bench": kernels_bench,
    "predictive_ablation": predictive_ablation,
    "serving_ladders": serving_ladders_bench,
    "multi_server": multi_server_bench,
    "cost_objective": cost_objective,
    "roofline_table": roofline_table,
    "fastsim_bench": fastsim_bench,
    "trace_replay": trace_replay_bench,
    "dag_bench": dag_bench,
}

BENCHES = {name: mod.run for name, mod in MODULES.items()}


def main() -> None:
    args = sys.argv[1:]
    known_flags = {"--smoke", "--check-docs", "--perf-gate"}
    unknown = [a for a in args if a.startswith("--") and a not in known_flags]
    if unknown:
        # a typo'd gate flag must fail loudly, not fall through to a
        # full-settings run of every benchmark with exit code 0.
        print(f"unknown flag(s): {' '.join(unknown)}", file=sys.stderr)
        print("usage: python -m benchmarks.run [--smoke] [name ...] | "
              "--check-docs | --perf-gate", file=sys.stderr)
        sys.exit(2)
    if "--check-docs" in args:
        from repro.tools.docscheck import main as docscheck_main

        sys.exit(docscheck_main())
    if "--perf-gate" in args:
        import os

        baseline = os.path.join(os.path.dirname(__file__), "..",
                                "experiments", "fastsim_bench.json")
        sys.exit(fastsim_bench.perf_gate(baseline))
    smoke = "--smoke" in args
    names = [a for a in args if not a.startswith("--")] or list(BENCHES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            fn = BENCHES[name]
            if smoke:
                fn = getattr(MODULES[name], "run_smoke", fn)
            row = fn()
            print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
