"""Fast-path simulation microbenchmark: vectorized sweeps vs the event heap.

Measures simulated-requests-per-second on the *same* static M/G/c sweep —
K ladder configurations x L Poisson loads x R replications at c in
{1, 4} — three ways:

- **event heap**: :class:`repro.serving.simulator.ServingSimulator`, the
  exact per-event oracle (one scenario at a time, reduced replication
  count so the baseline stays affordable);
- **fast single**: :func:`repro.serving.fastsim.simulate`, the dispatcher's
  bit-for-bit sequential fast path (one scenario at a time);
- **fast batch**: :func:`repro.serving.fastsim.simulate_batch`, the batched
  Lindley / Kiefer-Wolfowitz sweep (all scenarios as one grid of array
  ops) — the engine Planner validation and the figure sweeps run on.

Also tracks the vectorized surrogate scoring rate
(:meth:`repro.workflows.surrogate.SurrogateWorkflow.evaluate_samples`),
the other offline hot loop this PR vectorized.

With jax importable, every section is additionally measured on the jax
backend (``simulate_batch(..., backend="jax")`` — same host-generated
draws, recursion and reductions on the device), and a dedicated
**large-sweep cell** (``LARGE``: one deep 32 h M/M/1 trace at 8 QPS,
ladder x 2 replications, ~5.5M requests with N ~ 9e5 sequential steps
per scenario) compares the two engines where the numpy loop's
per-step dispatch overhead dominates.  The acceptance criterion for the
jax backend is **jax >= 5x numpy on the large-sweep cell**; a lognormal
(M/G/1) variant of the same cell is recorded alongside.  When jax is not
importable the jax sections and gate metrics are skipped with the logged
import reason — the numpy numbers are always measured.

Writes ``experiments/fastsim_bench.json`` with a ``metadata`` section
(backend availability, platform, library versions, timestamp) and a
``gate`` section measured at the small fixed gate configuration;
``python -m benchmarks.run --perf-gate`` re-measures the gate fresh and
fails on a >30% throughput regression against the committed baseline —
for the numpy metrics always, and for the jax metrics whenever jax is
importable.  The PR 5 acceptance criterion ``fast batch >= 20x event
heap`` keeps being checked on the numpy sweep.
"""

from __future__ import annotations

import time

from repro.serving import fastsim
from repro.serving.simulator import (
    ServingSimulator,
    lognormal_sampler_from_profile,
)
from repro.serving.workload import constant_rate, generate_arrivals
from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec
from repro.workflows.surrogate import RagSurrogate

from .common import Timer, save_json

# Gate-worthy measurements for the benchmark-history trajectory
# (BENCH_fastsim_bench.json, appended by `benchmarks.run --record`).  All
# of them are wall-clock derived, hence volatile=True: they are recorded
# from the pre-scrub payload and never appear in the stable smoke
# artifact.  The jax keys are optional (skipped on a jax-less install,
# mirroring --perf-gate), and the deep large-sweep cell is full-run-only.
BENCH_SPEC = BenchmarkSpec(
    artifact="fastsim_bench.json",
    smoke_artifact="fastsim_bench_smoke.json",
    measurements=(
        MeasurementSpec("fast_batch_rps_c1", "req/s", True,
                        path="gate.fast_batch_rps_c1", volatile=True),
        MeasurementSpec("fast_batch_rps_c4", "req/s", True,
                        path="gate.fast_batch_rps_c4", volatile=True),
        MeasurementSpec("fast_batch_jax_rps_c1", "req/s", True,
                        path="gate.fast_batch_jax_rps_c1", volatile=True,
                        optional=True),
        MeasurementSpec("fast_batch_jax_rps_c4", "req/s", True,
                        path="gate.fast_batch_jax_rps_c4", volatile=True,
                        optional=True),
        MeasurementSpec("batch_speedup_c1", "x", True,
                        path="sweep.c1.batch_speedup", target=20.0,
                        volatile=True),
        MeasurementSpec("batch_speedup_c4", "x", True,
                        path="sweep.c4.batch_speedup", target=20.0,
                        volatile=True),
        MeasurementSpec("surrogate_sps", "samples/s", True,
                        path="surrogate.sps", volatile=True),
        MeasurementSpec("jax_large_sweep_speedup", "x", True,
                        path="large_sweep.jax_speedup", target=5.0,
                        volatile=True, smoke=False, optional=True),
    ),
)

# the synthetic three-rung ladder shared with multi_server_bench
MEANS = [0.10, 0.25, 0.45]
P95S = [0.14, 0.35, 0.63]
SLO_S = 1.0

# full sweep (the committed-artifact measurement)
FULL = dict(duration_s=600.0, rates=(2.0, 5.0, 8.0), replications=16,
            heap_replications=2)
# gate sweep: fixed and re-measured fresh by --perf-gate.  Sized so one
# batched-sweep call simulates ~2M requests (~0.5 s) — with smaller
# measurements, allocator/timer noise dominates and cross-process medians
# spread by 30%+, flapping the gate; at this size the median-of-5 is
# reproducible to a few percent across fresh processes.
GATE = dict(duration_s=480.0, rates=(2.0, 5.0, 8.0), replications=64,
            heap_replications=1)
# large-sweep cell (the jax >= 5x acceptance measurement): one deep trace
# — 32 h at 8 QPS, the ladder's K = 3 configs x 2 replications — so the
# recursion runs ~9e5 sequential steps per scenario.  That is the regime
# the jax backend exists for: the numpy loop pays Python dispatch per
# step, the jitted scan does not.  M/M/1 (exponential services) keeps
# the shared host draw cost from masking the engine difference; the
# lognormal ladder variant of the same cell is recorded alongside.
LARGE = dict(duration_s=115200.0, rates=(8.0,), replications=2)


def run_metadata() -> dict:
    """Provenance for the committed artifact: which engines were measured,
    where, with what library versions."""
    import datetime
    import os
    import platform

    import numpy as np

    meta = {
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "cpu_count": os.cpu_count(),
        "numpy": np.__version__,
        "backends": ["numpy"],
        "jax": None,
        "jax_platform": None,
    }
    if fastsim.jax_available():
        import jax

        meta["backends"].append("jax")
        meta["jax"] = jax.__version__
        meta["jax_platform"] = jax.default_backend()
    else:
        meta["jax_unavailable_reason"] = fastsim.jax_unavailable_reason()
    return meta


def _sweep_sizes(cfg: dict):
    return len(MEANS), len(cfg["rates"]), cfg["replications"]


def measure_heap(cfg: dict, num_servers: int) -> dict:
    """Event-heap oracle over the sweep grid, one scenario at a time.
    ``heap_replications`` bounds the (slow) baseline; the rate is
    per-request, so fewer replications do not bias it."""
    total = 0
    t0 = time.perf_counter()
    for r in range(cfg["heap_replications"]):
        for l, rate in enumerate(cfg["rates"]):
            arrivals = generate_arrivals(
                constant_rate(rate), cfg["duration_s"], seed=1000 + 17 * r + l)
            for k in range(len(MEANS)):
                sim = ServingSimulator(
                    lognormal_sampler_from_profile(MEANS, P95S),
                    static_index=k, seed=r, num_servers=num_servers)
                out = sim.run(arrivals, cfg["duration_s"])
                total += len(out.completed)
    wall = time.perf_counter() - t0
    return {"requests": total, "wall_s": wall, "rps": total / wall}


def measure_fast_single(cfg: dict, num_servers: int) -> dict:
    """The dispatcher's sequential (bit-for-bit) fast path on the same
    per-scenario workload as the heap baseline."""
    total = 0
    t0 = time.perf_counter()
    for r in range(cfg["heap_replications"]):
        for l, rate in enumerate(cfg["rates"]):
            arrivals = generate_arrivals(
                constant_rate(rate), cfg["duration_s"], seed=1000 + 17 * r + l)
            for k in range(len(MEANS)):
                out = fastsim.simulate(
                    lognormal_sampler_from_profile(MEANS, P95S),
                    arrivals, cfg["duration_s"],
                    static_index=k, seed=r, num_servers=num_servers)
                total += out.num_completed
    wall = time.perf_counter() - t0
    return {"requests": total, "wall_s": wall, "rps": total / wall}


def measure_batch(cfg: dict, num_servers: int, *,
                  backend: str = "numpy", lognormal: bool = True) -> dict:
    """The batched sweep: the full R x K x L grid as one call.  ``backend``
    is pinned (numpy by default) so the committed metrics keep naming the
    engine they measure even as ``simulate_batch``'s auto selection
    evolves."""
    t0 = time.perf_counter()
    res = fastsim.simulate_batch(
        MEANS, P95S if lognormal else None,
        arrival_rates_qps=list(cfg["rates"]),
        duration_s=cfg["duration_s"],
        num_servers=num_servers,
        replications=cfg["replications"],
        slo_s=SLO_S,
        seed=0,
        backend=backend,
    )
    wall = time.perf_counter() - t0
    return {"requests": res.total_requests, "wall_s": wall,
            "rps": res.total_requests / wall}


def measure_large_cell(cfg: dict = LARGE, *, repeats: int = 3) -> dict:
    """numpy vs jax on the deep large-sweep cell, interleaved
    median-of-``repeats`` after a jax compile warmup.  Skipped (with the
    import reason) when jax is unavailable."""
    import statistics

    out = {"grid": {"configs": len(MEANS), "loads": len(cfg["rates"]),
                    "replications": cfg["replications"],
                    "duration_s": cfg["duration_s"]}}
    if not fastsim.jax_available():
        out["skipped"] = (f"jax not importable "
                          f"({fastsim.jax_unavailable_reason()})")
        print(f"fastsim_bench: large-sweep jax section skipped: "
              f"{out['skipped']}")
        return out
    for tag, lognormal in (("mm1", False), ("mg1_lognormal", True)):
        warm = dict(cfg, duration_s=60.0, replications=2)
        measure_batch(warm, 1, backend="jax", lognormal=lognormal)  # compile
        measure_batch(warm, 1, backend="numpy", lognormal=lognormal)
        npy, jx = [], []
        for _ in range(repeats):
            npy.append(measure_batch(cfg, 1, backend="numpy",
                                     lognormal=lognormal))
            jx.append(measure_batch(cfg, 1, backend="jax",
                                    lognormal=lognormal))
        n_rps = statistics.median(s["rps"] for s in npy)
        j_rps = statistics.median(s["rps"] for s in jx)
        out[tag] = {
            "requests": npy[0]["requests"],
            "numpy_rps": n_rps,
            "jax_rps": j_rps,
            "jax_speedup": j_rps / n_rps,
        }
    out["jax_speedup"] = out["mm1"]["jax_speedup"]
    return out


def measure_surrogate(num_configs: int = 40, samples: int = 200) -> dict:
    """Vectorized surrogate scoring rate (samples/s)."""
    sur = RagSurrogate()
    configs = list(sur.space.enumerate())[:num_configs]
    t0 = time.perf_counter()
    total = 0
    for c in configs:
        total += len(sur.evaluate_samples(c, range(samples)))
    wall = time.perf_counter() - t0
    return {"samples": total, "wall_s": wall, "sps": total / wall}


def measure_gate_section(cfg: dict, *, repeats: int = 5) -> dict:
    """The numbers --perf-gate compares: median-of-``repeats`` throughput
    for the batched sweep at c in {1, 4}, after one untimed warmup call
    (first-touch page faults and lazy numpy imports otherwise land in the
    first sample).  The median damps allocator/scheduler outliers on a
    loaded CI box far better than best-of."""
    import statistics

    out = {}
    for c in (1, 4):
        measure_batch(cfg, c)   # warmup, untimed
        samples = sorted(measure_batch(cfg, c)["rps"]
                         for _ in range(repeats))
        out[f"fast_batch_rps_c{c}"] = statistics.median(samples)
    if fastsim.jax_available():
        for c in (1, 4):
            measure_batch(cfg, c, backend="jax")   # warmup + compile
            samples = sorted(measure_batch(cfg, c, backend="jax")["rps"]
                             for _ in range(repeats))
            out[f"fast_batch_jax_rps_c{c}"] = statistics.median(samples)
    else:
        print(f"fastsim_bench: jax gate metrics skipped: jax not "
              f"importable ({fastsim.jax_unavailable_reason()})")
    return out


def _measure_batch_stable(cfg: dict, num_servers: int,
                          repeats: int = 3, *,
                          backend: str = "numpy") -> dict:
    """Warmed-up median-of-``repeats`` batched-sweep measurement — a single
    cold call pays first-touch page faults (and, for jax, compilation) and
    reads up to ~3x slow."""
    measure_batch(cfg, num_servers, backend=backend)   # warmup, untimed
    samples = sorted((measure_batch(cfg, num_servers, backend=backend)
                      for _ in range(repeats)),
                     key=lambda s: s["rps"])
    return samples[len(samples) // 2]


def _section(cfg: dict) -> dict:
    K, L, R = _sweep_sizes(cfg)
    section = {"grid": {"configs": K, "loads": L, "replications": R,
                        "duration_s": cfg["duration_s"]}}
    for c in (1, 4):
        heap = measure_heap(cfg, c)
        single = measure_fast_single(cfg, c)
        batch = _measure_batch_stable(cfg, c)
        row = {
            "event_heap": heap,
            "fast_single": single,
            "fast_batch": batch,
            "single_speedup": single["rps"] / heap["rps"],
            "batch_speedup": batch["rps"] / heap["rps"],
        }
        if fastsim.jax_available():
            jax_batch = _measure_batch_stable(cfg, c, backend="jax")
            row["fast_batch_jax"] = jax_batch
            row["jax_batch_speedup"] = jax_batch["rps"] / heap["rps"]
        section[f"c{c}"] = row
    return section


def _run(cfg: dict, artifact: str, *, large: bool = True,
         stable: bool = False) -> dict:
    with Timer() as t:
        payload = {
            "metadata": run_metadata(),
            "sweep": _section(cfg),
            "gate": measure_gate_section(GATE),
            "surrogate": measure_surrogate(),
        }
        if large:
            payload["large_sweep"] = measure_large_cell(LARGE)
    save_json(artifact, payload, stable=stable)
    c1 = payload["sweep"]["c1"]
    c4 = payload["sweep"]["c4"]
    worst_speedup = min(c1["batch_speedup"], c4["batch_speedup"])
    jax_note = ""
    if large and "jax_speedup" in payload.get("large_sweep", {}):
        jspd = payload["large_sweep"]["jax_speedup"]
        jax_note = (f" jax_large={jspd:.1f}x"
                    + ("" if jspd >= 5.0 else " [<5x: acceptance FAILED]"))
    return {
        "name": "fastsim_bench",
        "us_per_call": t.elapsed * 1e6,
        "derived": (
            f"heap={c1['event_heap']['rps']:.0f}/s "
            f"batch_c1={c1['fast_batch']['rps']:.0f}/s "
            f"batch_c4={c4['fast_batch']['rps']:.0f}/s "
            f"speedup_c1={c1['batch_speedup']:.0f}x "
            f"c4={c4['batch_speedup']:.0f}x "
            f"surrogate={payload['surrogate']['sps']:.0f} samples/s"
            + jax_note
            + ("" if worst_speedup >= 20.0
               else " [<20x: acceptance FAILED]")
        ),
    }


def run() -> dict:
    return _run(FULL, "fastsim_bench.json")


def run_smoke() -> dict:
    """Gate-sized sweep; separate artifact so the smoke gate never
    overwrites the committed baseline --perf-gate compares against.  The
    deep large-sweep cell is full-run-only (it alone takes ~15 s).
    ``stable=True``: the smoke artifact keeps only seed-deterministic
    content (grid shapes, request counts) so tier-1 reruns are
    byte-idempotent; the wall-clock numbers go to the benchmark-history
    trajectory via ``--record`` instead."""
    return _run(GATE, "fastsim_bench_smoke.json", large=False, stable=True)


def perf_gate(baseline_path: str, *, max_regression: float = 0.30) -> int:
    """Compare a fresh gate measurement against the committed baseline.

    Returns a process exit code: 0 when every gate metric is within
    ``max_regression`` of the committed value, 1 otherwise (or when the
    baseline artifact is missing/malformed)."""
    import json
    import os

    if not os.path.exists(baseline_path):
        print(f"perf-gate: missing baseline {baseline_path} "
              "(run: python -m benchmarks.run fastsim_bench)")
        return 1
    with open(baseline_path) as f:
        baseline = json.load(f).get("gate", {})
    if not baseline:
        print("perf-gate: baseline artifact has no 'gate' section")
        return 1
    fresh = measure_gate_section(GATE)
    failed = False
    for key, base in sorted(baseline.items()):
        now = fresh.get(key)
        if now is None:
            if "jax" in key and not fastsim.jax_available():
                # jax-backend baselines are only comparable where jax can
                # run; a jax-less install skips them instead of failing
                print(f"perf-gate: {key} SKIPPED (jax not importable: "
                      f"{fastsim.jax_unavailable_reason()})")
                continue
            print(f"perf-gate: metric {key} missing from fresh run")
            failed = True
            continue
        ratio = now / base
        status = "OK" if ratio >= 1.0 - max_regression else "REGRESSION"
        if status != "OK":
            failed = True
        print(f"perf-gate: {key} baseline={base:.0f}/s fresh={now:.0f}/s "
              f"({ratio:.2f}x) {status}")
    if failed:
        print(f"perf-gate: FAILED (>{max_regression:.0%} regression)")
        return 1
    print("perf-gate: OK")
    return 0


if __name__ == "__main__":
    print(run())
