"""Fig. 6: latency CDF under the 1000ms SLO, spike pattern.

Paper: Static-Accurate tails beyond 2500ms with ~30% compliance;
Static-Medium ~40%; Elastico tracks Static-Fast in the low-latency region
with a sharp rise at the SLO threshold.
"""

from __future__ import annotations

import numpy as np

from repro.core.elastico import ElasticoController

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import Timer, paper_arrivals, plan_for, save_json, simulate

# Trajectory measurements (BENCH_fig6_latency_cdf.json): the latency-CDF
# cut at the spike/1000ms cell — Elastico's compliance and tail.
BENCH_SPEC = BenchmarkSpec(
    artifact="fig6_latency_cdf.json",
    measurements=(
        MeasurementSpec("elastico_compliance", "frac", True,
                        path="elastico.compliance", tolerance=0.05),
        MeasurementSpec("elastico_p95_ms", "ms", False,
                        path="elastico.percentiles_ms.p95",
                        tolerance=0.15),
        MeasurementSpec("elastico_p99_ms", "ms", False,
                        path="elastico.percentiles_ms.p99",
                        tolerance=0.25),
    ),
)
from .table1_baselines import build_plan

SLO_S = 1.0
PCTS = [5, 25, 50, 75, 90, 95, 99]


def run() -> dict:
    sur, res, _ = build_plan()
    plan = plan_for(sur, res.feasible, SLO_S)
    ladder = plan.table.policies
    arrivals = paper_arrivals("spike")

    rows = {}
    with Timer() as t:
        for name, (ctrl, static) in {
            "elastico": (ElasticoController(plan.table), 0),
            "static-fast": (None, 0),
            "static-medium": (None, len(ladder) // 2),
            "static-accurate": (None, len(ladder) - 1),
        }.items():
            out, acc = simulate(
                sur, plan, arrivals, 180.0, controller=ctrl, static=static
            )
            lats = np.asarray(out.latencies())
            rows[name] = {
                "compliance": out.slo_compliance(SLO_S),
                "percentiles_ms": {
                    f"p{p}": float(np.percentile(lats, p) * 1e3) for p in PCTS
                },
                "max_ms": float(lats.max() * 1e3),
                "mean_accuracy": acc,
            }
    save_json("fig6_latency_cdf.json", rows)
    return {
        "name": "fig6_latency_cdf",
        "us_per_call": t.elapsed / 4 * 1e6,
        "derived": (
            f"elastico_p95={rows['elastico']['percentiles_ms']['p95']:.0f}ms "
            f"accurate_p95={rows['static-accurate']['percentiles_ms']['p95']:.0f}ms"
        ),
    }


if __name__ == "__main__":
    print(run())
