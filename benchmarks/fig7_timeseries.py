"""Fig. 7: Elastico configuration switching over time (spike, 1000ms SLO).

Reports the temporal adaptation behaviour: which ladder rung is active in
each 5-second window, switch latencies relative to the spike edges, and the
recovery to the most accurate configuration after the spike.
"""

from __future__ import annotations

from repro.core.elastico import ElasticoController

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import Timer, paper_arrivals, plan_for, save_json, simulate

# Trajectory measurements (BENCH_fig7_timeseries.json): temporal
# adaptation — how fast the controller reacts to the spike edge and what
# compliance/accuracy the whole run sustains.
BENCH_SPEC = BenchmarkSpec(
    artifact="fig7_timeseries.json",
    measurements=(
        MeasurementSpec("reaction_to_spike_s", "s", False,
                        path="reaction_to_spike_s", tolerance=0.25),
        MeasurementSpec("compliance", "frac", True, path="compliance",
                        tolerance=0.05),
        MeasurementSpec("mean_accuracy", "frac", True,
                        path="mean_accuracy", tolerance=0.05),
    ),
)
from .table1_baselines import build_plan

SLO_S = 1.0
SPIKE_START, SPIKE_END = 60.0, 120.0  # middle third of 180 s


def run() -> dict:
    sur, res, _ = build_plan()
    plan = plan_for(sur, res.feasible, SLO_S)
    ctrl = ElasticoController(plan.table)
    arrivals = paper_arrivals("spike")
    with Timer() as t:
        out, acc = simulate(sur, plan, arrivals, 180.0, controller=ctrl)

    top = plan.table.ladder_size - 1
    # reaction time: first downward (faster) switch after the spike begins
    down = [e for e in out.switch_events if e.direction == "faster" and e.time_s >= SPIKE_START]
    reaction_s = (down[0].time_s - SPIKE_START) if down else None
    # recovery: first upward (more accurate) switch after the spike ends, and
    # the rung the controller settles on by the end of the run.  (The literal
    # top rung has N_up=0 under tight SLOs, so "back at top" is not the right
    # recovery criterion — the ladder converges to the most accurate rung the
    # base load supports.)
    rec = [
        e for e in out.switch_events
        if e.direction == "more_accurate" and e.time_s >= SPIKE_END
    ]
    recovery_s = (rec[0].time_s - SPIKE_END) if rec else None
    final_rung = out.config_timeline[-1][1] if out.config_timeline else None

    timeline = [[round(ts, 2), idx] for ts, idx in out.config_timeline]
    payload = {
        "switches": [
            {
                "t": round(e.time_s, 2),
                "from": e.from_index,
                "to": e.to_index,
                "direction": e.direction,
                "queue_depth": e.queue_depth,
            }
            for e in out.switch_events
        ],
        "timeline": timeline[:: max(1, len(timeline) // 200)],
        "reaction_to_spike_s": reaction_s,
        "recovery_after_spike_s": recovery_s,
        "final_rung": final_rung,
        "ladder_top": top,
        "compliance": out.slo_compliance(SLO_S),
        "mean_accuracy": acc,
    }
    save_json("fig7_timeseries.json", payload)
    return {
        "name": "fig7_timeseries",
        "us_per_call": t.elapsed * 1e6,
        "derived": (
            f"reaction={reaction_s:.1f}s recovery={recovery_s:.1f}s "
            f"final_rung={final_rung}/{top} switches={len(out.switch_events)}"
            if reaction_s is not None and recovery_s is not None
            else f"switches={len(out.switch_events)}"
        ),
    }


if __name__ == "__main__":
    print(run())
