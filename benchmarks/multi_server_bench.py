"""Multi-server scaling: homogeneous pools, heterogeneous mixes, batching.

Part 1 (PR 1): identical arrival traces replayed against M/G/c simulator
pools of c ∈ {1, 2, 4}, each driven by an Elastico table derived for that c
(``derive_policies(..., num_servers=c)``).  Two beyond-paper load shapes
stress the pools:

- **sustained-overload**: rate steps to 2.5x one server's fastest-rung
  capacity — pools with c <= 2 are unstable, c = 4 drains it;
- **flash-crowd**: 10x ramp-hold-decay around a moderate base.

Part 2 (PR 2): heterogeneous worker pools at c = 4.  Every static mix on
the one-worker-shift ladder (``mix_ladder``) is swept under both traces,
recording accuracy/compliance per mix, and the *mix-shifting* controller
(``ElasticoMixController`` over Allen-Cunneen M/G/c thresholds,
``derive_mix_policies``) is compared against homogeneous switching.

Part 3 (PR 3): in-worker batching at c = 4.  A heavier overload (7x one
server's fastest-rung capacity — beyond what four unbatched workers can
drain) is replayed against the same pool unbatched and with
``max_batch_size = 8`` under an amortizing batch law
(alpha = 0.6 s-bar, beta = 0.4 s-bar, so a full batch serves 8 requests in
3.8 s-bar — ~2.1x per-worker throughput), each driven by thresholds derived
for its own runtime (``derive_policies(..., max_batch_size=B)``).  The
headline checks the PR's acceptance criterion: batched goodput must be
>= 1.5x unbatched goodput under sustained overload.

Part 4 (PR 4): work stealing on per-worker backlogs at c = 4.  Arrivals
are routed round-robin to per-worker queues (the static partition a
sharded frontend produces) with a skewed pinning ``[0, 0, 2, 2]`` — two
fast workers, two accurate ones — under a sustained overload the pool can
absorb in aggregate but the partition cannot (the accurate workers' share
alone overloads them).  Three disciplines run on the identical trace:
static pinning without stealing, pinning with work stealing (idle workers
pull from the globally deepest backlog at the
``repro.core.aqm.steal_threshold`` depth, serving stolen work under their
own pin), and the shared-queue ideal.  The headline checks the PR's
acceptance criterion: stealing must beat static pinning on
sustained-overload goodput.

``run_smoke()`` runs the same sweeps at the smallest useful setting
(short horizon, pool sizes {1, 4}) for the ``--smoke`` CI gate.

Since the fast-path PR every scenario goes through the
:func:`repro.serving.fastsim.simulate` dispatcher: the static sweeps
(part 2's static mixes, part 4's shared-queue ideal) run on the
vectorized Lindley/Kiefer-Wolfowitz engine — bit-for-bit identical
results — while controller / batching / stealing scenarios keep the
event-heap oracle.
"""

from __future__ import annotations

from repro.core.aqm import (
    HysteresisSpec,
    derive_mix_policies,
    derive_policies,
    steal_threshold,
)
from repro.core.elastico import ElasticoController, ElasticoMixController
from repro.core.pareto import BatchProfile, LatencyProfile, ParetoPoint
from repro.serving import fastsim
from repro.serving.simulator import lognormal_sampler_from_profile
from repro.serving.workload import (
    flash_crowd_pattern,
    generate_arrivals,
    sustained_overload_pattern,
)

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import Timer, save_json


def _by(rows, mode, pattern_prefix):
    """The one row with this mode whose pattern starts with the prefix —
    the overload factors are config constants, so specs match on the
    prefix rather than hard-coding them into measurement names."""
    matches = [r for r in rows if r["mode"] == mode
               and r["pattern"].startswith(pattern_prefix)]
    if len(matches) != 1:
        from repro.tools.benchhist import BenchHistError

        raise BenchHistError(
            f"expected exactly one row with mode={mode!r} "
            f"pattern~{pattern_prefix!r}, found {len(matches)}")
    return matches[0]


# Trajectory measurements (BENCH_multi_server.json): one headline per
# serving-substrate PR — batching goodput gain (PR 3), work-stealing vs
# pinned goodput (PR 4), mix-shifting compliance under overload (PR 2) —
# all virtual-time metrics, deterministic given the seeds.
BENCH_SPEC = BenchmarkSpec(
    artifact="multi_server_bench.json",
    smoke_artifact="multi_server_bench_smoke.json",
    measurements=(
        MeasurementSpec(
            "batch_goodput_gain", "x", True,
            extract=lambda rows: (
                _by(rows, "batched", "batch-overload")["goodput"]
                / max(_by(rows, "unbatched", "batch-overload")["goodput"],
                      1e-9)),
            target=1.5, tolerance=0.10),
        MeasurementSpec(
            "steal_goodput", "frac", True,
            extract=lambda rows: _by(rows, "pinned-steal",
                                     "steal-overload")["goodput"],
            tolerance=0.05),
        MeasurementSpec(
            "steal_gain_vs_pinned", "x", True,
            extract=lambda rows: (
                _by(rows, "pinned-steal", "steal-overload")["goodput"]
                / max(_by(rows, "pinned-no-steal",
                          "steal-overload")["goodput"], 1e-9)),
            tolerance=0.10),
        MeasurementSpec(
            "mix_shift_overload_compliance", "frac", True,
            extract=lambda rows: _by(rows, "mix-shifting",
                                     "sustained-overload")["compliance"],
            tolerance=0.10),
    ),
)

# synthetic three-rung ladder, the shape of the paper's Table I (seconds)
MEANS = [0.10, 0.25, 0.45]
P95S = [0.14, 0.35, 0.63]
ACCS = [0.76, 0.82, 0.85]
SLO_S = 1.0
DURATION_S = 120.0
POOL_SIZES = (1, 2, 4)
MIX_C = 4            # pool size for the heterogeneous comparison
BATCH_C = 4          # pool size for the batching comparison
MAX_BATCH = 8        # per-worker batch cap B
BATCH_LINGER_S = 0.005
BATCH_OVERLOAD = 7.0  # x one server's fastest-rung capacity; > BATCH_C, so
                      # only the batched pool can stay ahead of it
# amortizing batch-service law per rung: S(b) = 0.6 s-bar + 0.4 s-bar * b,
# the alpha-dominated shape of LLM serving (prefill/launch overhead shared
# across the batch); full batches run ~2.1x more requests per second.
BATCH_PROFILES = [BatchProfile(alpha=0.6 * m, beta=0.4 * m) for m in MEANS]
STEAL_C = 4                   # pool size for the work-stealing comparison
STEAL_ASSIGNMENT = (0, 0, 2, 2)   # skewed pinning: two fast, two accurate
# 1.8x one server's fastest-rung capacity: the pool's aggregate drain
# (2/s0 + 2/s2 = 24.4 qps) absorbs it, but a round-robin partition gives
# each accurate worker (capacity 2.2 qps) a 4.5 qps share — only
# rebalancing can save the SLO.
STEAL_OVERLOAD = 1.8


def _front():
    return [
        ParetoPoint(config=("rung", i), accuracy=a,
                    profile=LatencyProfile(mean=m, p95=p))
        for i, (m, p, a) in enumerate(zip(MEANS, P95S, ACCS))
    ]


def _traces(duration_s: float, seed: int = 1):
    fastest_capacity_qps = 1.0 / MEANS[0]
    overload = sustained_overload_pattern(
        fastest_capacity_qps, overload_factor=2.5, warmup_s=20.0
    )
    flash = flash_crowd_pattern(3.0, peak_factor=10.0, crowd_start_s=40.0,
                                ramp_s=5.0, hold_s=20.0)
    return {
        "sustained-overload": generate_arrivals(overload, duration_s, seed=seed),
        "flash-crowd": generate_arrivals(flash, duration_s, seed=seed),
    }


def _row(pattern, mode, c, arrivals, out, duration_s, extra=None):
    util = out.per_server_utilization()
    n_done = out.num_completed
    row = {
        "pattern": pattern,
        "mode": mode,
        "num_servers": c,
        "offered": len(arrivals),
        "completed": n_done,
        "throughput_qps": n_done / duration_s,
        "compliance": out.slo_compliance(SLO_S),
        # fraction of *offered* load served within the SLO.  The no-drop
        # simulator completes every arrival, so today this coincides with
        # compliance; it is charged against offered load (not completions)
        # so the column stays honest if a variant ever drops or truncates.
        "goodput": out.goodput(SLO_S),
        "p95_latency_s": out.p95_latency(),
        "mean_wait_s": out.mean_wait(),
        "mean_accuracy": out.mean_accuracy(ACCS),
        "mean_utilization": sum(util) / len(util),
        "per_server_utilization": util,
        "switches": len(out.switch_events),
        "mean_batch_size": out.mean_batch_size(),
    }
    if extra:
        row.update(extra)
    return row


def _run(duration_s: float, pool_sizes,
         artifact: str = "multi_server_bench.json",
         stable: bool = False) -> dict:
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    traces = _traces(duration_s)
    rows = []
    total_completed = 0
    hyst = HysteresisSpec(downscale_cooldown_s=5.0)
    with Timer() as t:
        # -- part 1: homogeneous switching across pool sizes ------------------
        for pattern, arrivals in traces.items():
            for c in pool_sizes:
                table = derive_policies(
                    _front(), slo_p95_s=SLO_S, hysteresis=hyst, num_servers=c,
                )
                out = fastsim.simulate(
                    sampler, arrivals, duration_s,
                    controller=ElasticoController(table),
                    seed=0,
                    num_servers=c,
                )
                total_completed += out.num_completed
                rows.append(_row(pattern, "homogeneous-switching", c, arrivals,
                                 out, duration_s))

        # -- part 2: heterogeneous mixes at c = MIX_C -------------------------
        mix_table = derive_mix_policies(
            _front(), slo_p95_s=SLO_S, hysteresis=hyst, num_servers=MIX_C,
        )
        for pattern, arrivals in traces.items():
            # mix-shifting controller: one worker repinned per decision
            out = fastsim.simulate(
                sampler, arrivals, duration_s,
                controller=ElasticoMixController(mix_table),
                seed=0,
                num_servers=MIX_C,
            )
            total_completed += out.num_completed
            # assignment_timeline[0] is the initial t=0 pinning, not a repin
            rows.append(_row(pattern, "mix-shifting", MIX_C, arrivals, out,
                             duration_s,
                             {"repin_events": max(0, len(out.assignment_timeline) - 1)}))

            # every static mix on the ladder: accuracy/compliance per mix —
            # these are exactly the static shared-FIFO scenarios the
            # dispatcher routes to the vectorized fast path
            for mp in mix_table.policies:
                out = fastsim.simulate(
                    sampler, arrivals, duration_s,
                    assignment=list(mp.assignment),
                    seed=0, num_servers=MIX_C,
                )
                total_completed += out.num_completed
                rows.append(_row(
                    pattern, "static-mix", MIX_C, arrivals, out, duration_s,
                    {
                        "assignment": list(mp.assignment),
                        "predicted_accuracy": mp.expected_accuracy,
                        "drain_rate_qps": mp.drain_rate_qps,
                        "mix_scv": mp.scv,
                    },
                ))

        # -- part 3: in-worker batching at c = BATCH_C ------------------------
        batch_arr = generate_arrivals(
            sustained_overload_pattern(1.0 / MEANS[0],
                                       overload_factor=BATCH_OVERLOAD,
                                       warmup_s=20.0),
            duration_s, seed=1,
        )
        unbatched_table = derive_policies(
            _front(), slo_p95_s=SLO_S, hysteresis=hyst, num_servers=BATCH_C,
        )
        batched_table = derive_policies(
            _front(), slo_p95_s=SLO_S, hysteresis=hyst, num_servers=BATCH_C,
            max_batch_size=MAX_BATCH, batch_profiles=BATCH_PROFILES,
        )
        for mode, table, kw in [
            ("unbatched", unbatched_table, {}),
            ("batched", batched_table, dict(max_batch_size=MAX_BATCH,
                                            batch_timeout_s=BATCH_LINGER_S,
                                            batch_profiles=BATCH_PROFILES)),
        ]:
            out = fastsim.simulate(
                sampler, batch_arr, duration_s,
                controller=ElasticoController(table), seed=0,
                num_servers=BATCH_C, **kw,
            )
            total_completed += out.num_completed
            rows.append(_row(
                f"batch-overload-{BATCH_OVERLOAD:g}x", mode, BATCH_C,
                batch_arr, out, duration_s,
                {"max_batch_size": kw.get("max_batch_size", 1),
                 "fast_rung_n_up": table.policies[0].upscale_threshold},
            ))

        # -- part 4: work stealing on per-worker backlogs at c = STEAL_C ------
        steal_arr = generate_arrivals(
            sustained_overload_pattern(1.0 / MEANS[0],
                                       overload_factor=STEAL_OVERLOAD,
                                       warmup_s=20.0),
            duration_s, seed=1,
        )
        n_steal = steal_threshold(_front(), STEAL_ASSIGNMENT, slo_p95_s=SLO_S)
        for mode, kw in [
            ("pinned-no-steal", dict(queue_discipline="per_worker")),
            ("pinned-steal", dict(queue_discipline="per_worker", steal=True,
                                  steal_threshold=n_steal)),
            ("pinned-shared", {}),   # shared-queue ideal, same pinning
        ]:
            # the shared-queue ideal takes the fast path; per-worker and
            # stealing disciplines fall back to the event-heap oracle
            out = fastsim.simulate(
                sampler, steal_arr, duration_s,
                assignment=list(STEAL_ASSIGNMENT), seed=0,
                num_servers=STEAL_C, **kw,
            )
            total_completed += out.num_completed
            rows.append(_row(
                f"steal-overload-{STEAL_OVERLOAD:g}x", mode, STEAL_C,
                steal_arr, out, duration_s,
                {"assignment": list(STEAL_ASSIGNMENT),
                 "steal_threshold": n_steal,
                 "stolen_batches": out.stolen_batches},
            ))
    save_json(artifact, rows, stable=stable)

    by_key = {(r["pattern"], r["mode"], r["num_servers"]): r for r in rows
              if r["mode"] != "static-mix"}
    c_lo, c_hi = min(pool_sizes), max(pool_sizes)
    ov1 = by_key[("sustained-overload", "homogeneous-switching", c_lo)]["compliance"]
    ov4 = by_key[("sustained-overload", "homogeneous-switching", c_hi)]["compliance"]
    mix_ov = by_key[("sustained-overload", "mix-shifting", MIX_C)]
    mix_fl = by_key[("flash-crowd", "mix-shifting", MIX_C)]
    hom_ov = by_key[("sustained-overload", "homogeneous-switching", MIX_C)]

    # PR-2 acceptance check: best static heterogeneous mix vs the all-fast
    # pool under sustained overload.
    statics = [r for r in rows
               if r["mode"] == "static-mix" and r["pattern"] == "sustained-overload"]
    all_fast = next(r for r in statics if set(r["assignment"]) == {0})
    het = [r for r in statics if len(set(r["assignment"])) > 1]
    good = [r for r in het
            if r["compliance"] >= all_fast["compliance"] - 0.02
            and r["mean_accuracy"] > all_fast["mean_accuracy"]]
    best = max(good, key=lambda r: r["mean_accuracy"]) if good else None

    # PR-3 acceptance check: batched vs unbatched goodput under the heavy
    # overload (>= 1.5x required).
    batch_pattern = f"batch-overload-{BATCH_OVERLOAD:g}x"
    unb = by_key[(batch_pattern, "unbatched", BATCH_C)]
    bat = by_key[(batch_pattern, "batched", BATCH_C)]
    batch_gain = bat["goodput"] / max(unb["goodput"], 1e-9)

    # PR-4 acceptance check: work stealing vs static pinning on per-worker
    # backlogs under sustained overload (steal must strictly improve).
    steal_pattern = f"steal-overload-{STEAL_OVERLOAD:g}x"
    pin = by_key[(steal_pattern, "pinned-no-steal", STEAL_C)]
    stl = by_key[(steal_pattern, "pinned-steal", STEAL_C)]
    shr = by_key[(steal_pattern, "pinned-shared", STEAL_C)]

    derived = (
        f"overload_compliance c{c_lo}={ov1:.3f} c{c_hi}={ov4:.3f} "
        f"(+{(ov4 - ov1) * 100:.1f}pts) "
        f"mix_shift c4: ov={mix_ov['compliance']:.3f}/acc={mix_ov['mean_accuracy']:.3f} "
        f"(hom acc={hom_ov['mean_accuracy']:.3f}) fl={mix_fl['compliance']:.3f} "
    )
    if best is not None:
        derived += (
            f"best_het_mix={best['assignment']} "
            f"comp={best['compliance']:.3f} (all-fast {all_fast['compliance']:.3f}) "
            f"acc={best['mean_accuracy']:.3f} (all-fast {all_fast['mean_accuracy']:.3f}) "
        )
    else:
        derived += "best_het_mix=NONE (acceptance criterion FAILED) "
    derived += (
        f"batch c{BATCH_C}xB{MAX_BATCH}@{BATCH_OVERLOAD:g}x: "
        f"goodput {unb['goodput']:.3f}->{bat['goodput']:.3f} "
        f"({batch_gain:.2f}x, mean_bs={bat['mean_batch_size']:.2f}, "
        f"N_up[0] {unb['fast_rung_n_up']}->{bat['fast_rung_n_up']})"
        + ("" if batch_gain >= 1.5 else " [<1.5x: acceptance FAILED]")
    )
    derived += (
        f" steal {list(STEAL_ASSIGNMENT)}@{STEAL_OVERLOAD:g}x: "
        f"goodput pinned={pin['goodput']:.3f} -> steal={stl['goodput']:.3f} "
        f"(shared ideal {shr['goodput']:.3f}, N_steal={stl['steal_threshold']}, "
        f"{stl['stolen_batches']} stolen)"
        + ("" if stl["goodput"] > pin["goodput"]
           else " [steal <= pinned: acceptance FAILED]")
    )
    return {
        "name": "multi_server",
        "us_per_call": t.elapsed / max(total_completed, 1) * 1e6,
        "derived": derived,
    }


def run() -> dict:
    return _run(DURATION_S, POOL_SIZES)


def run_smoke() -> dict:
    """Smallest setting: 30 s horizon, pool sizes {1, 4}; same code paths.
    Writes its own stable-scrubbed artifact so the smoke gate never
    overwrites the committed full-run experiment evidence and reruns are
    byte-identical."""
    return _run(30.0, (1, MIX_C), artifact="multi_server_bench_smoke.json",
                stable=True)


if __name__ == "__main__":
    print(run())
