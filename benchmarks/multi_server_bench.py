"""Multi-server scaling: homogeneous pools vs heterogeneous mixes.

Part 1 (PR 1): identical arrival traces replayed against M/G/c simulator
pools of c ∈ {1, 2, 4}, each driven by an Elastico table derived for that c
(``derive_policies(..., num_servers=c)``).  Two beyond-paper load shapes
stress the pools:

- **sustained-overload**: rate steps to 2.5x one server's fastest-rung
  capacity — pools with c <= 2 are unstable, c = 4 drains it;
- **flash-crowd**: 10x ramp-hold-decay around a moderate base.

Part 2 (PR 2): heterogeneous worker pools at c = 4.  Every static mix on
the one-worker-shift ladder (``mix_ladder``) is swept under both traces,
recording accuracy/compliance per mix, and the *mix-shifting* controller
(``ElasticoMixController`` over Allen-Cunneen M/G/c thresholds,
``derive_mix_policies``) is compared against homogeneous switching.  The
headline checks the PR's acceptance criterion: some heterogeneous mix must
hold SLO compliance within 2 points of the all-fast pool under sustained
overload while beating its mean accuracy.
"""

from __future__ import annotations

from repro.core.aqm import (
    HysteresisSpec,
    derive_mix_policies,
    derive_policies,
)
from repro.core.elastico import ElasticoController, ElasticoMixController
from repro.core.pareto import LatencyProfile, ParetoPoint
from repro.serving.simulator import ServingSimulator, lognormal_sampler_from_profile
from repro.serving.workload import (
    flash_crowd_pattern,
    generate_arrivals,
    sustained_overload_pattern,
)

from .common import Timer, save_json

# synthetic three-rung ladder, the shape of the paper's Table I (seconds)
MEANS = [0.10, 0.25, 0.45]
P95S = [0.14, 0.35, 0.63]
ACCS = [0.76, 0.82, 0.85]
SLO_S = 1.0
DURATION_S = 120.0
POOL_SIZES = (1, 2, 4)
MIX_C = 4            # pool size for the heterogeneous comparison


def _front():
    return [
        ParetoPoint(config=("rung", i), accuracy=a,
                    profile=LatencyProfile(mean=m, p95=p))
        for i, (m, p, a) in enumerate(zip(MEANS, P95S, ACCS))
    ]


def _traces(seed: int = 1):
    fastest_capacity_qps = 1.0 / MEANS[0]
    overload = sustained_overload_pattern(
        fastest_capacity_qps, overload_factor=2.5, warmup_s=20.0
    )
    flash = flash_crowd_pattern(3.0, peak_factor=10.0, crowd_start_s=40.0,
                                ramp_s=5.0, hold_s=20.0)
    return {
        "sustained-overload": generate_arrivals(overload, DURATION_S, seed=seed),
        "flash-crowd": generate_arrivals(flash, DURATION_S, seed=seed),
    }


def _row(pattern, mode, c, arrivals, out, extra=None):
    util = out.per_server_utilization()
    row = {
        "pattern": pattern,
        "mode": mode,
        "num_servers": c,
        "offered": len(arrivals),
        "completed": len(out.completed),
        "throughput_qps": len(out.completed) / DURATION_S,
        "compliance": out.slo_compliance(SLO_S),
        "p95_latency_s": out.p95_latency(),
        "mean_wait_s": out.mean_wait(),
        "mean_accuracy": out.mean_accuracy(ACCS),
        "mean_utilization": sum(util) / len(util),
        "per_server_utilization": util,
        "switches": len(out.switch_events),
    }
    if extra:
        row.update(extra)
    return row


def run() -> dict:
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    traces = _traces()
    rows = []
    total_completed = 0
    hyst = HysteresisSpec(downscale_cooldown_s=5.0)
    with Timer() as t:
        # -- part 1: homogeneous switching across pool sizes ------------------
        for pattern, arrivals in traces.items():
            for c in POOL_SIZES:
                table = derive_policies(
                    _front(), slo_p95_s=SLO_S, hysteresis=hyst, num_servers=c,
                )
                sim = ServingSimulator(
                    sampler,
                    controller=ElasticoController(table),
                    seed=0,
                    num_servers=c,
                )
                out = sim.run(arrivals, DURATION_S)
                total_completed += len(out.completed)
                rows.append(_row(pattern, "homogeneous-switching", c, arrivals, out))

        # -- part 2: heterogeneous mixes at c = MIX_C -------------------------
        mix_table = derive_mix_policies(
            _front(), slo_p95_s=SLO_S, hysteresis=hyst, num_servers=MIX_C,
        )
        for pattern, arrivals in traces.items():
            # mix-shifting controller: one worker repinned per decision
            sim = ServingSimulator(
                sampler,
                controller=ElasticoMixController(mix_table),
                seed=0,
                num_servers=MIX_C,
            )
            out = sim.run(arrivals, DURATION_S)
            total_completed += len(out.completed)
            # assignment_timeline[0] is the initial t=0 pinning, not a repin
            rows.append(_row(pattern, "mix-shifting", MIX_C, arrivals, out,
                             {"repin_events": max(0, len(out.assignment_timeline) - 1)}))

            # every static mix on the ladder: accuracy/compliance per mix
            for mp in mix_table.policies:
                sim = ServingSimulator(
                    sampler, assignment=list(mp.assignment),
                    seed=0, num_servers=MIX_C,
                )
                out = sim.run(arrivals, DURATION_S)
                total_completed += len(out.completed)
                rows.append(_row(
                    pattern, "static-mix", MIX_C, arrivals, out,
                    {
                        "assignment": list(mp.assignment),
                        "predicted_accuracy": mp.expected_accuracy,
                        "drain_rate_qps": mp.drain_rate_qps,
                        "mix_scv": mp.scv,
                    },
                ))
    save_json("multi_server_bench.json", rows)

    by_key = {(r["pattern"], r["mode"], r["num_servers"]): r for r in rows
              if r["mode"] != "static-mix"}
    ov1 = by_key[("sustained-overload", "homogeneous-switching", 1)]["compliance"]
    ov4 = by_key[("sustained-overload", "homogeneous-switching", 4)]["compliance"]
    mix_ov = by_key[("sustained-overload", "mix-shifting", MIX_C)]
    mix_fl = by_key[("flash-crowd", "mix-shifting", MIX_C)]
    hom_ov = by_key[("sustained-overload", "homogeneous-switching", MIX_C)]

    # acceptance check: best static heterogeneous mix vs the all-fast pool
    # under sustained overload.
    statics = [r for r in rows
               if r["mode"] == "static-mix" and r["pattern"] == "sustained-overload"]
    all_fast = next(r for r in statics if set(r["assignment"]) == {0})
    het = [r for r in statics if len(set(r["assignment"])) > 1]
    good = [r for r in het
            if r["compliance"] >= all_fast["compliance"] - 0.02
            and r["mean_accuracy"] > all_fast["mean_accuracy"]]
    best = max(good, key=lambda r: r["mean_accuracy"]) if good else None

    derived = (
        f"overload_compliance c1={ov1:.3f} c4={ov4:.3f} "
        f"(+{(ov4 - ov1) * 100:.1f}pts) "
        f"mix_shift c4: ov={mix_ov['compliance']:.3f}/acc={mix_ov['mean_accuracy']:.3f} "
        f"(hom acc={hom_ov['mean_accuracy']:.3f}) fl={mix_fl['compliance']:.3f} "
    )
    if best is not None:
        derived += (
            f"best_het_mix={best['assignment']} "
            f"comp={best['compliance']:.3f} (all-fast {all_fast['compliance']:.3f}) "
            f"acc={best['mean_accuracy']:.3f} (all-fast {all_fast['mean_accuracy']:.3f})"
        )
    else:
        derived += "best_het_mix=NONE (acceptance criterion FAILED)"
    return {
        "name": "multi_server",
        "us_per_call": t.elapsed / max(total_completed, 1) * 1e6,
        "derived": derived,
    }


if __name__ == "__main__":
    print(run())
