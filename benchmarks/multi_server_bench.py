"""Multi-server scaling: SLO compliance of c ∈ {1, 2, 4} worker pools.

Identical arrival traces are replayed against M/G/c simulator pools of
increasing size, each driven by an Elastico table derived for that c
(``derive_policies(..., num_servers=c)``).  Two beyond-paper load shapes
stress the pools:

- **sustained-overload**: rate steps to 2.5x one server's fastest-rung
  capacity — pools with c <= 2 are unstable, c = 4 drains it;
- **flash-crowd**: 10x ramp-hold-decay around a moderate base.

The derived headline tracks multi-worker throughput and the compliance gap
between c = 4 and c = 1 under sustained overload (which must be positive:
that is the acceptance criterion of the worker-pool refactor).
"""

from __future__ import annotations

from repro.core.aqm import HysteresisSpec, derive_policies
from repro.core.elastico import ElasticoController
from repro.core.pareto import LatencyProfile, ParetoPoint
from repro.serving.simulator import ServingSimulator, lognormal_sampler_from_profile
from repro.serving.workload import (
    flash_crowd_pattern,
    generate_arrivals,
    sustained_overload_pattern,
)

from .common import Timer, save_json

# synthetic three-rung ladder, the shape of the paper's Table I (seconds)
MEANS = [0.10, 0.25, 0.45]
P95S = [0.14, 0.35, 0.63]
ACCS = [0.76, 0.82, 0.85]
SLO_S = 1.0
DURATION_S = 120.0
POOL_SIZES = (1, 2, 4)


def _front():
    return [
        ParetoPoint(config=("rung", i), accuracy=a,
                    profile=LatencyProfile(mean=m, p95=p))
        for i, (m, p, a) in enumerate(zip(MEANS, P95S, ACCS))
    ]


def _traces(seed: int = 1):
    fastest_capacity_qps = 1.0 / MEANS[0]
    overload = sustained_overload_pattern(
        fastest_capacity_qps, overload_factor=2.5, warmup_s=20.0
    )
    flash = flash_crowd_pattern(3.0, peak_factor=10.0, crowd_start_s=40.0,
                                ramp_s=5.0, hold_s=20.0)
    return {
        "sustained-overload": generate_arrivals(overload, DURATION_S, seed=seed),
        "flash-crowd": generate_arrivals(flash, DURATION_S, seed=seed),
    }


def run() -> dict:
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    traces = _traces()
    rows = []
    total_completed = 0
    with Timer() as t:
        for pattern, arrivals in traces.items():
            for c in POOL_SIZES:
                table = derive_policies(
                    _front(),
                    slo_p95_s=SLO_S,
                    hysteresis=HysteresisSpec(downscale_cooldown_s=5.0),
                    num_servers=c,
                )
                sim = ServingSimulator(
                    sampler,
                    controller=ElasticoController(table),
                    seed=0,
                    num_servers=c,
                )
                out = sim.run(arrivals, DURATION_S)
                total_completed += len(out.completed)
                util = out.per_server_utilization()
                rows.append(
                    {
                        "pattern": pattern,
                        "num_servers": c,
                        "offered": len(arrivals),
                        "completed": len(out.completed),
                        "throughput_qps": len(out.completed) / DURATION_S,
                        "compliance": out.slo_compliance(SLO_S),
                        "p95_latency_s": out.p95_latency(),
                        "mean_wait_s": out.mean_wait(),
                        "mean_accuracy": out.mean_accuracy(ACCS),
                        "mean_utilization": sum(util) / len(util),
                        "per_server_utilization": util,
                        "switches": len(out.switch_events),
                    }
                )
    save_json("multi_server_bench.json", rows)

    by_key = {(r["pattern"], r["num_servers"]): r for r in rows}
    ov1 = by_key[("sustained-overload", 1)]["compliance"]
    ov4 = by_key[("sustained-overload", 4)]["compliance"]
    tput4 = by_key[("sustained-overload", 4)]["throughput_qps"]
    fl4 = by_key[("flash-crowd", 4)]["compliance"]
    return {
        "name": "multi_server",
        "us_per_call": t.elapsed / max(total_completed, 1) * 1e6,
        "derived": (
            f"overload_compliance c1={ov1:.3f} c4={ov4:.3f} "
            f"(+{(ov4 - ov1) * 100:.1f}pts) c4_tput={tput4:.1f}qps "
            f"flash_c4={fl4:.3f}"
        ),
    }


if __name__ == "__main__":
    print(run())
