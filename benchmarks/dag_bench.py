"""Workflow-DAG serving: the RAG pipeline as a 3-stage tandem scenario.

The rest of the benchmark suite serves the RAG workflow as one opaque
service time; this benchmark serves it as the compound pipeline it is —
retrieve -> rerank -> generate, each stage with its own config ladder,
worker, and FIFO queue (:mod:`repro.serving.dag`):

- **Network model** (validation): every pipeline rung is replayed across
  a load grid via the chained-Lindley fast path
  (:meth:`repro.core.planner.Planner.validate_pipeline`) and compared
  against the stationary queueing-network prediction — per-stage
  Allen-Cunneen waits with departure-SCV propagation
  (:func:`repro.serving.dag.pipeline_sojourn`).
- **Pipeline switching under diurnal load** (the headline): the
  pipeline-level Elastico controller — per-stage queue depths collapsed
  to bottleneck-equivalent units, thresholds from
  :func:`repro.serving.dag.derive_pipeline_policies` — against the two
  static baselines on the same diurnal trace, on the event-heap
  :class:`repro.serving.dag.DagSimulator`.  Acceptance: dynamic beats
  static-accurate on SLO compliance and static-fast on accuracy.
- **Fork-join**: two parallel retrieve branches joining at rerank; the
  synchronization penalty (``E[max]`` of the branch sojourns, harmonic
  growth) measured against :func:`repro.core.aqm.fork_join_sojourn`.

Writes ``experiments/dag_bench.json`` (full) /
``experiments/dag_bench_smoke.json`` (smoke; stable-scrubbed so the
tier-1 subprocess gate's rerun is diff-clean).
"""

from __future__ import annotations

import math

from repro.core.elastico import ElasticoController
from repro.core.planner import Planner
from repro.serving.dag import (
    DagSimulator,
    StageSpec,
    WorkflowDAG,
    derive_pipeline_policies,
    fork_join_sojourn,
    pipeline_sojourn,
)
from repro.serving.workload import diurnal_pattern, generate_arrivals
from repro.workflows.surrogate import RagSurrogate

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import RAG_BUDGET, Timer, make_profiler, save_json, search

# Trajectory measurements (BENCH_dag_bench.json): the pipeline-switching
# headline — dynamic compliance AND its margins over both statics — plus
# the network-model fit.  All seed-deterministic (virtual-time metrics),
# so they tolerate only small drift (tolerance 5%; the compliance gap has
# more replication noise at smoke sizes, so it gets 15%).
BENCH_SPEC = BenchmarkSpec(
    artifact="dag_bench.json",
    smoke_artifact="dag_bench_smoke.json",
    measurements=(
        MeasurementSpec("dynamic_compliance", "frac", True,
                        path="diurnal.dynamic.slo_compliance",
                        tolerance=0.05),
        MeasurementSpec("dynamic_accuracy", "frac", True,
                        path="diurnal.dynamic.mean_accuracy",
                        tolerance=0.05),
        MeasurementSpec(
            "compliance_gain_vs_static_accurate", "pts", True,
            extract=lambda p: (p["diurnal"]["dynamic"]["slo_compliance"]
                               - p["diurnal"]["static_accurate"]
                               ["slo_compliance"]),
            tolerance=0.15),
        MeasurementSpec(
            "accuracy_gain_vs_static_fast", "pts", True,
            extract=lambda p: (p["diurnal"]["dynamic"]["mean_accuracy"]
                               - p["diurnal"]["static_fast"]
                               ["mean_accuracy"]),
            tolerance=0.15),
        MeasurementSpec("sojourn_model_max_rel_err", "frac", False,
                        path="network_model.sojourn_max_rel_err",
                        tolerance=0.25),
        MeasurementSpec("jax_pipeline_sweep_speedup", "x", True,
                        path="pipeline_sweep.jax_speedup", target=5.0,
                        volatile=True, smoke=False, optional=True),
    ),
)
from .fastsim_bench import run_metadata

TAU = 0.75          # relative-accuracy floor (table1/fig7 setting)
SLO_S = 1.0         # 1000 ms end-to-end p95, the paper's serving SLO
PERIOD_S = 300.0    # compressed diurnal cycle for the event-heap runs
AMPLITUDE = 0.8
_Z95 = 1.6448536269514722

STAGE_ORDER = ("retrieve", "rerank", "generate")


def _p95_from_cv(mean: float, cv: float) -> float:
    """p95 of the lognormal with the given mean and coefficient of
    variation — the tail model the surrogate profiler samples from."""
    sigma = math.sqrt(math.log(1.0 + cv * cv))
    return mean * math.exp(_Z95 * sigma - sigma * sigma / 2.0)


def build_pipeline():
    """The RAG plan's admitted ladder, decomposed into its 3 stages.

    The single-stage planner picks the rungs (Pareto + SLO admission,
    exactly as every other benchmark plans RAG); each rung's config is
    then split via :meth:`repro.workflows.surrogate.RagSurrogate.stage_latencies_s`
    into per-stage mean ladders, so pipeline rung r == plan rung r by
    construction (the diagonal rung walk).  Rung accuracy rides on the
    generate stage — retrieval quality already feeds the surrogate's
    end-to-end accuracy model, and the pipeline product must reproduce
    the plan's per-rung accuracy exactly."""
    sur = RagSurrogate()
    res = search(sur, TAU, RAG_BUDGET)
    planner = Planner(profiler=make_profiler(sur))
    plan = planner.plan(res.feasible, slo_p95_s=SLO_S)
    cv = sur.latency_cv(plan.table.policies[0].point.config)
    stage_means = {name: [] for name in STAGE_ORDER}
    accs = []
    for pol in plan.table.policies:
        parts = sur.stage_latencies_s(pol.point.config)
        for name in STAGE_ORDER:
            stage_means[name].append(parts[name])
        accs.append(pol.point.accuracy)
    stages = [
        StageSpec(
            name=name,
            mean_s=tuple(stage_means[name]),
            p95_s=tuple(_p95_from_cv(m, cv) for m in stage_means[name]),
            accuracy=(tuple(accs) if name == "generate"
                      else (1.0,) * len(accs)),
        )
        for name in STAGE_ORDER
    ]
    dag = WorkflowDAG.tandem(stages)
    rungs = [(r,) * len(STAGE_ORDER) for r in range(len(accs))]
    table = derive_pipeline_policies(dag, slo_p95_s=SLO_S, rungs=rungs)
    return sur, planner, dag, table


# Pipeline-sweep cell (the jax >= 5x acceptance measurement): an 8-stage
# agentic-RAG tandem whose pooled (c > 1) stages are exactly where the
# numpy chained path degrades to the per-request Kiefer-Wolfowitz Python
# loop — the regime the jax pipeline grid exists for (jitted comparator
# scans + host permutations).  R=4 x K=5 x L=8 rungs/loads over 150 s
# traces gives a ~4.7M-slot grid (>= 1e6-slot full-size bar); rungs pin
# only the generate/verify configs, the common-random-numbers layout that
# lets coinciding stage configs share one service draw.  Full-run only.
SWEEP_STAGES = [
    ("plan",      2, [0.010], [0.025]),
    ("retrieve1", 8, [0.120], [0.300]),
    ("rerank1",   4, [0.060], [0.150]),
    ("retrieve2", 8, [0.120], [0.300]),
    ("rerank2",   4, [0.060], [0.150]),
    ("generate",  2, [0.035, 0.028, 0.022, 0.017, 0.013],
                     [0.090, 0.070, 0.055, 0.042, 0.032]),
    ("verify",    2, [0.024, 0.020, 0.018, 0.016, 0.014],
                     [0.070, 0.056, 0.048, 0.042, 0.036]),
    ("moderate",  2, [0.010], [0.030]),
]
SWEEP_CFG = dict(
    arrival_rates_qps=(10.0, 14.0, 18.0, 22.0, 26.0, 30.0, 35.0, 40.0),
    duration_s=150.0, replications=4, slo_s=1.5, seed=11)


def measure_pipeline_sweep(*, repeats: int = 3) -> dict:
    """numpy vs jax on the full-size pooled-pipeline sweep, interleaved
    median-of-``repeats`` after compile warmup (the fastsim_bench
    large-sweep protocol).  Skipped, with the import reason, when jax is
    unavailable."""
    import statistics
    import time as _time

    from repro.serving import fastsim
    from repro.serving.dag import sweep_pipeline

    dag = WorkflowDAG.tandem([
        StageSpec(name=n, mean_s=tuple(m), p95_s=tuple(p), num_servers=c)
        for n, c, m, p in SWEEP_STAGES])
    rungs = [[0, 0, 0, 0, 0, k, k, 0] for k in range(5)]
    out = {"grid": {"stages": dag.num_stages, "rungs": len(rungs),
                    "loads": len(SWEEP_CFG["arrival_rates_qps"]),
                    "replications": SWEEP_CFG["replications"],
                    "duration_s": SWEEP_CFG["duration_s"]}}
    if not fastsim.jax_available():
        out["skipped"] = (f"jax not importable "
                          f"({fastsim.jax_unavailable_reason()})")
        print(f"dag_bench: pipeline-sweep jax section skipped: "
              f"{out['skipped']}")
        return out

    def once(backend):
        t0 = _time.perf_counter()
        res = sweep_pipeline(dag, rungs, backend=backend,
                             scan_impl="sequential", **SWEEP_CFG)
        return _time.perf_counter() - t0, res

    once("jax")       # compile warmup
    once("numpy")     # page-fault warmup
    npy, jx = [], []
    for _ in range(repeats):
        tn, rn = once("numpy")
        tj, rj = once("jax")
        npy.append(tn)
        jx.append(tj)
    n_s = statistics.median(npy)
    j_s = statistics.median(jx)
    out.update({
        "slots": rn.num_requests * dag.num_stages,
        "bit_equal": rn.mean_latency_s == rj.mean_latency_s
                     and rn.p95_latency_s == rj.p95_latency_s,
        "numpy_s": n_s,
        "jax_s": j_s,
        "jax_speedup": n_s / j_s,
    })
    return out


def _capacity(dag, pol):
    """Bottleneck drain rate c_b / s_b of one pipeline rung — the load
    the diurnal peak is calibrated against: the peak must saturate the
    slowest rung's bottleneck (static-accurate sheds SLO) while staying
    below ~85% of the fastest rung's capacity (the switching ladder can
    always escape)."""
    b = pol.bottleneck_stage
    return dag.stages[b].num_servers / dag.stages[b].mean_s[pol.stage_indices[b]]


def _serve_metrics(result):
    return {
        "completed": result.num_completed,
        "slo_compliance": result.slo_compliance(SLO_S),
        "mean_accuracy": result.mean_pipeline_accuracy(),
        "p95_latency_s": result.p95_latency(),
        "mean_wait_s": result.mean_wait(),
        "switches": len(result.switch_events),
    }


def _run(*, periods: int, replications: int, validate_duration_s: float,
         artifact: str, stable: bool, large: bool = False) -> dict:
    sur, planner, dag, table = build_pipeline()
    with Timer() as t:
        # -- part 1: queueing-network model vs chained-recursion sweep ---
        from repro.serving.dag import PipelinePlan

        plan = PipelinePlan(dag=dag, table=table)
        val = planner.validate_pipeline(
            plan, load_fractions=(0.4, 0.6, 0.75),
            duration_s=validate_duration_s, replications=replications,
            seed=0)
        model_err = val.sojourn_model_error()

        # -- part 2: pipeline switching vs static baselines --------------
        cap_fast = _capacity(dag, table.policies[0])
        cap_slow = _capacity(dag, table.policies[-1])
        peak = min(1.35 * cap_slow, 0.85 * cap_fast)
        base = peak / (1.0 + AMPLITUDE)
        duration = periods * PERIOD_S
        pattern = diurnal_pattern(base, period_s=PERIOD_S,
                                  amplitude=AMPLITUDE)
        arrivals = generate_arrivals(pattern, duration, seed=21)

        def serve(controller, static_rung=0):
            sim = DagSimulator(
                dag,
                controller=controller,
                static_rung=static_rung,
                rungs=[pol.stage_indices for pol in table.policies],
                seed=17,
            )
            return _serve_metrics(sim.run(arrivals, duration))

        dynamic = serve(ElasticoController(table))
        static_fast = serve(None, static_rung=0)
        static_acc = serve(None, static_rung=table.ladder_size - 1)

        # -- part 3: fork-join synchronization penalty -------------------
        ret = dag.stages[0]
        fj = WorkflowDAG.fork_join(
            [StageSpec("ret_a", ret.mean_s, ret.p95_s),
             StageSpec("ret_b", ret.mean_s, ret.p95_s)],
            dag.stages[1],
            tail=[dag.stages[2]])
        fj_cfg = tuple(table.policies[0].stage_indices[j]
                       for j in (0, 0, 1, 2))
        fj_rate = 0.5 * cap_fast
        fj_arr = generate_arrivals(lambda _t: fj_rate, duration / 2.0,
                                   seed=23)
        fj_sim = DagSimulator(fj, static_stage_indices=fj_cfg, seed=29)
        fj_res = fj_sim.run(fj_arr, duration / 2.0)
        fj_pred = pipeline_sojourn(fj, fj_cfg, fj_rate)
        fj_sim_mean = (sum(r.latency_s for r in fj_res.completed)
                       / max(len(fj_res.completed), 1))
        branch_mean = ret.mean_s[fj_cfg[0]]
        sync_penalty = fork_join_sojourn([branch_mean, branch_mean]) / branch_mean

    ok = (dynamic["slo_compliance"] > static_acc["slo_compliance"]
          and dynamic["mean_accuracy"] > static_fast["mean_accuracy"])
    payload = {
        "metadata": run_metadata(),
        "pipeline": {
            "stages": [s.name for s in dag.stages],
            "rungs": table.ladder_size,
            "slo_s": SLO_S,
            "ladder": [
                {
                    "stage_indices": list(pol.stage_indices),
                    "mean_latency_s": pol.mean_latency_s,
                    "p95_latency_s": pol.p95_latency_s,
                    "accuracy": pol.accuracy,
                    "bottleneck": dag.stages[pol.bottleneck_stage].name,
                    "upscale_threshold": pol.upscale_threshold,
                    "downscale_threshold": pol.downscale_threshold,
                }
                for pol in table.policies
            ],
        },
        "network_model": {
            "arrival_rates_qps": list(val.arrival_rates_qps),
            "replications": val.replications,
            "num_requests": val.num_requests,
            "sojourn_max_rel_err": model_err,
        },
        "diurnal": {
            "base_qps": base,
            "peak_qps": peak,
            "period_s": PERIOD_S,
            "duration_s": duration,
            "requests": len(arrivals),
            "dynamic": dynamic,
            "static_fast": static_fast,
            "static_accurate": static_acc,
            "acceptance_ok": ok,
        },
        "fork_join": {
            "rate_qps": fj_rate,
            "requests": len(fj_res.completed),
            "sim_mean_sojourn_s": fj_sim_mean,
            "model_mean_sojourn_s": fj_pred,
            "sync_penalty": sync_penalty,
        },
    }
    if large:
        payload["pipeline_sweep"] = measure_pipeline_sweep()
    save_json(artifact, payload, stable=stable)
    return {
        "name": "dag_bench",
        "us_per_call": t.elapsed * 1e6,
        "derived": (
            f"pipeline={len(dag.stages)}stages/{table.ladder_size}rungs "
            f"model_err={model_err:.3f} "
            f"dyn_comp={dynamic['slo_compliance']:.4f} "
            f"acc_comp={static_acc['slo_compliance']:.4f} "
            f"dyn_acc={dynamic['mean_accuracy']:.4f} "
            f"fast_acc={static_fast['mean_accuracy']:.4f} "
            f"switches={dynamic['switches']} "
            f"fj_penalty={sync_penalty:.2f}x"
            + (f" jax_sweep={payload['pipeline_sweep']['jax_speedup']:.2f}x"
               if "jax_speedup" in payload.get("pipeline_sweep", {}) else "")
            + ("" if ok else " [pipeline switching acceptance FAILED]")
        ),
    }


def run() -> dict:
    return _run(periods=12, replications=4, validate_duration_s=300.0,
                artifact="dag_bench.json", stable=False, large=True)


def run_smoke() -> dict:
    """Three diurnal cycles and a short validation grid — same code paths,
    separate stable-scrubbed artifact so the tier-1 gate is diff-clean."""
    return _run(periods=3, replications=2, validate_duration_s=90.0,
                artifact="dag_bench_smoke.json", stable=True)


if __name__ == "__main__":
    print(run())
