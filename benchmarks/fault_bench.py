"""Chaos benchmark: degradation-aware adaptation through a capacity outage.

The scenario stacks the fault plane's three hazards against a c = 4 pool
serving the synthetic three-rung ladder (the Table-I shape also used by
:mod:`benchmarks.multi_server_bench`):

- **crash/recover**: workers 0 and 1 crash in sequence mid-run and come
  back together much later — the pool spends the middle of the run at
  half capacity;
- **flash crowd**: the arrival rate ramps to 2x base *during* the outage
  (the compound failure the paper's fixed-capacity setting fears most);
- **straggler**: one surviving worker serves 1.5x slower for a stretch
  of the outage window.

Three arms replay the identical trace, all through the
:func:`repro.serving.fastsim.simulate` dispatcher (a non-empty fault
schedule routes every arm to the event-heap oracle):

- ``degradation-aware``: Elastico over the full-capacity table plus the
  pre-derived per-c' degraded tables
  (:func:`repro.core.aqm.derive_degraded_tables`);
  :meth:`repro.core.elastico.ElasticoController.on_capacity_change`
  swaps the active table the moment the scheduler loses or regains a
  worker, so thresholds always describe the *surviving* capacity.
- ``fault-oblivious``: the same controller with full-capacity thresholds
  only — it still reacts to the backlog the outage causes, but with
  N(up) targets sized for 4 workers it reacts late and relaxes early.
- ``static-accurate``: the most-accurate rung pinned, the paper's
  fault-free baseline — at half capacity its service rate is below the
  crowd's arrival rate, so the queue (and latency) diverge until
  recovery.

The headline (and the smoke gate) is the PR's acceptance criterion:
degradation-aware SLO compliance must be >= 1.5x the static ladder's
through the outage.  Everything is virtual-time deterministic given the
seeds.
"""

from __future__ import annotations

from repro.core.aqm import HysteresisSpec, derive_degraded_tables, derive_policies
from repro.core.elastico import ElasticoController
from repro.core.pareto import LatencyProfile, ParetoPoint
from repro.serving import fastsim
from repro.serving.faults import FaultSchedule, Straggler, WorkerCrash
from repro.serving.simulator import lognormal_sampler_from_profile
from repro.serving.workload import flash_crowd_pattern, generate_arrivals

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import Timer, save_json


def _variant(rows, name):
    (row,) = [r for r in rows if r["variant"] == name]
    return row


# Trajectory measurements (BENCH_fault_bench.json): the acceptance-
# criterion ratio (>= 1.5x static through the outage), the aware arm's
# absolute compliance, and its margin over fault-oblivious switching.
BENCH_SPEC = BenchmarkSpec(
    artifact="fault_bench.json",
    smoke_artifact="fault_bench_smoke.json",
    measurements=(
        MeasurementSpec(
            "aware_vs_static_compliance", "x", True,
            extract=lambda rows: (
                _variant(rows, "degradation-aware")["compliance"]
                / max(_variant(rows, "static-accurate")["compliance"], 1e-9)),
            target=1.5, tolerance=0.15),
        MeasurementSpec(
            "aware_compliance", "frac", True,
            extract=lambda rows: _variant(
                rows, "degradation-aware")["compliance"],
            tolerance=0.05),
        MeasurementSpec(
            "aware_vs_oblivious_goodput", "x", True,
            extract=lambda rows: (
                _variant(rows, "degradation-aware")["goodput"]
                / max(_variant(rows, "fault-oblivious")["goodput"], 1e-9)),
            tolerance=0.10),
    ),
)

# the synthetic Table-I-shaped ladder (seconds) at a 1 s SLO
MEANS = [0.10, 0.25, 0.45]
P95S = [0.14, 0.35, 0.63]
ACCS = [0.76, 0.82, 0.85]
SLO_S = 1.0
NUM_SERVERS = 4
# base 6 qps: the accurate rung is stable at c = 4 (rho ~ 0.68) and
# unstable at c = 2 (service rate 4.4 qps) — the outage alone breaks the
# static ladder, and the 2x crowd during it breaks it decisively
BASE_QPS = 6.0
CROWD_FACTOR = 2.0
DURATION_S = 120.0


def _front():
    return [
        ParetoPoint(config=("rung", i), accuracy=a,
                    profile=LatencyProfile(mean=m, p95=p))
        for i, (m, p, a) in enumerate(zip(MEANS, P95S, ACCS))
    ]


def _scenario(duration_s: float, seed: int = 1):
    """The trace and the fault schedule, timed as fractions of the horizon
    so smoke runs exercise the same phases."""
    crowd = flash_crowd_pattern(
        BASE_QPS, peak_factor=CROWD_FACTOR,
        crowd_start_s=0.35 * duration_s,
        ramp_s=0.05 * duration_s,
        hold_s=0.20 * duration_s)
    arrivals = generate_arrivals(crowd, duration_s, seed=seed)
    faults = FaultSchedule(
        crashes=(
            WorkerCrash(time_s=0.25 * duration_s, worker_id=0,
                        recover_s=0.70 * duration_s),
            WorkerCrash(time_s=0.30 * duration_s, worker_id=1,
                        recover_s=0.70 * duration_s),
        ),
        stragglers=(
            Straggler(worker_id=2, start_s=0.40 * duration_s,
                      end_s=0.50 * duration_s, factor=1.5),
        ),
    )
    return arrivals, faults


def _run(duration_s: float, artifact: str = "fault_bench.json",
         stable: bool = False) -> dict:
    sampler = lognormal_sampler_from_profile(MEANS, P95S)
    arrivals, faults = _scenario(duration_s)
    hyst = HysteresisSpec(downscale_cooldown_s=5.0)
    table = derive_policies(_front(), slo_p95_s=SLO_S, hysteresis=hyst,
                            num_servers=NUM_SERVERS)
    degraded = derive_degraded_tables(_front(), slo_p95_s=SLO_S,
                                      hysteresis=hyst,
                                      num_servers=NUM_SERVERS)
    arms = {
        "degradation-aware": lambda: (
            ElasticoController(table, degraded_tables=degraded), 0),
        "fault-oblivious": lambda: (ElasticoController(table), 0),
        "static-accurate": lambda: (None, len(MEANS) - 1),
    }
    rows = []
    total_completed = 0
    with Timer() as t:
        for name, make in arms.items():
            ctrl, static = make()
            out = fastsim.simulate(
                sampler, arrivals, duration_s,
                controller=ctrl,
                static_index=static,
                seed=0,
                num_servers=NUM_SERVERS,
                faults=faults,
            )
            total_completed += out.num_completed
            rows.append({
                "variant": name,
                "offered": out.offered,
                "completed": out.num_completed,
                "failed": out.failed,
                "retried": out.retried,
                "in_flight": out.in_flight,
                "compliance": out.slo_compliance(SLO_S),
                "goodput": out.goodput(SLO_S),
                "p95_latency_s": out.p95_latency(),
                "mean_accuracy": out.mean_accuracy(ACCS),
                "switches": len(out.switch_events),
                "capacity_swaps": (len(ctrl.capacity_timeline)
                                   if ctrl is not None else 0),
            })
    save_json(artifact, rows, stable=stable)

    aware = _variant(rows, "degradation-aware")
    obliv = _variant(rows, "fault-oblivious")
    static = _variant(rows, "static-accurate")
    ratio = aware["compliance"] / max(static["compliance"], 1e-9)
    derived = (
        f"c={NUM_SERVERS} outage+crowd: compliance "
        f"aware={aware['compliance']:.3f} "
        f"oblivious={obliv['compliance']:.3f} "
        f"static={static['compliance']:.3f} ({ratio:.2f}x static, "
        f"{aware['capacity_swaps']} capacity swaps, "
        f"{aware['retried']} retries)"
        + ("" if ratio >= 1.5 else " [<1.5x: acceptance FAILED]")
    )
    return {
        "name": "fault_bench",
        "us_per_call": t.elapsed / max(total_completed, 1) * 1e6,
        "derived": derived,
    }


def run() -> dict:
    return _run(DURATION_S)


def run_smoke() -> dict:
    """Smallest setting: a 40 s horizon with the same phase fractions —
    the outage, crowd, and straggler windows all still overlap.  The
    smoke gate asserts the >= 1.5x acceptance ratio so a regression in
    degradation-aware switching fails CI, not just the full run."""
    result = _run(40.0, artifact="fault_bench_smoke.json", stable=True)
    if "FAILED" in result["derived"]:
        raise AssertionError(
            f"fault_bench smoke gate: {result['derived']}")
    return result


if __name__ == "__main__":
    print(run())
