"""Beyond-paper ablation: reactive Elastico vs predictive (anticipatory)
switching — the extension the paper's §VIII names as future work.

Compares SLO compliance / accuracy / switch counts on the spike and bursty
patterns at the paper's middle SLO, plus the aggressive-descent option.
"""

from __future__ import annotations

from repro.core.elastico import ElasticoController
from repro.core.predictive import PredictiveElastico

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import Timer, paper_arrivals, plan_for, save_json, simulate


def _cell(rows, pattern, variant):
    return next(r for r in rows
                if r["pattern"] == pattern and r["variant"] == variant)


# Trajectory measurements (BENCH_predictive_ablation.json): what the 3 s
# prediction horizon buys on the spike pattern — compliance gained over
# the reactive controller and the accuracy paid for it.
BENCH_SPEC = BenchmarkSpec(
    artifact="predictive_ablation.json",
    measurements=(
        MeasurementSpec(
            "spike_predictive_h3_compliance", "frac", True,
            extract=lambda rows: _cell(rows, "spike",
                                       "predictive_h3")["compliance"],
            tolerance=0.05),
        MeasurementSpec(
            "spike_compliance_gain_vs_reactive", "pts", True,
            extract=lambda rows: (
                _cell(rows, "spike", "predictive_h3")["compliance"]
                - _cell(rows, "spike", "reactive")["compliance"]),
            tolerance=0.50),
    ),
)
from .table1_baselines import build_plan

SLO_S = 1.0


def run() -> dict:
    sur, res, _ = build_plan()
    plan = plan_for(sur, res.feasible, SLO_S)

    rows = []
    with Timer() as t:
        for pattern in ("spike", "bursty"):
            arrivals = paper_arrivals(pattern)
            variants = {
                "reactive": ElasticoController(plan.table),
                "predictive_h1": PredictiveElastico(plan.table, horizon_s=1.0),
                "predictive_h3": PredictiveElastico(plan.table, horizon_s=3.0),
                "predictive_h3_aggr": PredictiveElastico(
                    plan.table, horizon_s=3.0, aggressive_descent=True
                ),
                "reactive_aggr": ElasticoController(
                    plan.table, aggressive_descent=True
                ),
            }
            for name, ctrl in variants.items():
                out, acc = simulate(sur, plan, arrivals, 180.0, controller=ctrl)
                rows.append(
                    {
                        "pattern": pattern,
                        "variant": name,
                        "compliance": out.slo_compliance(SLO_S),
                        "mean_accuracy": acc,
                        "p95_ms": out.p95_latency() * 1e3,
                        "switches": len(out.switch_events),
                    }
                )
    save_json("predictive_ablation.json", rows)
    sp = {r["variant"]: r for r in rows if r["pattern"] == "spike"}
    d = sp["predictive_h3"]["compliance"] - sp["reactive"]["compliance"]
    return {
        "name": "predictive_ablation",
        "us_per_call": t.elapsed / len(rows) * 1e6,
        "derived": (
            f"reactive={sp['reactive']['compliance']:.3f} "
            f"predictive_h3={sp['predictive_h3']['compliance']:.3f} "
            f"delta={d * 100:+.1f}pts"
        ),
    }


if __name__ == "__main__":
    print(run())
