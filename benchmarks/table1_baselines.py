"""Table I: the generated Pareto-front baseline configurations.

The paper's ladder at tau=0.75: Fast (F1 0.761, ~200ms), Medium (0.825,
~450ms), Accurate (0.853, ~700ms).  We run the same search+plan pipeline and
report the fastest / middle / most-accurate rungs.
"""

from __future__ import annotations

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import RAG_BUDGET, Timer, plan_for, save_json, search

# Trajectory measurements (BENCH_table1_baselines.json): the named
# baseline ladder — its size, the accurate rung's accuracy ceiling and
# the fast rung's p95 floor (the two ends paper Table I anchors).
BENCH_SPEC = BenchmarkSpec(
    artifact="table1_baselines.json",
    measurements=(
        MeasurementSpec("ladder_size", "rungs", True, path="ladder_size",
                        tolerance=0.01),
        MeasurementSpec(
            "accurate_rung_accuracy", "frac", True,
            extract=lambda p: max(r["accuracy"] for r in p["rows"]),
            tolerance=0.05),
        MeasurementSpec(
            "fast_rung_p95_ms", "ms", False,
            extract=lambda p: min(r["p95_ms"] for r in p["rows"]),
            tolerance=0.10),
    ),
)
from repro.workflows.surrogate import RagSurrogate


def build_plan(slo_s: float = 1.5):
    sur = RagSurrogate(seed=0)
    res = search(sur, 0.75, RAG_BUDGET)
    plan = plan_for(sur, res.feasible, slo_s)
    return sur, res, plan


def run() -> dict:
    with Timer() as t:
        sur, res, plan = build_plan()
    ladder = plan.table.policies
    named = {
        "Fast": ladder[0],
        "Medium": ladder[len(ladder) // 2],
        "Accurate": ladder[-1],
    }
    payload = []
    for name, pol in named.items():
        p = pol.point
        payload.append(
            {
                "name": name,
                "config": list(p.config),
                "accuracy": round(p.accuracy, 3),
                "mean_ms": round(p.profile.mean * 1e3, 1),
                "p95_ms": round(p.profile.p95 * 1e3, 1),
                "N_up": pol.upscale_threshold,
                "N_dn": pol.downscale_threshold,
            }
        )
    save_json("table1_baselines.json", {"ladder_size": len(ladder), "rows": payload})
    return {
        "name": "table1_baselines",
        "us_per_call": t.elapsed * 1e6,
        "derived": (
            f"fast_acc={payload[0]['accuracy']} acc_acc={payload[2]['accuracy']} "
            f"ladder={len(ladder)}"
        ),
    }


if __name__ == "__main__":
    print(run())
