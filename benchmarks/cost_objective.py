"""Beyond-paper: cost/energy as serving objectives (paper §VIII).

Annotates the tau=0.75 RAG ladder with per-rung cost and compares the
OPERATING cost of Elastico vs the static baselines under the spike workload:
adaptive switching should land near static-fast's cost while holding higher
accuracy — the cost story mirrors the latency story.
"""

from __future__ import annotations

from repro.core.cost import annotate_costs, timeline_cost
from repro.core.elastico import ElasticoController

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import Timer, paper_arrivals, plan_for, save_json, simulate


def _run_row(p, variant):
    return next(r for r in p["runs"] if r["variant"] == variant)


# Trajectory measurements (BENCH_cost_objective.json): the cost story —
# Elastico's $/1k requests, the saving vs static-accurate, and the
# compliance it holds while saving.
BENCH_SPEC = BenchmarkSpec(
    artifact="cost_objective.json",
    measurements=(
        MeasurementSpec(
            "elastico_usd_per_1k", "usd", False,
            extract=lambda p: _run_row(p, "elastico")["usd_per_1k"],
            tolerance=0.10),
        MeasurementSpec(
            "cost_saving_vs_static_accurate", "frac", True,
            extract=lambda p: (
                1.0 - _run_row(p, "elastico")["usd_per_1k"]
                / _run_row(p, "static-accurate")["usd_per_1k"]),
            tolerance=0.15),
        MeasurementSpec(
            "elastico_compliance", "frac", True,
            extract=lambda p: _run_row(p, "elastico")["compliance"],
            tolerance=0.05),
    ),
)
from .table1_baselines import build_plan

SLO_S = 1.0
CHIPS = 1  # the paper's single-server box; scale freely for a pod slice


def run() -> dict:
    sur, res, _ = build_plan()
    plan = plan_for(sur, res.feasible, SLO_S)
    rungs = annotate_costs(plan, chips=CHIPS)
    arrivals = paper_arrivals("spike")
    ladder = plan.table.policies

    rows = []
    with Timer() as t:
        for name, ctrl, static in [
            ("elastico", ElasticoController(plan.table), 0),
            ("static-fast", None, 0),
            ("static-accurate", None, len(ladder) - 1),
        ]:
            out, acc = simulate(sur, plan, arrivals, 180.0,
                                controller=ctrl, static=static)
            # config_counts() is array-backed on the fast path (the static
            # baselines) and a plain histogram on the event-heap oracle
            per_rung = out.config_counts()
            cost = timeline_cost(out.config_timeline, per_rung, rungs)
            rows.append({
                "variant": name,
                "compliance": out.slo_compliance(SLO_S),
                "accuracy": acc,
                **cost,
            })

    payload = {
        "rungs": [vars(r) for r in rungs],
        "runs": rows,
    }
    save_json("cost_objective.json", payload)
    el = rows[0]
    fa = rows[1]
    return {
        "name": "cost_objective",
        "us_per_call": t.elapsed / len(rows) * 1e6,
        "derived": (
            f"elastico=${el['usd_per_1k']:.4f}/1k "
            f"fast=${fa['usd_per_1k']:.4f}/1k "
            f"acc_delta=+{(el['accuracy'] - fa['accuracy']) * 100:.1f}pts"
        ),
    }


if __name__ == "__main__":
    print(run())
