"""Kernel microbenchmarks: allclose vs oracle + wall-clock of the jitted
reference path on CPU (the Pallas kernels themselves run interpret=True here;
TPU timing is projected by the roofline analysis, not measured)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cross_entropy import ops as ce_ops, ref as ce_ref
from repro.kernels.decode_attention import ops as da_ops, ref as da_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.rmsnorm import ops as rn_ops, ref as rn_ref
from repro.kernels.ssm_scan import ops as ss_ops, ref as ss_ref

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import save_json

# Trajectory measurements (BENCH_kernels_bench.json): per-kernel accuracy
# vs the reference oracle (deterministic) and the aggregate reference
# wall time (volatile — it is a timing, so it rides the trajectory with a
# generous tolerance rather than the stable artifact).
BENCH_SPEC = BenchmarkSpec(
    artifact="kernels_bench.json",
    measurements=(
        MeasurementSpec(
            "worst_kernel_abs_err", "abs", False,
            extract=lambda rows: max(r["max_abs_err_vs_oracle"]
                                     for r in rows),
            tolerance=0.50),
        MeasurementSpec(
            "kernel_count", "kernels", True,
            extract=lambda rows: len(rows), tolerance=0.01),
        MeasurementSpec(
            "total_ref_wall_us", "us", False,
            extract=lambda rows: sum(r["ref_wall_us"] for r in rows),
            volatile=True),
    ),
)


def time_fn(fn, *args, iters=5):
    # warmup: trigger compilation ONCE and block on that same result
    # (the old one-liner evaluated fn(*args) twice — once for the
    # isinstance check, once for the chosen branch)
    out = fn(*args)
    if isinstance(out, tuple):
        out[0].block_until_ready()
    else:
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run() -> dict:
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    rows = []

    # flash attention
    B, H, S, D = 2, 4, 512, 64
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    out = fa_ops.flash_attention(q, k, v, causal=True, block_q=256, block_k=256, interpret=True)
    ref = fa_ref.attention_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(out - ref)))
    jit_ref = jax.jit(lambda q, k, v: fa_ref.attention_ref(q, k, v, causal=True))
    rows.append(("flash_attention", time_fn(jit_ref, q, k, v), err))

    # decode attention
    S = 4096
    q1 = jax.random.normal(ks[3], (B, H, D))
    k1 = jax.random.normal(ks[4], (B, S, H, D))
    v1 = jax.random.normal(ks[5], (B, S, H, D))
    length = jnp.asarray(S * 3 // 4, jnp.int32)
    out = da_ops.decode_attention(q1, k1, v1, length, block_k=1024, interpret=True)
    ref = da_ref.decode_attention_ref(q1, k1, v1, length)
    err = float(jnp.max(jnp.abs(out - ref)))
    jit_ref = jax.jit(lambda *a: da_ref.decode_attention_ref(*a))
    rows.append(("decode_attention", time_fn(jit_ref, q1, k1, v1, length), err))

    # rmsnorm
    x = jax.random.normal(ks[6], (256, 2048))
    g = jax.random.normal(ks[7], (2048,))
    out = rn_ops.rmsnorm(x, g, interpret=True)
    ref = rn_ref.rmsnorm_ref(x, g)
    err = float(jnp.max(jnp.abs(out - ref)))
    rows.append(("rmsnorm", time_fn(jax.jit(rn_ref.rmsnorm_ref), x, g), err))

    # ssm scan
    T, DI, N = 256, 256, 16
    decay = jax.nn.sigmoid(jax.random.normal(ks[0], (1, T, DI, N)))
    drive = 0.1 * jax.random.normal(ks[1], (1, T, DI, N))
    c = jax.random.normal(ks[2], (1, T, N))
    out = ss_ops.ssm_scan(decay, drive, c, block_d=128, time_chunk=128, interpret=True)
    ref = ss_ref.ssm_scan_ref(decay, drive, c)
    err = float(jnp.max(jnp.abs(out - ref)))
    rows.append(("ssm_scan", time_fn(jax.jit(ss_ref.ssm_scan_ref), decay, drive, c), err))

    # chunked cross-entropy
    T, V = 512, 8192
    logits = jax.random.normal(ks[3], (T, V)) * 4
    labels = jax.random.randint(ks[4], (T,), 0, V)
    out = ce_ops.cross_entropy(logits, labels, block_t=256, block_v=2048, interpret=True)
    ref = ce_ref.cross_entropy_ref(logits, labels)
    err = float(jnp.max(jnp.abs(out - ref)))
    rows.append(("cross_entropy", time_fn(jax.jit(ce_ref.cross_entropy_ref), logits, labels), err))

    payload = [
        {"kernel": n, "ref_wall_us": w * 1e6, "max_abs_err_vs_oracle": e}
        for n, w, e in rows
    ]
    save_json("kernels_bench.json", payload)
    worst = max(r[2] for r in rows)
    return {
        "name": "kernels_bench",
        "us_per_call": sum(r[1] for r in rows) / len(rows) * 1e6,
        "derived": f"kernels={len(rows)} worst_err={worst:.2e}",
    }


if __name__ == "__main__":
    print(run())
