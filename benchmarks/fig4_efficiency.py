"""Fig. 4: COMPASS-V savings vs feasible fraction, both workflows.

The paper reports 20.3-84.7% savings (RAG) and 51.1-79.3% (detection), a
convex pattern with a minimum at moderate feasible fractions, 100% recall at
all 16 thresholds, and 57.5% average savings.
"""

from __future__ import annotations

from repro.workflows.surrogate import (
    DetectionSurrogate,
    RagSurrogate,
    paper_detection_thresholds,
    paper_rag_thresholds,
)

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import DET_BUDGET, RAG_BUDGET, Timer, ground_truth, save_json, search


def _all_rows(p):
    return p["rag"] + p["detection"]


# Trajectory measurements (BENCH_fig4_efficiency.json): the efficiency
# study across 16 thresholds x 2 workflows — worst-case recall (claim:
# 100%) and mean evaluation savings (paper: 57.5% mean).
BENCH_SPEC = BenchmarkSpec(
    artifact="fig4_efficiency.json",
    measurements=(
        MeasurementSpec(
            "min_recall", "frac", True,
            extract=lambda p: min(r["recall"] for r in _all_rows(p)),
            target=1.0, tolerance=0.01),
        MeasurementSpec(
            "mean_savings", "frac", True,
            extract=lambda p: (sum(r["savings"] for r in _all_rows(p))
                               / len(_all_rows(p))),
            tolerance=0.15),
    ),
)


def sweep(sur, thresholds, budget):
    rows = []
    for tau in thresholds:
        gt = ground_truth(sur, tau, budget[-1])
        res = search(sur, tau, budget)
        rows.append(
            {
                "tau": tau,
                "feasible_fraction": len(gt.feasible) / sur.space.cardinality,
                "recall": res.recall(list(gt.feasible)),
                "savings": res.savings_vs_exhaustive(sur.space, budget[-1]),
                "config_evals": res.num_evaluations,
                "cardinality": sur.space.cardinality,
            }
        )
    return rows


def run() -> dict:
    with Timer() as t:
        rag = sweep(RagSurrogate(seed=0), paper_rag_thresholds(), RAG_BUDGET)
        det = sweep(
            DetectionSurrogate(seed=0), paper_detection_thresholds(), DET_BUDGET
        )
    payload = {"rag": rag, "detection": det}
    save_json("fig4_efficiency.json", payload)
    allr = rag + det
    recalls = [r["recall"] for r in allr]
    savs = [r["savings"] for r in allr]
    mean_sav = sum(savs) / len(savs)
    return {
        "name": "fig4_efficiency",
        "us_per_call": t.elapsed / len(allr) * 1e6,
        "derived": (
            f"recall_min={min(recalls):.3f} savings_mean={mean_sav * 100:.1f}% "
            f"savings_max={max(savs) * 100:.1f}%"
        ),
    }


if __name__ == "__main__":
    print(run())
