"""Fig. 1: Pareto front in the RAG workflow.

The paper's preliminary study evaluates 72 configurations of the RAG pipeline
and shows that moving along the (accuracy, P95-latency) front trades ~2% F1
for ~1.6x P95 latency.  We reproduce the landscape over the calibrated
surrogate, compute the front, and report the same headline ratio.
"""

from __future__ import annotations

from repro.core.pareto import LatencyProfile, ParetoPoint, pareto_front
from repro.workflows.surrogate import RagSurrogate

from repro.tools.benchhist import BenchmarkSpec, MeasurementSpec

from .common import Timer, make_profiler, save_json

# Trajectory measurements (BENCH_fig1_pareto.json): the paper's headline
# Pareto trade — P95 speedup bought within the 2% accuracy envelope
# (paper: 1.6x / 2%) — plus the front size the search surfaces.
BENCH_SPEC = BenchmarkSpec(
    artifact="fig1_pareto.json",
    measurements=(
        MeasurementSpec("p95_speedup_within_2pct", "x", True,
                        path="headline.p95_speedup_within_2pct",
                        tolerance=0.05),
        MeasurementSpec("accuracy_drop", "frac", False,
                        path="headline.accuracy_drop", tolerance=0.25),
        MeasurementSpec("front_size", "configs", True, path="front_size",
                        tolerance=0.15),
    ),
)
from repro.core.planner import summarize_latencies


def run() -> dict:
    sur = RagSurrogate(seed=0)
    space = sur.space
    # the paper's subset: every other generator/k combination (72 configs)
    subset = [c for i, c in enumerate(space.enumerate()) if i % 5 == 0][:72]
    profiler = make_profiler(sur)

    points = []
    with Timer() as t:
        for c in subset:
            prof = summarize_latencies(profiler(c, 40))
            points.append(
                ParetoPoint(config=c, accuracy=sur.accuracy(c), profile=prof)
            )
    front = pareto_front(points)

    best = max(front, key=lambda p: p.accuracy)
    # the efficient alternative: within 2% accuracy at minimal latency
    candidates = [p for p in front if p.accuracy >= best.accuracy - 0.02]
    efficient = min(candidates, key=lambda p: p.profile.p95)
    speedup = best.profile.p95 / efficient.profile.p95
    drop = best.accuracy - efficient.accuracy

    payload = {
        "num_configs": len(points),
        "front_size": len(front),
        "front": [
            {
                "config": list(p.config),
                "accuracy": p.accuracy,
                "mean_ms": p.profile.mean * 1e3,
                "p95_ms": p.profile.p95 * 1e3,
            }
            for p in front
        ],
        "headline": {
            "p95_speedup_within_2pct": speedup,
            "accuracy_drop": drop,
            "paper_claim": "1.6x P95 reduction for 2% F1 drop",
        },
        "eval_s": t.elapsed,
    }
    save_json("fig1_pareto.json", payload)
    return {
        "name": "fig1_pareto",
        "us_per_call": t.elapsed / len(points) * 1e6,
        "derived": f"front={len(front)}/72 speedup_within_2pct={speedup:.2f}x",
    }


if __name__ == "__main__":
    print(run())
