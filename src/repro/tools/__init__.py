"""Developer tooling that ships with the repro (not part of the paper).

- :mod:`repro.tools.docscheck` — README/docs cross-reference checker: fails
  when documentation names a module, function, file, or CLI flag that no
  longer exists.  Wired into tier-1 via ``tests/test_docs.py`` and runnable
  standalone through ``python -m benchmarks.run --check-docs``.
- :mod:`repro.tools.benchhist` — benchmark-history telemetry: the
  Measurement/BenchRun schema, the append-only ``BENCH_<name>.json``
  trajectory store, and the suite-wide regression detector behind
  ``python -m benchmarks.run --record`` / ``--gate-all`` (see
  docs/performance.md §9).
"""
