"""Docs cross-reference checker: keep README/docs honest about the code.

Documentation rots silently: a refactor renames ``derive_policies`` or moves
a file and every prose mention becomes a lie.  This module extracts
inline-code spans from ``README.md`` and ``docs/*.md`` and verifies each
reference class against the working tree:

- **dotted names** (``repro.core.aqm.derive_mix_policies``): the longest
  importable module prefix is imported and the remainder resolved with
  ``getattr`` — so renamed/removed functions, classes, attributes, and
  modules all fail;
- **repo paths** (``src/repro/core/aqm.py``, ``docs/queueing.md``): must
  exist relative to the repo root;
- **CLI flags** (``--check-docs``): the literal flag string must appear in
  some ``*.py`` under ``benchmarks/``, ``examples/``, or ``src/``;
- **relative markdown links** (``[queueing model](queueing.md)``): the
  target, resolved against the *linking document's* directory, must exist
  (external ``http(s)://``/``mailto:`` targets and same-document
  ``#anchor`` links are skipped; a ``path#anchor`` target is checked for
  the path part).  Broken links between ``docs/*.md`` files used to pass
  silently — inline-code spans only cover backticked references.

Fenced code blocks are skipped (shell snippets legitimately mention
transient names); only inline backtick spans and markdown links are
checked.  Anything that matches none of the reference classes is ignored,
so prose can use backticks for emphasis (``c = 1``, ``N_k(up)``) freely.

Run via ``tests/test_docs.py`` (tier-1) or
``PYTHONPATH=src python -m benchmarks.run --check-docs``.
"""

from __future__ import annotations

import importlib
import re
from pathlib import Path
from typing import Iterable, List, Optional, Tuple

_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)
_INLINE_RE = re.compile(r"`([^`\n]+)`")
_DOTTED_RE = re.compile(r"^repro(\.[A-Za-z_][A-Za-z0-9_]*)+$")
_PATH_RE = re.compile(r"^[\w.\-/]+\.(py|md|ini|txt|json)$")
_FLAG_RE = re.compile(r"^--[a-z][a-z0-9-]*$")
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^()\s]+)\)")
_EXTERNAL_RE = re.compile(r"^[a-z][a-z0-9+.-]*:")   # http:, https:, mailto:, ...


def repo_root() -> Path:
    """The repository root, three levels up from this file
    (src/repro/tools/docscheck.py)."""
    return Path(__file__).resolve().parents[3]


def doc_files(root: Optional[Path] = None) -> List[Path]:
    root = root or repo_root()
    out = []
    readme = root / "README.md"
    if readme.exists():
        out.append(readme)
    docs = root / "docs"
    if docs.is_dir():
        out.extend(sorted(docs.glob("*.md")))
    return out


def extract_references(text: str) -> List[str]:
    """Inline-code spans outside fenced blocks, deduplicated in order."""
    stripped = _FENCE_RE.sub("", text)
    seen = []
    for m in _INLINE_RE.finditer(stripped):
        tok = m.group(1).strip()
        if tok and tok not in seen:
            seen.append(tok)
    return seen


def resolve_dotted(name: str) -> Optional[str]:
    """Resolve ``repro.a.b.attr`` by importing the longest module prefix and
    getattr-ing the rest.  Returns an error string or None when it resolves."""
    parts = name.split(".")
    module = None
    split = len(parts)
    while split > 0:
        try:
            module = importlib.import_module(".".join(parts[:split]))
            break
        except ImportError:
            split -= 1
    if module is None:
        return f"cannot import any prefix of {name!r}"
    obj = module
    for attr in parts[split:]:
        try:
            obj = getattr(obj, attr)
        except AttributeError:
            return (f"{name!r}: {'.'.join(parts[:split])} has no attribute "
                    f"{attr!r}")
    return None


def _flag_exists(flag: str, root: Path) -> bool:
    for sub in ("benchmarks", "examples", "src"):
        base = root / sub
        if not base.is_dir():
            continue
        for py in base.rglob("*.py"):
            try:
                if flag in py.read_text(errors="ignore"):
                    return True
            except OSError:
                continue
    return False


def extract_links(text: str) -> List[str]:
    """Markdown link targets outside fenced blocks, deduplicated in order."""
    stripped = _FENCE_RE.sub("", text)
    seen: List[str] = []
    for m in _LINK_RE.finditer(stripped):
        target = m.group(1).strip()
        if target and target not in seen:
            seen.append(target)
    return seen


def check_links(text: str, *, source: str = "<doc>",
                base_dir: Optional[Path] = None,
                root: Optional[Path] = None) -> List[str]:
    """Validate relative markdown links against the working tree.

    ``base_dir`` is the directory the linking document lives in (relative
    targets resolve against it, matching how GitHub renders them); defaults
    to the repo root.  External schemes and pure-anchor links are skipped.
    """
    root = root or repo_root()
    base = base_dir or root
    problems: List[str] = []
    for target in extract_links(text):
        if _EXTERNAL_RE.match(target) or target.startswith("#"):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (root / path.lstrip("/")) if target.startswith("/") \
            else (base / path)
        if not resolved.exists():
            problems.append(
                f"{source}: broken markdown link `{target}` "
                f"(resolved to {resolved})")
    return problems


def check_text(text: str, *, source: str = "<doc>",
               root: Optional[Path] = None,
               base_dir: Optional[Path] = None) -> List[str]:
    """Check one document's references; returns human-readable problems."""
    root = root or repo_root()
    problems: List[str] = []
    for tok in extract_references(text):
        if _DOTTED_RE.match(tok):
            err = resolve_dotted(tok)
            if err is not None:
                problems.append(f"{source}: stale code reference {err}")
        elif _PATH_RE.match(tok) and "/" in tok:
            rel = tok.lstrip("./")
            if not (root / rel).exists():
                problems.append(f"{source}: path `{tok}` does not exist")
        elif _FLAG_RE.match(tok):
            if not _flag_exists(tok, root):
                problems.append(
                    f"{source}: CLI flag `{tok}` not found in any "
                    "benchmarks/examples/src python file")
    problems.extend(
        check_links(text, source=source, base_dir=base_dir, root=root))
    return problems


def check_docs(root: Optional[Path] = None) -> List[str]:
    """Check README.md and docs/*.md; returns all problems found."""
    root = root or repo_root()
    files = doc_files(root)
    if not files:
        return ["no README.md or docs/*.md found to check"]
    problems: List[str] = []
    for f in files:
        problems.extend(
            check_text(f.read_text(), source=str(f.relative_to(root)),
                       root=root, base_dir=f.parent))
    return problems


def main() -> int:
    problems = check_docs()
    for p in problems:
        print(f"docscheck: {p}")
    if problems:
        print(f"docscheck: {len(problems)} stale reference(s)")
        return 1
    n = len(doc_files())
    print(f"docscheck: OK ({n} documents checked)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
