"""Benchmark-history telemetry: per-PR perf trajectories + regression gating.

Every benchmark in ``benchmarks/run.py``'s registry makes quantitative
claims — batching goodput, fast-path speedup, trace-replay rate, DAG
compliance — but until this module only the fastsim gate
(``--perf-gate``) guarded one of them against one committed baseline.
This module is the structured measurement surface for *all* of them:

- **Schema** — :class:`Measurement` (one named, unit-carrying, direction-
  aware number) and :class:`BenchRun` (one recorded invocation: git SHA,
  timestamp, platform, backend and library versions, plus its
  measurements).  Construction validates strictly; parsing rejects
  malformed or missing fields with actionable messages instead of
  silently skipping records.
- **Trajectory store** — one append-only ``BENCH_<benchmark>.json`` per
  registered benchmark at the repo root, appended by
  ``python -m benchmarks.run --record`` after any full or smoke run
  (:func:`append_run` / :func:`load_trajectory`).  Serialization is
  byte-stable (sorted keys, fixed indent), so serialize → parse →
  serialize round-trips identically and appends produce minimal diffs.
- **Regression detection** — :func:`detect_regressions` generalizes
  ``fastsim_bench.perf_gate``: the newest run's value for each
  measurement is compared against the **median of the most recent
  window** of same-mode predecessors, with a per-measurement tolerance
  and the comparison direction taken from ``higher_is_better``.
  :func:`gate_all` applies it to every trajectory in a directory and is
  wired as ``python -m benchmarks.run --gate-all``.
- **Declaration layer** — benchmark modules declare their gate-worthy
  measurements as a :class:`BenchmarkSpec` of :class:`MeasurementSpec`
  entries (a dotted path into the artifact payload, or an ``extract``
  callable for list-shaped artifacts).  ``benchmarks/run.py --record``
  collects them from the just-written artifact payload *before* volatile
  scrubbing, so throughput measurements survive even where the on-disk
  smoke artifact is scrubbed for byte-idempotence.

:data:`VOLATILE_KEYS` / :func:`scrub_volatile` live here (re-exported by
``benchmarks/common.py``) because both the artifact writer and the
trajectory serializer need the same notion of "wall-clock / host
dependent": a :class:`BenchRun`'s free-form ``context`` block is scrubbed
with the same function the smoke artifacts use.
"""

from __future__ import annotations

import json
import math
import os
import re
import statistics
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

SCHEMA_VERSION = 1

#: Keys whose values depend on the wall clock or the host rather than on a
#: benchmark's seeds: timing fields, throughput derived from timing, timing
#: ratios, and provenance metadata (timestamp + platform/library versions).
#: Smoke artifacts are rewritten by tier-1 subprocess gates on every test
#: run, so anything volatile in them turns every ``pytest`` into a dirty
#: working tree.  Volatile values still belong in the *trajectory* — that
#: is what :class:`BenchRun` records them for — they just may not live in
#: a stable-saved artifact.
VOLATILE_KEYS = frozenset({
    "timestamp_utc",
    "wall_s",
    "rps",
    "sps",
    "us_per_call",
    "metadata",
    # timing-derived ratios and whole-section timing blocks (fastsim_bench)
    "single_speedup",
    "batch_speedup",
    "jax_batch_speedup",
    "jax_speedup",
    "numpy_rps",
    "jax_rps",
    "jax_wall_s",
    "numpy_s",
    "jax_s",
    "gate",
    "large_sweep",
})


def scrub_volatile(payload, volatile: frozenset = VOLATILE_KEYS):
    """Recursively drop wall-clock / host-dependent keys from a payload so
    that reruns with the same seeds serialize byte-identically."""
    if isinstance(payload, dict):
        return {k: scrub_volatile(v, volatile)
                for k, v in payload.items() if k not in volatile}
    if isinstance(payload, (list, tuple)):
        return [scrub_volatile(v, volatile) for v in payload]
    return payload


class BenchHistError(ValueError):
    """A benchmark-history record or trajectory failed validation.

    Raised instead of silently skipping: a malformed committed trajectory
    means a regression could hide in an unreadable record, so parsing is
    strict and every message says which record, which field, and what was
    expected."""


_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*$")
_MODES = ("full", "smoke")

# ISO-8601 UTC, second resolution — the only timestamp format recorded, so
# trajectories sort lexicographically by time.
_TIMESTAMP_RE = re.compile(r"^\d{4}-\d{2}-\d{2}T\d{2}:\d{2}:\d{2}\+00:00$")


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise BenchHistError(msg)


def _as_float(value: Any, what: str) -> float:
    # bool is an int subclass; a compliance flag recorded as True/False is
    # a legitimate 0/1 measurement, so coerce instead of rejecting.
    if isinstance(value, bool):
        return float(value)
    _require(isinstance(value, (int, float)),
             f"{what}: expected a number, got {type(value).__name__} "
             f"({value!r})")
    value = float(value)
    _require(math.isfinite(value), f"{what}: value must be finite, got {value!r}")
    return value


@dataclass(frozen=True)
class Measurement:
    """One named, direction-aware number from one benchmark run.

    ``higher_is_better`` orients the regression detector (throughput up =
    good, latency up = bad); ``target`` records an acceptance bar from the
    benchmark's own criteria (informational — the gate compares against
    history, not targets); ``tolerance`` overrides the gate's default
    relative tolerance for this measurement (e.g. a noisy wall-clock
    throughput tolerates 30%, a deterministic compliance fraction 1%)."""

    name: str
    value: float
    unit: str
    higher_is_better: bool
    target: Optional[float] = None
    tolerance: Optional[float] = None

    def __post_init__(self) -> None:
        _require(isinstance(self.name, str) and _NAME_RE.match(self.name or ""),
                 f"Measurement.name must match {_NAME_RE.pattern!r}, "
                 f"got {self.name!r}")
        object.__setattr__(self, "value",
                           _as_float(self.value, f"Measurement {self.name!r}"))
        _require(isinstance(self.unit, str) and bool(self.unit),
                 f"Measurement {self.name!r}: unit must be a non-empty "
                 f"string, got {self.unit!r}")
        _require(isinstance(self.higher_is_better, bool),
                 f"Measurement {self.name!r}: higher_is_better must be a "
                 f"bool, got {self.higher_is_better!r}")
        if self.target is not None:
            object.__setattr__(
                self, "target",
                _as_float(self.target, f"Measurement {self.name!r} target"))
        if self.tolerance is not None:
            tol = _as_float(self.tolerance,
                            f"Measurement {self.name!r} tolerance")
            _require(0.0 < tol <= 1.0,
                     f"Measurement {self.name!r}: tolerance must be in "
                     f"(0, 1], got {tol!r}")
            object.__setattr__(self, "tolerance", tol)

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "name": self.name,
            "value": self.value,
            "unit": self.unit,
            "higher_is_better": self.higher_is_better,
        }
        if self.target is not None:
            out["target"] = self.target
        if self.tolerance is not None:
            out["tolerance"] = self.tolerance
        return out

    @classmethod
    def from_dict(cls, d: Any, *, where: str = "measurement") -> "Measurement":
        _require(isinstance(d, dict),
                 f"{where}: expected an object, got {type(d).__name__}")
        required = {"name", "value", "unit", "higher_is_better"}
        missing = required - d.keys()
        _require(not missing,
                 f"{where}: missing required field(s) {sorted(missing)} "
                 f"(record: {d!r})")
        unknown = d.keys() - required - {"target", "tolerance"}
        _require(not unknown,
                 f"{where}: unknown field(s) {sorted(unknown)} — schema "
                 f"version {SCHEMA_VERSION} does not define them")
        return cls(name=d["name"], value=d["value"], unit=d["unit"],
                   higher_is_better=d["higher_is_better"],
                   target=d.get("target"), tolerance=d.get("tolerance"))


_RUN_REQUIRED = ("benchmark", "mode", "git_sha", "timestamp_utc", "platform",
                 "python", "numpy", "backend", "measurements")
_RUN_OPTIONAL = ("jax", "context")


@dataclass(frozen=True)
class BenchRun:
    """One recorded benchmark invocation: provenance + measurements."""

    benchmark: str
    mode: str
    git_sha: str
    timestamp_utc: str
    platform: str
    python: str
    numpy: str
    backend: str
    measurements: Tuple[Measurement, ...]
    jax: Optional[str] = None
    context: Optional[Dict[str, Any]] = None

    def __post_init__(self) -> None:
        _require(isinstance(self.benchmark, str)
                 and _NAME_RE.match(self.benchmark or ""),
                 f"BenchRun.benchmark must match {_NAME_RE.pattern!r}, "
                 f"got {self.benchmark!r}")
        _require(self.mode in _MODES,
                 f"BenchRun.mode must be one of {_MODES}, got {self.mode!r}")
        for fname in ("git_sha", "platform", "python", "numpy", "backend"):
            v = getattr(self, fname)
            _require(isinstance(v, str) and bool(v),
                     f"BenchRun.{fname} must be a non-empty string, "
                     f"got {v!r}")
        _require(isinstance(self.timestamp_utc, str)
                 and bool(_TIMESTAMP_RE.match(self.timestamp_utc or "")),
                 f"BenchRun.timestamp_utc must be ISO-8601 UTC at second "
                 f"resolution (YYYY-MM-DDTHH:MM:SS+00:00), "
                 f"got {self.timestamp_utc!r}")
        _require(self.jax is None or (isinstance(self.jax, str) and self.jax),
                 f"BenchRun.jax must be None or a non-empty version string, "
                 f"got {self.jax!r}")
        ms = tuple(self.measurements)
        _require(len(ms) > 0,
                 f"BenchRun {self.benchmark!r}: measurements must be "
                 f"non-empty — a run with nothing measured gates nothing")
        for m in ms:
            _require(isinstance(m, Measurement),
                     f"BenchRun {self.benchmark!r}: measurements must be "
                     f"Measurement instances, got {type(m).__name__}")
        names = [m.name for m in ms]
        dupes = sorted({n for n in names if names.count(n) > 1})
        _require(not dupes,
                 f"BenchRun {self.benchmark!r}: duplicate measurement "
                 f"name(s) {dupes}")
        object.__setattr__(self, "measurements", ms)
        if self.context is not None:
            _require(isinstance(self.context, dict),
                     f"BenchRun {self.benchmark!r}: context must be a dict, "
                     f"got {type(self.context).__name__}")
            # the context block is free-form provenance; scrub it with the
            # same volatile-key filter the stable artifacts use so committed
            # trajectories never grow nested wall-clock junk
            object.__setattr__(self, "context", scrub_volatile(self.context))

    def measurement(self, name: str) -> Optional[Measurement]:
        for m in self.measurements:
            if m.name == name:
                return m
        return None

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "benchmark": self.benchmark,
            "mode": self.mode,
            "git_sha": self.git_sha,
            "timestamp_utc": self.timestamp_utc,
            "platform": self.platform,
            "python": self.python,
            "numpy": self.numpy,
            "jax": self.jax,
            "backend": self.backend,
            "measurements": [m.to_dict() for m in self.measurements],
        }
        if self.context is not None:
            out["context"] = self.context
        return out

    @classmethod
    def from_dict(cls, d: Any, *, where: str = "run") -> "BenchRun":
        _require(isinstance(d, dict),
                 f"{where}: expected an object, got {type(d).__name__}")
        missing = set(_RUN_REQUIRED) - d.keys() - {"jax"}
        _require(not missing,
                 f"{where}: missing required field(s) {sorted(missing)}")
        unknown = d.keys() - set(_RUN_REQUIRED) - set(_RUN_OPTIONAL)
        _require(not unknown,
                 f"{where}: unknown field(s) {sorted(unknown)} — schema "
                 f"version {SCHEMA_VERSION} does not define them")
        raw_ms = d["measurements"]
        _require(isinstance(raw_ms, list),
                 f"{where}: measurements must be a list, "
                 f"got {type(raw_ms).__name__}")
        ms = tuple(
            Measurement.from_dict(m, where=f"{where}.measurements[{i}]")
            for i, m in enumerate(raw_ms))
        return cls(benchmark=d["benchmark"], mode=d["mode"],
                   git_sha=d["git_sha"], timestamp_utc=d["timestamp_utc"],
                   platform=d["platform"], python=d["python"],
                   numpy=d["numpy"], jax=d.get("jax"), backend=d["backend"],
                   measurements=ms, context=d.get("context"))


# ---------------------------------------------------------------------------
# stable serialization + the append-only trajectory store


def dumps_run(run: BenchRun) -> str:
    """Byte-stable serialization of one run (sorted keys, fixed indent):
    serialize → :func:`loads_run` → serialize is byte-identical."""
    return json.dumps(run.to_dict(), sort_keys=True, indent=1)


def loads_run(text: str) -> BenchRun:
    try:
        d = json.loads(text)
    except json.JSONDecodeError as e:
        raise BenchHistError(f"run record is not valid JSON: {e}") from e
    return BenchRun.from_dict(d)


def trajectory_path(bench_dir: os.PathLike, benchmark: str) -> Path:
    return Path(bench_dir) / f"BENCH_{benchmark}.json"


def dumps_trajectory(benchmark: str, runs: Sequence[BenchRun]) -> str:
    payload = {
        "schema_version": SCHEMA_VERSION,
        "benchmark": benchmark,
        "runs": [r.to_dict() for r in runs],
    }
    return json.dumps(payload, sort_keys=True, indent=1) + "\n"


def load_trajectory(path: os.PathLike) -> List[BenchRun]:
    """Parse a ``BENCH_<benchmark>.json`` trajectory, strictly.

    Any malformed record raises :class:`BenchHistError` naming the file
    and record index — a trajectory that silently drops records would let
    regressions hide behind parse errors."""
    path = Path(path)
    try:
        d = json.loads(path.read_text())
    except FileNotFoundError:
        raise BenchHistError(
            f"{path}: no such trajectory (record one with "
            f"`python -m benchmarks.run --record`)") from None
    except json.JSONDecodeError as e:
        raise BenchHistError(f"{path}: not valid JSON: {e}") from e
    _require(isinstance(d, dict),
             f"{path}: expected a trajectory object, "
             f"got {type(d).__name__}")
    missing = {"schema_version", "benchmark", "runs"} - d.keys()
    _require(not missing, f"{path}: missing field(s) {sorted(missing)}")
    _require(d["schema_version"] == SCHEMA_VERSION,
             f"{path}: schema_version {d['schema_version']!r} != "
             f"{SCHEMA_VERSION} (this tool only reads version "
             f"{SCHEMA_VERSION})")
    _require(isinstance(d["runs"], list),
             f"{path}: runs must be a list, got {type(d['runs']).__name__}")
    runs = [BenchRun.from_dict(r, where=f"{path}: runs[{i}]")
            for i, r in enumerate(d["runs"])]
    for i, r in enumerate(runs):
        _require(r.benchmark == d["benchmark"],
                 f"{path}: runs[{i}] records benchmark {r.benchmark!r} but "
                 f"the trajectory is for {d['benchmark']!r}")
    return runs


def append_run(bench_dir: os.PathLike, run: BenchRun) -> Path:
    """Append one run to its benchmark's trajectory file (creating the
    file on first record) and rewrite it byte-stably."""
    path = trajectory_path(bench_dir, run.benchmark)
    runs = load_trajectory(path) if path.exists() else []
    runs.append(run)
    path.write_text(dumps_trajectory(run.benchmark, runs))
    return path


# ---------------------------------------------------------------------------
# environment provenance


def collect_environment() -> Dict[str, Any]:
    """Provenance shared by every recorded run: git SHA, timestamp,
    platform, library versions, and which sweep backends are importable."""
    import datetime
    import platform as _platform
    import subprocess

    import numpy as np

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    env: Dict[str, Any] = {
        "git_sha": sha,
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "platform": _platform.platform(),
        "python": _platform.python_version(),
        "numpy": np.__version__,
        "jax": None,
        "backend": "numpy",
    }
    try:
        from repro.serving import fastsim

        if fastsim.jax_available():
            import jax

            env["jax"] = jax.__version__
            env["backend"] = "numpy,jax"
    except ImportError:  # pragma: no cover - fastsim always importable here
        pass
    return env


def build_run(benchmark: str, mode: str,
              measurements: Sequence[Measurement],
              *, env: Optional[Dict[str, Any]] = None,
              context: Optional[Dict[str, Any]] = None) -> BenchRun:
    env = env or collect_environment()
    return BenchRun(
        benchmark=benchmark, mode=mode, git_sha=env["git_sha"],
        timestamp_utc=env["timestamp_utc"], platform=env["platform"],
        python=env["python"], numpy=env["numpy"], jax=env["jax"],
        backend=env["backend"], measurements=tuple(measurements),
        context=context)


# ---------------------------------------------------------------------------
# measurement declaration layer (what each benchmark module exports)


def resolve_path(payload: Any, path: str):
    """Resolve a dotted path into a JSON payload; integer segments index
    lists.  Raises :class:`BenchHistError` naming the missing segment."""
    cur = payload
    for seg in path.split("."):
        if isinstance(cur, list):
            try:
                cur = cur[int(seg)]
            except (ValueError, IndexError):
                raise BenchHistError(
                    f"path {path!r}: segment {seg!r} does not index a "
                    f"list of length {len(cur)}") from None
        elif isinstance(cur, dict):
            if seg not in cur:
                raise BenchHistError(
                    f"path {path!r}: key {seg!r} not in "
                    f"{sorted(cur.keys())[:12]}")
            cur = cur[seg]
        else:
            raise BenchHistError(
                f"path {path!r}: segment {seg!r} reached a leaf "
                f"({type(cur).__name__})")
    return cur


@dataclass(frozen=True)
class MeasurementSpec:
    """A benchmark module's declaration of one gate-worthy measurement.

    Exactly one of ``path`` (dotted path into the artifact payload) or
    ``extract`` (callable over the payload, for list-shaped artifacts)
    supplies the value.  ``volatile`` marks values derived from the wall
    clock: they are recorded into trajectories (from the pre-scrub
    payload) but are absent from stable-scrubbed smoke artifacts on disk.
    ``smoke=False`` marks full-run-only sections (e.g. fastsim's deep
    large-sweep cell); ``optional=True`` tolerates absence (e.g. jax gate
    keys on a jax-less install)."""

    name: str
    unit: str
    higher_is_better: bool
    path: Optional[str] = None
    extract: Optional[Callable[[Any], float]] = None
    target: Optional[float] = None
    tolerance: Optional[float] = None
    volatile: bool = False
    smoke: bool = True
    optional: bool = False

    def __post_init__(self) -> None:
        _require((self.path is None) != (self.extract is None),
                 f"MeasurementSpec {self.name!r}: exactly one of path= or "
                 f"extract= must be given")

    def measure(self, payload: Any) -> Optional[Measurement]:
        """Extract this measurement from an artifact payload; ``None`` if
        the spec is optional and the payload lacks it."""
        try:
            if self.path is not None:
                value = resolve_path(payload, self.path)
            else:
                value = self.extract(payload)
        except (BenchHistError, KeyError, IndexError, TypeError,
                StopIteration, ZeroDivisionError) as e:
            # extract= callables poke into list-shaped payloads with
            # next()/indexing; any of these means "the artifact no longer
            # carries this measurement's source"
            if self.optional:
                return None
            raise BenchHistError(
                f"measurement {self.name!r}: artifact payload is missing "
                f"its source (path={self.path!r}, cause: "
                f"{type(e).__name__}: {e}) — did the benchmark's artifact "
                f"schema change without updating its BENCH_SPEC?"
            ) from None
        return Measurement(name=self.name, value=value, unit=self.unit,
                           higher_is_better=self.higher_is_better,
                           target=self.target, tolerance=self.tolerance)


@dataclass(frozen=True)
class BenchmarkSpec:
    """Everything ``--record`` needs from one benchmark module: which
    artifact its run writes (full and smoke variants) and the gate-worthy
    measurements to extract from it."""

    artifact: str
    measurements: Tuple[MeasurementSpec, ...]
    smoke_artifact: Optional[str] = None

    def __post_init__(self) -> None:
        ms = tuple(self.measurements)
        _require(len(ms) > 0,
                 "BenchmarkSpec: at least one MeasurementSpec is required")
        names = [m.name for m in ms]
        dupes = sorted({n for n in names if names.count(n) > 1})
        _require(not dupes, f"BenchmarkSpec: duplicate spec name(s) {dupes}")
        object.__setattr__(self, "measurements", ms)
        if self.smoke_artifact is None:
            object.__setattr__(self, "smoke_artifact", self.artifact)

    def artifact_for(self, mode: str) -> str:
        return self.smoke_artifact if mode == "smoke" else self.artifact

    def specs_for(self, mode: str, *,
                  include_volatile: bool = True) -> List[MeasurementSpec]:
        return [s for s in self.measurements
                if (mode != "smoke" or s.smoke)
                and (include_volatile or not s.volatile)]

    def collect(self, payload: Any, mode: str, *,
                include_volatile: bool = True) -> List[Measurement]:
        out = []
        for spec in self.specs_for(mode, include_volatile=include_volatile):
            m = spec.measure(payload)
            if m is not None:
                out.append(m)
        return out


# ---------------------------------------------------------------------------
# regression detection


DEFAULT_WINDOW = 5
DEFAULT_TOLERANCE = 0.30   # matches the historical fastsim --perf-gate bar


@dataclass(frozen=True)
class Violation:
    benchmark: str
    measurement: str
    unit: str
    current: float
    median: float
    window: int
    tolerance: float
    higher_is_better: bool

    def describe(self) -> str:
        direction = "fell below" if self.higher_is_better else "rose above"
        return (f"{self.benchmark}.{self.measurement}: {self.current:g} "
                f"{self.unit} {direction} the median of the last "
                f"{self.window} run(s) ({self.median:g} {self.unit}) by "
                f"more than {self.tolerance:.0%}")


def detect_regressions(runs: Sequence[BenchRun], *,
                       window: int = DEFAULT_WINDOW,
                       default_tolerance: float = DEFAULT_TOLERANCE,
                       ) -> List[Violation]:
    """Compare the newest run against the median of its recent same-mode
    history, per measurement, direction-aware.

    The current run is ``runs[-1]``; its history is the up-to-``window``
    most recent *earlier* runs with the same mode (smoke and full runs
    measure different sweep sizes, so they never gate each other).  A
    measurement with no history passes (first recording of a new metric),
    as does a measurement moving in its good direction.  Entries older
    than the window never affect the verdict — appends shift the window
    forward instead of freezing a baseline forever, which is what lets
    trajectories absorb intentional perf changes after a few recorded
    runs."""
    _require(window >= 1, f"window must be >= 1, got {window}")
    _require(0.0 < default_tolerance <= 1.0,
             f"default_tolerance must be in (0, 1], "
             f"got {default_tolerance!r}")
    if len(runs) < 2:
        return []
    current = runs[-1]
    history = [r for r in runs[:-1] if r.mode == current.mode][-window:]
    if not history:
        return []
    violations: List[Violation] = []
    for m in current.measurements:
        past = [h.measurement(m.name).value for h in history
                if h.measurement(m.name) is not None]
        if not past:
            continue
        med = statistics.median(past)
        tol = m.tolerance if m.tolerance is not None else default_tolerance
        shortfall = (med - m.value) if m.higher_is_better else (m.value - med)
        if shortfall > tol * abs(med) + 1e-12:
            violations.append(Violation(
                benchmark=current.benchmark, measurement=m.name,
                unit=m.unit, current=m.value, median=med,
                window=len(past), tolerance=tol,
                higher_is_better=m.higher_is_better))
    return violations


def discover_trajectories(bench_dir: os.PathLike) -> List[Path]:
    return sorted(Path(bench_dir).glob("BENCH_*.json"))


def gate_all(bench_dir: os.PathLike, *,
             window: int = DEFAULT_WINDOW,
             default_tolerance: float = DEFAULT_TOLERANCE,
             log: Callable[[str], None] = print) -> int:
    """The suite-wide regression gate behind ``--gate-all``.

    Loads every ``BENCH_*.json`` under ``bench_dir``, runs
    :func:`detect_regressions` on each, and returns a process exit code:
    0 when every trajectory parses and no measurement regressed, 1
    otherwise — listing *every* violated measurement, not just the first,
    so one gate run names the full blast radius of a bad change."""
    paths = discover_trajectories(bench_dir)
    if not paths:
        log(f"gate-all: no BENCH_*.json trajectories under {bench_dir} "
            f"(record some with `python -m benchmarks.run --record`)")
        return 1
    failed = False
    total_measurements = 0
    for path in paths:
        try:
            runs = load_trajectory(path)
        except BenchHistError as e:
            log(f"gate-all: MALFORMED {e}")
            failed = True
            continue
        if not runs:
            log(f"gate-all: {path.name}: EMPTY trajectory (no recorded runs)")
            failed = True
            continue
        violations = detect_regressions(
            runs, window=window, default_tolerance=default_tolerance)
        total_measurements += len(runs[-1].measurements)
        if violations:
            failed = True
            for v in violations:
                log(f"gate-all: REGRESSION {v.describe()}")
        else:
            log(f"gate-all: {runs[-1].benchmark}: OK "
                f"({len(runs[-1].measurements)} measurement(s), "
                f"{len(runs)} run(s), mode={runs[-1].mode})")
    if failed:
        log("gate-all: FAILED")
        return 1
    log(f"gate-all: OK ({len(paths)} trajectories, "
        f"{total_measurements} gated measurements)")
    return 0


# ---------------------------------------------------------------------------
# trend report


def render_trends(bench_dir: os.PathLike, *,
                  window: int = DEFAULT_WINDOW,
                  max_points: int = 8) -> List[str]:
    """Markdown trend tables for every trajectory under ``bench_dir`` —
    the per-measurement view ``benchmarks/render_report.py`` embeds in
    EXPERIMENTS.md.  Shows the latest value, the same-mode median the gate
    would compare against, and the last few recorded points oldest-first."""
    lines: List[str] = []
    w = lines.append
    for path in discover_trajectories(bench_dir):
        runs = load_trajectory(path)
        if not runs:
            continue
        latest = runs[-1]
        history = [r for r in runs[:-1] if r.mode == latest.mode][-window:]
        w(f"### `{path.name}` — {len(runs)} run(s), latest "
          f"{latest.timestamp_utc} @ `{latest.git_sha[:12]}` "
          f"({latest.mode}, {latest.backend})\n")
        w("| measurement | unit | dir | latest | gate median | trajectory |")
        w("|---|---|---|---|---|---|")
        for m in latest.measurements:
            past = [r.measurement(m.name).value for r in history
                    if r.measurement(m.name) is not None]
            med = f"{statistics.median(past):g}" if past else "—"
            series = [r.measurement(m.name).value
                      for r in runs if r.mode == latest.mode
                      and r.measurement(m.name) is not None][-max_points:]
            traj = " → ".join(f"{v:g}" for v in series)
            arrow = "↑" if m.higher_is_better else "↓"
            w(f"| {m.name} | {m.unit} | {arrow} | {m.value:g} | {med} "
              f"| {traj} |")
        w("")
    return lines
