"""Pallas TPU kernel: vocab-chunked cross-entropy (online logsumexp).

The §Perf pair-B hot spot: CE over a 256k vocab materializes (T, V) fp32
intermediates if computed naively.  This kernel streams the vocab dimension
through VMEM in blocks, maintaining the flash-attention-style online
(max, sum-exp) pair plus the gold logit picked up in whichever block holds
the label — the full (T, V) fp32 tensor never exists.

Grid: (T / block_t, V / block_v), vocab innermost so the running stats for a
token block live in VMEM scratch across the vocab sweep.  Block shapes are
MXU/VPU aligned (multiples of 128 on the vocab axis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ce_kernel(labels_ref, logits_ref, out_ref, m_ref, s_ref, g_ref, *,
               block_v: int, num_v: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        s_ref[...] = jnp.zeros_like(s_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    chunk = logits_ref[...].astype(jnp.float32)          # (block_t, block_v)
    labels = labels_ref[...]                             # (block_t,)

    # online max / sum-exp update
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(chunk, axis=-1))
    s_ref[...] = s_ref[...] * jnp.exp(m_prev - m_new) + jnp.sum(
        jnp.exp(chunk - m_new[:, None]), axis=-1
    )
    m_ref[...] = m_new

    # gold logit: the label falls in exactly one vocab block
    offset = vi * block_v
    local = labels - offset                              # (block_t,)
    in_block = (local >= 0) & (local < block_v)
    cols = jnp.arange(block_v)[None, :]
    hit = cols == jnp.clip(local, 0, block_v - 1)[:, None]
    gold_here = jnp.sum(jnp.where(hit, chunk, 0.0), axis=-1)
    g_ref[...] = g_ref[...] + jnp.where(in_block, gold_here, 0.0)

    @pl.when(vi == num_v - 1)
    def _finish():
        out_ref[...] = m_ref[...] + jnp.log(s_ref[...]) - g_ref[...]


@functools.partial(jax.jit, static_argnames=("block_t", "block_v", "interpret"))
def cross_entropy_pallas(
    logits: jax.Array,      # (T, V)
    labels: jax.Array,      # (T,) int32
    *,
    block_t: int = 256,
    block_v: int = 2048,
    interpret: bool = True,
) -> jax.Array:
    t, v = logits.shape
    block_t = min(block_t, t)
    block_v = min(block_v, v)
    if t % block_t or v % block_v:
        raise ValueError(f"({t},{v}) not divisible by blocks ({block_t},{block_v})")
    num_v = v // block_v
    grid = (t // block_t, num_v)

    return pl.pallas_call(
        functools.partial(_ce_kernel, block_v=block_v, num_v=num_v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
            pl.BlockSpec((block_t, block_v), lambda ti, vi: (ti, vi)),
        ],
        out_specs=pl.BlockSpec((block_t,), lambda ti, vi: (ti,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_t,), jnp.float32),   # running max
            pltpu.VMEM((block_t,), jnp.float32),   # running sum-exp
            pltpu.VMEM((block_t,), jnp.float32),   # gold logit
        ],
        interpret=interpret,
    )(labels, logits)
