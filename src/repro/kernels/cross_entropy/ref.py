"""Pure-jnp oracle for the chunked cross-entropy kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy_ref(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-token CE loss.  logits: (T, V); labels: (T,) -> (T,) float32."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[:, None], axis=-1)[:, 0]
    return lse - gold
