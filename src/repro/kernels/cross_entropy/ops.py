"""Jitted public wrapper for the chunked cross-entropy kernel.

On CPU (this container) the kernel executes in interpret mode — the kernel
body runs as Python/jnp per grid step, proving correctness of the exact TPU
program.  On a TPU backend the same call compiles to Mosaic.
"""

from __future__ import annotations

from typing import Optional

import jax

from .kernel import cross_entropy_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def cross_entropy(
    logits: jax.Array,
    labels: jax.Array,
    *,
    block_t: int = 256,
    block_v: int = 2048,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Per-token CE loss without materializing (T, V) fp32.  (T, V) x (T,)
    -> (T,) float32."""
    interp = _on_cpu() if interpret is None else interpret
    return cross_entropy_pallas(
        logits, labels, block_t=block_t, block_v=block_v, interpret=interp
    )
