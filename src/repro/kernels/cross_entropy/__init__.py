from .ops import cross_entropy
from .ref import cross_entropy_ref

__all__ = ["cross_entropy", "cross_entropy_ref"]
