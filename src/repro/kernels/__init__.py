"""Pallas TPU kernels for the serving/training hot spots.

Each kernel package: ``kernel.py`` (pl.pallas_call + explicit BlockSpec VMEM
tiling), ``ops.py`` (jitted wrapper; interpret mode on CPU), ``ref.py``
(pure-jnp oracle used by the allclose test sweeps).

- flash_attention: blockwise online-softmax attention (prefill/train)
- decode_attention: flash-decode GQA single-token attention over KV cache
- ssm_scan: fused Mamba-style selective-scan recurrence
- rmsnorm: fused normalization
- lindley_scan: blocked max-plus Lindley recursion (fastsim's c = 1 sweep)
"""

from .decode_attention import decode_attention, decode_attention_ref
from .flash_attention import attention_ref, flash_attention
from .lindley_scan import lindley_scan, lindley_scan_ref, maxplus_combine
from .rmsnorm import rmsnorm, rmsnorm_ref
from .ssm_scan import ssm_scan, ssm_scan_ref

__all__ = [
    "decode_attention",
    "decode_attention_ref",
    "attention_ref",
    "flash_attention",
    "lindley_scan",
    "lindley_scan_ref",
    "maxplus_combine",
    "rmsnorm",
    "rmsnorm_ref",
    "ssm_scan",
    "ssm_scan_ref",
]
