"""Jitted wrapper for the fused selective scan (interpret on CPU)."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from .kernel import ssm_scan as _kernel
from .ref import ssm_scan_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("block_d", "time_chunk", "interpret"))
def ssm_scan(
    decay: jax.Array,
    drive: jax.Array,
    c: jax.Array,
    *,
    block_d: int = 128,
    time_chunk: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interp = _on_cpu() if interpret is None else interpret
    return _kernel(decay, drive, c, block_d=block_d, time_chunk=time_chunk,
                   interpret=interp)


__all__ = ["ssm_scan", "ssm_scan_ref"]
