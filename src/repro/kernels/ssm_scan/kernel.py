"""Fused selective-scan (Mamba-style) recurrence as a Pallas TPU kernel.

Recurrence per channel block: ``h_t = decay_t * h_{t-1} + drive_t`` with
readout ``y_t = C_t . h_t`` — the memory-bound inner loop of the SSM/hybrid
architectures.  The hardware adaptation (vs. the CUDA kernel of the Mamba
paper, which parallelizes across SMs with warp shuffles): TPU cores iterate
the grid's last dimension *sequentially*, so the state lives in VMEM scratch
and is carried across time-chunks without ever round-tripping to HBM —
the same SRAM-residency insight, realized through the Pallas grid contract
instead of persistent CUDA blocks.

Grid: ``(batch, d_inner_blocks, time_chunks)``; VMEM per step:
decay/drive chunks (tc, bd, N) fp32 + state (bd, N).  With tc=128, bd=128,
N=16: ~2.1 MB.  The time loop inside a chunk is a ``fori_loop`` over VMEM
tiles (no HBM traffic), so HBM sees exactly one read of decay/drive/C and
one write of y — the roofline floor for this op.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(decay_ref, drive_ref, c_ref, y_ref, h_scratch, *, time_chunk: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    decay = decay_ref[0].astype(jnp.float32)       # (tc, bd, N)
    drive = drive_ref[0].astype(jnp.float32)
    c = c_ref[0].astype(jnp.float32)               # (tc, N)

    def step(t, carry):
        h, ys = carry
        h = decay[t] * h + drive[t]                # (bd, N)
        y_t = jnp.sum(h * c[t][None, :], axis=-1)  # (bd,)
        ys = jax.lax.dynamic_update_index_in_dim(ys, y_t, t, axis=0)
        return h, ys

    h0 = h_scratch[...]
    ys0 = jnp.zeros((time_chunk, decay.shape[1]), jnp.float32)
    h_final, ys = jax.lax.fori_loop(0, time_chunk, step, (h0, ys0))
    h_scratch[...] = h_final
    y_ref[0, :, :] = ys.astype(y_ref.dtype)


def ssm_scan(
    decay: jax.Array,     # (B, T, d_inner, N)
    drive: jax.Array,     # (B, T, d_inner, N)
    c: jax.Array,         # (B, T, N)
    *,
    block_d: int = 128,
    time_chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Returns y: (B, T, d_inner) = sum_n (scan(decay, drive))_n * C_n."""
    b, t, di, n = decay.shape
    block_d = min(block_d, di)
    time_chunk = min(time_chunk, t)
    if di % block_d or t % time_chunk:
        raise ValueError(f"dims ({di},{t}) must divide blocks ({block_d},{time_chunk})")
    nd, nt = di // block_d, t // time_chunk

    kernel = functools.partial(_ssm_kernel, time_chunk=time_chunk)
    # layout: move time innermost-block-friendly — keep (B, T, di, N) and
    # slice (1, tc, bd, N) blocks
    out = pl.pallas_call(
        kernel,
        grid=(b, nd, nt),
        in_specs=[
            pl.BlockSpec((1, time_chunk, block_d, n), lambda b_, idd, it: (b_, it, idd, 0)),
            pl.BlockSpec((1, time_chunk, block_d, n), lambda b_, idd, it: (b_, it, idd, 0)),
            pl.BlockSpec((1, time_chunk, n), lambda b_, idd, it: (b_, it, 0)),
        ],
        out_specs=pl.BlockSpec((1, time_chunk, block_d), lambda b_, idd, it: (b_, it, idd)),
        out_shape=jax.ShapeDtypeStruct((b, t, di), decay.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(decay, drive, c)
    return out
