from .ops import ssm_scan
from .ref import ssm_scan_ref

__all__ = ["ssm_scan", "ssm_scan_ref"]
