"""Pure-jnp oracle for the selective-scan kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(decay: jax.Array, drive: jax.Array, c: jax.Array) -> jax.Array:
    """decay/drive: (B, T, di, N); c: (B, T, N) -> y: (B, T, di)."""
    def step(h, inputs):
        dec_t, drv_t, c_t = inputs
        h = dec_t * h + drv_t
        return h, jnp.einsum("bdn,bn->bd", h, c_t)

    b, t, di, n = decay.shape
    h0 = jnp.zeros((b, di, n), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0,
        (decay.astype(jnp.float32).swapaxes(0, 1),
         drive.astype(jnp.float32).swapaxes(0, 1),
         c.astype(jnp.float32).swapaxes(0, 1)),
    )
    return ys.swapaxes(0, 1).astype(decay.dtype)
