"""Jitted public wrapper for flash attention.

On CPU (this container) the kernel executes in interpret mode — the kernel
body runs as Python/jnp per grid step, proving correctness of the exact TPU
program.  On a TPU backend the same call compiles to Mosaic.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from .kernel import flash_attention as _kernel
from .ref import attention_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Blockwise attention.  q: (B, H, S, hd); k, v: (B, KV, S, hd)."""
    interp = _on_cpu() if interpret is None else interpret
    return _kernel(
        q, k, v,
        causal=causal, window=window,
        block_q=block_q, block_k=block_k,
        interpret=interp,
    )


__all__ = ["flash_attention", "attention_ref"]
