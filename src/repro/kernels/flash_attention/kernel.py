"""Flash attention (prefill/train) as a Pallas TPU kernel.

Blockwise online-softmax attention (Dao et al., adapted to TPU): the grid is
``(batch, q_heads, num_q_blocks, num_kv_blocks)`` with the LAST dimension
iterated sequentially per TPU core semantics, so the (m, l, acc) running
statistics live in VMEM scratch and are carried across kv blocks.  GQA is
handled in the BlockSpec index maps: the kv-head block index is
``q_head * num_kv_heads // num_q_heads`` — keys/values are never expanded to
the full head count in HBM.

VMEM working set per step:  q (bq, hd) + k,v (bk, hd) + acc (bq, hd) +
scores (bq, bk), all fp32 in scratch — with the default bq=bk=512, hd<=256
this stays well under the ~16 MB v5e VMEM budget, and every matmul feeds the
MXU with 128-aligned tiles.

Supports causal masking and an optional sliding window (the sub-quadratic
long-context variant: kv blocks fully outside the window are masked; the
wrapper skips lowering them entirely when static bounds allow).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_scratch, l_scratch, acc_scratch,
    *,
    scale: float,
    block_q: int,
    block_k: int,
    seq_len: int,
    causal: bool,
    window: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0, 0].astype(jnp.float32)                 # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                           # (bq, bk)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    mask = k_pos < seq_len
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_scratch[...]                             # (bq, 1)
    l_prev = l_scratch[...]
    m_cur = jnp.max(scores, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                         # (bq, bk)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)

    acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scratch[...] = m_new
    l_scratch[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scratch[...]
        l = jnp.where(l == 0.0, 1.0, l)                 # fully-masked rows
        o_ref[0, 0, :, :] = (acc_scratch[...] / l).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, H, S, hd); k, v: (B, KV, S, hd) -> (B, H, S, hd)."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    if h % kv:
        raise ValueError(f"q heads {h} not a multiple of kv heads {kv}")
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q or s % block_k:
        raise ValueError(f"seq {s} must divide block sizes {block_q}/{block_k}")
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    nq, nk = s // block_q, s // block_k

    grid = (b, h, nq, nk)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        seq_len=s,
        causal=causal,
        window=window,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h_, iq, ik, _kv=kv, _h=h: (b_, (h_ * _kv) // _h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, h_, iq, ik, _kv=kv, _h=h: (b_, (h_ * _kv) // _h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),      # m: running max
            pltpu.VMEM((block_q, 1), jnp.float32),      # l: running sum
            pltpu.VMEM((block_q, hd), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(q, k, v)
