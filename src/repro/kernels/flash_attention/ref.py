"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: Optional[float] = None,
) -> jax.Array:
    """q: (B, H, S, hd); k, v: (B, KV, S, hd) -> (B, H, S, hd)."""
    b, h, s, hd = q.shape
    kv = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    group = h // kv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # fully-masked rows (can happen with exotic windows) -> zeros
    probs = jnp.where(mask.any(-1)[None, None, :, None], probs, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
