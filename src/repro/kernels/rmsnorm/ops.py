"""Jitted wrapper for the RMSNorm kernel (interpret on CPU)."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from .kernel import rmsnorm as _kernel
from .ref import rmsnorm_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(
    x: jax.Array,
    weight: jax.Array,
    *,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """RMSNorm over the last dim.  Accepts (..., D); leading dims flattened."""
    interp = _on_cpu() if interpret is None else interpret
    shape = x.shape
    y = _kernel(x.reshape(-1, shape[-1]), weight, eps=eps,
                block_rows=min(block_rows, max(1, x.size // shape[-1])),
                interpret=interp)
    return y.reshape(shape)


__all__ = ["rmsnorm", "rmsnorm_ref"]
