"""Fused RMSNorm as a Pallas TPU kernel.

Memory-bound elementwise+reduction op: one HBM read of x, one write of y,
statistics in fp32.  Rows are tiled (block_rows, D) into VMEM; D is the
model dim (always a 128-multiple for the assigned archs after padding) and
feeds the VPU lanes directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)               # (br, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)[None, :]).astype(o_ref.dtype)


def rmsnorm(
    x: jax.Array,          # (rows, D) — callers flatten leading dims
    weight: jax.Array,     # (D,)
    *,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    rows, d = x.shape
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        raise ValueError(f"rows {rows} must divide block_rows {block_rows}")
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, weight)
