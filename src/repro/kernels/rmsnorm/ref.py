"""Pure-jnp oracle for the RMSNorm kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, weight: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)).astype(x.dtype)
