"""Jitted wrapper for the decode-attention kernel (interpret on CPU)."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from .kernel import decode_attention as _kernel
from .ref import decode_attention_ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    length,
    *,
    block_k: int = 1024,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One-token GQA decode.  q: (B, H, hd); cache k/v: (B, S, KV, hd)."""
    interp = _on_cpu() if interpret is None else interpret
    return _kernel(q, k, v, length, block_k=block_k, interpret=interp)


__all__ = ["decode_attention", "decode_attention_ref"]
