"""Pure-jnp oracle for decode attention."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,          # (B, H, hd)
    k: jax.Array,          # (B, S, KV, hd)
    v: jax.Array,
    length,                # () int32
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    b, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, kv, g, hd).astype(jnp.float32)
    kt = k.swapaxes(1, 2).astype(jnp.float32)          # (B, KV, S, hd)
    vt = v.swapaxes(1, 2).astype(jnp.float32)
    scores = jnp.einsum("bjgd,bjsd->bjgs", qg, kt) * scale
    valid = jnp.arange(s)[None, None, None, :] < jnp.asarray(length, jnp.int32)
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bjgs,bjsd->bjgd", probs, vt)
    return out.reshape(b, h, hd).astype(q.dtype)
