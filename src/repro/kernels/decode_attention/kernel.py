"""GQA single-token decode attention (flash-decode style) as a Pallas kernel.

The serving hot path: one query token per sequence against a long KV cache.
Grid: ``(batch, kv_heads, num_kv_blocks)`` — the last dimension walks the
cache sequentially while (m, l, acc) statistics accumulate in VMEM scratch.
All ``G = H / KV`` query heads of a kv group are processed together as a
(G, hd) tile, so the MXU sees a (G, hd) x (hd, bk) matmul per block rather
than G vector products.

The cache is a ring buffer (see ``repro.models.attention.KVCache``): slots
``>= length`` are masked out.  ``length`` arrives as a scalar-prefetch-style
operand (an int32 array) so the same compiled kernel serves any fill level.

VMEM per step: k,v (bk, hd) + q (G, hd) + acc (G, hd) + scores (G, bk);
bk=1024, hd<=256, G<=32 is well under budget; hd and bk are 128-multiples.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _decode_kernel(
    length_ref,                       # (1,1) int32 in SMEM-like memory
    q_ref, k_ref, v_ref, o_ref,
    m_scratch, l_scratch, acc_scratch,
    *,
    block_k: int,
    scale: float,
):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0, 0].astype(jnp.float32)                    # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                              # (G, bk)

    length = length_ref[0, 0]
    slot = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(slot < length, scores, NEG_INF)

    m_prev, l_prev = m_scratch[...], l_scratch[...]
    m_cur = jnp.max(scores, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scratch[...] = m_new
    l_scratch[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scratch[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_scratch[...] / l).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,          # (B, H, hd) — one token per sequence
    k: jax.Array,          # (B, S, KV, hd) ring-buffer cache
    v: jax.Array,
    length,                # () or (B,) int32 — valid cache entries
    *,
    scale: Optional[float] = None,
    block_k: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    b, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    if h % kv:
        raise ValueError(f"q heads {h} not a multiple of kv heads {kv}")
    g = h // kv
    block_k = min(block_k, s)
    if s % block_k:
        raise ValueError(f"cache len {s} must divide block_k {block_k}")
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    nk = s // block_k

    # regroup q: (B, KV, G, hd); cache to (B, KV, S, hd)
    qg = q.reshape(b, kv, g, hd)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    length_arr = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (1, 1))

    kernel = functools.partial(_decode_kernel, block_k=block_k, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, nk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, j, ik: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, hd), lambda b_, j, ik: (b_, j, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, j, ik: (b_, j, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b_, j, ik: (b_, j, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b_, j, ik: (b_, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(length_arr, qg, kt, vt)
    return out.reshape(b, h, hd)
