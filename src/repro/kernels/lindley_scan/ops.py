"""Jitted wrapper for the blocked Lindley scan (interpret on CPU)."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from .kernel import chained_lindley_scan as _chained_kernel
from .kernel import lindley_scan as _kernel
from .ref import chained_lindley_scan_ref, lindley_scan_ref, maxplus_combine


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("block_b", "time_chunk", "interpret"))
def lindley_scan(
    arrivals: jax.Array,
    services: jax.Array,
    *,
    block_b: int = 128,
    time_chunk: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interp = _on_cpu() if interpret is None else interpret
    return _kernel(arrivals, services, block_b=block_b,
                   time_chunk=time_chunk, interpret=interp)


@functools.partial(jax.jit, static_argnames=("block_b", "time_chunk", "interpret"))
def chained_lindley_scan(
    arrivals: jax.Array,
    services: jax.Array,
    *,
    block_b: int = 128,
    time_chunk: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    interp = _on_cpu() if interpret is None else interpret
    return _chained_kernel(arrivals, services, block_b=block_b,
                           time_chunk=time_chunk, interpret=interp)


__all__ = [
    "lindley_scan",
    "lindley_scan_ref",
    "chained_lindley_scan",
    "chained_lindley_scan_ref",
    "maxplus_combine",
]
