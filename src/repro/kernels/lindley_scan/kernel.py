"""Blocked Lindley-recursion scan as a Pallas TPU kernel.

Recurrence per scenario lane: ``C_i = max(A_i, C_{i-1}) + S_i`` — the c = 1
waiting-time recursion of an M/G/1 FIFO queue, which is the inner loop of
the fast-path simulation sweep (`repro.serving.fastsim`).  Structurally
this is the ssm_scan kernel's problem with (+, max) in place of (*, +): a
first-order linear recurrence in the max-plus semiring, carried across
time chunks in VMEM.

Hardware shape: the scenario axis B sits in lanes (last dim, blocks of
128), time chunks iterate the grid's last dimension *sequentially*, and
the per-lane completion-time carry lives in a VMEM scratch register that
never round-trips to HBM between chunks.  HBM sees one read of A and S
and one write of C — the roofline floor.  VMEM per step with tc = 256,
bs = 128: 3 x (256, 128) fp32 ~ 384 KB.

The time loop inside a chunk is a ``fori_loop`` over VMEM rows; each step
is one max and one add on a (1, bs) tile — sequential in time, parallel
across the 128 scenario lanes of the block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lindley_kernel(a_ref, s_ref, c_ref, carry_ref, *, time_chunk: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[...]                     # (tc, bs)
    s = s_ref[...]

    def step(t, carry):
        comp, rows = carry
        comp = jnp.maximum(a[t][None, :], comp) + s[t][None, :]   # (1, bs)
        rows = jax.lax.dynamic_update_index_in_dim(rows, comp[0], t, axis=0)
        return comp, rows

    comp0 = carry_ref[...]             # (1, bs)
    rows0 = jnp.zeros((time_chunk, a.shape[1]), a.dtype)
    comp, rows = jax.lax.fori_loop(0, time_chunk, step, (comp0, rows0))
    carry_ref[...] = comp
    c_ref[...] = rows


def _chained_lindley_kernel(a_ref, s_ref, c_ref, carry_ref, *,
                            time_chunk: int, num_stages: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[...]                     # (tc, bs)
    s = s_ref[...]                     # (J, tc, bs)

    def step(t, carry):
        comp, rows = carry             # J-tuple (1, bs), J-tuple (tc, bs)
        arr = a[t][None, :]            # (1, bs)
        new_comp, new_rows = [], []
        for j in range(num_stages):    # static unroll: J stays in-register
            cj = jnp.maximum(arr, comp[j]) + s[j, t][None, :]
            new_comp.append(cj)
            new_rows.append(jax.lax.dynamic_update_index_in_dim(
                rows[j], cj[0], t, axis=0))
            arr = cj                   # stage j+1 consumes stage j departures
        return tuple(new_comp), tuple(new_rows)

    carry0 = carry_ref[...]            # (J, bs)
    comp0 = tuple(carry0[j][None, :] for j in range(num_stages))
    rows0 = tuple(jnp.zeros((time_chunk, a.shape[1]), a.dtype)
                  for _ in range(num_stages))
    comp, rows = jax.lax.fori_loop(0, time_chunk, step, (comp0, rows0))
    carry_ref[...] = jnp.concatenate(comp, axis=0)
    c_ref[...] = jnp.stack(rows, axis=0)


def lindley_scan(
    arrivals: jax.Array,   # (N, B): FIFO-ordered arrival times
    services: jax.Array,   # (N, B): matching service times
    *,
    block_b: int = 128,
    time_chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Completion times C: (N, B), with C_i = max(A_i, C_{i-1}) + S_i.

    Scenarios (columns) are independent; rows are the sequential FIFO
    order.  ``B`` must divide into ``block_b`` lanes and ``N`` into
    ``time_chunk`` rows (the fastsim caller pads with zero-arrival /
    zero-service slots, which are self-masking: they dispatch instantly
    with zero service and leave the carry unchanged).
    """
    n, b = arrivals.shape
    if services.shape != (n, b):
        raise ValueError(f"shape mismatch: {arrivals.shape} vs {services.shape}")
    block_b = min(block_b, b)
    time_chunk = min(time_chunk, n)
    if b % block_b or n % time_chunk:
        raise ValueError(
            f"dims ({n},{b}) must divide blocks ({time_chunk},{block_b})")
    nb, nt = b // block_b, n // time_chunk

    kernel = functools.partial(_lindley_kernel, time_chunk=time_chunk)
    return pl.pallas_call(
        kernel,
        grid=(nb, nt),
        in_specs=[
            pl.BlockSpec((time_chunk, block_b), lambda ib, it: (it, ib)),
            pl.BlockSpec((time_chunk, block_b), lambda ib, it: (it, ib)),
        ],
        out_specs=pl.BlockSpec((time_chunk, block_b), lambda ib, it: (it, ib)),
        out_shape=jax.ShapeDtypeStruct((n, b), arrivals.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_b), arrivals.dtype)],
        interpret=interpret,
    )(arrivals, services)


def chained_lindley_scan(
    arrivals: jax.Array,   # (N, B): FIFO-ordered external arrival times
    services: jax.Array,   # (J, N, B): per-stage service times
    *,
    block_b: int = 128,
    time_chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Per-stage completion times C: (J, N, B) for a c = 1 tandem chain.

    The blocked multi-stage variant of :func:`lindley_scan`: each time
    row runs all J stage recursions back-to-back in-register (stage j+1's
    arrival is stage j's freshly computed completion), so the whole
    tandem chain is one kernel launch with a (J, block_b) VMEM carry —
    no host round-trip between stages.  Same padding contract as the
    flat kernel: zero-arrival / zero-service pad slots leave every
    stage's carry unchanged (stage carries are non-decreasing down the
    chain, so the cascaded ``max`` collapses onto each stage's own
    backlog).
    """
    if services.ndim != 3:
        raise ValueError(f"services must be (J, N, B), got {services.shape}")
    j, n, b = services.shape
    if arrivals.shape != (n, b):
        raise ValueError(
            f"shape mismatch: {arrivals.shape} vs {services.shape}")
    block_b = min(block_b, b)
    time_chunk = min(time_chunk, n)
    if b % block_b or n % time_chunk:
        raise ValueError(
            f"dims ({n},{b}) must divide blocks ({time_chunk},{block_b})")
    nb, nt = b // block_b, n // time_chunk

    kernel = functools.partial(
        _chained_lindley_kernel, time_chunk=time_chunk, num_stages=j)
    return pl.pallas_call(
        kernel,
        grid=(nb, nt),
        in_specs=[
            pl.BlockSpec((time_chunk, block_b), lambda ib, it: (it, ib)),
            pl.BlockSpec((j, time_chunk, block_b),
                         lambda ib, it: (0, it, ib)),
        ],
        out_specs=pl.BlockSpec((j, time_chunk, block_b),
                               lambda ib, it: (0, it, ib)),
        out_shape=jax.ShapeDtypeStruct((j, n, b), arrivals.dtype),
        scratch_shapes=[pltpu.VMEM((j, block_b), arrivals.dtype)],
        interpret=interpret,
    )(arrivals, services)
