from .ops import chained_lindley_scan, lindley_scan
from .ref import chained_lindley_scan_ref, lindley_scan_ref, maxplus_combine

__all__ = [
    "lindley_scan",
    "lindley_scan_ref",
    "chained_lindley_scan",
    "chained_lindley_scan_ref",
    "maxplus_combine",
]
