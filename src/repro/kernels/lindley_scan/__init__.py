from .ops import lindley_scan
from .ref import lindley_scan_ref, maxplus_combine

__all__ = ["lindley_scan", "lindley_scan_ref", "maxplus_combine"]
