"""Pure-jnp oracle for the blocked Lindley scan kernel.

The c = 1 Lindley recursion over completion times,

    C_i = max(A_i, C_{i-1}) + S_i,

is max-plus linear: writing f_i(x) = max(x + a_i, b_i) with a_i = S_i and
b_i = A_i + S_i, we have C_i = (f_i o ... o f_1)(0), and the composition
of two such affine max-plus maps is again one:

    (f2 o f1)(x) = max(x + a1 + a2, max(b1 + a2, b2))
                 = f_{(a1 + a2, max(b1 + a2, b2))}(x).

Equivalently each f_i is the 2x2 max-plus matrix [[a_i, b_i], [-inf, 0]]
acting on (x, 0), and composition is the max-plus matrix product — an
associative operator, so the whole prefix of completion times is one
``jax.lax.associative_scan`` (the same machinery as the ssm_scan kernel's
linear recurrence, with (+, max) in place of (*, +)).  The property test
in ``tests/test_fastsim_jax.py`` checks associativity of
:func:`maxplus_combine` directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def maxplus_combine(left, right):
    """Compose two max-plus affine operators (elementwise over a batch).

    Operands are ``(a, b)`` pairs representing x -> max(x + a, b); the
    *left* operand is applied first.  Associative by construction (it is a
    max-plus matrix product), which is what licenses evaluating the
    Lindley prefix as a parallel scan.
    """
    a_l, b_l = left
    a_r, b_r = right
    return a_l + a_r, jnp.maximum(b_l + a_r, b_r)


def lindley_scan_ref(arrivals: jax.Array, services: jax.Array) -> jax.Array:
    """Completion times of the c = 1 Lindley system, shape (N, B).

    ``arrivals``/``services``: (N, B) — N requests in FIFO order, B
    independent scenarios.  Evaluated as an associative max-plus scan over
    the per-request operators (a_i, b_i) = (S_i, A_i + S_i); starting from
    an idle server (x0 = 0), C_i = max(acum_i, bcum_i) where (acum, bcum)
    is the scanned prefix composition (acum_i = sum of services alone, the
    never-idle lower bound; bcum_i dominates whenever any arrival gate
    binds).
    """
    acum, bcum = jax.lax.associative_scan(
        maxplus_combine, (services, arrivals + services), axis=0)
    return jnp.maximum(acum, bcum)


def chained_lindley_scan_ref(arrivals: jax.Array,
                             services: jax.Array) -> jax.Array:
    """Per-stage completion times of a tandem of c = 1 Lindley systems.

    ``arrivals``: (N, B) external arrivals in FIFO order; ``services``:
    (J, N, B) per-stage service times.  Stage j+1's arrival process is
    stage j's departure process (completions of a c = 1 FIFO stage are
    already non-decreasing, so no re-sort is needed), which makes the
    whole tandem J chained max-plus scans: J · O(log N) associative-scan
    depth instead of O(J · N) sequential steps.  Returns the (J, N, B)
    stack of per-stage completion times.
    """
    out = []
    cur = arrivals
    for j in range(services.shape[0]):
        cur = lindley_scan_ref(cur, services[j])
        out.append(cur)
    return jnp.stack(out, axis=0)
