from .planner import ShardingPlanner, state_logical_axes

__all__ = ["ShardingPlanner", "state_logical_axes"]
