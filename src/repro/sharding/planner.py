"""Logical-axis sharding planner with divisibility fallback.

Every parameter / state tensor in the substrate carries a tuple of *logical
axis* names (see ``ParamSpec.axes``).  The planner maps logical axes to mesh
axes by priority rules, subject to:

  - a mesh axis is consumed at most once per tensor;
  - a dimension only takes a mesh axis whose size divides it (remaining
    size after earlier assignments) — otherwise the axis is skipped and the
    dim is (partially) replicated.  This is the fallback that handles e.g.
    hymba's 25 attention heads or granite's 49155 vocab on a 16-way
    tensor-parallel axis.

Rule sets:
  - ``train``  — tensor-parallel over "model" for heads/mlp/experts/vocab,
    FSDP over ("pod","data") on the "embed" dim of params, batch over
    ("pod","data").
  - ``serve``  — tensor-parallel only for params (weights stay resident,
    no FSDP gather per step wanted for latency); decode caches shard batch
    over ("pod","data") and the cache sequence over whatever is left
    (("data"|"model")), which is what makes the 500k-token cache fit.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..models.common import ParamSpec as ModelParamSpec


# priority-ordered mesh-axis candidates per logical axis
def _rules(mesh_axes: Tuple[str, ...], *, fsdp: bool, context: str) -> Dict[str, Tuple[str, ...]]:
    data_axes = tuple(a for a in mesh_axes if a in ("pod", "data"))
    rules: Dict[str, Tuple[str, ...]] = {
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "experts": ("model",),
        "ssm_inner": ("model",),
        "head": (),
        "layers": (),
        "frontend": (),
        # train: FSDP.  serve: 2D weight sharding for big archs (the model
        # axis alone leaves e.g. llama3-405B at >100 GB/chip — weights must
        # also split over data; decode activations are tiny, so GSPMD pays a
        # small per-layer partial-sum/gather instead).  Enabled per-arch via
        # ``serve_weight_2d``.
        "embed": data_axes if (fsdp and context == "train") else (),
        # activations / states
        "batch": data_axes,
        "seq": (),
        "kv_seq": data_axes + ("model",),
        "enc_seq": (),
        "state": (),
    }
    return rules


@dataclass
class ShardingPlanner:
    mesh: Mesh
    fsdp: bool = True
    context: str = "train"        # train | serve
    fsdp_vocab: bool = False      # FSDP the embed dim of vocab-bearing params?
    serve_weight_2d: bool = False  # serve: also shard weight embed dims over data

    def __post_init__(self) -> None:
        self.mesh_axes = tuple(self.mesh.axis_names)
        self.axis_sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        self.rules = _rules(self.mesh_axes, fsdp=self.fsdp, context=self.context)
        if self.context == "serve" and self.serve_weight_2d:
            data_axes = tuple(a for a in self.mesh_axes if a in ("pod", "data"))
            self.rules["embed"] = data_axes

    # -- core assignment ------------------------------------------------------

    def spec_for(self, shape: Sequence[int], axes: Sequence[Optional[str]]
                 ) -> PartitionSpec:
        if len(shape) != len(axes):
            raise ValueError(f"rank mismatch {shape} vs {axes}")
        used: set = set()
        dims = []
        # FSDP-sharding the embed dim of the (embed x vocab) projections makes
        # the unembed weight-grad contraction need FULL-batch dlogits per
        # chip: GSPMD all-gathers the fp32 logits over 'data' (67 GB/chip for
        # a 256k vocab at 1M tokens) instead of reduce-scattering the 0.2 GB
        # weight grad — measured §Perf pair B.  Keep those params
        # vocab-sharded only (a ~1 GB/chip optimizer-state cost).
        block_embed_fsdp = (not self.fsdp_vocab) and ("vocab" in axes)
        for size, logical in zip(shape, axes):
            assigned: list = []
            remaining = int(size)
            if logical == "embed" and block_embed_fsdp:
                logical = None
            if logical is not None:
                for mesh_ax in self.rules.get(logical, ()):
                    if mesh_ax not in self.axis_sizes or mesh_ax in used:
                        continue
                    ax_size = self.axis_sizes[mesh_ax]
                    if remaining % ax_size == 0 and remaining >= ax_size:
                        assigned.append(mesh_ax)
                        used.add(mesh_ax)
                        remaining //= ax_size
            if not assigned:
                dims.append(None)
            elif len(assigned) == 1:
                dims.append(assigned[0])
            else:
                dims.append(tuple(assigned))
        return PartitionSpec(*dims)

    def named(self, shape: Sequence[int], axes: Sequence[Optional[str]]
              ) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(shape, axes))

    # -- trees ------------------------------------------------------------------

    def tree_shardings(self, abstract_tree: Any, axes_tree: Any) -> Any:
        """NamedSharding tree for (ShapeDtypeStruct tree, logical-axes tree)."""
        return jax.tree.map(
            lambda leaf, ax: self.named(leaf.shape, ax),
            abstract_tree,
            axes_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(a, (str, type(None))) for a in x
            ),
        )

    def param_shardings(self, model) -> Any:
        """Shardings for a Model's parameter tree."""
        return self.tree_shardings(model.abstract_params(), model.logical_axes())

    # -- batches / states ---------------------------------------------------------

    def batch_spec(self, shape: Sequence[int], kind: str = "tokens") -> NamedSharding:
        """Input batch arrays: dim 0 = global batch, rest replicated/seq."""
        axes: list = ["batch"] + ["seq"] * (len(shape) - 1)
        if kind == "embeds" and len(shape) == 3:
            axes = ["batch", "seq", None]
        return self.named(shape, axes)

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, PartitionSpec())


def state_logical_axes(state_tree: Any, *, stack: int = 2) -> Any:
    """Logical axes for a decode-state pytree.

    States are stacked ``(repeat, count, ...)`` by the backbone (``stack=2``
    leading 'layers' dims).  Type-aware traversal: KVCache / SSMState /
    MLSTMState / SLSTMState leaves get their canonical axes; anything else
    falls back to (batch, replicated...).
    """
    from ..models.attention import KVCache
    from ..models.ssm import SSMState
    from ..models.xlstm import MLSTMState, SLSTMState

    lead = ("layers",) * stack

    def _rec(obj: Any) -> Any:
        if isinstance(obj, KVCache):
            scale_ax = lead + ("batch", "kv_seq", "kv_heads")
            return KVCache(
                k=lead + ("batch", "kv_seq", "kv_heads", "head"),
                v=lead + ("batch", "kv_seq", "kv_heads", "head"),
                index=lead if obj.index.ndim == stack else (None,) * obj.index.ndim,
                length=lead if obj.length.ndim == stack else (None,) * obj.length.ndim,
                k_scale=scale_ax if obj.k_scale is not None else None,
                v_scale=scale_ax if obj.v_scale is not None else None,
            )
        if isinstance(obj, SSMState):
            return SSMState(
                h=lead + ("batch", "ssm_inner", None),
                conv=lead + ("batch", None, "ssm_inner"),
            )
        if isinstance(obj, MLSTMState):
            return MLSTMState(
                c=lead + ("batch", "heads", "head", None),
                n=lead + ("batch", "heads", "head"),
                m=lead + ("batch", "heads"),
            )
        if isinstance(obj, SLSTMState):
            ax = lead + ("batch", "heads", "head")
            return SLSTMState(c=ax, n=ax, h=ax, m=ax)
        if isinstance(obj, dict):
            out = {}
            for k, v in obj.items():
                if k in ("enc_k", "enc_v"):
                    out[k] = lead + ("batch", "enc_seq", "kv_heads", "head")
                else:
                    out[k] = _rec(v)
            return out
        if isinstance(obj, (list, tuple)):
            t = type(obj)
            return t(_rec(v) for v in obj)
        if obj is None:
            return None
        # leaf array (e.g. "position" scalar)
        rank = getattr(obj, "ndim", 0)
        return (None,) * rank

    return _rec(state_tree)


def shard_hint(x, spec: Sequence[Optional[str]]):
    """Best-effort GSPMD sharding hint from the ambient mesh context.

    ``spec`` entries are logical: "batch" (maps to the ("pod","data") axes),
    "model", or None.  Outside a mesh context (single-device tests, the
    serving engine) this is a no-op, so model code can call it
    unconditionally.  A mesh axis is only applied when it divides the dim.

    WHY: GSPMD's auto propagation may re-shard interior ops against the
    communication-optimal choice (measured on the unembed matmul: it split
    the contraction dim across 'data', turning a 0.2 GB weight gather into a
    67 GB fp32 logits all-reduce — §Perf pair B).  Pinning the activation
    layout at the producer removes the solver's freedom to do that.
    """
    import jax as _jax
    from jax.interpreters import pxla as _pxla
    from jax.sharding import PartitionSpec as _P

    try:
        mesh = _pxla.thread_resources.env.physical_mesh
    except Exception:
        return x
    if mesh is None or mesh.empty or not mesh.axis_names:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dims = []
    for dim_size, s in zip(x.shape, spec):
        if s == "batch":
            axes = []
            rem = int(dim_size)
            for a in ("pod", "data"):
                if a in sizes and rem % sizes[a] == 0:
                    axes.append(a)
                    rem //= sizes[a]
            dims.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
        elif s == "model":
            ok = "model" in sizes and dim_size % sizes["model"] == 0
            dims.append("model" if ok else None)
        else:
            dims.append(None)
    return _jax.lax.with_sharding_constraint(x, _P(*dims))
