"""Training loop: metrics, checkpointing, deterministic resume."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import latest_step, restore_checkpoint, save_checkpoint
from ..data.synthetic import DataConfig, SyntheticLM
from ..models.model import Model
from ..optim.adamw import AdamW
from ..optim.schedule import cosine_with_warmup
from .steps import make_train_step


@dataclass
class TrainResult:
    losses: List[float]
    steps: int
    wall_s: float

    @property
    def final_loss(self) -> float:
        return float(np.mean(self.losses[-10:])) if self.losses else float("nan")

    @property
    def initial_loss(self) -> float:
        return float(np.mean(self.losses[:10])) if self.losses else float("nan")


def train(
    model: Model,
    *,
    steps: int,
    data_cfg: Optional[DataConfig] = None,
    optimizer: Optional[AdamW] = None,
    batch_fn: Optional[Callable[[int], Dict[str, np.ndarray]]] = None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 100,
    log_every: int = 10,
    seed: int = 0,
    log_fn: Callable[[str], None] = print,
) -> TrainResult:
    """Single-host training loop (the examples and smoke tests use this;
    the multi-pod path goes through repro.launch.train)."""
    cfg = model.cfg
    optimizer = optimizer or AdamW(learning_rate=3e-4)
    if data_cfg is None:
        data_cfg = DataConfig(
            vocab_size=cfg.vocab_size, seq_len=256, global_batch=8, seed=seed
        )
    stream = SyntheticLM(data_cfg)

    params = model.init(jax.random.PRNGKey(seed))
    opt_state = optimizer.init(params)
    start_step = 0
    if checkpoint_dir and latest_step(checkpoint_dir) is not None:
        (params, opt_state), start_step, _ = restore_checkpoint(
            checkpoint_dir, (params, opt_state)
        )
        log_fn(f"resumed from step {start_step}")

    schedule = lambda s: cosine_with_warmup(
        s, warmup_steps=max(10, steps // 20), total_steps=steps
    )
    step_fn = jax.jit(make_train_step(model, optimizer, schedule=schedule))

    losses: List[float] = []
    t0 = time.time()
    batches = stream.batches(start_step=start_step) if batch_fn is None else None
    for step in range(start_step, steps):
        if batch_fn is not None:
            batch = batch_fn(step)
        else:
            batch = next(batches)  # type: ignore[arg-type]
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, params, opt_state = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if np.isnan(losses[-1]):
            raise FloatingPointError(f"NaN loss at step {step}")
        if log_every and (step % log_every == 0 or step == steps - 1):
            log_fn(f"step {step:5d}  loss {losses[-1]:.4f}  "
                   f"({(time.time() - t0):.1f}s)")
        if checkpoint_dir and checkpoint_every and (step + 1) % checkpoint_every == 0:
            save_checkpoint(checkpoint_dir, step + 1, (params, opt_state),
                            metadata={"arch": cfg.arch_id})
    wall = time.time() - t0
    return TrainResult(losses=losses, steps=steps - start_step, wall_s=wall)
