from .loop import TrainResult, train
from .steps import make_eval_step, make_prefill_step, make_serve_step, make_train_step

__all__ = [
    "TrainResult",
    "train",
    "make_eval_step",
    "make_prefill_step",
    "make_serve_step",
    "make_train_step",
]
