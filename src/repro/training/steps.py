"""Step factories: jit-able train / eval / prefill / serve steps.

These are the functions the launcher jits with explicit in/out shardings and
the dry-run lowers against the production mesh.  They close over the static
Model + optimizer and take only pytrees of arrays, so ``.lower()`` works with
ShapeDtypeStruct stand-ins.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.model import Model
from ..optim.adamw import AdamW, AdamWState
from ..optim.schedule import cosine_with_warmup


def make_train_step(
    model: Model,
    optimizer: AdamW,
    *,
    schedule: Optional[Callable] = None,
) -> Callable:
    """(params, opt_state, batch) -> (loss, new_params, new_opt_state)."""

    def train_step(params, opt_state: AdamWState, batch: Dict[str, jax.Array]):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        lr_scale = schedule(opt_state.step) if schedule is not None else 1.0
        new_params, new_opt = optimizer.update(
            grads, opt_state, params, lr_scale=lr_scale
        )
        return loss, new_params, new_opt

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        return model.loss(params, batch)

    return eval_step


def make_prefill_step(model: Model, *, cache_len: Optional[int] = None) -> Callable:
    """(params, batch) -> (last-position logits, decode state)."""

    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len=cache_len)

    return prefill_step


def make_serve_step(model: Model, *, greedy: bool = True) -> Callable:
    """(params, state, token) -> (next_token | logits, new_state).

    The serve step is ONE new token against the standing decode state (the
    decode_32k / long_500k dry-run shape).
    """

    def serve_step(params, state, token):
        logits, new_state = model.decode_step(params, state, token)
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_state
        return logits, new_state

    return serve_step
