"""Distributed serving launcher with Compass configuration switching.

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --reduced --devices 8 --mesh 2x4 --tokens 16

Demonstrates the paper's mechanism at the MODEL level on a sharded mesh: two
serving configurations of the same architecture (accurate = full attention /
bf16 KV; fast = sliding-window / int8 KV) are compiled side by side against
the SAME weights, a batch is prefLLed, and the driver decodes tokens while an
Elastico controller switches the active executable from synthetic queue-depth
pressure — the production-plane analogue of the paper's <10 ms pipeline
rerouting (weights stay resident; only the compiled step changes).
"""

import argparse
import os
import sys


def _parse_args():
    ap = argparse.ArgumentParser(description="sharded serving launcher")
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--mesh", default="2x4")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--window", type=int, default=16,
                    help="sliding window of the fast serving config")
    return ap.parse_args()


def main() -> None:
    args = _parse_args()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    import dataclasses
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.configs  # noqa: F401
    from ..configs.reduced import reduced_config
    from ..models.registry import build_model, get_config
    from ..sharding.planner import ShardingPlanner

    dims = [int(x) for x in args.mesh.split("x")]
    names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    if np.prod(dims) != len(jax.devices()):
        sys.exit(f"mesh {dims} needs {np.prod(dims)} devices")
    mesh = jax.make_mesh(tuple(dims), names)

    base = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    variants = {
        "accurate": base,
        "fast": dataclasses.replace(
            base, sliding_window=args.window,
            kv_cache_dtype="int8" if base.family in ("dense", "hybrid") else "",
        ),
    }
    if base.family == "ssm":
        # attention-free: the fast rung varies nothing attention-shaped;
        # keep two identical rungs to exercise the switching path.
        variants["fast"] = base

    planner = ShardingPlanner(mesh, fsdp=False, context="serve")
    models = {k: build_model(cfg) for k, cfg in variants.items()}
    params = models["accurate"].init(jax.random.PRNGKey(0))
    params = jax.device_put(params, planner.param_shardings(models["accurate"]))

    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, base.vocab_size)

    cache_len = args.prompt_len + args.tokens
    with mesh:
        states, steps = {}, {}
        for name, m in models.items():
            last, st = m.prefill(params, {"tokens": tokens},
                                 cache_len=m.cache_len_for(cache_len))
            states[name] = st

            def step(params_, st_, tok_, m_=m):
                return m_.decode_step(params_, st_, tok_)

            steps[name] = jax.jit(step)
            print(f"compiled serving config '{name}' "
                  f"(window={models[name].cfg.sliding_window or 'full'}, "
                  f"kv={models[name].cfg.kv_cache_dtype or models[name].cfg.dtype})")

        active = "accurate"
        tok = jnp.argmax(last, -1).astype(jnp.int32)
        t0 = time.perf_counter()
        for i in range(args.tokens):
            # synthetic queue pressure: spike in the middle third
            depth = 10 if args.tokens // 3 <= i < 2 * args.tokens // 3 else 0
            want = "fast" if depth > 5 else "accurate"
            if want != active:
                print(f"  token {i:3d}: switch {active} -> {want} "
                      f"(queue depth {depth})")
                active = want
            logits, states[active] = steps[active](params, states[active], tok)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens x batch {args.batch} in {dt:.2f}s "
          f"({dt / args.tokens * 1e3:.0f} ms/token on CPU)")


if __name__ == "__main__":
    main()
