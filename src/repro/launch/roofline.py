"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs            / (chips * 197 TFLOP/s bf16)
  memory     = HLO_bytes_accessed   / (chips * 819 GB/s HBM)
  collective = collective_bytes     / (chips * 50 GB/s/link ICI)

Sources: ``compiled.cost_analysis()`` for FLOPs / bytes (XLA reports
whole-program totals for the SPMD program = per-device work; we multiply by
device count to get global and divide back — i.e. use them per-chip
directly).  Collective bytes are NOT in cost_analysis: we parse the
post-SPMD HLO text and sum the result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction
(for reduce-scatter the operand is group_size x result; we use the operand
estimate).  This is "logical bytes entering the interconnect per chip per
step" — algorithm factors (ring 2(n-1)/n etc.) are noted, not applied.

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per step; the ratio
MODEL_FLOPS / HLO_FLOPs flags remat/dispatch waste.
"""

from __future__ import annotations

import json
import math
import re
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from .mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


_COMPUTATION_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?\)[^\n]*?(?:condition=%?([\w.\-]+))[^\n]*?(?:body=%?([\w.\-]+))"
)
_WHILE_RE_BC = re.compile(
    r"\bwhile\(.*?\)[^\n]*?(?:body=%?([\w.\-]+))[^\n]*?(?:condition=%?([\w.\-]+))"
)
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """Split HLO module text into {computation_name: [lines]}."""
    comps: Dict[str, List[str]] = {}
    current: Optional[str] = None
    for line in hlo_text.splitlines():
        m = _COMPUTATION_RE.match(line.strip())
        if m and line.rstrip().endswith("{"):
            current = m.group(1)
            comps[current] = []
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(line)
    return comps


def _while_edges(comps: Dict[str, List[str]]) -> List[Tuple[str, str, int]]:
    """(caller_computation, body_computation, trip_count) for every while.

    Trip count heuristic: the largest integer constant in the loop condition
    computation (scan conditions compare the induction var against the
    length).  Falls back to 1 when unparseable (undercounts, never over).
    """
    edges: List[Tuple[str, str, int]] = []
    for caller, lines in comps.items():
        for line in lines:
            if " while(" not in line and "while(" not in line.strip():
                continue
            m = _WHILE_RE.search(line)
            cond = body = None
            if m:
                cond, body = m.group(1), m.group(2)
            else:
                m = _WHILE_RE_BC.search(line)
                if m:
                    body, cond = m.group(1), m.group(2)
            if not body:
                continue
            trip = 1
            if cond and cond in comps:
                consts = [int(c) for ln in comps[cond] for c in _CONST_RE.findall(ln)]
                if consts:
                    trip = max(consts)
            edges.append((caller, body, max(1, trip)))
    return edges


def _multipliers(comps: Dict[str, List[str]], entry: Optional[str] = None
                 ) -> Dict[str, int]:
    """Execution multiplier per computation, following while nesting."""
    edges = _while_edges(comps)
    mult: Dict[str, int] = {c: 1 for c in comps}
    # propagate: body multiplier = caller multiplier * trip, iterate to fix
    for _ in range(8):  # nesting depth bound
        changed = False
        for caller, body, trip in edges:
            want = mult.get(caller, 1) * trip
            if mult.get(body, 1) < want:
                mult[body] = want
                changed = True
        if not changed:
            break
    return mult


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum collective data sizes from (post-SPMD, optimized) HLO text.

    Collectives inside scan/while bodies are multiplied by the loop trip
    count (XLA cost_analysis does NOT do this — verified; see
    :mod:`repro.launch.analytic`).
    """
    comps = _split_computations(hlo_text)
    mults = _multipliers(comps)
    stats = CollectiveStats()
    for comp_name, lines in comps.items():
        mult = mults.get(comp_name, 1)
        for raw in lines:
            stripped = raw.strip()
            m = re.match(r"^(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.*)$", stripped)
            if not m:
                continue
            rhs = m.group(2)
            kind = None
            for c in _COLLECTIVES:
                if re.search(rf"\b{c}(-start)?\(", rhs):
                    kind = c
                    break
            if kind is None:
                continue
            result_part = rhs.split(kind)[0]
            size = sum(
                _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(result_part)
            )
            if kind == "reduce-scatter":
                g = _GROUPS_RE.search(rhs)
                group = len(g.group(1).split(",")) if g else 1
                size *= max(1, group)
            size *= mult
            stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + size
            stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + mult
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # analytic (trip-count-exact) global costs per step
    analytic_flops: float
    analytic_bytes: float
    # raw XLA cost_analysis (per-device SPMD program; scan bodies counted ONCE)
    xla_flops_raw: float
    xla_bytes_raw: float
    # collective bytes per device per step (HLO parse, trip-count corrected)
    collective_bytes: float
    model_flops: float             # useful-FLOPs floor: 6*N_active*D / 2*N*D
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_flops_ratio: float      # model_flops / analytic_flops
    collectives: Dict[str, int]
    memory_per_device: Dict[str, float] = field(default_factory=dict)
    compile_s: float = 0.0
    note: str = ""

    def dominant_term_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: Dict[str, float],
    hlo_text: str,
    model_flops: float,
    analytic_flops: float,
    analytic_bytes: float,
    memory_stats: Optional[Dict[str, float]] = None,
    compile_s: float = 0.0,
    note: str = "",
) -> RooflineReport:
    """Three-term roofline.  compute/memory terms use the analytic model
    (global / chips); the collective term uses the corrected HLO parse (the
    SPMD program is per-device, so parsed bytes are already per-chip)."""
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(hlo_text)

    compute_s = (analytic_flops / chips) / PEAK_FLOPS_BF16
    memory_s = (analytic_bytes / chips) / HBM_BW
    collective_s = coll.total_bytes / ICI_BW_PER_LINK
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    ratio = model_flops / analytic_flops if analytic_flops > 0 else float("nan")
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        analytic_flops=analytic_flops,
        analytic_bytes=analytic_bytes,
        xla_flops_raw=xla_flops,
        xla_bytes_raw=xla_bytes,
        collective_bytes=float(coll.total_bytes),
        model_flops=model_flops,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        useful_flops_ratio=ratio,
        collectives=dict(coll.bytes_by_kind),
        memory_per_device=memory_stats or {},
        compile_s=compile_s,
        note=note,
    )


def model_flops_for(cfg, shape_kind: str, seq_len: int, global_batch: int,
                    *, decoder_len: Optional[int] = None) -> float:
    """6*N*D rule (3x forward for train: fwd + bwd = 3x2ND; serve: 2*N*D per
    token).  MoE uses active params.  D = processed tokens per step."""
    n_active = cfg_active_params(cfg)
    if shape_kind == "train":
        tokens = global_batch * (decoder_len or seq_len)
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        tokens = global_batch * (decoder_len or seq_len)
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * global_batch


_active_cache: Dict[str, float] = {}


def cfg_active_params(cfg) -> float:
    key = cfg.arch_id + str(cfg.num_layers) + str(cfg.d_model)
    if key not in _active_cache:
        _active_cache[key] = float(cfg.active_param_count())
    return _active_cache[key]
