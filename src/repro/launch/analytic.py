"""Analytic FLOP / HBM-byte model for every architecture x input shape.

WHY THIS EXISTS: XLA's ``compiled.cost_analysis()`` counts a while-loop body
ONCE, not trip-count times (verified empirically — a 10-iteration scanned
matmul reports 1/10th the FLOPs of its unrolled twin).  Every backbone here
scans over layers, so raw cost_analysis under-reports by ~num_layers.  The
roofline therefore uses:

  - compute term: THIS analytic model (exact math of our own modules);
  - memory term: THIS analytic traffic model (params + activations + states);
  - collective term: HLO parse with while trip-count correction
    (:mod:`repro.launch.roofline`);
  - raw cost_analysis values are reported alongside for transparency.

All counts are GLOBAL (whole step, all chips); callers divide by chips.
A matmul of (m, k) x (k, n) counts 2*m*k*n FLOPs.  Backward = 2x forward
(two matmuls per forward matmul); remat="full" adds one extra forward.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from ..models.common import ModelConfig
from ..models.ssm import dt_rank


@dataclass(frozen=True)
class StepCosts:
    flops: float          # global FLOPs per step
    param_bytes: float    # bytes of parameters (param_dtype)
    act_bytes: float      # activation traffic (see memory model below)
    state_bytes: float    # KV-cache / recurrent-state traffic per step
    notes: str = ""

    @property
    def hbm_bytes(self) -> float:
        return self.param_bytes + self.act_bytes + self.state_bytes


def _attn_flops(cfg: ModelConfig, s: int, kv_len: Optional[int] = None,
                *, cross_kv: Optional[int] = None) -> float:
    """Per-sequence attention FLOPs (q from s positions)."""
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kvl = kv_len if kv_len is not None else s
    if cfg.sliding_window > 0:
        kvl = min(kvl, cfg.sliding_window)
    src = cross_kv if cross_kv is not None else s
    f = 0.0
    f += 2 * s * d * h * hd            # q proj
    f += 2 * src * d * kv * hd * 2     # k, v proj (on kv source)
    if cross_kv is not None:
        kvl = cross_kv
    # scores + values: causal halves the average kv length for self-attn
    eff = kvl if cross_kv is not None else max(1, kvl // 2) if kvl == s else kvl
    f += 2 * s * h * hd * eff * 2      # qk^T and pv
    f += 2 * s * h * hd * d            # out proj
    return f


def _mlp_flops(cfg: ModelConfig, s: int, d_ff: Optional[int] = None) -> float:
    f_dim = d_ff if d_ff is not None else cfg.d_ff
    n_mat = 3 if cfg.act in ("swiglu", "geglu") else 2
    return 2 * s * cfg.d_model * f_dim * n_mat


def _moe_flops(cfg: ModelConfig, s: int) -> float:
    d, f_dim, e, k = cfg.d_model, cfg.d_ff, cfg.num_experts, cfg.moe_top_k
    router = 2 * s * d * e
    if cfg.moe_impl == "gshard":
        # capacity buffers: E * C tokens, C = s*k*cf/E
        cap_tokens = s * k * cfg.capacity_factor
        expert = 2 * cap_tokens * d * f_dim * 3
    else:
        # dense dispatch: every expert touches every token
        expert = 2 * s * e * d * f_dim * 3
    shared = 0.0
    if cfg.num_shared_experts:
        shared = 2 * s * d * (f_dim * cfg.num_shared_experts) * 3
    return router + expert + shared


def _ssm_flops(cfg: ModelConfig, s: int) -> float:
    d, di, n = cfg.d_model, cfg.ssm_inner, cfg.ssm_state
    r = dt_rank(cfg)
    f = 0.0
    f += 2 * s * d * 2 * di            # in_proj
    f += 2 * s * di * cfg.ssm_conv     # conv (depthwise)
    f += 2 * s * di * (r + 2 * n)      # x_proj
    f += 2 * s * r * di                # dt_proj
    f += s * di * n * 6                # recurrence: decay*h + drive, y=C.h
    f += 2 * s * di * d                # out_proj
    return f


def _mlstm_flops(cfg: ModelConfig, s: int) -> float:
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    f = 2 * s * d * h * hd * 4         # q,k,v,o_gate projections
    f += 2 * s * d * h * 2             # i, f gates
    f += s * h * hd * hd * 4           # C update (outer product + decay) + C q
    f += 2 * s * h * hd * d            # out proj
    return f


def _slstm_flops(cfg: ModelConfig, s: int) -> float:
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    f = 2 * s * d * h * hd * 4         # w gates
    f += 2 * s * h * hd * hd * 4       # recurrent r gates
    f += s * h * hd * 8                # elementwise cell math
    f += 2 * s * h * hd * d            # out proj
    return f


def _layer_flops(cfg: ModelConfig, block_type: str, s: int,
                 kv_len: Optional[int] = None, enc_len: int = 0) -> float:
    if block_type == "dense":
        return _attn_flops(cfg, s, kv_len) + _mlp_flops(cfg, s)
    if block_type == "encoder":
        return _attn_flops(cfg, s, s) + _mlp_flops(cfg, s)
    if block_type == "cross":
        return (
            _attn_flops(cfg, s, kv_len)
            + _attn_flops(cfg, s, cross_kv=enc_len)
            + _mlp_flops(cfg, s)
        )
    if block_type == "moe":
        return _attn_flops(cfg, s, kv_len) + _moe_flops(cfg, s)
    if block_type == "hybrid":
        return _attn_flops(cfg, s, kv_len) + _ssm_flops(cfg, s) + _mlp_flops(cfg, s)
    if block_type == "mlstm":
        return _mlstm_flops(cfg, s)
    if block_type == "slstm":
        return _slstm_flops(cfg, s)
    raise ValueError(block_type)


def _decoder_flops(cfg: ModelConfig, s: int, kv_len: Optional[int] = None,
                   enc_len: int = 0) -> float:
    from ..models.transformer import derive_layout

    repeat, pattern = derive_layout(cfg)
    f = 0.0
    for block_type, count in pattern:
        f += repeat * count * _layer_flops(cfg, block_type, s, kv_len, enc_len)
    if cfg.family == "moe" and cfg.first_dense_layers:
        f += cfg.first_dense_layers * (
            _attn_flops(cfg, s, kv_len) + _mlp_flops(cfg, s, d_ff=cfg.dense_ff or cfg.d_ff)
        )
    return f


def _param_bytes(cfg: ModelConfig) -> float:
    from ..models.registry import build_model
    import numpy as np
    import jax

    model = build_model(cfg)
    bytes_per = 4 if cfg.param_dtype == "float32" else 2
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(model.abstract_params()))
    return float(n) * bytes_per


_PB_CACHE: Dict[str, float] = {}


def param_bytes_cached(cfg: ModelConfig) -> float:
    key = f"{cfg.arch_id}/{cfg.num_layers}/{cfg.d_model}/{cfg.sliding_window}"
    if key not in _PB_CACHE:
        _PB_CACHE[key] = _param_bytes(cfg)
    return _PB_CACHE[key]


def step_costs(cfg: ModelConfig, kind: str, seq_len: int, global_batch: int,
               *, opt_state_dtype_bytes: int = 4) -> StepCosts:
    """Global analytic costs for one step of (cfg, shape kind)."""
    b, s = global_batch, seq_len
    act_dtype = 2 if cfg.dtype == "bfloat16" else 4
    d = cfg.d_model
    l_total = cfg.num_layers + cfg.encoder_layers
    pbytes = param_bytes_cached(cfg)

    if kind in ("train", "prefill"):
        dec_s = s
        enc_len = 0
        if cfg.family == "audio":
            dec_s = max(1, s // cfg.decoder_len_ratio)
            enc_len = s
        fwd = _decoder_flops(cfg, dec_s, enc_len=enc_len) * b
        if cfg.family == "audio":
            fwd += cfg.encoder_layers * (
                _attn_flops(cfg, s, s) + _mlp_flops(cfg, s)
            ) * b
        # unembed (+ embed lookup is gather, ~free)
        out_positions = dec_s
        fwd += 2 * b * out_positions * d * cfg.vocab_size

        if kind == "prefill":
            flops = fwd
            # params read once; activations written once (and the KV cache)
            act = b * (s + dec_s) * d * act_dtype * l_total * 2
            cache = b * dec_s * cfg.num_kv_heads * cfg.head_dim * 2 * act_dtype * cfg.num_layers
            return StepCosts(flops=flops, param_bytes=pbytes,
                             act_bytes=act, state_bytes=cache)

        mult = 3.0 if cfg.remat == "none" else 4.0   # fwd+bwd (+re-fwd)
        flops = fwd * mult
        # params: fwd read + bwd read + grads write + opt read(p,m,v) +
        # write(p,m,v) — m/v in opt dtype
        opt_traffic = pbytes * 2 + 3 * pbytes  # fwd/bwd reads + p rw + grads
        opt_traffic += 4 * (pbytes / 4 * opt_state_dtype_bytes)  # m,v r+w
        # activations: with remat, only sqrt-ish checkpoints are stored; we
        # charge one write + one read of the per-layer residual stream
        act = b * (s + (dec_s if cfg.family == "audio" else 0)) * d * act_dtype
        act *= l_total * (2 if cfg.remat == "none" else 1) * 2
        return StepCosts(flops=flops, param_bytes=opt_traffic,
                         act_bytes=act, state_bytes=0.0,
                         notes=f"remat={cfg.remat}")

    # decode: one token per sequence
    kv_len = seq_len if cfg.sliding_window == 0 else min(cfg.sliding_window, seq_len)
    enc_len = (seq_len // cfg.decoder_len_ratio) if cfg.family == "audio" else 0
    flops = _decoder_flops(cfg, 1, kv_len=kv_len, enc_len=enc_len) * b
    flops += 2 * b * d * cfg.vocab_size
    # params read once per step; full KV cache / state read once
    if cfg.family == "ssm":
        # mLSTM matrix memory per layer
        state = cfg.num_layers * b * cfg.num_heads * cfg.head_dim ** 2 * 4
    else:
        kv_bytes = 1 if cfg.kv_cache_dtype == "int8" else act_dtype
        state = cfg.num_layers * b * kv_len * cfg.num_kv_heads * cfg.head_dim * 2 * kv_bytes
        if cfg.kv_cache_dtype == "int8":
            # per-(token, kv-head) fp32 absmax scales for k and v
            state += cfg.num_layers * b * kv_len * cfg.num_kv_heads * 2 * 4
        if cfg.family == "hybrid":
            state += cfg.num_layers * b * cfg.ssm_inner * cfg.ssm_state * 4
    act = b * d * act_dtype * l_total * 4
    return StepCosts(flops=flops, param_bytes=pbytes, act_bytes=act,
                     state_bytes=float(state))


# ---------------------------------------------------------------------------
# Serving-configuration cost model (production-plane Compass integration)
# ---------------------------------------------------------------------------

def serving_config_costs(cfg: ModelConfig, serving: Dict,
                         *, seq_len: int = 32768, chips: int = 256
                         ) -> "tuple[float, float]":
    """(relative_accuracy, per-request service time) for a serving config.

    The production plane exposes each architecture's accuracy/latency knobs —
    quantization dtype, attention window, MoE top-k, batch cap — as a Compass
    configuration space (DESIGN.md §2b).  Accuracy is *relative* to the
    full-quality configuration (1.0 = unchanged); latency is the analytic
    decode step time on a v5e pod slice divided across the batch.

    Quality model (documented deltas, order-of-magnitude from the quantization
    / windowed-attention / MoE-sparsity literature; exact values are knobs):
      int8 weights:      -1.5% relative accuracy
      window 4096/32k:   -1%   (distant-context loss)
      window 1024/32k:   -3%
      top-k k' < k:      -(1 - k'/k) * 6%
    """
    import dataclasses as _dc

    from .mesh import HBM_BW, PEAK_FLOPS_BF16

    quant = serving.get("quant", "bf16")
    window = serving.get("window", 0)
    top_k = serving.get("moe_top_k", cfg.moe_top_k)
    batch = serving.get("batch_cap", 16)

    acc = 1.0
    if quant == "int8":
        acc -= 0.015
    if window and seq_len > window:
        acc -= 0.03 if window <= 1024 else 0.01
    if cfg.num_experts and top_k < cfg.moe_top_k:
        acc -= (1.0 - top_k / cfg.moe_top_k) * 0.06

    eff = cfg
    over = {}
    if window:
        over["sliding_window"] = int(window)
    if cfg.num_experts and top_k != cfg.moe_top_k:
        over["moe_top_k"] = int(top_k)
    if over:
        eff = _dc.replace(cfg, **over)

    costs = step_costs(eff, "decode", seq_len, batch)
    bytes_total = costs.hbm_bytes
    if quant == "int8":
        bytes_total -= costs.param_bytes / 2  # int8 halves weight traffic
    compute_s = (costs.flops / chips) / PEAK_FLOPS_BF16
    memory_s = (bytes_total / chips) / HBM_BW
    step_s = max(compute_s, memory_s)
    # service time per REQUEST: decode step amortized over the batch, with a
    # nominal 64-token response
    service_s = step_s / batch * 64
    return acc, service_s
