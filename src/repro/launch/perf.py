"""Perf-iteration harness (§Perf hillclimbing).

Runs one (arch x shape) case with a named VARIANT — a set of config /
sharding overrides — re-derives the three roofline terms, and appends the
record to experiments/perf_iterations.jsonl.  ``--attribute`` additionally
prints the largest collective instructions (bytes x trip count) so the
dominant term can be attributed to specific tensors before choosing the next
change.

MUST run as its own process (forces 512 host devices before jax init):

    PYTHONPATH=src python -m repro.launch.perf --arch minitron-4b \
        --shape train_4k --variant baseline --attribute
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ---- everything below may touch jax ---------------------------------------

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
from typing import Dict, Optional  # noqa: E402

import numpy as np       # noqa: E402

from ..configs import INPUT_SHAPES  # noqa: E402
from ..models.common import ModelConfig  # noqa: E402
from .dryrun import BIG_ARCHS, effective_config, lower_case  # noqa: E402
from .analytic import step_costs  # noqa: E402
from .roofline import (  # noqa: E402
    _multipliers,
    _split_computations,
    _SHAPE_RE,
    _shape_bytes,
    analyze,
    model_flops_for,
    parse_collectives,
)

# ---------------------------------------------------------------------------
# named variants: config overrides per hillclimb iteration
# ---------------------------------------------------------------------------

VARIANTS: Dict[str, Dict] = {
    # "_planner" is passed to ShardingPlanner, everything else to the config.
    # fsdp_vocab=True reproduces the committed baseline's sharding.
    "baseline": {"_planner": {"fsdp_vocab": True}, "act_hints": False},
    "hints_only": {"_planner": {"fsdp_vocab": True}},
    # Pair A: deepseek-moe-16b x train_4k (compute-bound, useful=0.17)
    "moe_gshard": {"moe_impl": "gshard", "_planner": {"fsdp_vocab": True},
                   "act_hints": False},
    "moe_gshard_cf1": {"moe_impl": "gshard", "capacity_factor": 1.0,
                       "_planner": {"fsdp_vocab": True}, "act_hints": False},
    "moe_gshard_sharded_ce": {"moe_impl": "gshard", "sharded_ce": True},
    "moe_gshard_cf1_sharded_ce": {"moe_impl": "gshard", "capacity_factor": 1.0,
                                  "sharded_ce": True},
    # Pair B: minitron-4b x train_4k (collective-bound)
    #   sharded cross-entropy is a CODE change (models/layers.py), toggled via
    #   the config flag; no_vocab_fsdp is a ShardingPlanner rule change.
    "sharded_ce_only": {"sharded_ce": True, "_planner": {"fsdp_vocab": True},
                        "act_hints": False},
    "no_vocab_fsdp": {},
    "sharded_ce_no_vocab_fsdp": {"sharded_ce": True},
    # Pair C: llama3-405b x decode_32k (memory-bound)
    "kv_int8": {"kv_cache_dtype": "int8"},
    "window_8k": {"sliding_window": 8192},
    "window_8k_kv_int8": {"sliding_window": 8192, "kv_cache_dtype": "int8"},
    "serve_bf16": {"param_dtype": "bfloat16"},
    "serve_bf16_kv_int8": {"param_dtype": "bfloat16", "kv_cache_dtype": "int8"},
    "serve_bf16_kv_int8_window8k": {"param_dtype": "bfloat16",
                                    "kv_cache_dtype": "int8",
                                    "sliding_window": 8192},
}


def attribute_collectives(hlo_text: str, top: int = 12) -> list:
    """Top collective instructions by (bytes x trip count)."""
    comps = _split_computations(hlo_text)
    mults = _multipliers(comps)
    rows = []
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    for comp_name, lines in comps.items():
        mult = mults.get(comp_name, 1)
        for raw in lines:
            stripped = raw.strip()
            m = re.match(r"^(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.*)$", stripped)
            if not m:
                continue
            rhs = m.group(2)
            kind = next((c for c in kinds if re.search(rf"\b{c}(-start)?\(", rhs)), None)
            if kind is None:
                continue
            result_part = rhs.split(kind)[0]
            shapes = _SHAPE_RE.findall(result_part)
            size = sum(_shape_bytes(d, dims) for d, dims in shapes)
            rows.append(
                {
                    "kind": kind,
                    "bytes": size * mult,
                    "mult": mult,
                    "shape": " ".join(f"{d}[{s}]" for d, s in shapes),
                    "comp": comp_name[:40],
                }
            )
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:top]


def run_variant(arch: str, shape_name: str, variant: str,
                *, attribute: bool = False) -> Dict:
    overrides = dict(VARIANTS[variant])
    planner_kwargs = overrides.pop("_planner", None)
    cfg = effective_config(arch, shape_name)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    shape = INPUT_SHAPES[shape_name]
    t0 = time.time()
    lowered, meta = lower_case(arch, shape_name, cfg=cfg,
                               planner_kwargs=planner_kwargs)
    compiled = lowered.compile()
    t_total = time.time() - t0

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    dec_len = None
    if cfg.family == "audio":
        dec_len = max(1, shape.seq_len // cfg.decoder_len_ratio)
    mf = model_flops_for(cfg, shape.kind, shape.seq_len, shape.global_batch,
                         decoder_len=dec_len)
    costs = step_costs(
        cfg, shape.kind, shape.seq_len, shape.global_batch,
        opt_state_dtype_bytes=2 if arch in BIG_ARCHS else 4,
    )
    report = analyze(
        arch=arch, shape=shape_name, mesh_name=meta["mesh"], chips=meta["chips"],
        cost=dict(cost), hlo_text=hlo, model_flops=mf,
        analytic_flops=costs.flops, analytic_bytes=costs.hbm_bytes,
        compile_s=t_total, note=f"variant={variant}",
    )
    rec = dataclasses.asdict(report)
    rec["variant"] = variant
    rec["kind"] = shape.kind
    if attribute:
        rec["top_collectives"] = attribute_collectives(hlo)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline",
                    help=",".join(VARIANTS))
    ap.add_argument("--attribute", action="store_true")
    ap.add_argument("--out", default="experiments/perf_iterations.jsonl")
    args = ap.parse_args()

    for variant in args.variant.split(","):
        rec = run_variant(args.arch, args.shape, variant, attribute=args.attribute)
        print(
            f"{args.arch} x {args.shape} [{variant}]: "
            f"compute={rec['compute_s'] * 1e3:.2f}ms "
            f"memory={rec['memory_s'] * 1e3:.2f}ms "
            f"collective={rec['collective_s'] * 1e3:.2f}ms "
            f"bottleneck={rec['bottleneck']} useful={rec['useful_flops_ratio']:.3f}"
        )
        if args.attribute:
            for r in rec["top_collectives"]:
                print(
                    f"    {r['kind']:18s} {r['bytes'] / 1e9:8.2f}GB  x{r['mult']:<5d}"
                    f" {r['shape']}  in {r['comp']}"
                )
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
