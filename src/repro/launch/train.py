"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 20 --devices 8 --mesh 2x4

Builds the mesh (forcing host devices when requested — must happen before jax
initializes), plans GSPMD shardings for params / optimizer / batches through
the same ShardingPlanner the production dry-run uses, and runs REAL sharded
train steps on synthetic data with loss/step-time logging and checkpointing.
On a TPU pod this same entry point runs with ``--devices 0`` (use the real
device set) and ``--mesh 16x16``.
"""

import argparse
import os
import sys


def _parse_args():
    ap = argparse.ArgumentParser(description="sharded training launcher")
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--devices", type=int, default=8,
                    help="force N host devices (0 = use the real device set)")
    ap.add_argument("--mesh", default="2x4",
                    help="mesh shape, e.g. 2x4 (data x model) or 2x4x4 "
                         "(pod x data x model)")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--log-every", type=int, default=5)
    return ap.parse_args()


def main() -> None:
    args = _parse_args()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    # jax may only be imported after the device-count flag is set
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.configs  # noqa: F401
    from ..configs.reduced import reduced_config
    from ..data.synthetic import DataConfig, SyntheticLM
    from ..models.registry import build_model, get_config
    from ..optim.adamw import AdamW, AdamWState
    from ..sharding.planner import ShardingPlanner
    from ..training.steps import make_train_step
    from ..checkpoint.io import save_checkpoint

    dims = [int(x) for x in args.mesh.split("x")]
    names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    if len(dims) not in (2, 3):
        sys.exit("mesh must be 2- or 3-dimensional")
    if np.prod(dims) != len(jax.devices()):
        sys.exit(f"mesh {dims} needs {np.prod(dims)} devices, "
                 f"have {len(jax.devices())}")
    mesh = jax.make_mesh(tuple(dims), names)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    planner = ShardingPlanner(mesh, fsdp=True, context="train")
    param_sh = planner.param_shardings(model)

    opt = AdamW(learning_rate=args.lr)
    params = jax.device_put(model.init(jax.random.PRNGKey(0)), param_sh)
    opt_state = jax.device_put(
        opt.init(params),
        AdamWState(step=planner.replicated(), m=param_sh, v=param_sh),
    )

    lm = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=0,
    ))
    batches = lm.batches()
    sample = next(lm.batches())
    batch_sh = {k: planner.batch_spec(v.shape) for k, v in sample.items()}

    with mesh:
        step_fn = jax.jit(
            make_train_step(model, opt),
            in_shardings=(param_sh, None, batch_sh),
            out_shardings=(planner.replicated(), param_sh, None),
        )
        print(f"{args.arch}{' (reduced)' if args.reduced else ''} on "
              f"{'x'.join(map(str, dims))} mesh ({len(jax.devices())} devices)")
        t_first = None
        for step in range(args.steps):
            batch = {k: jax.device_put(jnp.asarray(v), batch_sh[k])
                     for k, v in next(batches).items()}
            t0 = time.perf_counter()
            loss, params, opt_state = step_fn(params, opt_state, batch)
            loss = float(loss)
            dt = time.perf_counter() - t0
            if t_first is None:
                t_first = dt
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss {loss:.4f}  {dt * 1e3:.0f} ms")
    if args.checkpoint_dir:
        path = save_checkpoint(args.checkpoint_dir, args.steps,
                               jax.device_get(params))
        print(f"checkpoint: {path}")


if __name__ == "__main__":
    main()
