"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes and extract roofline inputs.

MUST be run as its own process (``PYTHONPATH=src python -m repro.launch.dryrun``):
the first two statements force 512 placeholder host devices BEFORE jax
initializes.  Do not import this module from test/bench processes that need
the real single-device view — use a subprocess (tests/test_dryrun.py does).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ---- everything below may touch jax ---------------------------------------

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Any, Dict, Optional, Tuple   # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402

from ..configs import INPUT_SHAPES, LONG_CONTEXT_WINDOW, InputShape  # noqa: E402
from ..models.common import ModelConfig          # noqa: E402
from ..models.registry import arch_ids, build_model, get_config  # noqa: E402
from ..optim.adamw import AdamW, AdamWState      # noqa: E402
from ..sharding.planner import ShardingPlanner, state_logical_axes  # noqa: E402
from ..training.steps import (                   # noqa: E402
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from .analytic import step_costs                 # noqa: E402
from .mesh import make_production_mesh           # noqa: E402
from .roofline import RooflineReport, analyze, model_flops_for  # noqa: E402

BIG_ARCHS = {"llama3-405b"}     # bf16 optimizer state to fit single-pod HBM


def effective_config(arch_id: str, shape_name: str) -> ModelConfig:
    """Apply per-shape adaptations (the long-context sub-quadratic variant)."""
    cfg = get_config(arch_id)
    if (
        shape_name == "long_500k"
        and cfg.family != "ssm"              # xlstm: attention-free already
        and cfg.sliding_window == 0
    ):
        cfg = dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this case.

    Returns {"batch": ...} for train/prefill and {"state":..., "token":...}
    for decode kinds.  No device allocation happens here.
    """
    b, s = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    if shape.kind in ("train", "prefill"):
        batch: Dict[str, Any] = {}
        if cfg.family == "vlm":
            text = s - cfg.prefix_tokens
            batch["patch_embeds"] = _sds((b, cfg.prefix_tokens, cfg.prefix_dim), "bfloat16")
            batch["tokens"] = _sds((b, text), "int32")
            if shape.kind == "train":
                batch["labels"] = _sds((b, text), "int32")
        elif cfg.family == "audio":
            dec = max(1, s // cfg.decoder_len_ratio)
            batch["frames"] = _sds((b, s, cfg.prefix_dim), "bfloat16")
            batch["tokens"] = _sds((b, dec), "int32")
            if shape.kind == "train":
                batch["labels"] = _sds((b, dec), "int32")
        else:
            batch["tokens"] = _sds((b, s), "int32")
            if shape.kind == "train":
                batch["labels"] = _sds((b, s), "int32")
        return {"batch": batch}

    # decode: ONE new token against a standing cache/state of length s
    cache_len = model.cache_len_for(s)
    enc_len = (s // cfg.decoder_len_ratio) if cfg.family == "audio" else 0
    state = jax.eval_shape(
        lambda: model.init_decode_state(b, cache_len, enc_len=enc_len, position=0)
    )
    return {"state": state, "token": _sds((b,), "int32")}


def _opt_for(cfg: ModelConfig) -> AdamW:
    return AdamW(
        state_dtype="bfloat16" if cfg.arch_id in BIG_ARCHS else None
    )


def _opt_shardings(param_sh, planner: ShardingPlanner):
    return AdamWState(step=planner.replicated(), m=param_sh, v=param_sh)


def lower_case(
    arch_id: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    mesh=None,
    cfg: Optional[ModelConfig] = None,
    donate: bool = True,
    planner_kwargs: Optional[Dict[str, Any]] = None,
):
    """Build + lower one (arch, shape, mesh) case.  Returns (lowered, meta).

    ``mesh``/``cfg`` overrides let tests run reduced configs on tiny meshes
    through the exact same path.
    """
    shape = INPUT_SHAPES[shape_name]
    if cfg is None:
        cfg = effective_config(arch_id, shape_name)
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    specs = input_specs(cfg, shape)
    params_abs = model.abstract_params()

    context = "train" if shape.kind == "train" else "serve"
    pk = dict(planner_kwargs or {})
    if context == "serve":
        # big archs cannot hold a model-axis weight shard per chip (llama3-405B
        # = >100 GB/chip); split weights over data too (2D weight sharding).
        pk.setdefault("serve_weight_2d", arch_id in BIG_ARCHS)
    planner = ShardingPlanner(mesh, fsdp=True, context=context, **pk)
    param_sh = planner.param_shardings(model)

    # Trace/lower under the mesh context so interior ``shard_hint``
    # constraints (PartitionSpec-based) bind to this mesh.
    with mesh:
        if shape.kind == "train":
            opt = _opt_for(cfg)
            opt_abs = jax.eval_shape(opt.init, params_abs)
            opt_sh = _opt_shardings(param_sh, planner)
            batch_sh = {
                k: planner.batch_spec(v.shape) for k, v in specs["batch"].items()
            }
            step = make_train_step(model, opt)
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(planner.replicated(), param_sh, opt_sh),
                donate_argnums=(0, 1) if donate else (),
            )
            lowered = jitted.lower(params_abs, opt_abs, specs["batch"])
        elif shape.kind == "prefill":
            batch_sh = {
                k: planner.batch_spec(v.shape) for k, v in specs["batch"].items()
            }
            cache_len = model.cache_len_for(shape.seq_len)
            step = make_prefill_step(model, cache_len=cache_len)
            jitted = jax.jit(step, in_shardings=(param_sh, batch_sh))
            lowered = jitted.lower(params_abs, specs["batch"])
        else:  # decode
            state_abs = specs["state"]
            state_sh = planner.tree_shardings(
                state_abs, state_logical_axes(state_abs)
            )
            token_sh = planner.batch_spec(specs["token"].shape)
            step = make_serve_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(param_sh, state_sh, token_sh),
                out_shardings=(token_sh, state_sh),
                donate_argnums=(1,) if donate else (),
            )
            lowered = jitted.lower(params_abs, state_abs, specs["token"])

    meta = {
        "arch": arch_id,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(np.prod(mesh.devices.shape)),
        "cfg": cfg,
        "model": model,
    }
    return lowered, meta


def run_case(arch_id: str, shape_name: str, *, multi_pod: bool) -> Dict[str, Any]:
    t0 = time.time()
    lowered, meta = lower_case(arch_id, shape_name, multi_pod=multi_pod)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_stats = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
            "alias_size_bytes": getattr(mem, "alias_size_in_bytes", 0),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_stats = {"error": str(e)}

    shape = INPUT_SHAPES[shape_name]
    cfg = meta["cfg"]
    dec_len = None
    if cfg.family == "audio":
        dec_len = max(1, shape.seq_len // cfg.decoder_len_ratio)
    mf = model_flops_for(cfg, shape.kind, shape.seq_len, shape.global_batch,
                         decoder_len=dec_len)
    costs = step_costs(
        cfg, shape.kind, shape.seq_len, shape.global_batch,
        opt_state_dtype_bytes=2 if cfg.arch_id in BIG_ARCHS else 4,
    )
    hlo = compiled.as_text()
    report = analyze(
        arch=arch_id,
        shape=shape_name,
        mesh_name=meta["mesh"],
        chips=meta["chips"],
        cost=dict(cost),
        hlo_text=hlo,
        model_flops=mf,
        analytic_flops=costs.flops,
        analytic_bytes=costs.hbm_bytes,
        memory_stats=mem_stats,
        compile_s=t_compile,
        note=costs.notes,
    )
    out = dataclasses.asdict(report)
    out["lower_s"] = t_lower
    out["kind"] = shape.kind
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun_results.jsonl")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    arches = arch_ids() if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    n_ok = n_fail = 0
    with open(args.out, "a") as f:
        for arch in arches:
            for shape in shapes:
                for multi in meshes:
                    tag = f"{arch} x {shape} x {'2x16x16' if multi else '16x16'}"
                    t0 = time.time()
                    try:
                        rec = run_case(arch, shape, multi_pod=multi)
                        n_ok += 1
                        print(
                            f"[OK]   {tag}: compute={rec['compute_s']*1e3:.2f}ms "
                            f"memory={rec['memory_s']*1e3:.2f}ms "
                            f"collective={rec['collective_s']*1e3:.2f}ms "
                            f"bottleneck={rec['bottleneck']} "
                            f"useful={rec['useful_flops_ratio']:.2f} "
                            f"({time.time()-t0:.0f}s)",
                            flush=True,
                        )
                    except Exception as e:
                        n_fail += 1
                        rec = {
                            "arch": arch, "shape": shape,
                            "mesh": "2x16x16" if multi else "16x16",
                            "error": f"{type(e).__name__}: {e}",
                        }
                        print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
                        if args.fail_fast:
                            traceback.print_exc()
                            raise
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
