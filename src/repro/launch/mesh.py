"""Production mesh construction (dry-run target: TPU v5e pods).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — device count is locked
on first jax init, and only the dry-run entrypoint forces 512 host devices.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``.

    Axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many devices the current process has (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # 197 TFLOP/s
HBM_BW = 819e9                    # 819 GB/s
ICI_BW_PER_LINK = 50e9            # ~50 GB/s/link
