"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, warmup_steps: int, total_steps: int,
                       min_ratio: float = 0.1):
    """Linear warmup then cosine decay to ``min_ratio`` of peak LR."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1.0, warmup_steps))
    progress = jnp.clip(
        (step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    return warm * (min_ratio + (1.0 - min_ratio) * cos)


def constant(step, *, value: float = 1.0):
    return jnp.full((), value, jnp.float32)
