"""AdamW with decoupled weight decay and configurable state dtype.

Pure-functional (init / update), pytree-shaped exactly like the params so the
sharding planner can reuse the parameter shardings for the optimizer state.
``state_dtype`` lets big-model configs keep m/v in bf16 (halves optimizer HBM
— required for llama3-405b on a single 256-chip pod, see EXPERIMENTS.md).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array      # () int32
    m: Any               # first-moment pytree (like params)
    v: Any               # second-moment pytree


@dataclass(frozen=True)
class AdamW:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: Optional[str] = None    # None = same as param
    grad_clip_norm: float = 1.0

    def init(self, params: Any) -> AdamWState:
        def zeros_like(p):
            dt = jnp.dtype(self.state_dtype) if self.state_dtype else p.dtype
            return jnp.zeros(p.shape, dt)

        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros_like, params),
            v=jax.tree.map(zeros_like, params),
        )

    def update(
        self,
        grads: Any,
        state: AdamWState,
        params: Any,
        *,
        lr_scale: jax.Array | float = 1.0,
    ) -> Tuple[Any, AdamWState]:
        """Returns (new_params, new_state)."""
        step = state.step + 1
        if self.grad_clip_norm > 0:
            gnorm = jnp.sqrt(
                sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads))
            )
            clip = jnp.minimum(1.0, self.grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * clip.astype(g.dtype), grads)

        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self.learning_rate * lr_scale

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            m_hat = mf / bc1
            v_hat = vf / bc2
            delta = m_hat / (jnp.sqrt(v_hat) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
        return new_params, AdamWState(step=step, m=new_m, v=new_v)
