"""The Model facade: init / forward / loss / prefill / decode for every
architecture family, built from a :class:`ModelConfig`.

Parameter tree layout::

  {
    "embed":    {embedding, final_norm, unembed?},
    "decoder":  [segment_0, segment_1, ...],        # stacked (repeat, count, ...)
    "encoder":  [...],                              # encdec / audio only
    "frontend": {proj}                              # stubbed modality projector
  }

Batch conventions (what :func:`repro.launch.dryrun.input_specs` produces):

  decoder-only train:  {"tokens": (B,S) i32, "labels": (B,S) i32}
  vlm train:           {"patch_embeds": (B,P,prefix_dim), "tokens": (B,S_t), "labels": (B,S_t)}
  encdec train:        {"frames": (B,S_enc,prefix_dim), "tokens": (B,S_dec), "labels": (B,S_dec)}
  prefill:             same minus labels
  decode:              state + {"token": (B,) i32, "position": () i32}

``labels[t]`` is the target for output position ``t`` (callers pre-shift).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import blocks
from .common import (
    ModelConfig,
    ParamSpec,
    abstract_params as _abstract,
    init_params as _init,
    logical_axes as _axes,
)
from .layers import cross_entropy_loss, embed_specs, embed_tokens, unembed
from .transformer import (
    Layout,
    derive_layout,
    run_stack_decode,
    run_stack_prefill,
    run_stack_seq,
    _segment_specs,
)


def _cast_floats(tree: Any, dtype) -> Any:
    """Cast floating-point leaves to the activation dtype (params are kept in
    ``param_dtype`` for the optimizer; compute runs in ``dtype``).  Norm
    scales and router/ssm-decay weights re-upcast internally where needed."""
    def cast(a):
        if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating):
            return a.astype(dtype)
        return a

    return jax.tree.map(cast, tree)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- structure -----------------------------------------------------------

    @property
    def decoder_layout(self) -> Layout:
        return derive_layout(self.cfg)

    @property
    def encoder_layout(self) -> Optional[Layout]:
        if self.cfg.encoder_layers > 0:
            return (1, [("encoder", self.cfg.encoder_layers)])
        return None

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        specs: Dict[str, Any] = {"embed": embed_specs(cfg)}
        dec_specs = _segment_specs(cfg, self.decoder_layout)
        if cfg.family == "moe" and cfg.first_dense_layers > 0:
            # leading dense layers (DeepSeek-MoE): separate unstacked segment
            d_ff = cfg.dense_ff or cfg.d_ff
            first = _segment_specs(
                cfg, (1, [("dense", cfg.first_dense_layers)]), d_ff=d_ff
            )
            specs["first_dense"] = first
        specs["decoder"] = dec_specs
        if self.encoder_layout is not None:
            specs["encoder"] = _segment_specs(cfg, self.encoder_layout)
        if cfg.prefix_dim > 0:
            specs["frontend"] = {
                "proj": ParamSpec(
                    (cfg.prefix_dim, cfg.d_model), ("frontend", "embed"), "scaled"
                )
            }
        return specs

    # -- params ---------------------------------------------------------------

    def init(self, key: jax.Array) -> Dict[str, Any]:
        return _init(self.param_specs(), key, self.cfg.parameter_dtype)

    def abstract_params(self) -> Dict[str, Any]:
        return _abstract(self.param_specs(), self.cfg.parameter_dtype)

    def logical_axes(self) -> Dict[str, Any]:
        return _axes(self.param_specs())

    # -- helpers ---------------------------------------------------------------

    def _embed_inputs(self, params: Dict, batch: Dict) -> Tuple[jax.Array, int]:
        """Token + (optional) prefix embedding.  Returns (x, prefix_len)."""
        cfg = self.cfg
        dtype = cfg.activation_dtype
        x = embed_tokens(params["embed"], batch["tokens"], dtype)
        prefix_len = 0
        if cfg.family == "vlm":
            prefix = (
                batch["patch_embeds"].astype(dtype)
                @ params["frontend"]["proj"].astype(dtype)
            )
            x = jnp.concatenate([prefix, x], axis=1)
            prefix_len = prefix.shape[1]
        return x, prefix_len

    def _encode(self, params: Dict, batch: Dict) -> jax.Array:
        cfg = self.cfg
        dtype = cfg.activation_dtype
        enc_x = (
            batch["frames"].astype(dtype)
            @ params["frontend"]["proj"].astype(dtype)
        )
        enc_out, _ = run_stack_seq(
            params["encoder"], enc_x, cfg, self.encoder_layout
        )
        return enc_out

    def _first_dense(self, params: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
        if "first_dense" not in params:
            return x, jnp.zeros((), jnp.float32)
        return run_stack_seq(
            params["first_dense"], x, self.cfg,
            (1, [("dense", self.cfg.first_dense_layers)]),
        )

    # -- forward / loss ---------------------------------------------------------

    def forward(self, params: Dict, batch: Dict) -> Tuple[jax.Array, jax.Array]:
        """Full-sequence forward.  Returns (logits, aux_loss)."""
        cfg = self.cfg
        params = _cast_floats(params, cfg.activation_dtype)
        x, prefix_len = self._embed_inputs(params, batch)
        enc_out = None
        if self.encoder_layout is not None:
            enc_out = self._encode(params, batch)
        x, aux0 = self._first_dense(params, x)
        x, aux = run_stack_seq(
            params["decoder"], x, cfg, self.decoder_layout,
            prefix_len=prefix_len if cfg.prefix_lm else 0,
            enc_out=enc_out,
        )
        if prefix_len:
            x = x[:, prefix_len:, :]          # logits only over text positions
        logits = unembed(params["embed"], x, cfg)
        return logits, aux + aux0

    def loss(self, params: Dict, batch: Dict, *, aux_weight: float = 0.01) -> jax.Array:
        logits, aux = self.forward(params, batch)
        ce = cross_entropy_loss(
            logits, batch["labels"], sharded=self.cfg.sharded_ce
        )
        return ce + aux_weight * aux

    # -- serving paths ------------------------------------------------------------

    def cache_len_for(self, max_len: int) -> int:
        cfg = self.cfg
        if cfg.sliding_window > 0:
            return min(cfg.sliding_window, max_len)
        return max_len

    def prefill(self, params: Dict, batch: Dict, *, cache_len: Optional[int] = None
                ) -> Tuple[jax.Array, Dict[str, Any]]:
        """Prefill: forward over the prompt, build decode state, return the
        last-position logits and the state."""
        cfg = self.cfg
        params = _cast_floats(params, cfg.activation_dtype)
        x, prefix_len = self._embed_inputs(params, batch)
        enc_out = None
        if self.encoder_layout is not None:
            enc_out = self._encode(params, batch)
        seq_len = x.shape[1]
        c_len = cache_len if cache_len is not None else self.cache_len_for(seq_len)
        fd_states = None
        if "first_dense" in params:
            x, fd_states = run_stack_prefill(
                params["first_dense"], x, cfg,
                (1, [("dense", cfg.first_dense_layers)]), cache_len=c_len,
            )
        y, seg_states = run_stack_prefill(
            params["decoder"], x, cfg, self.decoder_layout,
            cache_len=c_len,
            prefix_len=prefix_len if cfg.prefix_lm else 0,
            enc_out=enc_out,
        )
        logits = unembed(params["embed"], y[:, -1:, :], cfg)[:, 0, :]
        state = {
            "segments": seg_states,
            "first_dense": fd_states,
            "position": jnp.asarray(seq_len, jnp.int32),
        }
        return logits, state

    def init_decode_state(self, batch_size: int, cache_len: int,
                          *, enc_len: int = 0, position: int = 0) -> Dict[str, Any]:
        """Fresh (or shape-only, via jax.eval_shape) decode state."""
        cfg = self.cfg
        repeat, pattern = self.decoder_layout
        dtype = cfg.activation_dtype

        segs: List[Any] = []
        for block_type, count in pattern:
            one = blocks.block_init_state(
                cfg, block_type, batch_size, cache_len, dtype, enc_len=enc_len
            )
            stacked = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (repeat, count) + a.shape), one
            )
            segs.append(stacked)
        fd_states = None
        if cfg.family == "moe" and cfg.first_dense_layers > 0:
            one = blocks.block_init_state(cfg, "dense", batch_size, cache_len, dtype)
            fd_states = [jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (1, cfg.first_dense_layers) + a.shape
                ), one
            )]
        return {
            "segments": segs,
            "first_dense": fd_states,
            "position": jnp.asarray(position, jnp.int32),
        }

    def decode_step(self, params: Dict, state: Dict, token: jax.Array
                    ) -> Tuple[jax.Array, Dict[str, Any]]:
        """One decode step.  token: (B,) int32.  Returns ((B, V) logits,
        new state)."""
        cfg = self.cfg
        dtype = cfg.activation_dtype
        params = _cast_floats(params, dtype)
        x = embed_tokens(params["embed"], token[:, None], dtype)   # (B, 1, D)
        new_fd = state.get("first_dense")
        if "first_dense" in params:
            x, new_fd = run_stack_decode(
                params["first_dense"], state["first_dense"], x, cfg,
                (1, [("dense", cfg.first_dense_layers)]),
                position=state["position"],
            )
        y, new_segs = run_stack_decode(
            params["decoder"], state["segments"], x, cfg, self.decoder_layout,
            position=state["position"],
        )
        logits = unembed(params["embed"], y, cfg)[:, 0, :]
        return logits, {
            "segments": new_segs,
            "first_dense": new_fd,
            "position": state["position"] + 1,
        }
