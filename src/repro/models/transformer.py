"""Backbone: periodic layer layout, scan-over-layers, and the Model API.

A backbone is described by a *layout*: ``(repeat, [(block_type, count), ...])``
— the block pattern of one period and how many times it repeats.  Examples:

  dense 32L        -> (1, [("dense", 32)])
  xLSTM 48L (1 sLSTM per 8) -> (6, [("mlstm", 7), ("slstm", 1)])
  deepseek-moe 28L -> dense first layer + (1, [("moe", 27)])

Per-segment parameters are stacked ``(repeat, count, *param_shape)`` and the
forward pass is a scan over ``repeat`` with an inner scan over ``count`` —
the HLO contains one body per distinct segment regardless of depth, which is
what keeps the 126-layer llama3-405b dry-run compile tractable.

``remat``: the per-layer body is wrapped in ``jax.checkpoint`` for training
(``cfg.remat``: "none" | "full" | "dots_saveable").
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import blocks
from .common import (
    ModelConfig,
    ParamSpec,
    abstract_params,
    init_params,
    logical_axes,
)
from .layers import cross_entropy_loss, embed_specs, embed_tokens, rmsnorm, unembed

Layout = Tuple[int, List[Tuple[str, int]]]


def derive_layout(cfg: ModelConfig) -> Layout:
    """Layer layout for the config's family (decoder stack)."""
    l = cfg.num_layers
    if cfg.family in ("dense", "vlm"):
        return (1, [("dense", l)])
    if cfg.family == "moe":
        n_moe = l - cfg.first_dense_layers
        return (1, [("moe", n_moe)])
    if cfg.family == "hybrid":
        return (1, [("hybrid", l)])
    if cfg.family == "ssm":
        if cfg.slstm_every and cfg.slstm_every > 1:
            period = cfg.slstm_every
            if l % period != 0:
                raise ValueError(f"{cfg.arch_id}: layers {l} not divisible by period {period}")
            return (l // period, [("mlstm", period - 1), ("slstm", 1)])
        return (1, [("mlstm", l)])
    if cfg.family in ("encdec", "audio"):
        return (1, [("cross", l)])       # decoder stack; encoder built separately
    raise ValueError(f"unknown family {cfg.family!r}")


def _stack_spec(spec: ParamSpec, repeat: int, count: int) -> ParamSpec:
    return ParamSpec(
        shape=(repeat, count) + spec.shape,
        axes=("layers", "layers") + spec.axes,
        init=spec.init,
        scale=spec.scale,
    )


def _segment_specs(cfg: ModelConfig, layout: Layout, *, d_ff: Optional[int] = None) -> List[Dict]:
    repeat, pattern = layout
    out = []
    for block_type, count in pattern:
        base = blocks.block_specs(cfg, block_type, d_ff=d_ff)
        out.append(
            jax.tree.map(
                lambda s: _stack_spec(s, repeat, count),
                base,
                is_leaf=lambda x: isinstance(x, ParamSpec),
            )
        )
    return out


# ---------------------------------------------------------------------------
# stack execution
# ---------------------------------------------------------------------------


def _maybe_remat(fn: Callable, cfg: ModelConfig) -> Callable:
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots_saveable":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_saveable
        )
    raise ValueError(f"unknown remat policy {cfg.remat!r}")


def run_stack_seq(
    seg_params: List[Dict],
    x: jax.Array,
    cfg: ModelConfig,
    layout: Layout,
    *,
    positions: Optional[jax.Array] = None,
    prefix_len: int = 0,
    enc_out: Optional[jax.Array] = None,
    ssm_mode: str = "serial",
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence pass through the whole stack.  Returns (y, aux_sum)."""
    repeat, pattern = layout

    def period_body(carry, period_params):
        h, aux = carry
        for (block_type, count), p_seg in zip(pattern, period_params):
            def layer_body(inner, p_layer, _bt=block_type):
                hh, aa = inner
                hh, a, _ = blocks.block_apply_seq(
                    p_layer, hh, cfg, _bt,
                    positions=positions, prefix_len=prefix_len,
                    enc_out=enc_out, ssm_mode=ssm_mode,
                )
                return (hh, aa + a)

            body = _maybe_remat(layer_body, cfg)
            (h, aux), _ = jax.lax.scan(
                lambda c, p: (body(c, p), None), (h, aux), p_seg
            )
        return (h, aux), None

    aux0 = jnp.zeros((), jnp.float32)
    (x, aux), _ = jax.lax.scan(period_body, (x, aux0), seg_params)
    return x, aux


def run_stack_prefill(
    seg_params: List[Dict],
    x: jax.Array,
    cfg: ModelConfig,
    layout: Layout,
    *,
    cache_len: int,
    positions: Optional[jax.Array] = None,
    prefix_len: int = 0,
    enc_out: Optional[jax.Array] = None,
    ssm_mode: str = "serial",
) -> Tuple[jax.Array, List[Any]]:
    """Full-sequence pass that also builds the decode state for every layer.
    Returns (y, segment states stacked (repeat, count, ...))."""
    repeat, pattern = layout

    def period_body(h, period_params):
        states = []
        for (block_type, count), p_seg in zip(pattern, period_params):
            def layer_body(hh, p_layer, _bt=block_type):
                hh, _, st = blocks.block_apply_seq(
                    p_layer, hh, cfg, _bt,
                    positions=positions, prefix_len=prefix_len,
                    enc_out=enc_out, ssm_mode=ssm_mode, cache_len=cache_len,
                )
                return hh, st

            h, st_seg = jax.lax.scan(layer_body, h, p_seg)
            states.append(st_seg)
        return h, states

    x, seg_states = jax.lax.scan(period_body, x, seg_params)
    return x, seg_states


def run_stack_decode(
    seg_params: List[Dict],
    seg_states: List[Any],
    x: jax.Array,
    cfg: ModelConfig,
    layout: Layout,
    *,
    position: jax.Array,
) -> Tuple[jax.Array, List[Any]]:
    """One-token decode through the stack.  Returns (y, new segment states)."""
    repeat, pattern = layout

    def period_body(h, inputs):
        period_params, period_states = inputs
        new_states = []
        for (block_type, count), p_seg, s_seg in zip(pattern, period_params, period_states):
            def layer_body(hh, xs, _bt=block_type):
                p_layer, s_layer = xs
                hh, new_s = blocks.block_apply_decode(
                    p_layer, hh, s_layer, cfg, _bt, position=position
                )
                return hh, new_s

            h, ns = jax.lax.scan(layer_body, h, (p_seg, s_seg))
            new_states.append(ns)
        return h, new_states

    x, new_seg_states = jax.lax.scan(period_body, x, (seg_params, seg_states))
    return x, new_seg_states
