"""Model substrate: configuration, parameter specs, initialization.

Every architecture in the pool is described by a :class:`ModelConfig` and
built by :mod:`repro.models.registry` into a :class:`Model` exposing

  - ``init(key)``            -> parameter pytree (real arrays)
  - ``abstract_params()``    -> ShapeDtypeStruct pytree (dry-run, no alloc)
  - ``logical_axes()``       -> pytree of logical-axis tuples (sharding)
  - ``train_loss(params, batch)``, ``prefill(params, tokens)``,
    ``decode_step(params, state, token, pos)``

Parameters are plain nested dicts of jnp arrays; layers are stacked on a
leading axis and traversed with ``jax.lax.scan`` so that the HLO contains one
layer body regardless of depth (critical for 126-layer dry-run compile times).
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Complete architectural description (one per assigned architecture)."""

    arch_id: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    first_dense_layers: int = 0  # leading dense-FFN layers (DeepSeek-MoE)
    dense_ff: int = 0            # their hidden size
    moe_impl: str = "dense"      # dense | gshard   (dispatch implementation)
    capacity_factor: float = 1.25

    # --- SSM / hybrid (Mamba-style selective scan) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2

    # --- xLSTM ---
    slstm_every: int = 0         # one sLSTM block every N layers (0 = all mLSTM)

    # --- encoder-decoder ---
    encoder_layers: int = 0
    cross_attention: bool = False
    decoder_len_ratio: int = 1   # train/prefill decoder length = seq // ratio

    # --- stubbed modality frontend (VLM patch / audio frame embeddings) ---
    prefix_tokens: int = 0
    prefix_dim: int = 0          # frontend output dim (projector -> d_model)
    prefix_lm: bool = False      # bidirectional attention over the prefix

    # --- attention ---
    sliding_window: int = 0      # 0 = full; >0 = sliding-window causal
    rope_theta: float = 1.0e4

    # --- numerics / misc ---
    sharded_ce: bool = True      # GSPMD-friendly cross-entropy (see layers.py)
    act_hints: bool = True       # pin activation layouts via shard_hint
    kv_cache_dtype: str = ""     # "" = activation dtype; "int8" = quantized KV
    norm_eps: float = 1.0e-5
    act: str = "swiglu"          # swiglu | gelu
    tied_embeddings: bool = False
    dtype: str = "bfloat16"      # activation dtype
    param_dtype: str = "float32"
    remat: str = "none"          # none | full | dots_saveable
    scan_layers: bool = True
    logit_softcap: float = 0.0

    def __post_init__(self) -> None:
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(1, self.num_kv_heads) != 0:
            raise ValueError(
                f"{self.arch_id}: num_heads {self.num_heads} not divisible by "
                f"kv heads {self.num_kv_heads}"
            )
        if self.family in ("moe",) and (self.num_experts <= 0 or self.moe_top_k <= 0):
            raise ValueError(f"{self.arch_id}: moe family needs experts/top_k")

    # -- derived ------------------------------------------------------------

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def activation_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.dtype)

    @property
    def parameter_dtype(self) -> jnp.dtype:
        return jnp.dtype(self.param_dtype)

    def param_count(self) -> int:
        """Exact parameter count from the spec tree."""
        from .registry import build_model  # late import to avoid cycle

        model = build_model(self)
        return sum(
            int(np.prod(l.shape)) for l in jax.tree.leaves(model.abstract_params())
        )

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared only)."""
        total = self.param_count()
        if self.num_experts <= 0:
            return total
        from .registry import build_model

        model = build_model(self)
        specs = model.abstract_params()
        inactive = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(specs)[0]:
            keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
            if any("experts" in str(k) for k in keys):
                n = int(np.prod(leaf.shape))
                inactive += n - n * self.moe_top_k // self.num_experts
        return total - inactive

    def reduced(self, **overrides: Any) -> "ModelConfig":
        """A smoke-test variant of the same family (2 layers, narrow dims,
        few experts) that runs a real forward/train step on CPU."""
        small: Dict[str, Any] = dict(
            num_layers=2,
            d_model=min(self.d_model, 128),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            dtype="float32",
            param_dtype="float32",
            remat="none",
        )
        if self.num_heads % min(self.num_heads, 4) != 0:
            small["num_heads"] = 1
        if small["num_heads"] % max(1, small["num_kv_heads"]) != 0:
            small["num_kv_heads"] = 1
        if self.num_experts:
            small.update(
                num_experts=min(self.num_experts, 4),
                moe_top_k=min(self.moe_top_k, 2),
                num_shared_experts=min(self.num_shared_experts, 1),
                first_dense_layers=min(self.first_dense_layers, 1),
                dense_ff=min(self.dense_ff, 256) if self.dense_ff else 0,
            )
        if self.encoder_layers:
            small["encoder_layers"] = 2
        if self.prefix_tokens:
            small.update(prefix_tokens=8, prefix_dim=min(self.prefix_dim, 64))
        if self.sliding_window:
            small["sliding_window"] = min(self.sliding_window, 64)
        if self.slstm_every:
            small["slstm_every"] = 2
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    """Shape + logical sharding axes + initializer scale for one parameter."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]     # logical axis name per dim (None = replicated)
    init: str = "normal"                # normal | zeros | ones | scaled
    scale: float = 1.0

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.axes):
            raise ValueError(f"spec rank mismatch: {self.shape} vs {self.axes}")


def init_param(key: jax.Array, spec: ParamSpec, dtype: jnp.dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    fan_in = spec.shape[0] if len(spec.shape) > 1 else max(1, spec.shape[0])
    if spec.init == "scaled":
        std = spec.scale / math.sqrt(fan_in)
    else:
        std = spec.scale * 0.02
    return (jax.random.normal(key, spec.shape) * std).astype(dtype)


def init_params(specs: Any, key: jax.Array, dtype: jnp.dtype) -> Any:
    """Initialize a pytree of ParamSpec into real arrays (deterministic
    per-leaf fold-in of the path hash)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    arrays = [init_param(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, arrays)


def abstract_params(specs: Any, dtype: jnp.dtype) -> Any:
    """ShapeDtypeStruct pytree — dry-run stand-in, no allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def logical_axes(specs: Any) -> Any:
    """Pytree of logical-axis tuples, same structure as the param tree."""
    return jax.tree.map(
        lambda s: s.axes, specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def stacked(spec: ParamSpec, layers: int) -> ParamSpec:
    """Stack a per-layer spec on a leading 'layers' axis (scan-compatible)."""
    return ParamSpec(
        shape=(layers,) + spec.shape,
        axes=("layers",) + spec.axes,
        init=spec.init,
        scale=spec.scale,
    )
