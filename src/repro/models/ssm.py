"""Mamba-style selective state-space block (for hymba's parallel SSM heads).

Selective scan:  h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t,
                 y_t = C_t . h_t + D_skip * x_t,
with input-dependent (dt, B, C) — the "selective" part — and a depthwise
causal conv in front (Mamba architecture, arXiv:2312.00752, adapted).

Two sequence paths:
- ``serial``  — ``jax.lax.scan`` over time.  O(1) memory in T, exact; the
  paper-faithful substrate baseline.
- ``chunked`` — split T into chunks, run an associative scan inside each
  chunk and carry the state across chunks.  Parallel within chunks (TPU
  friendly), identical math; the §Perf candidate.  The Pallas
  ``ssm_scan`` kernel implements the fused version of the serial inner loop.

Decode is a single recurrence step on a carried (B, d_inner, N) state plus a
rolling conv window.
"""

from __future__ import annotations

import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamSpec


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def ssm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, di, n = cfg.d_model, cfg.ssm_inner, cfg.ssm_state
    r = dt_rank(cfg)
    w = cfg.ssm_conv
    return {
        "in_proj": ParamSpec((d, 2 * di), ("embed", "ssm_inner"), "scaled"),
        "conv_w": ParamSpec((w, di), (None, "ssm_inner"), "scaled", 1.0),
        "conv_b": ParamSpec((di,), ("ssm_inner",), "zeros"),
        "x_proj": ParamSpec((di, r + 2 * n), ("ssm_inner", None), "scaled"),
        "dt_proj": ParamSpec((r, di), (None, "ssm_inner"), "scaled"),
        "dt_bias": ParamSpec((di,), ("ssm_inner",), "zeros"),
        "a_log": ParamSpec((di, n), ("ssm_inner", None), "ones"),
        "d_skip": ParamSpec((di,), ("ssm_inner",), "ones"),
        "out_proj": ParamSpec((di, d), ("ssm_inner", "embed"), "scaled"),
    }


class SSMState(NamedTuple):
    h: jax.Array         # (B, d_inner, N) recurrent state
    conv: jax.Array      # (B, conv_w - 1, d_inner) rolling conv inputs


def init_ssm_state(cfg: ModelConfig, batch: int, dtype: jnp.dtype) -> SSMState:
    return SSMState(
        h=jnp.zeros((batch, cfg.ssm_inner, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.ssm_inner), dtype),
    )


def _selective_params(params: Dict, u: jax.Array, cfg: ModelConfig):
    """u: (..., d_inner) -> dt (..., d_inner), B (..., N), C (..., N)."""
    r, n = dt_rank(cfg), cfg.ssm_state
    proj = u @ params["x_proj"]
    dt_in, b, c = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_proj"] + params["dt_bias"])
    return dt, b, c


def _conv_causal(params: Dict, x: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, T, d_inner)."""
    w = params["conv_w"]                     # (W, d_inner)
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    return jax.nn.silu(out + params["conv_b"])


def ssm_apply_seq(
    params: Dict, x: jax.Array, cfg: ModelConfig, *, mode: str = "serial",
    chunk: int = 128, return_state: bool = False,
):
    """Full-sequence selective scan.  x: (B, T, D) -> (B, T, D)
    (optionally also the final :class:`SSMState` for decode continuation)."""
    xz = x @ params["in_proj"]
    u_raw, z = jnp.split(xz, 2, axis=-1)               # (B, T, di) each
    u = _conv_causal(params, u_raw)
    dt, b, c = _selective_params(params, u, cfg)       # (B,T,di),(B,T,N),(B,T,N)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (di, N), negative

    decay = jnp.exp(dt.astype(jnp.float32)[..., None] * a)          # (B,T,di,N)
    # drive: (B, T, di, N) = dt*u (B,T,di,1) * B_t (B,T,1,N)
    drive = (dt * u).astype(jnp.float32)[..., None] * b.astype(jnp.float32)[..., None, :]

    if mode == "chunked":
        y, h_final = _scan_chunked(decay, drive, c, chunk)
    else:
        y, h_final = _scan_serial(decay, drive, c)
    y = y + u.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["out_proj"]
    if return_state:
        w = params["conv_w"].shape[0]
        t = x.shape[1]
        if t >= w - 1:
            conv_state = u_raw[:, t - (w - 1) :, :]
        else:
            conv_state = jnp.pad(u_raw, ((0, 0), (w - 1 - t, 0), (0, 0)))
        return out, SSMState(h=h_final, conv=conv_state)
    return out


def _scan_serial(decay: jax.Array, drive: jax.Array, c: jax.Array):
    """Serial recurrence.  decay/drive: (B,T,di,N); c: (B,T,N) ->
    (y (B,T,di), final state (B,di,N))."""
    def step(h, inputs):
        dec_t, drv_t, c_t = inputs
        h = dec_t * h + drv_t                       # (B, di, N)
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    b_, t, di, n = decay.shape
    h0 = jnp.zeros((b_, di, n), jnp.float32)
    h_final, ys = jax.lax.scan(
        step, h0,
        (decay.swapaxes(0, 1), drive.swapaxes(0, 1),
         c.astype(jnp.float32).swapaxes(0, 1)),
    )
    return ys.swapaxes(0, 1), h_final                # (B, T, di), (B, di, N)


def _scan_chunked(decay: jax.Array, drive: jax.Array, c: jax.Array,
                  chunk: int):
    """Chunked associative scan: parallel inside chunks, serial across.

    Identical recurrence; inside a chunk the pairs (decay, drive) compose
    associatively: (a2, b2) o (a1, b1) = (a1*a2, a2*b1 + b2).
    """
    b_, t, di, n = decay.shape
    if t % chunk != 0:
        return _scan_serial(decay, drive, c)
    nc = t // chunk
    dec = decay.reshape(b_, nc, chunk, di, n)
    drv = drive.reshape(b_, nc, chunk, di, n)
    cc = c.astype(jnp.float32).reshape(b_, nc, chunk, n)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    # prefix-scan within each chunk (axis=2)
    a_pref, b_pref = jax.lax.associative_scan(combine, (dec, drv), axis=2)

    def chunk_step(h, inputs):
        a_p, b_p, c_p = inputs                       # (B, chunk, di, N), ..., (B, chunk, N)
        h_t = a_p * h[:, None] + b_p                 # states at every pos in chunk
        y = jnp.einsum("btdn,btn->btd", h_t, c_p)
        return h_t[:, -1], y

    h0 = jnp.zeros((b_, di, n), jnp.float32)
    h_final, ys = jax.lax.scan(
        chunk_step, h0,
        (a_pref.swapaxes(0, 1), b_pref.swapaxes(0, 1), cc.swapaxes(0, 1)),
    )                                                # ys: (nc, B, chunk, di)
    return ys.swapaxes(0, 1).reshape(b_, t, di), h_final


def ssm_apply_decode(
    params: Dict, x: jax.Array, state: SSMState, cfg: ModelConfig
) -> Tuple[jax.Array, SSMState]:
    """One decode step.  x: (B, 1, D) -> (B, 1, D), new state."""
    xz = x[:, 0, :] @ params["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                 # (B, di)

    # rolling causal conv window
    window = jnp.concatenate([state.conv, u[:, None, :]], axis=1)  # (B, W, di)
    w = params["conv_w"]
    u_conv = jax.nn.silu(
        jnp.einsum("bwd,wd->bd", window, w) + params["conv_b"]
    )
    new_conv = window[:, 1:, :]

    dt, b, c = _selective_params(params, u_conv, cfg)              # (B,di),(B,N),(B,N)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt.astype(jnp.float32)[..., None] * a)         # (B, di, N)
    drive = (dt * u_conv).astype(jnp.float32)[..., None] * b.astype(jnp.float32)[:, None, :]
    h = decay * state.h + drive
    y = jnp.einsum("bdn,bn->bd", h, c.astype(jnp.float32))
    y = y + u_conv.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = (y @ params["out_proj"])[:, None, :]
    return out, SSMState(h=h, conv=new_conv)
