"""Unified transformer-block layer: one spec/apply/decode interface over all
block types so the backbone can scan homogeneous segments.

Block types:
  - ``dense``   attn + MLP                          (llama/stablelm/minitron/...)
  - ``moe``     attn + MoE FFN                      (granite / deepseek)
  - ``hybrid``  parallel attn + Mamba SSM + MLP     (hymba)
  - ``mlstm``   mLSTM (no FFN; xLSTM-style block)
  - ``slstm``   sLSTM + MLP-less block
  - ``encoder`` bidirectional attn + MLP            (seamless encoder)
  - ``cross``   causal self-attn + cross-attn + MLP (seamless decoder)

Every block is pre-norm with residual connections.  Decode state is a
NamedTuple per type; stacked across layers by the backbone.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .common import ModelConfig, ParamSpec
from .layers import mlp_apply, mlp_specs, rmsnorm, rmsnorm_spec

BLOCK_TYPES = ("dense", "moe", "hybrid", "mlstm", "slstm", "encoder", "cross")


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------


def block_specs(cfg: ModelConfig, block_type: str, *, d_ff: Optional[int] = None) -> Dict:
    if block_type in ("dense", "encoder"):
        return {
            "attn_norm": rmsnorm_spec(cfg.d_model),
            "attn": attn.attention_specs(cfg),
            "mlp_norm": rmsnorm_spec(cfg.d_model),
            "mlp": mlp_specs(cfg, d_ff=d_ff),
        }
    if block_type == "moe":
        return {
            "attn_norm": rmsnorm_spec(cfg.d_model),
            "attn": attn.attention_specs(cfg),
            "mlp_norm": rmsnorm_spec(cfg.d_model),
            "moe": moe_mod.moe_specs(cfg),
        }
    if block_type == "hybrid":
        # Hymba: attention heads and SSM heads in parallel on the same input,
        # outputs averaged (arXiv:2411.13676), followed by an MLP.
        return {
            "mix_norm": rmsnorm_spec(cfg.d_model),
            "attn": attn.attention_specs(cfg),
            "ssm": ssm_mod.ssm_specs(cfg),
            "mlp_norm": rmsnorm_spec(cfg.d_model),
            "mlp": mlp_specs(cfg, d_ff=d_ff),
        }
    if block_type == "mlstm":
        return {
            "norm": rmsnorm_spec(cfg.d_model),
            "mlstm": xlstm_mod.mlstm_specs(cfg),
        }
    if block_type == "slstm":
        return {
            "norm": rmsnorm_spec(cfg.d_model),
            "slstm": xlstm_mod.slstm_specs(cfg),
        }
    if block_type == "cross":
        return {
            "attn_norm": rmsnorm_spec(cfg.d_model),
            "attn": attn.attention_specs(cfg),
            "cross_norm": rmsnorm_spec(cfg.d_model),
            "cross": attn.attention_specs(cfg, cross=True),
            "mlp_norm": rmsnorm_spec(cfg.d_model),
            "mlp": mlp_specs(cfg, d_ff=d_ff),
        }
    raise ValueError(f"unknown block type {block_type!r}")


# ---------------------------------------------------------------------------
# full-sequence apply (train / prefill)
# ---------------------------------------------------------------------------


def block_apply_seq(
    params: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    block_type: str,
    *,
    positions: Optional[jax.Array] = None,
    prefix_len: int = 0,
    enc_out: Optional[jax.Array] = None,
    ssm_mode: str = "serial",
    cache_len: int = 0,       # > 0: also build+return decode state (prefill)
) -> Tuple[jax.Array, jax.Array, Any]:
    """Apply one block to (B, S, D).  Returns (y, aux_loss, state|None).

    ``cache_len > 0`` marks the prefill path: attention blocks populate a
    KVCache of that size; recurrent blocks return their final states.
    """
    aux = jnp.zeros((), jnp.float32)
    state: Any = None
    if block_type in ("dense", "moe", "encoder", "cross"):
        h = rmsnorm(x, params["attn_norm"], cfg.norm_eps)
        res = attn.full_attention(
            params["attn"], h, cfg,
            positions=positions,
            causal=(block_type != "encoder"),
            window=cfg.sliding_window,
            prefix_len=prefix_len if block_type != "encoder" else 0,
            return_kv=cache_len > 0,
        )
        if cache_len > 0:
            h, (k, v) = res
            state = attn.cache_from_prefill(k, v, cache_len, cfg=cfg)
        else:
            h = res
        x = x + h
        if block_type == "cross":
            assert enc_out is not None, "cross block needs encoder output"
            h = rmsnorm(x, params["cross_norm"], cfg.norm_eps)
            h = attn.full_attention(params["cross"], h, cfg, kv_source=enc_out)
            x = x + h
            if cache_len > 0:
                enc_k, enc_v = attn.encode_cross_kv(params["cross"], enc_out)
                state = {"kv": state, "enc_k": enc_k, "enc_v": enc_v}
        h = rmsnorm(x, params["mlp_norm"], cfg.norm_eps)
        if block_type == "moe":
            h, aux = moe_mod.moe_apply(params["moe"], h, cfg)
        else:
            h = mlp_apply(params["mlp"], h, cfg.act)
        return x + h, aux, state

    if block_type == "hybrid":
        h = rmsnorm(x, params["mix_norm"], cfg.norm_eps)
        res = attn.full_attention(
            params["attn"], h, cfg,
            positions=positions, causal=True, window=cfg.sliding_window,
            return_kv=cache_len > 0,
        )
        if cache_len > 0:
            a, (k, v) = res
            sres = ssm_mod.ssm_apply_seq(params["ssm"], h, cfg, mode=ssm_mode,
                                         return_state=True)
            s, ssm_state = sres
            state = {"kv": attn.cache_from_prefill(k, v, cache_len, cfg=cfg), "ssm": ssm_state}
        else:
            a = res
            s = ssm_mod.ssm_apply_seq(params["ssm"], h, cfg, mode=ssm_mode)
        x = x + 0.5 * (a + s)
        h = rmsnorm(x, params["mlp_norm"], cfg.norm_eps)
        return x + mlp_apply(params["mlp"], h, cfg.act), aux, state

    if block_type == "mlstm":
        h = rmsnorm(x, params["norm"], cfg.norm_eps)
        if cache_len > 0:
            y, state = xlstm_mod.mlstm_apply_seq(params["mlstm"], h, cfg,
                                                 return_state=True)
        else:
            y = xlstm_mod.mlstm_apply_seq(params["mlstm"], h, cfg)
        return x + y, aux, state

    if block_type == "slstm":
        h = rmsnorm(x, params["norm"], cfg.norm_eps)
        if cache_len > 0:
            y, state = xlstm_mod.slstm_apply_seq(params["slstm"], h, cfg,
                                                 return_state=True)
        else:
            y = xlstm_mod.slstm_apply_seq(params["slstm"], h, cfg)
        return x + y, aux, state

    raise ValueError(f"unknown block type {block_type!r}")


# ---------------------------------------------------------------------------
# decode state + single-token apply
# ---------------------------------------------------------------------------


def block_init_state(
    cfg: ModelConfig, block_type: str, batch: int, cache_len: int,
    dtype: jnp.dtype, *, enc_len: int = 0,
) -> Any:
    if block_type in ("dense", "moe"):
        return attn.init_cache(cfg, batch, cache_len, dtype)
    if block_type == "hybrid":
        return {
            "kv": attn.init_cache(cfg, batch, cache_len, dtype),
            "ssm": ssm_mod.init_ssm_state(cfg, batch, dtype),
        }
    if block_type == "mlstm":
        return xlstm_mod.init_mlstm_state(cfg, batch)
    if block_type == "slstm":
        return xlstm_mod.init_slstm_state(cfg, batch)
    if block_type == "cross":
        return {
            "kv": attn.init_cache(cfg, batch, cache_len, dtype),
            # encoder K/V computed once at prefill
            "enc_k": jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dtype),
            "enc_v": jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        }
    raise ValueError(f"no decode state for block type {block_type!r}")


def block_apply_decode(
    params: Dict,
    x: jax.Array,
    state: Any,
    cfg: ModelConfig,
    block_type: str,
    *,
    position: jax.Array,
    ssm_mode: str = "serial",
) -> Tuple[jax.Array, Any]:
    """One-token decode through one block.  x: (B, 1, D)."""
    if block_type in ("dense", "moe"):
        h = rmsnorm(x, params["attn_norm"], cfg.norm_eps)
        h, new_state = attn.decode_attention(params["attn"], h, state, cfg,
                                             position=position)
        x = x + h
        h = rmsnorm(x, params["mlp_norm"], cfg.norm_eps)
        if block_type == "moe":
            h, _ = moe_mod.moe_apply(params["moe"], h, cfg)
        else:
            h = mlp_apply(params["mlp"], h, cfg.act)
        return x + h, new_state

    if block_type == "hybrid":
        h = rmsnorm(x, params["mix_norm"], cfg.norm_eps)
        a, new_kv = attn.decode_attention(params["attn"], h, state["kv"], cfg,
                                          position=position)
        s, new_ssm = ssm_mod.ssm_apply_decode(params["ssm"], h, state["ssm"], cfg)
        x = x + 0.5 * (a + s)
        h = rmsnorm(x, params["mlp_norm"], cfg.norm_eps)
        x = x + mlp_apply(params["mlp"], h, cfg.act)
        return x, {"kv": new_kv, "ssm": new_ssm}

    if block_type == "mlstm":
        h = rmsnorm(x, params["norm"], cfg.norm_eps)
        y, new_state = xlstm_mod.mlstm_apply_decode(params["mlstm"], h, state, cfg)
        return x + y, new_state

    if block_type == "slstm":
        h = rmsnorm(x, params["norm"], cfg.norm_eps)
        y, new_state = xlstm_mod.slstm_apply_decode(params["slstm"], h, state, cfg)
        return x + y, new_state

    if block_type == "cross":
        h = rmsnorm(x, params["attn_norm"], cfg.norm_eps)
        h, new_kv = attn.decode_attention(params["attn"], h, state["kv"], cfg,
                                          position=position)
        x = x + h
        h = rmsnorm(x, params["cross_norm"], cfg.norm_eps)
        h = attn.decode_cross_attention(params["cross"], h, state["enc_k"], state["enc_v"])
        x = x + h
        h = rmsnorm(x, params["mlp_norm"], cfg.norm_eps)
        x = x + mlp_apply(params["mlp"], h, cfg.act)
        return x, {"kv": new_kv, "enc_k": state["enc_k"], "enc_v": state["enc_v"]}

    raise ValueError(f"unknown block type {block_type!r}")
