"""Model substrate: all assigned architecture families in functional JAX."""

from .common import ModelConfig, ParamSpec
from .model import Model
from .registry import arch_ids, build_model, get_config, get_model, register_arch

__all__ = [
    "ModelConfig",
    "ParamSpec",
    "Model",
    "arch_ids",
    "build_model",
    "get_config",
    "get_model",
    "register_arch",
]
