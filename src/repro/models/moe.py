"""Mixture-of-Experts FFN: router, dense and capacity-based dispatch.

Two dispatch implementations, selectable via ``ModelConfig.moe_impl``:

- ``dense``  — every expert processes every token, outputs combined with the
  (sparse) router weights.  Simple, numerically exact, GSPMD-friendly
  (experts shard cleanly over the 'model' mesh axis), but compiled FLOPs are
  ``num_experts / top_k`` times the useful work.  This is the *paper-faithful
  baseline* substrate: the roofline's MODEL_FLOPS/HLO_FLOPs ratio exposes the
  waste, and the §Perf hillclimb switches to the grouped path.

- ``gshard`` — capacity-based scatter dispatch (GShard/Switch style): tokens
  are routed into per-expert capacity buffers, experts run batched matmuls
  over their buffers only, results scatter back weighted by router probs.
  Compiled FLOPs ~ top_k x FFN (+ padding to capacity); tokens overflowing
  an expert's capacity are dropped (standard capacity-factor semantics).

Router: softmax over experts, top-k selection, probabilities renormalized
over the selected experts (DeepSeek-MoE style), plus an auxiliary
load-balancing loss (Switch Transformer Eq. 4).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamSpec
from .layers import mlp_apply, mlp_specs


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    specs: Dict[str, ParamSpec] = {
        "router": ParamSpec((d, e), ("embed", "experts"), "scaled"),
        "experts": {
            "wi": ParamSpec((e, d, f), ("experts", "embed", "mlp"), "scaled"),
            "wg": ParamSpec((e, d, f), ("experts", "embed", "mlp"), "scaled"),
            "wo": ParamSpec((e, f, d), ("experts", "mlp", "embed"), "scaled"),
        },
    }
    if cfg.num_shared_experts > 0:
        # shared experts run on every token (DeepSeek-MoE fine-grained design)
        specs["shared"] = mlp_specs(cfg, d_ff=cfg.d_ff * cfg.num_shared_experts)
    return specs


def route(
    router_w: jax.Array, x: jax.Array, top_k: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Router: x (T, D) -> (probs (T, k), indices (T, k), aux_loss ()).

    Softmax over all experts in fp32; top-k probabilities renormalized.
    Aux loss = E * sum_e f_e * p_e  (Switch Transformer load balancing).
    """
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs_full = jax.nn.softmax(logits, axis=-1)                 # (T, E)
    probs, idx = jax.lax.top_k(probs_full, top_k)                # (T, k)
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)

    e = router_w.shape[-1]
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)           # (T, k, E)
    frac_tokens = onehot.sum(axis=(0, 1)) / (x.shape[0] * top_k) # f_e
    mean_probs = probs_full.mean(axis=0)                         # p_e
    aux = e * jnp.sum(frac_tokens * mean_probs)
    return probs, idx, aux


def _dense_dispatch(
    params: Dict, x: jax.Array, probs: jax.Array, idx: jax.Array, cfg: ModelConfig
) -> jax.Array:
    """All experts on all tokens; combine with sparse weights.  x: (T, D)."""
    e = cfg.num_experts
    # (T, E) combine weights (zero for unselected experts)
    combine = jnp.zeros((x.shape[0], e), x.dtype).at[
        jnp.arange(x.shape[0])[:, None], idx
    ].set(probs.astype(x.dtype))
    wi, wg, wo = params["experts"]["wi"], params["experts"]["wg"], params["experts"]["wo"]
    h = jnp.einsum("td,edf->tef", x, wi)
    g = jnp.einsum("td,edf->tef", x, wg)
    h = jax.nn.silu(g) * h
    y = jnp.einsum("tef,efd->ted", h, wo)
    return jnp.einsum("ted,te->td", y, combine)


def _gshard_dispatch(
    params: Dict, x: jax.Array, probs: jax.Array, idx: jax.Array, cfg: ModelConfig
) -> jax.Array:
    """Capacity-based scatter dispatch.  x: (T, D) -> (T, D).

    capacity C = ceil(T * top_k * capacity_factor / E).  Each (token, k)
    assignment gets a slot in its expert's buffer if the expert is not full
    (position-in-expert via a cumulative count over the flattened assignment
    order); overflow assignments are dropped.
    """
    t, d = x.shape
    e, k = cfg.num_experts, cfg.moe_top_k
    capacity = max(1, int((t * k * cfg.capacity_factor) / e))

    flat_expert = idx.reshape(-1)                                # (T*k,)
    flat_prob = probs.reshape(-1)                                # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), k)                    # (T*k,)

    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)     # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - 1)             # running count
    pos = jnp.take_along_axis(pos_in_expert, flat_expert[:, None], axis=1)[:, 0]
    keep = pos < capacity

    # scatter tokens into (E, C, D) buffers; dropped tokens write nowhere
    safe_pos = jnp.where(keep, pos, capacity - 1)
    buf = jnp.zeros((e, capacity, d), x.dtype)
    contrib = jnp.where(keep[:, None], x[flat_token], 0.0)
    buf = buf.at[flat_expert, safe_pos].add(contrib)

    wi, wg, wo = params["experts"]["wi"], params["experts"]["wg"], params["experts"]["wo"]
    h = jnp.einsum("ecd,edf->ecf", buf, wi)
    g = jnp.einsum("ecd,edf->ecf", buf, wg)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo)       # (E, C, D)

    # gather back, weight by router prob
    gathered = y[flat_expert, safe_pos]                          # (T*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    out = jnp.zeros((t, d), x.dtype).at[flat_token].add(
        gathered * flat_prob[:, None].astype(x.dtype)
    )
    return out


def moe_apply(
    params: Dict, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """MoE FFN over x: (B, S, D) -> ((B, S, D), aux_loss)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    probs, idx, aux = route(params["router"], xt, cfg.moe_top_k)
    if cfg.moe_impl == "gshard":
        y = _gshard_dispatch(params, xt, probs, idx, cfg)
    else:
        y = _dense_dispatch(params, xt, probs, idx, cfg)
    if cfg.num_shared_experts > 0:
        y = y + mlp_apply(params["shared"], xt, cfg.act)
    return y.reshape(b, s, d), aux
