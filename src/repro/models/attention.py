"""Attention: GQA with RoPE, causal / prefix-LM / sliding-window masks,
full-sequence (train, prefill) and single-token KV-cache decode paths.

Pure-jnp einsum formulation: under pjit the GSPMD partitioner shards the
einsums and inserts the collectives (including distributed softmax when the
KV-cache sequence dim is sharded for long-context decode).  The Pallas
kernels in :mod:`repro.kernels` implement the same contract for the TPU
hot paths and are validated against this module's math.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamSpec
from .layers import apply_rope, rmsnorm_spec


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def attention_specs(cfg: ModelConfig, *, cross: bool = False) -> Dict[str, ParamSpec]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head"), "scaled"),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head"), "scaled"),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head"), "scaled"),
        "wo": ParamSpec((h, hd, d), ("heads", "head", "embed"), "scaled"),
    }


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------


def make_mask(
    q_len: int,
    kv_len: int,
    *,
    causal: bool = True,
    window: int = 0,
    prefix_len: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    """Boolean (q_len, kv_len) mask.  True = attend.

    ``window > 0`` restricts to the last ``window`` positions (inclusive of
    self).  ``prefix_len > 0`` makes the first ``prefix_len`` kv positions
    visible to everyone (PaliGemma-style prefix-LM).  ``q_offset`` shifts
    query positions (decode / chunked prefill).
    """
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    if causal:
        mask = kv_pos <= q_pos
    else:
        mask = jnp.ones((q_len, kv_len), dtype=bool)
    if window > 0:
        mask = mask & (kv_pos > q_pos - window)
    if prefix_len > 0:
        mask = mask | (kv_pos < prefix_len)
    return mask


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Sq, H, hd), k: (B, Skv, KV, hd) -> (B, H, Sq, Skv) with
    grouped-query head sharing."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    group = h // kv
    qg = q.reshape(b, sq, kv, group, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k)
    return scores.reshape(b, kv * group, sq, k.shape[1])


def _gqa_values(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: (B, H, Sq, Skv), v: (B, Skv, KV, hd) -> (B, Sq, H, hd)."""
    b, h, sq, skv = probs.shape
    kv = v.shape[2]
    group = h // kv
    pg = probs.reshape(b, kv, group, sq, skv)
    out = jnp.einsum("bkgqs,bskd->bqkgd", pg, v)
    return out.reshape(b, sq, h, v.shape[3])


def attend(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    mask: Optional[jax.Array],
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """Masked softmax attention with fp32 accumulation.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd); mask: (Sq, Skv) or
    broadcastable.  Returns (B, Sq, H, hd).
    """
    hd = q.shape[-1]
    scale = scale if scale is not None else hd ** -0.5
    scores = _gqa_scores(q, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return _gqa_values(probs, v)


# ---------------------------------------------------------------------------
# module-level forward paths
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    """Decode-time key/value cache for one attention module.

    k, v: (B, S_cache, KV, hd).  ``index`` is the write position (ring buffer
    for sliding-window archs, linear for full attention).  ``length`` is the
    number of valid positions (<= S_cache).

    With ``cfg.kv_cache_dtype == "int8"`` (§Perf pair C), k/v are stored int8
    with per-(token, kv-head) absmax scales in ``k_scale``/``v_scale``
    ((B, S_cache, KV), fp32).  Storage traffic per step drops ~2x vs bf16 at
    ~0.4% attention-output RMS error (validated in tests/test_kv_int8.py);
    scales add 2/head_dim of the int8 bytes.
    """

    k: jax.Array
    v: jax.Array
    index: jax.Array      # () int32 — next write slot
    length: jax.Array     # () int32 — valid entries
    k_scale: Optional[jax.Array] = None   # (B, S_cache, KV) fp32, int8 mode
    v_scale: Optional[jax.Array] = None


def _quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (..., hd) float -> (int8 values, (...,) fp32 absmax scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)).astype(dtype)


def full_attention(
    params: Dict[str, jax.Array],
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
    window: int = 0,
    prefix_len: int = 0,
    kv_source: Optional[jax.Array] = None,
    return_kv: bool = False,
):
    """Full-sequence attention (train / prefill).  x: (B, S, D).

    ``kv_source`` switches to cross-attention: keys/values come from the
    encoder output (no RoPE on cross-attention, T5/seamless-style).
    ``return_kv`` additionally returns the (post-RoPE) keys/values so that
    prefill can populate the decode cache without recomputation.
    """
    b, s, _ = x.shape
    kv_in = kv_source if kv_source is not None else x
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_in, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_in, params["wv"])
    if kv_source is None:
        pos = positions if positions is not None else jnp.arange(s)[None, :]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        mask = make_mask(s, s, causal=causal, window=window, prefix_len=prefix_len)
    else:
        mask = None  # decoder attends the full encoder output
    out = attend(q, k, v, mask)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if return_kv:
        return y, (k, v)
    return y


def cache_from_prefill(k: jax.Array, v: jax.Array, cache_len: int,
                       *, cfg: Optional[ModelConfig] = None) -> KVCache:
    """Build a decode KVCache from prefill keys/values (B, S, KV, hd).

    If ``cache_len >= S`` the entries are written linearly and padded.  If
    ``cache_len < S`` (sliding-window archs) the last ``cache_len`` entries
    are kept and rolled so that position p sits in ring slot ``p % W``,
    matching :func:`decode_attention`'s write pattern.
    """
    b, s, kvh, hd = k.shape
    if cache_len >= s:
        pad = ((0, 0), (0, cache_len - s), (0, 0), (0, 0))
        kc, vc = jnp.pad(k, pad), jnp.pad(v, pad)
        length = s
    else:
        w = cache_len
        kc = jnp.roll(k[:, s - w :], shift=s % w, axis=1)
        vc = jnp.roll(v[:, s - w :], shift=s % w, axis=1)
        length = w
    k_scale = v_scale = None
    if cfg is not None and cfg.kv_cache_dtype == "int8":
        kc, k_scale = _quantize_kv(kc)
        vc, v_scale = _quantize_kv(vc)
    return KVCache(
        k=kc,
        v=vc,
        index=jnp.asarray(s, jnp.int32),
        length=jnp.asarray(length, jnp.int32),
        k_scale=k_scale,
        v_scale=v_scale,
    )


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype: jnp.dtype) -> KVCache:
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    if cfg.kv_cache_dtype == "int8":
        return KVCache(
            k=jnp.zeros(shape, jnp.int8),
            v=jnp.zeros(shape, jnp.int8),
            index=jnp.zeros((), jnp.int32),
            length=jnp.zeros((), jnp.int32),
            k_scale=jnp.zeros(shape[:3], jnp.float32),
            v_scale=jnp.zeros(shape[:3], jnp.float32),
        )
    return KVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        index=jnp.zeros((), jnp.int32),
        length=jnp.zeros((), jnp.int32),
    )


def decode_attention(
    params: Dict[str, jax.Array],
    x: jax.Array,
    cache: KVCache,
    cfg: ModelConfig,
    *,
    position: jax.Array,
) -> Tuple[jax.Array, KVCache]:
    """Single-token decode.  x: (B, 1, D); position: () int32 — the absolute
    position of the new token (RoPE).  Returns (B, 1, D) and updated cache.

    The cache is a ring buffer of size S_cache; for full-attention archs
    S_cache = max context and ``index`` never wraps within a run, for
    sliding-window archs S_cache = window and writes wrap.  Invalid slots are
    masked by ``length``.
    """
    b = x.shape[0]
    s_cache = cache.k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    q = apply_rope(q, position[None, None], cfg.rope_theta)
    k_new = apply_rope(k_new, position[None, None], cfg.rope_theta)

    slot = jnp.mod(cache.index, s_cache)
    quantized = cache.k_scale is not None
    if quantized:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        k_store = jax.lax.dynamic_update_slice(cache.k, kq, (0, slot, 0, 0))
        v_store = jax.lax.dynamic_update_slice(cache.v, vq, (0, slot, 0, 0))
        k_scale = jax.lax.dynamic_update_slice(cache.k_scale, ks, (0, slot, 0))
        v_scale = jax.lax.dynamic_update_slice(cache.v_scale, vs, (0, slot, 0))
        k = _dequantize_kv(k_store, k_scale, x.dtype)
        v = _dequantize_kv(v_store, v_scale, x.dtype)
    else:
        k_store = k = jax.lax.dynamic_update_slice(
            cache.k, k_new.astype(cache.k.dtype), (0, slot, 0, 0))
        v_store = v = jax.lax.dynamic_update_slice(
            cache.v, v_new.astype(cache.v.dtype), (0, slot, 0, 0))
        k_scale = v_scale = None
    new_len = jnp.minimum(cache.length + 1, s_cache)

    # mask out unwritten slots (ring semantics make every written slot valid)
    valid = jnp.arange(s_cache)[None, :] < new_len           # (1, S_cache)
    out = attend(q, k, v, valid[None, None, :, :])           # mask (1,1,1,S)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, KVCache(k=k_store, v=v_store, index=cache.index + 1,
                      length=new_len, k_scale=k_scale, v_scale=v_scale)


def decode_cross_attention(
    params: Dict[str, jax.Array],
    x: jax.Array,
    enc_k: jax.Array,
    enc_v: jax.Array,
) -> jax.Array:
    """Cross-attention during decode: the encoder K/V are precomputed at
    prefill time and static thereafter.  x: (B, 1, D)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    out = attend(q, enc_k, enc_v, None)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def encode_cross_kv(
    params: Dict[str, jax.Array], enc_out: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, params["wv"])
    return k, v
