"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory with
recurrent connections), following arXiv:2405.04517 (stabilized exponential
gating), adapted to the functional JAX substrate.

mLSTM recurrence (per head, head_dim = hd):
    i_t = exp(w_i . x_t + b_i)          (input gate, stabilized)
    f_t = sigmoid(w_f . x_t + b_f)       (forget gate)
    C_t = f_t * C_{t-1} + i_t * v_t k_t^T      (hd x hd matrix state)
    n_t = f_t * n_{t-1} + i_t * k_t
    h_t = o_t * (C_t q_t) / max(|n_t . q_t|, 1)

Stabilization: gates tracked in log space with running max m_t (paper Eq. 15)
so exp() never overflows.  mLSTM has no token-mixing recurrence other than
the state, so the sequence path is a scan with (C, n, m) carry.

sLSTM keeps per-head scalar cells with a recurrent weight on h_{t-1}
(true recurrence — serial by construction).
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamSpec
from .layers import rmsnorm, rmsnorm_spec


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    return {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head"), "scaled"),
        "wk": ParamSpec((d, h, hd), ("embed", "heads", "head"), "scaled"),
        "wv": ParamSpec((d, h, hd), ("embed", "heads", "head"), "scaled"),
        "wi": ParamSpec((d, h), ("embed", "heads"), "scaled"),
        "wf": ParamSpec((d, h), ("embed", "heads"), "scaled"),
        "bi": ParamSpec((h,), ("heads",), "zeros"),
        "bf": ParamSpec((h,), ("heads",), "ones"),
        "wo_gate": ParamSpec((d, h, hd), ("embed", "heads", "head"), "scaled"),
        "wo": ParamSpec((h, hd, d), ("heads", "head", "embed"), "scaled"),
        "norm": rmsnorm_spec(cfg.head_dim),
    }


class MLSTMState(NamedTuple):
    c: jax.Array     # (B, H, hd, hd)
    n: jax.Array     # (B, H, hd)
    m: jax.Array     # (B, H)   log-space stabilizer


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    h, hd = cfg.num_heads, cfg.head_dim
    return MLSTMState(
        c=jnp.zeros((batch, h, hd, hd), jnp.float32),
        n=jnp.zeros((batch, h, hd), jnp.float32),
        m=jnp.full((batch, h), -1e9, jnp.float32),
    )


def _mlstm_gates(params: Dict, x: jax.Array):
    """x: (..., D) -> (q, k, v, o_gate, log_i, log_f) with head dims."""
    q = jnp.einsum("...d,dhk->...hk", x, params["wq"])
    k = jnp.einsum("...d,dhk->...hk", x, params["wk"]) / (x.shape[-1] ** 0.5)
    v = jnp.einsum("...d,dhk->...hk", x, params["wv"])
    o = jax.nn.sigmoid(jnp.einsum("...d,dhk->...hk", x, params["wo_gate"]))
    log_i = (jnp.einsum("...d,dh->...h", x, params["wi"]) + params["bi"]).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("...d,dh->...h", x, params["wf"]) + params["bf"]
    ).astype(jnp.float32)
    return q, k, v, o, log_i, log_f


def _mlstm_step(state: MLSTMState, q, k, v, o, log_i, log_f, eps=1e-6):
    """One stabilized mLSTM step.  q,k,v,o: (B,H,hd); gates: (B,H)."""
    m_new = jnp.maximum(log_f + state.m, log_i)
    f_eff = jnp.exp(log_f + state.m - m_new)[..., None]            # (B,H,1)
    i_eff = jnp.exp(log_i - m_new)[..., None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c = f_eff[..., None] * state.c + i_eff[..., None] * (
        vf[..., :, None] * kf[..., None, :]
    )                                                              # (B,H,hd,hd)
    n = f_eff * state.n + i_eff * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhij,bhj->bhi", c, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, qf)), 1.0)[..., None]
    h_out = (num / den) * o.astype(jnp.float32)
    return MLSTMState(c=c, n=n, m=m_new), h_out


def mlstm_apply_seq(params: Dict, x: jax.Array, cfg: ModelConfig,
                    *, return_state: bool = False):
    """Full-sequence mLSTM.  x: (B, T, D) -> (B, T, D)."""
    b, t, d = x.shape
    q, k, v, o, log_i, log_f = _mlstm_gates(params, x)   # (B,T,H,hd)...

    def step(state, inputs):
        qt, kt, vt, ot, lit, lft = inputs
        state, h_out = _mlstm_step(state, qt, kt, vt, ot, lit, lft)
        return state, h_out

    state0 = init_mlstm_state(cfg, b)
    xs = (
        q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
        o.swapaxes(0, 1), log_i.swapaxes(0, 1), log_f.swapaxes(0, 1),
    )
    state_f, hs = jax.lax.scan(step, state0, xs)         # (T, B, H, hd)
    hs = rmsnorm(hs.swapaxes(0, 1).astype(x.dtype), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bthk,hkd->btd", hs, params["wo"])
    if return_state:
        return out, state_f
    return out


def mlstm_apply_decode(
    params: Dict, x: jax.Array, state: MLSTMState, cfg: ModelConfig
) -> Tuple[jax.Array, MLSTMState]:
    """One decode step.  x: (B, 1, D)."""
    q, k, v, o, log_i, log_f = _mlstm_gates(params, x[:, 0, :])
    state, h_out = _mlstm_step(state, q, k, v, o, log_i, log_f)
    h_out = rmsnorm(h_out.astype(x.dtype), params["norm"], cfg.norm_eps)
    return jnp.einsum("bhk,hkd->bd", h_out, params["wo"])[:, None, :], state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    return {
        "wz": ParamSpec((d, h, hd), ("embed", "heads", "head"), "scaled"),
        "rz": ParamSpec((h, hd, hd), ("heads", "head", None), "scaled"),
        "wi": ParamSpec((d, h, hd), ("embed", "heads", "head"), "scaled"),
        "ri": ParamSpec((h, hd, hd), ("heads", "head", None), "scaled"),
        "wf": ParamSpec((d, h, hd), ("embed", "heads", "head"), "scaled"),
        "rf": ParamSpec((h, hd, hd), ("heads", "head", None), "scaled"),
        "wo_gate": ParamSpec((d, h, hd), ("embed", "heads", "head"), "scaled"),
        "ro": ParamSpec((h, hd, hd), ("heads", "head", None), "scaled"),
        "bf": ParamSpec((h, hd), ("heads", "head"), "ones"),
        "wo": ParamSpec((h, hd, d), ("heads", "head", "embed"), "scaled"),
    }


class SLSTMState(NamedTuple):
    c: jax.Array     # (B, H, hd) cell
    n: jax.Array     # (B, H, hd) normalizer
    h: jax.Array     # (B, H, hd) hidden (recurrent input)
    m: jax.Array     # (B, H, hd) stabilizer


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    shape = (batch, cfg.num_heads, cfg.head_dim)
    return SLSTMState(
        c=jnp.zeros(shape, jnp.float32),
        n=jnp.zeros(shape, jnp.float32),
        h=jnp.zeros(shape, jnp.float32),
        m=jnp.full(shape, -1e9, jnp.float32),
    )


def _slstm_step(params: Dict, state: SLSTMState, x_t: jax.Array, eps=1e-6):
    """x_t: (B, D) -> (state, h_out (B,H,hd)).  Recurrent on h_{t-1}."""
    hp = state.h                                           # (B,H,hd) fp32

    def gate(wname, rname):
        return (
            jnp.einsum("bd,dhk->bhk", x_t, params[wname]).astype(jnp.float32)
            + jnp.einsum("bhj,hjk->bhk", hp, params[rname].astype(jnp.float32))
        )

    z = jnp.tanh(gate("wz", "rz"))
    log_i = gate("wi", "ri")
    log_f = jax.nn.log_sigmoid(gate("wf", "rf") + params["bf"].astype(jnp.float32))
    o = jax.nn.sigmoid(gate("wo_gate", "ro"))
    m_new = jnp.maximum(log_f + state.m, log_i)
    f_eff = jnp.exp(log_f + state.m - m_new)
    i_eff = jnp.exp(log_i - m_new)
    c = f_eff * state.c + i_eff * z
    n = f_eff * state.n + i_eff
    h_out = o * c / jnp.maximum(n, 1.0)
    return SLSTMState(c=c, n=n, h=h_out, m=m_new), h_out


def slstm_apply_seq(params: Dict, x: jax.Array, cfg: ModelConfig,
                    *, return_state: bool = False):
    b, t, d = x.shape

    def step(state, x_t):
        state, h_out = _slstm_step(params, state, x_t)
        return state, h_out

    state_f, hs = jax.lax.scan(step, init_slstm_state(cfg, b), x.swapaxes(0, 1))
    hs = hs.swapaxes(0, 1).astype(x.dtype)                 # (B,T,H,hd)
    out = jnp.einsum("bthk,hkd->btd", hs, params["wo"])
    if return_state:
        return out, state_f
    return out


def slstm_apply_decode(
    params: Dict, x: jax.Array, state: SLSTMState, cfg: ModelConfig
) -> Tuple[jax.Array, SLSTMState]:
    state, h_out = _slstm_step(params, state, x[:, 0, :])
    out = jnp.einsum("bhk,hkd->bd", h_out.astype(x.dtype), params["wo"])
    return out[:, None, :], state
