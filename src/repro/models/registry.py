"""Architecture registry: ModelConfig -> Model, and the --arch lookup."""

from __future__ import annotations

from typing import Callable, Dict

from .common import ModelConfig
from .model import Model

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register_arch(arch_id: str, factory: Callable[[], ModelConfig]) -> None:
    if arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch id {arch_id!r}")
    _REGISTRY[arch_id] = factory


def arch_ids() -> list:
    _ensure_configs_loaded()
    return sorted(_REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    _ensure_configs_loaded()
    try:
        return _REGISTRY[arch_id]()
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {', '.join(sorted(_REGISTRY))}"
        )


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


def get_model(arch_id: str) -> Model:
    return build_model(get_config(arch_id))


def _ensure_configs_loaded() -> None:
    # configs register themselves on import
    import repro.configs  # noqa: F401
