"""Shared neural-net layers: RMSNorm, RoPE, gated MLPs, embeddings."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamSpec


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec(shape=(d,), axes=(None,), init="ones")


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm in fp32 accumulation (TPU-safe for bf16 activations)."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE.  x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                     # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                        # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, ParamSpec]:
    f = d_ff if d_ff is not None else cfg.d_ff
    d = cfg.d_model
    if cfg.act in ("swiglu", "geglu"):
        return {
            "wi": ParamSpec((d, f), ("embed", "mlp"), "scaled"),
            "wg": ParamSpec((d, f), ("embed", "mlp"), "scaled"),
            "wo": ParamSpec((f, d), ("mlp", "embed"), "scaled"),
        }
    return {
        "wi": ParamSpec((d, f), ("embed", "mlp"), "scaled"),
        "wo": ParamSpec((f, d), ("mlp", "embed"), "scaled"),
    }


def mlp_apply(params: Dict[str, jax.Array], x: jax.Array, act: str) -> jax.Array:
    if act == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * (x @ params["wi"])
    elif act == "geglu":
        h = jax.nn.gelu(x @ params["wg"]) * (x @ params["wi"])
    else:
        h = jax.nn.gelu(x @ params["wi"])
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def embed_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    specs: Dict[str, ParamSpec] = {
        "embedding": ParamSpec(
            (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), "normal", 1.0
        ),
        "final_norm": rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tied_embeddings:
        specs["unembed"] = ParamSpec(
            (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), "scaled"
        )
    return specs


def embed_tokens(params: Dict[str, jax.Array], tokens: jax.Array,
                 dtype: jnp.dtype) -> jax.Array:
    return params["embedding"].astype(dtype)[tokens]


def unembed(params: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    from ..sharding.planner import shard_hint

    # Pin the residual stream entering the unembed to batch-sharded layout.
    # Under FSDP rules GSPMD otherwise prefers to keep activations sharded on
    # the hidden dim over 'data' (avoiding per-layer weight gathers) and pays
    # a full-batch fp32 logits all-reduce here instead (§Perf pair B).
    if cfg.act_hints:
        x = shard_hint(x, ["batch"] + [None] * (x.ndim - 1))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tied_embeddings:
        logits = x @ params["embedding"].astype(x.dtype).T
    else:
        logits = x @ params["unembed"].astype(x.dtype)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    # Pin the logits layout (batch over data axes, vocab over model): without
    # this GSPMD may split the unembed contraction over 'data' and pay a
    # full-logits fp32 all-reduce (measured 67 GB/chip, §Perf pair B).
    # No-op outside a mesh context.
    from ..sharding.planner import shard_hint

    if not cfg.act_hints:
        return logits
    spec = ["batch"] + [None] * (logits.ndim - 2) + ["model"]
    return shard_hint(logits, spec)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       *, z_loss: float = 1e-4, sharded: bool = False) -> jax.Array:
    """Mean token-level cross entropy with an optional z-loss regularizer
    (stabilizes the logit scale on long runs; standard in production LMs).

    ``sharded=False`` — the straightforward formulation: cast the full logits
    to fp32 and gather the gold logit with ``take_along_axis``.  Under GSPMD
    with the vocab axis tensor-parallel this forces an all-gather of the fp32
    logits over the 'model' axis (and a matching scatter in the backward
    pass): ~4 bytes x tokens x vocab per chip — the dominant collective for
    big-vocab archs (§Perf pair B).

    ``sharded=True`` — GSPMD-friendly formulation: every reduction over the
    vocab axis is a proper reduce (max / sum-exp / one-hot dot), so the
    partitioner lowers them to (B, S)-sized all-reduces instead of gathering
    logits.  The one-hot product is fused into the reduction and its backward
    is a local scatter.  Numerically identical math (max-shifted logsumexp,
    fp32 accumulation).
    """
    if not sharded:
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    else:
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1))          # (B, S)
        shifted = logits - m[..., None].astype(logits.dtype)
        sumexp = jnp.sum(jnp.exp(shifted.astype(jnp.float32)), axis=-1)
        lse = m.astype(jnp.float32) + jnp.log(sumexp)
        onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.einsum(
            "...v,...v->...", logits, onehot,
            preferred_element_type=jnp.float32,
        )
    loss = (lse - gold).mean()
    if z_loss:
        loss = loss + z_loss * (lse ** 2).mean()
    return loss
