"""seamless-m4t-medium [audio]: enc-dec, 12L d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206.  [arXiv:2308.11596]

The speech frontend (mel-spectrogram + conv feature extractor) is STUBBED per
the brief: ``input_specs`` provides precomputed frame embeddings
(B, S, prefix_dim) consumed by a learned projection into the encoder.  The
transformer backbone (12 encoder + 12 decoder layers with cross-attention) is
fully implemented.  Train/prefill decoder length is seq_len / 4 (speech frames
outnumber text tokens).
"""

from ..models.common import ModelConfig
from ..models.registry import register_arch

ARCH_ID = "seamless-m4t-medium"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="audio",
        num_layers=12,             # decoder layers
        encoder_layers=12,
        cross_attention=True,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=256206,
        prefix_dim=1024,           # stubbed frame-embedding dim
        decoder_len_ratio=4,
        act="gelu",                # m4t uses standard transformer FFN
        rope_theta=1.0e4,
    )


register_arch(ARCH_ID, config)
