"""Input shapes and config plumbing for the assigned architecture pool.

The four assigned input shapes (see the reproduction brief):

  train_4k     seq_len=4,096    global_batch=256   train_step
  prefill_32k  seq_len=32,768   global_batch=32    prefill_step (inference)
  decode_32k   seq_len=32,768   global_batch=128   serve_step: ONE new token
                                                   against a KV cache of 32k
  long_500k    seq_len=524,288  global_batch=1     serve_step with 500k state;
                                                   requires sub-quadratic
                                                   attention (window / SSM)

``long_context_window``: dense/attention archs run long_500k with a
sliding-window variant of this size (the sub-quadratic option required by the
brief); SSM/hybrid archs carry O(1)/O(window) state natively.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

LONG_CONTEXT_WINDOW = 8192


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
