"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base / granite-3.0-3b-a800m family]
"""

from ..models.common import ModelConfig
from ..models.registry import register_arch

ARCH_ID = "granite-moe-3b-a800m"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="moe",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        head_dim=64,
        d_ff=512,                  # per-expert FFN hidden
        vocab_size=49155,
        num_experts=40,
        moe_top_k=8,
        rope_theta=1.0e4,
        tied_embeddings=True,      # granite MoE ties embeddings
    )


register_arch(ARCH_ID, config)
