"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16) d_ff=1408
vocab=102400, 64 routed experts top-6 + 2 shared experts, fine-grained;
first layer is a dense FFN (first_k_dense_replace=1).  [arXiv:2401.06066]
"""

from ..models.common import ModelConfig
from ..models.registry import register_arch

ARCH_ID = "deepseek-moe-16b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="moe",
        num_layers=28,             # 1 dense + 27 MoE
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,           # MHA
        head_dim=128,
        d_ff=1408,                 # per routed expert
        vocab_size=102400,
        num_experts=64,
        num_shared_experts=2,
        moe_top_k=6,
        first_dense_layers=1,
        dense_ff=10944,            # the dense layer's FFN (paper table 2)
        rope_theta=1.0e4,
    )


register_arch(ARCH_ID, config)
