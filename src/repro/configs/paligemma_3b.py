"""paligemma-3b [vlm]: 18L d_model=2048 8H (GQA kv=1, MQA) d_ff=16384
vocab=257216; SigLIP vision tower + gemma decoder.  [arXiv:2407.07726]

The SigLIP vision encoder is STUBBED per the brief: ``input_specs`` provides
precomputed patch embeddings (B, 256, 1152); the linear projector into the
gemma embedding space and the full language decoder are implemented.
PaliGemma uses prefix-LM attention: bidirectional over image+prompt prefix,
causal over the generated suffix.
"""

from ..models.common import ModelConfig
from ..models.registry import register_arch

ARCH_ID = "paligemma-3b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="vlm",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,            # multi-query attention
        head_dim=256,              # gemma head dim
        d_ff=16384,
        vocab_size=257216,
        prefix_tokens=256,         # 224x224 / 14x14 SigLIP patches
        prefix_dim=1152,           # SigLIP-So400m embedding width
        prefix_lm=True,
        act="geglu",               # gemma GeGLU
        tied_embeddings=True,      # gemma ties embeddings
        rope_theta=1.0e4,
    )


register_arch(ARCH_ID, config)
