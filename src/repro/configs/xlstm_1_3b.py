"""xlstm-1.3b [ssm]: 48L d_model=2048 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks (one sLSTM per 8 layers; the rest mLSTM with matrix memory).
[arXiv:2405.04517]

Attention-free: decode state is O(1) per layer (head_dim^2 matrix memory),
so long_500k runs natively.  The Compass serving ladder for this arch uses
chunk-size / quantization knobs — attention-window parameters do not exist
(see DESIGN.md §Arch-applicability).
"""

from ..models.common import ModelConfig
from ..models.registry import register_arch

ARCH_ID = "xlstm-1.3b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=4,
        num_kv_heads=4,
        head_dim=512,
        d_ff=0,                    # xLSTM blocks have no separate FFN
        vocab_size=50304,
        slstm_every=8,
        rope_theta=1.0e4,          # unused (no attention) but kept for API
    )


register_arch(ARCH_ID, config)
