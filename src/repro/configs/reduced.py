"""Reduced variants of every assigned architecture for CPU smoke tests.

Per the brief: same family, 2 layers, d_model <= 512, <= 4 experts.  The
reduction preserves every structural feature that matters for coverage
(GQA ratio, MoE routing with shared experts, SSM state, sLSTM interleave,
enc-dec cross-attention, VLM prefix) while shrinking the compute so a full
forward/train step runs in seconds on one CPU device.
"""

from __future__ import annotations

import dataclasses

from ..models.common import ModelConfig
from ..models.registry import get_config


def reduced_config(arch_id: str, *, layers: int = 2) -> ModelConfig:
    """A tiny, same-family variant of ``arch_id``."""
    cfg = get_config(arch_id)
    kv_ratio = cfg.num_heads // cfg.num_kv_heads
    heads = 4
    # keep the GQA ratio where possible (cap kv>=1)
    kv = max(1, heads // min(kv_ratio, heads))
    over: dict = dict(
        num_layers=layers,
        d_model=256,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=64,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
    )
    if cfg.num_experts:
        over.update(
            num_experts=4,
            moe_top_k=min(2, cfg.moe_top_k),
            num_shared_experts=min(1, cfg.num_shared_experts),
            first_dense_layers=min(1, cfg.first_dense_layers),
            dense_ff=512 if cfg.dense_ff else 0,
        )
    if cfg.ssm_state:
        over.update(ssm_state=8)
    if cfg.slstm_every:
        over.update(slstm_every=2)
    if cfg.encoder_layers:
        over.update(encoder_layers=layers)
    if cfg.prefix_tokens:
        over.update(prefix_tokens=8, prefix_dim=64)
    elif cfg.prefix_dim:     # audio frames (no fixed token count)
        over.update(prefix_dim=64)
    if cfg.sliding_window:
        over.update(sliding_window=16)
    return dataclasses.replace(cfg, **over)
