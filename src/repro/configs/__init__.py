"""Assigned-architecture configs.  Importing this package registers every
architecture with :mod:`repro.models.registry` (``--arch <id>`` lookup).

Pool (10 archs, 6 families):
  granite-moe-3b-a800m  deepseek-moe-16b  seamless-m4t-medium  paligemma-3b
  hymba-1.5b  stablelm-3b  internlm2-1.8b  llama3-405b  xlstm-1.3b
  minitron-4b
"""

from .base import INPUT_SHAPES, LONG_CONTEXT_WINDOW, InputShape

# importing registers each arch
from . import granite_moe_3b_a800m  # noqa: F401
from . import deepseek_moe_16b  # noqa: F401
from . import seamless_m4t_medium  # noqa: F401
from . import paligemma_3b  # noqa: F401
from . import hymba_1_5b  # noqa: F401
from . import stablelm_3b  # noqa: F401
from . import internlm2_1_8b  # noqa: F401
from . import llama3_405b  # noqa: F401
from . import xlstm_1_3b  # noqa: F401
from . import minitron_4b  # noqa: F401

__all__ = ["INPUT_SHAPES", "LONG_CONTEXT_WINDOW", "InputShape"]
