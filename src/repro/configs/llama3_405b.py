"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256.  [arXiv:2407.21783]

The capacity-bound architecture of the pool: fp32 params + Adam state exceed
a single 256-chip v5e pod's HBM (see EXPERIMENTS.md §Dry-run), so training
defaults to full activation remat and relies on 2-pod FSDP; this is also the
arch where Compass-style configuration switching matters most in serving
(largest service-time spread across its serving ladder).
"""

from ..models.common import ModelConfig
from ..models.registry import register_arch

ARCH_ID = "llama3-405b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        num_layers=126,
        d_model=16384,
        num_heads=128,
        num_kv_heads=8,
        head_dim=128,
        d_ff=53248,
        vocab_size=128256,
        rope_theta=5.0e5,
        remat="full",
    )


register_arch(ARCH_ID, config)
