"""internlm2-1.8b [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544.  [arXiv:2403.17297]
"""

from ..models.common import ModelConfig
from ..models.registry import register_arch

ARCH_ID = "internlm2-1.8b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92544,
        rope_theta=1.0e6,          # internlm2 uses a large rope base
    )


register_arch(ARCH_ID, config)
