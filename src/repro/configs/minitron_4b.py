"""minitron-4b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — pruned nemotron.  [arXiv:2407.14679]
"""

from ..models.common import ModelConfig
from ..models.registry import register_arch

ARCH_ID = "minitron-4b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9216,
        vocab_size=256000,
        act="gelu",                # nemotron uses squared-relu/gelu MLp
        rope_theta=1.0e4,
    )


register_arch(ARCH_ID, config)
