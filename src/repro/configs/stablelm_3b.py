"""stablelm-3b [dense]: 32L d_model=2560 32H (kv=32, MHA) d_ff=6912
vocab=50304.  [hf:stabilityai/stablelm-2-1_6b family / stablelm-3b-4e1t]
"""

from ..models.common import ModelConfig
from ..models.registry import register_arch

ARCH_ID = "stablelm-3b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="dense",
        num_layers=32,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=6912,
        vocab_size=50304,
        rope_theta=1.0e4,
    )


register_arch(ARCH_ID, config)
