"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + Mamba heads in every layer.
[arXiv:2411.13676]

Note: 25 heads is NOT divisible by a 16-way tensor-parallel axis; the
sharding planner replicates the attention head dim for this arch (divisibility
fallback) while still sharding d_ff (5504 = 16 x 344) and the SSM inner dim.
"""

from ..models.common import ModelConfig
from ..models.registry import register_arch

ARCH_ID = "hymba-1.5b"


def config() -> ModelConfig:
    return ModelConfig(
        arch_id=ARCH_ID,
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        ssm_state=16,
        ssm_expand=2,
        rope_theta=1.0e4,
    )


register_arch(ARCH_ID, config)
