"""Synthetic data pipeline: deterministic token streams with learnable
structure, batching, and host-side sharding.

The training substrate needs data a model can actually learn (loss must go
down for the train-100M example), so the stream is a mixture of:

  - order-k Markov chains over the vocab (local structure),
  - copy spans ("needle" patterns: a marker token, a payload, and a later
    re-quote of the payload) — the same pattern the RAG workflow's tiny
    generators are trained on,
  - uniform noise for regularization.

Everything is generated on the fly from a counter-based RNG: no files, fully
reproducible, infinite.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    markov_order: int = 2
    copy_fraction: float = 0.3     # fraction of sequences with copy spans
    noise_fraction: float = 0.05
    seed: int = 0


class SyntheticLM:
    """Deterministic synthetic language-model stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # sparse-ish Markov transition table: each context maps to a small
        # set of likely successors (keeps the task learnable by tiny models)
        self._n_contexts = min(4096, v * 4)
        self._succ = rng.integers(0, v, size=(self._n_contexts, 4))
        self._marker = 1  # token id used as the copy marker

    def _context_id(self, a: int, b: int) -> int:
        return (a * 31 + b * 7) % self._n_contexts

    def sample_sequence(self, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        v, s = cfg.vocab_size, cfg.seq_len
        out = np.empty(s + 1, dtype=np.int64)
        out[0] = rng.integers(2, v)
        out[1] = rng.integers(2, v)
        for t in range(2, s + 1):
            if rng.random() < cfg.noise_fraction:
                out[t] = rng.integers(2, v)
            else:
                ctx = self._context_id(int(out[t - 2]), int(out[t - 1]))
                out[t] = self._succ[ctx, rng.integers(0, 4)]
        if rng.random() < cfg.copy_fraction and s >= 32:
            # plant a copy task: marker payload ... marker payload
            span = int(rng.integers(4, 9))
            start = int(rng.integers(2, s // 2 - span - 1))
            payload = rng.integers(2, v, size=span)
            out[start] = self._marker
            out[start + 1 : start + 1 + span] = payload
            echo = int(rng.integers(s // 2, s - span - 1))
            out[echo] = self._marker
            out[echo + 1 : echo + 1 + span] = payload
        return out

    def batches(self, *, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        """Yield {"tokens": (B, S), "labels": (B, S)} batches, deterministic
        per step index (resume-safe: checkpoint stores only the step)."""
        cfg = self.cfg
        step = start_step
        while True:
            rng = np.random.default_rng((cfg.seed, step))
            seqs = np.stack(
                [self.sample_sequence(rng) for _ in range(cfg.global_batch)]
            )
            yield {
                "tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32),
            }
            step += 1


def stub_frontend_batch(
    kind: str, batch: int, seq: int, dim: int, *, seed: int = 0
) -> np.ndarray:
    """Precomputed frame/patch embeddings for the stubbed modality frontends
    (the one permitted stub: we implement the language backbone, not the
    ViT / conv codec)."""
    rng = np.random.default_rng((hash(kind) & 0xFFFF, seed))
    return rng.standard_normal((batch, seq, dim)).astype(np.float32)
