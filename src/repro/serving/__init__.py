"""Inference serving runtime: scheduler core, monitor, worker pool, engine,
simulator.

One scheduling core, two drivers
--------------------------------

Every dispatch decision — admission control, FIFO order, batch draining
with linger, per-worker assignment, work stealing, the Elastico switch
hook — is made in exactly one place:
:class:`repro.serving.scheduler.Scheduler`, a pure state machine over an
injected clock.  Two thin drivers execute its decisions:

- :class:`ServingSimulator` (``num_servers``) — deterministic discrete-event
  M/G/c under virtual time: it owns the event heap and the service-time
  RNG, feeds arrival/completion/linger/tick events to the scheduler, and
  turns each :class:`~repro.serving.scheduler.Dispatch` into a sampled
  service time; per-server utilization is reported in
  :class:`SimulationResult`.
- :class:`WorkerPool` (``c``) / :class:`ServingEngine` (``num_workers``) —
  the real-time path: c worker threads execute the scheduler's dispatches
  through one thread-safe :class:`WorkflowExecutor`, with all scheduler
  access serialized behind the pool's lock.

With a homogeneous controller the Elastico switch flips the executor's
default configuration for every worker at once; with an
:class:`~repro.core.elastico.ElasticoMixController` the scheduler carries
a *per-worker assignment vector* and each switch repins exactly one
worker, blending accuracy and latency across the pool.
``max_queue_depth`` adds admission control (bounded buffer with drop
accounting in ``EngineReport.dropped`` / ``SimulationResult.dropped``),
and ``admission_reroute=True`` upgrades it to *mix-aware admission*: the
scheduler forces the fastest rung before rejecting.  The switching
thresholds come from :func:`repro.core.aqm.derive_policies`
(``num_servers=c``), which scales the paper's Eq. 10/13 by the pool's
aggregate drain rate c / s-bar; heterogeneous mixes use
:func:`repro.core.aqm.derive_mix_policies`, whose Allen-Cunneen M/G/c wait
model folds in the service-time SCV measured by the profiler and which
also emits the steal/re-route thresholds the scheduler consumes.

In-worker batching (``max_batch_size``, ``batch_timeout_s`` on both
:class:`ServingEngine`/:class:`WorkerPool` and :class:`ServingSimulator`)
lets each dispatch carry up to B requests — the scheduler lingers a short
batch up to the batch timeout for arrivals to fill it — executed as one
batch (:meth:`WorkflowExecutor.execute_batch`), amortizing per-dispatch
overhead by the measured ``alpha + beta * b`` law
(:class:`repro.core.pareto.BatchProfile`); thresholds derived with
``max_batch_size > 1`` account for the depth-dependent drain rate
(:func:`repro.core.aqm.batch_expected_wait`).

Work stealing (``queue_discipline="per_worker"``, ``steal=True``) routes
arrivals round-robin to per-worker backlogs and lets idle workers pull
from the globally deepest backlog (:func:`repro.core.aqm.steal_threshold`),
always serving stolen work under their own pinned configuration.

``c = 1`` is the paper-faithful default throughout and reproduces the
original single-server (M/G/1) behavior exactly — same seeds, same results;
an all-same-config assignment vector likewise reproduces the homogeneous
pool bit-for-bit, and ``max_batch_size = 1`` the unbatched runtime.
Elastico always observes the *buffered* queue depth (requests waiting for
dispatch, excluding those in service), the depth the thresholds are stated
in.

Fast path (:mod:`repro.serving.fastsim`): static shared-FIFO scenarios can
skip the event heap entirely — :func:`simulate` routes eligible cases to a
vectorized Lindley / Kiefer-Wolfowitz recursion (bit-for-bit identical at
c = 1), and :func:`simulate_batch` sweeps R replications x K configs x L
loads as one set of numpy array ops for Planner validation and the
benchmark suite.  The event-heap :class:`ServingSimulator` remains the
exact oracle every fast-path result is tested against.
"""

from .engine import EngineReport, ServingEngine, replay_workload
from .executor import ExecutionRecord, WorkerPool, WorkflowExecutor
from .fastsim import (
    FastSimulationResult,
    SweepResult,
    fast_path_eligible,
    simulate,
    simulate_batch,
)
from .monitor import LoadMonitor, LoadSnapshot
from .traces import (
    ChunkedMMPPTrace,
    ChunkedPoissonTrace,
    ReplayStats,
    StreamingQuantile,
    bursty_mmpp_trace,
    diurnal_trace,
    flash_crowd_trace,
    replay_mix,
    replay_trace,
)
from .scheduler import AdmissionDecision, Dispatch, Linger, Scheduler
from .simulator import (
    CompletedRequest,
    ServingSimulator,
    SimulationResult,
    deterministic_sampler,
    exponential_sampler,
    lognormal_sampler_from_profile,
)
from .workload import (
    Request,
    bursty_pattern,
    constant_rate,
    diurnal_pattern,
    flash_crowd_pattern,
    generate_arrivals,
    spike_pattern,
    sustained_overload_pattern,
)

__all__ = [
    "EngineReport",
    "ServingEngine",
    "replay_workload",
    "ExecutionRecord",
    "WorkerPool",
    "WorkflowExecutor",
    "FastSimulationResult",
    "SweepResult",
    "fast_path_eligible",
    "simulate",
    "simulate_batch",
    "LoadMonitor",
    "LoadSnapshot",
    "ChunkedMMPPTrace",
    "ChunkedPoissonTrace",
    "ReplayStats",
    "StreamingQuantile",
    "bursty_mmpp_trace",
    "diurnal_trace",
    "flash_crowd_trace",
    "replay_mix",
    "replay_trace",
    "AdmissionDecision",
    "Dispatch",
    "Linger",
    "Scheduler",
    "CompletedRequest",
    "ServingSimulator",
    "SimulationResult",
    "deterministic_sampler",
    "exponential_sampler",
    "lognormal_sampler_from_profile",
    "Request",
    "bursty_pattern",
    "constant_rate",
    "diurnal_pattern",
    "flash_crowd_pattern",
    "generate_arrivals",
    "spike_pattern",
    "sustained_overload_pattern",
]
