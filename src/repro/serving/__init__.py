"""Inference serving runtime: queue, monitor, executor, engine, simulator."""

from .engine import EngineReport, ServingEngine, replay_workload
from .executor import ExecutionRecord, WorkflowExecutor
from .monitor import LoadMonitor, LoadSnapshot
from .queue import RequestQueue
from .simulator import (
    CompletedRequest,
    ServingSimulator,
    SimulationResult,
    deterministic_sampler,
    lognormal_sampler_from_profile,
)
from .workload import (
    Request,
    bursty_pattern,
    constant_rate,
    diurnal_pattern,
    generate_arrivals,
    spike_pattern,
)

__all__ = [
    "EngineReport",
    "ServingEngine",
    "replay_workload",
    "ExecutionRecord",
    "WorkflowExecutor",
    "LoadMonitor",
    "LoadSnapshot",
    "RequestQueue",
    "CompletedRequest",
    "ServingSimulator",
    "SimulationResult",
    "deterministic_sampler",
    "lognormal_sampler_from_profile",
    "Request",
    "bursty_pattern",
    "constant_rate",
    "diurnal_pattern",
    "generate_arrivals",
    "spike_pattern",
]
