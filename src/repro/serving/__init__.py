"""Inference serving runtime: queue, monitor, worker pool, engine, simulator.

Worker-pool architecture (M/G/c)
--------------------------------

Every layer of the runtime is parameterized by a server count ``c >= 1``:

- :class:`ServingSimulator` (``num_servers``) — deterministic discrete-event
  M/G/c: a bank of c server slots drains one FIFO queue, dispatching to the
  lowest-numbered free server; per-server utilization is reported in
  :class:`SimulationResult`.
- :class:`WorkerPool` (``c``) / :class:`ServingEngine` (``num_workers``) —
  the real-time path: c worker threads drain one shared
  :class:`RequestQueue`, all executing through one thread-safe
  :class:`WorkflowExecutor`.  With a homogeneous controller the Elastico
  switch flips the executor's default configuration for every worker at
  once; with an :class:`~repro.core.elastico.ElasticoMixController` the
  pool instead carries a *per-worker assignment vector*
  (``WorkerPool.set_assignment``) and each switch repins exactly one
  worker, blending accuracy and latency across the pool.
  ``max_queue_depth`` adds admission control (bounded buffer with drop
  accounting in ``EngineReport.dropped``).
- The switching thresholds come from
  :func:`repro.core.aqm.derive_policies` (``num_servers=c``), which scales
  the paper's Eq. 10/13 by the pool's aggregate drain rate c / s-bar;
  heterogeneous mixes use :func:`repro.core.aqm.derive_mix_policies`, whose
  Allen-Cunneen M/G/c wait model folds in the service-time SCV measured by
  the profiler.

In-worker batching (``max_batch_size``, ``batch_timeout_s`` on both
:class:`ServingEngine`/:class:`WorkerPool` and :class:`ServingSimulator`)
lets each worker drain up to B requests per dequeue — lingering up to the
batch timeout for a short batch to fill — and execute them as one batch
(:meth:`WorkflowExecutor.execute_batch`), amortizing per-dispatch overhead
by the measured ``alpha + beta * b`` law
(:class:`repro.core.pareto.BatchProfile`); thresholds derived with
``max_batch_size > 1`` account for the depth-dependent drain rate
(:func:`repro.core.aqm.batch_expected_wait`).

``c = 1`` is the paper-faithful default throughout and reproduces the
original single-server (M/G/1) behavior exactly — same seeds, same results;
an all-same-config assignment vector likewise reproduces the homogeneous
pool bit-for-bit, and ``max_batch_size = 1`` the unbatched runtime.
Elastico always observes the *buffered* queue depth (waiting requests,
excluding the up-to-c in service), the depth the thresholds are stated in.
"""

from .engine import EngineReport, ServingEngine, replay_workload
from .executor import ExecutionRecord, WorkerPool, WorkflowExecutor
from .monitor import LoadMonitor, LoadSnapshot
from .queue import RequestQueue
from .simulator import (
    CompletedRequest,
    ServingSimulator,
    SimulationResult,
    deterministic_sampler,
    exponential_sampler,
    lognormal_sampler_from_profile,
)
from .workload import (
    Request,
    bursty_pattern,
    constant_rate,
    diurnal_pattern,
    flash_crowd_pattern,
    generate_arrivals,
    spike_pattern,
    sustained_overload_pattern,
)

__all__ = [
    "EngineReport",
    "ServingEngine",
    "replay_workload",
    "ExecutionRecord",
    "WorkerPool",
    "WorkflowExecutor",
    "LoadMonitor",
    "LoadSnapshot",
    "RequestQueue",
    "CompletedRequest",
    "ServingSimulator",
    "SimulationResult",
    "deterministic_sampler",
    "exponential_sampler",
    "lognormal_sampler_from_profile",
    "Request",
    "bursty_pattern",
    "constant_rate",
    "diurnal_pattern",
    "flash_crowd_pattern",
    "generate_arrivals",
    "spike_pattern",
    "sustained_overload_pattern",
]
