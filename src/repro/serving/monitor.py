"""Load monitor: queue depth + arrival-rate tracking (paper §III-B).

Elastico's decisions key off the *buffered* queue depth (requests waiting
for service, excluding the up-to-c in service across the worker pool); the
engine passes that depth, the pool-wide in-flight count, and — when
in-worker batching is enabled — the pool's realized mean batch size to
``snapshot`` under its observe lock, so snapshots are consistent even with
many workers observing concurrently.  The arrival-rate EWMA is exposed for observability
and for the predictive-adaptation extension point mentioned in the paper's
future work; ``record_drop`` tracks admission-control rejections.
"""

from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple


@dataclass
class LoadSnapshot:
    """One control-loop observation.  ``assignment`` is the per-worker config
    pinning in effect when the snapshot was taken (None for homogeneous
    pools) — it lets post-hoc analysis correlate queue depth with the mix
    the heterogeneous controller had deployed.  ``batch_size`` is the
    pool's realized mean batch size (requests per worker dispatch) up to
    the snapshot — None when the runtime doesn't batch, 1.0 when batching
    is enabled but batches never form, rising toward ``max_batch_size``
    as backlog lets workers fill their batches."""

    time_s: float
    queue_depth: int
    arrival_rate_qps: float
    in_flight: int
    assignment: Optional[Tuple[int, ...]] = None
    batch_size: Optional[float] = None


class LoadMonitor:
    """Tracks arrivals with an exponentially-weighted rate estimate.

    ``record_arrival`` is called by the engine's ingress; ``snapshot`` is
    called by the controller loop.  ``halflife_s`` controls the EWMA memory.
    """

    def __init__(self, *, halflife_s: float = 5.0,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self._clock = clock
        self._halflife_s = halflife_s
        self._lock = threading.Lock()
        self._rate_qps = 0.0
        self._last_update_s: Optional[float] = None
        self._arrivals = 0
        self._drops = 0
        self._history: List[LoadSnapshot] = []

    def set_clock(self, clock: Callable[[], float]) -> None:
        """Align the monitor with the engine's epoch-relative clock.

        ``record_arrival`` (ingress) and ``snapshot`` (control loop) must
        stamp times on the same axis or the EWMA's decay term sees a huge
        negative dt, clamps to zero, and the arrival rate never decays.
        The engine calls this at ``start()`` with its relative clock."""
        with self._lock:
            self._clock = clock

    def record_arrival(self, now_s: Optional[float] = None) -> None:
        now = self._clock() if now_s is None else now_s
        with self._lock:
            if self._last_update_s is None:
                self._rate_qps = 0.0
            else:
                dt = max(1e-9, now - self._last_update_s)
                decay = 0.5 ** (dt / self._halflife_s)
                # event-driven EWMA of instantaneous rate 1/dt
                self._rate_qps = decay * self._rate_qps + (1.0 - decay) * (1.0 / dt)
            self._last_update_s = now
            self._arrivals += 1

    def arrival_rate(self, now_s: Optional[float] = None) -> float:
        now = self._clock() if now_s is None else now_s
        with self._lock:
            if self._last_update_s is None:
                return 0.0
            dt = max(0.0, now - self._last_update_s)
            decay = 0.5 ** (dt / self._halflife_s)
            return self._rate_qps * decay

    def record_drop(self) -> None:
        """Count an admission-control rejection (bounded queue full)."""
        with self._lock:
            self._drops += 1

    @property
    def total_arrivals(self) -> int:
        with self._lock:
            return self._arrivals

    @property
    def total_drops(self) -> int:
        with self._lock:
            return self._drops

    def snapshot(self, queue_depth: int, in_flight: int,
                 now_s: Optional[float] = None,
                 assignment: Optional[Tuple[int, ...]] = None,
                 batch_size: Optional[float] = None) -> LoadSnapshot:
        now = self._clock() if now_s is None else now_s
        snap = LoadSnapshot(
            time_s=now,
            queue_depth=queue_depth,
            arrival_rate_qps=self.arrival_rate(now),
            in_flight=in_flight,
            assignment=assignment,
            batch_size=batch_size,
        )
        with self._lock:
            self._history.append(snap)
        return snap

    def history(self) -> List[LoadSnapshot]:
        with self._lock:
            return list(self._history)
