"""Real-time serving engine: queue + monitor + Elastico + worker pool (§III-B).

The engine wires the runtime components of the paper's serving architecture
and runs them against wall-clock time on this host:

  ingress thread  ->  RequestQueue  ->  WorkerPool (c x WorkflowExecutor)
                          |                   |
                      LoadMonitor  <----------+
                          |
                  control thread (Elastico) -> executor.set_active (homogeneous)
                                            -> pool.set_assignment (mix)

``num_workers=1`` (the default) is the paper-faithful M/G/1 server; larger
pools drain the same shared queue concurrently (M/G/c) with the switching
thresholds derived for that c (pass ``num_servers`` to ``derive_policies``).
The controller may be either flavor: a homogeneous
:class:`~repro.core.elastico.ElasticoController`, whose decisions flip the
executor's default active index for all workers at once, or a heterogeneous
:class:`~repro.core.elastico.ElasticoMixController`, whose decisions repin
the pool's per-worker assignment vector one worker at a time
(``pool.set_assignment``); ``EngineReport.assignment_timeline`` records the
mix trajectory.  Controller decisions are serialized behind a lock so
concurrent workers never interleave observations, and every decision keys
off the *buffered* queue depth — requests waiting for service, excluding
the up-to-c in flight.

``max_queue_depth`` enables admission control (beyond-paper): arrivals that
find the buffer full are rejected at ingress and surface in
``EngineReport.dropped`` (see that field's documentation for exact
semantics).

``max_batch_size``/``batch_timeout_s`` enable in-worker batching
(beyond-paper): each worker drains up to ``max_batch_size`` requests per
dequeue — lingering up to ``batch_timeout_s`` for a short batch to fill —
and executes the run as one batch (see
:meth:`repro.serving.executor.WorkflowExecutor.execute_batch`).  The drain
logic accounts for batches a lingering worker has claimed but not yet
executed (``WorkerPool.pending``), and ``EngineReport.mean_batch_size``
reports the realized amortization.  ``max_batch_size=1`` (default) takes
the exact pre-batching code path.

A deterministic-virtual-time variant is provided by
:mod:`repro.serving.simulator`; this module is the "it actually serves"
path used by the examples and smoke tests.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence

from ..core.elastico import ElasticoController, ElasticoMixController
from .executor import ExecutionRecord, WorkerPool, WorkflowExecutor
from .monitor import LoadMonitor
from .queue import RequestQueue
from .workload import Request


@dataclass
class EngineReport:
    """Serving run summary.

    ``dropped`` counts admission-control rejections: arrivals that found the
    bounded buffer (``max_queue_depth``) full and were rejected at ingress —
    they never enqueued, never executed, and have no
    :class:`~repro.serving.executor.ExecutionRecord`.  Invariants:
    ``total_requests == len(records) + dropped`` after a clean
    ``drain_and_stop``, and ``dropped == 0`` whenever the queue is unbounded
    (the paper's no-drop default — configuration switches never drop
    requests, §III-B).  ``slo_compliance`` ignores drops (fraction of
    *served* requests in SLO); ``goodput`` charges them (fraction of
    *offered* load served in SLO).

    ``assignment_timeline`` records ``(time_s, assignment_vector)`` repin
    events when a mix controller drives a heterogeneous pool; empty for
    homogeneous runs, whose ``config_timeline`` records the global switches.
    """

    records: List[ExecutionRecord]
    switch_events: List
    config_timeline: List
    total_requests: int
    dropped: int = 0
    num_workers: int = 1
    served_per_worker: List[int] = field(default_factory=list)
    assignment_timeline: List = field(default_factory=list)
    # realized requests-per-dispatch across the pool; 1.0 for unbatched runs
    mean_batch_size: float = 1.0
    max_batch_size: int = 1

    def slo_compliance(self, slo_s: float) -> float:
        if not self.records:
            return 1.0
        return sum(1 for r in self.records if r.latency_s <= slo_s) / len(self.records)

    def goodput(self, slo_s: float) -> float:
        """Fraction of *offered* load served within the SLO — unlike
        ``slo_compliance`` this charges dropped requests against the engine."""
        if self.total_requests == 0:
            return 1.0
        ok = sum(1 for r in self.records if r.latency_s <= slo_s)
        return ok / self.total_requests

    def mean_accuracy(self, accuracies: Sequence[float]) -> float:
        if not self.records:
            return 0.0
        return sum(accuracies[r.config_index] for r in self.records) / len(self.records)


class ServingEngine:
    """Threaded serving engine with dynamic configuration switching.

    ``num_workers`` sizes the worker pool (c of the M/G/c model);
    ``max_queue_depth`` bounds the shared buffer for admission control
    (None = unbounded, the paper's no-drop default); ``max_batch_size`` /
    ``batch_timeout_s`` enable in-worker batching (1 / 0.0 = unbatched,
    the paper-faithful default).  ``controller`` may be
    a homogeneous :class:`ElasticoController` (switches the global default
    config) or an :class:`ElasticoMixController` (repins the per-worker
    assignment vector one worker at a time); pass None for a static run,
    optionally with a fixed heterogeneous pinning via ``assignment``.
    """

    def __init__(
        self,
        executor: WorkflowExecutor,
        controller: Optional[ElasticoController] = None,
        *,
        num_workers: int = 1,
        max_queue_depth: Optional[int] = None,
        control_tick_s: float = 0.05,
        clock: Callable[[], float] = time.monotonic,
        assignment: Optional[Sequence[int]] = None,
        max_batch_size: int = 1,
        batch_timeout_s: float = 0.0,
    ) -> None:
        if num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        if assignment is not None and controller is not None:
            # reject silently-dead configurations: pinned workers never
            # consult the default active index a homogeneous controller
            # switches, and a mix controller repins the pool from its own
            # ladder at start() anyway.
            raise ValueError(
                "assignment is for static runs (controller=None); use "
                "ElasticoMixController for dynamic per-worker pinning")
        self.queue = RequestQueue(max_depth=max_queue_depth)
        self.monitor = LoadMonitor(clock=clock)
        self.executor = executor
        self.controller = controller
        self.pool = WorkerPool(
            executor, self.queue, c=num_workers, on_observe=self._observe,
            assignment=assignment,
            max_batch_size=max_batch_size, batch_timeout_s=batch_timeout_s,
        )
        self.control_tick_s = control_tick_s
        self._clock = clock
        self._stop = threading.Event()
        self._ctrl_thread: Optional[threading.Thread] = None
        self._timeline: List = []
        self._assignment_timeline: List = []
        self._epoch: Optional[float] = None
        # one lock serializes controller observations from all workers + the
        # control loop: ElasticoController is pure decision logic and relies
        # on the caller for thread safety.
        self._observe_lock = threading.Lock()
        self._submitted = 0
        self._dropped = 0
        self._ingress_lock = threading.Lock()

    @property
    def num_workers(self) -> int:
        return self.pool.c

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        if self._ctrl_thread is not None:
            raise RuntimeError("engine already started")
        self._epoch = self._clock()
        self.executor.set_clock(self._now_rel)
        self.monitor.set_clock(self._now_rel)  # one time axis for all stamps
        if self.controller is not None:
            self.controller.reset()
            if isinstance(self.controller, ElasticoMixController):
                vec = self.controller.current_assignment
                self.pool.set_assignment(vec)
                self._assignment_timeline.append((0.0, vec))
            else:
                self.executor.set_active(self.controller.current_index)
            self._timeline.append((0.0, self.controller.current_index))
        elif self.pool.assignment() is not None:
            self._assignment_timeline.append((0.0, self.pool.assignment()))
        self.pool.start()
        self._ctrl_thread = threading.Thread(
            target=self._control_loop, name="compass-elastico", daemon=True
        )
        self._ctrl_thread.start()

    def submit(self, request: Request) -> bool:
        """Offer a request to the engine; returns False if admission control
        rejected it (bounded queue full)."""
        self.monitor.record_arrival()
        accepted = self.queue.put(request)
        with self._ingress_lock:
            self._submitted += 1
            if not accepted:
                self._dropped += 1
        if not accepted:
            self.monitor.record_drop()
        return accepted

    def drain_and_stop(self, *, timeout_s: float = 120.0) -> EngineReport:
        """Close ingress, wait until the queue empties, stop threads.

        The drain condition uses ``queue.buffered()`` (waiting + claimed by
        a lingering forming batch) plus ``pool.pending()`` (a dequeued batch
        not yet executing), so a worker mid-linger cannot race the shutdown
        into dropping its partial batch."""
        deadline = self._clock() + timeout_s
        while (self.queue.buffered() > 0 or self.executor.in_flight() > 0
               or self.pool.pending() > 0) and self._clock() < deadline:
            time.sleep(0.01)
        self.queue.close()
        self._stop.set()
        self.pool.stop()
        if self._ctrl_thread is not None:
            self._ctrl_thread.join(timeout=5.0)
            self._ctrl_thread = None
        with self._ingress_lock:
            submitted, dropped = self._submitted, self._dropped
        return EngineReport(
            records=list(self.executor.records),
            switch_events=list(self.controller.events) if self.controller else [],
            config_timeline=list(self._timeline),
            total_requests=submitted,
            dropped=dropped,
            num_workers=self.pool.c,
            served_per_worker=self.pool.served_per_worker(),
            assignment_timeline=list(self._assignment_timeline),
            mean_batch_size=self.pool.mean_batch_size(),
            max_batch_size=self.pool.max_batch_size,
        )

    # -- loops ---------------------------------------------------------------

    def _now_rel(self) -> float:
        assert self._epoch is not None
        return self._clock() - self._epoch

    def _control_loop(self) -> None:
        while not self._stop.is_set():
            self._observe()
            time.sleep(self.control_tick_s)

    def _observe(self) -> None:
        if self.controller is None:
            return
        with self._observe_lock:
            # buffered requests only (see simulator): waiting in the queue
            # plus any lingering worker's forming batch — the simulator keeps
            # forming batches in its waiting list, so both runtimes show the
            # controller the same depth for the same state.
            depth = self.queue.buffered()
            now = self._now_rel()
            batch = (self.pool.mean_batch_size()
                     if self.pool.max_batch_size > 1 else None)
            self.monitor.snapshot(depth, self.executor.in_flight(), now,
                                  assignment=self.pool.assignment(),
                                  batch_size=batch)
            ev = self.controller.observe(depth, now)
            if ev is not None:
                if isinstance(self.controller, ElasticoMixController):
                    vec = self.controller.assignment_for(ev.to_index)
                    self.pool.set_assignment(vec)
                    self._assignment_timeline.append((now, vec))
                else:
                    self.executor.set_active(ev.to_index)
                self._timeline.append((now, ev.to_index))


def replay_workload(
    engine: ServingEngine,
    arrivals: Sequence[float],
    *,
    payload_fn: Optional[Callable[[int], Any]] = None,
    time_scale: float = 1.0,
) -> None:
    """Feed a precomputed arrival trace into a started engine in real time
    (optionally time-scaled for faster tests)."""
    t0 = time.monotonic()
    for i, at in enumerate(arrivals):
        target = t0 + at * time_scale
        delay = target - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        payload = payload_fn(i) if payload_fn is not None else None
        engine.submit(Request(request_id=i, arrival_s=engine._now_rel(), payload=payload))
