"""Real-time serving engine: queue + monitor + Elastico + executor (§III-B).

The engine wires the four runtime components of the paper's serving
architecture and runs them against wall-clock time on this host:

  ingress thread  ->  RequestQueue  ->  worker thread (WorkflowExecutor)
                          |                   |
                      LoadMonitor  <----------+
                          |
                  control thread (ElasticoController) -> executor.set_active

A deterministic-virtual-time variant is provided by
:mod:`repro.serving.simulator`; this module is the "it actually serves"
path used by the examples and smoke tests.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence

from ..core.elastico import ElasticoController
from .executor import ExecutionRecord, WorkflowExecutor
from .monitor import LoadMonitor
from .queue import RequestQueue
from .workload import Request


@dataclass
class EngineReport:
    records: List[ExecutionRecord]
    switch_events: List
    config_timeline: List
    total_requests: int
    dropped: int = 0

    def slo_compliance(self, slo_s: float) -> float:
        if not self.records:
            return 1.0
        return sum(1 for r in self.records if r.latency_s <= slo_s) / len(self.records)

    def mean_accuracy(self, accuracies: Sequence[float]) -> float:
        if not self.records:
            return 0.0
        return sum(accuracies[r.config_index] for r in self.records) / len(self.records)


class ServingEngine:
    """Threaded serving engine with dynamic configuration switching."""

    def __init__(
        self,
        executor: WorkflowExecutor,
        controller: Optional[ElasticoController] = None,
        *,
        control_tick_s: float = 0.05,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        self.queue = RequestQueue()
        self.monitor = LoadMonitor(clock=clock)
        self.executor = executor
        self.controller = controller
        self.control_tick_s = control_tick_s
        self._clock = clock
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._timeline: List = []
        self._epoch: Optional[float] = None

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        if self._threads:
            raise RuntimeError("engine already started")
        self._epoch = self._clock()
        self.executor.set_clock(self._now_rel)
        if self.controller is not None:
            self.controller.reset()
            self.executor.set_active(self.controller.current_index)
            self._timeline.append((0.0, self.controller.current_index))
        worker = threading.Thread(target=self._worker_loop, name="compass-worker", daemon=True)
        ctrl = threading.Thread(target=self._control_loop, name="compass-elastico", daemon=True)
        self._threads = [worker, ctrl]
        for t in self._threads:
            t.start()

    def submit(self, request: Request) -> None:
        self.monitor.record_arrival()
        self.queue.put(request)

    def drain_and_stop(self, *, timeout_s: float = 120.0) -> EngineReport:
        """Close ingress, wait until the queue empties, stop threads."""
        deadline = self._clock() + timeout_s
        while (self.queue.depth() > 0 or self.executor.in_flight() > 0) \
                and self._clock() < deadline:
            time.sleep(0.01)
        self.queue.close()
        self._stop.set()
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads = []
        return EngineReport(
            records=list(self.executor.records),
            switch_events=list(self.controller.events) if self.controller else [],
            config_timeline=list(self._timeline),
            total_requests=self.queue.total_enqueued,
        )

    # -- loops ---------------------------------------------------------------

    def _now_rel(self) -> float:
        assert self._epoch is not None
        return self._clock() - self._epoch

    def _worker_loop(self) -> None:
        while not self._stop.is_set():
            req = self.queue.get(timeout=0.05)
            if req is None:
                continue
            self._observe()          # arrival-to-service boundary decision
            self.executor.execute(req.request_id, req.arrival_s, req.payload)
            self._observe()

    def _control_loop(self) -> None:
        while not self._stop.is_set():
            self._observe()
            time.sleep(self.control_tick_s)

    def _observe(self) -> None:
        if self.controller is None:
            return
        depth = self.queue.depth()  # buffered requests only (see simulator)
        now = self._now_rel()
        self.monitor.snapshot(self.queue.depth(), self.executor.in_flight(), now)
        ev = self.controller.observe(depth, now)
        if ev is not None:
            self.executor.set_active(ev.to_index)
            self._timeline.append((now, ev.to_index))


def replay_workload(
    engine: ServingEngine,
    arrivals: Sequence[float],
    *,
    payload_fn: Optional[Callable[[int], Any]] = None,
    time_scale: float = 1.0,
) -> None:
    """Feed a precomputed arrival trace into a started engine in real time
    (optionally time-scaled for faster tests)."""
    t0 = time.monotonic()
    for i, at in enumerate(arrivals):
        target = t0 + at * time_scale
        delay = target - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        payload = payload_fn(i) if payload_fn is not None else None
        engine.submit(Request(request_id=i, arrival_s=engine._now_rel(), payload=payload))
