"""Real-time serving engine: monitor + Elastico + scheduler + worker pool.

The engine wires the runtime components of the paper's serving architecture
(§III-B) and runs them against wall-clock time on this host.  Since PR 4
every *dispatch decision* — admission, FIFO order, batch draining with
linger, per-worker assignment, work stealing, the Elastico switch hook —
is made by the shared :class:`repro.serving.scheduler.Scheduler`; this
module contributes only the wall-clock driving: ingress, worker threads
(via :class:`repro.serving.executor.WorkerPool`), the control-loop thread,
and the report.

  ingress thread  ->  Scheduler (policy core)  ->  WorkerPool (c threads)
                          |                             |
                      LoadMonitor  <--------------------+
                          |
                  control thread (Elastico) -> scheduler.observe
                                               (index flip or one-worker repin)

``num_workers=1`` (the default) is the paper-faithful M/G/1 server; larger
pools drain the same buffered backlog concurrently (M/G/c) with the
switching thresholds derived for that c (pass ``num_servers`` to
``derive_policies``).  The controller may be either flavor: a homogeneous
:class:`~repro.core.elastico.ElasticoController`, whose decisions flip the
executor's default active index for all workers at once, or a heterogeneous
:class:`~repro.core.elastico.ElasticoMixController`, whose decisions repin
the scheduler's per-worker assignment vector one worker at a time;
``EngineReport.assignment_timeline`` records the mix trajectory.
Controller decisions are serialized behind the pool's scheduler lock so
concurrent workers never interleave observations, and every decision keys
off the *buffered* queue depth — requests waiting for dispatch, excluding
those in flight.

``max_queue_depth`` enables admission control (beyond-paper): arrivals that
find the buffer full are rejected at ingress and surface in
``EngineReport.dropped`` (see that field's documentation for exact
semantics).  ``admission_reroute=True`` adds *mix-aware admission*: the
scheduler forces the controller to the fastest rung before rejecting, and
only drops when the pool is already all-fast (or the depth exceeds the mix
table's re-route threshold) — ``EngineReport.rerouted`` counts the saves.

``max_batch_size``/``batch_timeout_s`` enable in-worker batching
(beyond-paper): each dispatch carries up to ``max_batch_size`` requests —
the scheduler lingers a short batch up to ``batch_timeout_s`` for arrivals
to fill it — and executes the run as one batch (see
:meth:`repro.serving.executor.WorkflowExecutor.execute_batch`).
``EngineReport.mean_batch_size`` reports the realized amortization;
``max_batch_size=1`` (default) takes the exact pre-batching code path.

``queue_discipline="per_worker"`` with ``steal=True`` (beyond-paper)
switches the scheduler to per-worker backlogs with work stealing: arrivals
are routed round-robin, and an idle worker pulls from the globally deepest
backlog once it reaches the steal threshold — serving stolen requests
under its *own* pinned configuration.  ``EngineReport.stolen_batches``
counts the rebalanced dispatches.

A deterministic-virtual-time driver over the same scheduler is provided by
:mod:`repro.serving.simulator`; this module is the "it actually serves"
path used by the examples and smoke tests.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence

from ..core.elastico import ElasticoController, ElasticoMixController
from .executor import ExecutionRecord, WorkerError, WorkerPool, WorkflowExecutor
from .faults import FaultSchedule
from .monitor import LoadMonitor
from .scheduler import Scheduler
from .workload import Request


@dataclass
class EngineReport:
    """Serving run summary.

    ``dropped`` counts admission-control rejections: arrivals that found the
    bounded buffer (``max_queue_depth``) full and were rejected at ingress —
    they never enqueued, never executed, and have no
    :class:`~repro.serving.executor.ExecutionRecord`.  Invariants:
    ``total_requests == len(records) + dropped`` after a clean
    ``drain_and_stop``, and ``dropped == 0`` whenever the queue is unbounded
    (the paper's no-drop default — configuration switches never drop
    requests, §III-B).  ``slo_compliance`` ignores drops (fraction of
    *served* requests in SLO); ``goodput`` charges them (fraction of
    *offered* load served in SLO).  ``rerouted`` counts arrivals that
    mix-aware admission saved by forcing the fastest rung instead of
    dropping.

    ``assignment_timeline`` records ``(time_s, assignment_vector)`` repin
    events when a mix controller drives a heterogeneous pool; empty for
    homogeneous runs, whose ``config_timeline`` records the global switches.

    Robustness surface (beyond-paper): ``failed`` counts requests whose
    workflow execution kept raising until the worker retry budget ran out
    (distinct from admission ``dropped``); ``worker_errors`` lists every
    captured worker-thread exception; ``drain_timed_out`` flags a
    ``drain_and_stop`` that hit its deadline (or gave up because every
    worker had halted) with ``backlog`` requests still unserved.  The
    conservation invariant:
    ``total_requests == len(records) + dropped + failed + backlog``.
    """

    records: List[ExecutionRecord]
    switch_events: List
    config_timeline: List
    total_requests: int
    dropped: int = 0
    num_workers: int = 1
    served_per_worker: List[int] = field(default_factory=list)
    assignment_timeline: List = field(default_factory=list)
    # realized requests-per-dispatch across the pool; 1.0 for unbatched runs
    mean_batch_size: float = 1.0
    max_batch_size: int = 1
    rerouted: int = 0
    stolen_batches: int = 0
    failed: int = 0
    worker_errors: List[WorkerError] = field(default_factory=list)
    drain_timed_out: bool = False
    backlog: int = 0

    def slo_compliance(self, slo_s: float) -> float:
        if not self.records:
            return 1.0
        return sum(1 for r in self.records if r.latency_s <= slo_s) / len(self.records)

    def goodput(self, slo_s: float) -> float:
        """Fraction of *offered* load served within the SLO — unlike
        ``slo_compliance`` this charges dropped requests against the engine."""
        if self.total_requests == 0:
            return 1.0
        ok = sum(1 for r in self.records if r.latency_s <= slo_s)
        return ok / self.total_requests

    def mean_accuracy(self, accuracies: Sequence[float]) -> float:
        if not self.records:
            return 0.0
        return sum(accuracies[r.config_index] for r in self.records) / len(self.records)


class ServingEngine:
    """Threaded serving engine with dynamic configuration switching.

    ``num_workers`` sizes the worker pool (c of the M/G/c model);
    ``max_queue_depth`` bounds the buffered backlog for admission control
    (None = unbounded, the paper's no-drop default); ``max_batch_size`` /
    ``batch_timeout_s`` enable in-worker batching (1 / 0.0 = unbatched,
    the paper-faithful default).  ``controller`` may be
    a homogeneous :class:`ElasticoController` (switches the global default
    config) or an :class:`ElasticoMixController` (repins the per-worker
    assignment vector one worker at a time); pass None for a static run,
    optionally with a fixed heterogeneous pinning via ``assignment``.
    ``queue_discipline`` / ``steal`` / ``steal_threshold`` /
    ``admission_reroute`` forward to the shared
    :class:`repro.serving.scheduler.Scheduler` (see its documentation).
    """

    def __init__(
        self,
        executor: WorkflowExecutor,
        controller: Optional[ElasticoController] = None,
        *,
        num_workers: int = 1,
        max_queue_depth: Optional[int] = None,
        control_tick_s: float = 0.05,
        clock: Callable[[], float] = time.monotonic,
        assignment: Optional[Sequence[int]] = None,
        max_batch_size: int = 1,
        batch_timeout_s: float = 0.0,
        queue_discipline: str = "shared",
        steal: bool = False,
        steal_threshold: Optional[int] = None,
        admission_reroute: bool = False,
        faults: Optional[FaultSchedule] = None,
        on_worker_error: str = "restart",
        retry_budget: int = 3,
    ) -> None:
        if num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        self.monitor = LoadMonitor(clock=clock)
        self.executor = executor
        self.controller = controller
        # the single source of dispatch policy, shared with the simulator;
        # construction validates the whole configuration eagerly (e.g. an
        # assignment under a controller would be silently dead and raises).
        self.scheduler = Scheduler(
            num_workers=num_workers,
            max_batch_size=max_batch_size,
            batch_timeout_s=batch_timeout_s,
            max_queue_depth=max_queue_depth,
            controller=controller,
            static_index=executor.active_index(),
            assignment=assignment,
            num_configs=executor.num_configs,
            queue_discipline=queue_discipline,
            steal=steal,
            steal_threshold=steal_threshold,
            admission_reroute=admission_reroute,
            record_initial_config=controller is not None,
            on_switch=self._mirror_switch,
        )
        self.pool = WorkerPool(
            executor, c=num_workers, on_observe=self._observe,
            scheduler=self.scheduler, clock=clock,
            on_worker_error=on_worker_error, retry_budget=retry_budget,
            faults=faults,
        )
        # wall-clock fault plane: capacity events (crash/recover) are
        # applied from the control loop at tick granularity — a running
        # thread cannot be preempted, so a "crashed" worker finishes its
        # in-flight batch and then receives no further dispatches until the
        # recovery event returns it to the free pool.  Straggler/brownout
        # inflation is applied by the workers themselves (sleep-stretch in
        # the worker loop).
        self._faults = (faults if faults is not None and not faults.is_empty()
                        else None)
        self._fault_events = (list(self._faults.capacity_events(None))
                              if self._faults is not None else [])
        self._fault_pos = 0
        self.control_tick_s = control_tick_s
        self._clock = clock
        self._stop = threading.Event()
        self._ctrl_thread: Optional[threading.Thread] = None
        self._epoch: Optional[float] = None
        self._submitted = 0
        self._dropped = 0
        self._ingress_lock = threading.Lock()

    @property
    def num_workers(self) -> int:
        return self.pool.c

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        if self._ctrl_thread is not None:
            raise RuntimeError("engine already started")
        self._epoch = self._clock()
        self.executor.set_clock(self._now_rel)
        self.monitor.set_clock(self._now_rel)  # one time axis for all stamps
        self.pool.set_clock(self._now_rel)     # scheduler timestamps likewise
        if self.controller is not None and not isinstance(
                self.controller, ElasticoMixController):
            # homogeneous: workers follow the executor's default index; the
            # mix path pins every dispatch through the scheduler instead.
            self.executor.set_active(self.controller.current_index)
        self.pool.start()
        self._ctrl_thread = threading.Thread(
            target=self._control_loop, name="compass-elastico", daemon=True
        )
        self._ctrl_thread.start()

    def submit(self, request: Request) -> bool:
        """Offer a request to the engine; returns False if admission control
        rejected it (bounded queue full, and — with mix-aware admission —
        not salvageable by re-routing to the fastest rung)."""
        if self._epoch is None:
            # before start() the epoch-relative clock is not installed, so
            # scheduler timestamps (linger deadlines, switch times) would
            # land on the raw host clock axis and never fire/compare sanely.
            raise RuntimeError("engine not started")
        self.monitor.record_arrival()
        adm = self.pool.submit(request)
        with self._ingress_lock:
            self._submitted += 1
            if not adm.admitted:
                self._dropped += 1
        if not adm.admitted:
            self.monitor.record_drop()
        return adm.admitted

    def drain_and_stop(self, *, timeout_s: float = 120.0) -> EngineReport:
        """Close ingress, wait until the backlog empties, stop threads.

        The drain condition uses the scheduler's ``buffered()`` (waiting,
        including any forming batch held open by a linger window) plus
        ``pool.pending()`` (dispatched to a worker mailbox but not yet
        finished), so a worker mid-linger cannot race the shutdown into
        dropping its partial batch.  The loop gives up early — instead of
        sleeping out the full timeout — once every worker has halted on a
        failure (``on_worker_error='halt'``), and reports either outcome
        via ``EngineReport.drain_timed_out`` / ``backlog``."""
        deadline = self._clock() + timeout_s
        drain_timed_out = False
        while (self.pool.buffered() > 0 or self.executor.in_flight() > 0
               or self.pool.pending() > 0):
            if self.pool.all_workers_dead():
                drain_timed_out = True   # nothing can drain this backlog
                break
            if self._clock() >= deadline:
                drain_timed_out = True
                break
            time.sleep(0.01)
        backlog = (self.pool.buffered() + self.executor.in_flight()
                   + self.pool.pending())
        with self.pool.lock:
            self.scheduler.close()
        self._stop.set()
        self.pool.stop()
        if self._ctrl_thread is not None:
            self._ctrl_thread.join(timeout=5.0)
            self._ctrl_thread = None
        with self._ingress_lock:
            submitted, dropped = self._submitted, self._dropped
        return EngineReport(
            records=list(self.executor.records),
            switch_events=list(self.controller.events) if self.controller else [],
            config_timeline=list(self.scheduler.config_timeline),
            total_requests=submitted,
            dropped=dropped,
            num_workers=self.pool.c,
            served_per_worker=self.pool.served_per_worker(),
            assignment_timeline=list(self.scheduler.assignment_timeline),
            mean_batch_size=self.pool.mean_batch_size(),
            max_batch_size=self.pool.max_batch_size,
            rerouted=self.scheduler.rerouted,
            stolen_batches=self.scheduler.stolen_batches,
            failed=self.scheduler.failed,
            worker_errors=list(self.pool.worker_errors),
            drain_timed_out=drain_timed_out,
            backlog=backlog,
        )

    # -- loops ---------------------------------------------------------------

    def _now_rel(self) -> float:
        assert self._epoch is not None
        return self._clock() - self._epoch

    def _control_loop(self) -> None:
        while not self._stop.is_set():
            self._apply_faults()
            self._observe()
            time.sleep(self.control_tick_s)

    def _apply_faults(self) -> None:
        """Apply every due capacity event from the fault schedule: crash
        takes the worker out of dispatch rotation (and rescues its
        per-worker backlog to the queue head), recover returns it.  Both
        run the scheduler's capacity-change hook, so a degradation-aware
        controller swaps its threshold table in the same critical
        section."""
        if self._fault_pos >= len(self._fault_events):
            return
        now = self._now_rel()
        with self.pool.lock:
            while (self._fault_pos < len(self._fault_events)
                   and self._fault_events[self._fault_pos][0] <= now):
                _t, kind, w = self._fault_events[self._fault_pos]
                self._fault_pos += 1
                if kind == "crash":
                    self.scheduler.mark_worker_down(w, now)
                    rescued = self.scheduler.drain_worker_backlog(w)
                    if rescued:
                        self.scheduler.requeue_front(rescued)
                else:
                    self.scheduler.mark_worker_up(w, now)
                self.pool._pump_locked()
            self.pool.lock.notify_all()

    def _observe(self) -> None:
        if self.controller is None:
            return
        # the pool's scheduler lock serializes controller observations from
        # all workers + the control loop: the scheduler (and Elastico) are
        # pure decision logic and rely on the caller for thread safety.
        with self.pool.lock:
            # buffered requests only: waiting for dispatch, including any
            # lingering forming batch — the same depth the simulator's
            # event loop shows the controller for the same state.
            depth = self.scheduler.buffered()
            now = self._now_rel()
            batch = (self.pool.mean_batch_size()
                     if self.pool.max_batch_size > 1 else None)
            self.monitor.snapshot(depth, self.executor.in_flight(), now,
                                  assignment=self.scheduler.assignment(),
                                  batch_size=batch)
            # any resulting switch is mirrored into the executor by the
            # scheduler's on_switch hook (_mirror_switch) inside this same
            # critical section — racing observers cannot reorder it.
            self.scheduler.observe(now)

    def _mirror_switch(self, ev) -> None:
        """Scheduler on_switch hook: keep the executor's default index in
        step with homogeneous switches (mix switches pin per dispatch and
        need no mirroring).  Runs under the pool's scheduler lock."""
        if not isinstance(self.controller, ElasticoMixController):
            self.executor.set_active(ev.to_index)


def replay_workload(
    engine: ServingEngine,
    arrivals: Sequence[float],
    *,
    payload_fn: Optional[Callable[[int], Any]] = None,
    time_scale: float = 1.0,
) -> None:
    """Feed a precomputed arrival trace into a started engine in real time
    (optionally time-scaled for faster tests)."""
    t0 = time.monotonic()
    for i, at in enumerate(arrivals):
        target = t0 + at * time_scale
        delay = target - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        payload = payload_fn(i) if payload_fn is not None else None
        engine.submit(Request(request_id=i, arrival_s=engine._now_rel(), payload=payload))
