"""Workflow DAGs: multi-stage compound pipelines as first-class scenarios.

The paper's subject is *compound* AI workflows — retrieve -> rerank ->
generate, detect -> verify — yet a classic serving model collapses the
whole pipeline into one opaque service time.  This module makes the stage
structure explicit and threads it through the queueing stack:

- :class:`StageSpec` / :class:`WorkflowDAG`: named stages with per-stage
  latency ladders (one (mean, p95) per configuration), per-stage worker
  pools, and tandem or fork-join topology (parallel branches joining at a
  synchronization stage).  Exactly one sink; any number of sources (an
  arrival is offered to every source — the fork-at-entry case).
- :func:`derive_pipeline_policies`: the *pipeline ladder*.  Each rung
  fixes one configuration per stage; switching thresholds are derived
  from the stage-level queue models exactly like the single-stage AQM
  (Eq. 10/13) but stated at the pipeline's *bottleneck* stage, with the
  end-to-end P95 supplying the queuing slack.  A single-stage DAG
  reproduces :func:`repro.core.aqm.derive_policies` thresholds exactly.
- :class:`DagSimulator`: the event-heap **exact oracle** for DAG serving.
  One :class:`repro.serving.scheduler.Scheduler` per stage (per-stage
  FIFO + worker pool + admission bound); a stage's batch completion
  offers its requests to the successor stages (fork duplicates the
  handle down every branch, a join waits for all predecessors); the
  pipeline-level Elastico controller consumes *per-stage* queue depths
  (:meth:`repro.core.elastico.ElasticoController.observe_stages`) and its
  rung decisions are applied stage-by-stage via
  :meth:`repro.serving.scheduler.Scheduler.set_active_index`.

  **Degenerate collapse contract**: a single-stage DAG replays
  :class:`repro.serving.simulator.ServingSimulator` *bit-for-bit* — same
  event order, same RNG stream (stage 0 draws from ``random.Random(seed)``;
  only stages past the first use derived streams), same float ops — so
  the DAG layer provably costs nothing when the workflow is not compound
  (golden digest test in ``tests/test_dag.py``).
- :func:`simulate_dag`: the chained-Lindley **fast path** for static DAG
  scenarios — stage n's departures are stage n+1's arrivals; a join's
  arrival is the element-wise max over its predecessors' completions.
  Service times are drawn from the identical per-stage ``random.Random``
  streams in stage-dispatch order, so completions agree with the oracle
  bit-for-bit (no admission bounds, no controller — the same eligibility
  idea as :func:`repro.serving.fastsim.simulate`).
- :func:`sweep_pipeline`: the vectorized rungs x loads x replications
  validation grid over content-keyed numpy streams (the
  :func:`repro.serving.fastsim.simulate_batch` idea lifted to chained
  recursions via :func:`repro.serving.fastsim.chained_lindley`), plus
  :func:`pipeline_sojourn`, the stationary queueing-network prediction
  (per-stage Allen-Cunneen with departure-SCV propagation, fork-join
  critical path) the grid is compared against.
"""

from __future__ import annotations

import heapq
import math
import random
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.aqm import (
    HysteresisSpec,
    allen_cunneen_mean_wait,
    departure_scv,
    fork_join_sojourn,
)
from ..core.elastico import ElasticoController
from .faults import FaultSchedule
from .scheduler import Dispatch, Scheduler
from .simulator import (
    CompletedRequest,
    ServiceSampler,
    SimulationResult,
    deterministic_sampler,
    lognormal_sampler_from_profile,
)

_Z95 = 1.6448536269514722


def _lognormal_sigma(mean: float, p95: float) -> float:
    """The sigma the lognormal sampler fits to (mean, p95) — same solve as
    :func:`repro.serving.simulator.lognormal_sampler_from_profile`."""
    ratio = max(p95 / mean, 1.001)
    c = math.log(ratio)
    disc = _Z95 * _Z95 - 2.0 * c
    return _Z95 - math.sqrt(disc) if disc > 0 else _Z95


def stage_service_variance(mean: float, p95: Optional[float]) -> float:
    """Variance of the fitted lognormal service time (0 when the stage is
    deterministic, i.e. no p95 given)."""
    if p95 is None:
        return 0.0
    sigma = _lognormal_sigma(mean, p95)
    return (math.exp(sigma * sigma) - 1.0) * mean * mean


def stage_service_scv(mean: float, p95: Optional[float]) -> float:
    """Squared coefficient of variation of the fitted lognormal."""
    if p95 is None:
        return 0.0
    sigma = _lognormal_sigma(mean, p95)
    return math.exp(sigma * sigma) - 1.0


# --------------------------------------------------------------------------
# the DAG itself
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class StageSpec:
    """One named stage: a latency ladder (one entry per configuration the
    stage can run), its worker-pool size, and optional per-config accuracy
    factors (pipeline accuracy composes multiplicatively — the compound-
    workflow coupling the paper's surrogate models) and admission bound."""

    name: str
    mean_s: Tuple[float, ...]
    p95_s: Optional[Tuple[float, ...]] = None
    num_servers: int = 1
    accuracy: Optional[Tuple[float, ...]] = None
    max_queue_depth: Optional[int] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "mean_s", tuple(float(m) for m in self.mean_s))
        if self.p95_s is not None:
            object.__setattr__(self, "p95_s",
                               tuple(float(p) for p in self.p95_s))
        if self.accuracy is not None:
            object.__setattr__(self, "accuracy",
                               tuple(float(a) for a in self.accuracy))
        if not self.name:
            raise ValueError("stage needs a name")
        if not self.mean_s:
            raise ValueError(f"stage {self.name!r}: empty config ladder")
        if any(m <= 0 for m in self.mean_s):
            raise ValueError(f"stage {self.name!r}: means must be positive")
        if self.p95_s is not None and len(self.p95_s) != len(self.mean_s):
            raise ValueError(f"stage {self.name!r}: p95 ladder length "
                             "must match the mean ladder")
        if self.accuracy is not None and len(self.accuracy) != len(self.mean_s):
            raise ValueError(f"stage {self.name!r}: accuracy ladder length "
                             "must match the mean ladder")
        if self.num_servers < 1:
            raise ValueError(f"stage {self.name!r}: num_servers must be >= 1")
        if self.max_queue_depth is not None and self.max_queue_depth < 1:
            raise ValueError(f"stage {self.name!r}: max_queue_depth "
                             "must be >= 1 (or None)")

    @property
    def num_configs(self) -> int:
        return len(self.mean_s)

    def sampler(self) -> ServiceSampler:
        """The stage's service-time sampler: lognormal tails fitted to
        (mean, p95) per config, or deterministic without a p95 ladder."""
        if self.p95_s is not None:
            return lognormal_sampler_from_profile(self.mean_s, self.p95_s)
        return deterministic_sampler(self.mean_s)

    def accuracy_of(self, config_index: int) -> float:
        return 1.0 if self.accuracy is None else self.accuracy[config_index]


@dataclass(frozen=True)
class WorkflowDAG:
    """Stages plus directed edges ``(from_index, to_index)``.

    Must be acyclic with exactly one sink.  Stages without predecessors
    are *sources* — every external arrival is offered to each of them
    (so a fork at the very entry is just multiple sources).  A stage with
    several predecessors is a *join*: a request becomes eligible there
    only once all of its predecessor copies have completed."""

    stages: Tuple[StageSpec, ...]
    edges: Tuple[Tuple[int, int], ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "stages", tuple(self.stages))
        object.__setattr__(
            self, "edges",
            tuple((int(a), int(b)) for a, b in self.edges))
        if not self.stages:
            raise ValueError("a workflow needs at least one stage")
        names = [s.name for s in self.stages]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stage names in {names}")
        n = len(self.stages)
        seen = set()
        for a, b in self.edges:
            if not (0 <= a < n and 0 <= b < n):
                raise ValueError(f"edge ({a}, {b}) out of range")
            if a == b:
                raise ValueError(f"self-loop at stage {a}")
            if (a, b) in seen:
                raise ValueError(f"duplicate edge ({a}, {b})")
            seen.add((a, b))
        order = self.topological_order()   # raises on cycles
        sinks = [j for j in range(n) if not self.successors(j)]
        if len(sinks) != 1:
            raise ValueError(
                f"workflow must have exactly one sink stage, got "
                f"{[self.stages[j].name for j in sinks]}")
        assert order[-1] in sinks or n == 1 or True

    # -- topology ----------------------------------------------------------

    @property
    def num_stages(self) -> int:
        return len(self.stages)

    def predecessors(self, j: int) -> Tuple[int, ...]:
        return tuple(a for a, b in self.edges if b == j)

    def successors(self, j: int) -> Tuple[int, ...]:
        return tuple(b for a, b in self.edges if a == j)

    def sources(self) -> Tuple[int, ...]:
        return tuple(j for j in range(len(self.stages))
                     if not self.predecessors(j))

    def sink(self) -> int:
        (s,) = [j for j in range(len(self.stages))
                if not self.successors(j)]
        return s

    def topological_order(self) -> Tuple[int, ...]:
        """Kahn's algorithm with the lowest-index tie-break (deterministic
        processing order for the simulator and the fast path)."""
        n = len(self.stages)
        indeg = [len(self.predecessors(j)) for j in range(n)]
        ready = [j for j in range(n) if indeg[j] == 0]
        heapq.heapify(ready)
        out: List[int] = []
        while ready:
            j = heapq.heappop(ready)
            out.append(j)
            for s in self.successors(j):
                indeg[s] -= 1
                if indeg[s] == 0:
                    heapq.heappush(ready, s)
        if len(out) != n:
            raise ValueError("workflow graph has a cycle")
        return tuple(out)

    def is_tandem(self) -> bool:
        """True when the DAG is a simple chain (every stage has at most one
        predecessor and one successor)."""
        return all(len(self.predecessors(j)) <= 1
                   and len(self.successors(j)) <= 1
                   for j in range(len(self.stages)))

    def stage_index(self, name: str) -> int:
        for j, s in enumerate(self.stages):
            if s.name == name:
                return j
        raise KeyError(name)

    # -- constructors ------------------------------------------------------

    @classmethod
    def single(cls, stage: StageSpec) -> "WorkflowDAG":
        """The degenerate one-stage workflow (collapse-contract anchor)."""
        return cls(stages=(stage,), edges=())

    @classmethod
    def tandem(cls, stages: Sequence[StageSpec]) -> "WorkflowDAG":
        sts = tuple(stages)
        return cls(stages=sts,
                   edges=tuple((j, j + 1) for j in range(len(sts) - 1)))

    @classmethod
    def fork_join(cls, branches: Sequence[StageSpec], join: StageSpec, *,
                  tail: Sequence[StageSpec] = ()) -> "WorkflowDAG":
        """Parallel single-stage branches joining at ``join`` (e.g. two
        retrieve branches joining at rerank), followed by an optional
        tandem ``tail`` (e.g. generate)."""
        if len(branches) < 2:
            raise ValueError("fork-join needs at least two branches")
        sts = tuple(branches) + (join,) + tuple(tail)
        jidx = len(branches)
        edges = [(b, jidx) for b in range(len(branches))]
        for t in range(len(tail)):
            edges.append((jidx + t, jidx + t + 1))
        return cls(stages=sts, edges=tuple(edges))

    def validate_stage_indices(self, stage_indices: Sequence[int]) -> Tuple[int, ...]:
        cfg = tuple(int(k) for k in stage_indices)
        if len(cfg) != len(self.stages):
            raise ValueError(f"need one config index per stage "
                             f"({len(self.stages)}), got {len(cfg)}")
        for j, k in enumerate(cfg):
            if not 0 <= k < self.stages[j].num_configs:
                raise IndexError(
                    f"stage {self.stages[j].name!r}: config {k} out of "
                    f"range [0, {self.stages[j].num_configs})")
        return cfg


# --------------------------------------------------------------------------
# pipeline-level service profile and queueing model
# --------------------------------------------------------------------------


def pipeline_service_profile(dag: WorkflowDAG,
                             stage_indices: Sequence[int]
                             ) -> Tuple[float, float]:
    """(mean, p95) of the end-to-end *service* time (no queueing) of one
    pipeline rung.

    Tandem segments add; a join's segment mean is the fork-join
    critical path over its predecessors' cumulative means
    (:func:`repro.core.aqm.fork_join_sojourn` — m * H_k for identical
    branches).  The p95 is the critical-path normal-tail estimate
    ``mean + z95 * sqrt(sum of stage variances along the max-mean path)``
    — except for a single-stage workflow, where the stage's own fitted
    p95 is returned unchanged so the degenerate DAG's thresholds collapse
    exactly to the single-stage AQM values.
    """
    cfg = dag.validate_stage_indices(stage_indices)
    if dag.num_stages == 1:
        st = dag.stages[0]
        p95 = (st.p95_s[cfg[0]] if st.p95_s is not None else st.mean_s[cfg[0]])
        return st.mean_s[cfg[0]], p95
    cum_mean: Dict[int, float] = {}
    cum_var: Dict[int, float] = {}
    for j in dag.topological_order():
        st = dag.stages[j]
        m = st.mean_s[cfg[j]]
        v = stage_service_variance(
            m, None if st.p95_s is None else st.p95_s[cfg[j]])
        preds = dag.predecessors(j)
        if not preds:
            base_m, base_v = 0.0, 0.0
        elif len(preds) == 1:
            base_m, base_v = cum_mean[preds[0]], cum_var[preds[0]]
        else:
            base_m = fork_join_sojourn([cum_mean[p] for p in preds])
            base_v = max(cum_var[p] for p in preds)
        cum_mean[j] = base_m + m
        cum_var[j] = base_v + v
    sink = dag.sink()
    mean = cum_mean[sink]
    return mean, mean + _Z95 * math.sqrt(cum_var[sink])


def pipeline_sojourn(dag: WorkflowDAG, stage_indices: Sequence[int],
                     arrival_rate_qps: float, *,
                     scv_arrival: float = 1.0) -> float:
    """Stationary mean end-to-end sojourn (queueing + service) of one
    pipeline rung under Poisson-ish external load — the queueing-network
    prediction :func:`sweep_pipeline` grids are validated against.

    Every stage sees the full external rate (a fork duplicates each
    request down every branch).  Per stage: Allen-Cunneen G/G/c wait with
    the arrival SCV chained from the predecessor's departure process
    (:func:`repro.core.aqm.departure_scv`); a join averages its
    predecessors' departure SCVs and takes the fork-join critical path
    over the branches' cumulative sojourns.  Saturated stages propagate
    ``inf``."""
    cfg = dag.validate_stage_indices(stage_indices)
    if arrival_rate_qps < 0:
        raise ValueError("arrival rate must be >= 0")
    cum: Dict[int, float] = {}
    out_scv: Dict[int, float] = {}
    for j in dag.topological_order():
        st = dag.stages[j]
        m = st.mean_s[cfg[j]]
        cs2 = stage_service_scv(
            m, None if st.p95_s is None else st.p95_s[cfg[j]])
        preds = dag.predecessors(j)
        if not preds:
            base, ca2 = 0.0, float(scv_arrival)
        elif len(preds) == 1:
            base, ca2 = cum[preds[0]], out_scv[preds[0]]
        else:
            branches = [cum[p] for p in preds]
            base = (float("inf") if any(math.isinf(b) for b in branches)
                    else fork_join_sojourn(branches))
            ca2 = sum(out_scv[p] for p in preds) / len(preds)
        c = st.num_servers
        wait = allen_cunneen_mean_wait(c, arrival_rate_qps, m,
                                       scv_service=cs2, scv_arrival=ca2)
        rho = arrival_rate_qps * m / c
        cum[j] = base + wait + m
        out_scv[j] = departure_scv(c, rho, scv_arrival=ca2, scv_service=cs2)
    return cum[dag.sink()]


# --------------------------------------------------------------------------
# the pipeline ladder: rungs + switching thresholds
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class PipelinePolicy:
    """One pipeline rung: a per-stage configuration choice plus the
    switching thresholds stated at the rung's bottleneck stage.

    Duck-type compatible with :class:`repro.core.aqm.SwitchingPolicy` as
    far as :class:`repro.core.elastico.ElasticoController` reads it
    (``upscale_threshold`` / ``downscale_threshold``); ``stage_weights``
    additionally lets :meth:`~repro.core.elastico.ElasticoController.observe_stages`
    collapse per-stage depths into bottleneck-equivalent units."""

    index: int
    stage_indices: Tuple[int, ...]
    mean_latency_s: float            # end-to-end service mean (no queueing)
    p95_latency_s: float             # end-to-end service p95 estimate
    accuracy: float                  # product of stage accuracy factors
    queuing_slack_s: float           # Delta_r = L - p95_e2e  (Eq. 7 lifted)
    upscale_threshold: int           # N_r(up), bottleneck-equivalent depth
    downscale_threshold: Optional[int]
    bottleneck_stage: int            # argmax_j s_j / c_j at this rung
    stage_weights: Tuple[float, ...]  # (s_j / c_j) / (s_b / c_b)


@dataclass(frozen=True)
class PipelinePolicyTable:
    """The pipeline ladder — duck-type compatible with
    :class:`repro.core.aqm.AQMPolicyTable` so the unmodified
    :class:`repro.core.elastico.ElasticoController` can walk it."""

    slo_p95_s: float
    slack_buffer_s: float
    policies: Tuple[PipelinePolicy, ...]
    hysteresis: HysteresisSpec
    excluded: Tuple[Tuple[int, ...], ...] = ()   # rungs with Delta <= 0
    num_servers: int = 1       # bottleneck pool size of the fastest rung
    max_batch_size: int = 1    # pipeline ladders are unbatched (B = 1)

    @property
    def ladder_size(self) -> int:
        return len(self.policies)

    def policy(self, k: int) -> PipelinePolicy:
        return self.policies[k]

    def stage_indices(self, k: int) -> Tuple[int, ...]:
        return self.policies[k].stage_indices


def _greedy_rung_walk(dag: WorkflowDAG) -> List[Tuple[int, ...]]:
    """Default pipeline ladder: start all-fastest and repeatedly upgrade
    the single stage with the best accuracy-gain per added end-to-end
    mean latency (ties break toward the lowest stage index) until every
    stage is at its most accurate configuration.  Produces a ladder of
    ``sum_j (K_j - 1) + 1`` rungs with strictly non-decreasing mean."""
    cur = [0] * dag.num_stages
    rungs = [tuple(cur)]
    while True:
        best: Optional[Tuple[float, float, int]] = None
        base_mean, _ = pipeline_service_profile(dag, cur)
        base_acc = 1.0
        for j, st in enumerate(dag.stages):
            base_acc *= st.accuracy_of(cur[j])
        for j, st in enumerate(dag.stages):
            if cur[j] + 1 >= st.num_configs:
                continue
            trial = list(cur)
            trial[j] += 1
            mean, _ = pipeline_service_profile(dag, trial)
            dm = mean - base_mean
            acc = 1.0
            for i, s2 in enumerate(dag.stages):
                acc *= s2.accuracy_of(trial[i])
            da = acc - base_acc
            score = da / dm if dm > 1e-12 else float("inf")
            # max score, ties toward the lowest stage index
            key = (score, -j)
            if best is None or key > (best[0], -best[2]):
                best = (score, dm, j)
        if best is None:
            return rungs
        cur[best[2]] += 1
        rungs.append(tuple(cur))


def derive_pipeline_policies(
    dag: WorkflowDAG,
    *,
    slo_p95_s: float,
    slack_buffer_s: float = 0.050,
    hysteresis: HysteresisSpec = HysteresisSpec(),
    rungs: Optional[Sequence[Sequence[int]]] = None,
) -> PipelinePolicyTable:
    """Derive the pipeline-level switching ladder from stage-level models.

    Each rung r fixes one configuration per stage.  The thresholds lift
    Eq. 10/13 to the pipeline: with queuing slack ``Delta_r = L -
    p95_e2e(r)`` (end-to-end service p95,
    :func:`pipeline_service_profile`) and bottleneck stage ``b`` (largest
    per-request drain time ``s_j / c_j``),

      N_r(up) = floor(c_b * Delta_r / s_b)
      N_r(dn) = floor(c_b' * (Delta_{r+1} - h_s) / s_b')   (next rung's b')

    — the deepest bottleneck-equivalent backlog the rung can drain inside
    its slack.  The controller compares these against the weighted
    per-stage depth (:attr:`PipelinePolicy.stage_weights`,
    :meth:`repro.core.elastico.ElasticoController.observe_stages`).  For
    a single-stage DAG every formula collapses to
    :func:`repro.core.aqm.derive_policies` exactly.

    ``rungs`` overrides the ladder (must be strictly increasing in
    end-to-end mean); the default is the greedy accuracy-per-latency walk
    from all-fastest to all-most-accurate.  Rungs whose end-to-end p95
    already exceeds the SLO are excluded (cannot meet it even unloaded).
    """
    if slo_p95_s <= 0:
        raise ValueError("SLO must be positive")
    if rungs is None:
        walk = _greedy_rung_walk(dag)
    else:
        walk = [dag.validate_stage_indices(r) for r in rungs]
        means = [pipeline_service_profile(dag, r)[0] for r in walk]
        for a, b in zip(means, means[1:]):
            if not b > a:
                raise ValueError("pipeline rungs must be ordered by "
                                 "strictly increasing end-to-end mean")
    admitted: List[Tuple[Tuple[int, ...], float, float]] = []
    excluded: List[Tuple[int, ...]] = []
    for cfg in walk:
        mean, p95 = pipeline_service_profile(dag, cfg)
        if slo_p95_s - p95 > 0:
            admitted.append((tuple(cfg), mean, p95))
        else:
            excluded.append(tuple(cfg))

    def bottleneck(cfg: Sequence[int]) -> Tuple[int, float, Tuple[float, ...]]:
        per = [dag.stages[j].mean_s[cfg[j]] / dag.stages[j].num_servers
               for j in range(dag.num_stages)]
        b = max(range(len(per)), key=lambda j: (per[j], -j))
        weights = tuple(p / per[b] for p in per)
        return b, per[b], weights

    policies: List[PipelinePolicy] = []
    n = len(admitted)
    for r, (cfg, mean, p95) in enumerate(admitted):
        delta = slo_p95_s - p95
        b, drain_b, weights = bottleneck(cfg)
        c_b = dag.stages[b].num_servers
        s_b = dag.stages[b].mean_s[cfg[b]]
        up = int(math.floor(c_b * delta / s_b))
        down: Optional[int] = None
        if r + 1 < n:
            nxt_cfg, _, nxt_p95 = admitted[r + 1]
            delta_next = slo_p95_s - nxt_p95
            budget = max(0.0, delta_next - slack_buffer_s)
            nb, _, _ = bottleneck(nxt_cfg)
            down = int(math.floor(
                dag.stages[nb].num_servers * budget
                / dag.stages[nb].mean_s[nxt_cfg[nb]]))
        acc = 1.0
        for j, st in enumerate(dag.stages):
            acc *= st.accuracy_of(cfg[j])
        policies.append(PipelinePolicy(
            index=r,
            stage_indices=cfg,
            mean_latency_s=mean,
            p95_latency_s=p95,
            accuracy=acc,
            queuing_slack_s=delta,
            upscale_threshold=max(0, up),
            downscale_threshold=down,
            bottleneck_stage=b,
            stage_weights=weights,
        ))
    fastest_c = (dag.stages[policies[0].bottleneck_stage].num_servers
                 if policies else 1)
    return PipelinePolicyTable(
        slo_p95_s=slo_p95_s,
        slack_buffer_s=slack_buffer_s,
        policies=tuple(policies),
        hysteresis=hysteresis,
        excluded=tuple(excluded),
        num_servers=fastest_c,
    )


@dataclass
class PipelinePlan:
    """Planner output for a workflow DAG: the DAG plus its pipeline
    ladder (:meth:`repro.core.planner.Planner.plan_pipeline`)."""

    dag: WorkflowDAG
    table: PipelinePolicyTable

    def describe(self) -> str:
        topo = " -> ".join(self.dag.stages[j].name
                           for j in self.dag.topological_order())
        lines = [
            f"pipeline SLO p95 = {self.table.slo_p95_s * 1e3:.0f} ms, "
            f"{self.dag.num_stages} stages [{topo}], ladder of "
            f"{self.table.ladder_size} rungs "
            f"({len(self.table.excluded)} infeasible for SLO)"
        ]
        for pol in self.table.policies:
            lines.append(
                f"  [{pol.index}] cfg={list(pol.stage_indices)} "
                f"acc={pol.accuracy:.3f} mean={pol.mean_latency_s * 1e3:.1f}ms "
                f"p95~{pol.p95_latency_s * 1e3:.1f}ms "
                f"bottleneck={self.dag.stages[pol.bottleneck_stage].name} "
                f"N_up={pol.upscale_threshold} N_dn={pol.downscale_threshold}")
        return "\n".join(lines)


# --------------------------------------------------------------------------
# the event-heap oracle
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class StageStats:
    """Per-stage accounting of one :class:`DagSimulator` run.  The
    conservation invariant the property tests pin:
    ``admitted == completed + in_flight + failed`` at every stage, where
    ``in_flight`` counts buffered plus in-service requests at the moment
    the run stopped (always 0 for drained fault-free runs) and ``failed``
    counts requests whose crash-retry budget was exhausted at this stage
    (always 0 without a fault schedule)."""

    name: str
    offered: int
    dropped: int
    completed: int
    in_flight: int
    busy_s: Tuple[float, ...]
    depth_samples: Tuple[Tuple[float, int], ...]
    config_timeline: Tuple[Tuple[float, int], ...]
    failed: int = 0
    retried: int = 0

    @property
    def admitted(self) -> int:
        return self.offered - self.dropped


@dataclass
class DagSimulationResult(SimulationResult):
    """DAG run result.  The inherited surface reads end-to-end: each
    ``completed`` record is the *sink* dispatch (``arrival_s`` is the
    original external arrival, so ``latency_s`` is the end-to-end sojourn
    and ``wait_s`` the time before sink service started);
    ``config_timeline`` is the *pipeline rung* timeline;
    ``queue_depth_samples`` totals buffered depth across stages;
    ``per_server_busy_s`` concatenates the stage pools in stage order —
    all of which makes a single-stage run bit-identical to
    :class:`repro.serving.simulator.ServingSimulator`."""

    stage_stats: Tuple[StageStats, ...] = ()
    request_accuracy: Dict[int, float] = field(default_factory=dict)

    def mean_pipeline_accuracy(self) -> float:
        """Mean over completed requests of the product of the stage
        accuracy factors each request was actually served under (a
        request can traverse different rungs at different stages)."""
        if not self.completed:
            return 0.0
        return sum(self.request_accuracy[r.request_id]
                   for r in self.completed) / len(self.completed)


def _stage_seed(seed: int, j: int) -> int:
    """Stage j's RNG seed.  Stage 0 uses ``seed`` itself — the degenerate
    single-stage DAG must replay ``ServingSimulator``'s exact
    ``random.Random(seed)`` stream — and later stages use independent
    derived streams."""
    return seed if j == 0 else (seed * 1_000_003 + j) & 0x7FFFFFFF


@dataclass
class DagSimulator:
    """Event-heap simulator routing requests stage-to-stage through a
    :class:`WorkflowDAG` — the exact oracle for compound pipelines.

    One shared-FIFO :class:`repro.serving.scheduler.Scheduler` per stage
    (per-stage worker pools and admission bounds from the
    :class:`StageSpec`); completions forward the batch's requests to the
    successor stages (joins wait for every predecessor), and sink
    dispatches produce the end-to-end completion records.  ``controller``
    (optional) is a pipeline-level
    :class:`repro.core.elastico.ElasticoController` over a
    :class:`PipelinePolicyTable`: it consumes per-stage buffered depths
    (:meth:`~repro.core.elastico.ElasticoController.observe_stages`) at
    every event and control tick, and its rung switches are applied to
    each stage via
    :meth:`repro.serving.scheduler.Scheduler.set_active_index` (stages
    whose config the new rung leaves unchanged are untouched).  Without a
    controller the run is pinned to ``static_rung`` of ``rungs`` — or to
    an explicit ``static_stage_indices`` vector.

    ``run(..., drain=False)`` stops processing at ``duration_s`` and
    reports the in-flight population per stage instead of draining the
    backlog — the mode the conservation property tests use.

    Degenerate collapse: a one-stage DAG reproduces
    :class:`repro.serving.simulator.ServingSimulator` bit-for-bit (see
    module docstring)."""

    dag: WorkflowDAG
    controller: Optional[ElasticoController] = None
    static_rung: int = 0
    rungs: Optional[Sequence[Sequence[int]]] = None
    static_stage_indices: Optional[Sequence[int]] = None
    control_tick_s: float = 0.25
    switch_latency_s: float = 0.010
    seed: int = 0
    # fault plane (beyond-paper): per-stage worker crashes/recoveries,
    # straggler windows, and stage-wide brownouts
    # (:mod:`repro.serving.faults` — every fault here must carry a stage
    # index).  Crash semantics mirror the flat simulator: the in-flight
    # batch on a crashed stage worker is cancelled and requeued at that
    # stage's queue head, retrying up to ``retry_budget`` times before
    # counting as ``failed`` at that stage.  An empty schedule (or None)
    # reproduces the fault-free run bit-for-bit.
    faults: Optional[FaultSchedule] = None
    retry_budget: int = 3

    def _resolve_rungs(self) -> List[Tuple[int, ...]]:
        if self.static_stage_indices is not None:
            if self.controller is not None:
                raise ValueError("static_stage_indices is for controller-"
                                 "free runs")
            return [self.dag.validate_stage_indices(self.static_stage_indices)]
        if self.rungs is not None:
            return [self.dag.validate_stage_indices(r) for r in self.rungs]
        if self.controller is not None:
            table = self.controller.table
            if hasattr(table, "stage_indices"):
                return [self.dag.validate_stage_indices(table.stage_indices(k))
                        for k in range(table.ladder_size)]
            # a single-stage AQM table: rung k is config k on the only stage
            if self.dag.num_stages != 1:
                raise ValueError(
                    "a multi-stage DAG needs pipeline rungs: pass rungs=, "
                    "or a controller over a PipelinePolicyTable")
            return [self.dag.validate_stage_indices((k,))
                    for k in range(table.ladder_size)]
        # static run without explicit rungs: diagonal over the shortest
        # stage ladder
        depth = min(st.num_configs for st in self.dag.stages)
        return [self.dag.validate_stage_indices((k,) * self.dag.num_stages)
                for k in range(depth)]

    def run(self, arrivals: Sequence[float], duration_s: float, *,
            drain: bool = True) -> DagSimulationResult:
        dag = self.dag
        topo = dag.topological_order()
        sources = dag.sources()
        sink = dag.sink()
        preds = [dag.predecessors(j) for j in range(dag.num_stages)]
        succs = [dag.successors(j) for j in range(dag.num_stages)]
        rungs = self._resolve_rungs()

        faults = (self.faults
                  if self.faults is not None and not self.faults.is_empty()
                  else None)
        if self.retry_budget < 0:
            raise ValueError("retry_budget must be >= 0")
        if faults is not None:
            scoped = ([(c.stage, c.worker_id) for c in faults.crashes]
                      + [(s.stage, s.worker_id) for s in faults.stragglers]
                      + [(b.stage, 0) for b in faults.brownouts])
            for j, w in scoped:
                if j is None or not 0 <= j < dag.num_stages:
                    raise ValueError(
                        f"DAG faults must carry a stage index in "
                        f"[0, {dag.num_stages}); got {j!r}")
                if w >= dag.stages[j].num_servers:
                    raise ValueError(
                        f"fault addresses worker {w} of stage "
                        f"{dag.stages[j].name!r} "
                        f"(c={dag.stages[j].num_servers})")

        ctrl = self.controller
        if ctrl is not None:
            ctrl.reset()
            rung = ctrl.current_index
            if rung >= len(rungs):
                raise ValueError("controller ladder larger than rung list")
        else:
            if not 0 <= self.static_rung < len(rungs):
                raise ValueError(f"static_rung {self.static_rung} out of "
                                 f"range [0, {len(rungs)})")
            rung = self.static_rung
        cfg = rungs[rung]

        rngs = [random.Random(_stage_seed(self.seed, j))
                for j in range(dag.num_stages)]
        samplers = [st.sampler() for st in dag.stages]
        scheds = [Scheduler(
            num_workers=st.num_servers,
            max_queue_depth=st.max_queue_depth,
            static_index=cfg[j],
            num_configs=st.num_configs,
            switch_latency_s=self.switch_latency_s,
            record_initial_config=True,
        ) for j, st in enumerate(dag.stages)]

        # event heap: (time, order, kind, payload) — the flat simulator's
        # exact structure, with completion payloads carrying the stage
        events: List[Tuple[float, int, str, object]] = []
        order = 0
        for i, t in enumerate(arrivals):
            heapq.heappush(events, (t, order, "arrival", i))
            order += 1
        t = 0.0
        while t < duration_s:
            heapq.heappush(events, (t, order, "tick", None))
            order += 1
            t += self.control_tick_s
        if faults is not None:
            # capacity events are seeded after arrivals and ticks, so at
            # equal times they process after same-time ticks/arrivals
            for j in range(dag.num_stages):
                for ft, fkind, fworker in faults.capacity_events(j):
                    heapq.heappush(events, (ft, order, fkind, (j, fworker)))
                    order += 1

        arrival_time: Dict[int, float] = {i: a for i, a in enumerate(arrivals)}
        busy: List[List[float]] = [[0.0] * st.num_servers
                                   for st in dag.stages]
        completed: List[CompletedRequest] = []
        depth_samples: List[Tuple[float, int]] = []
        stage_depth_samples: List[List[Tuple[float, int]]] = [
            [] for _ in dag.stages]
        pending: Dict[Tuple[int, int], Tuple[object, ...]] = {}
        join_count: Dict[Tuple[int, int], int] = {}
        stage_completed = [0] * dag.num_stages
        acc: Dict[int, float] = {}
        rung_timeline: List[Tuple[float, int]] = [(0.0, rung)]
        # fault-tracking state, all untouched when faults is None: worker
        # epochs (a crash bumps the epoch so the stale completion event is
        # skipped), dispatch metadata needed to unwind a crashed batch,
        # and per-(stage, request) crash-retry attempts
        epoch: Dict[Tuple[int, int], int] = {}
        meta: Dict[Tuple[int, int], Tuple[int, float, float, int, float]] = {}
        attempts: Dict[Tuple[int, int], int] = {}

        def execute_stage(j: int, polled) -> None:
            nonlocal order
            dispatches, lingers = polled
            assert not lingers     # B = 1: no linger is ever scheduled
            for d in dispatches:
                svc = samplers[j](d.config_index, rngs[j])
                if faults is not None:
                    svc *= faults.inflation(d.worker_id, d.start_s, stage=j)
                comp = d.start_s + svc
                busy[j][d.worker_id] += comp - d.start_s
                a_factor = dag.stages[j].accuracy_of(d.config_index)
                for rid in d.items:
                    if a_factor != 1.0 or rid in acc:
                        acc[rid] = acc.get(rid, 1.0) * a_factor
                rec_lo = len(completed)
                if j == sink:
                    for rid in d.items:
                        completed.append(CompletedRequest(
                            request_id=rid,
                            arrival_s=arrival_time[rid],
                            start_s=d.start_s,
                            completion_s=comp,
                            config_index=d.config_index,
                            server_id=d.worker_id,
                            batch_size=d.batch_size,
                        ))
                pending[(j, d.worker_id)] = d.items
                ep = 0
                if faults is not None:
                    key = (j, d.worker_id)
                    ep = epoch.get(key, 0)
                    meta[key] = (ep, d.start_s, comp, rec_lo, a_factor)
                heapq.heappush(events, (comp, order, "completion",
                                        (j, d.worker_id, ep)))
                order += 1

        def poll_all(now: float) -> None:
            for j in topo:
                execute_stage(j, scheds[j].poll(now))

        def forward(j: int, items, now: float) -> None:
            for s in succs[j]:
                need = len(preds[s])
                for rid in items:
                    if need > 1:
                        key = (s, rid)
                        got = join_count.get(key, 0) + 1
                        if got < need:
                            join_count[key] = got
                            continue
                        join_count.pop(key, None)
                    scheds[s].offer(rid, now)

        def observe_ctrl(now: float) -> None:
            nonlocal rung, cfg
            if ctrl is None:
                return
            depths = [scheds[j].buffered() for j in range(dag.num_stages)]
            ev = ctrl.observe_stages(depths, now)
            if ev is not None:
                rung = ev.to_index
                cfg = rungs[rung]
                for j in range(dag.num_stages):
                    scheds[j].set_active_index(cfg[j], now)
                rung_timeline.append((now, rung))

        stopped_early = False
        while events:
            now, _, kind, payload = heapq.heappop(events)
            if now > duration_s and kind == "tick":
                continue
            if not drain and now > duration_s:
                heapq.heappush(events, (now, 0, kind, payload))
                stopped_early = True
                break
            if kind == "arrival":
                for s in sources:
                    scheds[s].offer(int(payload), now)  # type: ignore[arg-type]
                poll_all(now)
                observe_ctrl(now)
            elif kind == "completion":
                j, worker, ep = payload  # type: ignore[misc]
                if faults is not None:
                    if ep != epoch.get((j, worker), 0):
                        continue    # stale: the worker crashed mid-batch
                    meta.pop((j, worker), None)
                scheds[j].release(worker, now)
                items = pending.pop((j, worker))
                stage_completed[j] += len(items)
                forward(j, items, now)
                poll_all(now)
                observe_ctrl(now)
            elif kind == "crash":
                j, w = payload  # type: ignore[misc]
                scheds[j].mark_worker_down(w, now)
                requeue: List[object] = []
                key = (j, w)
                if key in meta:
                    # cancel the in-flight batch: refund the unserved busy
                    # time, undo the accuracy factor, null the sink
                    # records, and requeue survivors at the queue head
                    ep, start_s, comp_s, rec_lo, a_factor = meta.pop(key)
                    epoch[key] = ep + 1
                    items = pending.pop(key)
                    busy[j][w] -= comp_s - max(start_s, min(now, comp_s))
                    if a_factor != 1.0:
                        for rid in items:
                            acc[rid] = acc.get(rid, 1.0) / a_factor
                    if j == sink:
                        for i in range(rec_lo, rec_lo + len(items)):
                            completed[i] = None  # type: ignore[call-overload]
                    for rid in items:
                        a = attempts.get((j, rid), 0) + 1
                        attempts[(j, rid)] = a
                        if a > self.retry_budget:
                            scheds[j].record_failed(1)
                        else:
                            requeue.append(rid)
                    scheds[j].worker_idle_while_down(w)
                requeue.extend(scheds[j].drain_worker_backlog(w))
                scheds[j].requeue_front(requeue)
                poll_all(now)
                observe_ctrl(now)
            elif kind == "recover":
                j, w = payload  # type: ignore[misc]
                scheds[j].mark_worker_up(w, now)
                poll_all(now)
                observe_ctrl(now)
            else:   # control tick
                observe_ctrl(now)
                poll_all(now)
                total = sum(sched.buffered() for sched in scheds)
                depth_samples.append((now, total))
                for j in range(dag.num_stages):
                    stage_depth_samples[j].append((now, scheds[j].buffered()))

        if faults is not None:
            # crashed sink dispatches left None placeholders (so earlier
            # record indices stayed stable); drop them now
            completed = [r for r in completed if r is not None]
        in_service = [0] * dag.num_stages
        for (j, _w), items in pending.items():
            in_service[j] += len(items)
        stats = tuple(StageStats(
            name=dag.stages[j].name,
            offered=scheds[j].offered,
            dropped=scheds[j].dropped,
            completed=stage_completed[j],
            in_flight=scheds[j].buffered() + in_service[j],
            busy_s=tuple(busy[j]),
            depth_samples=tuple(stage_depth_samples[j]),
            config_timeline=tuple(scheds[j].config_timeline),
            failed=scheds[j].failed,
            retried=scheds[j].retried,
        ) for j in range(dag.num_stages))
        assert drain or stopped_early or not events

        flat_busy: List[float] = []
        for row in busy:
            flat_busy.extend(row)
        return DagSimulationResult(
            completed=completed,
            switch_events=list(ctrl.events) if ctrl is not None else [],
            config_timeline=rung_timeline,
            queue_depth_samples=depth_samples,
            duration_s=duration_s,
            num_servers=sum(st.num_servers for st in dag.stages),
            per_server_busy_s=flat_busy,
            num_batches=sum(s.num_batches for s in scheds),
            offered=scheds[sources[0]].offered,
            dropped=sum(s.dropped for s in scheds),
            failed=sum(s.failed for s in scheds),
            retried=sum(s.retried for s in scheds),
            in_flight=sum(s.in_flight for s in stats),
            stage_stats=stats,
            request_accuracy={r.request_id: acc.get(r.request_id, 1.0)
                              for r in completed},
        )


# --------------------------------------------------------------------------
# the chained-recursion fast path (exact for static runs)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class DagFastResult:
    """Result of :func:`simulate_dag`: sink completion records in sink
    dispatch order (bit-identical to the oracle's), plus the per-stage
    completion grid aligned to the original arrival order."""

    completed: Tuple[CompletedRequest, ...]
    stage_completions: np.ndarray       # (num_stages, n), original order
    arrivals: np.ndarray

    def latencies(self) -> np.ndarray:
        return self.stage_completions[-1] - self.arrivals

    def slo_compliance(self, slo_s: float) -> float:
        lats = self.latencies()
        return float((lats <= slo_s).mean()) if lats.size else 1.0


def simulate_dag(dag: WorkflowDAG, arrivals: Sequence[float], *,
                 stage_indices: Sequence[int],
                 seed: int = 0) -> DagFastResult:
    """Chained-Lindley replay of a *static* DAG scenario — stage n's
    departures are stage n+1's arrivals; a join's arrival is the
    element-wise max over its predecessors' completions.

    Exactness contract (the eligibility mirror of
    :func:`repro.serving.fastsim.simulate`): no controller, no admission
    bounds, B = 1 — then each stage's FIFO dispatch order is its arrival
    order, service draws come from the same per-stage ``random.Random``
    streams in the same order as :class:`DagSimulator`, and the start /
    completion floats are computed by the identical ``max`` + one-add
    ops, so the sink records are **bit-for-bit** the oracle's (property-
    tested in ``tests/test_dag.py``).  Stages with ``c > 1`` run the
    sorted-workload Kiefer-Wolfowitz recursion, exact for the same
    reason.  Ordering between equal-time stage arrivals follows the
    stable sort by original request id; continuous (lognormal) service
    makes ties measure-zero."""
    cfg = dag.validate_stage_indices(stage_indices)
    if any(st.max_queue_depth is not None for st in dag.stages):
        raise ValueError("fast path requires unbounded stage queues "
                         "(admission drops need the event-heap oracle)")
    A = np.asarray(list(arrivals), dtype=float)
    n = A.size
    topo = dag.topological_order()
    sink = dag.sink()
    comp = np.zeros((dag.num_stages, n), dtype=float)
    sink_records: List[CompletedRequest] = []
    for j in topo:
        st = dag.stages[j]
        pr = dag.predecessors(j)
        if not pr:
            arr_j = A
        elif len(pr) == 1:
            arr_j = comp[pr[0]]
        else:
            arr_j = np.max(np.stack([comp[p] for p in pr]), axis=0)
        order = np.argsort(arr_j, kind="stable")
        rng = random.Random(_stage_seed(seed, j))
        sampler = st.sampler()
        k = cfg[j]
        c = st.num_servers
        out = np.empty(n, dtype=float)
        if c == 1:
            prev = 0.0
            first = True
            for rid in order:
                a = float(arr_j[rid])
                svc = sampler(k, rng)
                start = a if first or a > prev else prev
                first = False
                done = start + svc
                prev = done
                out[rid] = done
                if j == sink:
                    sink_records.append(CompletedRequest(
                        request_id=int(rid), arrival_s=float(A[rid]),
                        start_s=start, completion_s=done, config_index=k))
        else:
            free = [0.0] * c
            for rid in order:
                a = float(arr_j[rid])
                svc = sampler(k, rng)
                f0 = free[0]
                start = a if a > f0 else f0
                done = start + svc
                free[0] = done
                free.sort()
                out[rid] = done
                if j == sink:
                    sink_records.append(CompletedRequest(
                        request_id=int(rid), arrival_s=float(A[rid]),
                        start_s=start, completion_s=done, config_index=k))
        comp[j] = out
    return DagFastResult(completed=tuple(sink_records),
                         stage_completions=comp, arrivals=A)


# --------------------------------------------------------------------------
# the vectorized validation grid
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class PipelineSweep:
    """Rungs x loads grids, replication-averaged, from
    :func:`sweep_pipeline`; ``predicted_sojourn_s`` is the queueing-
    network prediction (:func:`pipeline_sojourn`) for each cell."""

    arrival_rates_qps: Tuple[float, ...]
    replications: int
    duration_s: float
    mean_latency_s: Tuple[Tuple[float, ...], ...]       # (K, L)
    p95_latency_s: Tuple[Tuple[float, ...], ...]
    slo_compliance: Tuple[Tuple[float, ...], ...]
    predicted_sojourn_s: Tuple[Tuple[float, ...], ...]
    num_requests: int

    def sojourn_model_error(self) -> float:
        """Max relative error of the network model over cells with a
        finite prediction and a meaningful sojourn (> 1 ms)."""
        worst = 0.0
        for sim_row, pred_row in zip(self.mean_latency_s,
                                     self.predicted_sojourn_s):
            for sim, pred in zip(sim_row, pred_row):
                if math.isfinite(pred) and pred > 1e-3:
                    worst = max(worst, abs(sim - pred) / pred)
        return worst


def sweep_pipeline(dag: WorkflowDAG,
                   rungs: Sequence[Sequence[int]], *,
                   arrival_rates_qps: Sequence[float],
                   duration_s: float = 120.0,
                   replications: int = 4,
                   slo_s: float = 1.0,
                   seed: int = 0,
                   backend: str = "auto",
                   scan_impl: str = "auto") -> PipelineSweep:
    """Replay every pipeline rung against a grid of Poisson arrival rates
    with R replications via the chained closed-form recursion
    (:func:`repro.serving.fastsim.chained_lindley` per topological
    stage, element-wise max at joins) — the DAG analogue of
    :func:`repro.serving.fastsim.simulate_batch`, used by
    :meth:`repro.core.planner.Planner.validate_pipeline`.

    Streams are content-keyed (rate / stage-config fingerprints), so each
    (replication, rung, load) cell is a pure function of its inputs —
    lanes share arrival traces (common random numbers across rungs).

    ``backend`` selects the evaluation engine.  ``"numpy"`` is the
    authoritative reference: the original per-(replication, rung, load)
    loop, byte-stable across PRs.  ``"jax"`` batches the whole sweep
    into one (R, K, L) padded grid in the
    :func:`repro.serving.fastsim.simulate_batch` style — identical
    content-keyed host draws (so common random numbers across rungs are
    preserved, and coinciding per-stage configs share one draw), the
    stage network evaluated through jitted device scans with host-side
    permutations (:func:`repro.serving.fastsim._jax_pipeline_grid` —
    runs of c = 1 stages lower to one fused multi-stage scan), and
    per-cell p95 order statistics on the host with the same
    non-interpolated convention.
    ``"auto"`` picks jax only for grids whose ``stages x slots`` product
    clears :func:`repro.serving.fastsim.resolve_backend`'s amortization
    bar.  With ``scan_impl="sequential"`` (the CPU ``"auto"``
    resolution) the jax grids are bit-exact against numpy; associative /
    pallas impls are float64-allclose."""
    from .fastsim import (
        _fingerprint,
        chained_lindley,
        lognormal_params,
        resolve_backend,
    )

    rung_cfgs = [dag.validate_stage_indices(r) for r in rungs]
    rates = [float(r) for r in arrival_rates_qps]
    if not rates or not rung_cfgs:
        raise ValueError("need at least one rung and one arrival rate")
    if duration_s <= 0 or replications < 1:
        raise ValueError("duration must be positive, replications >= 1")
    K, L, R = len(rung_cfgs), len(rates), int(replications)
    topo = dag.topological_order()
    sink = dag.sink()

    # pre-draw the per-(replication, load) arrival traces: each has its
    # own content-keyed generator, so hoisting the draws out of the sweep
    # loop is byte-identical to drawing them inline
    traces: List[List[np.ndarray]] = []
    for r in range(R):
        row = []
        for rate in rates:
            trace_key = [seed & 0x7FFFFFFF, 11, r,
                         _fingerprint(np.float64(rate).tobytes()),
                         _fingerprint(np.float64(duration_s).tobytes())]
            gen = np.random.Generator(np.random.PCG64(
                np.random.SeedSequence(trace_key)))
            n = gen.poisson(rate * duration_s)
            row.append(np.sort(gen.uniform(0.0, duration_s, size=n))
                       if n > 0 else np.empty(0, dtype=float))
        traces.append(row)
    n_max = max((t.size for row in traces for t in row), default=0)
    max_c = max(dag.stages[j].num_servers for j in topo)
    chosen = resolve_backend(backend, num_servers=max_c,
                             total_slots=R * K * L * n_max,
                             num_stages=len(topo))

    if chosen == "jax" and n_max > 0:
        lat_sum, p95_acc, ok, total = _sweep_pipeline_jax(
            dag, topo, sink, rung_cfgs, rates, traces,
            slo_s=slo_s, seed=seed, scan_impl=scan_impl)
        predicted = tuple(
            tuple(pipeline_sojourn(dag, cfg, rate) for rate in rates)
            for cfg in rung_cfgs)
        return PipelineSweep(
            arrival_rates_qps=tuple(rates),
            replications=R,
            duration_s=duration_s,
            mean_latency_s=tuple(map(tuple, lat_sum / R)),
            p95_latency_s=tuple(map(tuple, p95_acc / R)),
            slo_compliance=tuple(map(tuple, ok / R)),
            predicted_sojourn_s=predicted,
            num_requests=total,
        )

    lat_sum = np.zeros((K, L))
    p95_acc = np.zeros((K, L))
    ok = np.zeros((K, L))
    total = 0
    for r in range(R):
        for l, rate in enumerate(rates):
            A = traces[r][l]
            n = A.size
            if n == 0:
                ok[:, l] += 1.0
                continue
            for k, cfg in enumerate(rung_cfgs):
                services = []
                servers = []
                for j in topo:
                    st = dag.stages[j]
                    m = st.mean_s[cfg[j]]
                    p95 = None if st.p95_s is None else st.p95_s[cfg[j]]
                    skey = [seed & 0x7FFFFFFF, 12, r, j,
                            _fingerprint(np.float64(m).tobytes()
                                         + np.float64(p95 or 0.0).tobytes()),
                            _fingerprint(np.float64(rate).tobytes())]
                    sgen = np.random.Generator(np.random.PCG64(
                        np.random.SeedSequence(skey)))
                    if p95 is not None:
                        mu, sigma = lognormal_params(m, p95)
                        services.append(sgen.lognormal(mu, sigma, size=n))
                    else:
                        services.append(np.full(n, m))
                    servers.append(st.num_servers)
                comp = _chain_dag(dag, topo, A, services, servers)
                lats = comp[sink] - A
                lat_sum[k, l] += lats.mean()
                idx = int(0.95 * (n - 1))
                p95_acc[k, l] += np.partition(lats, idx)[idx]
                ok[k, l] += (lats <= slo_s).mean()
                total += n
    predicted = tuple(
        tuple(pipeline_sojourn(dag, cfg, rate) for rate in rates)
        for cfg in rung_cfgs)
    return PipelineSweep(
        arrival_rates_qps=tuple(rates),
        replications=R,
        duration_s=duration_s,
        mean_latency_s=tuple(map(tuple, lat_sum / R)),
        p95_latency_s=tuple(map(tuple, p95_acc / R)),
        slo_compliance=tuple(map(tuple, ok / R)),
        predicted_sojourn_s=predicted,
        num_requests=total,
    )


def _pipeline_topo_meta(dag: WorkflowDAG,
                        topo: Sequence[int]) -> Tuple[Tuple, ...]:
    """Static topology descriptor for the batched jax DAG evaluator:
    per topological position, ``(predecessor positions, num_servers,
    needs_sort)``.  ``needs_sort`` propagates sortedness statically:
    sorted external arrivals stay sorted through c = 1 stages (FIFO
    completions are non-decreasing in dispatch order, and the stable
    argsort of a sorted vector is the identity — even under ties) and
    through joins of sorted branches (element-wise max preserves
    monotonicity); only stages downstream of a c > 1 stage pay a
    device-side stable argsort."""
    pos = {j: i for i, j in enumerate(topo)}
    sorted_out: List[bool] = []
    meta = []
    for i, j in enumerate(topo):
        preds = tuple(pos[p] for p in dag.predecessors(j))
        in_sorted = all(sorted_out[p] for p in preds) if preds else True
        c = dag.stages[j].num_servers
        sorted_out.append(in_sorted and c == 1)
        meta.append((preds, c, not in_sorted))
    return tuple(meta)


def _sweep_pipeline_jax(dag: WorkflowDAG, topo: Sequence[int], sink: int,
                        rung_cfgs: Sequence[Tuple[int, ...]],
                        rates: Sequence[float],
                        traces: Sequence[Sequence[np.ndarray]], *,
                        slo_s: float, seed: int, scan_impl: str):
    """Batched jax evaluation of the pipeline sweep: one padded
    (R*K*L, N_max) grid per array, the whole stage network jitted,
    per-cell statistics on the host with the numpy path's exact
    conventions (non-interpolated p95 via ``np.partition``, identical
    accumulation order over replications).

    Host draws reuse the numpy path's content-keyed streams byte-for-
    byte; because service streams are keyed by (replication, stage,
    config content, rate) — not by rung — rungs that pin the same config
    for a stage share one draw (the common-random-numbers contract),
    which the cache below exploits instead of re-drawing per rung.
    Padded arrival slots carry ``+inf`` so they stay trailing through
    every device-side sort and join."""
    from . import fastsim as _fs
    from jax.experimental import enable_x64

    from .fastsim import _fingerprint, lognormal_params

    R, L, K = len(traces), len(rates), len(rung_cfgs)
    J = len(topo)
    n_max = max(t.size for row in traces for t in row)
    B = R * K * L
    base = seed & 0x7FFFFFFF

    A = np.full((B, n_max), np.inf, dtype=float)
    S = np.zeros((J, B, n_max), dtype=float)
    cell_counts = np.zeros(B, dtype=np.int64)

    def cell(r: int, k: int, l: int) -> int:
        return (r * K + k) * L + l

    svc_cache: dict = {}
    for r in range(R):
        for l, rate in enumerate(rates):
            trace = traces[r][l]
            n = trace.size
            for k, cfg in enumerate(rung_cfgs):
                b = cell(r, k, l)
                cell_counts[b] = n
                if n == 0:
                    continue
                A[b, :n] = trace
                for i, j in enumerate(topo):
                    st = dag.stages[j]
                    m = st.mean_s[cfg[j]]
                    p95 = None if st.p95_s is None else st.p95_s[cfg[j]]
                    ck = (r, l, j, m, p95)
                    svc = svc_cache.get(ck)
                    if svc is None:
                        skey = [base, 12, r, j,
                                _fingerprint(np.float64(m).tobytes()
                                             + np.float64(p95 or 0.0)
                                             .tobytes()),
                                _fingerprint(np.float64(rate).tobytes())]
                        sgen = np.random.Generator(np.random.PCG64(
                            np.random.SeedSequence(skey)))
                        if p95 is not None:
                            mu, sigma = lognormal_params(m, p95)
                            svc = sgen.lognormal(mu, sigma, size=n)
                        else:
                            svc = np.full(n, m)
                        svc_cache[ck] = svc
                    S[i, b, :n] = svc

    topo_meta = _pipeline_topo_meta(dag, topo)
    impl = _fs._resolve_scan_impl(scan_impl)
    sink_pos = list(topo).index(sink)
    # One strided pass to the scan layout (J, N, B); per-stage slices are
    # then contiguous device pushes inside the grid evaluator.
    S_nb = np.ascontiguousarray(S.transpose(0, 2, 1))
    with enable_x64():
        sink_comp = _fs._jax_pipeline_grid(
            A, S_nb, topo_meta, impl,
            out_pos=(sink_pos,))[sink_pos]             # (B, N_max)

    lat_sum = np.zeros((K, L))
    p95_acc = np.zeros((K, L))
    ok = np.zeros((K, L))
    total = 0
    for r in range(R):
        for l in range(L):
            n = traces[r][l].size
            for k in range(K):
                if n == 0:
                    ok[k, l] += 1.0
                    continue
                b = cell(r, k, l)
                lats = sink_comp[b, :n] - traces[r][l]
                lat_sum[k, l] += lats.mean()
                idx = int(0.95 * (n - 1))
                p95_acc[k, l] += np.partition(lats, idx)[idx]
                ok[k, l] += (lats <= slo_s).mean()
                total += n
    return lat_sum, p95_acc, ok, total


def _chain_dag(dag: WorkflowDAG, topo: Sequence[int], A: np.ndarray,
               services: Sequence[np.ndarray],
               servers: Sequence[int], *,
               backend: str = "numpy",
               scan_impl: str = "auto") -> np.ndarray:
    """Vectorized DAG chaining: run each topological stage through
    :func:`repro.serving.fastsim.chained_lindley` (one stage at a time so
    joins can max their predecessors' completions).  ``services[i]`` is
    the service stream of the i-th *topological* stage, consumed in that
    stage's dispatch order.  ``backend`` / ``scan_impl`` forward to
    :func:`repro.serving.fastsim.chained_lindley` per stage (the parity
    property tests drive the jax engine through this path against the
    numpy reference and the event-heap oracle)."""
    from .fastsim import chained_lindley

    comp = np.zeros((dag.num_stages, A.size))
    for i, j in enumerate(topo):
        pr = dag.predecessors(j)
        if not pr:
            arr_j = A
        elif len(pr) == 1:
            arr_j = comp[pr[0]]
        else:
            arr_j = np.max(np.stack([comp[p] for p in pr]), axis=0)
        comp[j] = chained_lindley(arr_j, [services[i]],
                                  num_servers=[servers[i]],
                                  backend=backend,
                                  scan_impl=scan_impl)[-1]
    return comp
