"""Chunked arrival-trace generation and streaming replay.

The sweep engine (:mod:`repro.serving.fastsim`) materializes every trace —
right for R x K x L grids of bounded cells, hopeless for the million-user
replays the Planner wants validated against realistic day-scale load: a
1e8-request trace is ~0.8 GB *per array*, and the scalar thinning loop in
:func:`repro.serving.workload.generate_arrivals` would take minutes before
a single request is simulated.  This module streams instead:

- **Chunked generators** (:class:`ChunkedPoissonTrace` for rate-function
  loads — diurnal, flash crowd — and :class:`ChunkedMMPPTrace` for the
  Markov-modulated bursty process) yield sorted numpy chunks of arrival
  times covering ``[0, duration_s)`` window by window.  Thinning is
  vectorized per window (Lewis & Shedler with a per-window envelope), so
  generation cost is a few array ops per chunk and resident memory is
  O(chunk), never O(total requests).
- **Streaming replay** (:func:`replay_mix` / :func:`replay_trace`) runs
  the Lindley (c = 1) or Kiefer-Wolfowitz (c > 1) recursion chunk by
  chunk, carrying the workload state across chunk boundaries — the
  replayed system is *identical* to simulating the whole trace at once;
  only the statistics are streamed.  Mean wait / latency, SLO compliance,
  throughput, and max latency are exact; p95 comes from a fixed-memory
  power-of-two rebinned histogram (:class:`StreamingQuantile`) whose
  error is bounded by one bin width (reported as ``p95_resolution_s``).

Engines.  c = 1 replay uses the closed-form prefix-scan form of the
Lindley recursion — with prefix sums ``P_i = sum_{j<=i} S_j`` and initial
backlog ``C_0``, ``C_i = P_i + max(C_0, max_{j<=i}(A_j - P_{j-1}))`` — two
vectorized cumulative ops per chunk, no Python-per-request loop.  c > 1
prefers the jax comparator scan from the fastsim backend work (carried
sorted workload vector, unrolled insertion network) and falls back to a
numpy per-request loop when jax is unavailable.  Replay therefore never
touches the event heap; the event-heap simulator remains the *oracle*
these engines are tested against on small traces.

Determinism and purity.  A trace is fully determined by its constructor
parameters and ``seed`` (the window schedule is part of the identity —
documented on each class).  Service streams are keyed by content
fingerprints ``(seed, lane-config, trace-fingerprint)`` exactly in the
:func:`repro.serving.fastsim.simulate_batch` style, so replaying a subset
of the mix ladder reproduces those lanes' statistics bit-for-bit.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .fastsim import (
    _fingerprint,
    jax_available,
    jax_unavailable_reason,
    lognormal_params,
)
from . import fastsim as _fs

__all__ = [
    "ChunkedMMPPTrace",
    "ChunkedPoissonTrace",
    "ReplayStats",
    "StreamingQuantile",
    "bursty_mmpp_trace",
    "diurnal_trace",
    "flash_crowd_trace",
    "DagReplayStats",
    "replay_dag",
    "replay_mix",
    "replay_trace",
]

# Vectorized rate function: array of times -> array of instantaneous rates.
VectorRateFn = Callable[[np.ndarray], np.ndarray]

_DEFAULT_CHUNK_REQUESTS = 262_144


def _thin_window(rng: np.random.Generator, t0: float, t1: float,
                 lam: float, rate_fn: VectorRateFn) -> np.ndarray:
    """Vectorized Lewis-Shedler thinning on one window: homogeneous
    candidates at envelope rate ``lam``, kept with probability
    ``rate(t) / lam``.  Sorted candidates stay sorted through the mask."""
    if lam <= 0.0 or t1 <= t0:
        return np.empty(0, dtype=float)
    n = int(rng.poisson(lam * (t1 - t0)))
    if n == 0:
        return np.empty(0, dtype=float)
    times = np.sort(rng.uniform(t0, t1, size=n))
    keep = rng.uniform(0.0, lam, size=n) <= rate_fn(times)
    return times[keep]


class ChunkedPoissonTrace:
    """Non-homogeneous Poisson arrivals from a vectorized rate function,
    yielded as sorted chunks of O(``window_s`` x rate) times.

    The envelope for each window is probed at 65 evenly spaced points with
    5% headroom (capped by the global ``rate_max``), which is exact for
    the smooth built-in patterns; pass an explicit ``rate_max`` or a
    finer ``window_s`` for rate functions with sub-window spikes.

    Identity: the realized trace is a pure function of ``(label, seed,
    duration_s, window_s, rate_max)`` — the window schedule is part of the
    trace, so two traces differing only in ``window_s`` are *different*
    (equally distributed) traces.  ``fingerprint`` hashes exactly that
    tuple and keys the replay's service streams.
    """

    kind = "nhpp"

    def __init__(self, rate_fn: VectorRateFn, duration_s: float, *,
                 seed: int = 0, label: str = "nhpp",
                 rate_max: Optional[float] = None,
                 window_s: Optional[float] = None):
        if duration_s <= 0:
            raise ValueError("duration_s must be positive")
        self.rate_fn = rate_fn
        self.duration_s = float(duration_s)
        self.seed = int(seed)
        self.label = label
        if rate_max is None:
            probes = rate_fn(np.linspace(0.0, self.duration_s, 2049))
            rate_max = float(np.max(probes)) * 1.05 + 1e-9
        if rate_max <= 0:
            raise ValueError("rate_max must be positive")
        self.rate_max = float(rate_max)
        if window_s is None:
            window_s = _DEFAULT_CHUNK_REQUESTS / self.rate_max
        self.window_s = float(min(max(window_s, 1e-3), self.duration_s))
        self.fingerprint = _fingerprint(
            b"nhpp" + label.encode() + np.float64(self.duration_s).tobytes()
            + np.int64(self.seed).tobytes()
            + np.float64(self.window_s).tobytes()
            + np.float64(self.rate_max).tobytes())

    def chunks(self) -> Iterator[np.ndarray]:
        """Yield sorted arrival-time chunks; concatenated, they are one
        NHPP realization on ``[0, duration_s)``.  Empty windows are
        skipped."""
        rng = np.random.Generator(np.random.PCG64(
            np.random.SeedSequence([self.seed & 0x7FFFFFFF, self.fingerprint])))
        n_windows = int(math.ceil(self.duration_s / self.window_s))
        for w in range(n_windows):
            t0 = w * self.window_s
            t1 = min(t0 + self.window_s, self.duration_s)
            probes = self.rate_fn(np.linspace(t0, t1, 65))
            lam = min(float(np.max(probes)) * 1.05 + 1e-12, self.rate_max)
            chunk = _thin_window(rng, t0, t1, lam, self.rate_fn)
            if chunk.size:
                yield chunk


class ChunkedMMPPTrace:
    """Bursty arrivals as a 2-state Markov-modulated Poisson process.

    The modulating chain alternates base periods (rate ``base_qps``,
    mean sojourn ``mean_gap_s``) and bursts (rate ``base_qps x
    burst_factor``, mean sojourn ``mean_burst_s``) with exponential
    sojourns — the renewal structure behind
    :func:`repro.serving.workload.bursty_pattern`, as a proper doubly
    stochastic process.  The burst rate is an *exact* envelope, so
    thinning here has no probing error.

    The modulating path is drawn from its own stream, so it does not
    depend on the window schedule; only the candidate draws do.  Chunks
    stream with O(window) memory like :class:`ChunkedPoissonTrace`.
    """

    kind = "mmpp"

    def __init__(self, base_qps: float = 1.5, *, burst_factor: float = 4.0,
                 mean_burst_s: float = 10.0, mean_gap_s: float = 25.0,
                 duration_s: float, seed: int = 0,
                 window_s: Optional[float] = None):
        if duration_s <= 0:
            raise ValueError("duration_s must be positive")
        if base_qps <= 0 or burst_factor < 1.0:
            raise ValueError("base_qps must be positive, burst_factor >= 1")
        if mean_burst_s <= 0 or mean_gap_s <= 0:
            raise ValueError("sojourn means must be positive")
        self.base_qps = float(base_qps)
        self.burst_factor = float(burst_factor)
        self.mean_burst_s = float(mean_burst_s)
        self.mean_gap_s = float(mean_gap_s)
        self.duration_s = float(duration_s)
        self.seed = int(seed)
        self.rate_max = self.base_qps * self.burst_factor
        if window_s is None:
            window_s = _DEFAULT_CHUNK_REQUESTS / self.rate_max
        self.window_s = float(min(max(window_s, 1e-3), self.duration_s))
        self.fingerprint = _fingerprint(
            b"mmpp" + np.float64(self.base_qps).tobytes()
            + np.float64(self.burst_factor).tobytes()
            + np.float64(self.mean_burst_s).tobytes()
            + np.float64(self.mean_gap_s).tobytes()
            + np.float64(self.duration_s).tobytes()
            + np.int64(self.seed).tobytes()
            + np.float64(self.window_s).tobytes())

    def chunks(self) -> Iterator[np.ndarray]:
        base = self.seed & 0x7FFFFFFF
        seg_rng = np.random.Generator(np.random.PCG64(
            np.random.SeedSequence([base, self.fingerprint, 1])))
        cand_rng = np.random.Generator(np.random.PCG64(
            np.random.SeedSequence([base, self.fingerprint, 2])))
        rates = (self.base_qps, self.rate_max)
        sojourns = (self.mean_gap_s, self.mean_burst_s)
        # lazily extended piecewise-constant modulating path
        seg_starts: List[float] = [0.0]
        seg_rates: List[float] = [rates[0]]
        state = 0
        seg_end = float(seg_rng.exponential(sojourns[0]))

        def extend_to(t: float) -> None:
            nonlocal state, seg_end
            while seg_end < t:
                state = 1 - state
                seg_starts.append(seg_end)
                seg_rates.append(rates[state])
                seg_end += float(seg_rng.exponential(sojourns[state]))

        def rate_fn(times: np.ndarray) -> np.ndarray:
            idx = np.searchsorted(starts_arr, times, side="right") - 1
            return rates_arr[idx]

        n_windows = int(math.ceil(self.duration_s / self.window_s))
        for w in range(n_windows):
            t0 = w * self.window_s
            t1 = min(t0 + self.window_s, self.duration_s)
            extend_to(t1)
            starts_arr = np.asarray(seg_starts)
            rates_arr = np.asarray(seg_rates)
            chunk = _thin_window(cand_rng, t0, t1, self.rate_max, rate_fn)
            # drop segments fully behind the window front (O(chunk) memory)
            cut = int(np.searchsorted(starts_arr, t1, side="right")) - 1
            if cut > 0:
                del seg_starts[:cut]
                del seg_rates[:cut]
            if chunk.size:
                yield chunk


def diurnal_trace(base_qps: float, *, amplitude: float = 0.8,
                  period_s: float = 86_400.0, duration_s: float,
                  seed: int = 0,
                  window_s: Optional[float] = None) -> ChunkedPoissonTrace:
    """Smooth diurnal cycle ``base x (1 + amplitude sin(2 pi t / T))`` —
    the day-scale load shape, defaulting to a 24 h period (the sweep-cell
    twin :func:`repro.serving.workload.diurnal_pattern` keeps its short
    demo period)."""
    if not 0.0 <= amplitude < 1.0:
        raise ValueError("amplitude must be in [0, 1)")

    def rate(t: np.ndarray) -> np.ndarray:
        return base_qps * (1.0 + amplitude * np.sin(2.0 * np.pi * t / period_s))

    label = f"diurnal:{base_qps!r}:{amplitude!r}:{period_s!r}"
    return ChunkedPoissonTrace(rate, duration_s, seed=seed, label=label,
                               rate_max=base_qps * (1.0 + amplitude) * 1.01,
                               window_s=window_s)


def flash_crowd_trace(base_qps: float, *, peak_factor: float = 10.0,
                      crowd_start_s: float, ramp_s: float = 5.0,
                      hold_s: float = 20.0, duration_s: float, seed: int = 0,
                      window_s: Optional[float] = None) -> ChunkedPoissonTrace:
    """Flash crowd: linear ramp to ``peak_factor x base``, a hold, and a
    symmetric ramp down — :func:`repro.serving.workload.flash_crowd_pattern`
    vectorized for chunked generation."""
    if peak_factor < 1.0:
        raise ValueError("peak_factor must be >= 1")
    peak = base_qps * peak_factor
    up0, up1 = crowd_start_s, crowd_start_s + ramp_s
    dn0, dn1 = up1 + hold_s, up1 + hold_s + ramp_s
    xp = [0.0, up0, up1, dn0, dn1, max(duration_s, dn1 + 1.0)]
    fp = [base_qps, base_qps, peak, peak, base_qps, base_qps]

    def rate(t: np.ndarray) -> np.ndarray:
        return np.interp(t, xp, fp)

    label = (f"flash:{base_qps!r}:{peak_factor!r}:{crowd_start_s!r}"
             f":{ramp_s!r}:{hold_s!r}")
    return ChunkedPoissonTrace(rate, duration_s, seed=seed, label=label,
                               rate_max=peak * 1.01, window_s=window_s)


def bursty_mmpp_trace(base_qps: float = 1.5, *, burst_factor: float = 4.0,
                      mean_burst_s: float = 10.0, mean_gap_s: float = 25.0,
                      duration_s: float, seed: int = 0,
                      window_s: Optional[float] = None) -> ChunkedMMPPTrace:
    """Bursty MMPP with the paper-pattern defaults (2-5x short bursts ->
    one representative 4x burst rate, 10 s mean bursts, 25 s mean gaps)."""
    return ChunkedMMPPTrace(base_qps, burst_factor=burst_factor,
                            mean_burst_s=mean_burst_s, mean_gap_s=mean_gap_s,
                            duration_s=duration_s, seed=seed,
                            window_s=window_s)


class StreamingQuantile:
    """Fixed-memory quantile sketch: a linear histogram over ``[0, hi)``
    that doubles its range (merging bin pairs exactly) whenever a value
    lands past it.  The reported quantile is the upper edge of the bin
    holding the target order statistic, so the error vs the exact order
    statistic is at most one bin width (``resolution_s``); counts are
    never approximated, only positions within a bin."""

    def __init__(self, num_bins: int = 8192, initial_max: float = 1.0):
        if num_bins < 2 or num_bins % 2:
            raise ValueError("num_bins must be an even integer >= 2")
        if initial_max <= 0:
            raise ValueError("initial_max must be positive")
        self._nb = int(num_bins)
        self._hi = float(initial_max)
        self._counts = np.zeros(self._nb, dtype=np.int64)
        self._n = 0

    def _double(self) -> None:
        merged = self._counts.reshape(-1, 2).sum(axis=1)
        self._counts = np.concatenate(
            [merged, np.zeros(self._nb // 2, dtype=np.int64)])
        self._hi *= 2.0

    def update(self, values: np.ndarray) -> None:
        x = np.asarray(values, dtype=float).ravel()
        if x.size == 0:
            return
        if np.any(x < 0.0):
            raise ValueError("StreamingQuantile tracks non-negative values")
        top = float(x.max())
        while top >= self._hi:
            self._double()
        idx = np.minimum((x * (self._nb / self._hi)).astype(np.int64),
                         self._nb - 1)
        self._counts += np.bincount(idx, minlength=self._nb)
        self._n += x.size

    @property
    def count(self) -> int:
        return self._n

    @property
    def resolution(self) -> float:
        """Current bin width — the quantile error bound."""
        return self._hi / self._nb

    def quantile(self, q: float) -> float:
        if not 0.0 <= q <= 1.0:
            raise ValueError("q must be in [0, 1]")
        if self._n == 0:
            return 0.0
        rank = max(int(math.ceil(q * self._n)), 1)
        cum = np.cumsum(self._counts)
        k = int(np.searchsorted(cum, rank, side="left"))
        return (k + 1) * self._hi / self._nb


@dataclass(frozen=True)
class ReplayStats:
    """Streamed per-configuration replay statistics.

    ``mean_wait_s`` / ``mean_latency_s`` / ``slo_compliance`` /
    ``max_latency_s`` / ``throughput_qps`` are exact over the full trace;
    ``p95_latency_s`` is the histogram estimate, exact to within
    ``p95_resolution_s`` (the sketch bin width)."""

    num_requests: int
    duration_s: float
    throughput_qps: float
    mean_wait_s: float
    mean_latency_s: float
    p95_latency_s: float
    p95_resolution_s: float
    slo_compliance: float
    max_latency_s: float
    slo_s: Optional[float]
    engine: str


def _resolve_replay_engine(backend: str, num_servers: int) -> str:
    """Pick the chunk engine: ``closed_form`` (vectorized numpy prefix
    scan, c = 1 only), ``jax`` (carried comparator scan, any c up to the
    fastsim bound), or ``loop`` (numpy per-request fallback for c > 1
    without jax)."""
    if backend not in ("auto", "numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "jax":
        if not jax_available():
            raise RuntimeError(
                f"backend='jax' requested but jax is not importable "
                f"({jax_unavailable_reason()})")
        if num_servers > _fs._JAX_MAX_SERVERS:
            raise ValueError(
                f"jax replay supports num_servers <= {_fs._JAX_MAX_SERVERS}")
        return "jax"
    if num_servers == 1:
        return "closed_form"
    if backend == "auto" and jax_available() \
            and num_servers <= _fs._JAX_MAX_SERVERS:
        return "jax"
    return "loop"


def _chunk_closed_form(A: np.ndarray, S: np.ndarray,
                       comp0: np.ndarray) -> Tuple[np.ndarray, np.ndarray,
                                                   np.ndarray]:
    """c = 1 Lindley chunk via the prefix-scan closed form.

    ``C_i = P_i + max(comp0, max_{j<=i}(A_j - P_{j-1}))`` with
    ``P = cumsum(S)`` — two cumulative ops instead of a per-request loop.
    Waits are clamped at zero: the closed form reassociates the additions,
    so an idle slot can come out at -1e-16 where the sequential recursion
    gives exactly 0 (agreement is allclose at ~1e-13, not bit-for-bit)."""
    P = np.cumsum(S, axis=0)
    M = np.maximum.accumulate(A[:, None] - (P - S), axis=0)
    C = P + np.maximum(M, comp0[None, :])
    waits = np.maximum(C - S - A[:, None], 0.0)
    lats = C - A[:, None]
    return waits, lats, C[-1].copy()


def _chunk_loop_kw(A: np.ndarray, S: np.ndarray,
                   F: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """c > 1 numpy fallback: the Kiefer-Wolfowitz step per request, with
    the (K, c) workload matrix carried in place."""
    n, K = S.shape
    waits = np.empty((n, K), dtype=float)
    lats = np.empty((n, K), dtype=float)
    for i in range(n):
        a = A[i]
        st = np.maximum(a, F[:, 0])
        ct = st + S[i]
        F[:, 0] = ct
        F.sort(axis=1)
        waits[i] = st - a
        lats[i] = ct - a
    return waits, lats


def _make_chunk_jax():
    """Build the jitted carried-state chunk scanner (shape-specialized on
    the padded chunk length and on c via ``F0.shape[0]``)."""
    _jax, _jnp = _fs._jax, _fs._jnp

    @_jax.jit
    def scan_chunk(A, S, F0):
        c = F0.shape[0]

        def step(F, inp):
            a, s = inp
            st = _jnp.maximum(a, F[0])
            ct = st + s
            cur = ct
            out = []
            for j in range(1, c):
                out.append(_jnp.minimum(F[j], cur))
                cur = _jnp.maximum(F[j], cur)
            out.append(cur)
            return _jnp.stack(out), (st - a, ct - a)

        F, (waits, lats) = _jax.lax.scan(step, F0, (A, S))
        return waits, lats, F

    return scan_chunk


def _make_chunk_dag_jax(num_stages: int):
    """Build the jitted fused tandem-chunk evaluator: one device program
    statically unrolled over the J stages, each replaying the c = 1
    closed form (``P = cumsum(S)``, ``M = cummax(A - (P - S))``,
    ``C = P + max(M, comp0)``) with stage j+1 consuming stage j's
    completions in-register.  Allclose (~1e-13) vs the numpy chunk, not
    bit-exact: XLA's ``cumsum`` may reassociate the prefix additions —
    the same caveat :func:`_chunk_closed_form` already carries vs the
    sequential recursion.  Returns per-stage waits, sojourns, departures
    and the carried backlog tails."""
    _jax, _jnp = _fs._jax, _fs._jnp

    @_jax.jit
    def chunk(A, S, comp0):           # (n,), (J, n), (J,)
        cur = A
        waits, lats, tails = [], [], []
        for j in range(num_stages):   # static unroll over stages
            s = S[j]
            P = _jnp.cumsum(s)
            M = _jax.lax.cummax(cur - (P - s))
            C = P + _jnp.maximum(M, comp0[j])
            waits.append(_jnp.maximum(C - s - cur, 0.0))
            lats.append(C - cur)
            tails.append(C[-1])
            cur = C
        return (_jnp.stack(waits), _jnp.stack(lats), cur,
                _jnp.stack(tails))

    return chunk


def replay_mix(trace, service_mean_s: Sequence[float],
               service_p95_s: Optional[Sequence[float]] = None, *,
               num_servers: int = 1, slo_s: Optional[float] = None,
               seed: int = 0, backend: str = "auto",
               quantile_bins: int = 8192) -> List[ReplayStats]:
    """Replay one chunked trace against every configuration of a mix
    ladder simultaneously, streaming the statistics.

    All K lanes see the *same* arrival chunks (common random numbers on
    the arrival process, the ``arrival_traces`` semantics of
    :func:`repro.serving.fastsim.simulate_batch`); each lane draws its own
    service stream keyed ``(seed, lane-config, trace-fingerprint)``.
    Memory is O(chunk x K) regardless of trace length.  ``backend``
    follows the fastsim convention ("auto" resolves per
    :func:`_resolve_replay_engine`; the chosen engine is reported in
    ``ReplayStats.engine``).
    """
    means = np.asarray(service_mean_s, dtype=float)
    if means.ndim != 1 or means.size == 0:
        raise ValueError("service_mean_s must be a non-empty 1-D sequence")
    if np.any(means <= 0):
        raise ValueError("service means must be positive")
    K = means.size
    if service_p95_s is not None:
        p95s = np.asarray(service_p95_s, dtype=float)
        if p95s.shape != means.shape:
            raise ValueError("service_p95_s must match service_mean_s")
        ln_params = [lognormal_params(m, p) for m, p in zip(means, p95s)]
        cfg_fps = [_fingerprint(b"ln" + np.float64(m).tobytes()
                                + np.float64(p).tobytes())
                   for m, p in zip(means, p95s)]
    else:
        ln_params = None
        cfg_fps = [_fingerprint(b"exp" + np.float64(m).tobytes())
                   for m in means]
    if num_servers < 1:
        raise ValueError("num_servers must be >= 1")
    c = int(num_servers)
    engine = _resolve_replay_engine(backend, c)

    base_seed = seed & 0x7FFFFFFF
    gens = [np.random.Generator(np.random.PCG64(np.random.SeedSequence(
        [base_seed, 2, cfg_fps[k], trace.fingerprint]))) for k in range(K)]

    count = 0
    wait_sum = np.zeros(K)
    lat_sum = np.zeros(K)
    ok = np.zeros(K, dtype=np.int64)
    max_lat = np.zeros(K)
    init_hi = max(4.0 * float(means.max()), float(slo_s or 0.0) * 2.0, 1e-6)
    sketches = [StreamingQuantile(quantile_bins, init_hi) for _ in range(K)]

    if engine == "jax":
        from jax.experimental import enable_x64
        scan_chunk = _make_chunk_jax()
        F = np.zeros((c, K), dtype=float)
    else:
        comp0 = np.zeros(K, dtype=float)
        F_loop = np.zeros((K, c), dtype=float)

    for A in trace.chunks():
        n = A.size
        S = np.empty((n, K), dtype=float)
        for k in range(K):
            if ln_params is not None:
                mu, sigma = ln_params[k]
                S[:, k] = gens[k].lognormal(mean=mu, sigma=sigma, size=n)
            else:
                S[:, k] = gens[k].exponential(scale=means[k], size=n)

        if engine == "closed_form":
            waits, lats, comp0 = _chunk_closed_form(A, S, comp0)
        elif engine == "loop":
            waits, lats = _chunk_loop_kw(A, S, F_loop)
        else:
            # pad to a power-of-two length (self-masking zero slots: they
            # dispatch instantly with zero service, leaving the carried
            # workload untouched) so jit specializes on few shapes
            pad = max(4096, 1 << (n - 1).bit_length()) - n
            Ap = np.pad(A, (0, pad))
            Sp = np.pad(S, ((0, pad), (0, 0)))
            with enable_x64():
                w, l, Fj = scan_chunk(_fs._jnp.asarray(Ap),
                                      _fs._jnp.asarray(Sp),
                                      _fs._jnp.asarray(F))
                waits = np.asarray(w)[:n]
                lats = np.asarray(l)[:n]
                F = np.asarray(Fj)

        count += n
        wait_sum += waits.sum(axis=0)
        lat_sum += lats.sum(axis=0)
        if slo_s is not None:
            ok += (lats <= slo_s).sum(axis=0)
        np.maximum(max_lat, lats.max(axis=0), out=max_lat)
        for k in range(K):
            sketches[k].update(lats[:, k])

    duration = float(trace.duration_s)
    n_eff = max(count, 1)
    out = []
    for k in range(K):
        out.append(ReplayStats(
            num_requests=count,
            duration_s=duration,
            throughput_qps=count / duration,
            mean_wait_s=float(wait_sum[k]) / n_eff,
            mean_latency_s=float(lat_sum[k]) / n_eff,
            p95_latency_s=sketches[k].quantile(0.95),
            p95_resolution_s=sketches[k].resolution,
            slo_compliance=(float(ok[k]) / n_eff if slo_s is not None
                            and count > 0 else 1.0),
            max_latency_s=float(max_lat[k]),
            slo_s=slo_s,
            engine=engine,
        ))
    return out


def replay_trace(trace, service_mean_s: float,
                 service_p95_s: Optional[float] = None, *,
                 num_servers: int = 1, slo_s: Optional[float] = None,
                 seed: int = 0, backend: str = "auto",
                 quantile_bins: int = 8192) -> ReplayStats:
    """Single-configuration convenience wrapper over :func:`replay_mix`."""
    return replay_mix(
        trace, [float(service_mean_s)],
        None if service_p95_s is None else [float(service_p95_s)],
        num_servers=num_servers, slo_s=slo_s, seed=seed, backend=backend,
        quantile_bins=quantile_bins)[0]


@dataclass(frozen=True)
class DagReplayStats:
    """Streamed tandem-pipeline replay: per-stage statistics (wait and
    sojourn measured at each stage's own arrival process) plus the
    end-to-end view (latency = sink completion - external arrival; wait =
    sum of per-stage queueing waits).  SLO compliance is end-to-end."""

    stages: Tuple[ReplayStats, ...]
    end_to_end: ReplayStats


def replay_dag(trace, stage_mean_s: Sequence[float],
               stage_p95_s: Optional[Sequence[float]] = None, *,
               slo_s: Optional[float] = None, seed: int = 0,
               backend: str = "auto",
               quantile_bins: int = 8192) -> DagReplayStats:
    """Stream one chunked trace through a *tandem* of single-server stages
    via chained closed-form Lindley recursions — stage n's departures are
    stage n+1's arrivals, chunk by chunk.

    Each stage carries its own backlog scalar across chunk boundaries;
    because a c = 1 FIFO stage's completions are non-decreasing, a chunk's
    departure vector is already a sorted arrival chunk for the next stage,
    so the chaining is exact over the whole trace (identical to replaying
    it unchunked).  One (mean, p95) pair per stage — the pinned pipeline
    rung — with service streams keyed ``(seed, stage, stage-config,
    trace-fingerprint)`` in the :func:`replay_mix` style.  Multi-server or
    fork-join pipelines need :func:`repro.serving.dag.sweep_pipeline` or
    the event-heap :class:`repro.serving.dag.DagSimulator`.

    ``backend`` follows the fastsim convention.  ``"auto"`` and
    ``"numpy"`` run the per-stage numpy closed form (engine
    ``"chained_closed_form"`` — the byte-stable reference, and the
    consistent ``"auto"`` resolution: this is the all-c = 1 case, where
    the flat replay resolves to ``closed_form`` too).  ``"jax"`` fuses
    all J stage recursions into one jitted device program per chunk
    (engine ``"chained_closed_form_jax"``), carrying the per-stage
    backlog vector across chunk boundaries on the host — allclose
    (~1e-13) agreement, identical content-keyed service draws.
    """
    if backend not in ("auto", "numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "jax" and not jax_available():
        raise RuntimeError(
            f"backend='jax' requested but jax is not importable "
            f"({jax_unavailable_reason()})")
    means = np.asarray(stage_mean_s, dtype=float)
    if means.ndim != 1 or means.size == 0:
        raise ValueError("stage_mean_s must be a non-empty 1-D sequence")
    if np.any(means <= 0):
        raise ValueError("stage service means must be positive")
    J = means.size
    if stage_p95_s is not None:
        p95s = np.asarray(stage_p95_s, dtype=float)
        if p95s.shape != means.shape:
            raise ValueError("stage_p95_s must match stage_mean_s")
        ln_params = [lognormal_params(m, p) for m, p in zip(means, p95s)]
        cfg_fps = [_fingerprint(b"ln" + np.float64(m).tobytes()
                                + np.float64(p).tobytes())
                   for m, p in zip(means, p95s)]
    else:
        ln_params = None
        cfg_fps = [_fingerprint(b"exp" + np.float64(m).tobytes())
                   for m in means]

    base_seed = seed & 0x7FFFFFFF
    gens = [np.random.Generator(np.random.PCG64(np.random.SeedSequence(
        [base_seed, 3, j, cfg_fps[j], trace.fingerprint])))
        for j in range(J)]

    count = 0
    wait_sum = np.zeros(J)
    lat_sum = np.zeros(J)
    e2e_lat_sum = 0.0
    e2e_ok = 0
    max_lat = np.zeros(J)
    e2e_max = 0.0
    stage_init = [max(4.0 * float(m), 1e-6) for m in means]
    e2e_init = max(4.0 * float(means.sum()), float(slo_s or 0.0) * 2.0, 1e-6)
    sketches = [StreamingQuantile(quantile_bins, hi) for hi in stage_init]
    e2e_sketch = StreamingQuantile(quantile_bins, e2e_init)
    comp0 = np.zeros(J, dtype=float)

    use_jax = backend == "jax"
    if use_jax:
        from jax.experimental import enable_x64
        chunk_jax = _make_chunk_dag_jax(J)

    for A in trace.chunks():
        n = A.size
        if use_jax and n:
            S = np.empty((J, n), dtype=float)
            for j in range(J):
                if ln_params is not None:
                    mu, sigma = ln_params[j]
                    S[j] = gens[j].lognormal(mean=mu, sigma=sigma, size=n)
                else:
                    S[j] = gens[j].exponential(scale=means[j], size=n)
            # pad to a power-of-two length so jit specializes on few
            # shapes; zero-arrival / zero-service pad slots replicate
            # each stage's last completion, leaving the carried backlog
            # tails untouched
            pad = max(4096, 1 << (n - 1).bit_length()) - n
            Ap = np.pad(A, (0, pad))
            Sp = np.pad(S, ((0, 0), (0, pad)))
            with enable_x64():
                wj, lj, dep, tails = chunk_jax(
                    _fs._jnp.asarray(Ap), _fs._jnp.asarray(Sp),
                    _fs._jnp.asarray(comp0))
                waits_g = np.asarray(wj)[:, :n]
                lats_g = np.asarray(lj)[:, :n]
                cur = np.asarray(dep)[:n]
                comp0 = np.asarray(tails)
            for j in range(J):
                wait_sum[j] += waits_g[j].sum()
                lat_sum[j] += lats_g[j].sum()
                max_lat[j] = max(max_lat[j], float(lats_g[j].max()))
                sketches[j].update(lats_g[j])
        else:
            cur = A
            for j in range(J):
                if ln_params is not None:
                    mu, sigma = ln_params[j]
                    S1 = gens[j].lognormal(mean=mu, sigma=sigma, size=n)
                else:
                    S1 = gens[j].exponential(scale=means[j], size=n)
                waits, lats, tail = _chunk_closed_form(cur, S1[:, None],
                                                       comp0[j:j + 1])
                comp0[j] = tail[0]
                w = waits[:, 0]
                l = lats[:, 0]
                wait_sum[j] += w.sum()
                lat_sum[j] += l.sum()
                if n:
                    max_lat[j] = max(max_lat[j], float(l.max()))
                sketches[j].update(l)
                cur = cur + l   # departures: arrivals + stage sojourns
        e2e = cur - A
        count += n
        e2e_lat_sum += e2e.sum()
        if slo_s is not None:
            e2e_ok += int((e2e <= slo_s).sum())
        if n:
            e2e_max = max(e2e_max, float(e2e.max()))
        e2e_sketch.update(e2e)

    duration = float(trace.duration_s)
    n_eff = max(count, 1)
    engine = "chained_closed_form_jax" if use_jax else "chained_closed_form"

    def stats(wsum: float, lsum: float, sketch: StreamingQuantile,
              mx: float, ok: Optional[int]) -> ReplayStats:
        return ReplayStats(
            num_requests=count,
            duration_s=duration,
            throughput_qps=count / duration,
            mean_wait_s=wsum / n_eff,
            mean_latency_s=lsum / n_eff,
            p95_latency_s=sketch.quantile(0.95),
            p95_resolution_s=sketch.resolution,
            slo_compliance=(ok / n_eff if ok is not None and count > 0
                            else 1.0),
            max_latency_s=mx,
            slo_s=slo_s,
            engine=engine,
        )

    stages = tuple(
        stats(float(wait_sum[j]), float(lat_sum[j]), sketches[j],
              float(max_lat[j]), None)
        for j in range(J))
    e2e_stats = stats(float(wait_sum.sum()), float(e2e_lat_sum), e2e_sketch,
                      float(e2e_max),
                      e2e_ok if slo_s is not None else None)
    return DagReplayStats(stages=stages, end_to_end=e2e_stats)
