"""Central request queue for the inference serving system (paper §III-B).

One thread-safe FIFO buffer shared by every worker of the M/G/c pool —
there is no per-worker queue, so whichever of the c workers frees first
pops the oldest request (or, with in-worker batching, the oldest *run* of
requests via :meth:`RequestQueue.get_batch`, optionally lingering up to a
batch timeout for the batch to fill).  By default the queue is unbounded
and never drops requests: during a configuration switch — whether the
global index flip of the homogeneous controller or a one-worker repin of
the mix controller — workers keep draining under the configurations they
hold until the new pinning takes effect.  Passing ``max_depth`` enables
admission control (beyond-paper): a ``put`` against a full buffer is
rejected and counted instead of enqueued, bounding worst-case queueing
delay under sustained overload.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

from .workload import Request


class RequestQueue:
    def __init__(self, max_depth: Optional[int] = None) -> None:
        if max_depth is not None and max_depth < 1:
            raise ValueError("max_depth must be >= 1 (or None for unbounded)")
        self._items: Deque[Request] = deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._closed = False
        self._max_depth = max_depth
        self._total_enqueued = 0
        self._total_dropped = 0
        # requests popped by an in-progress get_batch that has not returned
        # yet (a lingering worker's forming batch).  They are out of _items
        # but not yet in service: buffered() counts them so the controller
        # and the engine's drain logic see the same depth the simulator's
        # event loop reports for a forming batch.
        self._claimed = 0

    def put(self, request: Request) -> bool:
        """Enqueue; returns False (and counts a drop) if the buffer is full.

        Raises RuntimeError once the queue is closed to ingress.
        """
        with self._lock:
            if self._closed:
                raise RuntimeError("queue closed")
            # admission bounds the *buffered* count (waiting + claimed by a
            # lingering forming batch): claimed requests still occupy the
            # delay budget max_depth promises to bound, so vacating a deque
            # slot into a forming batch must not admit another request.
            if self._max_depth is not None and \
                    len(self._items) + self._claimed >= self._max_depth:
                self._total_dropped += 1
                return False
            self._items.append(request)
            self._total_enqueued += 1
            self._not_empty.notify()
            return True

    def get(self, timeout: Optional[float] = None) -> Optional[Request]:
        """Pop the oldest request (FIFO); None on timeout or closed+empty."""
        with self._not_empty:
            while not self._items:
                if self._closed:
                    return None
                if not self._not_empty.wait(timeout=timeout):
                    return None
            return self._items.popleft()

    def get_batch(self, max_size: int, timeout: Optional[float] = None,
                  linger_s: float = 0.0) -> List[Request]:
        """Pop up to ``max_size`` oldest requests as one batch (FIFO order).

        Blocks like :meth:`get` for the *first* request (up to ``timeout``;
        returns ``[]`` on timeout or closed+empty).  Once one request is
        held, the batch fills greedily from whatever is already buffered;
        if it is still short of ``max_size`` and ``linger_s > 0``, the
        caller lingers — waiting up to ``linger_s`` (wall-clock) for more
        arrivals — and returns the partial batch when the window expires or
        the queue closes.  ``max_size=1`` is exactly :meth:`get` (the batch
        is full at the first request, so the linger window never opens).
        """
        if max_size < 1:
            raise ValueError("max_size must be >= 1")
        with self._not_empty:
            while not self._items:
                if self._closed:
                    return []
                if not self._not_empty.wait(timeout=timeout):
                    return []
            batch = [self._items.popleft()]
            while len(batch) < max_size and self._items:
                batch.append(self._items.popleft())
            if len(batch) < max_size and linger_s > 0.0:
                deadline = time.monotonic() + linger_s
                try:
                    self._claimed += len(batch)
                    while len(batch) < max_size and not self._closed:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            break
                        self._not_empty.wait(timeout=remaining)
                        while len(batch) < max_size and self._items:
                            batch.append(self._items.popleft())
                            self._claimed += 1
                finally:
                    self._claimed -= len(batch)
            return batch

    def claimed(self) -> int:
        """Requests held in a lingering ``get_batch``'s forming batch."""
        with self._lock:
            return self._claimed

    def buffered(self) -> int:
        """Requests buffered but not in service: waiting in the queue plus
        claimed by a lingering batch.  This is the depth the AQM thresholds
        are stated in — it matches the simulator, whose forming batches stay
        in its waiting list.  Equals :meth:`depth` whenever no worker is
        mid-linger (in particular always for unbatched pools)."""
        with self._lock:
            return len(self._items) + self._claimed

    def depth(self) -> int:
        with self._lock:
            return len(self._items)

    @property
    def max_depth(self) -> Optional[int]:
        return self._max_depth

    @property
    def total_enqueued(self) -> int:
        with self._lock:
            return self._total_enqueued

    @property
    def total_dropped(self) -> int:
        with self._lock:
            return self._total_dropped

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
