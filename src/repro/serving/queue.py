"""Central request queue for the inference serving system (paper §III-B).

A thread-safe FIFO buffer.  The queue never drops requests: during a
configuration switch the executor keeps draining with the old configuration
until the new one is ready.
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Optional

from .workload import Request


class RequestQueue:
    def __init__(self) -> None:
        self._items: Deque[Request] = deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._closed = False
        self._total_enqueued = 0

    def put(self, request: Request) -> None:
        with self._lock:
            if self._closed:
                raise RuntimeError("queue closed")
            self._items.append(request)
            self._total_enqueued += 1
            self._not_empty.notify()

    def get(self, timeout: Optional[float] = None) -> Optional[Request]:
        """Pop the oldest request (FIFO); None on timeout or closed+empty."""
        with self._not_empty:
            while not self._items:
                if self._closed:
                    return None
                if not self._not_empty.wait(timeout=timeout):
                    return None
            return self._items.popleft()

    def depth(self) -> int:
        with self._lock:
            return len(self._items)

    @property
    def total_enqueued(self) -> int:
        with self._lock:
            return self._total_enqueued

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
