"""Central request queue for the inference serving system (paper §III-B).

A thread-safe FIFO buffer shared by all workers of the pool.  By default the
queue is unbounded and never drops requests: during a configuration switch
the executor keeps draining with the old configuration until the new one is
ready.  Passing ``max_depth`` enables admission control (beyond-paper): a
``put`` against a full buffer is rejected and counted instead of enqueued,
bounding worst-case queueing delay under sustained overload.
"""

from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Optional

from .workload import Request


class RequestQueue:
    def __init__(self, max_depth: Optional[int] = None) -> None:
        if max_depth is not None and max_depth < 1:
            raise ValueError("max_depth must be >= 1 (or None for unbounded)")
        self._items: Deque[Request] = deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._closed = False
        self._max_depth = max_depth
        self._total_enqueued = 0
        self._total_dropped = 0

    def put(self, request: Request) -> bool:
        """Enqueue; returns False (and counts a drop) if the buffer is full.

        Raises RuntimeError once the queue is closed to ingress.
        """
        with self._lock:
            if self._closed:
                raise RuntimeError("queue closed")
            if self._max_depth is not None and len(self._items) >= self._max_depth:
                self._total_dropped += 1
                return False
            self._items.append(request)
            self._total_enqueued += 1
            self._not_empty.notify()
            return True

    def get(self, timeout: Optional[float] = None) -> Optional[Request]:
        """Pop the oldest request (FIFO); None on timeout or closed+empty."""
        with self._not_empty:
            while not self._items:
                if self._closed:
                    return None
                if not self._not_empty.wait(timeout=timeout):
                    return None
            return self._items.popleft()

    def depth(self) -> int:
        with self._lock:
            return len(self._items)

    @property
    def max_depth(self) -> Optional[int]:
        return self._max_depth

    @property
    def total_enqueued(self) -> int:
        with self._lock:
            return self._total_enqueued

    @property
    def total_dropped(self) -> int:
        with self._lock:
            return self._total_dropped

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._not_empty.notify_all()
