"""Workflow executor + worker pool: process requests with the active config.

The executor owns the mapping config -> executable workflow.  All Pareto
configurations are kept *resident* (the paper pre-loads all configs in GPU
memory; here every config's parameters/compiled functions stay live), so a
switch only flips an index — the paper's <10 ms "pipeline rerouting".

:class:`WorkerPool` generalizes the runtime from the paper's single worker
(M/G/1) to ``c`` worker threads draining one shared :class:`RequestQueue`
(M/G/c).  ``c = 1`` reproduces the seed's single-worker engine behavior.
All record collection goes through the executor's lock, so a pool of any
size yields one consistent, thread-safe record list.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

from ..core.space import Config
from .queue import RequestQueue

WorkflowFn = Callable[[Config, Any], Any]
"""(config, payload) -> result.  One full compound-workflow execution."""


@dataclass
class ExecutionRecord:
    request_id: int
    arrival_s: float
    start_s: float
    completion_s: float
    config_index: int
    result: Any = None
    worker_id: int = 0

    @property
    def latency_s(self) -> float:
        return self.completion_s - self.arrival_s


class WorkflowExecutor:
    """Configuration-resident executor shared by every worker of the pool.

    ``configs`` is the Pareto ladder (index 0 = fastest); ``workflow_fn`` runs
    one request under a given configuration.  ``set_active`` is thread-safe
    and takes effect for the *next* request — in-flight requests always
    complete under the configuration they started with (no drops, §III-B).
    ``execute`` may be called concurrently from any number of workers;
    record collection and in-flight accounting are lock-protected.
    """

    def __init__(self, configs: Sequence[Config], workflow_fn: WorkflowFn,
                 *, clock: Callable[[], float] = time.monotonic) -> None:
        if not configs:
            raise ValueError("executor needs at least one configuration")
        self._configs = list(configs)
        self._workflow_fn = workflow_fn
        self._clock = clock
        self._active = len(configs) - 1
        self._lock = threading.Lock()
        self._in_flight = 0
        self.records: List[ExecutionRecord] = []

    @property
    def num_configs(self) -> int:
        return len(self._configs)

    def active_index(self) -> int:
        with self._lock:
            return self._active

    def set_active(self, index: int) -> None:
        if not 0 <= index < len(self._configs):
            raise IndexError(f"config index {index} out of range")
        with self._lock:
            self._active = index

    def in_flight(self) -> int:
        with self._lock:
            return self._in_flight

    def set_clock(self, clock: Callable[[], float]) -> None:
        """Align the executor's timestamps with the engine's relative clock.

        Request ``arrival_s`` values are engine-epoch-relative; the executor
        must stamp start/completion on the same axis or latencies come out
        shifted by the epoch (a real bug caught by examples/serve_adaptive).
        """
        self._clock = clock

    def execute(self, request_id: int, arrival_s: float, payload: Any,
                worker_id: int = 0) -> ExecutionRecord:
        with self._lock:
            idx = self._active
            self._in_flight += 1
        try:
            start = self._clock()
            result = self._workflow_fn(self._configs[idx], payload)
            end = self._clock()
        finally:
            with self._lock:
                self._in_flight -= 1
        rec = ExecutionRecord(
            request_id=request_id,
            arrival_s=arrival_s,
            start_s=start,
            completion_s=end,
            config_index=idx,
            result=result,
            worker_id=worker_id,
        )
        with self._lock:
            self.records.append(rec)
        return rec


class WorkerPool:
    """``c`` worker threads draining one shared request queue (M/G/c).

    Each worker loops: pop a request, fire the observe hook (the
    arrival-to-service boundary is where Elastico decides), execute under
    the currently active configuration, fire the hook again.  The hook is
    supplied by the engine and must be safe to call concurrently (the
    engine serializes controller access internally).

    ``c = 1`` is the paper-faithful single-worker server; the pool then
    behaves exactly like the seed's single ``compass-worker`` thread.
    """

    def __init__(
        self,
        executor: WorkflowExecutor,
        queue: RequestQueue,
        *,
        c: int = 1,
        on_observe: Optional[Callable[[], None]] = None,
        poll_timeout_s: float = 0.05,
        name: str = "compass-worker",
    ) -> None:
        if c < 1:
            raise ValueError("worker pool needs c >= 1 workers")
        self.executor = executor
        self.queue = queue
        self.c = c
        self._on_observe = on_observe
        self._poll_timeout_s = poll_timeout_s
        self._name = name
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._served_per_worker = [0] * c

    @property
    def num_workers(self) -> int:
        return self.c

    def served_per_worker(self) -> List[int]:
        """Requests completed by each worker (a load-balance observability
        hook; reads are benign-racy while the pool is running)."""
        return list(self._served_per_worker)

    def start(self) -> None:
        if self._threads:
            raise RuntimeError("worker pool already started")
        self._stop.clear()
        self._threads = [
            threading.Thread(
                target=self._worker_loop,
                args=(w,),
                name=f"{self._name}-{w}" if self.c > 1 else self._name,
                daemon=True,
            )
            for w in range(self.c)
        ]
        for t in self._threads:
            t.start()

    def in_flight(self) -> int:
        return self.executor.in_flight()

    def stop(self, *, join_timeout_s: float = 5.0) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=join_timeout_s)
        self._threads = []

    def _worker_loop(self, worker_id: int) -> None:
        while not self._stop.is_set():
            req = self.queue.get(timeout=self._poll_timeout_s)
            if req is None:
                continue
            if self._on_observe is not None:
                self._on_observe()   # arrival-to-service boundary decision
            self.executor.execute(req.request_id, req.arrival_s, req.payload,
                                  worker_id=worker_id)
            self._served_per_worker[worker_id] += 1
            if self._on_observe is not None:
                self._on_observe()
